"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes, ranks and dtypes; assert_allclose against ref.py
is the core correctness signal of the build path (see DESIGN.md §8).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.cp_project import cp_project, vmem_bytes as cp_vmem
from compile.kernels.gemm import gemm_project, vmem_bytes as gemm_vmem
from compile.kernels.tt_step import (
    tt_step,
    tt_step_blocked,
    vmem_bytes as tt_vmem,
)

# interpret=True Pallas is CPU-slow; keep hypothesis deadlines off.
COMMON = dict(deadline=None, max_examples=20)


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, dtype)


@settings(**COMMON)
@given(
    b=st.integers(1, 3),
    k=st.integers(1, 4),
    r=st.integers(1, 6),
    rt=st.integers(1, 6),
    d=st.integers(1, 5),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_tt_step_matches_ref(b, k, r, rt, d, dtype):
    # x64 is disabled on this image; sweep the two TPU-relevant dtypes.
    keys = jax.random.split(jax.random.PRNGKey(b * 1000 + k * 100 + r * 10 + d), 3)
    m = _rand(keys[0], (b, k, r, rt), dtype)
    g = _rand(keys[1], (k, r, d, r), dtype)
    x = _rand(keys[2], (b, rt, d, rt), dtype)
    got = tt_step(m, g, x)
    want = ref.tt_step_ref(m, g, x)
    assert got.dtype == want.dtype
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@settings(**COMMON)
@given(
    b=st.integers(1, 3),
    k=st.integers(1, 5),
    n=st.integers(2, 6),
    d=st.integers(1, 5),
    r=st.integers(1, 5),
    rt=st.integers(1, 4),
)
def test_cp_project_matches_ref(b, k, n, d, r, rt):
    keys = jax.random.split(jax.random.PRNGKey(n * 37 + d * 7 + r), 2)
    a = _rand(keys[0], (k, n, d, r), jnp.float32)
    x = _rand(keys[1], (b, n, d, rt), jnp.float32)
    scale = 1.0 / np.sqrt(k)
    got = cp_project(a, x, scale)
    want = ref.cp_project_ref(a, x, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(**COMMON)
@given(
    b=st.sampled_from([1, 2, 4, 8]),
    k=st.sampled_from([4, 8, 16]),
    d=st.sampled_from([32, 64, 128, 256]),
)
def test_gemm_matches_ref(b, k, d):
    keys = jax.random.split(jax.random.PRNGKey(b + k + d), 2)
    x = _rand(keys[0], (b, d), jnp.float32)
    w = _rand(keys[1], (k, d), jnp.float32)
    got = gemm_project(x, w, 0.5, bm=min(b, 128), bn=min(k, 128), bk=min(d, 64))
    want = ref.gemm_project_ref(w, x, 0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(**COMMON)
@given(
    b=st.integers(1, 2),
    kblocks=st.integers(1, 3),
    kb=st.sampled_from([1, 2, 4]),
    r=st.integers(1, 4),
    rt=st.integers(1, 4),
    d=st.integers(1, 4),
)
def test_tt_step_blocked_matches_unblocked(b, kblocks, kb, r, rt, d):
    k = kblocks * kb
    keys = jax.random.split(jax.random.PRNGKey(k * 97 + r * 11 + d), 3)
    m = _rand(keys[0], (b, k, r, rt), jnp.float32)
    g = _rand(keys[1], (k, r, d, r), jnp.float32)
    x = _rand(keys[2], (b, rt, d, rt), jnp.float32)
    got = tt_step_blocked(m, g, x, kb=kb)
    want = tt_step(m, g, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_tt_step_blocked_rejects_non_dividing_block():
    m = jnp.zeros((1, 3, 2, 2), jnp.float32)
    g = jnp.zeros((3, 2, 2, 2), jnp.float32)
    x = jnp.zeros((1, 2, 2, 2), jnp.float32)
    with pytest.raises(AssertionError):
        tt_step_blocked(m, g, x, kb=2)


def test_blocked_vmem_scales_with_kb():
    assert tt_vmem(5, 10, 3, kb=8) > tt_vmem(5, 10, 3, kb=1)
    # The medium-config blocked kernel still fits VMEM easily.
    assert tt_vmem(5, 10, 3, kb=128) < 16 * 1024 * 1024 // 4


def test_gemm_rejects_non_dividing_tiles():
    x = jnp.zeros((3, 10), jnp.float32)
    w = jnp.zeros((2, 10), jnp.float32)
    with pytest.raises(AssertionError):
        gemm_project(x, w, 1.0, bm=2, bn=2, bk=10)


def test_tt_chain_equals_dense_inner_product():
    """End-to-end L1 check: the full boundary-matrix chain equals the inner
    product of the materialized TT tensors."""
    key = jax.random.PRNGKey(7)
    n, d, r, rt = 5, 3, 3, 2
    ks = jax.random.split(key, 6)
    g_first = _rand(ks[0], (1, d, r), jnp.float32)
    g_mid = _rand(ks[1], (1, n - 2, r, d, r), jnp.float32)
    g_last = _rand(ks[2], (1, r, d), jnp.float32)
    x_first = _rand(ks[3], (1, d, rt), jnp.float32)
    x_mid = _rand(ks[4], (1, n - 2, rt, d, rt), jnp.float32)
    x_last = _rand(ks[5], (1, rt, d), jnp.float32)

    m = ref.tt_boundary_init(g_first, x_first)
    for i in range(n - 2):
        m = tt_step(m, g_mid[:, i], x_mid[:, i])
    y = ref.tt_finalize(m, g_last, x_last)[0, 0]

    g_dense = ref.tt_to_dense(g_first[0], g_mid[0], g_last[0])
    x_dense = ref.tt_to_dense(x_first[0], x_mid[0], x_last[0])
    want = jnp.sum(g_dense * x_dense)
    np.testing.assert_allclose(float(y), float(want), rtol=1e-4)


def test_cp_ref_equals_dense_inner_product():
    key = jax.random.PRNGKey(9)
    n, d, r, rt = 4, 3, 3, 2
    ks = jax.random.split(key, 2)
    a = _rand(ks[0], (1, n, d, r), jnp.float32)
    x = _rand(ks[1], (1, n, d, rt), jnp.float32)
    y = ref.cp_project_ref(a, x, 1.0)[0, 0]
    a_dense = ref.cp_to_dense(a[0])
    x_dense = ref.cp_to_dense(x[0])
    want = jnp.sum(a_dense * x_dense)
    np.testing.assert_allclose(float(y), float(want), rtol=1e-4)


def test_vmem_estimates_fit_tpu_budget():
    """DESIGN.md §Hardware-Adaptation: the artifact-config working sets must
    fit a 16 MiB VMEM with ample slack."""
    budget = 16 * 1024 * 1024
    assert tt_vmem(r=5, rt=10, d=3) < budget // 100
    assert cp_vmem(n=12, d=3, r=25, rt=10) < budget // 100
    assert gemm_vmem(128, 128, 128) < budget // 4
