"""AOT pipeline CLI behaviour + artifact-set invariants."""

import json
import os
import subprocess
import sys
import tempfile

import pytest

from compile import aot

PY_DIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_aot(*extra):
    out = tempfile.mkdtemp(prefix="trp_aot_")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", out, *extra],
        cwd=PY_DIR,
        check=True,
        capture_output=True,
    )
    return out


def test_only_flag_lowers_single_artifact():
    out = run_aot("--only", "tt_rp_medium")
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    assert [a["name"] for a in manifest["artifacts"]] == ["tt_rp_medium"]
    assert os.path.exists(os.path.join(out, "tt_rp_medium.hlo.txt"))


def test_skip_pallas_excludes_pallas_artifacts():
    out = run_aot("--skip-pallas")
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    names = [a["name"] for a in manifest["artifacts"]]
    assert "tt_rp_medium" in names
    assert all(not a["use_pallas"] for a in manifest["artifacts"])


def test_artifact_set_covers_paper_regimes():
    """The compiled set must cover: medium-order TT (ref + pallas), medium
    CP, small dense, small TT — the serving configs of DESIGN.md §7."""
    names = {a["name"] for a in aot.ARTIFACTS}
    assert {
        "tt_rp_medium",
        "tt_rp_medium_pallas",
        "cp_rp_medium",
        "gauss_small",
        "tt_rp_small",
    } <= names
    for spec in aot.ARTIFACTS:
        cfg = spec["cfg"]
        # Batch and k are positive; scale is 1/sqrt(k).
        assert cfg.k > 0 and cfg.batch > 0
        entry = aot.artifact_manifest_entry(spec["name"], spec["kind"], cfg)
        assert abs(entry["scale"] - cfg.k ** -0.5) < 1e-12
        # Parameter shapes are consistent with the config's own shapes.
        assert entry["params"] == [
            {"name": n, "shape": list(s)} for n, s in cfg.param_shapes()
        ]


def test_medium_configs_match_paper_regime():
    tt = next(s for s in aot.ARTIFACTS if s["name"] == "tt_rp_medium")["cfg"]
    assert (tt.n_modes, tt.dim, tt.input_rank) == (12, 3, 10)
    small = next(s for s in aot.ARTIFACTS if s["name"] == "gauss_small")["cfg"]
    assert small.input_dim == 15 ** 3


def test_unknown_kind_rejected():
    with pytest.raises(ValueError):
        aot.build_fn("tucker", None)
