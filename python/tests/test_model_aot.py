"""L2/AOT tests: model graphs vs references, pallas vs non-pallas paths,
manifest consistency, and HLO-text round-trip through the XLA client —
the same load path the Rust runtime uses."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def _params_for(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    out = []
    for i, (_, shape) in enumerate(cfg.param_shapes()):
        key, sub = jax.random.split(key)
        out.append(jax.random.normal(sub, shape, jnp.float32))
    return out


SMALL_TT = model.TtConfig(
    n_modes=5, dim=3, rank=3, input_rank=2, k=6, batch=2, use_pallas=True
)
SMALL_CP = model.CpConfig(
    n_modes=4, dim=3, rank=4, input_rank=2, k=5, batch=2, use_pallas=True
)
SMALL_DENSE = model.DenseConfig(input_dim=64, k=8, batch=4, use_pallas=True)


def test_tt_model_pallas_equals_ref_path():
    cfg_ref = model.TtConfig(**{**SMALL_TT.__dict__, "use_pallas": False})
    params = _params_for(SMALL_TT)
    y_pallas = model.tt_project_fn(SMALL_TT)(*params)[0]
    y_ref = model.tt_project_fn(cfg_ref)(*params)[0]
    np.testing.assert_allclose(np.asarray(y_pallas), np.asarray(y_ref), rtol=1e-5)


def test_cp_model_pallas_equals_ref_path():
    cfg_ref = model.CpConfig(**{**SMALL_CP.__dict__, "use_pallas": False})
    params = _params_for(SMALL_CP)
    y_pallas = model.cp_project_fn(SMALL_CP)(*params)[0]
    y_ref = model.cp_project_fn(cfg_ref)(*params)[0]
    np.testing.assert_allclose(np.asarray(y_pallas), np.asarray(y_ref), rtol=1e-5)


def test_dense_model_pallas_equals_ref_path():
    cfg_ref = model.DenseConfig(**{**SMALL_DENSE.__dict__, "use_pallas": False})
    params = _params_for(SMALL_DENSE)
    y_pallas = model.dense_project_fn(SMALL_DENSE)(*params)[0]
    y_ref = model.dense_project_fn(cfg_ref)(*params)[0]
    np.testing.assert_allclose(np.asarray(y_pallas), np.asarray(y_ref), rtol=1e-4)


def test_tt_model_output_shape_and_scale():
    params = _params_for(SMALL_TT)
    y = model.tt_project_fn(SMALL_TT)(*params)[0]
    assert y.shape == (SMALL_TT.batch, SMALL_TT.k)
    # Doubling k halves the scale; same params truncated is not meaningful,
    # so just check the scale property directly.
    assert np.isclose(SMALL_TT.scale, 1.0 / np.sqrt(SMALL_TT.k))


def test_largest_divisor():
    assert model._largest_divisor(3375, 128) == 125
    assert model._largest_divisor(128, 128) == 128
    assert model._largest_divisor(7, 4) == 1


def test_aot_writes_artifacts_and_manifest(tmp_path=None):
    out = tempfile.mkdtemp()
    lowered = aot.lower_artifact("tt", SMALL_TT)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # Manifest entry matches the config.
    entry = aot.artifact_manifest_entry("small_tt", "tt", SMALL_TT)
    assert entry["k"] == SMALL_TT.k
    assert entry["params"][0]["name"] == "g_first"
    assert entry["output_shape"] == [SMALL_TT.batch, SMALL_TT.k]
    del out


def test_hlo_text_parses_back():
    """The emitted HLO text must parse back into an HLO module. (The
    authoritative execute-and-compare round-trip lives on the Rust side in
    rust/tests/runtime_pjrt.rs, against the same artifacts.)"""
    from jax._src.lib import xla_client as xc

    cfg = SMALL_DENSE
    lowered = aot.lower_artifact("dense", cfg)
    text = aot.to_hlo_text(lowered)
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None
    want = model.dense_project_fn(cfg)(*_params_for(cfg, seed=3))[0]
    assert want.shape == (cfg.batch, cfg.k)


def test_repo_manifest_is_consistent_with_artifacts():
    """If `make artifacts` has run, the manifest must describe every file."""
    art_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(art_dir, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    with open(mpath) as f:
        manifest = json.load(f)
    assert manifest["format_version"] == 1
    for entry in manifest["artifacts"]:
        fpath = os.path.join(art_dir, entry["file"])
        assert os.path.exists(fpath), f"missing artifact {entry['file']}"
        with open(fpath) as f:
            head = f.read(200)
        assert "HloModule" in head
        assert entry["output_shape"] == [entry["batch"], entry["k"]]
        # Parameter count sanity: tt has 6 params, cp/dense have 2.
        expected = 6 if entry["kind"] == "tt" else 2
        assert len(entry["params"]) == expected
