"""L2: JAX compute graphs for the projection maps (build-time only).

Each function here assembles the full batched projection for one artifact
configuration, calling the L1 Pallas kernels for the hot contractions (or
the einsum references when ``use_pallas=False`` — both lower to the same
interface and are cross-checked by pytest).

The random projection parameters (TT cores / CP factors / dense matrix)
are *runtime inputs* of the compiled function, not baked constants: one
artifact serves any seed. The Rust coordinator draws the parameters with
its own RNG and feeds them as PJRT literals.

Stacked-core layouts match `kernels/ref.py` (and the Rust runtime packs
the same layouts — see ``rust/src/runtime/pack.rs``).
"""

import math
from dataclasses import dataclass

from .kernels import cp_project as cp_kernel
from .kernels import gemm as gemm_kernel
from .kernels import ref
from .kernels import tt_step as tt_kernel


@dataclass(frozen=True)
class TtConfig:
    """Shape configuration of one f_TT(R) artifact (uniform d and ranks)."""

    n_modes: int
    dim: int
    rank: int  # projection TT rank R
    input_rank: int  # input TT rank R~
    k: int  # embedding dimension
    batch: int  # compiled request batch B
    use_pallas: bool = True

    @property
    def scale(self) -> float:
        return 1.0 / math.sqrt(self.k)

    def param_shapes(self):
        """Ordered (name, shape) of the compiled function's parameters."""
        n, d, r, rt, k, b = (
            self.n_modes,
            self.dim,
            self.rank,
            self.input_rank,
            self.k,
            self.batch,
        )
        return [
            ("g_first", (k, d, r)),
            ("g_mid", (k, n - 2, r, d, r)),
            ("g_last", (k, r, d)),
            ("x_first", (b, d, rt)),
            ("x_mid", (b, n - 2, rt, d, rt)),
            ("x_last", (b, rt, d)),
        ]


@dataclass(frozen=True)
class CpConfig:
    """Shape configuration of one f_CP(R) artifact."""

    n_modes: int
    dim: int
    rank: int
    input_rank: int
    k: int
    batch: int
    use_pallas: bool = True

    @property
    def scale(self) -> float:
        return 1.0 / math.sqrt(self.k)

    def param_shapes(self):
        n, d, r, rt, k, b = (
            self.n_modes,
            self.dim,
            self.rank,
            self.input_rank,
            self.k,
            self.batch,
        )
        return [
            ("a", (k, n, d, r)),
            ("x", (b, n, d, rt)),
        ]


@dataclass(frozen=True)
class DenseConfig:
    """Shape configuration of one dense Gaussian RP artifact."""

    input_dim: int
    k: int
    batch: int
    use_pallas: bool = True

    @property
    def scale(self) -> float:
        return 1.0 / math.sqrt(self.k)

    def param_shapes(self):
        return [
            ("w", (self.k, self.input_dim)),
            ("x", (self.batch, self.input_dim)),
        ]


def tt_project_fn(cfg: TtConfig):
    """Build the batched f_TT(R)-on-TT-input function: params → y [B, k]."""

    def fn(g_first, g_mid, g_last, x_first, x_mid, x_last):
        m = ref.tt_boundary_init(g_first, x_first)
        for i in range(cfg.n_modes - 2):
            if cfg.use_pallas:
                m = tt_kernel.tt_step(m, g_mid[:, i], x_mid[:, i])
            else:
                m = ref.tt_step_ref(m, g_mid[:, i], x_mid[:, i])
        return (ref.tt_finalize(m, g_last, x_last) * cfg.scale,)

    return fn


def cp_project_fn(cfg: CpConfig):
    """Build the batched f_CP(R)-on-CP-input function: params → y [B, k]."""

    def fn(a, x):
        if cfg.use_pallas:
            y = cp_kernel.cp_project(a, x, cfg.scale)
        else:
            y = ref.cp_project_ref(a, x, cfg.scale)
        return (y,)

    return fn


def dense_project_fn(cfg: DenseConfig):
    """Build the batched dense Gaussian RP function: params → y [B, k]."""

    def fn(w, x):
        if cfg.use_pallas:
            # Pick tile sizes that divide the problem exactly.
            bm = _largest_divisor(cfg.batch, 128)
            bn = _largest_divisor(cfg.k, 128)
            bk = _largest_divisor(cfg.input_dim, 128)
            y = gemm_kernel.gemm_project(x, w, cfg.scale, bm=bm, bn=bn, bk=bk)
        else:
            y = ref.gemm_project_ref(w, x, cfg.scale)
        return (y,)

    return fn


def _largest_divisor(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is ≤ cap (≥ 1)."""
    for c in range(min(cap, n), 0, -1):
        if n % c == 0:
            return c
    return 1
