"""Tiled-GEMM Pallas kernel for the dense Gaussian RP baseline (Layer 1).

``y[B, K] = scale · x[B, D] @ w[K, D]ᵀ`` with a classic blocked matmul:
grid over (B/bm, K/bn, D/bk) tiles, an f32 accumulator tile resident in
VMEM, and the reduction dimension as the innermost (sequential) grid axis.
This is the direct MXU mapping described in DESIGN.md §Hardware-Adaptation;
block sizes default to MXU-friendly 128 but shrink to the problem size.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(x_ref, w_ref, o_ref, *, scale, n_k_blocks):
    """Tile (i, j, kb): accumulate x-tile @ w-tileᵀ into the output tile."""
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...] @ w_ref[...].T

    @pl.when(kb == n_k_blocks - 1)
    def _finish():
        o_ref[...] = o_ref[...] * scale


def gemm_project(x, w, scale, bm=128, bn=128, bk=128):
    """Dense projection ``scale·x@wᵀ`` via a tiled Pallas matmul.

    x: [B, D], w: [K, D] → y [B, K].
    """
    b, d = x.shape
    k, _ = w.shape
    bm = min(bm, b)
    bn = min(bn, k)
    bk = min(bk, d)
    assert b % bm == 0 and k % bn == 0 and d % bk == 0, (
        f"tile sizes must divide the problem: ({b},{k},{d}) vs ({bm},{bn},{bk})"
    )
    n_k_blocks = d // bk
    kernel = functools.partial(_gemm_kernel, scale=scale, n_k_blocks=n_k_blocks)
    return pl.pallas_call(
        kernel,
        grid=(b // bm, k // bn, n_k_blocks),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kb: (i, kb)),
            pl.BlockSpec((bn, bk), lambda i, j, kb: (j, kb)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, k), x.dtype),
        interpret=True,
    )(x, w)


def vmem_bytes(bm=128, bn=128, bk=128, dtype_bytes=4):
    """Static VMEM footprint per grid cell: x-tile + w-tile + accumulator."""
    return dtype_bytes * (bm * bk + bn * bk + bm * bn)
