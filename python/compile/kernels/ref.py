"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has an exact einsum counterpart here;
pytest/hypothesis assert elementwise agreement. These references are also
used directly by `model.py` when a config opts out of the Pallas path
(`use_pallas=False`), so the AOT artifacts can be built either way.

Shape conventions (uniform mode size ``d``, uniform ranks):

* TT projection rows, stacked over the embedding dimension ``k``:
  ``g_first  [k, d, R]`` — first cores (left rank 1 squeezed),
  ``g_mid    [k, N-2, R, d, R]`` — interior cores,
  ``g_last   [k, R, d]`` — last cores (right rank 1 squeezed).
* TT inputs, stacked over the request batch ``B``:
  ``x_first  [B, d, Rt]``, ``x_mid [B, N-2, Rt, d, Rt]``, ``x_last [B, Rt, d]``.
* CP projection rows: ``a [k, N, d, R]``; CP inputs: ``x [B, N, d, Rt]``.
* Dense: ``w [k, D]``; inputs ``x [B, D]``.
"""

import jax.numpy as jnp


def tt_boundary_init(g_first, x_first):
    """First-mode contraction: M[b,k,r,t] = sum_j g_first[k,j,r]·x_first[b,j,t]."""
    return jnp.einsum("kjr,bjt->bkrt", g_first, x_first)


def tt_step_ref(m, g, x):
    """One interior-mode update of the TT×TT boundary matrix.

    m: [B, k, R, Rt], g: [k, R, d, R], x: [B, Rt, d, Rt] → [B, k, R, Rt].
    """
    # tmp[b,k,j,r2,t] = sum_r m[b,k,r,t] g[k,r,j,r2]
    tmp = jnp.einsum("bkrt,krjs->bkjst", m, g)
    # out[b,k,r2,t2] = sum_{j,t} tmp[b,k,j,r2,t] x[b,t,j,t2]
    return jnp.einsum("bkjst,btju->bksu", tmp, x)


def tt_finalize(m, g_last, x_last):
    """Last-mode contraction: y[b,k] = sum_{r,t,j} m[b,k,r,t]·g_last[k,r,j]·x_last[b,t,j]."""
    return jnp.einsum("bkrt,krj,btj->bk", m, g_last, x_last)


def tt_project_ref(g_first, g_mid, g_last, x_first, x_mid, x_last, scale):
    """Full f_TT(R) on TT inputs: [B, k] projections (already scaled by 1/√k)."""
    m = tt_boundary_init(g_first, x_first)
    n_mid = g_mid.shape[1]
    for i in range(n_mid):
        m = tt_step_ref(m, g_mid[:, i], x_mid[:, i])
    return tt_finalize(m, g_last, x_last) * scale


def cp_mode_product(a, x):
    """Per-mode CP Gram product: G[b,k,r,t] = sum_i a[k,i,r]·x[b,i,t]."""
    return jnp.einsum("kir,bit->bkrt", a, x)


def cp_project_ref(a, x, scale):
    """Full f_CP(R) on CP inputs.

    a: [k, N, d, R], x: [B, N, d, Rt] → y [B, k] = scale·Σ_{r,t} Π_n G_n.
    """
    n = a.shape[1]
    h = cp_mode_product(a[:, 0], x[:, 0])
    for i in range(1, n):
        h = h * cp_mode_product(a[:, i], x[:, i])
    return jnp.sum(h, axis=(2, 3)) * scale


def gemm_project_ref(w, x, scale):
    """Dense Gaussian RP: y [B, k] = scale·x @ wᵀ."""
    return (x @ w.T) * scale


def tt_to_dense(first, mid, last):
    """Materialize a (single) stacked-core TT tensor — test helper only.

    first: [d, R], mid: [N-2, R, d, R], last: [R, d] → dense [d]*N.
    """
    t = first  # [d1, r]
    n_mid = mid.shape[0]
    d = first.shape[0]
    for i in range(n_mid):
        core = mid[i]  # [r, d, r2]
        r, dd, r2 = core.shape
        t = jnp.reshape(t, (-1, r)) @ jnp.reshape(core, (r, dd * r2))
        t = jnp.reshape(t, (-1, r2))
    t = jnp.reshape(t, (-1, last.shape[0])) @ last  # [(d^{N-1}), d]
    n = n_mid + 2
    return jnp.reshape(t, (d,) * n)


def cp_to_dense(factors):
    """Materialize a CP tensor from factors [N, d, R] — test helper only."""
    n, d, r = factors.shape
    m = factors[0]  # [d, R]
    for i in range(1, n):
        m = jnp.reshape(m[:, None, :] * factors[i][None, :, :], (-1, r))
    return jnp.reshape(jnp.sum(m, axis=1), (d,) * n)
