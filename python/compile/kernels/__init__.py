"""L1: Pallas kernels for the projection hot-spots + pure-jnp oracles.

* ``tt_step``    — boundary-matrix update of f_TT(R) on TT inputs,
* ``cp_project`` — fused per-mode Gram/Hadamard of f_CP(R) on CP inputs,
* ``gemm``       — tiled matmul for the dense Gaussian RP baseline,
* ``ref``        — einsum oracles for all of the above.
"""

from . import cp_project, gemm, ref, tt_step  # noqa: F401
