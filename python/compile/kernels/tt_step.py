"""Pallas kernel for the TT-projection boundary update — the hot spot of
``f_TT(R)`` on TT inputs (Layer 1).

One interior mode of the contraction chain updates, for every (batch b,
output component k), the boundary matrix ``M ∈ R^{R×Rt}``:

    M'[r2, t2] = Σ_{r, j, t}  M[r, t] · G[r, j, r2] · X[t, j, t2]

The kernel fuses both contractions per (b, k) grid cell, holding the M
slab and one projection core in VMEM while streaming the input core.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid is (B, k) so
each program instance owns an ``R×Rt`` slab — MXU-shaped matmuls of size
``R×Rt`` per mode index — and the BlockSpec index maps express the
HBM↔VMEM schedule a CUDA implementation would express with threadblocks.
``interpret=True`` everywhere on this image: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so lowering stays in plain HLO.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tt_step_kernel(m_ref, g_ref, x_ref, o_ref):
    """Grid cell (b, k): update one boundary matrix.

    Block shapes: m [R, Rt], g [R, d, R2], x [Rt, d, Rt2] → o [R2, Rt2].
    """
    m = m_ref[0, 0, :, :]
    g = g_ref[0, :, :, :]
    x = x_ref[0, :, :, :]
    r, d, r2 = g.shape
    t, _, t2 = x.shape
    # tmp[j, r2, t] = Σ_r g[r, j, r2]·m[r, t] — one (d·R2)×R by R×Rt matmul.
    gm = jnp.reshape(jnp.transpose(g, (1, 2, 0)), (d * r2, r))  # [(j r2), r]
    tmp = jnp.reshape(gm @ m, (d, r2, t))  # [j, r2, t]
    # out[r2, t2] = Σ_{j,t} tmp[j, r2, t]·x[t, j, t2] — R2×(d·Rt) by (d·Rt)×Rt2.
    lhs = jnp.reshape(jnp.transpose(tmp, (1, 0, 2)), (r2, d * t))  # [r2, (j t)]
    rhs = jnp.reshape(jnp.transpose(x, (1, 0, 2)), (d * t, t2))  # [(j t), t2]
    o_ref[0, 0, :, :] = lhs @ rhs


@functools.partial(jax.jit, static_argnames=())
def tt_step(m, g, x):
    """Batched boundary update via Pallas.

    m: [B, K, R, Rt], g: [K, R, d, R2], x: [B, Rt, d, Rt2] → [B, K, R2, Rt2].
    """
    bsz, k, r, t = m.shape
    _, _, d, r2 = g.shape
    t2 = x.shape[-1]
    return pl.pallas_call(
        _tt_step_kernel,
        grid=(bsz, k),
        in_specs=[
            pl.BlockSpec((1, 1, r, t), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, r, d, r2), lambda b, i: (i, 0, 0, 0)),
            pl.BlockSpec((1, t, d, t2), lambda b, i: (b, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, r2, t2), lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, k, r2, t2), m.dtype),
        interpret=True,
    )(m, g, x)


def _tt_step_kernel_blocked(m_ref, g_ref, x_ref, o_ref):
    """Grid cell (b, k-block): update KB boundary matrices at once.

    Block shapes: m [1, KB, R, Rt], g [KB, R, d, R2], x [1, Rt, d, Rt2] →
    o [1, KB, R2, Rt2]. Batching KB output components per grid cell
    amortizes the streamed X core across KB boundary updates — the VMEM
    trade-off knob of DESIGN.md §Hardware-Adaptation: VMEM grows by
    KB·(R·Rt + R·d·R2) while X-core HBM traffic drops by KB×.
    """
    m = m_ref[0]  # [KB, R, Rt]
    g = g_ref[...]  # [KB, R, d, R2]
    x = x_ref[0]  # [Rt, d, Rt2]
    kb, r, d, r2 = g.shape
    t, _, t2 = x.shape
    # tmp[kb, j, r2, t] = Σ_r g[kb, r, j, r2]·m[kb, r, t]
    gm = jnp.reshape(jnp.transpose(g, (0, 2, 3, 1)), (kb, d * r2, r))
    tmp = jnp.reshape(gm @ m, (kb, d, r2, t))
    # out[kb, r2, t2] = Σ_{j,t} tmp[kb, j, r2, t]·x[t, j, t2]
    lhs = jnp.reshape(jnp.transpose(tmp, (0, 2, 1, 3)), (kb, r2, d * t))
    rhs = jnp.reshape(jnp.transpose(x, (1, 0, 2)), (d * t, t2))
    o_ref[0] = lhs @ rhs


def tt_step_blocked(m, g, x, kb=8):
    """K-blocked variant of :func:`tt_step` (requires ``kb | K``)."""
    bsz, k, r, t = m.shape
    _, _, d, r2 = g.shape
    t2 = x.shape[-1]
    assert k % kb == 0, f"k-block {kb} must divide k={k}"
    return pl.pallas_call(
        _tt_step_kernel_blocked,
        grid=(bsz, k // kb),
        in_specs=[
            pl.BlockSpec((1, kb, r, t), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((kb, r, d, r2), lambda b, i: (i, 0, 0, 0)),
            pl.BlockSpec((1, t, d, t2), lambda b, i: (b, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, kb, r2, t2), lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, k, r2, t2), m.dtype),
        interpret=True,
    )(m, g, x)


def vmem_bytes(r, rt, d, dtype_bytes=4, kb=1):
    """Static VMEM footprint estimate for one grid cell (DESIGN.md §Perf):
    M slabs + G cores + X core + output slabs + the two reshaped operands.
    ``kb`` is the k-block of :func:`tt_step_blocked` (1 = unblocked)."""
    m = kb * r * rt
    g = kb * r * d * r
    x = rt * d * rt
    out = kb * r * rt
    tmp = kb * d * r * rt
    return dtype_bytes * (m + g + x + out + 2 * tmp)
