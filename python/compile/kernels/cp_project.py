"""Pallas kernel for ``f_CP(R)`` on CP inputs (Layer 1).

For every (batch b, output component k) the projection component is

    y = Σ_{r, t}  Π_n  G_n[r, t],   G_n = AⁿᵀXⁿ  ∈ R^{R×Rt}

The kernel fuses the N per-mode Gram products and the Hadamard
accumulation per (b, k) grid cell: the running Hadamard product stays in
VMEM (an ``R×Rt`` slab) while the factor slabs stream in. N is static at
trace time (one compiled artifact per order), so the mode loop unrolls.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cp_project_kernel(a_ref, x_ref, o_ref, *, scale):
    """Grid cell (b, k): one projection component.

    Blocks: a [N, d, R] (factors of row k), x [N, d, Rt] (input factors of
    batch item b) → o scalar (stored as [1, 1]).
    """
    a = a_ref[0, :, :, :]
    x = x_ref[0, :, :, :]
    n = a.shape[0]
    # h[r, t] ← Π_n AⁿᵀXⁿ, unrolled (n is static).
    h = a[0].T @ x[0]
    for i in range(1, n):
        h = h * (a[i].T @ x[i])
    o_ref[0, 0] = jnp.sum(h) * scale


def cp_project(a, x, scale):
    """Batched CP projection via Pallas.

    a: [K, N, d, R], x: [B, N, d, Rt] → y [B, K] (scaled by ``scale``).
    """
    k, n, d, r = a.shape
    bsz, _, _, rt = x.shape

    def kernel(a_ref, x_ref, o_ref):
        _cp_project_kernel(a_ref, x_ref, o_ref, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=(bsz, k),
        in_specs=[
            pl.BlockSpec((1, n, d, r), lambda b, i: (i, 0, 0, 0)),
            pl.BlockSpec((1, n, d, rt), lambda b, i: (b, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((bsz, k), a.dtype),
        interpret=True,
    )(a, x)


def vmem_bytes(n, d, r, rt, dtype_bytes=4):
    """Static VMEM footprint per grid cell: factor slabs + Hadamard slab."""
    return dtype_bytes * (n * d * r + n * d * rt + 2 * r * rt)
