"""AOT pipeline: lower the L2 graphs to HLO **text** + manifest.json.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids that the xla_extension 0.5.1
bundled with the Rust ``xla`` crate rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage (from the ``python/`` directory, as the Makefile does):

    python -m compile.aot --out-dir ../artifacts [--skip-pallas]

Writes one ``<name>.hlo.txt`` per artifact plus ``manifest.json``
describing parameter order/shapes and map metadata for the Rust runtime.
"""

import argparse
import json
import math
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# The artifact set (see DESIGN.md §7). `B` is the compiled batch size the
# Rust dynamic batcher pads to; parameters are runtime inputs.
ARTIFACTS = [
    dict(
        name="tt_rp_medium",
        kind="tt",
        cfg=model.TtConfig(
            n_modes=12, dim=3, rank=5, input_rank=10, k=128, batch=8, use_pallas=False
        ),
    ),
    dict(
        name="tt_rp_medium_pallas",
        kind="tt",
        cfg=model.TtConfig(
            n_modes=12, dim=3, rank=5, input_rank=10, k=128, batch=8, use_pallas=True
        ),
    ),
    dict(
        name="cp_rp_medium",
        kind="cp",
        cfg=model.CpConfig(
            n_modes=12, dim=3, rank=25, input_rank=10, k=128, batch=8, use_pallas=True
        ),
    ),
    dict(
        name="gauss_small",
        kind="dense",
        cfg=model.DenseConfig(input_dim=3375, k=128, batch=8, use_pallas=True),
    ),
    dict(
        name="tt_rp_small",
        kind="tt",
        cfg=model.TtConfig(
            n_modes=3, dim=15, rank=5, input_rank=10, k=128, batch=8, use_pallas=True
        ),
    ),
]


def build_fn(kind, cfg):
    if kind == "tt":
        return model.tt_project_fn(cfg)
    if kind == "cp":
        return model.cp_project_fn(cfg)
    if kind == "dense":
        return model.dense_project_fn(cfg)
    raise ValueError(f"unknown artifact kind {kind!r}")


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(kind, cfg):
    fn = build_fn(kind, cfg)
    specs = [
        jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in cfg.param_shapes()
    ]
    return jax.jit(fn).lower(*specs)


def artifact_manifest_entry(name, kind, cfg):
    entry = {
        "name": name,
        "kind": kind,
        "file": f"{name}.hlo.txt",
        "dtype": "f32",
        "k": cfg.k,
        "batch": cfg.batch,
        "scale": 1.0 / math.sqrt(cfg.k),
        "use_pallas": cfg.use_pallas,
        "params": [
            {"name": pname, "shape": list(shape)} for pname, shape in cfg.param_shapes()
        ],
        "output_shape": [cfg.batch, cfg.k],
    }
    if kind in ("tt", "cp"):
        entry.update(
            n_modes=cfg.n_modes,
            dim=cfg.dim,
            rank=cfg.rank,
            input_rank=cfg.input_rank,
        )
    else:
        entry.update(input_dim=cfg.input_dim)
    return entry


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--skip-pallas",
        action="store_true",
        help="skip pallas-path artifacts (faster lowering for smoke tests)",
    )
    ap.add_argument("--only", default=None, help="lower a single artifact by name")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"format_version": 1, "artifacts": []}
    for spec in ARTIFACTS:
        name, kind, cfg = spec["name"], spec["kind"], spec["cfg"]
        if args.only and name != args.only:
            continue
        if args.skip_pallas and cfg.use_pallas:
            continue
        print(f"[aot] lowering {name} …", flush=True)
        lowered = lower_artifact(kind, cfg)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"[aot]   wrote {path} ({len(text)} chars)")
        manifest["artifacts"].append(artifact_manifest_entry(name, kind, cfg))

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] wrote {mpath} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
