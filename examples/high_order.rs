//! High-order embedding: the regime where the paper's contribution is
//! qualitative, not incremental — `d = 3, N = 25` means the ambient
//! dimension is ≈ 8.5·10¹¹ and *no classical RP can even be stored*,
//! while the TT map projects in milliseconds.
//!
//! Reproduces the Figure 1 (right panel) story on a small grid and prints
//! the TT-vs-CP gap.
//!
//! ```text
//! cargo run --release --example high_order
//! ```

use tensorized_rp::data::inputs::{regime_input, Regime};
use tensorized_rp::experiments::{mean_distortion, MapSpec};
use tensorized_rp::rng::Rng;
use tensorized_rp::tensor::{AnyTensor, Shape};
use tensorized_rp::util::Timer;

fn main() {
    let regime = Regime::High;
    let dims = regime.dims();
    let ambient = Shape::new(&dims).numel_f64();
    println!(
        "high-order regime: N={} modes of size {}, ambient dim {:.2e}",
        dims.len(),
        dims[0],
        ambient
    );
    println!(
        "a dense Gaussian RP with k=100 would need {:.2e} parameters — impossible.\n",
        100.0 * ambient
    );

    let mut rng = Rng::seed_from(7);
    let x = AnyTensor::Tt(regime_input(regime, &mut rng));

    println!("{:<10} {:>6} {:>18} {:>12}", "map", "k", "mean distortion", "ms/project");
    let trials = 25;
    for spec in [
        MapSpec::Tt(2),
        MapSpec::Tt(5),
        MapSpec::Tt(10),
        MapSpec::Cp(4),
        MapSpec::Cp(25),
        MapSpec::Cp(100),
    ] {
        for k in [50usize, 200] {
            let (mean, _) = mean_distortion(
                spec,
                &x,
                k,
                trials,
                9,
                tensorized_rp::experiments::default_threads(),
            );
            // Time one projection (map drawn outside the timer).
            let f = spec.build(&dims, k, &mut rng);
            let t = Timer::start();
            std::hint::black_box(f.project(&x));
            let ms = t.elapsed_ms();
            println!("{:<10} {:>6} {:>18.4} {:>12.2}", spec.label(), k, mean, ms);
        }
    }
    println!(
        "\nexpected shape (paper Fig. 1, right): TT(5), TT(10) embed well; every CP rank fails."
    );
}
