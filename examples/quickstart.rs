//! Quickstart: draw tensorized random projections, embed a high-order
//! tensor, and compare against the paper's theory.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use tensorized_rp::prelude::*;
use tensorized_rp::projections::distortion_ratio;
use tensorized_rp::theory;

fn main() {
    let mut rng = Rng::seed_from(42);

    // A 12-mode, 3-dimensional tensor (ambient dimension 3^12 = 531 441),
    // generated directly in TT format with rank 10 and unit norm — the
    // paper's medium-order input.
    let dims = vec![3usize; 12];
    let x = TtTensor::random_unit(&dims, 10, &mut rng);
    println!(
        "input: {} modes, ambient dim {}, TT rank {}, {} parameters",
        dims.len(),
        531441,
        10,
        x.num_params()
    );

    // Embed into R^128 with a TT(5) tensorized random projection
    // (Definition 1) and with a CP(25) one (Definition 2) — roughly equal
    // parameter budgets, per the paper's §6 pairing.
    let k = 128;
    for (name, y, params) in [
        {
            let f = TtProjection::new(&dims, 5, k, &mut rng);
            ("f_TT(5) ", f.project_tt(&x), f.num_params())
        },
        {
            let f = CpProjection::new(&dims, 25, k, &mut rng);
            ("f_CP(25)", f.project_tt(&x), f.num_params())
        },
    ] {
        let d = distortion_ratio(&y, x.fro_norm());
        println!("{name}: k={k}, params={params:>8}, distortion |‖f(X)‖²/‖X‖² − 1| = {d:.4}");
    }

    // What a dense Gaussian JLT would need to store for the same job:
    println!(
        "dense Gaussian RP would store k·d^N = {} parameters",
        k * 531441
    );

    // Theory: Theorem 2 lower bounds on k for ε = 0.5, m = 100 points.
    let (eps, m, delta) = (0.5, 100, 0.05);
    let tt_k = theory::tt_k_lower_bound(eps, 12, 5, m, delta);
    let cp_k = theory::cp_k_lower_bound(eps, 12, 25, m, delta);
    println!("Theorem 2: k_TT ≳ {tt_k:.2e}, k_CP ≳ {cp_k:.2e} (CP needs {:.1e}× more)", cp_k / tt_k);
}
