//! Pairwise-distance preservation on image data (the paper's Appendix
//! B.1 use case): embed images with tensorized maps and verify that
//! nearest-neighbor structure survives.
//!
//! ```text
//! cargo run --release --example pairwise_images [-- --cifar path/to/data_batch_1.bin]
//! ```

use tensorized_rp::data::images::{load_images, TENSOR_DIMS};
use tensorized_rp::experiments::MapSpec;
use tensorized_rp::rng::Rng;
use tensorized_rp::tensor::DenseTensor;
use tensorized_rp::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap();
    let cifar = args.get("cifar").map(std::path::PathBuf::from);
    let n = 24usize;
    let (images, source) = load_images(n, cifar.as_deref(), 5);
    println!("[pairwise] {n} {source} images as {:?} tensors", TENSOR_DIMS);

    let tensors: Vec<DenseTensor> = images.iter().map(|im| im.to_tensor()).collect();
    let mut rng = Rng::seed_from(11);
    let k = 64;

    for spec in [MapSpec::Gaussian, MapSpec::Tt(5), MapSpec::Cp(25)] {
        let f = spec.build(&TENSOR_DIMS, k, &mut rng);
        let projected: Vec<Vec<f64>> = tensors.iter().map(|t| f.project_dense(t)).collect();

        // Pairwise ratio stats + nearest-neighbor preservation.
        let mut ratios = Vec::new();
        let mut nn_preserved = 0usize;
        for i in 0..n {
            let mut best_orig = (f64::MAX, usize::MAX);
            let mut best_proj = (f64::MAX, usize::MAX);
            for j in 0..n {
                if i == j {
                    continue;
                }
                let dx = tensors[i].sub(&tensors[j]).fro_norm();
                let dy: f64 = projected[i]
                    .iter()
                    .zip(&projected[j])
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    .sqrt();
                if dx > 1e-12 {
                    ratios.push(dy / dx);
                }
                if dx < best_orig.0 {
                    best_orig = (dx, j);
                }
                if dy < best_proj.0 {
                    best_proj = (dy, j);
                }
            }
            if best_orig.1 == best_proj.1 {
                nn_preserved += 1;
            }
        }
        let s = tensorized_rp::util::stats::Summary::of(&ratios);
        println!(
            "{:<10} k={k}: distance ratio mean {:.3} ± {:.3} | nearest-neighbor preserved {}/{}",
            spec.label(),
            s.mean,
            s.std,
            nn_preserved,
            n
        );
    }
    println!("\nexpected shape (paper Fig. 3): tensorized maps ≈ Gaussian RP on image data.");
}
