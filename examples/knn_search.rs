//! Approximate nearest-neighbor search through tensorized projections —
//! the application the paper's introduction motivates (RP + k-NN,
//! Indyk & Motwani 1998).
//!
//! Build a corpus of high-order TT tensors (ambient dim 3¹² = 531 441,
//! where exact dense k-NN is already painful and a dense Gaussian RP
//! would store 68M parameters), embed everything into R^k with `f_TT(R)`,
//! and measure recall@10 of projected-space neighbors against exact
//! TT-space distances.
//!
//! ```text
//! cargo run --release --example knn_search
//! ```

use tensorized_rp::prelude::*;
use tensorized_rp::projections::squared_norm;
use tensorized_rp::rng::Rng;
use tensorized_rp::tensor::TtTensor;

/// Exact squared distance between two TT tensors (in-format).
fn tt_dist2(a: &TtTensor, b: &TtTensor) -> f64 {
    // ‖a − b‖² = ‖a‖² + ‖b‖² − 2⟨a,b⟩ — all computable without densify.
    let na = a.fro_norm();
    let nb = b.fro_norm();
    na * na + nb * nb - 2.0 * a.inner(b)
}

/// Indices of the `top` smallest values.
fn top_indices(vals: &[f64], top: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..vals.len()).collect();
    idx.sort_by(|&a, &b| vals[a].partial_cmp(&vals[b]).unwrap());
    idx.truncate(top);
    idx
}

fn main() {
    let dims = vec![3usize; 12];
    let n_corpus = 200;
    let n_queries = 20;
    let top = 10;
    let mut rng = Rng::seed_from(0xA11CE);

    // Corpus: clustered TT tensors (queries share a cluster center with
    // some corpus points, so neighbors are meaningful, not uniform).
    println!("building corpus: {n_corpus} TT tensors, ambient dim 531441 …");
    let centers: Vec<TtTensor> = (0..20)
        .map(|_| TtTensor::random_unit(&dims, 5, &mut rng))
        .collect();
    let perturbed = |c: &TtTensor, rng: &mut Rng| -> TtTensor {
        let noise = TtTensor::random_unit(&dims, 5, rng);
        // Cluster structure via core-space jitter around the center (the
        // multiplicative TT map turns small core perturbations into small
        // relative entry perturbations), then renormalize.
        let mut t = c.clone();
        for m in 0..t.order() {
            let nc = noise.core(m).to_vec();
            for (a, b) in t.core_mut(m).iter_mut().zip(nc) {
                *a = 0.95 * *a + 0.15 * b;
            }
        }
        let norm = t.fro_norm();
        t.scale(1.0 / norm);
        t
    };
    let corpus: Vec<TtTensor> = (0..n_corpus)
        .map(|i| perturbed(&centers[i % centers.len()], &mut rng))
        .collect();
    let queries: Vec<TtTensor> = (0..n_queries)
        .map(|i| perturbed(&centers[i % centers.len()], &mut rng))
        .collect();

    for k in [32usize, 128, 512] {
        let f = TtProjection::new(&dims, 5, k, &mut rng);
        let t0 = std::time::Instant::now();
        let corpus_emb: Vec<Vec<f64>> = corpus.iter().map(|x| f.project_tt(x)).collect();
        let embed_secs = t0.elapsed().as_secs_f64();

        let mut recall_sum = 0.0;
        let mut exact_secs = 0.0;
        let mut approx_secs = 0.0;
        for q in &queries {
            // Exact neighbors in TT space.
            let t = std::time::Instant::now();
            let exact_d: Vec<f64> = corpus.iter().map(|c| tt_dist2(q, c)).collect();
            exact_secs += t.elapsed().as_secs_f64();
            let exact_top = top_indices(&exact_d, top);

            // Approximate neighbors in projected space.
            let qe = f.project_tt(q);
            let t = std::time::Instant::now();
            let approx_d: Vec<f64> = corpus_emb
                .iter()
                .map(|c| {
                    let mut diff = 0.0;
                    for (a, b) in qe.iter().zip(c) {
                        diff += (a - b) * (a - b);
                    }
                    diff
                })
                .collect();
            approx_secs += t.elapsed().as_secs_f64();
            let approx_top = top_indices(&approx_d, top);

            let hits = approx_top.iter().filter(|i| exact_top.contains(i)).count();
            recall_sum += hits as f64 / top as f64;
        }
        let recall = recall_sum / n_queries as f64;
        println!(
            "k={k:>4}: recall@{top} = {recall:.2} | embed corpus {:.1} ms | query scan: exact \
             {:.2} ms vs projected {:.3} ms ({:.0}× faster)",
            embed_secs * 1e3,
            exact_secs * 1e3 / n_queries as f64,
            approx_secs * 1e3 / n_queries as f64,
            exact_secs / approx_secs.max(1e-12)
        );
        // Embedding norm sanity.
        let mean_norm: f64 = corpus_emb.iter().map(|e| squared_norm(e)).sum::<f64>()
            / n_corpus as f64;
        assert!((mean_norm - 1.0).abs() < 0.6, "embeddings badly scaled");
    }
    println!("\nexpected: recall grows with k; projected scans are orders of magnitude faster.");
}
