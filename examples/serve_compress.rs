//! **End-to-end driver** (DESIGN.md §4, "E2E serving"): run the full
//! three-layer system on a realistic workload and report serving metrics.
//!
//! This exercises every layer composing:
//!   artifacts (JAX/Pallas, AOT) → PJRT runtime → router → dynamic
//!   batcher → worker pool → responses, with the native engine serving
//!   the shapes no artifact covers, and a numerical cross-check of the
//!   two paths at the end.
//!
//! ```text
//! make artifacts && cargo run --release --example serve_compress
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

use tensorized_rp::coordinator::{Coordinator, CoordinatorConfig, EnginePath, ProjectRequest};
use tensorized_rp::data::inputs::Regime;
use tensorized_rp::data::workload::{poisson_trace, FormatMix};
use tensorized_rp::projections::squared_norm;
use tensorized_rp::runtime::PjrtEngine;
use tensorized_rp::tensor::{AnyTensor, TtTensor};
use tensorized_rp::util::stats::Summary;

fn main() -> Result<(), String> {
    // ── 1. Load the compiled artifact set. ────────────────────────────
    let mut engine = PjrtEngine::cpu().map_err(|e| e.to_string())?;
    let n_artifacts = engine
        .load_dir(std::path::Path::new("artifacts"))
        .map_err(|e| format!("{e} — run `make artifacts` first"))?;
    println!("[e2e] PJRT {} | {} artifacts compiled", engine.platform(), n_artifacts);

    // ── 2. Start the coordinator. ─────────────────────────────────────
    let coord = Coordinator::start(
        CoordinatorConfig { master_seed: 42, max_delay_us: 2_000, ..Default::default() },
        Some(engine),
    );

    // ── 3. Replay a Poisson trace of mixed TT/CP requests. ────────────
    let n = 400;
    let trace = poisson_trace(n, 4_000.0, Regime::Medium, FormatMix { tt: 0.7, cp: 0.3 }, 7);
    println!("[e2e] replaying {n} requests (70% TT / 30% CP, medium-order inputs)");
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = trace
        .payloads
        .into_iter()
        .enumerate()
        .map(|(i, p)| coord.submit(ProjectRequest::new(i as u64, p)))
        .collect();
    let mut latencies = Vec::with_capacity(n);
    let mut norms = Vec::with_capacity(n);
    let mut pjrt_count = 0usize;
    for rx in rxs {
        let resp = rx.recv().map_err(|e| e.to_string())??;
        latencies.push((resp.queued_us + resp.exec_us) as f64 / 1e3);
        norms.push(squared_norm(&resp.embedding));
        if matches!(resp.path, EnginePath::Pjrt(_)) {
            pjrt_count += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    let lat = Summary::of(&latencies);
    let nrm = Summary::of(&norms);

    println!("\n===== E2E serving report =====");
    println!("requests        : {n} ({} via PJRT, {} native)", pjrt_count, n - pjrt_count);
    println!("wall time       : {wall:.3} s → throughput {:.0} req/s", n as f64 / wall);
    println!(
        "latency (ms)    : mean {:.1} | p50 {:.1} | p95 {:.1} | max {:.1}",
        lat.mean, lat.median, lat.p95, lat.max
    );
    println!(
        "PJRT batches    : {} ({} padded slots, {:.1}% padding)",
        m.pjrt_batches,
        m.padded_slots,
        100.0 * m.padded_slots as f64 / (m.pjrt_batches as f64 * 8.0).max(1.0)
    );
    println!(
        "isometry check  : mean ‖f(X)‖² = {:.4} (unit-norm inputs ⇒ expect ≈ 1), std {:.4}",
        nrm.mean, nrm.std
    );

    // ── 4. Cross-check: PJRT path ≡ native path on the same map. ──────
    let mut rng = tensorized_rp::rng::Rng::seed_from(99);
    let x = TtTensor::random_unit(&Regime::Medium.dims(), 10, &mut rng);
    let via_pjrt = coord
        .project_blocking(ProjectRequest::new(9_000, AnyTensor::Tt(x.clone())))?;
    coord.shutdown();

    // Native coordinator configured to use the *same* registry key
    // (rank 5, k 128 — the artifact's parameters) and master seed.
    let native = Coordinator::start(
        CoordinatorConfig {
            master_seed: 42,
            default_tt_rank: 5,
            default_k: 128,
            ..Default::default()
        },
        None,
    );
    let via_native = native.project_blocking(ProjectRequest::new(9_001, AnyTensor::Tt(x)))?;
    native.shutdown();

    let max_diff = via_pjrt
        .embedding
        .iter()
        .zip(&via_native.embedding)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!(
        "path cross-check: max |pjrt − native| = {max_diff:.2e} ({} vs {})",
        via_pjrt.path, via_native.path
    );
    if max_diff > 1e-3 {
        return Err(format!("cross-check failed: {max_diff}"));
    }
    println!("e2e OK");
    Ok(())
}
