#!/usr/bin/env bash
# Tier-1 gate for the rust crate: build + tests + the in-repo static
# analysis (`trp lint`) are hard requirements, and `cargo fmt --check`
# and `cargo clippy -- -D warnings` gate by default. Set TIER1_STRICT=0 to
# demote them back to advisory (e.g. on a machine with a divergent
# rustfmt/clippy version).
#
# Usage: scripts/tier1.sh  [from anywhere; operates on rust/]
set -uo pipefail

repo_root="$(cd "$(dirname "$0")/.." && pwd)"
cd "$repo_root/rust"

strict="${TIER1_STRICT:-1}"
fail=0

echo "== tier1: cargo build --release =="
cargo build --release || fail=1

if [ "$fail" -eq 0 ]; then
  echo "== tier1: cargo test -q =="
  cargo test -q || fail=1
fi

# Snapshot round-trip is load-bearing for crash recovery: run it as its
# own named gate so a persistence regression is visible at a glance
# (cheap — the test binary is already built by the full run above).
if [ "$fail" -eq 0 ]; then
  echo "== tier1: snapshot round-trip (persist_recovery) =="
  cargo test -q --test persist_recovery || fail=1
fi

# The compressed-input batch kernels are gated on bit-equivalence with
# per-item dispatch: name the property suite so a batching regression is
# visible at a glance (also cheap — binary already built).
if [ "$fail" -eq 0 ]; then
  echo "== tier1: compressed-batch bit-equivalence (projection_batch_props) =="
  cargo test -q --test projection_batch_props || fail=1
fi

# Sharded index execution is gated on bit-identity with the unsharded
# baseline (S ∈ {1,2,4}) plus ordering/migration/consistent-cut
# properties: name the suite so a sharding regression is visible at a
# glance (cheap — binary already built by the full run above).
if [ "$fail" -eq 0 ]; then
  echo "== tier1: sharded-ordering bit-identity (sharded_props) =="
  cargo test -q --test sharded_props || fail=1
fi

# The packed GEMM kernel is gated on its determinism contract: exhaustive
# small-shape bitwise match vs the naive chain, parallel row-panel
# bit-identity across worker counts {1,2,4}, fused-regroup TT×TT bitwise
# regression vs the staged path, and NaN/Inf propagation. Name the suite
# so a kernel regression is visible at a glance (cheap — already built).
if [ "$fail" -eq 0 ]; then
  echo "== tier1: GEMM kernel bit-identity (gemm_kernel_props) =="
  cargo test -q --test gemm_kernel_props || fail=1
fi

# Observability is gated on zero perturbation: the response stream must
# be bit-identical with tracing on vs off across backends, formats and
# shard counts, counters must total exactly under pipelined traffic, and
# a traced session's span JSONL must cover every pipeline stage. Name
# the suite so a tracing regression is visible at a glance.
if [ "$fail" -eq 0 ]; then
  echo "== tier1: tracing zero-perturbation (obs_props) =="
  cargo test -q --test obs_props || fail=1
fi

# Crash recovery through the write-ahead log is gated on its durability
# contract: SIGKILL (real and simulated) plus injected-panic crashes
# during concurrent pipelined ingest, recovered coordinators answering
# bit-identically to uninterrupted twins across {flat,lsh} × S ∈ {1,2,4}
# with restore into a different shard count, and zero behavior change
# with the WAL off. Name the suite so a durability regression is visible
# at a glance (the child-process test reuses the release `trp` binary).
if [ "$fail" -eq 0 ]; then
  echo "== tier1: WAL crash recovery (wal_recovery) =="
  cargo test -q --test wal_recovery || fail=1
fi

# The determinism/concurrency static-analysis pass is gated on a clean
# tree: zero unwaived findings across the seven rules (float-total-order,
# no-fma, hot-path-panic, unordered-iteration, unsafe-audit,
# relaxed-handoff, fsync-discipline), an empty baseline, and a written
# reason on every waiver. Run both the in-tree meta-test and the CLI
# itself, so the gate exercises the same binary CI exports (cheap —
# release build above).
if [ "$fail" -eq 0 ]; then
  echo "== tier1: static-analysis clean tree (lint_clean) =="
  cargo test -q --test lint_clean || fail=1
  cargo run -q --release --bin trp -- lint || fail=1
fi

advisory() {
  local label="$1"
  shift
  if [ "$strict" = "1" ]; then
    echo "== tier1: $label =="
  else
    echo "== tier1 (advisory): $label =="
  fi
  if ! "$@"; then
    if [ "$strict" = "1" ]; then
      echo "tier1: $label failed (strict mode; set TIER1_STRICT=0 to demote)"
      fail=1
    else
      echo "tier1: $label failed (advisory — not gating; set TIER1_STRICT=1 to gate)"
    fi
  fi
}

# rustfmt / clippy components may be absent in minimal toolchains.
if cargo fmt --version >/dev/null 2>&1; then
  advisory "cargo fmt --check" cargo fmt --check
else
  echo "== tier1 (advisory): cargo fmt unavailable — skipped =="
fi
if cargo clippy --version >/dev/null 2>&1; then
  advisory "cargo clippy -- -D warnings" cargo clippy -- -D warnings
else
  echo "== tier1 (advisory): cargo clippy unavailable — skipped =="
fi

if [ "$fail" -ne 0 ]; then
  echo "tier1: FAILED"
  exit 1
fi
echo "tier1: OK"
