//! Property-based tests of the coordinator invariants (DESIGN.md §8),
//! using the in-repo `util::proptest` framework (no proptest crate
//! offline — see DESIGN.md §3 for the substitution).

use tensorized_rp::coordinator::{
    Batcher, BatcherConfig, Coordinator, CoordinatorConfig, MapKey, MapKind, ProjectRequest,
    ProjectionRegistry, RouteKey, Router,
};
use tensorized_rp::tensor::{AnyTensor, CpTensor, DenseTensor, TtTensor};
use tensorized_rp::util::proptest::{run, Config};

/// Batcher invariant: every pushed item comes out exactly once, in FIFO
/// order, regardless of the interleaving of pushes/polls/flushes.
#[test]
fn prop_batcher_conserves_items_in_order() {
    run("batcher conservation", Config { cases: 128, seed: 0xBA7C }, |g| {
        let max_batch = g.usize_in(1, 6);
        let max_delay = g.usize_in(1, 500) as u64;
        let mut b = Batcher::new(BatcherConfig { max_batch, max_delay_us: max_delay });
        let n_ops = g.usize_in(1, 60);
        let mut now = 0u64;
        let mut next_id = 0u32;
        let mut out: Vec<u32> = Vec::new();
        for _ in 0..n_ops {
            now += g.usize_in(0, 300) as u64;
            if g.bool_with(0.7) {
                if let Some(batch) = b.push(next_id, now) {
                    if batch.len() > max_batch {
                        return Err(format!("oversized batch {}", batch.len()));
                    }
                    out.extend(batch);
                }
                next_id += 1;
            } else if let Some(batch) = b.poll(now) {
                out.extend(batch);
            }
        }
        if let Some(batch) = b.flush() {
            out.extend(batch);
        }
        let want: Vec<u32> = (0..next_id).collect();
        if out != want {
            return Err(format!("items lost/reordered: got {out:?}, want {want:?}"));
        }
        Ok(())
    });
}

/// Batcher invariant: a pending item never waits longer than max_delay
/// past its arrival before poll() at/after the deadline releases it.
#[test]
fn prop_batcher_deadline_is_honored() {
    run("batcher deadline", Config { cases: 64, seed: 0xDEAD }, |g| {
        let max_delay = g.usize_in(10, 1000) as u64;
        let mut b = Batcher::new(BatcherConfig { max_batch: 100, max_delay_us: max_delay });
        let t_arrive = g.usize_in(0, 10_000) as u64;
        b.push(1u8, t_arrive);
        // Just before the deadline: nothing.
        if b.poll(t_arrive + max_delay - 1).is_some() {
            return Err("flushed before deadline".into());
        }
        // At the deadline: flushed.
        if b.poll(t_arrive + max_delay).is_none() {
            return Err("not flushed at deadline".into());
        }
        Ok(())
    });
}

/// Router invariant: routing is total and deterministic, and a payload
/// routed to an artifact always matches that artifact's signature.
#[test]
fn prop_router_total_and_consistent() {
    run("router totality", Config { cases: 96, seed: 0x0907E }, |g| {
        let mut router = Router::new();
        // One TT artifact with random signature.
        let n = g.usize_in(3, 6);
        let d = g.usize_in(2, 4);
        let rt = g.usize_in(1, 4);
        let spec = tensorized_rp::runtime::ArtifactSpec {
            name: "art".into(),
            kind: tensorized_rp::runtime::ArtifactKind::Tt,
            file: "art.hlo.txt".into(),
            k: 4,
            batch: 2,
            scale: 0.5,
            use_pallas: false,
            params: vec![],
            output_shape: vec![2, 4],
            n_modes: Some(n),
            dim: Some(d),
            rank: Some(2),
            input_rank: Some(rt),
            input_dim: None,
        };
        router.register_artifacts([&spec]);
        // Random payload, maybe matching.
        let pn = g.usize_in(3, 6);
        let pd = g.usize_in(2, 4);
        let prt = g.usize_in(1, 4);
        let x = TtTensor::random(&vec![pd; pn], prt, g.rng());
        let payload = AnyTensor::Tt(x);
        let t1 = router.route(&payload);
        let t2 = router.route(&payload);
        if t1 != t2 {
            return Err("routing not deterministic".into());
        }
        let matches = pn == n && pd == d && prt == rt;
        match (matches, &t1) {
            (true, tensorized_rp::coordinator::RouteTarget::Pjrt(name)) if name == "art" => Ok(()),
            (false, tensorized_rp::coordinator::RouteTarget::Native) => Ok(()),
            _ => Err(format!(
                "route mismatch: match={matches}, target={t1:?} (payload {pn}/{pd}/{prt} vs \
                 artifact {n}/{d}/{rt})"
            )),
        }
    });
}

/// RouteKey extraction is stable across clones of the payload.
#[test]
fn prop_route_key_stable() {
    run("route key stability", Config { cases: 64, seed: 0x5AB1E }, |g| {
        let n = g.usize_in(2, 5);
        let d = g.usize_in(2, 4);
        let payload = match g.usize_in(0, 2) {
            0 => AnyTensor::Tt(TtTensor::random(&vec![d; n], g.usize_in(1, 3), g.rng())),
            1 => AnyTensor::Cp(CpTensor::random(&vec![d; n], g.usize_in(1, 3), g.rng())),
            _ => AnyTensor::Dense(DenseTensor::random(&vec![d; n], g.rng())),
        };
        let k1 = RouteKey::of(&payload);
        let k2 = RouteKey::of(&payload.clone());
        if k1 != k2 {
            return Err("route key unstable".into());
        }
        if k1.dims != payload.dims() {
            return Err("dims mismatch".into());
        }
        Ok(())
    });
}

/// Registry invariant: same key ⇒ same map object; embeddings are
/// reproducible across registries with the same master seed.
#[test]
fn prop_registry_determinism() {
    run("registry determinism", Config { cases: 32, seed: 0x4E6 }, |g| {
        let seed = g.usize_in(0, 1_000_000) as u64;
        let n = g.usize_in(2, 4);
        let d = g.usize_in(2, 4);
        let rank = g.usize_in(1, 3);
        let k = g.usize_in(1, 8);
        let key = MapKey { kind: MapKind::Tt { rank }, dims: vec![d; n], k };
        let x = AnyTensor::Tt(TtTensor::random_unit(&vec![d; n], 2, g.rng()));
        let y1 = ProjectionRegistry::new(seed).get_or_create(&key).map.project(&x);
        let y2 = ProjectionRegistry::new(seed).get_or_create(&key).map.project(&x);
        if y1 != y2 {
            return Err("registry draw not deterministic".into());
        }
        if y1.len() != k {
            return Err(format!("wrong embedding size {} != {k}", y1.len()));
        }
        Ok(())
    });
}

/// End-to-end coordinator invariant: every request is answered exactly
/// once with its own id, for random payload mixes and worker counts.
#[test]
fn prop_coordinator_answers_every_request_once() {
    run(
        "coordinator request conservation",
        Config { cases: 10, seed: 0xC00D },
        |g| {
            let workers = g.usize_in(1, 4);
            let n_req = g.usize_in(1, 24);
            let coord = Coordinator::start(
                CoordinatorConfig {
                    workers,
                    default_k: 8,
                    queue_cap: 8,
                    ..Default::default()
                },
                None,
            );
            let mut rxs = Vec::new();
            for i in 0..n_req {
                let payload = match g.usize_in(0, 2) {
                    0 => AnyTensor::Tt(TtTensor::random_unit(&[3; 4], 2, g.rng())),
                    1 => AnyTensor::Cp(CpTensor::random_unit(&[3; 4], 2, g.rng())),
                    _ => AnyTensor::Dense(DenseTensor::random_unit(&[3, 3], g.rng())),
                };
                rxs.push((i as u64, coord.submit(ProjectRequest::new(i as u64, payload))));
            }
            for (id, rx) in rxs {
                let resp = rx
                    .recv()
                    .map_err(|e| format!("no response for {id}: {e}"))?
                    .map_err(|e| format!("request {id} failed: {e}"))?;
                if resp.id != id {
                    return Err(format!("id mismatch: got {} want {id}", resp.id));
                }
                // Exactly-once: a second recv must find the channel closed,
                // not a duplicate response.
                if rx.recv().is_ok() {
                    return Err(format!("duplicate response for {id}"));
                }
            }
            let m = coord.metrics();
            if m.completed != n_req as u64 {
                return Err(format!("completed {} != {n_req}", m.completed));
            }
            coord.shutdown();
            Ok(())
        },
    );
}

/// Batcher invariant (deadline edge): with pushes strictly inside the
/// window, the batch flushes *exactly* when the oldest item has waited
/// `max_delay_us` — boundary inclusive, never one tick early — and the
/// deadline clock fully resets after every flush (`oldest_us` cleared:
/// the next push restarts the window from its own arrival tick, not the
/// flushed batch's).
#[test]
fn prop_batcher_deadline_boundary_and_reset() {
    run(
        "batcher deadline boundary + reset",
        Config { cases: 128, seed: 0xB0DE },
        |g| {
            let max_delay = g.usize_in(1, 1_000) as u64;
            let n_items = g.usize_in(1, 8);
            // max_batch above n_items so only the deadline can flush.
            let mut b = Batcher::new(BatcherConfig {
                max_batch: n_items + 1,
                max_delay_us: max_delay,
            });
            let t0 = g.usize_in(0, 10_000) as u64;
            if b.push(0u32, t0).is_some() {
                return Err("size flush below max_batch".into());
            }
            // Later arrivals inside the window must not extend the
            // deadline (it tracks the *oldest* item).
            for i in 1..n_items {
                let t = t0 + (g.usize_in(0, max_delay.saturating_sub(1) as usize) as u64);
                if b.push(i as u32, t).is_some() {
                    return Err("size flush below max_batch".into());
                }
            }
            if b.deadline_us() != Some(t0 + max_delay) {
                return Err(format!(
                    "deadline {:?} != oldest + max_delay {}",
                    b.deadline_us(),
                    t0 + max_delay
                ));
            }
            if b.poll(t0 + max_delay - 1).is_some() {
                return Err("flushed one tick before the deadline".into());
            }
            let batch = b
                .poll(t0 + max_delay)
                .ok_or("did not flush exactly at the deadline (boundary must be inclusive)")?;
            if batch.len() != n_items {
                return Err(format!("flushed {} of {n_items} items", batch.len()));
            }
            // Reset: no residual deadline, an arbitrarily late poll stays
            // empty, and a new push restarts the window from its own tick.
            if b.deadline_us().is_some() {
                return Err("oldest_us not cleared after deadline flush".into());
            }
            if b.poll(t0 + 100 * max_delay).is_some() {
                return Err("phantom flush from an empty batcher".into());
            }
            let t1 = t0 + max_delay + 1 + g.usize_in(0, 5_000) as u64;
            b.push(99u32, t1);
            if b.deadline_us() != Some(t1 + max_delay) {
                return Err(format!(
                    "post-flush deadline {:?} != new arrival + max_delay {}",
                    b.deadline_us(),
                    t1 + max_delay
                ));
            }
            if b.poll(t1 + max_delay - 1).is_some() {
                return Err("post-flush window shrank (stale oldest_us)".into());
            }
            if b.poll(t1 + max_delay).is_none() {
                return Err("post-flush window did not flush at its deadline".into());
            }
            Ok(())
        },
    );
}
