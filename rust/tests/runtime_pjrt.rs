//! PJRT round-trip integration tests: the compiled artifacts must agree
//! numerically with the native Rust engine on identical parameters.
//!
//! These tests require `make artifacts` to have run; they skip (pass
//! trivially with a note) when `artifacts/manifest.json` is absent so
//! `cargo test` stays green on a fresh checkout.

use std::path::Path;
use tensorized_rp::projections::Projection;
use tensorized_rp::rng::Rng;
use tensorized_rp::runtime::{pack, ArtifactKind, Manifest, PjrtEngine};
use tensorized_rp::tensor::{CpTensor, DenseTensor, TtTensor};

fn artifacts_dir() -> Option<&'static Path> {
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("[skip] artifacts not built; run `make artifacts`");
        None
    }
}

fn engine() -> Option<PjrtEngine> {
    let dir = artifacts_dir()?;
    let mut e = PjrtEngine::cpu().expect("PJRT cpu client");
    e.load_dir(dir).expect("compile artifacts");
    Some(e)
}

#[test]
fn manifest_and_files_are_consistent() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(dir).unwrap();
    assert!(m.artifacts.len() >= 4, "expected the full artifact set");
    for spec in &m.artifacts {
        assert!(dir.join(&spec.file).exists(), "missing {}", spec.file);
    }
}

#[test]
fn tt_artifact_matches_native_engine() {
    let Some(engine) = engine() else { return };
    let spec = engine.spec("tt_rp_medium").expect("tt_rp_medium").clone();
    let (n, d, r, rt) = spec.tt_meta().unwrap();
    let dims = vec![d; n];
    let mut rng = Rng::seed_from(123);
    let f = tensorized_rp::projections::TtProjection::new(&dims, r, spec.k, &mut rng);
    let (gf, gm, gl) = pack::pack_tt_projection(&f, n, d, r).unwrap();
    // Two real inputs in a batch of spec.batch (padded).
    let x1 = TtTensor::random_unit(&dims, rt, &mut rng);
    let x2 = TtTensor::random_unit(&dims, rt, &mut rng);
    let (xf, xm, xl) = pack::pack_tt_inputs(&[&x1, &x2], spec.batch, n, d, rt).unwrap();
    let y = engine
        .execute("tt_rp_medium", &[gf, gm, gl, xf, xm, xl])
        .unwrap();
    assert_eq!(y.len(), spec.batch * spec.k);
    // Rows 0 and 1 must match the native projection; padded rows are 0.
    for (row, x) in [(0usize, &x1), (1usize, &x2)] {
        let want = f.project_tt(x);
        let got = &y[row * spec.k..(row + 1) * spec.k];
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 2e-4, "row {row}: pjrt={a} native={b}");
        }
    }
    for v in &y[2 * spec.k..] {
        assert_eq!(*v, 0.0, "padded rows must be exactly zero");
    }
}

#[test]
fn pallas_artifact_matches_reference_artifact() {
    let Some(engine) = engine() else { return };
    let spec = engine.spec("tt_rp_medium").unwrap().clone();
    let (n, d, r, rt) = spec.tt_meta().unwrap();
    let dims = vec![d; n];
    let mut rng = Rng::seed_from(7);
    let f = tensorized_rp::projections::TtProjection::new(&dims, r, spec.k, &mut rng);
    let (gf, gm, gl) = pack::pack_tt_projection(&f, n, d, r).unwrap();
    let x = TtTensor::random_unit(&dims, rt, &mut rng);
    let (xf, xm, xl) = pack::pack_tt_inputs(&[&x], spec.batch, n, d, rt).unwrap();
    let inputs = vec![gf, gm, gl, xf, xm, xl];
    let y_ref = engine.execute("tt_rp_medium", &inputs).unwrap();
    let y_pal = engine.execute("tt_rp_medium_pallas", &inputs).unwrap();
    for (a, b) in y_ref.iter().zip(&y_pal) {
        assert!((a - b).abs() < 1e-5, "pallas={b} ref={a}");
    }
}

#[test]
fn cp_artifact_matches_native_engine() {
    let Some(engine) = engine() else { return };
    let spec = engine.spec("cp_rp_medium").expect("cp_rp_medium").clone();
    assert_eq!(spec.kind, ArtifactKind::Cp);
    let n = spec.n_modes.unwrap();
    let d = spec.dim.unwrap();
    let r = spec.rank.unwrap();
    let rt = spec.input_rank.unwrap();
    let dims = vec![d; n];
    let mut rng = Rng::seed_from(9);
    let f = tensorized_rp::projections::CpProjection::new(&dims, r, spec.k, &mut rng);
    let a = pack::pack_cp_projection(&f, n, d, r).unwrap();
    let x = CpTensor::random_unit(&dims, rt, &mut rng);
    let xp = pack::pack_cp_inputs(&[&x], spec.batch, n, d, rt).unwrap();
    let y = engine.execute("cp_rp_medium", &[a, xp]).unwrap();
    let want = f.project_cp(&x);
    for (got, b) in y[..spec.k].iter().zip(&want) {
        assert!((got - b).abs() < 2e-4, "pjrt={got} native={b}");
    }
}

#[test]
fn dense_artifact_matches_native_engine() {
    let Some(engine) = engine() else { return };
    let spec = engine.spec("gauss_small").expect("gauss_small").clone();
    let dim = spec.input_dim.unwrap();
    let mut rng = Rng::seed_from(31);
    // 15×15×15 = 3375-dim inputs.
    let f = tensorized_rp::projections::GaussianProjection::new(&[15, 15, 15], spec.k, &mut rng);
    let w = pack::pack_dense_projection(&f);
    let x = DenseTensor::random_unit(&[15, 15, 15], &mut rng);
    let xp = pack::pack_dense_inputs(&[&x], spec.batch, dim).unwrap();
    let y = engine.execute("gauss_small", &[w, xp]).unwrap();
    let want = f.project_dense(&x);
    for (got, b) in y[..spec.k].iter().zip(&want) {
        assert!((got - b).abs() < 2e-4, "pjrt={got} native={b}");
    }
}

#[test]
fn small_regime_tt_artifact_matches_native() {
    // The small-order regime artifact (d=15, N=3) — pallas gemm-backed.
    let Some(engine) = engine() else { return };
    let spec = engine.spec("tt_rp_small").expect("tt_rp_small").clone();
    let (n, d, r, rt) = spec.tt_meta().unwrap();
    assert_eq!((n, d), (3, 15));
    let dims = vec![d; n];
    let mut rng = Rng::seed_from(88);
    let f = tensorized_rp::projections::TtProjection::new(&dims, r, spec.k, &mut rng);
    let (gf, gm, gl) = pack::pack_tt_projection(&f, n, d, r).unwrap();
    let x = TtTensor::random_unit(&dims, rt, &mut rng);
    let (xf, xm, xl) = pack::pack_tt_inputs(&[&x], spec.batch, n, d, rt).unwrap();
    let y = engine
        .execute("tt_rp_small", &[gf, gm, gl, xf, xm, xl])
        .unwrap();
    let want = f.project_tt(&x);
    for (got, b) in y[..spec.k].iter().zip(&want) {
        assert!((got - b).abs() < 2e-4, "pjrt={got} native={b}");
    }
}

#[test]
fn execute_rejects_bad_input_arity_and_shape() {
    let Some(engine) = engine() else { return };
    assert!(engine.execute("tt_rp_medium", &[]).is_err());
    assert!(engine.execute("nonexistent", &[]).is_err());
    let spec = engine.spec("gauss_small").unwrap().clone();
    let w = vec![0f32; spec.params[0].numel()];
    let bad_x = vec![0f32; 3]; // wrong element count
    assert!(engine.execute("gauss_small", &[w, bad_x]).is_err());
}

#[test]
fn exec_stats_accumulate() {
    let Some(engine) = engine() else { return };
    let spec = engine.spec("gauss_small").unwrap().clone();
    let w = vec![0f32; spec.params[0].numel()];
    let x = vec![0f32; spec.params[1].numel()];
    let before = engine.stats("gauss_small").unwrap().executions;
    engine.execute("gauss_small", &[w.clone(), x.clone()]).unwrap();
    engine.execute("gauss_small", &[w, x]).unwrap();
    let after = engine.stats("gauss_small").unwrap();
    assert_eq!(after.executions, before + 2);
    assert!(after.total_secs > 0.0);
}
