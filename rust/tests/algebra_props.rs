//! Property-based tests of the tensor-algebra substrate over random
//! shapes/ranks (util::proptest). These are the invariants every higher
//! layer silently relies on.

use tensorized_rp::linalg::{matmul, qr, rel_err, svd, Matrix};
use tensorized_rp::tensor::{CpTensor, DenseTensor, Shape, TtContraction, TtTensor};
use tensorized_rp::util::proptest::{run, Config};

#[test]
fn prop_matricization_preserves_norm_and_roundtrips() {
    run("matricization", Config { cases: 48, seed: 1 }, |g| {
        let n = g.usize_in(2, 4);
        let dims: Vec<usize> = (0..n).map(|_| g.usize_in(1, 5)).collect();
        let t = DenseTensor::random(&dims, g.rng());
        for mode in 0..n {
            let m = t.matricize(mode);
            if (m.fro_norm() - t.fro_norm()).abs() > 1e-9 {
                return Err(format!("norm changed in mode-{mode} matricization"));
            }
            if m.rows() != dims[mode] || m.cols() != t.numel() / dims[mode] {
                return Err("matricization shape wrong".into());
            }
        }
        // Split matricization is a pure reshape.
        if n >= 2 {
            let split = g.usize_in(1, n - 1);
            let m = t.matricize_split(split);
            if m.data() != t.data() {
                return Err("split matricization moved data".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tt_inner_equals_dense_inner() {
    run("tt inner", Config { cases: 40, seed: 2 }, |g| {
        let n = g.usize_in(2, 4);
        let dims: Vec<usize> = (0..n).map(|_| g.usize_in(2, 4)).collect();
        let ra = g.usize_in(1, 4);
        let rb = g.usize_in(1, 4);
        let a = TtTensor::random(&dims, ra, g.rng());
        let b = TtTensor::random(&dims, rb, g.rng());
        let fast = a.inner(&b);
        let slow = a.to_dense().inner(&b.to_dense());
        if (fast - slow).abs() > 1e-8 * slow.abs().max(1.0) {
            return Err(format!("fast={fast} slow={slow}"));
        }
        // And the amortized contraction agrees too.
        let ctx = TtContraction::new(&b);
        let amortized = ctx.inner(&a);
        if (amortized - slow).abs() > 1e-8 * slow.abs().max(1.0) {
            return Err(format!("amortized={amortized} slow={slow}"));
        }
        Ok(())
    });
}

#[test]
fn prop_cp_inner_equalities() {
    run("cp inner", Config { cases: 40, seed: 3 }, |g| {
        let n = g.usize_in(2, 4);
        let dims: Vec<usize> = (0..n).map(|_| g.usize_in(2, 4)).collect();
        let ra = g.usize_in(1, 4);
        let rb = g.usize_in(1, 4);
        let a = CpTensor::random(&dims, ra, g.rng());
        let b = CpTensor::random(&dims, rb, g.rng());
        let slow = a.to_dense().inner(&b.to_dense());
        if (a.inner(&b) - slow).abs() > 1e-8 * slow.abs().max(1.0) {
            return Err("cp×cp mismatch".into());
        }
        // CP→TT conversion preserves inner products.
        let tt_b = b.to_tt();
        if (a.inner_tt(&tt_b) - slow).abs() > 1e-7 * slow.abs().max(1.0) {
            return Err("cp×tt mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_tt_svd_respects_tolerance() {
    run("tt-svd", Config { cases: 16, seed: 4 }, |g| {
        let n = g.usize_in(2, 4);
        let dims: Vec<usize> = (0..n).map(|_| g.usize_in(2, 4)).collect();
        let x = DenseTensor::random(&dims, g.rng());
        let eps = g.f64_in(0.05, 0.5);
        let tt = TtTensor::tt_svd(&x, eps, 64);
        let err = rel_err(tt.to_dense().data(), x.data());
        if err > eps * 1.01 {
            return Err(format!("err {err} > eps {eps}"));
        }
        Ok(())
    });
}

#[test]
fn prop_tt_round_preserves_value_and_shrinks_ranks() {
    run("tt-round", Config { cases: 16, seed: 5 }, |g| {
        let n = g.usize_in(3, 4);
        let dims: Vec<usize> = (0..n).map(|_| g.usize_in(2, 4)).collect();
        let r = g.usize_in(1, 3);
        let x = TtTensor::random(&dims, r, g.rng());
        let rounded = x.round(1e-10, 64);
        let err = rel_err(rounded.to_dense().data(), x.to_dense().data());
        if err > 1e-7 {
            return Err(format!("round changed the tensor: {err}"));
        }
        // Ranks never exceed the prescribed ones (rounding clips the
        // redundant boundary parameterization).
        for (got, want) in rounded.ranks().iter().zip(x.ranks()) {
            if got > want {
                return Err(format!("rank grew: {:?} vs {:?}", rounded.ranks(), x.ranks()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_qr_and_svd_factorizations() {
    run("qr/svd", Config { cases: 24, seed: 6 }, |g| {
        let m = g.usize_in(1, 10);
        let n = g.usize_in(1, 10);
        let a = Matrix::from_vec(m, n, g.rng().gaussian_vec(m * n, 1.0));
        let (q, r) = qr(&a);
        if rel_err(q.matmul(&r).data(), a.data()) > 1e-9 {
            return Err("QR reconstruction failed".into());
        }
        let d = svd(&a);
        if rel_err(d.reconstruct().data(), a.data()) > 1e-8 {
            return Err("SVD reconstruction failed".into());
        }
        // Singular values descending and bounded by the norm.
        let norm = a.fro_norm();
        let mut prev = f64::INFINITY;
        for &s in &d.s {
            if s > prev + 1e-12 || s > norm + 1e-9 {
                return Err("singular values unsorted or too large".into());
            }
            prev = s;
        }
        Ok(())
    });
}

#[test]
fn prop_gemm_is_linear_and_associative_with_identity() {
    run("gemm", Config { cases: 32, seed: 7 }, |g| {
        let m = g.usize_in(1, 12);
        let k = g.usize_in(1, 12);
        let n = g.usize_in(1, 12);
        let a = g.rng().gaussian_vec(m * k, 1.0);
        let b = g.rng().gaussian_vec(k * n, 1.0);
        let c = g.rng().gaussian_vec(k * n, 1.0);
        // A(B + C) = AB + AC.
        let bc: Vec<f64> = b.iter().zip(&c).map(|(x, y)| x + y).collect();
        let left = matmul(&a, &bc, m, k, n);
        let ab = matmul(&a, &b, m, k, n);
        let ac = matmul(&a, &c, m, k, n);
        let right: Vec<f64> = ab.iter().zip(&ac).map(|(x, y)| x + y).collect();
        if rel_err(&left, &right) > 1e-10 {
            return Err("distributivity failed".into());
        }
        Ok(())
    });
}

#[test]
fn prop_shape_linear_multi_roundtrip() {
    run("shape index", Config { cases: 64, seed: 8 }, |g| {
        let n = g.usize_in(1, 6);
        let dims: Vec<usize> = (0..n).map(|_| g.usize_in(1, 6)).collect();
        let shape = Shape::new(&dims);
        let lin = g.usize_in(0, shape.numel() - 1);
        let idx = shape.multi(lin);
        if shape.linear(&idx) != lin {
            return Err(format!("roundtrip failed at {lin}"));
        }
        let mut idx2 = vec![0; n];
        shape.multi_into(lin, &mut idx2);
        if idx2 != idx {
            return Err("multi_into disagrees with multi".into());
        }
        Ok(())
    });
}
