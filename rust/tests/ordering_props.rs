//! Regression tests for the `float-total-order` paydown.
//!
//! PR 8 replaced every `partial_cmp().unwrap()` float sort in the tree
//! with `f64::total_cmp`. These tests pin the claim that made the swap
//! safe: on NaN-free data the two comparators induce bit-identical
//! orderings (total_cmp additionally orders -0.0 below +0.0, which the
//! fixtures below avoid — no sort site in the tree distinguishes signed
//! zeros), and unlike the old comparator total_cmp cannot panic.

use tensorized_rp::rng::Rng;
use tensorized_rp::util::stats::Summary;

/// Gaussian draws plus the awkward magnitudes: exact duplicates, zero,
/// subnormals, and extreme exponents. No NaN, no -0.0.
fn nan_free_fixture(seed: u64, n: usize) -> Vec<f64> {
    let mut rng = Rng::seed_from(seed);
    let mut xs = rng.gaussian_vec(n, 1.0);
    xs.push(0.0);
    xs.push(1.0);
    xs.push(1.0);
    xs.push(-1.0);
    xs.push(f64::MIN_POSITIVE / 4.0); // subnormal
    xs.push(f64::MAX);
    xs.push(f64::MIN);
    xs.push(f64::EPSILON);
    xs
}

#[test]
fn total_cmp_sort_is_bit_identical_to_partial_cmp_on_nan_free_data() {
    for seed in [3, 41, 271, 828] {
        let xs = nan_free_fixture(seed, 997);
        let mut by_total = xs.clone();
        by_total.sort_by(f64::total_cmp);
        let mut by_partial = xs.clone();
        // lint:allow(float-total-order): this is the regression fixture — it deliberately reproduces the replaced comparator to prove the swap changed no ordering.
        by_partial.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
        assert_eq!(bits(&by_total), bits(&by_partial), "seed {seed}");
    }
}

#[test]
fn dist_then_id_tiebreak_matches_old_comparator() {
    // The index query paths sort (distance, id) pairs; duplicate
    // distances exercise the id tiebreak both comparators share.
    let mut rng = Rng::seed_from(7);
    let mut pairs: Vec<(f64, u64)> = rng
        .gaussian_vec(500, 1.0)
        .into_iter()
        .enumerate()
        .map(|(i, d)| (d.abs(), i as u64))
        .collect();
    let dups: Vec<(f64, u64)> = pairs[..100].iter().map(|&(d, id)| (d, id + 10_000)).collect();
    pairs.extend(dups);
    let mut by_total = pairs.clone();
    by_total.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let mut by_partial = pairs;
    // lint:allow(float-total-order): regression fixture for the replaced tuple comparator (see above).
    by_partial.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    let key = |v: &[(f64, u64)]| v.iter().map(|(d, id)| (d.to_bits(), *id)).collect::<Vec<_>>();
    assert_eq!(key(&by_total), key(&by_partial));
}

#[test]
fn summary_percentiles_unchanged_by_the_comparator_swap() {
    // Summary::of sorts internally; recompute its order statistics with
    // the old comparator and check bit equality of every reported field.
    let xs = nan_free_fixture(1234, 503);
    let s = Summary::of(&xs);
    let mut sorted = xs.clone();
    // lint:allow(float-total-order): regression fixture for the replaced comparator (see above).
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert_eq!(s.min.to_bits(), sorted[0].to_bits());
    assert_eq!(s.max.to_bits(), sorted[sorted.len() - 1].to_bits());
    let pct = |p: f64| tensorized_rp::util::stats::percentile_sorted(&sorted, p);
    assert_eq!(s.median.to_bits(), pct(50.0).to_bits());
    assert_eq!(s.p95.to_bits(), pct(95.0).to_bits());
}

#[test]
fn total_cmp_stays_total_where_the_old_comparator_panicked() {
    // The motivating failure mode: one NaN distance (e.g. a 0/0 from a
    // degenerate norm) turned a query into a panic under
    // partial_cmp().unwrap(). total_cmp sorts it deterministically last.
    let mut xs = vec![2.0, f64::NAN, -1.0, f64::INFINITY, 0.5, f64::NEG_INFINITY];
    xs.sort_by(f64::total_cmp);
    assert_eq!(xs[0], f64::NEG_INFINITY);
    assert_eq!(xs[4], f64::INFINITY);
    assert!(xs[5].is_nan(), "positive NaN sorts above +inf in the total order");
}
