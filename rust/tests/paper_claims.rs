//! Statistical validation of the paper's theorems and claims (DESIGN.md
//! §8). All tests are seeded; tolerances are sized from the CLT so the
//! flake probability is negligible.

use tensorized_rp::linalg::Matrix;
use tensorized_rp::projections::{
    squared_norm, CpProjection, Projection, TrpProjection, TtProjection,
};
use tensorized_rp::rng::Rng;
use tensorized_rp::tensor::{AnyTensor, TtTensor};
use tensorized_rp::theory;
use tensorized_rp::util::stats::{mean, variance};

/// Empirical moments of ‖f(X)‖² over fresh map draws.
fn moments(
    build: impl Fn(&mut Rng) -> Box<dyn Projection>,
    x: &AnyTensor,
    trials: usize,
    seed: u64,
) -> (f64, f64) {
    let mut vals = Vec::with_capacity(trials);
    for t in 0..trials as u64 {
        let mut rng = Rng::seed_from(tensorized_rp::rng::derive_seed(seed, t));
        let f = build(&mut rng);
        vals.push(squared_norm(&f.project(x)));
    }
    (mean(&vals), variance(&vals))
}

#[test]
fn theorem1_expected_isometry_tt_and_cp() {
    // E‖f(X)‖² = ‖X‖²_F for both maps, at several (N, R).
    let mut rng = Rng::seed_from(1);
    for (n, r) in [(3usize, 2usize), (5, 3), (8, 5)] {
        let dims = vec![3usize; n];
        let x = AnyTensor::Tt(TtTensor::random_unit(&dims, 2, &mut rng));
        let k = 48; // larger k shrinks the per-trial variance
        let trials = 300;
        let (m_tt, _) = moments(
            |rng| Box::new(TtProjection::new(&dims, r, k, rng)),
            &x,
            trials,
            100 + n as u64,
        );
        // Theorem 1 TT variance bound → CLT tolerance (4 sigma).
        let tol_tt = 4.0 * (theory::tt_variance_bound(n, r, k) / trials as f64).sqrt();
        assert!(
            (m_tt - 1.0).abs() < tol_tt.max(0.02),
            "TT N={n} R={r}: mean={m_tt}, tol={tol_tt}"
        );
        let (m_cp, _) = moments(
            |rng| Box::new(CpProjection::new(&dims, r, k, rng)),
            &x,
            trials,
            200 + n as u64,
        );
        let tol_cp = 4.0 * (theory::cp_variance_bound(n, r, k) / trials as f64).sqrt();
        assert!(
            (m_cp - 1.0).abs() < tol_cp.max(0.02),
            "CP N={n} R={r}: mean={m_cp}, tol={tol_cp}"
        );
    }
}

#[test]
fn theorem1_variance_bounds_hold_empirically() {
    let mut rng = Rng::seed_from(2);
    for (n, r, k) in [(2usize, 1usize, 8usize), (4, 2, 8), (6, 5, 16)] {
        let dims = vec![3usize; n];
        let x = AnyTensor::Tt(TtTensor::random_unit(&dims, 2, &mut rng));
        // ‖f(X)‖² is heavy-tailed (degree-4N polynomial of Gaussians), so
        // the sample variance converges slowly — use many trials.
        let trials = 3000;
        let (_, v_tt) = moments(
            |rng| Box::new(TtProjection::new(&dims, r, k, rng)),
            &x,
            trials,
            300 + n as u64,
        );
        let bound_tt = theory::tt_variance_bound(n, r, k);
        // Generous slack for the slow, heavy-tailed convergence.
        assert!(
            v_tt <= bound_tt * 1.5,
            "TT N={n} R={r} k={k}: var={v_tt:.4} bound={bound_tt:.4}"
        );
        let (_, v_cp) = moments(
            |rng| Box::new(CpProjection::new(&dims, r, k, rng)),
            &x,
            trials,
            400 + n as u64,
        );
        let bound_cp = theory::cp_variance_bound(n, r, k);
        assert!(
            v_cp <= bound_cp * 1.5,
            "CP N={n} R={r} k={k}: var={v_cp:.4} bound={bound_cp:.4}"
        );
    }
}

#[test]
fn order2_exact_tt_variance_formula() {
    // The paper's closed form for order-2 inputs:
    // Var(‖f_TT(X)‖²) = (2‖X‖⁴ + (6/R)·Tr[(XᵀX)²])/k.
    let mut rng = Rng::seed_from(3);
    let (dr, dc, r, k) = (5usize, 4usize, 3usize, 8usize);
    let x_mat = Matrix::from_vec(dr, dc, rng.gaussian_vec(dr * dc, 1.0));
    let x = AnyTensor::Dense(tensorized_rp::tensor::DenseTensor::from_vec(
        &[dr, dc],
        x_mat.data().to_vec(),
    ));
    let exact = theory::tt_order2_exact_variance(&x_mat, r, k);
    let trials = 4000;
    let (_, emp) = moments(
        |rng| Box::new(TtProjection::new(&[dr, dc], r, k, rng)),
        &x,
        trials,
        55,
    );
    // 4-sigma band for a sample variance of a heavy-ish tailed statistic.
    let rel_tol = 0.25;
    assert!(
        (emp - exact).abs() < exact * rel_tol,
        "exact={exact:.4} empirical={emp:.4}"
    );
}

#[test]
fn tt_needs_smaller_k_than_cp_at_high_order() {
    // The headline: at N=25, TT(10) achieves small distortion at k=64
    // while CP(100) stays near-useless. (Figure 1 right panel, distilled.)
    let mut rng = Rng::seed_from(4);
    let dims = vec![3usize; 25];
    let x = AnyTensor::Tt(TtTensor::random_unit(&dims, 3, &mut rng));
    let trials = 30;
    let mut tt_ds = Vec::new();
    let mut cp_ds = Vec::new();
    for t in 0..trials as u64 {
        let mut rng = Rng::seed_from(tensorized_rp::rng::derive_seed(77, t));
        let f_tt = TtProjection::new(&dims, 10, 64, &mut rng);
        tt_ds.push(tensorized_rp::projections::distortion_ratio(
            &f_tt.project(&x),
            1.0,
        ));
        let f_cp = CpProjection::new(&dims, 100, 64, &mut rng);
        cp_ds.push(tensorized_rp::projections::distortion_ratio(
            &f_cp.project(&x),
            1.0,
        ));
    }
    let tt_mean = mean(&tt_ds);
    let cp_mean = mean(&cp_ds);
    assert!(
        tt_mean < 0.5,
        "TT(10) should embed well at high order: {tt_mean}"
    );
    assert!(
        cp_mean > 2.0 * tt_mean,
        "CP(100) should be far worse: tt={tt_mean} cp={cp_mean}"
    );
}

#[test]
fn trp_equivalence_is_exact() {
    // §3: f_TRP(T) ≡ f_CP(R=T) — exact equality under matched seeds.
    let mut rng = Rng::seed_from(5);
    let dims = [3usize, 4, 3, 2];
    for t in [1usize, 2, 5] {
        let trp = TrpProjection::new(&dims, t, 9, &mut rng);
        let cp = trp.as_cp_projection();
        let x = tensorized_rp::tensor::DenseTensor::random(&dims, &mut rng);
        let y1 = trp.project_dense(&x);
        let y2 = cp.project_dense(&x);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-10, "T={t}");
        }
        assert_eq!(cp.rank(), t);
    }
}

#[test]
fn theorem5_concentration_envelope_holds() {
    // The fraction of trials with distortion ≥ ε must not exceed the
    // Theorem 5 tail bound (with its constants, generously).
    let mut rng = Rng::seed_from(6);
    let dims = vec![3usize; 4];
    let x = AnyTensor::Tt(TtTensor::random_unit(&dims, 2, &mut rng));
    let (n, r, k, eps) = (4usize, 5usize, 128usize, 0.6f64);
    let trials = 400;
    let mut exceed = 0usize;
    for t in 0..trials as u64 {
        let mut rng = Rng::seed_from(tensorized_rp::rng::derive_seed(88, t));
        let f = TtProjection::new(&dims, r, k, &mut rng);
        let d = tensorized_rp::projections::distortion_ratio(&f.project(&x), 1.0);
        if d >= eps {
            exceed += 1;
        }
    }
    let emp = exceed as f64 / trials as f64;
    let bound = theory::tt_concentration_tail(eps, n, r, k);
    assert!(
        emp <= bound + 0.05,
        "empirical tail {emp} exceeds Theorem 5 envelope {bound}"
    );
    // And Chebyshev with the Theorem-1 variance bound is also respected.
    let cheb = theory::tt_variance_bound(n, r, k) / (eps * eps);
    assert!(emp <= cheb.min(1.0) + 0.05, "tail {emp} vs Chebyshev {cheb}");
}

#[test]
fn memory_complexity_matches_paper_table() {
    // O(kNdR²) for TT vs O(kNdR) for CP vs O(kd^N) dense — concretely.
    let mut rng = Rng::seed_from(7);
    let (d, n, k) = (3usize, 8usize, 16usize);
    let dims = vec![d; n];
    let tt = TtProjection::new(&dims, 4, k, &mut rng);
    let cp = CpProjection::new(&dims, 4, k, &mut rng);
    assert_eq!(tt.num_params(), k * ((n - 2) * d * 16 + 2 * d * 4));
    assert_eq!(cp.num_params(), k * n * d * 4);
    let dense_params = k * d.pow(n as u32);
    assert!(tt.num_params() < dense_params / 20);
    assert!(cp.num_params() < tt.num_params());
}

#[test]
fn complexity_scaling_is_linear_in_order() {
    // Projection time O(kNd·max(R,R̃)³): doubling N should ≈ double the
    // time, not square it. Coarse check with generous bounds.
    let mut rng = Rng::seed_from(8);
    let time_for = |n: usize, rng: &mut Rng| -> f64 {
        let dims = vec![3usize; n];
        let f = TtProjection::new(&dims, 5, 32, rng);
        let x = TtTensor::random_unit(&dims, 5, rng);
        // Warmup + median of 5.
        let mut ts = Vec::new();
        f.project_tt(&x);
        for _ in 0..5 {
            let t = tensorized_rp::util::Timer::start();
            std::hint::black_box(f.project_tt(&x));
            ts.push(t.elapsed_secs());
        }
        ts.sort_by(f64::total_cmp);
        ts[2]
    };
    let t8 = time_for(8, &mut rng);
    let t32 = time_for(32, &mut rng);
    let ratio = t32 / t8;
    assert!(
        ratio < 16.0,
        "time should scale ~linearly in N (got {ratio:.1}× for 4× modes)"
    );
}
