//! Panic containment in the serving path.
//!
//! PR 8's hot-path-panic paydown converted the coordinator's lock/ticket
//! plumbing to poison-tolerant recovery (`lock_recover`/`wait_recover`)
//! and made the sequencer's turn hand-off panic-safe via a drop guard.
//! These tests inject worker panics at both seams and assert the lane
//! keeps serving — no wedged turn, no permanently poisoned shard.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::{Duration, Instant};

use tensorized_rp::coordinator::{IndexRegistry, MapKey, MapKind, WorkspacePool};
use tensorized_rp::index::{BackendKind, LshConfig};
use tensorized_rp::util::sync::poison_recoveries;

fn two_shard_slot() -> tensorized_rp::coordinator::SharedIndex {
    let reg = IndexRegistry::new(97, BackendKind::Flat, LshConfig::default()).with_shards(2);
    reg.get_or_create(&MapKey { kind: MapKind::Tt { rank: 2 }, dims: vec![3; 4], k: 4 })
}

#[test]
fn poisoned_shard_lock_does_not_wedge_the_lane() {
    let slot = two_shard_slot();
    // Inject the failure: a worker panics while holding shard 0's index
    // lock, poisoning the mutex.
    let holder = {
        let slot = Arc::clone(&slot);
        std::thread::spawn(move || {
            let _guard = slot.lock_shard(0);
            panic!("injected worker crash while holding the shard lock");
        })
    };
    assert!(holder.join().is_err(), "injected panic should propagate to join");

    let before = poison_recoveries();
    // Continued service: a sequenced insert pass on the poisoned shard
    // must recover the lock and apply its write.
    let (shard, ticket) = slot.issue_tickets(&[0])[0];
    slot.run_shard_turn(shard, ticket, |index| index.insert(11, &[0.25, 1.0, 0.0, -0.5]));
    assert_eq!(slot.shard_lens(), vec![1, 0]);
    assert!(poison_recoveries() > before, "recovery path should be the one that served");

    // And reads still answer on the same shard.
    let pool = WorkspacePool::new();
    let mut ws = pool.acquire();
    let (shard, ticket) = slot.issue_tickets(&[0])[0];
    let hits =
        slot.run_shard_turn(shard, ticket, |index| index.query(&[0.25, 1.0, 0.0, -0.5], 1, &mut ws));
    assert_eq!(hits.len(), 1);
    assert_eq!(hits[0].id, 11);
}

#[test]
fn panicking_pass_hands_the_turn_to_the_next_ticket() {
    let slot = two_shard_slot();
    let (s0, t0) = slot.issue_tickets(&[0])[0];
    let (s1, t1) = slot.issue_tickets(&[0])[0];
    assert_eq!((s0, t0, s1, t1), (0, 0, 0, 1));

    // Inject the failure: the first ticket's pass panics mid-turn.
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        slot.run_shard_turn(s0, t0, |_index| {
            panic!("injected pass failure");
        })
    }));
    assert!(outcome.is_err(), "injected panic should unwind out of the pass");

    // Continued service: the follower ticket's pass must run. If the
    // drop guard failed to advance the turn this would block forever,
    // so drive it on a thread under a watchdog instead of inline.
    let follower = {
        let slot = Arc::clone(&slot);
        std::thread::spawn(move || {
            slot.run_shard_turn(s1, t1, |index| index.insert(21, &[1.0, 0.0, 0.0, 0.0]));
        })
    };
    let deadline = Instant::now() + Duration::from_secs(30);
    while !follower.is_finished() {
        assert!(
            Instant::now() < deadline,
            "lane wedged: the turn did not advance past the panicking pass"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    follower.join().expect("follower pass completes normally");
    assert_eq!(slot.shard_lens(), vec![1, 0]);

    // The untouched lane was never involved and still sequences from 0.
    let (shard, ticket) = slot.issue_tickets(&[1])[0];
    assert_eq!((shard, ticket), (1, 0));
    slot.run_shard_turn(shard, ticket, |index| index.insert(22, &[0.0, 1.0, 0.0, 0.0]));
    assert_eq!(slot.shard_lens(), vec![1, 1]);
}

#[test]
fn barrier_still_covers_every_lane_after_a_panic() {
    // A panic on one lane must not desync issue_barrier's per-lane
    // tickets: drain a full barrier after an injected failure.
    let slot = two_shard_slot();
    let (shard, ticket) = slot.issue_tickets(&[1])[0];
    let _ = catch_unwind(AssertUnwindSafe(|| {
        slot.run_shard_turn(shard, ticket, |_index| {
            panic!("injected pass failure");
        });
    }));

    for (shard, ticket) in slot.issue_barrier() {
        let base = 30 + shard as u64;
        slot.run_shard_turn(shard, ticket, |index| {
            index.insert(base, &[0.5, 0.5, 0.5, 0.5])
        });
    }
    assert_eq!(slot.shard_lens(), vec![1, 1]);
}
