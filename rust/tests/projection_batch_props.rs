//! Property tests for the batch-first execution path: for every map
//! family, `project_batch_into` must produce output **bit-identical** to
//! per-item `project` — across dense/TT/CP input formats, mixed-format
//! batches, and batch sizes {1, 3, 8, 17} — while reusing one shared
//! `Workspace` across all calls (stale scratch must never leak).

use tensorized_rp::projections::{
    CpProjection, GaussianProjection, KroneckerFjlt, Projection, SparseKind, SparseProjection,
    TensorSketch, TrpProjection, TtProjection, Workspace,
};
use tensorized_rp::rng::Rng;
use tensorized_rp::tensor::{AnyTensor, CpTensor, DenseTensor, TtTensor};
use tensorized_rp::util::proptest::{run, Config};

const BATCH_SIZES: [usize; 4] = [1, 3, 8, 17];

fn make_maps(dims: &[usize], k: usize, rng: &mut Rng) -> Vec<Box<dyn Projection>> {
    vec![
        Box::new(GaussianProjection::new(dims, k, rng)),
        Box::new(SparseProjection::new(dims, k, SparseKind::Achlioptas, rng)),
        Box::new(SparseProjection::new(dims, k, SparseKind::VerySparse, rng)),
        Box::new(TtProjection::new(dims, 3, k, rng)),
        Box::new(CpProjection::new(dims, 3, k, rng)),
        Box::new(TrpProjection::new(dims, 2, k, rng)),
        Box::new(KroneckerFjlt::new(dims, k, rng)),
        // 7th map: exercises the trait's default per-item implementation.
        Box::new(TensorSketch::new(dims, k, rng)),
    ]
}

fn input(format: usize, dims: &[usize], rng: &mut Rng) -> AnyTensor {
    match format {
        0 => AnyTensor::Dense(DenseTensor::random_unit(dims, rng)),
        1 => AnyTensor::Tt(TtTensor::random_unit(dims, 2, rng)),
        _ => AnyTensor::Cp(CpTensor::random_unit(dims, 2, rng)),
    }
}

/// Assert bitwise equality between the batched output and per-item
/// projection for every item of `xs`.
fn assert_bit_match(
    map: &dyn Projection,
    xs: &[AnyTensor],
    ws: &mut Workspace,
) -> Result<(), String> {
    let k = map.k();
    let mut out = vec![f64::NAN; xs.len() * k];
    map.project_batch_into(xs, &mut out, ws);
    for (b, x) in xs.iter().enumerate() {
        let want = map.project(x);
        let got = &out[b * k..(b + 1) * k];
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            if g.to_bits() != w.to_bits() {
                return Err(format!(
                    "map {} B={} item {b} component {i}: batched {g:?} != single {w:?}",
                    map.name(),
                    xs.len()
                ));
            }
        }
    }
    Ok(())
}

#[test]
fn batch_matches_single_for_all_maps_formats_and_sizes() {
    // Deterministic exhaustive core of the satellite requirement: six
    // structured maps (+ TensorSketch), three uniform input formats,
    // B ∈ {1, 3, 8, 17}, one workspace shared across everything.
    let dims = [3usize, 4, 2];
    let mut rng = Rng::seed_from(0xB17);
    let maps = make_maps(&dims, 8, &mut rng);
    let mut ws = Workspace::new();
    for map in &maps {
        for format in 0..3 {
            for &b in &BATCH_SIZES {
                let xs: Vec<AnyTensor> =
                    (0..b).map(|_| input(format, &dims, &mut rng)).collect();
                assert_bit_match(map.as_ref(), &xs, &mut ws).unwrap();
            }
        }
    }
}

#[test]
fn prop_batch_matches_single_on_random_mixed_batches() {
    run(
        "batched projection bit-equivalence",
        Config { cases: 24, seed: 0xBA7C },
        |g| {
            // Random small shape, random mixed-format batch: mixed batches
            // take the per-item fallback inside each override, uniform
            // dense batches take the stacked kernels — both must match.
            let order = g.usize_in(2, 4);
            let dims: Vec<usize> = (0..order).map(|_| g.usize_in(2, 4)).collect();
            let k = g.usize_in(1, 9);
            let b = g.usize_in(1, 9);
            let maps = make_maps(&dims, k, g.rng());
            let mut ws = Workspace::new();
            let uniform_dense = g.bool_with(0.5);
            let xs: Vec<AnyTensor> = (0..b)
                .map(|_| {
                    let f = if uniform_dense { 0 } else { g.usize_in(0, 2) };
                    input(f, &dims, g.rng())
                })
                .collect();
            for map in &maps {
                assert_bit_match(map.as_ref(), &xs, &mut ws)?;
            }
            Ok(())
        },
    );
}

#[test]
fn project_batch_convenience_wrapper_matches_into() {
    let dims = [3usize, 3, 3];
    let mut rng = Rng::seed_from(7);
    let f = TtProjection::new(&dims, 2, 6, &mut rng);
    let xs: Vec<AnyTensor> = (0..5)
        .map(|_| AnyTensor::Dense(DenseTensor::random_unit(&dims, &mut rng)))
        .collect();
    let mut ws = Workspace::new();
    let via_wrapper = f.project_batch(&xs, &mut ws);
    let mut via_into = vec![0.0; xs.len() * f.k()];
    f.project_batch_into(&xs, &mut via_into, &mut ws);
    assert_eq!(via_wrapper, via_into);
}
