//! Property tests for the batch-first execution path: for every map
//! family, `project_batch_into` must produce output **bit-identical** to
//! per-item `project` — across dense/TT/CP input formats, mixed-format
//! batches, and batch sizes {1, 3, 8, 17} — while reusing one shared
//! `Workspace` across all calls (stale scratch must never leak).

use tensorized_rp::projections::{
    CpProjection, GaussianProjection, KroneckerFjlt, Projection, SparseKind, SparseProjection,
    TensorSketch, TrpProjection, TtProjection, Workspace,
};
use tensorized_rp::rng::Rng;
use tensorized_rp::tensor::{AnyTensor, CpTensor, DenseTensor, TtTensor};
use tensorized_rp::util::proptest::{run, Config};

const BATCH_SIZES: [usize; 4] = [1, 3, 8, 17];

fn make_maps(dims: &[usize], k: usize, rng: &mut Rng) -> Vec<Box<dyn Projection>> {
    vec![
        Box::new(GaussianProjection::new(dims, k, rng)),
        Box::new(SparseProjection::new(dims, k, SparseKind::Achlioptas, rng)),
        Box::new(SparseProjection::new(dims, k, SparseKind::VerySparse, rng)),
        Box::new(TtProjection::new(dims, 3, k, rng)),
        Box::new(CpProjection::new(dims, 3, k, rng)),
        Box::new(TrpProjection::new(dims, 2, k, rng)),
        Box::new(KroneckerFjlt::new(dims, k, rng)),
        // 7th map: exercises the trait's default per-item implementation.
        Box::new(TensorSketch::new(dims, k, rng)),
    ]
}

fn input(format: usize, dims: &[usize], rng: &mut Rng) -> AnyTensor {
    match format {
        0 => AnyTensor::Dense(DenseTensor::random_unit(dims, rng)),
        1 => AnyTensor::Tt(TtTensor::random_unit(dims, 2, rng)),
        _ => AnyTensor::Cp(CpTensor::random_unit(dims, 2, rng)),
    }
}

/// Assert bitwise equality between the batched output and per-item
/// projection for every item of `xs`.
fn assert_bit_match(
    map: &dyn Projection,
    xs: &[AnyTensor],
    ws: &mut Workspace,
) -> Result<(), String> {
    let k = map.k();
    let mut out = vec![f64::NAN; xs.len() * k];
    map.project_batch_into(xs, &mut out, ws);
    for (b, x) in xs.iter().enumerate() {
        let want = map.project(x);
        let got = &out[b * k..(b + 1) * k];
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            if g.to_bits() != w.to_bits() {
                return Err(format!(
                    "map {} B={} item {b} component {i}: batched {g:?} != single {w:?}",
                    map.name(),
                    xs.len()
                ));
            }
        }
    }
    Ok(())
}

#[test]
fn batch_matches_single_for_all_maps_formats_and_sizes() {
    // Deterministic exhaustive core of the satellite requirement: six
    // structured maps (+ TensorSketch), three uniform input formats,
    // B ∈ {1, 3, 8, 17}, one workspace shared across everything.
    let dims = [3usize, 4, 2];
    let mut rng = Rng::seed_from(0xB17);
    let maps = make_maps(&dims, 8, &mut rng);
    let mut ws = Workspace::new();
    for map in &maps {
        for format in 0..3 {
            for &b in &BATCH_SIZES {
                let xs: Vec<AnyTensor> =
                    (0..b).map(|_| input(format, &dims, &mut rng)).collect();
                assert_bit_match(map.as_ref(), &xs, &mut ws).unwrap();
            }
        }
    }
}

#[test]
fn compressed_shape_groups_match_single_bitwise() {
    // The compressed-input batch kernels: TT/CP/TRP maps × TT/CP inputs,
    // homogeneous and heterogeneous (mixed rank and mixed format)
    // batches, including the B = 1 degenerate group. Every batched
    // output must be bit-identical to per-item `project` dispatch.
    let dims = [3usize, 4, 2];
    let mut rng = Rng::seed_from(0xC0DE);
    let maps: Vec<Box<dyn Projection>> = vec![
        Box::new(TtProjection::new(&dims, 3, 7, &mut rng)),
        Box::new(CpProjection::new(&dims, 4, 7, &mut rng)),
        Box::new(TrpProjection::new(&dims, 2, 7, &mut rng)),
    ];
    let mut ws = Workspace::new();
    for map in &maps {
        // Homogeneous TT batches over B ∈ {1, 3, 8, 17}.
        for &b in &BATCH_SIZES {
            let xs: Vec<AnyTensor> = (0..b)
                .map(|_| AnyTensor::Tt(TtTensor::random_unit(&dims, 3, &mut rng)))
                .collect();
            assert_bit_match(map.as_ref(), &xs, &mut ws).unwrap();
            let xs: Vec<AnyTensor> = (0..b)
                .map(|_| AnyTensor::Cp(CpTensor::random_unit(&dims, 2, &mut rng)))
                .collect();
            assert_bit_match(map.as_ref(), &xs, &mut ws).unwrap();
        }
        // Heterogeneous ranks: TT rank 2 and 4 interleaved — two
        // shape-groups inside one flush, plus a singleton (B = 1) group.
        let mut xs: Vec<AnyTensor> = Vec::new();
        for i in 0..7 {
            let rank = if i % 2 == 0 { 2 } else { 4 };
            xs.push(AnyTensor::Tt(TtTensor::random_unit(&dims, rank, &mut rng)));
        }
        xs.push(AnyTensor::Tt(TtTensor::random_unit(&dims, 1, &mut rng)));
        assert_bit_match(map.as_ref(), &xs, &mut ws).unwrap();
        // Fully mixed: dense + TT (two ranks) + CP (two ranks) in one
        // batch — dense group, two TT groups, two CP groups.
        let xs: Vec<AnyTensor> = vec![
            AnyTensor::Cp(CpTensor::random_unit(&dims, 3, &mut rng)),
            AnyTensor::Dense(DenseTensor::random_unit(&dims, &mut rng)),
            AnyTensor::Tt(TtTensor::random_unit(&dims, 2, &mut rng)),
            AnyTensor::Cp(CpTensor::random_unit(&dims, 1, &mut rng)),
            AnyTensor::Tt(TtTensor::random_unit(&dims, 4, &mut rng)),
            AnyTensor::Dense(DenseTensor::random_unit(&dims, &mut rng)),
            AnyTensor::Tt(TtTensor::random_unit(&dims, 2, &mut rng)),
            AnyTensor::Cp(CpTensor::random_unit(&dims, 3, &mut rng)),
        ];
        assert_bit_match(map.as_ref(), &xs, &mut ws).unwrap();
    }
}

#[test]
fn prop_compressed_batches_match_single_on_random_shapes() {
    run(
        "compressed-batch bit-equivalence",
        Config { cases: 20, seed: 0xC0DE2 },
        |g| {
            let order = g.usize_in(2, 4);
            let dims: Vec<usize> = (0..order).map(|_| g.usize_in(2, 4)).collect();
            let k = g.usize_in(1, 9);
            let b = g.usize_in(1, 9);
            let maps: Vec<Box<dyn Projection>> = vec![
                Box::new(TtProjection::new(&dims, g.usize_in(1, 4), k, g.rng())),
                Box::new(CpProjection::new(&dims, g.usize_in(1, 4), k, g.rng())),
                Box::new(TrpProjection::new(&dims, g.usize_in(1, 3), k, g.rng())),
            ];
            let mut ws = Workspace::new();
            // Random per-item format AND rank: exercises the
            // shape-group partitioning across group counts and sizes.
            let xs: Vec<AnyTensor> = (0..b)
                .map(|_| {
                    let rank = g.usize_in(1, 4);
                    match g.usize_in(0, 2) {
                        0 => AnyTensor::Dense(DenseTensor::random_unit(&dims, g.rng())),
                        1 => AnyTensor::Tt(TtTensor::random_unit(&dims, rank, g.rng())),
                        _ => AnyTensor::Cp(CpTensor::random_unit(&dims, rank, g.rng())),
                    }
                })
                .collect();
            for map in &maps {
                assert_bit_match(map.as_ref(), &xs, &mut ws)?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batch_matches_single_on_random_mixed_batches() {
    run(
        "batched projection bit-equivalence",
        Config { cases: 24, seed: 0xBA7C },
        |g| {
            // Random small shape, random mixed-format batch: mixed batches
            // take the per-item fallback inside each override, uniform
            // dense batches take the stacked kernels — both must match.
            let order = g.usize_in(2, 4);
            let dims: Vec<usize> = (0..order).map(|_| g.usize_in(2, 4)).collect();
            let k = g.usize_in(1, 9);
            let b = g.usize_in(1, 9);
            let maps = make_maps(&dims, k, g.rng());
            let mut ws = Workspace::new();
            let uniform_dense = g.bool_with(0.5);
            let xs: Vec<AnyTensor> = (0..b)
                .map(|_| {
                    let f = if uniform_dense { 0 } else { g.usize_in(0, 2) };
                    input(f, &dims, g.rng())
                })
                .collect();
            for map in &maps {
                assert_bit_match(map.as_ref(), &xs, &mut ws)?;
            }
            Ok(())
        },
    );
}

#[test]
fn project_batch_convenience_wrapper_matches_into() {
    let dims = [3usize, 3, 3];
    let mut rng = Rng::seed_from(7);
    let f = TtProjection::new(&dims, 2, 6, &mut rng);
    let xs: Vec<AnyTensor> = (0..5)
        .map(|_| AnyTensor::Dense(DenseTensor::random_unit(&dims, &mut rng)))
        .collect();
    let mut ws = Workspace::new();
    let via_wrapper = f.project_batch(&xs, &mut ws);
    let mut via_into = vec![0.0; xs.len() * f.k()];
    f.project_batch_into(&xs, &mut via_into, &mut ws);
    assert_eq!(via_wrapper, via_into);
}
