//! Observability properties — the tier-1 gates of the tracing + metrics
//! layer:
//!
//! 1. zero perturbation: the full response stream (embeddings, neighbor
//!    lists, delete acks) is bit-identical with tracing on vs off, for
//!    `{flat, lsh} × {dense, tt} × S ∈ {1, 2, 4}`;
//! 2. exact accounting: multi-connection pipelined TCP traffic produces
//!    exact global and per-signature counter totals in the `metrics`
//!    wire op's snapshot;
//! 3. isolation: signatures never leak counts into each other's entries;
//! 4. histogram consistency: per-stage histograms are internally
//!    consistent (bucket mass equals the count, p50 ≤ p99), and error
//!    replies record end-to-end latency too;
//! 5. coverage: a traced serve session writes parseable span JSONL in
//!    which every required pipeline stage appears, meta records (anchor,
//!    signature interning, stats seal) frame the stream, and the seal
//!    proves zero ring drops;
//! 6. context: a client-supplied trace id is echoed in the response and
//!    threaded into spans; dispatcher-assigned ids never reach the wire;
//! 7. exemplars: context-carrying traffic stamps per-bucket histogram
//!    exemplars that always sit in populated buckets;
//! 8. objectives: a `--slo` objective fires its burn-rate alarm under
//!    injected over-target latency and clears when traffic stops, with
//!    both transitions appended to `alarms.jsonl`.

use std::sync::Arc;
use tensorized_rp::coordinator::{
    Coordinator, CoordinatorConfig, NetClient, NetServer, Payload, ProjectRequest, RequestOp,
};
use tensorized_rp::index::{BackendKind, LshConfig};
use tensorized_rp::obs::{Objective, SloConfig, TraceConfig, OPTIONAL_STAGES, REQUIRED_STAGES};
use tensorized_rp::rng::Rng;
use tensorized_rp::tensor::{AnyTensor, DenseTensor, Format, TtTensor};
use tensorized_rp::util::json::Json;

const DIMS: [usize; 4] = [3, 3, 3, 3];

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("trp_obs_props_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// One response reduced to exactly-comparable bits: id, embedding bit
/// patterns, neighbor (id, dist-bits) pairs, delete ack, trace echo.
type ExactResponse = (u64, Vec<u64>, Option<Vec<(u64, u64)>>, Option<bool>, Option<u64>);

/// Deterministic per-request trace-context id for `ctx` workloads.
fn ctx_id(req_id: u64) -> u64 {
    req_id ^ 0xA5A5
}

/// Pipelined insert → query → delete → query workload against a fresh
/// coordinator; the same seeds produce the same inputs and maps on every
/// call, so two runs may differ only through the serving pipeline itself.
/// With `ctx`, every request carries a client-supplied trace-context id
/// derived from its request id — still deterministic across runs.
fn run_workload(
    backend: BackendKind,
    fmt: &str,
    shards: usize,
    trace: Option<TraceConfig>,
    ctx: bool,
) -> Vec<ExactResponse> {
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 4,
            default_k: 12,
            master_seed: 0xB17,
            index_backend: backend,
            lsh: LshConfig { tables: 4, bits: 7, probes: 2 },
            index_shards: shards,
            trace,
            ..Default::default()
        },
        None,
    );
    let mut rng = Rng::seed_from(0xF00D);
    let input = |rng: &mut Rng| -> AnyTensor {
        if fmt == "tt" {
            AnyTensor::Tt(TtTensor::random_unit(&DIMS, 2, rng))
        } else {
            AnyTensor::Dense(DenseTensor::random_unit(&DIMS, rng))
        }
    };
    let mut out: Vec<ExactResponse> = Vec::new();
    let with_ctx = |req: ProjectRequest| {
        let t = ctx_id(req.id);
        if ctx {
            req.with_trace(t)
        } else {
            req
        }
    };
    let drain = |rxs: Vec<std::sync::mpsc::Receiver<tensorized_rp::coordinator::Reply>>,
                     out: &mut Vec<ExactResponse>| {
        for rx in rxs {
            let resp = rx.recv().expect("coordinator alive").expect("request ok");
            out.push((
                resp.id,
                resp.embedding.iter().map(|v| v.to_bits()).collect(),
                resp.neighbors.map(|ns| {
                    ns.iter().map(|n| (n.id, n.dist.to_bits())).collect()
                }),
                resp.removed,
                resp.trace,
            ));
        }
    };
    let rxs: Vec<_> = (0..8u64)
        .map(|i| coord.submit(with_ctx(ProjectRequest::insert(i, input(&mut rng)))))
        .collect();
    drain(rxs, &mut out);
    let rxs: Vec<_> = (0..4u64)
        .map(|i| coord.submit(with_ctx(ProjectRequest::query(100 + i, input(&mut rng), 3))))
        .collect();
    drain(rxs, &mut out);
    let rxs: Vec<_> = [2u64, 5]
        .iter()
        .map(|&t| {
            coord.submit(with_ctx(ProjectRequest::delete(200 + t, t, Format::Tt, DIMS.to_vec())))
        })
        .collect();
    // Deletes route on the TT signature; for the dense sweep they miss
    // (removed = false) — still part of the compared stream.
    drain(rxs, &mut out);
    let rxs: Vec<_> = (0..2u64)
        .map(|i| coord.submit(with_ctx(ProjectRequest::query(300 + i, input(&mut rng), 3))))
        .collect();
    drain(rxs, &mut out);
    coord.shutdown();
    out
}

#[test]
fn tracing_is_bit_identical_across_backends_formats_and_shards() {
    for backend in [BackendKind::Flat, BackendKind::Lsh] {
        for fmt in ["dense", "tt"] {
            for shards in [1usize, 2, 4] {
                for ctx in [false, true] {
                    let dir = temp_dir(&format!("ident_{backend:?}_{fmt}_{shards}_{ctx}"));
                    let off = run_workload(backend, fmt, shards, None, ctx);
                    let on =
                        run_workload(backend, fmt, shards, Some(TraceConfig::new(&dir)), ctx);
                    let _ = std::fs::remove_dir_all(&dir);
                    assert_eq!(off.len(), on.len());
                    assert_eq!(
                        off, on,
                        "tracing perturbed responses at {backend:?}/{fmt}/S={shards}/ctx={ctx}"
                    );
                    // Echo semantics ride the same comparison: a supplied
                    // context comes back verbatim, and without one the
                    // response stays context-free even while the
                    // dispatcher assigns span ids internally.
                    for (id, _, _, _, echo) in &on {
                        if ctx {
                            assert_eq!(*echo, Some(ctx_id(*id)), "context echo at id {id}");
                        } else {
                            assert_eq!(*echo, None, "assigned span id leaked at id {id}");
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn multi_connection_pipelined_traffic_has_exact_counter_totals() {
    let coord = Arc::new(Coordinator::start(
        CoordinatorConfig { workers: 4, default_k: 12, master_seed: 0xC0, ..Default::default() },
        None,
    ));
    let server = NetServer::start(Arc::clone(&coord), "127.0.0.1:0").unwrap();
    let addr = server.addr();
    let handles: Vec<_> = (0..4u64)
        .map(|c| {
            std::thread::spawn(move || {
                let mut client = NetClient::connect(addr).unwrap();
                let mut rng = Rng::seed_from(100 + c);
                let base = c * 1000;
                let dims = DIMS.to_vec();
                for i in 0..10 {
                    let x = TtTensor::random_unit(&DIMS, 2, &mut rng);
                    client.send(&ProjectRequest::insert(base + i, AnyTensor::Tt(x))).unwrap();
                }
                for i in 0..5 {
                    let x = TtTensor::random_unit(&DIMS, 2, &mut rng);
                    client
                        .send(&ProjectRequest::query(base + 100 + i, AnyTensor::Tt(x), 3))
                        .unwrap();
                }
                for t in [base, base + 1] {
                    client
                        .send(&ProjectRequest::delete(500 + t, t, Format::Tt, dims.clone()))
                        .unwrap();
                }
                for i in 0..3 {
                    let x = TtTensor::random_unit(&DIMS, 2, &mut rng);
                    client.send(&ProjectRequest::new(base + 300 + i, AnyTensor::Tt(x))).unwrap();
                }
                for _ in 0..20 {
                    let resp = client.recv().unwrap();
                    assert!(resp.error.is_none(), "pipelined request failed: {resp:?}");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // 4 connections × (10 insert + 5 query + 2 delete + 3 project) = 80.
    let mut client = NetClient::connect(addr).unwrap();
    let resp = client.roundtrip(&ProjectRequest::metrics(9999, false)).unwrap();
    assert!(resp.error.is_none());
    let snap = resp.metrics.expect("metrics snapshot over the wire");
    assert_eq!(snap.global.submitted, 81, "80 traffic requests + this metrics op");
    assert_eq!(snap.global.completed, 80, "snapshot precedes the op counting itself");
    assert_eq!(snap.global.failed, 0);
    assert_eq!(snap.global.index_inserts, 40);
    assert_eq!(snap.global.index_queries, 20);
    assert_eq!(snap.global.index_deletes, 8);
    assert_eq!(snap.signatures.len(), 1, "one TT signature served everything");
    let sig = &snap.signatures[0];
    assert_eq!(sig.signature, "tt-r5/3x3x3x3/k12");
    assert_eq!(sig.requests, 80);
    assert_eq!(sig.inserts, 40);
    assert_eq!(sig.queries, 20);
    assert_eq!(sig.deletes, 8);
    assert_eq!(sig.projects, 12);
    assert_eq!(sig.errors, 0);
    assert!(sig.flushes >= 1);
    server.shutdown();
}

#[test]
fn signatures_do_not_leak_counts_into_each_other() {
    let coord = Coordinator::start(
        CoordinatorConfig { workers: 2, default_k: 8, master_seed: 7, ..Default::default() },
        None,
    );
    let mut rng = Rng::seed_from(3);
    for i in 0..5u64 {
        let x = TtTensor::random_unit(&DIMS, 2, &mut rng);
        coord.project_blocking(ProjectRequest::insert(i, AnyTensor::Tt(x))).unwrap();
    }
    for i in 0..3u64 {
        let x = DenseTensor::random_unit(&[4, 4], &mut rng);
        coord.project_blocking(ProjectRequest::new(100 + i, AnyTensor::Dense(x))).unwrap();
    }
    for i in 0..2u64 {
        let x = TtTensor::random_unit(&[2, 2, 2], 2, &mut rng);
        coord.project_blocking(ProjectRequest::query(200 + i, AnyTensor::Tt(x), 1)).unwrap();
    }
    let snap =
        coord.project_blocking(ProjectRequest::metrics(999, false)).unwrap().metrics.unwrap();
    assert_eq!(snap.signatures.len(), 3);
    let get = |label: &str| {
        snap.signatures
            .iter()
            .find(|s| s.signature == label)
            .unwrap_or_else(|| panic!("missing signature {label}"))
    };
    let a = get("tt-r5/3x3x3x3/k8");
    assert_eq!((a.requests, a.inserts, a.projects, a.queries), (5, 5, 0, 0));
    let b = get("gaussian/4x4/k8");
    assert_eq!((b.requests, b.projects, b.inserts, b.queries), (3, 3, 0, 0));
    let c = get("tt-r5/2x2x2/k8");
    assert_eq!((c.requests, c.queries, c.inserts, c.projects), (2, 2, 0, 0));
    coord.shutdown();
}

#[test]
fn stage_histograms_are_internally_consistent() {
    let coord = Coordinator::start(
        CoordinatorConfig { workers: 2, default_k: 8, master_seed: 9, ..Default::default() },
        None,
    );
    let mut rng = Rng::seed_from(21);
    let rxs: Vec<_> = (0..20u64)
        .map(|i| {
            let x = TtTensor::random_unit(&DIMS, 2, &mut rng);
            coord.submit(ProjectRequest::insert(i, AnyTensor::Tt(x)))
        })
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let snap =
        coord.project_blocking(ProjectRequest::metrics(99, false)).unwrap().metrics.unwrap();
    assert!(snap.global.p50_latency_us >= 1, "e2e histogram must have observations");
    assert!(snap.global.p50_latency_us <= snap.global.p99_latency_us);
    let sig = &snap.signatures[0];
    assert!(!sig.stages.is_empty());
    for st in &sig.stages {
        assert!(st.count > 0, "capture omits empty stages, got {st:?}");
        assert_eq!(
            st.buckets.iter().sum::<u64>(),
            st.count,
            "bucket mass must equal the observation count in {}",
            st.stage
        );
        assert!(st.p50_us <= st.p99_us, "quantiles out of order in {}", st.stage);
        assert!(st.mean_us >= 0.0);
    }
    coord.shutdown();
}

#[test]
fn error_replies_record_end_to_end_latency() {
    let coord = Coordinator::start(
        CoordinatorConfig { workers: 2, default_k: 8, master_seed: 1, ..Default::default() },
        None,
    );
    // A `project` op with a signature-only payload is rejected before it
    // ever reaches a worker — exactly the path that used to skip the
    // e2e histogram.
    let req = ProjectRequest {
        id: 1,
        op: RequestOp::Project,
        payload: Payload::Signature { format: Format::Tt, dims: DIMS.to_vec() },
        trace: None,
    };
    assert!(coord.project_blocking(req).is_err());
    let snap =
        coord.project_blocking(ProjectRequest::metrics(2, false)).unwrap().metrics.unwrap();
    assert_eq!(snap.global.failed, 1);
    assert!(
        snap.global.p50_latency_us >= 1,
        "failed reply must land in the e2e latency histogram"
    );
    coord.shutdown();
}

#[test]
fn metrics_reset_over_the_wire_clears_high_waters_only() {
    let coord = Arc::new(Coordinator::start(
        CoordinatorConfig {
            workers: 4,
            default_k: 8,
            master_seed: 2,
            index_shards: 2,
            ..Default::default()
        },
        None,
    ));
    let server = NetServer::start(Arc::clone(&coord), "127.0.0.1:0").unwrap();
    let mut client = NetClient::connect(server.addr()).unwrap();
    let mut rng = Rng::seed_from(5);
    for i in 0..8u64 {
        let x = TtTensor::random_unit(&DIMS, 2, &mut rng);
        let resp = client.roundtrip(&ProjectRequest::insert(i, AnyTensor::Tt(x))).unwrap();
        assert!(resp.error.is_none());
    }
    let snap = client
        .roundtrip(&ProjectRequest::metrics(100, true))
        .unwrap()
        .metrics
        .expect("snapshot");
    assert!(snap.global.index_shard_parallel >= 1);
    assert_eq!(snap.global.index_inserts, 8);
    assert!(!snap.trace.enabled, "no trace configured on this server");
    let snap2 = client
        .roundtrip(&ProjectRequest::metrics(101, false))
        .unwrap()
        .metrics
        .expect("snapshot");
    assert_eq!(snap2.global.index_shard_parallel, 0, "reset cleared the high-water");
    assert_eq!(snap2.global.index_shard_max_skew, 0);
    assert_eq!(snap2.global.index_inserts, 8, "counters survive a reset");
    assert_eq!(snap2.signatures[0].inserts, 8);
    server.shutdown();
}

#[test]
fn traced_serve_session_writes_parseable_spans_covering_every_stage() {
    let dir = temp_dir("coverage");
    let coord = Arc::new(Coordinator::start(
        CoordinatorConfig {
            workers: 2,
            default_k: 8,
            master_seed: 4,
            trace: Some(TraceConfig::new(&dir)),
            ..Default::default()
        },
        None,
    ));
    let server = NetServer::start(Arc::clone(&coord), "127.0.0.1:0").unwrap();
    let mut client = NetClient::connect(server.addr()).unwrap();
    let mut rng = Rng::seed_from(11);
    for i in 0..12u64 {
        let x = TtTensor::random_unit(&DIMS, 2, &mut rng);
        client.send(&ProjectRequest::insert(i, AnyTensor::Tt(x))).unwrap();
    }
    for _ in 0..12 {
        assert!(client.recv().unwrap().error.is_none());
    }
    let x = TtTensor::random_unit(&DIMS, 2, &mut rng);
    let resp = client.roundtrip(&ProjectRequest::query(100, AnyTensor::Tt(x), 3)).unwrap();
    assert!(resp.error.is_none());
    drop(client);
    server.shutdown();
    // Last Arc: drop joins the dispatcher and drains the span ring to
    // disk before the recorder thread exits.
    drop(coord);
    let mut stages = std::collections::BTreeSet::new();
    let mut lines = 0u64;
    let mut anchors = 0u64;
    let mut traced_spans = 0u64;
    let mut sealed_dropped: Option<u64> = None;
    for entry in std::fs::read_dir(&dir).expect("trace dir exists") {
        let path = entry.unwrap().path();
        let text = std::fs::read_to_string(&path).unwrap();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let v = Json::parse(line)
                .unwrap_or_else(|e| panic!("unparseable span line {line:?}: {e}"));
            if let Some(kind) = v.get("meta").and_then(Json::as_str) {
                match kind {
                    "anchor" => {
                        // Wall-clock anchor leads every generation so
                        // spans from different processes align.
                        assert_eq!(i, 0, "anchor must be the first line of {path:?}");
                        assert!(v.get("unix_us").and_then(Json::as_usize).is_some());
                        assert!(v.get("epoch_us").and_then(Json::as_usize).is_some());
                        anchors += 1;
                    }
                    "sig" => {
                        assert!(v.get("id").and_then(Json::as_usize).is_some());
                        assert!(v.get("label").and_then(Json::as_str).is_some());
                    }
                    "stats" => {
                        sealed_dropped =
                            Some(v.get("dropped").and_then(Json::as_usize).unwrap() as u64);
                    }
                    other => panic!("unknown meta record kind {other:?}"),
                }
                continue;
            }
            let stage = v
                .get("stage")
                .and_then(Json::as_str)
                .unwrap_or_else(|| panic!("span without stage: {line:?}"))
                .to_string();
            assert!(
                REQUIRED_STAGES.contains(&stage.as_str())
                    || OPTIONAL_STAGES.contains(&stage.as_str()),
                "unknown stage tag {stage:?}"
            );
            assert!(v.get("start_us").and_then(Json::as_usize).is_some(), "bad start_us");
            assert!(v.get("dur_us").and_then(Json::as_usize).is_some(), "bad dur_us");
            if v.get("trace").and_then(Json::as_usize).is_some() {
                traced_spans += 1;
            }
            stages.insert(stage);
            lines += 1;
        }
    }
    assert!(lines > 0, "traced session must write spans");
    assert!(anchors >= 1, "every generation opens with a wall-clock anchor");
    assert!(
        traced_spans > 0,
        "tracing-enabled sessions assign trace-context ids to spans"
    );
    assert_eq!(sealed_dropped, Some(0), "clean shutdown seals the stream with zero drops");
    for s in REQUIRED_STAGES {
        assert!(stages.contains(s), "required stage {s:?} missing from {stages:?}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn trace_context_echoes_over_the_wire_only_when_supplied() {
    let dir = temp_dir("echo");
    let coord = Arc::new(Coordinator::start(
        CoordinatorConfig {
            workers: 2,
            default_k: 8,
            master_seed: 13,
            trace: Some(TraceConfig::new(&dir)),
            ..Default::default()
        },
        None,
    ));
    let server = NetServer::start(Arc::clone(&coord), "127.0.0.1:0").unwrap();
    let mut client = NetClient::connect(server.addr()).unwrap();
    let mut rng = Rng::seed_from(17);
    let x = TtTensor::random_unit(&DIMS, 2, &mut rng);
    let resp = client
        .roundtrip(&ProjectRequest::insert(1, AnyTensor::Tt(x)).with_trace(0xCAFE))
        .unwrap();
    assert!(resp.error.is_none());
    assert_eq!(resp.trace, Some(0xCAFE), "client-supplied context echoes verbatim");
    // No context supplied: even with tracing enabled (the dispatcher is
    // assigning span ids right now) the response stays context-free.
    let x = TtTensor::random_unit(&DIMS, 2, &mut rng);
    let resp = client.roundtrip(&ProjectRequest::query(2, AnyTensor::Tt(x), 1)).unwrap();
    assert!(resp.error.is_none());
    assert_eq!(resp.trace, None, "dispatcher-assigned ids never reach the wire");
    // The early-returning metrics arm echoes too.
    let resp = client.roundtrip(&ProjectRequest::metrics(3, false).with_trace(7)).unwrap();
    assert!(resp.error.is_none());
    assert_eq!(resp.trace, Some(7));
    drop(client);
    server.shutdown();
    drop(coord);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn client_supplied_context_stamps_histogram_exemplars() {
    // No trace dir: exemplars ride the always-on registry and need only
    // the request's own context id.
    let coord = Coordinator::start(
        CoordinatorConfig { workers: 2, default_k: 8, master_seed: 31, ..Default::default() },
        None,
    );
    let mut rng = Rng::seed_from(23);
    for i in 0..10u64 {
        let x = TtTensor::random_unit(&DIMS, 2, &mut rng);
        coord
            .project_blocking(ProjectRequest::insert(i, AnyTensor::Tt(x)).with_trace(1000 + i))
            .unwrap();
    }
    let snap =
        coord.project_blocking(ProjectRequest::metrics(99, false)).unwrap().metrics.unwrap();
    let sig = snap
        .signatures
        .iter()
        .find(|s| s.signature.starts_with("tt-"))
        .expect("TT signature present");
    let mut nonzero = 0u64;
    for st in &sig.stages {
        assert_eq!(
            st.exemplars.len(),
            st.buckets.len(),
            "exemplars align with buckets in {}",
            st.stage
        );
        for (b, &e) in st.exemplars.iter().enumerate() {
            if e == 0 {
                continue;
            }
            nonzero += 1;
            assert!(
                st.buckets[b] > 0,
                "exemplar without observations in {} bucket {b}",
                st.stage
            );
            let t = e - 1;
            assert!(
                (1000..1010).contains(&t),
                "exemplar {t} in {} is not one of the supplied context ids",
                st.stage
            );
        }
    }
    assert!(nonzero > 0, "context-carrying traffic must stamp at least one exemplar");
    coord.shutdown();
}

#[test]
fn slo_alarm_fires_under_injected_latency_and_clears_when_traffic_stops() {
    let dir = temp_dir("slo");
    std::fs::create_dir_all(&dir).unwrap();
    let alarms = dir.join("alarms.jsonl");
    // A 1 µs p99 target no real request can meet: every observation
    // burns budget, so the alarm must fire under sustained traffic. The
    // objective names the traffic signature explicitly so the metrics
    // polls below (a different signature) don't feed the burn windows.
    let slo = SloConfig {
        objectives: vec![Objective {
            signature: "tt-r5/3x3x3x3/k8".into(),
            p99_latency_us: Some(1),
            error_rate: None,
            fast_window_s: 0.05,
            slow_window_s: 0.1,
            burn_threshold: 14.0,
        }],
        poll_interval_ms: 10,
        alarms_path: Some(alarms.clone()),
    };
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 2,
            default_k: 8,
            master_seed: 77,
            slo: Some(slo),
            ..Default::default()
        },
        None,
    );
    let mut rng = Rng::seed_from(41);
    let mut fired = false;
    for round in 0..400u64 {
        let x = TtTensor::random_unit(&DIMS, 2, &mut rng);
        coord.project_blocking(ProjectRequest::insert(round, AnyTensor::Tt(x))).unwrap();
        let snap = coord
            .project_blocking(ProjectRequest::metrics(10_000 + round, false))
            .unwrap()
            .metrics
            .unwrap();
        if snap.slo.iter().any(|s| s.firing) {
            fired = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    assert!(fired, "sustained over-target traffic must trip the burn-rate alarm");
    // Stop the traffic. Once both windows see no new observations the
    // burn rate reads zero and the alarm clears.
    let mut cleared = false;
    for i in 0..500u64 {
        std::thread::sleep(std::time::Duration::from_millis(10));
        let snap = coord
            .project_blocking(ProjectRequest::metrics(20_000 + i, false))
            .unwrap()
            .metrics
            .unwrap();
        if snap.slo.iter().all(|s| !s.firing) {
            cleared = true;
            break;
        }
    }
    assert!(cleared, "alarm must clear once traffic stops");
    coord.shutdown();
    let text = std::fs::read_to_string(&alarms).expect("alarm transitions were appended");
    let states: Vec<String> = text
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| {
            let v = Json::parse(l).expect("alarm line parses");
            assert!(v.get("unix_us").and_then(Json::as_usize).is_some());
            assert!(v.get("signature").and_then(Json::as_str).is_some());
            v.get("state").and_then(Json::as_str).expect("alarm state").to_string()
        })
        .collect();
    assert!(states.contains(&"firing".to_string()), "firing transition logged");
    assert_eq!(states.last().map(String::as_str), Some("clear"), "clear transition logged last");
    let _ = std::fs::remove_dir_all(&dir);
}
