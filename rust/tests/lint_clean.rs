//! Tier-1 gate: the live source tree must be lint-clean.
//!
//! This is the meta-test behind `trp lint` — it runs the same analysis
//! engine over the crate's own sources (resolved via `CARGO_MANIFEST_DIR`,
//! so it works from any cwd) and fails on any unwaived violation. The
//! committed baseline is expected to stay empty: new findings must be
//! fixed or carry a written `lint:allow` reason, not grandfathered.

use std::path::{Path, PathBuf};

use tensorized_rp::analysis::{baseline::Baseline, lint_root, LintReport, RULE_IDS};

fn crate_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

fn baseline_path() -> PathBuf {
    crate_root().join("lint_baseline.txt")
}

fn lint_live_tree() -> LintReport {
    let baseline = Baseline::load(&baseline_path()).expect("committed baseline parses");
    lint_root(crate_root(), baseline).expect("lint walk over the crate sources")
}

#[test]
fn live_tree_has_zero_unwaived_violations() {
    let report = lint_live_tree();
    assert!(report.files > 0, "lint walked no files — wrong root?");
    let rendered: Vec<String> = report.violations.iter().map(|d| d.render()).collect();
    assert!(
        rendered.is_empty(),
        "unwaived lint violations on the live tree:\n{}",
        rendered.join("\n")
    );
}

#[test]
fn committed_baseline_carries_no_grandfathered_sites() {
    // The baseline mechanism exists for future emergencies; this PR pays
    // all findings down, so the committed file must stay entry-free and
    // nothing in it may be stale.
    let report = lint_live_tree();
    assert!(
        report.baselined.is_empty(),
        "baseline should be empty — fix or waive instead:\n{}",
        report.baselined.iter().map(|d| d.render()).collect::<Vec<_>>().join("\n")
    );
    assert_eq!(report.stale_baseline, 0, "stale baseline entries should be pruned");
}

#[test]
fn every_waiver_on_the_live_tree_has_a_written_reason() {
    let report = lint_live_tree();
    // The engine refuses reasonless waivers at parse time, so an empty
    // reason here would mean the invariant broke inside the engine.
    for (diag, reason) in &report.waived {
        assert!(
            !reason.trim().is_empty(),
            "waived finding without a reason: {}",
            diag.render()
        );
    }
    // The tree deliberately carries waivers (dispatcher sweeps, the Vyukov
    // ring); if this count drops to zero the waiver plumbing most likely
    // stopped matching, which would silently weaken the other assertions.
    assert!(
        !report.waived.is_empty(),
        "expected at least one waived finding on the live tree"
    );
}

#[test]
fn rule_catalog_is_the_documented_seven() {
    let expected = [
        "float-total-order",
        "no-fma",
        "hot-path-panic",
        "unordered-iteration",
        "unsafe-audit",
        "relaxed-handoff",
        "fsync-discipline",
    ];
    assert_eq!(RULE_IDS, &expected[..]);
}
