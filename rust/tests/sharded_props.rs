//! Sharded index execution properties — the tier-1 gates of the sharding
//! layer:
//!
//! 1. bit-identity: pipelined `insert → query → delete → query` cycles on
//!    the same id, plus phased concurrent multi-connection traffic,
//!    return bit-identical responses for `S ∈ {1, 2, 4}` vs the
//!    unsharded baseline (`S = 1` runs the same code over a single lane,
//!    and `index_props::coordinator_query_identical_to_direct_index`
//!    anchors that to a direct unsharded index);
//! 2. legacy migration: a pre-shard single-file snapshot restores into a
//!    sharded coordinator by re-partitioning, answering bit-identically
//!    to the unsharded index it captured;
//! 3. consistency: a snapshot captured mid-pipelined-traffic is a
//!    consistent cut, and restores (into a different shard count) to
//!    exactly that cut;
//! 4. saturation: a single hot signature's index phases overlap across
//!    workers (`index_shard_parallel ≥ 2`), which the unsharded design
//!    could never do.

use std::path::PathBuf;
use std::sync::Arc;
use tensorized_rp::coordinator::{
    snapshot_file_stem, Coordinator, CoordinatorConfig, MapKey, MapKind, ProjectRequest,
    ProjectionRegistry,
};
use tensorized_rp::index::{
    shard_of, AnnIndex, BackendKind, FlatIndex, IndexSnapshot, LshConfig, Neighbor,
};
use tensorized_rp::projections::{Projection, Workspace};
use tensorized_rp::rng::Rng;
use tensorized_rp::tensor::{AnyTensor, Format, TtTensor};

const DIMS: [usize; 4] = [3, 3, 3, 3];
const K: usize = 12;
const MASTER_SEED: u64 = 0x5AADED;

fn coordinator(backend: BackendKind, shards: usize, snapshot_dir: Option<PathBuf>) -> Coordinator {
    Coordinator::start(
        CoordinatorConfig {
            workers: 4,
            default_k: K,
            master_seed: MASTER_SEED,
            index_backend: backend,
            lsh: LshConfig { tables: 4, bits: 7, probes: 2 },
            index_shards: shards,
            snapshot_dir,
            ..Default::default()
        },
        None,
    )
}

fn sig_key() -> MapKey {
    MapKey {
        kind: MapKind::Tt { rank: CoordinatorConfig::default().default_tt_rank },
        dims: DIMS.to_vec(),
        k: K,
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("trp_sharded_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn tensors(n: usize, seed: u64) -> Vec<TtTensor> {
    let mut rng = Rng::seed_from(seed);
    (0..n).map(|_| TtTensor::random_unit(&DIMS, 2, &mut rng)).collect()
}

/// Property 1a (named tier-1 gate): pipelined same-id cycles are ordered
/// and bit-identical for S ∈ {1, 2, 4}. Every `insert → query → delete →
/// query` quad rides the pipeline without awaiting replies, so flush
/// boundaries land arbitrarily — arrival-order semantics must hold
/// regardless, on every shard count.
#[test]
fn pipelined_same_id_cycles_bit_identical_for_s_1_2_4() {
    let xs = tensors(30, 7);
    let run = |shards: usize| -> Vec<(Option<Vec<Neighbor>>, Option<bool>)> {
        let c = coordinator(BackendKind::Flat, shards, None);
        let mut rxs = Vec::new();
        for (id, x) in xs.iter().enumerate() {
            let id = id as u64;
            rxs.push(c.submit(ProjectRequest::insert(id, AnyTensor::Tt(x.clone()))));
            rxs.push(c.submit(ProjectRequest::query(1000 + id, AnyTensor::Tt(x.clone()), 3)));
            rxs.push(c.submit(ProjectRequest::delete(2000 + id, id, Format::Tt, DIMS.to_vec())));
            rxs.push(c.submit(ProjectRequest::query(3000 + id, AnyTensor::Tt(x.clone()), 3)));
        }
        let out: Vec<_> = rxs
            .into_iter()
            .map(|rx| {
                let r = rx.recv().unwrap().unwrap();
                (r.neighbors, r.removed)
            })
            .collect();
        c.shutdown();
        out
    };
    let baseline = run(1);
    // Semantic spot-checks on the unsharded baseline: the first query of
    // each quad sees exactly its own item (everything earlier was
    // deleted), the second sees an empty index.
    for (i, quad) in baseline.chunks_exact(4).enumerate() {
        let ns = quad[1].0.as_ref().expect("query returns neighbors");
        assert_eq!(ns.len(), 1, "round {i}: only the round's own item is live");
        assert_eq!(ns[0].id, i as u64);
        assert!(ns[0].dist < 1e-9);
        assert_eq!(quad[2].1, Some(true), "round {i}: delete observes the insert");
        assert_eq!(quad[3].0.as_deref(), Some(&[][..]), "round {i}: post-delete query is empty");
    }
    assert_eq!(run(2), baseline, "S=2 must be bit-identical to the unsharded baseline");
    assert_eq!(run(4), baseline, "S=4 must be bit-identical to the unsharded baseline");
}

/// Property 1b: the same gate under concurrent multi-connection traffic,
/// for both backends. Concurrency is phased so the results stay
/// deterministic: concurrent inserts on disjoint ids (any interleaving
/// produces the same corpus), then concurrent queries against the frozen
/// corpus, then a pipelined mixed tail.
#[test]
fn concurrent_traffic_bit_identical_for_s_1_2_4() {
    const THREADS: usize = 4;
    const PER_THREAD: usize = 12;
    let inserts: Vec<Vec<TtTensor>> =
        (0..THREADS).map(|t| tensors(PER_THREAD, 100 + t as u64)).collect();
    let queries = tensors(6, 900);
    for backend in [BackendKind::Flat, BackendKind::Lsh] {
        let run = |shards: usize| -> Vec<Vec<Vec<Neighbor>>> {
            let c = Arc::new(coordinator(backend, shards, None));
            // Phase 1: concurrent inserts from THREADS "connections".
            let handles: Vec<_> = (0..THREADS)
                .map(|t| {
                    let c = Arc::clone(&c);
                    let xs = inserts[t].clone();
                    std::thread::spawn(move || {
                        for (i, x) in xs.into_iter().enumerate() {
                            let id = (t * 1000 + i) as u64;
                            c.project_blocking(ProjectRequest::insert(id, AnyTensor::Tt(x)))
                                .unwrap();
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            // Phase 2: concurrent queries against the frozen corpus.
            let handles: Vec<_> = (0..THREADS)
                .map(|t| {
                    let c = Arc::clone(&c);
                    let qs = queries.clone();
                    std::thread::spawn(move || {
                        qs.into_iter()
                            .enumerate()
                            .map(|(i, q)| {
                                c.project_blocking(ProjectRequest::query(
                                    (9000 + t * 100 + i) as u64,
                                    AnyTensor::Tt(q),
                                    5,
                                ))
                                .unwrap()
                                .neighbors
                                .unwrap()
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            let out: Vec<Vec<Vec<Neighbor>>> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            c.shutdown();
            out
        };
        let baseline = run(1);
        if backend == BackendKind::Flat {
            // Exact scan always fills k on a 48-item corpus; LSH may
            // legitimately probe fewer candidates — for it the property
            // is the cross-shard comparison alone.
            for row in &baseline {
                for ns in row {
                    assert_eq!(ns.len(), 5, "corpus is large enough for k=5");
                }
            }
        }
        assert_eq!(run(2), baseline, "{}: S=2 differs from baseline", backend.name());
        assert_eq!(run(4), baseline, "{}: S=4 differs from baseline", backend.name());
    }
}

/// Property 2 (legacy migration): a pre-shard single-file snapshot — the
/// PR 3/4 on-disk layout — restores into a sharded coordinator by
/// re-partitioning its pairs, and post-restore queries are bit-identical
/// to the unsharded index the file captured.
#[test]
fn legacy_snapshot_restores_bit_identical_into_sharded_coordinator() {
    let dir = tmp_dir("legacy");
    let key = sig_key();
    let xs = tensors(20, 41);
    let queries = tensors(5, 42);
    // The unsharded baseline: the same deterministic map the coordinator
    // draws (same master seed + key policy), feeding a plain FlatIndex.
    let registry = ProjectionRegistry::new(MASTER_SEED);
    let map = registry.get_or_create(&key);
    let mut baseline = FlatIndex::new(K);
    for (i, x) in xs.iter().enumerate() {
        baseline.insert(i as u64, &map.map.project(&AnyTensor::Tt(x.clone())));
    }
    // Write the legacy layout: one unsequenced `<stem>.snap` file.
    let snap = IndexSnapshot::capture(key.encode(), &baseline);
    snap.write_atomic(&dir.join(format!("{}.snap", snapshot_file_stem(&key)))).unwrap();
    // A sharded coordinator restores it at startup (re-partition into 4).
    let c = coordinator(BackendKind::Flat, 4, Some(dir.clone()));
    let (sigs, items) = c.restore_from(&dir).unwrap();
    assert_eq!((sigs, items), (1, 20));
    let slot = c.index_slot(&key);
    assert_eq!(slot.shards(), 4);
    assert_eq!(slot.shard_lens().iter().sum::<u64>(), 20);
    let mut ws = Workspace::new();
    for (qi, q) in queries.iter().enumerate() {
        let served = c
            .project_blocking(ProjectRequest::query(500 + qi as u64, AnyTensor::Tt(q.clone()), 6))
            .unwrap()
            .neighbors
            .unwrap();
        let direct = baseline.query(&map.map.project(&AnyTensor::Tt(q.clone())), 6, &mut ws);
        assert_eq!(served, direct, "restored sharded answers must match the legacy index");
    }
    // The wire `restore` op re-reads the same legacy file at runtime:
    // mutate past the cut, restore, and the extra item is gone.
    c.project_blocking(ProjectRequest::insert(777, AnyTensor::Tt(queries[0].clone()))).unwrap();
    let r = c
        .project_blocking(ProjectRequest::restore(778, Format::Tt, DIMS.to_vec()))
        .unwrap();
    assert_eq!(r.restored, Some(20));
    let stats = c
        .project_blocking(ProjectRequest::index_stats(779, Format::Tt, DIMS.to_vec()))
        .unwrap()
        .index
        .unwrap();
    assert_eq!(stats.len, 20, "restore rewound past the post-cut insert");
    assert_eq!(stats.shards, 4);
    c.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Property 3: a snapshot op pipelined into the middle of a burst —
/// submitted before any reply is awaited — captures exactly the ops that
/// arrived before it (consistent cut across every shard), writes the
/// sharded manifest layout off-turn, and restores into a *different*
/// shard count bit-identically.
#[test]
fn snapshot_mid_traffic_is_a_consistent_cut_across_shards() {
    let dir = tmp_dir("cut");
    let xs = tensors(60, 77);
    let queries = tensors(5, 78);
    let c = coordinator(BackendKind::Flat, 4, Some(dir.clone()));
    let mut rxs = Vec::new();
    for (i, x) in xs.iter().take(40).enumerate() {
        rxs.push(c.submit(ProjectRequest::insert(i as u64, AnyTensor::Tt(x.clone()))));
    }
    rxs.push(c.submit(ProjectRequest::snapshot(5000, Format::Tt, DIMS.to_vec())));
    for (i, x) in xs.iter().enumerate().skip(40) {
        rxs.push(c.submit(ProjectRequest::insert(i as u64, AnyTensor::Tt(x.clone()))));
    }
    let mut report = None;
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        if let Some(s) = resp.snapshot {
            report = Some(s);
        }
    }
    let report = report.expect("snapshot op replies with a report");
    assert_eq!(report.items, 40, "the cut holds exactly the pre-snapshot arrivals");
    assert!(report.path.ends_with(".manifest"), "sharded snapshots are manifest-rooted");
    let stem = snapshot_file_stem(&sig_key());
    let shard_files: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| {
            let n = e.file_name().to_string_lossy().into_owned();
            n.starts_with(&stem) && n.contains(".shard") && n.ends_with(".snap")
        })
        .collect();
    assert_eq!(shard_files.len(), 4, "one file per shard");
    assert_eq!(c.metrics().index_snapshots, 1);
    c.shutdown(); // the "kill"

    // Restore into a coordinator sharded differently (2 ≠ 4): the pairs
    // re-partition, and answers must match a replay of exactly the
    // pre-cut ops on an unsharded coordinator.
    let b = coordinator(BackendKind::Flat, 2, Some(dir.clone()));
    let (sigs, items) = b.restore_from(&dir).unwrap();
    assert_eq!((sigs, items), (1, 40));
    let replay = coordinator(BackendKind::Flat, 1, None);
    for (i, x) in xs.iter().take(40).enumerate() {
        replay
            .project_blocking(ProjectRequest::insert(i as u64, AnyTensor::Tt(x.clone())))
            .unwrap();
    }
    for (qi, q) in queries.iter().enumerate() {
        let id = 6000 + qi as u64;
        let restored = b
            .project_blocking(ProjectRequest::query(id, AnyTensor::Tt(q.clone()), 7))
            .unwrap()
            .neighbors
            .unwrap();
        let truth = replay
            .project_blocking(ProjectRequest::query(id, AnyTensor::Tt(q.clone()), 7))
            .unwrap()
            .neighbors
            .unwrap();
        assert_eq!(restored, truth, "restored cut must answer like the pre-cut replay");
        assert!(restored.iter().all(|n| n.id < 40), "post-cut inserts must be absent");
    }
    b.shutdown();
    replay.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Property 4 (saturation): with one hot signature sharded 4-ways and
/// single-insert flushes, index phases must overlap across workers —
/// `index_shard_parallel ≥ 2` — which the single-lane design could never
/// produce. Skipped on single-core machines (no real overlap to observe);
/// retried in rounds elsewhere since the gauge is a high-water mark over
/// genuinely concurrent passes.
#[test]
fn saturation_runs_index_phases_on_multiple_workers() {
    if std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) < 2 {
        eprintln!("[sharded_props] single-core machine — skipping the overlap assertion");
        return;
    }
    let c = Coordinator::start(
        CoordinatorConfig {
            workers: 4,
            default_k: K,
            master_seed: MASTER_SEED,
            index_backend: BackendKind::Lsh,
            lsh: LshConfig { tables: 6, bits: 8, probes: 2 },
            index_shards: 4,
            // Single-request flushes: every insert is its own job, so
            // disjoint-shard jobs can run truly concurrently.
            native_max_batch: 1,
            adaptive_batch: false,
            ..Default::default()
        },
        None,
    );
    let mut rng = Rng::seed_from(55);
    for round in 0..6u64 {
        let xs: Vec<TtTensor> =
            (0..200).map(|_| TtTensor::random_unit(&DIMS, 2, &mut rng)).collect();
        let rxs: Vec<_> = xs
            .into_iter()
            .enumerate()
            .map(|(i, x)| {
                c.submit(ProjectRequest::insert(round * 1000 + i as u64, AnyTensor::Tt(x)))
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        if c.metrics().index_shard_parallel >= 2 {
            break;
        }
    }
    let m = c.metrics();
    assert!(
        m.index_shard_parallel >= 2,
        "sharded single-signature ingest must overlap index phases across \
         workers (saw high-water {})",
        m.index_shard_parallel
    );
    // The skew gauge observed a live (possibly imbalanced) partition.
    let stats = c
        .project_blocking(ProjectRequest::index_stats(1, Format::Tt, DIMS.to_vec()))
        .unwrap()
        .index
        .unwrap();
    assert_eq!(stats.shards, 4);
    assert!(stats.len > 0);
    c.shutdown();
}

/// The partitioning rule is pure and stable — restore re-partitions rely
/// on it, so pin it down at the integration level too.
#[test]
fn partitioning_is_stable_and_total() {
    for id in 0..1000u64 {
        for s in [1usize, 2, 4, 8] {
            assert!(shard_of(id, s) < s);
            assert_eq!(shard_of(id, s), shard_of(id, s));
        }
        assert_eq!(shard_of(id, 1), 0);
    }
}
