//! Cross-module integration tests: full experiment pipelines on reduced
//! settings, CLI config plumbing, data → projection → metric flows.

use tensorized_rp::data::images::load_images;
use tensorized_rp::data::inputs::Regime;
use tensorized_rp::experiments::{ablations, fig1, fig2, fig3, fig4, MapSpec};
use tensorized_rp::projections::Projection;
use tensorized_rp::rng::Rng;
use tensorized_rp::tensor::{CpTensor, DenseTensor, TtTensor};
use tensorized_rp::util::csv::CsvTable;

#[test]
fn fig1_pipeline_quick() {
    let mut cfg = fig1::Fig1Config::quick(Regime::Small);
    cfg.ks = vec![8, 64];
    cfg.trials = 6;
    let rows = fig1::run(&cfg);
    assert_eq!(rows.len(), 7 * 2);
    // Within each series, distortion at k=64 ≤ distortion at k=8 on
    // average is likely but noisy per-series; check the aggregate.
    let mean_at = |k: usize| -> f64 {
        let sel: Vec<f64> = rows.iter().filter(|r| r.k == k).map(|r| r.mean).collect();
        sel.iter().sum::<f64>() / sel.len() as f64
    };
    assert!(mean_at(64) < mean_at(8), "aggregate distortion must shrink with k");
    // CSV round-trips.
    let csv = fig1::to_csv(Regime::Small, &rows);
    let parsed = CsvTable::parse(&csv.to_csv()).unwrap();
    assert_eq!(parsed.len(), rows.len());
}

#[test]
fn fig2_pipeline_quick() {
    let mut cfg = fig2::Fig2Config::quick();
    cfg.ks = vec![8];
    cfg.reps = 1;
    let rows = fig2::run(&cfg);
    assert_eq!(rows.len(), 14);
    assert!(fig2::to_csv(&rows).to_csv().contains("very_sparse"));
}

#[test]
fn fig3_pipeline_quick_with_synthetic_images() {
    let mut cfg = fig3::Fig3Config::quick();
    cfg.cifar_path = None;
    cfg.n_images = 4;
    cfg.ks = vec![12];
    cfg.trials = 2;
    let rows = fig3::run(&cfg);
    assert_eq!(rows.len(), 9);
    assert!(rows.iter().all(|r| r.source == "synthetic"));
}

#[test]
fn fig4_pipeline_quick() {
    let cfg = fig4::Fig4Config::quick();
    let rows = fig4::run(&cfg);
    // Both panels present, all series feasible at small orders.
    assert!(rows.len() >= 2 * 2 * 5);
    let csv = fig4::to_csv(&rows);
    assert!(csv.len() == rows.len());
}

#[test]
fn ablation_pipeline_quick() {
    let cfg = ablations::AblationConfig::quick();
    let rows = ablations::run_variance_sweep(&cfg);
    assert_eq!(rows.len(), 2 * cfg.orders.len() * cfg.ranks.len());
    for r in &rows {
        assert!(r.emp_var.is_finite() && r.bound > 0.0);
    }
}

#[test]
fn all_maps_agree_across_input_formats_at_scale() {
    // One shared medium-ish shape; every map must give identical results
    // for the same tensor presented dense / TT / CP.
    let mut rng = Rng::seed_from(42);
    let dims = vec![3usize; 6];
    let cp_x = CpTensor::random_unit(&dims, 3, &mut rng);
    let dense_x = cp_x.to_dense();
    let tt_x = cp_x.to_tt();
    for spec in [
        MapSpec::Gaussian,
        MapSpec::VerySparse,
        MapSpec::Tt(4),
        MapSpec::Cp(6),
    ] {
        let f = spec.build(&dims, 12, &mut rng);
        let y_dense = f.project_dense(&dense_x);
        let y_tt = f.project_tt(&tt_x);
        let y_cp = f.project_cp(&cp_x);
        for i in 0..12 {
            assert!(
                (y_dense[i] - y_tt[i]).abs() < 1e-8,
                "{}: dense vs tt at {i}",
                spec.label()
            );
            assert!(
                (y_dense[i] - y_cp[i]).abs() < 1e-8,
                "{}: dense vs cp at {i}",
                spec.label()
            );
        }
    }
}

#[test]
fn pairwise_distances_are_preserved_for_moderate_k() {
    // JL property on a concrete point set: all pairwise distances of 10
    // image tensors preserved within 60% at k=256 (loose but meaningful).
    let (images, _) = load_images(10, None, 3);
    let tensors: Vec<DenseTensor> = images.iter().map(|im| im.to_tensor()).collect();
    let mut rng = Rng::seed_from(4);
    let f = tensorized_rp::projections::TtProjection::new(
        &tensorized_rp::data::images::TENSOR_DIMS,
        5,
        256,
        &mut rng,
    );
    let projected: Vec<Vec<f64>> = tensors.iter().map(|t| f.project_dense(t)).collect();
    for i in 0..tensors.len() {
        for j in (i + 1)..tensors.len() {
            let dx = tensors[i].sub(&tensors[j]).fro_norm();
            let dy: f64 = projected[i]
                .iter()
                .zip(&projected[j])
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            let ratio = dy / dx;
            assert!(
                (0.4..2.5).contains(&ratio),
                "pair ({i},{j}): ratio {ratio}"
            );
        }
    }
}

#[test]
fn non_uniform_mode_sizes_are_supported_end_to_end() {
    // The theory (and this implementation) allow d₁ ≠ … ≠ d_N; only the
    // AOT artifacts fix uniform shapes. Exercise every map on mixed dims.
    let mut rng = Rng::seed_from(77);
    let dims = vec![2usize, 5, 3, 4];
    let x_tt = TtTensor::random_unit(&dims, 3, &mut rng);
    let x_dense = x_tt.to_dense();
    for spec in [
        MapSpec::Gaussian,
        MapSpec::VerySparse,
        MapSpec::Tt(3),
        MapSpec::Cp(4),
    ] {
        let f = spec.build(&dims, 10, &mut rng);
        let y_tt = f.project_tt(&x_tt);
        let y_dense = f.project_dense(&x_dense);
        assert_eq!(y_tt.len(), 10, "{}", spec.label());
        for i in 0..10 {
            assert!(
                (y_tt[i] - y_dense[i]).abs() < 1e-8,
                "{} mixed dims: tt vs dense at {i}",
                spec.label()
            );
        }
    }
    // TensorSketch and TRP too.
    let ts = tensorized_rp::projections::TensorSketch::new(&dims, 10, &mut rng);
    let y = ts.project_dense(&x_dense);
    assert_eq!(y.len(), 10);
    let trp = tensorized_rp::projections::TrpProjection::new(&dims, 2, 10, &mut rng);
    assert_eq!(trp.project_dense(&x_dense).len(), 10);
}

#[test]
fn tt_arithmetic_composes_with_projections() {
    // f(a + b) == f(a) + f(b) where the sum is computed in TT format.
    let mut rng = Rng::seed_from(78);
    let dims = vec![3usize; 5];
    let a = TtTensor::random(&dims, 2, &mut rng);
    let b = TtTensor::random(&dims, 2, &mut rng);
    let sum = a.add(&b).round(1e-12, 16);
    let f = tensorized_rp::projections::TtProjection::new(&dims, 3, 12, &mut rng);
    let ya = f.project_tt(&a);
    let yb = f.project_tt(&b);
    let ysum = f.project_tt(&sum);
    for i in 0..12 {
        assert!((ysum[i] - ya[i] - yb[i]).abs() < 1e-8);
    }
}

#[test]
fn tt_svd_roundtrip_through_projection() {
    // Dense → TT-SVD → project in TT format ≈ project dense directly.
    let mut rng = Rng::seed_from(5);
    let src = TtTensor::random(&[4, 3, 4, 3], 3, &mut rng);
    let dense = src.to_dense();
    let recompressed = TtTensor::tt_svd(&dense, 1e-10, 32);
    let f = tensorized_rp::projections::TtProjection::new(&[4, 3, 4, 3], 3, 16, &mut rng);
    let y1 = f.project_dense(&dense);
    let y2 = f.project_tt(&recompressed);
    for (a, b) in y1.iter().zip(&y2) {
        assert!((a - b).abs() < 1e-7);
    }
}

#[test]
fn workload_trace_feeds_coordinator() {
    use tensorized_rp::coordinator::{Coordinator, CoordinatorConfig, ProjectRequest};
    use tensorized_rp::data::workload::{poisson_trace, FormatMix};
    let trace = poisson_trace(16, 10_000.0, Regime::Small, FormatMix::default(), 8);
    let coord = Coordinator::start(
        CoordinatorConfig { default_k: 8, workers: 2, ..Default::default() },
        None,
    );
    let rxs: Vec<_> = trace
        .payloads
        .into_iter()
        .enumerate()
        .map(|(i, p)| coord.submit(ProjectRequest::new(i as u64, p)))
        .collect();
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.embedding.len(), 8);
    }
    coord.shutdown();
}

#[test]
fn theory_guides_experiments_consistently() {
    // suggest_k must recommend TT in every regime the experiments cover.
    for n in [3usize, 12, 25] {
        let (map, _) = tensorized_rp::theory::suggest_k(0.5, n, 10, 100, 0.05);
        if n > 3 {
            assert_eq!(map, "tt");
        }
    }
}
