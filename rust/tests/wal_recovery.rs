//! Tier-1 gate: crash recovery through the write-ahead log.
//!
//! The durability contract under test: with `wal_dir` configured, every
//! acknowledged mutation survives an abrupt process death (no shutdown,
//! no final snapshot), and a restarted coordinator answers queries
//! **bit-identically** to an uninterrupted twin that received exactly
//! the recovered ops. Crashes are injected three ways:
//!
//! 1. in-process "SIGKILL" (`std::mem::forget` of the live coordinator —
//!    no destructor runs, exactly like a kill) at the end of a pipelined
//!    ingest burst, across {flat, lsh} × S ∈ {1, 2, 4}, always restoring
//!    into a *different* shard count;
//! 2. a real `SIGKILL` of a `trp serve --listen --wal-dir` child process
//!    at randomized points during concurrent pipelined TCP ingest —
//!    recovery must hold acked ⊆ recovered ⊆ sent;
//! 3. an injected panic mid shard-turn (poisons the lane), after which
//!    the WAL must still be appendable and replayable.
//!
//! Plus the zero-behavior-change tripwire: without `wal_dir` the
//! coordinator's replies are bit-identical to a WAL-less twin and no WAL
//! counter ever moves.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use tensorized_rp::coordinator::{
    Coordinator, CoordinatorConfig, IndexRegistry, MapKey, MapKind, NetClient, ProjectRequest,
};
use tensorized_rp::data::inputs::unit_input;
use tensorized_rp::index::{shard_of, wal, BackendKind, LshConfig, WalConfig, WalFsync};
use tensorized_rp::rng::Rng;
use tensorized_rp::tensor::AnyTensor;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("trp_walrec_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn coordinator(
    backend: BackendKind,
    shards: usize,
    snap: Option<&Path>,
    wal_dir: Option<&Path>,
) -> Coordinator {
    Coordinator::start(
        CoordinatorConfig {
            workers: 3,
            default_k: 12,
            master_seed: 0xFEED,
            index_backend: backend,
            lsh: LshConfig { tables: 4, bits: 8, probes: 2 },
            index_shards: shards,
            snapshot_dir: snap.map(Path::to_path_buf),
            wal_dir: wal_dir.map(Path::to_path_buf),
            // Tiny cap so every burst crosses several segment rotations.
            wal_segment_cap: 1024,
            wal_fsync: WalFsync::Flush,
            ..Default::default()
        },
        None,
    )
}

/// Pipelined burst: 24 inserts, then a delete of id 3, all submitted
/// before a single reply is awaited.
fn ingest_burst(coord: &Coordinator, payloads: &[AnyTensor]) {
    let fmt = payloads[0].format();
    let dims = vec![3usize; 4];
    let mut rxs = Vec::new();
    for (i, p) in payloads.iter().enumerate() {
        rxs.push(coord.submit(ProjectRequest::insert(i as u64, p.clone())));
    }
    rxs.push(coord.submit(ProjectRequest::delete(100, 3, fmt, dims)));
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
}

fn query_ids(coord: &Coordinator, q: &AnyTensor, id: u64, k: usize) -> Vec<u64> {
    coord
        .project_blocking(ProjectRequest::query(id, q.clone(), k))
        .unwrap()
        .neighbors
        .unwrap()
        .iter()
        .map(|n| n.id)
        .collect()
}

#[test]
fn killed_coordinator_recovers_bit_identically_into_a_different_shard_count() {
    for backend in [BackendKind::Flat, BackendKind::Lsh] {
        for (s_before, s_after) in [(1usize, 2usize), (2, 4), (4, 1)] {
            let tag = format!("{}_{s_before}to{s_after}", backend.name());
            let root = tmp_dir(&tag);
            let snap = root.join("snap");
            let wal_dir = root.join("wal");
            let dims = vec![3usize; 4];
            let mut rng = Rng::seed_from(31);
            let payloads: Vec<AnyTensor> =
                (0..24).map(|_| unit_input(&dims, 2, "tt", &mut rng)).collect();
            let queries: Vec<AnyTensor> =
                (0..6).map(|_| unit_input(&dims, 2, "tt", &mut rng)).collect();

            // Coordinator A ingests, gets every ack, then "dies": forget
            // runs no destructor — no shutdown snapshot, no WAL close,
            // exactly the state a SIGKILL leaves behind.
            let a = coordinator(backend, s_before, Some(&snap), Some(&wal_dir));
            ingest_burst(&a, &payloads);
            std::mem::forget(a);

            // Coordinator B restarts with a DIFFERENT shard count;
            // recovery runs inside start(), before any traffic.
            let b = coordinator(backend, s_after, Some(&snap), Some(&wal_dir));
            assert_eq!(
                b.metrics().wal_replayed,
                25,
                "[{tag}] 24 inserts + 1 delete replayed from the segment tail"
            );

            // Twin C: uninterrupted, same ops, same shard count as B.
            let c = coordinator(backend, s_after, None, None);
            ingest_burst(&c, &payloads);

            for (qi, q) in queries.iter().enumerate() {
                let id = 500 + qi as u64;
                let nb = b
                    .project_blocking(ProjectRequest::query(id, q.clone(), 5))
                    .unwrap()
                    .neighbors
                    .unwrap();
                let nc = c
                    .project_blocking(ProjectRequest::query(id, q.clone(), 5))
                    .unwrap()
                    .neighbors
                    .unwrap();
                assert_eq!(
                    nb, nc,
                    "[{tag}] recovered replies must be bit-identical to the twin"
                );
                assert!(nb.iter().all(|n| n.id != 3), "[{tag}] logged delete replayed");
            }
            b.shutdown();
            c.shutdown();
            let _ = std::fs::remove_dir_all(&root);
        }
    }
}

#[test]
fn snapshot_checkpoint_bounds_replay_to_the_segment_tail() {
    let root = tmp_dir("checkpoint");
    let snap = root.join("snap");
    let wal_dir = root.join("wal");
    let dims = vec![3usize; 4];
    let mut rng = Rng::seed_from(47);
    let payloads: Vec<AnyTensor> =
        (0..24).map(|_| unit_input(&dims, 2, "tt", &mut rng)).collect();
    let queries: Vec<AnyTensor> =
        (0..4).map(|_| unit_input(&dims, 2, "tt", &mut rng)).collect();
    let fmt = payloads[0].format();

    // A: 12 inserts, a snapshot op (the WAL checkpoint), 12 more inserts
    // and a delete — all pipelined — then death without shutdown.
    let a = coordinator(BackendKind::Flat, 2, Some(&snap), Some(&wal_dir));
    let mut rxs = Vec::new();
    for (i, p) in payloads.iter().take(12).enumerate() {
        rxs.push(a.submit(ProjectRequest::insert(i as u64, p.clone())));
    }
    rxs.push(a.submit(ProjectRequest::snapshot(200, fmt, dims.clone())));
    for (i, p) in payloads.iter().enumerate().skip(12) {
        rxs.push(a.submit(ProjectRequest::insert(i as u64, p.clone())));
    }
    rxs.push(a.submit(ProjectRequest::delete(201, 3, fmt, dims.clone())));
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    assert_eq!(a.metrics().index_snapshots, 1);
    std::mem::forget(a);

    // B restores into 3 shards: the checkpoint supplies the first 12
    // items, the WAL supplies ONLY the 13-op tail past the marks.
    let b = coordinator(BackendKind::Flat, 3, Some(&snap), Some(&wal_dir));
    assert_eq!(
        b.metrics().wal_replayed,
        13,
        "records covered by the checkpoint watermarks must not replay"
    );

    let c = coordinator(BackendKind::Flat, 3, None, None);
    ingest_burst(&c, &payloads);

    for (qi, q) in queries.iter().enumerate() {
        let id = 600 + qi as u64;
        let nb = b
            .project_blocking(ProjectRequest::query(id, q.clone(), 5))
            .unwrap()
            .neighbors
            .unwrap();
        let nc = c
            .project_blocking(ProjectRequest::query(id, q.clone(), 5))
            .unwrap()
            .neighbors
            .unwrap();
        assert_eq!(nb, nc, "checkpoint + tail must equal the uninterrupted stream");
    }
    b.shutdown();
    c.shutdown();
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn sigkill_mid_pipelined_ingest_loses_no_acked_op() {
    let root = tmp_dir("sigkill");
    for (round, kill_ms) in [40u64, 160].into_iter().enumerate() {
        let snap = root.join(format!("snap{round}"));
        let wal_dir = root.join(format!("wal{round}"));
        std::fs::create_dir_all(&snap).unwrap();

        let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_trp"))
            .args([
                "serve",
                "--listen",
                "127.0.0.1:0",
                "--no-pjrt",
                "--seed",
                "4242",
                "--snapshot-dir",
                snap.to_str().unwrap(),
                "--wal-dir",
                wal_dir.to_str().unwrap(),
                "--wal-segment-cap",
                "8192",
            ])
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::null())
            .spawn()
            .expect("spawn trp serve");
        let addr = {
            use std::io::BufRead;
            let out = child.stdout.take().unwrap();
            let mut found = None;
            for line in std::io::BufReader::new(out).lines() {
                let line = line.unwrap();
                if let Some(rest) = line.strip_prefix("[serve] listening on ") {
                    found = rest.split_whitespace().next().map(str::to_string);
                    break;
                }
            }
            found.expect("child announced its listen address")
        };

        // Concurrent pipelined ingest until the connection dies under us.
        let acked = Arc::new(Mutex::new(Vec::<u64>::new()));
        let sent = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let ingest = {
            let (acked, sent, stop) = (Arc::clone(&acked), Arc::clone(&sent), Arc::clone(&stop));
            std::thread::spawn(move || {
                let Ok(mut client) = NetClient::connect(&addr) else { return };
                let dims = vec![3usize; 4];
                let mut rng = Rng::seed_from(1717);
                for i in 0..u64::MAX {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    let x = unit_input(&dims, 2, "tt", &mut rng);
                    sent.fetch_add(1, Ordering::Relaxed);
                    match client.roundtrip(&ProjectRequest::insert(i, x)) {
                        Ok(resp) if resp.error.is_none() => acked.lock().unwrap().push(i),
                        _ => break,
                    }
                }
            })
        };
        // The kill point is randomized by scheduling: the delay lands
        // wherever the ingest loop happens to be — mid-flush included.
        std::thread::sleep(Duration::from_millis(kill_ms));
        child.kill().expect("SIGKILL the serving child");
        child.wait().unwrap();
        stop.store(true, Ordering::Relaxed);
        ingest.join().unwrap();
        let acked: Vec<u64> = std::mem::take(&mut acked.lock().unwrap());
        let sent = sent.load(Ordering::Relaxed);

        // Recover in-process under the child's exact serving identity
        // (seed, default_k, backend); shard count is free to differ.
        let b = Coordinator::start(
            CoordinatorConfig {
                master_seed: 4242,
                snapshot_dir: Some(snap.clone()),
                wal_dir: Some(wal_dir.clone()),
                index_shards: 2,
                ..Default::default()
            },
            None,
        );
        let dims = vec![3usize; 4];
        let mut qrng = Rng::seed_from(99);
        let probe = unit_input(&dims, 2, "tt", &mut qrng);
        let recovered = query_ids(&b, &probe, 1_000_000, sent as usize + 1);
        let rset: std::collections::BTreeSet<u64> = recovered.iter().copied().collect();

        // acked ⊆ recovered ⊆ sent.
        assert!(
            rset.iter().all(|&id| id < sent),
            "[round {round}] recovered an id that was never sent"
        );
        for id in &acked {
            assert!(
                rset.contains(id),
                "[round {round}] acked insert {id} lost across SIGKILL \
                 ({} acked, {} recovered of {} sent)",
                acked.len(),
                rset.len(),
                sent
            );
        }

        // Twin: a fresh WAL-less coordinator fed exactly the recovered
        // set must answer bit-identically.
        let t = Coordinator::start(
            CoordinatorConfig { master_seed: 4242, ..Default::default() },
            None,
        );
        let mut prng = Rng::seed_from(1717);
        let payloads: Vec<AnyTensor> =
            (0..sent).map(|_| unit_input(&dims, 2, "tt", &mut prng)).collect();
        for &id in &rset {
            t.project_blocking(ProjectRequest::insert(id, payloads[id as usize].clone()))
                .unwrap();
        }
        for qi in 0..4u64 {
            let q = unit_input(&dims, 2, "tt", &mut qrng);
            let nb = b
                .project_blocking(ProjectRequest::query(2_000_000 + qi, q.clone(), 8))
                .unwrap()
                .neighbors
                .unwrap();
            let nt = t
                .project_blocking(ProjectRequest::query(2_000_000 + qi, q.clone(), 8))
                .unwrap()
                .neighbors
                .unwrap();
            assert_eq!(
                nb, nt,
                "[round {round}] recovered replies must be bit-identical to a twin \
                 built from the recovered set"
            );
        }
        b.shutdown();
        t.shutdown();
    }
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn injected_panic_mid_turn_leaves_the_wal_appendable_and_replayable() {
    let root = tmp_dir("panic");
    let snap = root.join("snap");
    let wal_dir = root.join("wal");
    let make = || {
        IndexRegistry::new(0xFEED, BackendKind::Flat, LshConfig::default())
            .with_snapshot_dir(Some(snap.clone()))
            .with_shards(2)
            .with_wal(Some(WalConfig {
                dir: wal_dir.clone(),
                segment_cap: 1 << 16,
                fsync: WalFsync::Flush,
            }))
    };
    let key = MapKey { kind: MapKind::Tt { rank: 2 }, dims: vec![3; 4], k: 6 };

    let r1 = make();
    let slot = r1.get_or_create(&key);
    let log_and_apply = |id: u64| {
        let s = shard_of(id, 2);
        let payload = vec![id as f64; 6];
        slot.wal_append(s, wal::WAL_OP_INSERT, id, &payload).unwrap().unwrap();
        let t = slot.issue_tickets(&[s]);
        slot.run_shard_turn(s, t[0].1, |ix| ix.insert(id, &payload));
        slot.note_shard_mutations(s, 1);
    };
    for id in 0..10u64 {
        log_and_apply(id);
    }
    for s in 0..2 {
        slot.wal_commit(s, WalFsync::Flush).unwrap();
    }

    // Inject a panic mid shard-turn: the lane's index mutex poisons, the
    // turn still advances (drop guard), and the WAL must keep working.
    let t = slot.issue_tickets(&[0]);
    let hit: std::thread::Result<()> =
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            slot.run_shard_turn(0, t[0].1, |_| panic!("injected fault"))
        }));
    assert!(hit.is_err(), "the injected panic must surface");

    // The lane survives: one more logged op after the poisoning.
    log_and_apply(100);
    for s in 0..2 {
        slot.wal_commit(s, WalFsync::Flush).unwrap();
    }
    drop(slot);
    std::mem::forget(r1); // crash: no destructors

    let r2 = make();
    let (sigs, replayed) = r2.recover_wal().unwrap();
    assert_eq!((sigs, replayed), (1, 11), "10 + 1 post-panic records replay");
    let slot = r2.get_or_create(&key);
    let mut ids = Vec::new();
    for s in 0..2 {
        slot.lock_shard(s).for_each_live(&mut |id, _| ids.push(id));
    }
    ids.sort_unstable();
    let expect: Vec<u64> = (0..10u64).chain([100]).collect();
    assert_eq!(ids, expect, "every logged op survives the injected panic");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn wal_off_is_bit_identical_and_never_logs() {
    let root = tmp_dir("waloff");
    let snap = root.join("snap");
    let wal_dir = root.join("wal");
    let dims = vec![3usize; 4];
    let mut rng = Rng::seed_from(13);
    let payloads: Vec<AnyTensor> =
        (0..16).map(|_| unit_input(&dims, 2, "tt", &mut rng)).collect();
    let queries: Vec<AnyTensor> =
        (0..4).map(|_| unit_input(&dims, 2, "tt", &mut rng)).collect();

    let on = coordinator(BackendKind::Flat, 2, Some(&snap), Some(&wal_dir));
    let off = coordinator(BackendKind::Flat, 2, None, None);
    ingest_burst(&on, &payloads);
    ingest_burst(&off, &payloads);
    for (qi, q) in queries.iter().enumerate() {
        let id = 700 + qi as u64;
        assert_eq!(
            query_ids(&on, q, id, 5),
            query_ids(&off, q, id, 5),
            "the WAL must not perturb replies"
        );
    }
    let m_on = on.metrics();
    let m_off = off.metrics();
    assert_eq!(m_on.wal_appends, 17, "16 inserts + 1 delete logged");
    assert!(m_on.wal_fsyncs >= 1, "group commit synced at least once");
    assert_eq!(
        (m_off.wal_appends, m_off.wal_fsyncs, m_off.wal_replayed),
        (0, 0, 0),
        "no wal_dir → zero WAL activity"
    );
    on.shutdown();
    off.shutdown();
    let _ = std::fs::remove_dir_all(&root);
}
