//! Snapshot / crash-recovery tests of the index persistence subsystem:
//!
//! 1. property: over both backends (flat, LSH) and both payload formats
//!    (dense, TT), a coordinator that snapshots under concurrent
//!    pipelined traffic, dies, and is restored from disk answers every
//!    query **bit-identically** to an uninterrupted coordinator that
//!    received exactly the pre-snapshot ops — and the snapshot is a
//!    consistent cut (ops submitted after the snapshot op are absent);
//! 2. the `snapshot`/`restore` wire ops round-trip over TCP, reporting
//!    file path/items/bytes and reloading the on-disk state;
//! 3. periodic snapshots (`snapshot_every_ops`) write files without any
//!    explicit op;
//! 4. snapshot ops on a coordinator without a configured snapshot
//!    directory fail loudly instead of silently dropping durability.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use tensorized_rp::coordinator::{
    Coordinator, CoordinatorConfig, NetClient, NetServer, ProjectRequest,
};
use tensorized_rp::data::inputs::unit_input;
use tensorized_rp::index::{BackendKind, LshConfig};
use tensorized_rp::rng::Rng;
use tensorized_rp::tensor::{AnyTensor, Format};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("trp_recovery_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn coordinator(backend: BackendKind, snapshot_dir: Option<&Path>, every: u64) -> Coordinator {
    Coordinator::start(
        CoordinatorConfig {
            workers: 3,
            default_k: 12,
            master_seed: 0xFEED,
            index_backend: backend,
            lsh: LshConfig { tables: 4, bits: 8, probes: 2 },
            snapshot_dir: snapshot_dir.map(|d| d.to_path_buf()),
            snapshot_every_ops: every,
            ..Default::default()
        },
        None,
    )
}

#[test]
fn snapshot_restore_is_bit_identical_across_backends_and_formats() {
    for backend in [BackendKind::Flat, BackendKind::Lsh] {
        for format in ["dense", "tt"] {
            let tag = format!("{}_{format}", backend.name());
            let dir = tmp_dir(&tag);
            let dims = vec![3usize; 4];
            let mut rng = Rng::seed_from(31);
            let payloads: Vec<AnyTensor> =
                (0..24).map(|_| unit_input(&dims, 2, format, &mut rng)).collect();
            let queries: Vec<AnyTensor> =
                (0..6).map(|_| unit_input(&dims, 2, format, &mut rng)).collect();
            let fmt = payloads[0].format();

            // Coordinator A: inserts, a delete, the snapshot, and
            // post-snapshot traffic — all pipelined before a single
            // reply is awaited, so the snapshot cut happens under
            // concurrent in-flight ops.
            let a = coordinator(backend, Some(&dir), 0);
            let mut rxs = Vec::new();
            for (i, p) in payloads.iter().enumerate() {
                rxs.push(a.submit(ProjectRequest::insert(i as u64, p.clone())));
            }
            rxs.push(a.submit(ProjectRequest::delete(100, 3, fmt, dims.clone())));
            rxs.push(a.submit(ProjectRequest::snapshot(101, fmt, dims.clone())));
            rxs.push(a.submit(ProjectRequest::delete(102, 5, fmt, dims.clone())));
            for rx in rxs {
                rx.recv().unwrap().unwrap();
            }
            assert_eq!(a.metrics().index_snapshots, 1);
            a.shutdown(); // the "kill"

            // Coordinator B: fresh process image, restored from disk.
            let b = coordinator(backend, Some(&dir), 0);
            let (sigs, items) = b.restore_from(&dir).unwrap();
            assert_eq!(sigs, 1, "[{tag}] one signature was snapshotted");
            assert_eq!(items, 23, "[{tag}] snapshot holds the pre-cut state");

            // Coordinator C: never snapshotted, never restarted; receives
            // exactly the pre-snapshot ops. This is the ground truth.
            let c = coordinator(backend, None, 0);
            for (i, p) in payloads.iter().enumerate() {
                c.project_blocking(ProjectRequest::insert(i as u64, p.clone())).unwrap();
            }
            c.project_blocking(ProjectRequest::delete(100, 3, fmt, dims.clone())).unwrap();

            for (qi, q) in queries.iter().enumerate() {
                let id = 500 + qi as u64;
                let nb = b
                    .project_blocking(ProjectRequest::query(id, q.clone(), 5))
                    .unwrap()
                    .neighbors
                    .unwrap();
                let nc = c
                    .project_blocking(ProjectRequest::query(id, q.clone(), 5))
                    .unwrap()
                    .neighbors
                    .unwrap();
                assert_eq!(
                    nb, nc,
                    "[{tag}] restored queries must be bit-identical to the \
                     uninterrupted coordinator"
                );
                assert!(nb.iter().all(|n| n.id != 3), "[{tag}] pre-cut delete persisted");
            }
            // Consistent cut: the delete submitted after the snapshot op
            // must NOT be reflected in the restored corpus.
            let stats = b
                .project_blocking(ProjectRequest::index_stats(900, fmt, dims.clone()))
                .unwrap()
                .index
                .unwrap();
            assert_eq!(stats.len, 23, "[{tag}] post-snapshot delete is not in the file");
            b.shutdown();
            c.shutdown();
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

#[test]
fn snapshot_and_restore_ops_roundtrip_over_the_wire() {
    let dir = tmp_dir("wire");
    let dims = vec![3usize; 4];
    let coord = Arc::new(coordinator(BackendKind::Flat, Some(&dir), 0));
    let server = NetServer::start(Arc::clone(&coord), "127.0.0.1:0").unwrap();
    let mut client = NetClient::connect(server.addr()).unwrap();
    let mut rng = Rng::seed_from(7);
    for i in 0..4u64 {
        let x = unit_input(&dims, 2, "tt", &mut rng);
        let resp = client.roundtrip(&ProjectRequest::insert(i, x)).unwrap();
        assert!(resp.error.is_none());
    }
    // Snapshot: the reply reports what was written.
    let resp = client
        .roundtrip(&ProjectRequest::snapshot(50, Format::Tt, dims.clone()))
        .unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    let report = resp.snapshot.expect("snapshot report over the wire");
    assert_eq!(report.items, 4);
    assert!(report.bytes > 0);
    assert!(Path::new(&report.path).exists(), "file at the reported path");
    // Mutate past the snapshot, then restore: back to the cut.
    for i in 4..6u64 {
        let x = unit_input(&dims, 2, "tt", &mut rng);
        client.roundtrip(&ProjectRequest::insert(i, x)).unwrap();
    }
    let resp = client
        .roundtrip(&ProjectRequest::restore(51, Format::Tt, dims.clone()))
        .unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert_eq!(resp.restored, Some(4));
    let resp = client
        .roundtrip(&ProjectRequest::index_stats(52, Format::Tt, dims))
        .unwrap();
    assert_eq!(resp.index.unwrap().len, 4, "restore rewound to the snapshot cut");
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn periodic_snapshots_fire_on_mutation_count() {
    let dir = tmp_dir("periodic");
    let dims = vec![3usize; 4];
    let coord = coordinator(BackendKind::Flat, Some(&dir), 4);
    let mut rng = Rng::seed_from(9);
    for i in 0..10u64 {
        let x = unit_input(&dims, 2, "tt", &mut rng);
        coord.project_blocking(ProjectRequest::insert(i, x)).unwrap();
    }
    assert!(
        coord.metrics().index_snapshots >= 1,
        "10 inserts at snapshot_every_ops=4 must write at least one snapshot"
    );
    let snaps: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "snap"))
        .collect();
    assert!(
        (1..=2).contains(&snaps.len()),
        "one signature → at most snapshot_keep (default 2) rotated files, got {}",
        snaps.len()
    );
    // The file is a valid snapshot a fresh coordinator can recover from.
    let fresh = coordinator(BackendKind::Flat, None, 0);
    let (sigs, items) = fresh.restore_from(&dir).unwrap();
    assert_eq!(sigs, 1);
    assert!((4..=10).contains(&items), "periodic cut holds 4..=10 items, got {items}");
    coord.shutdown();
    fresh.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn snapshot_without_configured_dir_fails_loudly() {
    let dims = vec![3usize; 4];
    let coord = coordinator(BackendKind::Flat, None, 0);
    let mut rng = Rng::seed_from(11);
    let x = unit_input(&dims, 2, "tt", &mut rng);
    coord.project_blocking(ProjectRequest::insert(0, x)).unwrap();
    let reply = coord.project_blocking(ProjectRequest::snapshot(1, Format::Tt, dims.clone()));
    assert!(reply.is_err(), "snapshot without snapshot_dir must error");
    let reply = coord.project_blocking(ProjectRequest::restore(2, Format::Tt, dims));
    assert!(reply.is_err(), "restore without snapshot_dir must error");
    assert_eq!(coord.metrics().failed, 2);
    coord.shutdown();
}
