//! Correctness tests of the similarity-search index subsystem:
//!
//! 1. flat-backend top-k equals brute-force top-k in the *original*
//!    tensor space up to the JL distortion the paper bounds (planted
//!    low-rank clusters, generous margins);
//! 2. the LSH backend's recall never falls far below the flat backend on
//!    the same embeddings;
//! 3. insert/delete/query/stats round-trip through the coordinator's TCP
//!    wire path;
//! 4. coordinator-served `query` results are identical to direct
//!    in-process index queries over the same registry map and seed
//!    (the batched service path adds no approximation).

use std::sync::Arc;
use tensorized_rp::coordinator::{
    Coordinator, CoordinatorConfig, MapKey, MapKind, NetClient, NetServer, ProjectRequest,
    ProjectionRegistry,
};
use tensorized_rp::index::{build_index, AnnIndex, BackendKind, FlatIndex, LshConfig};
use tensorized_rp::projections::{Projection, TtProjection, Workspace};
use tensorized_rp::rng::Rng;
use tensorized_rp::tensor::{AnyTensor, Format, TtTensor};
use tensorized_rp::util::proptest::{run, Config};

/// One tensor additively jittered around `center` in TT format:
/// `normalize(center + σ·noise)`. Within-cluster squared distances are
/// ≈ `2σ²/(1+σ²)`; cross-cluster ones ≈ 2 — a margin the JL maps must
/// preserve.
fn jittered(center: &TtTensor, dims: &[usize], rank: usize, sigma: f64, rng: &mut Rng) -> TtTensor {
    let mut noise = TtTensor::random_unit(dims, rank, rng);
    noise.scale(sigma);
    let mut t = center.add(&noise);
    let norm = t.fro_norm();
    if norm > 0.0 {
        t.scale(1.0 / norm);
    }
    t
}

/// Clustered corpus + queries around *shared* centres, so each query's
/// true nearest neighbours are the corpus members of its own cluster.
fn clustered_tt(
    dims: &[usize],
    rank: usize,
    n_centers: usize,
    n_corpus: usize,
    n_queries: usize,
    rng: &mut Rng,
) -> (Vec<TtTensor>, Vec<TtTensor>) {
    let centers: Vec<TtTensor> = (0..n_centers)
        .map(|_| TtTensor::random_unit(dims, rank, rng))
        .collect();
    let corpus = (0..n_corpus)
        .map(|i| jittered(&centers[i % n_centers], dims, rank, 0.35, rng))
        .collect();
    let queries = (0..n_queries)
        .map(|i| jittered(&centers[i % n_centers], dims, rank, 0.35, rng))
        .collect();
    (corpus, queries)
}

/// Exact original-space top-k ids (TT-format distances, no densify).
fn true_topk(corpus: &[TtTensor], q: &TtTensor, k: usize) -> Vec<u64> {
    let qn = q.fro_norm();
    let mut d: Vec<(f64, u64)> = corpus
        .iter()
        .enumerate()
        .map(|(i, x)| {
            let xn = x.fro_norm();
            let d2 = (xn * xn + qn * qn - 2.0 * q.inner(x)).max(0.0);
            (d2, i as u64)
        })
        .collect();
    d.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    d.truncate(k);
    d.into_iter().map(|(_, i)| i).collect()
}

/// Property: flat-backend top-k over projected embeddings recovers the
/// original-space top-k up to JL distortion. With m = 64 and clustered
/// (margin-separated) data the recall floor is comfortably high; exactness
/// of the flat scan itself is covered by unit tests, this is the JL
/// end-to-end statement.
#[test]
fn prop_flat_topk_matches_original_space_up_to_distortion() {
    run(
        "flat recall under JL distortion",
        Config { cases: 8, seed: 0x11DE },
        |g| {
            let dims = vec![3usize; g.usize_in(5, 7)];
            let rank = g.usize_in(2, 3);
            let n = g.usize_in(30, 60);
            let topk = 5;
            let m = 64;
            let rng = g.rng();
            // Cluster size tracks topk, so the true top-k is (roughly) the
            // query's own cluster and recall measures cluster recovery.
            let n_centers = (n / topk).max(2);
            let (corpus, queries) = clustered_tt(&dims, rank, n_centers, n, 4, rng);
            let mut map_rng = Rng::seed_from(0xF00D);
            let map = TtProjection::new(&dims, 4, m, &mut map_rng);
            let mut idx = FlatIndex::new(m);
            for (i, x) in corpus.iter().enumerate() {
                idx.insert(i as u64, &map.project_tt(x));
            }
            let mut ws = Workspace::new();
            let mut hits = 0usize;
            let mut total = 0usize;
            for q in &queries {
                let truth = true_topk(&corpus, q, topk);
                let got = idx.query(&map.project_tt(q), topk, &mut ws);
                total += topk;
                hits += got.iter().filter(|nb| truth.contains(&nb.id)).count();
            }
            let recall = hits as f64 / total as f64;
            if recall < 0.6 {
                return Err(format!(
                    "recall {recall:.3} below the JL floor (dims {dims:?}, n {n})"
                ));
            }
            Ok(())
        },
    );
}

/// LSH recall floor: on identical embeddings, multi-probe LSH stays close
/// to the flat backend's retrieved sets (candidates are exactly
/// re-scored, so the only loss is candidates never probed).
#[test]
fn lsh_recall_floor_against_flat() {
    let mut rng = Rng::seed_from(0x15A);
    let dims = vec![3usize; 6];
    let m = 32;
    let topk = 5;
    let (corpus, queries) = clustered_tt(&dims, 3, 16, 80, 10, &mut rng);
    let mut map_rng = Rng::seed_from(0xBEEF);
    let map = TtProjection::new(&dims, 4, m, &mut map_rng);
    let lsh_cfg = LshConfig { tables: 10, bits: 8, probes: 6 };
    let mut flat = build_index(BackendKind::Flat, m, &lsh_cfg, 1);
    let mut lsh = build_index(BackendKind::Lsh, m, &lsh_cfg, 1);
    for (i, x) in corpus.iter().enumerate() {
        let e = map.project_tt(x);
        flat.insert(i as u64, &e);
        lsh.insert(i as u64, &e);
    }
    let mut ws = Workspace::new();
    let mut hits = 0usize;
    let mut total = 0usize;
    for q in &queries {
        let e = map.project_tt(q);
        let want = flat.query(&e, topk, &mut ws);
        let got = lsh.query(&e, topk, &mut ws);
        total += want.len();
        let got_ids: Vec<u64> = got.iter().map(|n| n.id).collect();
        hits += want.iter().filter(|n| got_ids.contains(&n.id)).count();
    }
    let recall = hits as f64 / total as f64;
    assert!(
        recall >= 0.6,
        "LSH recall vs flat fell to {recall:.3} (want ≥ 0.6)"
    );
}

/// Insert/delete/query/stats round-trip over the TCP wire path.
#[test]
fn wire_roundtrip_insert_query_delete_stats() {
    let coord = Arc::new(Coordinator::start(
        CoordinatorConfig { workers: 2, default_k: 16, ..Default::default() },
        None,
    ));
    let server = NetServer::start(Arc::clone(&coord), "127.0.0.1:0").unwrap();
    let mut client = NetClient::connect(server.addr()).unwrap();
    let mut rng = Rng::seed_from(0xCAFE);
    let dims = vec![3usize; 4];
    let xs: Vec<TtTensor> = (0..5)
        .map(|_| TtTensor::random_unit(&dims, 2, &mut rng))
        .collect();
    for (i, x) in xs.iter().enumerate() {
        let resp = client
            .roundtrip(&ProjectRequest::insert(i as u64, AnyTensor::Tt(x.clone())))
            .unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.embedding.unwrap().len(), 16);
    }
    // Query an inserted item: itself at distance ~0 first.
    let resp = client
        .roundtrip(&ProjectRequest::query(50, AnyTensor::Tt(xs[1].clone()), 3))
        .unwrap();
    let ns = resp.neighbors.expect("neighbors over the wire");
    assert_eq!(ns.len(), 3);
    assert_eq!(ns[0].id, 1);
    assert!(ns[0].dist < 1e-9);
    assert!(ns.windows(2).all(|w| w[0].dist <= w[1].dist));
    // Delete it.
    let resp = client
        .roundtrip(&ProjectRequest::delete(51, 1, Format::Tt, dims.clone()))
        .unwrap();
    assert_eq!(resp.removed, Some(true));
    // Gone from subsequent queries.
    let resp = client
        .roundtrip(&ProjectRequest::query(52, AnyTensor::Tt(xs[1].clone()), 5))
        .unwrap();
    let ns = resp.neighbors.unwrap();
    assert_eq!(ns.len(), 4, "only 4 items remain");
    assert!(ns.iter().all(|n| n.id != 1));
    // Stats reflect the history.
    let resp = client
        .roundtrip(&ProjectRequest::index_stats(53, Format::Tt, dims))
        .unwrap();
    let stats = resp.index.expect("stats over the wire");
    assert_eq!(stats.backend, "flat");
    assert_eq!(stats.len, 4);
    assert_eq!(stats.inserts, 5);
    assert_eq!(stats.deletes, 1);
    assert_eq!(stats.queries, 2);
    server.shutdown();
}

/// Acceptance: coordinator-served queries are identical — ids and
/// bit-level distances — to direct in-process index queries over the same
/// registry map (same master seed, same insert order).
#[test]
fn coordinator_query_identical_to_direct_index() {
    let master_seed = 0x5EED;
    let dims = vec![3usize; 4];
    let default_k = 16;
    let tt_rank = 5;
    let mut rng = Rng::seed_from(0xD1CE);
    let xs: Vec<TtTensor> = (0..12)
        .map(|_| TtTensor::random_unit(&dims, 2, &mut rng))
        .collect();
    let queries: Vec<TtTensor> = (0..4)
        .map(|_| TtTensor::random_unit(&dims, 2, &mut rng))
        .collect();

    // Service side.
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 2,
            default_k,
            default_tt_rank: tt_rank,
            master_seed,
            ..Default::default()
        },
        None,
    );
    for (i, x) in xs.iter().enumerate() {
        coord
            .project_blocking(ProjectRequest::insert(i as u64, AnyTensor::Tt(x.clone())))
            .unwrap();
    }
    let served: Vec<_> = queries
        .iter()
        .enumerate()
        .map(|(i, q)| {
            coord
                .project_blocking(ProjectRequest::query(
                    100 + i as u64,
                    AnyTensor::Tt(q.clone()),
                    5,
                ))
                .unwrap()
                .neighbors
                .unwrap()
        })
        .collect();
    coord.shutdown();

    // Direct side: same registry map (same master seed + key policy),
    // same flat backend, same insert order.
    let registry = ProjectionRegistry::new(master_seed);
    let key = MapKey {
        kind: MapKind::Tt { rank: tt_rank },
        dims: dims.clone(),
        k: default_k,
    };
    let map = registry.get_or_create(&key);
    let mut idx = FlatIndex::new(default_k);
    for (i, x) in xs.iter().enumerate() {
        idx.insert(i as u64, &map.map.project(&AnyTensor::Tt(x.clone())));
    }
    let mut ws = Workspace::new();
    for (q, served_ns) in queries.iter().zip(&served) {
        let direct = idx.query(&map.map.project(&AnyTensor::Tt(q.clone())), 5, &mut ws);
        assert_eq!(
            &direct, served_ns,
            "coordinator-served query must be identical to the direct index query"
        );
    }
}

/// Property: index contents equal a model HashMap under random
/// insert/overwrite/delete interleavings, for both backends.
#[test]
fn prop_index_matches_model_under_mutation() {
    run(
        "index mutation model",
        Config { cases: 32, seed: 0x10DE },
        |g| {
            let dim = g.usize_in(2, 6);
            let backend = if g.bool_with(0.5) { BackendKind::Flat } else { BackendKind::Lsh };
            let lsh = LshConfig { tables: 3, bits: 5, probes: 2 };
            let mut idx = build_index(backend, dim, &lsh, 7);
            let mut model: std::collections::HashMap<u64, Vec<f64>> =
                std::collections::HashMap::new();
            let ops = g.usize_in(1, 60);
            for _ in 0..ops {
                let id = g.usize_in(0, 9) as u64;
                if g.bool_with(0.7) {
                    let v: Vec<f64> = (0..dim).map(|_| g.gaussian()).collect();
                    idx.insert(id, &v);
                    model.insert(id, v);
                } else {
                    let removed = idx.remove(id);
                    let model_removed = model.remove(&id).is_some();
                    if removed != model_removed {
                        return Err(format!("remove({id}) = {removed}, model {model_removed}"));
                    }
                }
                if idx.len() != model.len() {
                    return Err(format!("len {} != model {}", idx.len(), model.len()));
                }
            }
            // Every live item must be retrievable as its own nearest
            // neighbour at distance ~0 (exact for flat; for LSH the exact
            // bucket of the item's own hash is always probed).
            let mut ws = Workspace::new();
            for (id, v) in &model {
                let res = idx.query(v, 1, &mut ws);
                if res.is_empty() || res[0].id != *id || res[0].dist > 1e-9 {
                    return Err(format!("self-query of {id} failed: {res:?}"));
                }
            }
            Ok(())
        },
    );
}
