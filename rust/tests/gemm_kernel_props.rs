//! Property gates for the packed GEMM kernel (`linalg::gemm`), named in
//! `scripts/tier1.sh`:
//!
//! 1. **Exhaustive small-shape sweep** — every `m ∈ 1..=2·MR`,
//!    `n ∈ 1..=2·NR` (crossing every microkernel edge-tile case) over a
//!    `k` ladder spanning the dot, simple and packed dispatch paths,
//!    checked **bit-identical** to the naive triple loop: the kernel's
//!    determinism contract says each output element is one ascending-`k`
//!    IEEE chain, which is exactly what naive computes.
//! 2. **Parallel row-panel bit-identity** — worker counts {1, 2, 4}
//!    produce bitwise-equal output (rank-stable partitioning).
//! 3. **Fused-regroup TT×TT regression** — the group kernel with the
//!    regroup permutes fused into the GEMM pack/store
//!    (`inner_tt_rows_into`) stays bit-identical to the staged PR 4
//!    path (`inner_tt_rows_into_unfused`).
//! 4. **NaN/Inf propagation** — `0·NaN` and `0·∞` reach the output on
//!    every dispatch path (the seed kernel's zero-skip swallowed them).

use tensorized_rp::linalg::gemm::{self, MR, NR};
use tensorized_rp::linalg::{matmul, matmul_acc_with_threads, matmul_into, matvec};
use tensorized_rp::rng::Rng;
use tensorized_rp::tensor::{TtBatchContraction, TtDenseContraction, TtTensor};

/// Naive triple loop: acc starts at zero and adds in ascending-`k`
/// order — the chain the kernel contract pins.
fn matmul_naive(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0.0; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0;
            for p in 0..k {
                acc += a[i * k + p] * b[p * n + j];
            }
            c[i * n + j] = acc;
        }
    }
    c
}

#[test]
fn exhaustive_small_shapes_bit_match_naive() {
    let mut rng = Rng::seed_from(0x6E11);
    // k ladder: 1 (degenerate), around the tile sizes, and 300 (pushes
    // m ≥ MR, n ≥ NR shapes over the packing threshold and across a KC
    // boundary in combination with the widest m·n).
    for k in [1usize, 2, 3, 7, 8, 9, 300] {
        for m in 1..=2 * MR {
            for n in 1..=2 * NR {
                let a = rng.gaussian_vec(m * k, 1.0);
                let b = rng.gaussian_vec(k * n, 1.0);
                let got = matmul(&a, &b, m, k, n);
                let want = matmul_naive(&a, &b, m, k, n);
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        w.to_bits(),
                        "shape {m}x{k}x{n} element {i}: {g:?} != naive {w:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn parallel_row_panels_bit_identical_across_worker_counts() {
    let mut rng = Rng::seed_from(0x6E12);
    // Crosses the parallel flop floor and leaves a ragged last panel
    // (150 rows = 37 full MR-tiles + 2 rows).
    let (m, k, n) = (150usize, 130usize, 80usize);
    let a = rng.gaussian_vec(m * k, 1.0);
    let b = rng.gaussian_vec(k * n, 1.0);
    // Accumulate onto a nonzero C so the chains include a C prologue.
    let c0 = rng.gaussian_vec(m * n, 1.0);
    let mut base = c0.clone();
    matmul_acc_with_threads(&a, &b, &mut base, m, k, n, 1);
    for threads in [2usize, 4] {
        let mut c = c0.clone();
        matmul_acc_with_threads(&a, &b, &mut c, m, k, n, threads);
        for (i, (x, y)) in c.iter().zip(&base).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "threads={threads} element {i}");
        }
    }
}

#[test]
fn fused_tt_regroup_bit_identical_to_unfused() {
    let mut rng = Rng::seed_from(0x6E13);
    let dims = [3usize, 4, 2, 3];
    let rows_raw: Vec<TtTensor> = (0..6)
        .map(|_| TtTensor::random_projection_row(&dims, 3, &mut rng))
        .collect();
    let rows: Vec<TtDenseContraction> = rows_raw.iter().map(TtDenseContraction::new).collect();
    for b in [1usize, 4, 9] {
        let items: Vec<TtTensor> =
            (0..b).map(|_| TtTensor::random_unit(&dims, 2, &mut rng)).collect();
        let refs: Vec<&TtTensor> = items.iter().collect();
        let ctx = TtBatchContraction::for_tt_map(&refs);
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        let mut fused = vec![f64::NAN; b * rows.len()];
        ctx.inner_tt_rows_into(&rows, &mut fused, &mut pa, &mut pb);
        let mut staged = vec![f64::NAN; b * rows.len()];
        ctx.inner_tt_rows_into_unfused(&rows, &mut staged, &mut pa, &mut pb);
        for (i, (f, s)) in fused.iter().zip(&staged).enumerate() {
            assert_eq!(
                f.to_bits(),
                s.to_bits(),
                "B={b} slot {i}: fused {f:?} != staged {s:?}"
            );
        }
    }
}

#[test]
fn nan_and_inf_propagate_on_every_dispatch_path() {
    // Dot path (n = 1).
    let y = matvec(&[0.0, 1.0], &[f64::NAN, 2.0], 1, 2);
    assert!(y[0].is_nan(), "dot path swallowed 0·NaN");
    // Simple path (small shape, n > 1).
    let mut a = vec![1.0; 2 * 5];
    a[2] = 0.0;
    let mut b = vec![1.0; 5 * 3];
    b[2 * 3] = f64::INFINITY; // row p=2 of B: 0·∞ = NaN for output row 0
    let c = matmul(&a, &b, 2, 5, 3);
    assert!(c[0].is_nan(), "simple path swallowed 0·∞");
    // Packed path: big enough shape, one zero A entry against a NaN row.
    let (m, k, n) = (16usize, 256usize, 32usize);
    let mut a = vec![1.0; m * k];
    a[7 * k + 100] = 0.0;
    let mut b = vec![1.0; k * n];
    for v in &mut b[100 * n..101 * n] {
        *v = f64::NAN;
    }
    let mut c = vec![0.0; m * n];
    matmul_into(&a, &b, &mut c, m, k, n);
    for j in 0..n {
        assert!(c[7 * n + j].is_nan(), "packed path swallowed 0·NaN at col {j}");
    }
    // Rows whose A entry is 1.0 against the NaN B row are NaN too (sanity
    // that the poison came from the product, not the zero special case).
    assert!(c[0].is_nan());
    // The frozen PR 5 reference keeps its historical zero-skip: the same
    // dot-shape product does NOT propagate there (documented contrast).
    let mut cref = vec![0.0; 1];
    gemm::reference::matmul_into(&[0.0, 1.0], &[f64::NAN, 2.0], &mut cref, 1, 2, 1);
    assert!(!cref[0].is_nan());
}
