//! Bench target: GEMM-kernel roofline micro-benchmark — GFLOP/s of the
//! packed SIMD kernel vs the frozen PR 5 scalar kernel
//! (`linalg::gemm::reference`) on the GEMM shape mix the batch sweep
//! actually issues (dense flush, flat-index scan, TT-chain absorb-row
//! and fused absorb-input GEMMs).
//!
//! ```text
//! cargo bench --bench kernel_bench [-- --quick] [-- --out FILE]
//! ```
//!
//! Emits the rows into `BENCH_batch_sweep.json` as the `kernel` series:
//! when the file already exists (written by `cargo bench --bench
//! batch_sweep` or `trp experiment batch`) only its `kernel` key is
//! replaced, so the sweep series are preserved; otherwise a fresh
//! document with empty sweep series is written. Acceptance tripwire for
//! this PR: packed kernel ≥ 2× the PR 5 baseline on the dominant shapes.

use tensorized_rp::experiments::batch::{
    kernel_bench, print_kernel_verdict, to_json, BatchSweepConfig, KernelRow,
};
use tensorized_rp::util::bench::BenchReport;
use tensorized_rp::util::cli::Args;
use tensorized_rp::util::json::{obj, Json};

/// Serialize kernel rows exactly as `to_json` does for its `kernel` key.
fn kernel_json(krows: &[KernelRow]) -> Json {
    Json::Arr(
        krows
            .iter()
            .map(|r| {
                obj(vec![
                    ("shape", Json::Str(r.shape.clone())),
                    ("m", Json::Num(r.m as f64)),
                    ("k", Json::Num(r.k as f64)),
                    ("n", Json::Num(r.n as f64)),
                    ("packed_gflops", Json::Num(r.packed_gflops)),
                    ("reference_gflops", Json::Num(r.reference_gflops)),
                    ("speedup", Json::Num(r.speedup)),
                ])
            })
            .collect(),
    )
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench")).unwrap();
    let cfg = if args.flag("quick") {
        BatchSweepConfig::quick()
    } else {
        BatchSweepConfig::paper()
    };
    eprintln!("[kernel_bench] dims={:?} k={} input_rank={}", cfg.dims, cfg.k, cfg.input_rank);
    let krows = kernel_bench(&cfg);

    let mut report = BenchReport::new(
        "GEMM kernel roofline: packed SIMD vs frozen PR 5 scalar kernel",
        &["shape", "m", "k", "n", "packed_gflops", "reference_gflops", "speedup"],
    );
    for r in &krows {
        report.push(vec![
            r.shape.clone(),
            r.m.to_string(),
            r.k.to_string(),
            r.n.to_string(),
            format!("{:.2}", r.packed_gflops),
            format!("{:.2}", r.reference_gflops),
            format!("{:.2}", r.speedup),
        ]);
    }
    report.finish("kernel_bench.csv");

    let out_path = args.get_or("out", "BENCH_batch_sweep.json");
    let mut doc = std::fs::read_to_string(&out_path)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .filter(|d| matches!(d, Json::Obj(_)))
        .unwrap_or_else(|| to_json(&cfg, &[], &[], None, None));
    if let Json::Obj(map) = &mut doc {
        map.insert("kernel".to_string(), kernel_json(&krows));
    }
    match std::fs::write(&out_path, doc.to_string_pretty()) {
        Ok(()) => println!("[written {out_path} (kernel series)]"),
        Err(e) => eprintln!("[warn] could not write {out_path}: {e}"),
    }

    print_kernel_verdict(&krows);
}
