//! Bench target regenerating **Figure 3** (Appendix B.1): pairwise
//! distance preservation on image data, tensorized vs Gaussian RP.
//!
//! ```text
//! cargo bench --bench fig3_pairwise [-- --quick --trials T --cifar PATH]
//! ```
//!
//! Uses real CIFAR-10 binary batches when `--cifar` points at one (or the
//! default path exists); otherwise the synthetic natural-image substitute
//! of DESIGN.md §5. Expected shape: tensorized maps track Gaussian RP
//! closely, with higher ranks tightening the std.

use tensorized_rp::experiments::fig3;
use tensorized_rp::util::bench::BenchReport;
use tensorized_rp::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench")).unwrap();
    let mut cfg = if args.flag("quick") {
        fig3::Fig3Config::quick()
    } else {
        fig3::Fig3Config::paper()
    };
    if let Some(t) = args.get("trials") {
        cfg.trials = t.parse().expect("bad --trials");
    }
    if let Some(p) = args.get("cifar") {
        cfg.cifar_path = Some(p.into());
    }
    eprintln!(
        "[fig3] images={} trials={} ks={:?}",
        cfg.n_images, cfg.trials, cfg.ks
    );
    let rows = fig3::run(&cfg);
    let source = rows.first().map(|r| r.source.clone()).unwrap_or_default();
    let mut report = BenchReport::new(
        &format!("Figure 3: pairwise distance ratio on {source} images"),
        &["panel", "map", "k", "mean_ratio", "std"],
    );
    for r in &rows {
        report.push(vec![
            r.panel.clone(),
            r.map.clone(),
            r.k.to_string(),
            format!("{:.4}", r.mean_ratio),
            format!("{:.4}", r.std_ratio),
        ]);
    }
    report.finish("fig3_pairwise.csv");
    // Shape check: at the largest k every map's ratio is near 1.
    let kmax = *cfg.ks.iter().max().unwrap();
    for r in rows.iter().filter(|r| r.k == kmax) {
        println!(
            "[fig3:{}] {} ratio at k={kmax}: {:.4} ± {:.4}",
            r.panel, r.map, r.mean_ratio, r.std_ratio
        );
    }
}
