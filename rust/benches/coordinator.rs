//! Coordinator/serving benches: end-to-end throughput and latency of the
//! compression service under a Poisson trace — native path vs PJRT
//! artifacts, and the dynamic-batching ablation (batch size / deadline).
//!
//! ```text
//! cargo bench --bench coordinator [-- --requests N --quick]
//! ```

use tensorized_rp::coordinator::{Coordinator, CoordinatorConfig, ProjectRequest};
use tensorized_rp::data::inputs::Regime;
use tensorized_rp::data::workload::{poisson_trace, FormatMix, Trace};
use tensorized_rp::runtime::PjrtEngine;
use tensorized_rp::util::bench::BenchReport;
use tensorized_rp::util::cli::Args;

type Snapshot = tensorized_rp::coordinator::MetricsSnapshot;

fn run_trace(coord: &Coordinator, trace: &Trace) -> (f64, Snapshot) {
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = trace
        .payloads
        .iter()
        .enumerate()
        .map(|(i, p)| coord.submit(ProjectRequest::new(i as u64, p.clone())))
        .collect();
    for rx in rxs {
        rx.recv().unwrap().expect("request failed");
    }
    (t0.elapsed().as_secs_f64(), coord.metrics())
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench")).unwrap();
    let n: usize = args
        .get("requests")
        .map(|s| s.parse().expect("bad --requests"))
        .unwrap_or(if args.flag("quick") { 48 } else { 256 });
    let trace = poisson_trace(n, 5_000.0, Regime::Medium, FormatMix::default(), 42);

    let mut report = BenchReport::new(
        "Coordinator: throughput/latency, native vs PJRT, batching ablation",
        &["config", "req_s", "mean_us", "p50_us", "p99_us", "batches", "padded"],
    );

    // Native-only baseline — configured with the SAME map parameters the
    // artifacts compile (k=128, TT rank 5, CP rank 25) so the comparison
    // is apples-to-apples.
    {
        let coord = Coordinator::start(
            CoordinatorConfig {
                default_k: 128,
                default_tt_rank: 5,
                default_cp_rank: 25,
                ..Default::default()
            },
            None,
        );
        let (secs, m) = run_trace(&coord, &trace);
        report.push(vec![
            "native".into(),
            format!("{:.0}", n as f64 / secs),
            format!("{:.0}", m.mean_latency_us),
            m.p50_latency_us.to_string(),
            m.p99_latency_us.to_string(),
            "0".into(),
            "0".into(),
        ]);
        coord.shutdown();
    }

    // PJRT with different batching deadlines (ablation).
    for &delay_us in &[500u64, 2_000, 10_000] {
        let engine = match PjrtEngine::cpu() {
            Ok(mut e) => match e.load_dir(std::path::Path::new("artifacts")) {
                Ok(_) => Some(e),
                Err(err) => {
                    eprintln!("[coordinator] artifacts unavailable ({err}); skipping PJRT rows");
                    None
                }
            },
            Err(err) => {
                eprintln!("[coordinator] PJRT unavailable ({err}); skipping");
                None
            }
        };
        let Some(engine) = engine else { break };
        let coord = Coordinator::start(
            CoordinatorConfig { max_delay_us: delay_us, ..Default::default() },
            Some(engine),
        );
        let (secs, m) = run_trace(&coord, &trace);
        report.push(vec![
            format!("pjrt_delay{delay_us}us"),
            format!("{:.0}", n as f64 / secs),
            format!("{:.0}", m.mean_latency_us),
            m.p50_latency_us.to_string(),
            m.p99_latency_us.to_string(),
            m.pjrt_batches.to_string(),
            m.padded_slots.to_string(),
        ]);
        coord.shutdown();
    }

    report.finish("coordinator_serving.csv");
}
