//! Ablation benches: Theorem-1 bound vs measurement, the Definition-1
//! variance-prescription ablation, and the §3 TRP≡CP equivalence check.
//!
//! ```text
//! cargo bench --bench ablations [-- --quick --trials T]
//! ```

use tensorized_rp::experiments::ablations;
use tensorized_rp::projections::Projection;
use tensorized_rp::rng::Rng;
use tensorized_rp::util::bench::BenchReport;
use tensorized_rp::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench")).unwrap();
    let mut cfg = if args.flag("quick") {
        ablations::AblationConfig::quick()
    } else {
        ablations::AblationConfig::default_sweep()
    };
    if let Some(t) = args.get("trials") {
        cfg.trials = t.parse().expect("bad --trials");
    }

    // (1) Theorem 1: empirical variance vs bound.
    eprintln!("[ablations] variance sweep: orders={:?} ranks={:?}", cfg.orders, cfg.ranks);
    let rows = ablations::run_variance_sweep(&cfg);
    let mut report = BenchReport::new(
        "Theorem 1: empirical Var(‖f(X)‖²) vs bound",
        &["map", "N", "R", "k", "emp_mean", "emp_var", "bound", "bound_ratio"],
    );
    // The sample variance of a heavy-tailed statistic fluctuates around
    // the true variance: mild excesses (<1.5×) at a few hundred trials are
    // sampling noise, not bound violations.
    let mut violations = 0;
    let mut soft = 0;
    for r in &rows {
        if r.emp_var > r.bound * 1.5 {
            violations += 1;
        } else if r.emp_var > r.bound {
            soft += 1;
        }
        report.push(vec![
            r.map.clone(),
            r.order.to_string(),
            r.rank.to_string(),
            r.k.to_string(),
            format!("{:.4}", r.emp_mean),
            format!("{:.3e}", r.emp_var),
            format!("{:.3e}", r.bound),
            format!("{:.3}", r.emp_var / r.bound),
        ]);
    }
    report.finish("ablation_variance.csv");
    println!(
        "[ablations] bound violations: {violations}/{} hard (expect 0), {soft} within \
         sampling noise (<1.5×)",
        rows.len()
    );

    // (2) Definition-1 prescription ablation.
    let (prescribed, naive) =
        ablations::run_prescription_ablation(5, 4, 16, cfg.trials.min(100), 7);
    println!(
        "[ablations] E‖f(X)‖² with Definition-1 variances: {prescribed:.3}; \
         with naive unit variances: {naive:.3} (isometry requires ≈ 1)"
    );

    // (2b) JL point-set: Theorem 2 in action — max pairwise distortion of
    // m points embedded simultaneously, TT(5) vs CP(25).
    let jl_rows = ablations::run_jl_set(10, &[16, 64, 256], 0.8, cfg.trials.min(25), 11);
    let mut jl_report = BenchReport::new(
        "Theorem 2: max pairwise distortion over a 10-point set",
        &["map", "k", "mean_max_distortion", "success_rate(ε=0.8)"],
    );
    for r in &jl_rows {
        jl_report.push(vec![
            r.map.clone(),
            r.k.to_string(),
            format!("{:.4}", r.mean_max_distortion),
            format!("{:.2}", r.success_rate),
        ]);
    }
    jl_report.finish("ablation_jl_set.csv");

    // (3) §3 equivalence: TRP(T) vs the constructed CP(R=T) map agree
    //     numerically, and the CP view's fast TT path is faster.
    let mut rng = Rng::seed_from(3);
    let dims = vec![3usize; 8];
    let trp = tensorized_rp::projections::TrpProjection::new(&dims, 4, 32, &mut rng);
    let cp = trp.as_cp_projection();
    let x = tensorized_rp::tensor::TtTensor::random_unit(&dims, 5, &mut rng);
    let x_dense = x.to_dense();
    let y1 = trp.project_dense(&x_dense);
    let y2 = cp.project_dense(&x_dense);
    let max_diff = y1
        .iter()
        .zip(&y2)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    let t = tensorized_rp::util::Timer::start();
    std::hint::black_box(trp.project_dense(&x_dense));
    let t_dense = t.elapsed_secs();
    let t = tensorized_rp::util::Timer::start();
    std::hint::black_box(cp.project_tt(&x));
    let t_fast = t.elapsed_secs();
    println!(
        "[ablations] TRP(4) ≡ CP(4): max |Δ| = {max_diff:.2e}; dense path {t_dense:.2e}s vs \
         CP-view TT path {t_fast:.2e}s"
    );
}
