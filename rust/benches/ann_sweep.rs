//! Bench target: ANN recall/QPS sweep — the retrieval-quality trajectory
//! future PRs track via `BENCH_ann_sweep.json` (also emitted by
//! `trp experiment ann`).
//!
//! ```text
//! cargo bench --bench ann_sweep [-- --quick] [-- --out FILE]
//! ```
//!
//! Per map family (TT, CP, Gaussian) and projection dimension `m`,
//! reports recall@topk of the flat and LSH index backends against exact
//! original-space (TT-format) neighbours, and each backend's query
//! throughput. Acceptance tripwire for this PR: some `m` where TT reaches
//! recall ≥ 0.9 while CP at the same `m` is strictly lower.

use tensorized_rp::experiments::ann::{print_verdict, run, to_json, AnnSweepConfig};
use tensorized_rp::util::bench::BenchReport;
use tensorized_rp::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench")).unwrap();
    let cfg = if args.flag("quick") {
        AnnSweepConfig::quick()
    } else {
        AnnSweepConfig::paper()
    };
    eprintln!(
        "[ann_sweep] dims={:?} n_corpus={} n_queries={} topk={} ms={:?}",
        cfg.dims, cfg.n_corpus, cfg.n_queries, cfg.topk, cfg.ms
    );
    let rows = run(&cfg);

    let mut report = BenchReport::new(
        "ANN sweep: recall@topk and QPS vs projection dim m and shard count",
        &["map", "m", "shards", "flat_recall", "lsh_recall", "flat_qps", "lsh_qps"],
    );
    for r in &rows {
        report.push(vec![
            r.map.clone(),
            r.m.to_string(),
            r.shards.to_string(),
            format!("{:.4}", r.flat_recall),
            format!("{:.4}", r.lsh_recall),
            format!("{:.1}", r.flat_qps),
            format!("{:.1}", r.lsh_qps),
        ]);
    }
    report.finish("ann_sweep.csv");

    let out_path = args.get_or("out", "BENCH_ann_sweep.json");
    match std::fs::write(&out_path, to_json(&cfg, &rows).to_string_pretty()) {
        Ok(()) => println!("[written {out_path}]"),
        Err(e) => eprintln!("[warn] could not write {out_path}: {e}"),
    }
    print_verdict(&rows);
}
