//! Bench target regenerating **Figure 2**: embedding time vs `k` for the
//! medium-order case, with TT-format (top) and CP-format (bottom) inputs.
//!
//! ```text
//! cargo bench --bench fig2_embedding_time [-- --quick]
//! ```
//!
//! Expected shape: `f_TT` fastest on TT inputs, `f_CP` fastest on CP
//! inputs, `f_TT` always faster than very sparse RP.

use tensorized_rp::experiments::fig2;
use tensorized_rp::util::bench::BenchReport;
use tensorized_rp::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench")).unwrap();
    let cfg = if args.flag("quick") {
        fig2::Fig2Config::quick()
    } else {
        fig2::Fig2Config::paper()
    };
    eprintln!("[fig2] ks={:?} reps={}", cfg.ks, cfg.reps);
    let rows = fig2::run(&cfg);
    for panel in ["tt", "cp"] {
        let mut report = BenchReport::new(
            &format!("Figure 2 ({panel}-format input): embedding time vs k"),
            &["map", "k", "median_secs"],
        );
        for r in rows.iter().filter(|r| r.input_format == panel) {
            report.push(vec![
                r.map.clone(),
                r.k.to_string(),
                format!("{:.3e}", r.secs),
            ]);
        }
        report.finish(&format!("fig2_time_{panel}_input.csv"));
    }
    // Shape check: per panel, which map is fastest at the largest k.
    let kmax = *cfg.ks.iter().max().unwrap();
    for panel in ["tt", "cp"] {
        let fastest = rows
            .iter()
            .filter(|r| r.input_format == panel && r.k == kmax)
            .min_by(|a, b| a.secs.total_cmp(&b.secs))
            .unwrap();
        println!(
            "[fig2:{panel}-input] fastest at k={kmax}: {} ({:.3e}s)",
            fastest.map, fastest.secs
        );
    }
}
