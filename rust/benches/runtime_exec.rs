//! Runtime-layer bench: per-batch execution time of each compiled
//! artifact vs the native engine on identical inputs — the L2/L3 numbers
//! behind EXPERIMENTS.md §Perf (including the pallas-interpret vs fused
//! artifact comparison that drives the router's preference).
//!
//! ```text
//! cargo bench --bench runtime_exec
//! ```

use tensorized_rp::projections::Projection;
use tensorized_rp::rng::Rng;
use tensorized_rp::runtime::{pack, PjrtEngine};
use tensorized_rp::tensor::TtTensor;
use tensorized_rp::util::bench::BenchReport;

fn main() {
    let mut engine = match PjrtEngine::cpu() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("[runtime_exec] PJRT unavailable: {e}");
            return;
        }
    };
    if let Err(e) = engine.load_dir(std::path::Path::new("artifacts")) {
        eprintln!("[runtime_exec] artifacts unavailable ({e}); run `make artifacts`");
        return;
    }

    let spec = engine.spec("tt_rp_medium").expect("tt_rp_medium").clone();
    let (n, d, r, rt) = spec.tt_meta().unwrap();
    let dims = vec![d; n];
    let mut rng = Rng::seed_from(1);
    let f = tensorized_rp::projections::TtProjection::new(&dims, r, spec.k, &mut rng);
    let (gf, gm, gl) = pack::pack_tt_projection(&f, n, d, r).unwrap();
    let xs: Vec<TtTensor> = (0..spec.batch)
        .map(|_| TtTensor::random_unit(&dims, rt, &mut rng))
        .collect();
    let xrefs: Vec<&TtTensor> = xs.iter().collect();
    let (xf, xm, xl) = pack::pack_tt_inputs(&xrefs, spec.batch, n, d, rt).unwrap();
    let inputs = vec![gf, gm, gl, xf, xm, xl];

    let mut report = BenchReport::new(
        "Runtime: ms per batch of 8 medium-order TT projections (k=128, R=5)",
        &["engine", "ms_per_batch", "ms_per_request"],
    );
    let reps = 20;
    for name in ["tt_rp_medium", "tt_rp_medium_pallas"] {
        engine.execute(name, &inputs).unwrap(); // warmup/compile caches
        let t = std::time::Instant::now();
        for _ in 0..reps {
            engine.execute(name, &inputs).unwrap();
        }
        let ms = t.elapsed().as_secs_f64() * 1e3 / reps as f64;
        report.push(vec![
            format!("pjrt:{name}"),
            format!("{ms:.3}"),
            format!("{:.3}", ms / spec.batch as f64),
        ]);
    }
    // Native engine, same 8 inputs.
    for x in &xs {
        std::hint::black_box(f.project_tt(x));
    }
    let t = std::time::Instant::now();
    for _ in 0..reps {
        for x in &xs {
            std::hint::black_box(f.project_tt(x));
        }
    }
    let ms = t.elapsed().as_secs_f64() * 1e3 / reps as f64;
    report.push(vec![
        "native".into(),
        format!("{ms:.3}"),
        format!("{:.3}", ms / spec.batch as f64),
    ]);
    report.finish("runtime_exec.csv");
}
