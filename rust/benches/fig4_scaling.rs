//! Bench target regenerating **Figure 4** (Appendix B.2): embedding time
//! vs input dimension `d^N` for `d=3, N ∈ {8,11,12,13}`.
//!
//! ```text
//! cargo bench --bench fig4_scaling [-- --quick]
//! ```
//!
//! Expected shape: tensorized maps scale ~linearly in N (so ~log in d^N);
//! the Gaussian series disappears once `k·d^N` is unmaterializable; TT is
//! faster than classical RPs on both panels at large `d^N`.

use tensorized_rp::experiments::fig4;
use tensorized_rp::util::bench::BenchReport;
use tensorized_rp::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench")).unwrap();
    let cfg = if args.flag("quick") {
        fig4::Fig4Config::quick()
    } else {
        fig4::Fig4Config::paper()
    };
    eprintln!("[fig4] orders={:?} k={} reps={}", cfg.orders, cfg.k, cfg.reps);
    let rows = fig4::run(&cfg);
    for panel in ["tt", "cp"] {
        let mut report = BenchReport::new(
            &format!("Figure 4 ({panel}-format input): time vs d^N"),
            &["map", "order", "numel", "median_secs"],
        );
        for r in rows.iter().filter(|r| r.input_format == panel) {
            report.push(vec![
                r.map.clone(),
                r.order.to_string(),
                format!("{:.3e}", r.numel),
                format!("{:.3e}", r.secs),
            ]);
        }
        report.finish(&format!("fig4_scaling_{panel}_input.csv"));
    }
    let nmax = *cfg.orders.iter().max().unwrap();
    for panel in ["tt", "cp"] {
        if let Some(fastest) = rows
            .iter()
            .filter(|r| r.input_format == panel && r.order == nmax)
            .min_by(|a, b| a.secs.total_cmp(&b.secs))
        {
            println!(
                "[fig4:{panel}-input] fastest at N={nmax}: {} ({:.3e}s)",
                fastest.map, fastest.secs
            );
        }
    }
}
