//! Bench target regenerating **Figure 1**: distortion ratio vs `k` for the
//! small / medium / high-order regimes.
//!
//! ```text
//! cargo bench --bench fig1_distortion                  # all three panels
//! cargo bench --bench fig1_distortion -- --case high --trials 100
//! cargo bench --bench fig1_distortion -- --quick
//! ```
//!
//! Writes `results/fig1_<case>.csv` and prints the paper-shaped tables.
//! Expected shape (paper §6): all maps ≈ Gaussian in the small case; rank
//! matters in the medium case with CP(100) still poor; CP fails outright
//! in the high case while TT(5,10) embeds well.

use tensorized_rp::data::inputs::Regime;
use tensorized_rp::experiments::fig1;
use tensorized_rp::util::bench::BenchReport;
use tensorized_rp::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench")).unwrap();
    let quick = args.flag("quick");
    let cases: Vec<Regime> = match args.get("case") {
        Some(c) => vec![Regime::parse(c).expect("bad --case")],
        None => vec![Regime::Small, Regime::Medium, Regime::High],
    };
    for case in cases {
        let mut cfg = if quick {
            fig1::Fig1Config::quick(case)
        } else {
            fig1::Fig1Config::paper(case)
        };
        if let Some(t) = args.get("trials") {
            cfg.trials = t.parse().expect("bad --trials");
        }
        if let Some(s) = args.get("seed") {
            cfg.seed = s.parse().expect("bad --seed");
        }
        eprintln!(
            "[fig1] case={} trials={} ks={:?}",
            case.name(),
            cfg.trials,
            cfg.ks
        );
        let rows = fig1::run(&cfg);
        let mut report = BenchReport::new(
            &format!("Figure 1 ({}): mean distortion ratio vs k", case.name()),
            &["map", "k", "mean_distortion", "std"],
        );
        for r in &rows {
            report.push(vec![
                r.map.clone(),
                r.k.to_string(),
                format!("{:.4}", r.mean),
                format!("{:.4}", r.std),
            ]);
        }
        report.finish(&format!("fig1_{}.csv", case.name()));

        // Paper-shape sanity line: who wins at the largest k.
        let kmax = *cfg.ks.iter().max().unwrap();
        let best = rows
            .iter()
            .filter(|r| r.k == kmax)
            .min_by(|a, b| a.mean.total_cmp(&b.mean))
            .unwrap();
        println!("[fig1:{}] best at k={kmax}: {} ({:.4})", case.name(), best.map, best.mean);
    }
}
