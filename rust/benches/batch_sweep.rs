//! Bench target: batch-size sweep of the batched projection path — the
//! throughput trajectory future PRs track via `BENCH_batch_sweep.json`.
//!
//! ```text
//! cargo bench --bench batch_sweep [-- --quick] [-- --out FILE]
//! ```
//!
//! Per map family and B ∈ {1, 4, 16, 64} (quick: {1, 4, 16}) on dense
//! inputs, reports per-input time through an item-at-a-time `project`
//! loop vs one `project_batch_into` call, the speedup, and per-map
//! throughput in inputs/s. Acceptance tripwire for this PR: batched TT on
//! the dense medium-order shape must reach ≥ 2× item-at-a-time at B = 16.

use tensorized_rp::experiments::batch::{run, BatchSweepConfig};
use tensorized_rp::util::bench::BenchReport;
use tensorized_rp::util::cli::Args;
use tensorized_rp::util::json::{num_arr, obj, Json};

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench")).unwrap();
    let cfg = if args.flag("quick") {
        BatchSweepConfig::quick()
    } else {
        BatchSweepConfig::paper()
    };
    eprintln!(
        "[batch_sweep] dims={:?} k={} batch_sizes={:?}",
        cfg.dims, cfg.k, cfg.batch_sizes
    );
    let rows = run(&cfg);

    let mut report = BenchReport::new(
        "Batch-size sweep: project loop vs project_batch_into",
        &["map", "B", "item_us/input", "batched_us/input", "speedup"],
    );
    for r in &rows {
        report.push(vec![
            r.map.clone(),
            r.batch.to_string(),
            format!("{:.3}", r.item_us),
            format!("{:.3}", r.batched_us),
            format!("{:.2}", r.speedup),
        ]);
    }
    report.finish("batch_sweep.csv");

    // Machine-readable trajectory file: per-map series over B with
    // batched throughput (inputs/s).
    let mut maps: Vec<String> = rows.iter().map(|r| r.map.clone()).collect();
    maps.dedup();
    let series: Vec<Json> = maps
        .iter()
        .map(|name| {
            let per_map: Vec<_> = rows.iter().filter(|r| &r.map == name).collect();
            obj(vec![
                ("map", Json::Str(name.clone())),
                (
                    "batch_sizes",
                    Json::Arr(per_map.iter().map(|r| Json::Num(r.batch as f64)).collect()),
                ),
                (
                    "batched_throughput_per_s",
                    num_arr(
                        &per_map
                            .iter()
                            .map(|r| 1e6 / r.batched_us.max(1e-12))
                            .collect::<Vec<f64>>(),
                    ),
                ),
                (
                    "item_throughput_per_s",
                    num_arr(
                        &per_map
                            .iter()
                            .map(|r| 1e6 / r.item_us.max(1e-12))
                            .collect::<Vec<f64>>(),
                    ),
                ),
                (
                    "speedup",
                    num_arr(&per_map.iter().map(|r| r.speedup).collect::<Vec<f64>>()),
                ),
            ])
        })
        .collect();
    let doc = obj(vec![
        ("bench", Json::Str("batch_sweep".into())),
        ("dims", Json::Arr(cfg.dims.iter().map(|&d| Json::Num(d as f64)).collect())),
        ("k", Json::Num(cfg.k as f64)),
        ("series", Json::Arr(series)),
    ]);
    let out_path = args.get_or("out", "BENCH_batch_sweep.json");
    match std::fs::write(&out_path, doc.to_string_pretty()) {
        Ok(()) => println!("[written {out_path}]"),
        Err(e) => eprintln!("[warn] could not write {out_path}: {e}"),
    }

    // Acceptance tripwire (report, don't panic: machine load varies).
    for r in rows.iter().filter(|r| r.map.starts_with("TT(") && r.batch == 16) {
        let verdict = if r.speedup >= 2.0 { "PASS" } else { "MISS" };
        println!(
            "[batch_sweep] TT dense B=16 batched speedup: {:.2}x ({verdict}, target ≥ 2x)",
            r.speedup
        );
    }
}
