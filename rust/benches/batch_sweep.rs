//! Bench target: batch-size sweep of the batched projection path — the
//! throughput trajectory future PRs track via `BENCH_batch_sweep.json`.
//!
//! ```text
//! cargo bench --bench batch_sweep [-- --quick] [-- --out FILE]
//! ```
//!
//! Per map family, input format (dense for all six maps; TT and CP format
//! for the tensorized TT/CP/TRP maps) and B ∈ {1, 4, 16, 64} (quick:
//! {1, 4, 16}), reports per-input time through an item-at-a-time
//! `project` loop vs one `project_batch_into` call, the speedup, and
//! per-map throughput in inputs/s. Acceptance tripwire for this PR:
//! batched TT-map throughput on **TT-format** inputs must reach ≥ 2×
//! item-at-a-time at B = 16 (the dense tripwire from PR 1 stays).

use tensorized_rp::experiments::batch::{
    kernel_bench, print_kernel_verdict, print_trace_verdict, print_verdict, print_wal_verdict,
    run, to_json, trace_overhead, wal_overhead, BatchSweepConfig,
};
use tensorized_rp::util::bench::BenchReport;
use tensorized_rp::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench")).unwrap();
    let cfg = if args.flag("quick") {
        BatchSweepConfig::quick()
    } else {
        BatchSweepConfig::paper()
    };
    eprintln!(
        "[batch_sweep] dims={:?} k={} input_rank={} batch_sizes={:?}",
        cfg.dims, cfg.k, cfg.input_rank, cfg.batch_sizes
    );
    let rows = run(&cfg);

    let mut report = BenchReport::new(
        "Batch-size sweep: project loop vs project_batch_into",
        &["map", "input", "B", "item_us/input", "batched_us/input", "speedup"],
    );
    for r in &rows {
        report.push(vec![
            r.map.clone(),
            r.input.clone(),
            r.batch.to_string(),
            format!("{:.3}", r.item_us),
            format!("{:.3}", r.batched_us),
            format!("{:.2}", r.speedup),
        ]);
    }
    report.finish("batch_sweep.csv");

    // Kernel micro-benchmark on the sweep's GEMM shape mix: packed
    // kernel vs the frozen PR 5 baseline, emitted as the `kernel` series.
    let krows = kernel_bench(&cfg);

    // Tracing tripwire on the B = 16 serving point: bit-identical
    // responses with tracing off vs on, bounded enabled-path overhead.
    let trow = trace_overhead(&cfg);

    // Durability tripwire on the B = 16 insert point: bit-identical
    // responses with the write-ahead log off vs on, and WAL-on
    // retaining ≥ 80% of WAL-off insert throughput.
    let wrow = wal_overhead(&cfg);

    // Machine-readable trajectory file: one series per (map, input).
    let doc = to_json(&cfg, &rows, &krows, Some(&trow), Some(&wrow));
    let out_path = args.get_or("out", "BENCH_batch_sweep.json");
    match std::fs::write(&out_path, doc.to_string_pretty()) {
        Ok(()) => println!("[written {out_path}]"),
        Err(e) => eprintln!("[warn] could not write {out_path}: {e}"),
    }

    print_verdict(&rows);
    print_kernel_verdict(&krows);
    print_trace_verdict(&trow);
    print_wal_verdict(&wrow);
}
