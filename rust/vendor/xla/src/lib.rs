//! Offline stub of the `xla` (xla_extension) crate surface used by
//! `tensorized_rp::runtime::engine`.
//!
//! The real crate links against the PJRT C API and an XLA shared library,
//! neither of which exists in this build environment. This stub keeps the
//! runtime layer compiling unchanged while making the backend's absence a
//! clean runtime error: [`PjRtClient::cpu`] fails with a descriptive
//! message, so every caller (`trp serve`, benches, tests) takes its
//! existing "PJRT unavailable → native engine" fallback path. Swapping the
//! `xla` entry in `rust/Cargo.toml` back to the real crate re-enables the
//! compiled path with no source changes.

use std::fmt;
use std::path::Path;

/// Stub error: always "backend unavailable".
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT/XLA backend not available (offline stub build; native engine only)"
    ))
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    /// The real crate opens the PJRT CPU plugin; the stub reports that no
    /// backend is linked.
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Platform name (unreachable in practice: no client can be built).
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation (unreachable: no client can be built).
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text (unreachable in practice).
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation graph (stub).
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Marker trait for executable argument types (stub of the real crate's
/// buffer-argument bound).
pub trait ExecuteArg {}

impl ExecuteArg for Literal {}

/// A compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with host literals (unreachable in practice).
    pub fn execute<T: ExecuteArg>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy back to a host literal (unreachable in practice).
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A host literal (stub).
pub struct Literal;

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    /// Unwrap a 1-tuple result (unreachable in practice).
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    /// Read out as a typed vector (unreachable in practice).
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("not available"));
    }

    #[test]
    fn literal_construction_is_usable() {
        // The engine builds literals before executing; construction and
        // reshape must succeed so the failure surfaces at execute time
        // with the clearest message.
        let lit = Literal::vec1(&[1.0, 2.0]).reshape(&[2]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
    }
}
