//! Offline drop-in for the subset of the `anyhow` crate this workspace
//! uses: [`Error`], [`Result`], [`anyhow!`], [`bail!`] and the
//! [`Context`] extension trait. No external registry access is available
//! in the build environment, so this minimal shim is vendored in-tree.
//!
//! Semantics match real `anyhow` for the covered surface: errors carry a
//! display message (with `context` prepended as `"<context>: <cause>"`)
//! and any `std::error::Error` converts via `?`.

use std::fmt;

/// A type-erased error with a display message and optional source.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string(), source: None }
    }

    /// Wrap a concrete error, preserving it as the source.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(error: E) -> Self {
        Self { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Prepend context to the display message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The root cause chain's original error, if one was captured.
    pub fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn std::error::Error + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Mirrors anyhow's blanket conversion. `Error` itself deliberately does
// not implement `std::error::Error`, which keeps this impl coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result`.
pub trait Context<T, E> {
    /// Attach a context message to the error case.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Attach a lazily-built context message to the error case.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*).into())
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_and_context_compose() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "missing");
    }

    #[test]
    fn result_context_helpers() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("reading {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "reading x: missing");
    }

    #[test]
    fn macros_format() {
        let name = "tt";
        let e = anyhow!("unknown artifact {name:?}");
        assert_eq!(e.to_string(), "unknown artifact \"tt\"");
        fn f(x: usize) -> Result<()> {
            if x > 2 {
                bail!("too big: {x}");
            }
            Ok(())
        }
        assert!(f(1).is_ok());
        assert_eq!(f(3).unwrap_err().to_string(), "too big: 3");
    }
}
