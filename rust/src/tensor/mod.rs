//! Tensor formats: dense, tensor-train (TT) and CP.
//!
//! The paper's projection maps act on `N`-th order tensors that may be
//! given dense, in TT format (`⟨⟨G¹,…,G^N⟩⟩`, Oseledets 2011) or in CP
//! format (`[[A¹,…,A^N]]`, Hitchcock 1927). This module implements all
//! three with the operations the projection layer and the experiment
//! harness need: evaluation, conversion, matricization, inner products in
//! compressed form, norms, random generation with the paper's variance
//! prescriptions, TT-SVD and TT-rounding.

mod batch;
mod cp;
mod dense;
mod shape;
mod tt;
mod tucker;

pub use batch::{CpBatchContraction, TtBatchContraction};
pub use cp::CpTensor;
pub use dense::DenseTensor;
pub use shape::Shape;
pub use tt::{TtContraction, TtDenseContraction, TtEntryEvaluator, TtTensor};
pub use tucker::TuckerTensor;

/// How an input tensor is physically represented.
///
/// The coordinator routes requests on this tag, and the projection maps
/// pick the contraction schedule with the complexity the paper states for
/// each case (§3 and §4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Format {
    /// Fully materialized `d₁·…·d_N` buffer.
    Dense,
    /// Tensor-train cores.
    Tt,
    /// CP factor matrices.
    Cp,
}

impl Format {
    /// Parse the canonical wire/CLI name (the inverse of `Display`).
    pub fn parse(s: &str) -> Option<Format> {
        match s {
            "dense" => Some(Format::Dense),
            "tt" => Some(Format::Tt),
            "cp" => Some(Format::Cp),
            _ => None,
        }
    }
}

impl std::fmt::Display for Format {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Format::Dense => write!(f, "dense"),
            Format::Tt => write!(f, "tt"),
            Format::Cp => write!(f, "cp"),
        }
    }
}

/// A tensor in any of the three supported formats.
#[derive(Debug, Clone)]
pub enum AnyTensor {
    /// Dense representation.
    Dense(DenseTensor),
    /// Tensor-train representation.
    Tt(TtTensor),
    /// CP representation.
    Cp(CpTensor),
}

impl AnyTensor {
    /// The format tag of this tensor.
    pub fn format(&self) -> Format {
        match self {
            AnyTensor::Dense(_) => Format::Dense,
            AnyTensor::Tt(_) => Format::Tt,
            AnyTensor::Cp(_) => Format::Cp,
        }
    }

    /// Mode sizes.
    pub fn dims(&self) -> &[usize] {
        match self {
            AnyTensor::Dense(t) => t.dims(),
            AnyTensor::Tt(t) => t.dims(),
            AnyTensor::Cp(t) => t.dims(),
        }
    }

    /// Frobenius norm (computed in-format; never materializes).
    pub fn fro_norm(&self) -> f64 {
        match self {
            AnyTensor::Dense(t) => t.fro_norm(),
            AnyTensor::Tt(t) => t.fro_norm(),
            AnyTensor::Cp(t) => t.fro_norm(),
        }
    }

    /// Materialize as a dense tensor (only valid for small products of
    /// dims; callers guard with [`Shape::numel`]).
    pub fn to_dense(&self) -> DenseTensor {
        match self {
            AnyTensor::Dense(t) => t.clone(),
            AnyTensor::Tt(t) => t.to_dense(),
            AnyTensor::Cp(t) => t.to_dense(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn format_tags() {
        let mut rng = Rng::seed_from(1);
        let t = TtTensor::random(&[2, 3, 2], 2, &mut rng);
        assert_eq!(AnyTensor::Tt(t).format(), Format::Tt);
    }

    #[test]
    fn any_tensor_norm_consistency() {
        let mut rng = Rng::seed_from(2);
        let t = TtTensor::random(&[3, 4, 3], 3, &mut rng);
        let any = AnyTensor::Tt(t.clone());
        let dense = any.to_dense();
        assert!((any.fro_norm() - dense.fro_norm()).abs() < 1e-9);
    }

    #[test]
    fn format_display() {
        assert_eq!(Format::Tt.to_string(), "tt");
        assert_eq!(Format::Cp.to_string(), "cp");
        assert_eq!(Format::Dense.to_string(), "dense");
    }

    #[test]
    fn format_parse_inverts_display() {
        for f in [Format::Dense, Format::Tt, Format::Cp] {
            assert_eq!(Format::parse(&f.to_string()), Some(f));
        }
        assert_eq!(Format::parse("tucker"), None);
    }
}
