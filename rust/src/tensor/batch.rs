//! Batched compressed-input contraction kernels.
//!
//! The paper's efficiency claim is that tensorized maps apply cheaply to
//! inputs *given in TT or CP format* — and a serving flush delivers many
//! such inputs at once. These contexts group a flush's same-shape,
//! same-rank compressed inputs, stack their cores / factor columns into
//! contiguous panels **once**, and run every mode of the contraction
//! chain as blocked GEMMs over the whole group: one GEMM sequence per
//! shape-group instead of one full chain per `(row, item)` pair.
//!
//! Bit-equivalence contract (property-tested in
//! `rust/tests/projection_batch_props.rs`): every kernel folds the batch
//! into either the leading rows or the trailing columns of GEMMs whose
//! output entries are computed independently with ascending-index
//! accumulation (`linalg::matmul_acc`), so a group of `B` items produces
//! outputs bit-identical to `B` single-item (`B = 1`) calls, and any
//! row-subset of the map produces the same values as the full map
//! (which is what lets `project_tt_parallel` shard rows).
//!
//! * [`TtBatchContraction`] — a group of TT inputs, contracted against a
//!   TT map's rows ([`TtBatchContraction::inner_tt_rows_into`]), a CP
//!   map's rows ([`TtBatchContraction::inner_cp_rows_into`]), or a TRP's
//!   Khatri-Rao factors ([`TtBatchContraction::inner_trp_into`]).
//! * [`CpBatchContraction`] — the CP-input analogue with the same three
//!   map-side entry points.

use super::tt::TtDenseContraction;
use super::{CpTensor, TtTensor};
use crate::linalg::matmul_into;

/// A group of same-shape, same-rank TT inputs with their cores permuted
/// once into the two layouts the blocked kernels consume.
pub struct TtBatchContraction {
    dims: Vec<usize>,
    /// Shared input rank vector (length `N + 1`).
    ranks: Vec<usize>,
    /// Group size `B`.
    b: usize,
    /// Per mode: `B` blocks of the core permuted to `[(d·rₘ), rₘ₊₁]`
    /// row-major (`xperm[m][bi·sz + (i·rₘ + a)·rₘ₊₁ + a2] = X[a, i, a2]`)
    /// — the right operand of the TT-map chain's absorb-input GEMM.
    xperm: Vec<Vec<f64>>,
    /// Per mode: `B` blocks of the core transposed to `[(d·rₘ₊₁), rₘ]`
    /// row-major (`cores_t[m][bi·sz + (i·rₘ₊₁ + ar)·rₘ + a] = X[a, i, ar]`)
    /// — the right operand of the CP/TRP right-to-left chain GEMM.
    cores_t: Vec<Vec<f64>>,
}

impl TtBatchContraction {
    /// Build the group context with **both** panel layouts (convenience
    /// for callers driving more than one kernel family). Panics unless
    /// every item shares one `(dims, ranks)` shape — the caller
    /// partitions mixed batches into shape-groups first
    /// (`projections::partition_by_shape`).
    pub fn new(items: &[&TtTensor]) -> Self {
        Self::with_layouts(items, true, true)
    }

    /// Panels for a TT map's chain only (`inner_tt_rows_into` reads
    /// `xperm`; the `cores_t` staging is skipped).
    pub fn for_tt_map(items: &[&TtTensor]) -> Self {
        Self::with_layouts(items, true, false)
    }

    /// Panels for CP/TRP right-to-left chains only
    /// (`inner_cp_rows_into`/`inner_trp_into` read `cores_t`; the
    /// `xperm` staging is skipped).
    pub fn for_compressed_rows(items: &[&TtTensor]) -> Self {
        Self::with_layouts(items, false, true)
    }

    fn with_layouts(items: &[&TtTensor], want_xperm: bool, want_cores_t: bool) -> Self {
        assert!(!items.is_empty(), "empty TT batch group");
        let dims = items[0].dims().to_vec();
        let ranks = items[0].ranks().to_vec();
        for x in items {
            assert_eq!(x.dims(), &dims[..], "TT group dims mismatch");
            assert_eq!(x.ranks(), &ranks[..], "TT group ranks mismatch");
        }
        let b = items.len();
        let n = dims.len();
        let mut xperm = Vec::with_capacity(n);
        let mut cores_t = Vec::with_capacity(n);
        for m in 0..n {
            let rl = ranks[m];
            let d = dims[m];
            let rr = ranks[m + 1];
            let sz = rl * d * rr;
            // Unwanted layouts stay empty per mode (a kernel touching one
            // panics loudly on the slice bound rather than reading junk).
            let mut xp = if want_xperm { vec![0.0; b * sz] } else { Vec::new() };
            let mut ct = if want_cores_t { vec![0.0; b * sz] } else { Vec::new() };
            for (bi, x) in items.iter().enumerate() {
                let core = x.core(m);
                let (xp_base, ct_base) = (bi * sz, bi * sz);
                for a in 0..rl {
                    for i in 0..d {
                        let src = &core[(a * d + i) * rr..(a * d + i + 1) * rr];
                        if want_xperm {
                            let dst = xp_base + (i * rl + a) * rr;
                            xp[dst..dst + rr].copy_from_slice(src);
                        }
                        if want_cores_t {
                            for (ar, &v) in src.iter().enumerate() {
                                ct[ct_base + (i * rr + ar) * rl + a] = v;
                            }
                        }
                    }
                }
            }
            xperm.push(xp);
            cores_t.push(ct);
        }
        Self { dims, ranks, b, xperm, cores_t }
    }

    /// Group size `B`.
    pub fn batch(&self) -> usize {
        self.b
    }

    /// Mode sizes of the group.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Shared rank vector of the group.
    pub fn ranks(&self) -> &[usize] {
        &self.ranks
    }

    fn xperm_item(&self, m: usize, bi: usize) -> &[f64] {
        let sz = self.ranks[m] * self.dims[m] * self.ranks[m + 1];
        &self.xperm[m][bi * sz..(bi + 1) * sz]
    }

    fn core_t_item(&self, m: usize, bi: usize) -> &[f64] {
        let sz = self.ranks[m] * self.dims[m] * self.ranks[m + 1];
        &self.cores_t[m][bi * sz..(bi + 1) * sz]
    }

    /// Contract the group against the rows of a **TT map** (given as the
    /// rows' pre-transposed [`TtDenseContraction`] contexts), writing raw
    /// inner products `out[bi·rows.len() + r] = ⟨rowᵣ, x_bᵢ⟩`.
    ///
    /// Per mode: one absorb-row GEMM per map row over all `B` boundary
    /// matrices at once, then one absorb-input GEMM per item over all map
    /// rows at once — `k + B` GEMMs per mode instead of `k·B` hand-rolled
    /// chains. `pa`/`pb` are caller-held panel scratch
    /// (`projections::Workspace::panel_*`).
    ///
    /// The two regroup permutes that PR 4 staged through a third scratch
    /// panel (flagged pure-memory-traffic-hot in its notes) are fused
    /// into the absorb-input GEMM itself via
    /// [`crate::linalg::matmul_gather_scatter_acc`]: regroup #1 becomes
    /// the GEMM's A-side *gather* (the pack prologue reads `pb` through
    /// the permutation index map) and regroup #2 becomes its C-side row
    /// *scatter* (the store epilogue lands each output row directly at
    /// its mode-`m+1` boundary-panel slot). Bit-identical to the staged
    /// path by the kernel's determinism contract — same operand values,
    /// same ascending-index chains — which
    /// [`Self::inner_tt_rows_into_unfused`] pins as a regression test.
    pub fn inner_tt_rows_into(
        &self,
        rows: &[TtDenseContraction],
        out: &mut [f64],
        pa: &mut Vec<f64>,
        pb: &mut Vec<f64>,
    ) {
        let n = self.dims.len();
        let b = self.b;
        let kr = rows.len();
        assert!(out.len() >= b * kr, "output buffer size");
        if kr == 0 {
            return;
        }
        for row in rows {
            assert_eq!(row.dims(), &self.dims[..], "map row shape mismatch");
        }
        // Boundary panels: per row r a row-major [raᵣ, B·rb] block,
        // blocks concatenated in row order. At mode boundary 0 every
        // rank is 1: one 1×B block of ones per row.
        pa.clear();
        pa.resize(kr * b, 1.0);
        // Fused-regroup index maps, rebuilt per mode (k2 global rows).
        let mut row_base: Vec<usize> = Vec::new();
        let mut row_stride: Vec<usize> = Vec::new();
        let mut row_dst: Vec<usize> = Vec::new();
        for m in 0..n {
            let d = self.dims[m];
            let rb = self.ranks[m];
            let rb2 = self.ranks[m + 1];
            // Absorb the row core: Tᵣ[(i·ra2 + a2), (bi·rb + bv)] =
            //   Σₐ rowᵣ[a, i, a2] · Mᵣ[a, (bi·rb + bv)] — one GEMM per row
            // with the whole group folded into the columns.
            let total_t: usize = rows.iter().map(|r| d * r.ranks()[m + 1] * b * rb).sum();
            pb.clear();
            pb.resize(total_t, 0.0);
            let mut mo = 0usize;
            let mut to = 0usize;
            for row in rows {
                let ra = row.ranks()[m];
                let ra2 = row.ranks()[m + 1];
                let msz = ra * b * rb;
                let tsz = d * ra2 * b * rb;
                matmul_into(
                    row.core_t(m),
                    &pa[mo..mo + msz],
                    &mut pb[to..to + tsz],
                    d * ra2,
                    ra,
                    b * rb,
                );
                mo += msz;
                to += tsz;
            }
            // Absorb the input core with both regroups fused into the
            // GEMM. Conceptual A operand per item (the old staged t2):
            //   t2_bᵢ[(roffᵣ + a2), (i·rb + bv)]
            //     = pb[toᵣ + (i·ra2ᵣ + a2)·(B·rb) + bi·rb + bv]
            // so global row g = roffᵣ + a2 gathers through
            //   row_base[g]   = toᵣ + a2·(B·rb)      (the i = 0 slot)
            //   row_stride[g] = ra2ᵣ·(B·rb)          (step per i)
            // and its output row lands at the mode-(m+1) boundary slot
            //   row_dst[g]    = m2ᵣ + a2·(B·rb2)     (+ bi·rb2 per item),
            // which is exactly where the staged path's regroup #2 copied.
            let k2: usize = rows.iter().map(|r| r.ranks()[m + 1]).sum();
            row_base.clear();
            row_stride.clear();
            row_dst.clear();
            let mut to = 0usize;
            let mut m2 = 0usize;
            for row in rows {
                let ra2 = row.ranks()[m + 1];
                for a2 in 0..ra2 {
                    row_base.push(to + a2 * (b * rb));
                    row_stride.push(ra2 * (b * rb));
                    row_dst.push(m2 + a2 * (b * rb2));
                }
                to += d * ra2 * b * rb;
                m2 += ra2 * b * rb2;
            }
            let pb_read: &[f64] = pb;
            pa.clear();
            pa.resize(k2 * b * rb2, 0.0);
            for bi in 0..b {
                crate::linalg::matmul_gather_scatter_acc(
                    |g, p| pb_read[row_base[g] + (p / rb) * row_stride[g] + bi * rb + p % rb],
                    self.xperm_item(m, bi),
                    pa,
                    k2,
                    d * rb,
                    rb2,
                    |g| row_dst[g] + bi * rb2,
                );
            }
        }
        // Every rank is 1 again: pa[r·b + bi] is ⟨rowᵣ, x_bᵢ⟩.
        for r in 0..kr {
            for bi in 0..b {
                out[bi * kr + r] = pa[r * b + bi];
            }
        }
    }

    /// The PR 4 staged path — regroup #1 into a materialized `t2` panel,
    /// a plain absorb-input GEMM, regroup #2 back out — kept as the
    /// baseline the fused-regroup bit-identity regression test
    /// (`rust/tests/gemm_kernel_props.rs`) compares against. Allocates
    /// its scratch internally; not used by any production path.
    pub fn inner_tt_rows_into_unfused(
        &self,
        rows: &[TtDenseContraction],
        out: &mut [f64],
        pa: &mut Vec<f64>,
        pb: &mut Vec<f64>,
    ) {
        let n = self.dims.len();
        let b = self.b;
        let kr = rows.len();
        assert!(out.len() >= b * kr, "output buffer size");
        if kr == 0 {
            return;
        }
        for row in rows {
            assert_eq!(row.dims(), &self.dims[..], "map row shape mismatch");
        }
        let mut pc: Vec<f64> = Vec::new();
        pa.clear();
        pa.resize(kr * b, 1.0);
        for m in 0..n {
            let d = self.dims[m];
            let rb = self.ranks[m];
            let rb2 = self.ranks[m + 1];
            let total_t: usize = rows.iter().map(|r| d * r.ranks()[m + 1] * b * rb).sum();
            pb.clear();
            pb.resize(total_t, 0.0);
            let mut mo = 0usize;
            let mut to = 0usize;
            for row in rows {
                let ra = row.ranks()[m];
                let ra2 = row.ranks()[m + 1];
                let msz = ra * b * rb;
                let tsz = d * ra2 * b * rb;
                matmul_into(
                    row.core_t(m),
                    &pa[mo..mo + msz],
                    &mut pb[to..to + tsz],
                    d * ra2,
                    ra,
                    b * rb,
                );
                mo += msz;
                to += tsz;
            }
            // Regroup per item: t2_bᵢ[(roffᵣ + a2), (i·rb + bv)], stacking
            // every map row's block vertically (k2 = Σᵣ ra2ᵣ rows).
            let k2: usize = rows.iter().map(|r| r.ranks()[m + 1]).sum();
            pc.clear();
            pc.resize(b * k2 * d * rb, 0.0);
            let mut to = 0usize;
            let mut roff = 0usize;
            for row in rows {
                let ra2 = row.ranks()[m + 1];
                for i in 0..d {
                    for a2 in 0..ra2 {
                        let src_base = to + (i * ra2 + a2) * (b * rb);
                        for bi in 0..b {
                            let src = &pb[src_base + bi * rb..src_base + (bi + 1) * rb];
                            let dst = bi * (k2 * d * rb) + (roff + a2) * (d * rb) + i * rb;
                            pc[dst..dst + rb].copy_from_slice(src);
                        }
                    }
                }
                to += d * ra2 * b * rb;
                roff += ra2;
            }
            // Absorb the input core: one GEMM per item over the stacked
            // rows: N_bᵢ = t2_bᵢ · xperm_bᵢ ((k2 × d·rb) × (d·rb × rb2)).
            pb.clear();
            pb.resize(b * k2 * rb2, 0.0);
            for bi in 0..b {
                matmul_into(
                    &pc[bi * k2 * d * rb..(bi + 1) * k2 * d * rb],
                    self.xperm_item(m, bi),
                    &mut pb[bi * k2 * rb2..(bi + 1) * k2 * rb2],
                    k2,
                    d * rb,
                    rb2,
                );
            }
            // Regroup back into per-row boundary panels for mode m + 1.
            pa.clear();
            pa.resize(k2 * b * rb2, 0.0);
            let mut m2 = 0usize;
            let mut roff = 0usize;
            for row in rows {
                let ra2 = row.ranks()[m + 1];
                for a2 in 0..ra2 {
                    for bi in 0..b {
                        let src = bi * (k2 * rb2) + (roff + a2) * rb2;
                        let dst = m2 + a2 * (b * rb2) + bi * rb2;
                        pa[dst..dst + rb2].copy_from_slice(&pb[src..src + rb2]);
                    }
                }
                m2 += ra2 * b * rb2;
                roff += ra2;
            }
        }
        for r in 0..kr {
            for bi in 0..b {
                out[bi * kr + r] = pa[r * b + bi];
            }
        }
    }

    /// Contract the group against the rows of a **CP map**, given as the
    /// map's pre-transposed factors (`rows_t[r][m]` is `[rank, dₘ]`
    /// row-major), all rows sharing `rank`. Writes raw inner products
    /// `out[bi·rows_t.len() + r]`.
    ///
    /// The chain runs right-to-left per `(row, component)` pair with all
    /// `k·rank` pairs stacked into the leading GEMM rows: per mode, one
    /// GEMM per item against that item's transposed core.
    pub fn inner_cp_rows_into(
        &self,
        rows_t: &[Vec<Vec<f64>>],
        rank: usize,
        out: &mut [f64],
        pa: &mut Vec<f64>,
        pb: &mut Vec<f64>,
    ) {
        let n = self.dims.len();
        let b = self.b;
        let kr = rows_t.len();
        assert!(out.len() >= b * kr, "output buffer size");
        if kr == 0 {
            return;
        }
        let kp = kr * rank;
        // State V per item: [(kr·rank), rₘ] blocks, item-major.
        pa.clear();
        pa.resize(b * kp, 1.0);
        for m in (0..n).rev() {
            let d = self.dims[m];
            let rl = self.ranks[m];
            let rr = self.ranks[m + 1];
            // U[(row·rank + ρ), (i·rr + ar)] = fᵣ[ρ, i] · V[(row·rank + ρ), ar].
            pb.clear();
            pb.resize(b * kp * d * rr, 0.0);
            for bi in 0..b {
                let v_base = bi * kp * rr;
                let u_base = bi * kp * d * rr;
                for (ri, row) in rows_t.iter().enumerate() {
                    let ft = &row[m];
                    debug_assert_eq!(ft.len(), rank * d);
                    for p in 0..rank {
                        let vrow = &pa[v_base + (ri * rank + p) * rr..][..rr];
                        let urow = &mut pb[u_base + (ri * rank + p) * d * rr..][..d * rr];
                        for i in 0..d {
                            let f = ft[p * d + i];
                            for (u, &v) in urow[i * rr..(i + 1) * rr].iter_mut().zip(vrow) {
                                *u = f * v;
                            }
                        }
                    }
                }
            }
            // V' = U · core_t (one GEMM per item over all kp chains).
            pa.clear();
            pa.resize(b * kp * rl, 0.0);
            for bi in 0..b {
                matmul_into(
                    &pb[bi * kp * d * rr..(bi + 1) * kp * d * rr],
                    self.core_t_item(m, bi),
                    &mut pa[bi * kp * rl..(bi + 1) * kp * rl],
                    kp,
                    d * rr,
                    rl,
                );
            }
        }
        // Left boundary rank 1: sum the rank components per (item, row).
        for bi in 0..b {
            for ri in 0..kr {
                let mut acc = 0.0;
                for p in 0..rank {
                    acc += pa[bi * kp + ri * rank + p];
                }
                out[bi * kr + ri] = acc;
            }
        }
    }

    /// Contract the group against a **TRP** (Khatri-Rao) map:
    /// `factors_t[t][m]` is the `t`-th averaged term's factor transposed
    /// to `[k, dₘ]` row-major (the map's pre-transposed compressed-kernel
    /// layout). Writes the raw per-component sums over terms,
    /// `out[bi·k + col] = Σₜ ⟨⊗ₘ Aᵐₜ[:, col], x_bᵢ⟩` (unscaled).
    pub fn inner_trp_into(
        &self,
        factors_t: &[Vec<Vec<f64>>],
        k: usize,
        out: &mut [f64],
        pa: &mut Vec<f64>,
        pb: &mut Vec<f64>,
    ) {
        let n = self.dims.len();
        let b = self.b;
        let t_terms = factors_t.len();
        assert!(out.len() >= b * k, "output buffer size");
        if t_terms == 0 || k == 0 {
            for v in out[..b * k].iter_mut() {
                *v = 0.0;
            }
            return;
        }
        let kp = t_terms * k;
        pa.clear();
        pa.resize(b * kp, 1.0);
        for m in (0..n).rev() {
            let d = self.dims[m];
            let rl = self.ranks[m];
            let rr = self.ranks[m + 1];
            pb.clear();
            pb.resize(b * kp * d * rr, 0.0);
            for bi in 0..b {
                let v_base = bi * kp * rr;
                let u_base = bi * kp * d * rr;
                for (t, term) in factors_t.iter().enumerate() {
                    let ft = &term[m];
                    debug_assert_eq!(ft.len(), k * d);
                    for col in 0..k {
                        let chain = t * k + col;
                        let vrow = &pa[v_base + chain * rr..][..rr];
                        let urow = &mut pb[u_base + chain * d * rr..][..d * rr];
                        for i in 0..d {
                            let f = ft[col * d + i];
                            for (u, &v) in urow[i * rr..(i + 1) * rr].iter_mut().zip(vrow) {
                                *u = f * v;
                            }
                        }
                    }
                }
            }
            pa.clear();
            pa.resize(b * kp * rl, 0.0);
            for bi in 0..b {
                matmul_into(
                    &pb[bi * kp * d * rr..(bi + 1) * kp * d * rr],
                    self.core_t_item(m, bi),
                    &mut pa[bi * kp * rl..(bi + 1) * kp * rl],
                    kp,
                    d * rr,
                    rl,
                );
            }
        }
        // Average structure: sum the T independent terms per component,
        // in ascending term order (the per-item order).
        for bi in 0..b {
            for col in 0..k {
                let mut acc = 0.0;
                for t in 0..t_terms {
                    acc += pa[bi * kp + t * k + col];
                }
                out[bi * k + col] = acc;
            }
        }
    }
}

/// A group of same-shape, same-rank CP inputs with their factors stacked
/// once into the panels the blocked kernels consume.
pub struct CpBatchContraction {
    dims: Vec<usize>,
    /// Shared CP rank of the group's items.
    rank: usize,
    /// Group size `B`.
    b: usize,
    /// Per mode: `B` blocks of the factor transposed to `[rank, dₘ]`
    /// (`factors_t[m][bi·sz + ρ·d + i] = F_bᵢ[i, ρ]`).
    factors_t: Vec<Vec<f64>>,
    /// Per mode: one stacked `[dₘ, B·rank]` panel
    /// (`panel[m][i·(B·rank) + bi·rank + ρ] = F_bᵢ[i, ρ]`) — the right
    /// operand of the Gram GEMMs, covering the whole group at once.
    panel: Vec<Vec<f64>>,
}

impl CpBatchContraction {
    /// Build the group context. Panics unless every item shares one
    /// `(dims, rank)` shape.
    pub fn new(items: &[&CpTensor]) -> Self {
        assert!(!items.is_empty(), "empty CP batch group");
        let dims = items[0].dims().to_vec();
        let rank = items[0].rank();
        for x in items {
            assert_eq!(x.dims(), &dims[..], "CP group dims mismatch");
            assert_eq!(x.rank(), rank, "CP group rank mismatch");
        }
        let b = items.len();
        let n = dims.len();
        let mut factors_t = Vec::with_capacity(n);
        let mut panel = Vec::with_capacity(n);
        for m in 0..n {
            let d = dims[m];
            let mut ft = vec![0.0; b * rank * d];
            let mut pn = vec![0.0; d * b * rank];
            for (bi, x) in items.iter().enumerate() {
                let f = x.factor(m);
                for i in 0..d {
                    for p in 0..rank {
                        let v = f[(i, p)];
                        ft[bi * rank * d + p * d + i] = v;
                        pn[i * (b * rank) + bi * rank + p] = v;
                    }
                }
            }
            factors_t.push(ft);
            panel.push(pn);
        }
        Self { dims, rank, b, factors_t, panel }
    }

    /// Group size `B`.
    pub fn batch(&self) -> usize {
        self.b
    }

    /// Mode sizes of the group.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Shared CP rank of the group.
    pub fn rank(&self) -> usize {
        self.rank
    }

    fn ft_item(&self, m: usize, bi: usize) -> &[f64] {
        let sz = self.rank * self.dims[m];
        &self.factors_t[m][bi * sz..(bi + 1) * sz]
    }

    /// Contract the group against the rows of a **TT map** (the rows'
    /// [`TtDenseContraction`] contexts). Writes raw inner products
    /// `out[bi·rows.len() + r] = ⟨rowᵣ, x_bᵢ⟩`.
    ///
    /// Right-to-left chain per `(item, component)` with all `B·rank`
    /// chains folded into the leading GEMM rows — one GEMM per map row
    /// per mode for the entire group (the row's transposed core is shared
    /// across items).
    pub fn inner_tt_rows_into(
        &self,
        rows: &[TtDenseContraction],
        out: &mut [f64],
        pa: &mut Vec<f64>,
        pb: &mut Vec<f64>,
    ) {
        let n = self.dims.len();
        let b = self.b;
        let rank = self.rank;
        let kr = rows.len();
        assert!(out.len() >= b * kr, "output buffer size");
        for (ri, row) in rows.iter().enumerate() {
            assert_eq!(row.dims(), &self.dims[..], "map row shape mismatch");
            let rranks = row.ranks();
            pa.clear();
            pa.resize(b * rank, 1.0);
            for m in (0..n).rev() {
                let d = self.dims[m];
                let rl = rranks[m];
                let rr = rranks[m + 1];
                // U[(bi·rank + ρ), (i·rr + ar)] = F_bᵢ[i, ρ] · V[(bi·rank + ρ), ar].
                pb.clear();
                pb.resize(b * rank * d * rr, 0.0);
                for bi in 0..b {
                    let ft = self.ft_item(m, bi);
                    for p in 0..rank {
                        let chain = bi * rank + p;
                        let vrow = &pa[chain * rr..(chain + 1) * rr];
                        let urow = &mut pb[chain * d * rr..(chain + 1) * d * rr];
                        for i in 0..d {
                            let f = ft[p * d + i];
                            for (u, &v) in urow[i * rr..(i + 1) * rr].iter_mut().zip(vrow) {
                                *u = f * v;
                            }
                        }
                    }
                }
                // V' = U · core_t — one GEMM for the whole group.
                pa.clear();
                pa.resize(b * rank * rl, 0.0);
                matmul_into(pb, row.core_t(m), pa, b * rank, d * rr, rl);
            }
            for bi in 0..b {
                let mut acc = 0.0;
                for p in 0..rank {
                    acc += pa[bi * rank + p];
                }
                out[bi * kr + ri] = acc;
            }
        }
    }

    /// Contract the group against the rows of a **CP map** via per-mode
    /// Gram matrices: `⟨rowᵣ, x⟩ = Σ_{ρ,ρ'} Πₘ (AᵣᵐᵀFᵐ)[ρ, ρ']`.
    /// `rows_t[r][m]` is the row's factor transposed to `[rank_map, dₘ]`.
    /// Writes raw inner products `out[bi·rows_t.len() + r]`.
    ///
    /// One Gram GEMM per row per mode covers the whole group (the group
    /// panel stacks every item's factor columns side by side).
    pub fn gram_cp_rows_into(
        &self,
        rows_t: &[Vec<Vec<f64>>],
        rank_map: usize,
        out: &mut [f64],
        pa: &mut Vec<f64>,
        pb: &mut Vec<f64>,
    ) {
        let n = self.dims.len();
        let b = self.b;
        let rin = self.rank;
        let kr = rows_t.len();
        assert!(out.len() >= b * kr, "output buffer size");
        for (ri, row) in rows_t.iter().enumerate() {
            // Running Hadamard product of the per-mode Gram matrices,
            // [rank_map, B·rin].
            pa.clear();
            pa.resize(rank_map * b * rin, 1.0);
            for m in 0..n {
                let d = self.dims[m];
                debug_assert_eq!(row[m].len(), rank_map * d);
                pb.clear();
                pb.resize(rank_map * b * rin, 0.0);
                matmul_into(&row[m], &self.panel[m], pb, rank_map, d, b * rin);
                for (h, &g) in pa.iter_mut().zip(pb.iter()) {
                    *h *= g;
                }
            }
            for bi in 0..b {
                let mut acc = 0.0;
                for p in 0..rank_map {
                    let base = p * (b * rin) + bi * rin;
                    for q in 0..rin {
                        acc += pa[base + q];
                    }
                }
                out[bi * kr + ri] = acc;
            }
        }
    }

    /// Contract the group against a **TRP** map (`factors_t[t][m]` is
    /// term `t`'s factor pre-transposed to `[k, dₘ]`): each term is a
    /// rank-1 Gram chain. Writes raw sums over terms, `out[bi·k + col]`
    /// (unscaled).
    pub fn gram_trp_into(
        &self,
        factors_t: &[Vec<Vec<f64>>],
        k: usize,
        out: &mut [f64],
        pa: &mut Vec<f64>,
        pb: &mut Vec<f64>,
    ) {
        let n = self.dims.len();
        let b = self.b;
        let rin = self.rank;
        assert!(out.len() >= b * k, "output buffer size");
        for v in out[..b * k].iter_mut() {
            *v = 0.0;
        }
        for term in factors_t {
            // H[col, (bi·rin + ρ')] = Πₘ (Aᵐ[:, col]ᵀ Fᵐ_bᵢ[:, ρ']).
            pa.clear();
            pa.resize(k * b * rin, 1.0);
            for m in 0..n {
                let d = self.dims[m];
                debug_assert_eq!(term[m].len(), k * d);
                pb.clear();
                pb.resize(k * b * rin, 0.0);
                matmul_into(&term[m], &self.panel[m], pb, k, d, b * rin);
                for (h, &g) in pa.iter_mut().zip(pb.iter()) {
                    *h *= g;
                }
            }
            for bi in 0..b {
                for col in 0..k {
                    let base = col * (b * rin) + bi * rin;
                    let mut acc = 0.0;
                    for q in 0..rin {
                        acc += pa[base + q];
                    }
                    out[bi * k + col] += acc;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn tt_rows(dims: &[usize], rank: usize, k: usize, rng: &mut Rng) -> Vec<TtDenseContraction> {
        (0..k)
            .map(|_| TtDenseContraction::new(&TtTensor::random_projection_row(dims, rank, rng)))
            .collect()
    }

    #[test]
    fn tt_group_matches_tt_inner_and_is_batch_invariant() {
        let mut rng = Rng::seed_from(41);
        let dims = [3usize, 4, 2, 3];
        let rows_raw: Vec<TtTensor> = (0..5)
            .map(|_| TtTensor::random_projection_row(&dims, 3, &mut rng))
            .collect();
        let rows: Vec<TtDenseContraction> = rows_raw.iter().map(TtDenseContraction::new).collect();
        for b in [1usize, 3, 8] {
            let items: Vec<TtTensor> =
                (0..b).map(|_| TtTensor::random_unit(&dims, 2, &mut rng)).collect();
            let refs: Vec<&TtTensor> = items.iter().collect();
            let ctx = TtBatchContraction::new(&refs);
            let mut out = vec![0.0; b * rows.len()];
            let (mut pa, mut pb) = (Vec::new(), Vec::new());
            ctx.inner_tt_rows_into(&rows, &mut out, &mut pa, &mut pb);
            for (bi, x) in items.iter().enumerate() {
                for (r, row) in rows_raw.iter().enumerate() {
                    let want = row.inner(x);
                    let got = out[bi * rows.len() + r];
                    assert!(
                        (got - want).abs() < 1e-9 * want.abs().max(1.0),
                        "b={b} item {bi} row {r}: got {got} want {want}"
                    );
                }
                // Batch invariance: the group result is bit-identical to a
                // singleton-group run of the same item.
                let solo = TtBatchContraction::new(&[x]);
                let mut one = vec![0.0; rows.len()];
                solo.inner_tt_rows_into(&rows, &mut one, &mut pa, &mut pb);
                for r in 0..rows.len() {
                    assert_eq!(
                        out[bi * rows.len() + r].to_bits(),
                        one[r].to_bits(),
                        "b={b} item {bi} row {r} not bit-identical"
                    );
                }
            }
        }
    }

    #[test]
    fn tt_group_row_subsets_are_bit_identical() {
        // Sharding the map rows (project_tt_parallel) must not change any
        // value: each row's chain is independent inside the stacked GEMMs.
        let mut rng = Rng::seed_from(42);
        let dims = [3usize, 3, 3];
        let rows = tt_rows(&dims, 4, 6, &mut rng);
        let x = TtTensor::random_unit(&dims, 3, &mut rng);
        let ctx = TtBatchContraction::for_tt_map(&[&x]);
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        let mut full = vec![0.0; rows.len()];
        ctx.inner_tt_rows_into(&rows, &mut full, &mut pa, &mut pb);
        for chunk in [1usize, 2, 4] {
            let mut parts = Vec::new();
            for rows_chunk in rows.chunks(chunk) {
                let mut out = vec![0.0; rows_chunk.len()];
                ctx.inner_tt_rows_into(rows_chunk, &mut out, &mut pa, &mut pb);
                parts.extend(out);
            }
            for (a, b) in full.iter().zip(&parts) {
                assert_eq!(a.to_bits(), b.to_bits(), "chunk={chunk}");
            }
        }
    }

    #[test]
    fn cp_map_rows_over_tt_group_match_dense() {
        let mut rng = Rng::seed_from(43);
        let dims = [3usize, 2, 4];
        let cp_rows: Vec<CpTensor> = (0..4)
            .map(|_| CpTensor::random_projection_row(&dims, 3, &mut rng))
            .collect();
        let rows_t: Vec<Vec<Vec<f64>>> = cp_rows
            .iter()
            .map(|row| {
                (0..dims.len())
                    .map(|m| {
                        let f = row.factor(m);
                        let d = dims[m];
                        let mut t = vec![0.0; row.rank() * d];
                        for p in 0..row.rank() {
                            for i in 0..d {
                                t[p * d + i] = f[(i, p)];
                            }
                        }
                        t
                    })
                    .collect()
            })
            .collect();
        let items: Vec<TtTensor> =
            (0..3).map(|_| TtTensor::random_unit(&dims, 2, &mut rng)).collect();
        let refs: Vec<&TtTensor> = items.iter().collect();
        let ctx = TtBatchContraction::for_compressed_rows(&refs);
        let mut out = vec![0.0; items.len() * cp_rows.len()];
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        ctx.inner_cp_rows_into(&rows_t, 3, &mut out, &mut pa, &mut pb);
        for (bi, x) in items.iter().enumerate() {
            for (r, row) in cp_rows.iter().enumerate() {
                let want = row.inner_tt(x);
                let got = out[bi * cp_rows.len() + r];
                assert!(
                    (got - want).abs() < 1e-9 * want.abs().max(1.0),
                    "item {bi} row {r}: got {got} want {want}"
                );
            }
        }
    }

    #[test]
    fn cp_group_kernels_match_cp_inner() {
        let mut rng = Rng::seed_from(44);
        let dims = [3usize, 4, 2];
        let tt_map = tt_rows(&dims, 2, 3, &mut rng);
        let tt_raw: Vec<TtTensor> = tt_map.iter().map(|c| c.to_tt()).collect();
        let items: Vec<CpTensor> =
            (0..4).map(|_| CpTensor::random_unit(&dims, 3, &mut rng)).collect();
        let refs: Vec<&CpTensor> = items.iter().collect();
        let ctx = CpBatchContraction::new(&refs);
        assert_eq!(ctx.batch(), 4);
        assert_eq!(ctx.rank(), 3);
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        let mut out = vec![0.0; items.len() * tt_map.len()];
        ctx.inner_tt_rows_into(&tt_map, &mut out, &mut pa, &mut pb);
        for (bi, x) in items.iter().enumerate() {
            for (r, row) in tt_raw.iter().enumerate() {
                let want = x.inner_tt(row);
                let got = out[bi * tt_map.len() + r];
                assert!(
                    (got - want).abs() < 1e-9 * want.abs().max(1.0),
                    "item {bi} row {r}: got {got} want {want}"
                );
            }
        }
        // CP-map Gram kernel against CpTensor::inner.
        let cp_rows: Vec<CpTensor> = (0..3)
            .map(|_| CpTensor::random_projection_row(&dims, 2, &mut rng))
            .collect();
        let rows_t: Vec<Vec<Vec<f64>>> = cp_rows
            .iter()
            .map(|row| {
                (0..dims.len())
                    .map(|m| {
                        let f = row.factor(m);
                        let d = dims[m];
                        let mut t = vec![0.0; row.rank() * d];
                        for p in 0..row.rank() {
                            for i in 0..d {
                                t[p * d + i] = f[(i, p)];
                            }
                        }
                        t
                    })
                    .collect()
            })
            .collect();
        let mut out = vec![0.0; items.len() * cp_rows.len()];
        ctx.gram_cp_rows_into(&rows_t, 2, &mut out, &mut pa, &mut pb);
        for (bi, x) in items.iter().enumerate() {
            for (r, row) in cp_rows.iter().enumerate() {
                let want = row.inner(x);
                let got = out[bi * cp_rows.len() + r];
                assert!(
                    (got - want).abs() < 1e-9 * want.abs().max(1.0),
                    "gram item {bi} row {r}: got {got} want {want}"
                );
            }
        }
    }

    #[test]
    fn order_one_groups_work() {
        let mut rng = Rng::seed_from(45);
        let dims = [5usize];
        let rows = tt_rows(&dims, 2, 2, &mut rng);
        let items: Vec<TtTensor> =
            (0..2).map(|_| TtTensor::random_unit(&dims, 2, &mut rng)).collect();
        let refs: Vec<&TtTensor> = items.iter().collect();
        let ctx = TtBatchContraction::for_tt_map(&refs);
        let mut out = vec![0.0; 4];
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        ctx.inner_tt_rows_into(&rows, &mut out, &mut pa, &mut pb);
        for (bi, x) in items.iter().enumerate() {
            for (r, row) in rows.iter().enumerate() {
                let want = row.to_tt().inner(x);
                assert!((out[bi * 2 + r] - want).abs() < 1e-10);
            }
        }
    }
}
