//! Dense (fully materialized) tensors.

use super::Shape;
use crate::linalg::Matrix;
use crate::rng::Rng;

/// A dense `N`-th order tensor stored row-major (last mode fastest).
#[derive(Debug, Clone, PartialEq)]
pub struct DenseTensor {
    shape: Shape,
    data: Vec<f64>,
}

impl DenseTensor {
    /// Zero tensor of the given shape.
    pub fn zeros(dims: &[usize]) -> Self {
        let shape = Shape::new(dims);
        let n = shape.numel();
        Self { shape, data: vec![0.0; n] }
    }

    /// Build from a row-major buffer.
    pub fn from_vec(dims: &[usize], data: Vec<f64>) -> Self {
        let shape = Shape::new(dims);
        assert_eq!(data.len(), shape.numel(), "buffer size mismatch");
        Self { shape, data }
    }

    /// i.i.d. standard Gaussian entries.
    pub fn random(dims: &[usize], rng: &mut Rng) -> Self {
        let shape = Shape::new(dims);
        let data = rng.gaussian_vec(shape.numel(), 1.0);
        Self { shape, data }
    }

    /// Random Gaussian tensor normalized to unit Frobenius norm.
    pub fn random_unit(dims: &[usize], rng: &mut Rng) -> Self {
        let mut t = Self::random(dims, rng);
        let norm = t.fro_norm();
        if norm > 0.0 {
            t.scale(1.0 / norm);
        }
        t
    }

    /// Mode sizes.
    pub fn dims(&self) -> &[usize] {
        self.shape.dims()
    }

    /// Shape object.
    pub fn shape(&self) -> &Shape {
        &self.shape
    }

    /// Order `N`.
    pub fn order(&self) -> usize {
        self.shape.order()
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.shape.numel()
    }

    /// Row-major buffer.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable row-major buffer.
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Element access by multi-index.
    pub fn get(&self, idx: &[usize]) -> f64 {
        self.data[self.shape.linear(idx)]
    }

    /// Element assignment by multi-index.
    pub fn set(&mut self, idx: &[usize], v: f64) {
        let lin = self.shape.linear(idx);
        self.data[lin] = v;
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Inner product `⟨self, other⟩`.
    pub fn inner(&self, other: &DenseTensor) -> f64 {
        assert_eq!(self.dims(), other.dims(), "shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a * b)
            .sum()
    }

    /// Scale in place.
    pub fn scale(&mut self, s: f64) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// Elementwise difference `self − other`.
    pub fn sub(&self, other: &DenseTensor) -> DenseTensor {
        assert_eq!(self.dims(), other.dims());
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        DenseTensor { shape: self.shape.clone(), data }
    }

    /// Vectorization: the tensor's row-major buffer as a vector copy
    /// (`vec(S)` under this crate's fixed ordering convention).
    pub fn vectorize(&self) -> Vec<f64> {
        self.data.clone()
    }

    /// Mode-`n` matricization `S₍ₙ₎ ∈ R^{d_n × ∏_{m≠n} d_m}`.
    ///
    /// Row `i` holds the mode-`n` fiber slice `S[…, i_n = i, …]` with the
    /// remaining modes flattened row-major in their original order.
    pub fn matricize(&self, n: usize) -> Matrix {
        let dims = self.dims();
        assert!(n < dims.len());
        let (rows, cols) = self.shape.matricization_shape(n);
        let mut out = Matrix::zeros(rows, cols);
        // inner = product of dims after n; outer = product of dims before n.
        let inner: usize = dims[n + 1..].iter().product();
        let outer: usize = dims[..n].iter().product();
        let dn = dims[n];
        for o in 0..outer {
            for i in 0..dn {
                let src_base = (o * dn + i) * inner;
                let dst_base = o * inner;
                let dst_row = out.row_mut(i);
                dst_row[dst_base..dst_base + inner]
                    .copy_from_slice(&self.data[src_base..src_base + inner]);
            }
        }
        out
    }

    /// Matricization over the leading `split` modes:
    /// `S₍{1..split}₎ ∈ R^{(d₁…d_split) × (d_{split+1}…d_N)}`.
    ///
    /// Under row-major layout this is a pure reshape (no data movement).
    pub fn matricize_split(&self, split: usize) -> Matrix {
        let dims = self.dims();
        assert!(split >= 1 && split < dims.len());
        let rows: usize = dims[..split].iter().product();
        let cols: usize = dims[split..].iter().product();
        Matrix::from_vec(rows, cols, self.data.clone())
    }

    /// Reshape to new dims with identical element count (row-major).
    pub fn reshape(&self, dims: &[usize]) -> DenseTensor {
        let shape = Shape::new(dims);
        assert_eq!(shape.numel(), self.numel(), "reshape element count");
        DenseTensor { shape, data: self.data.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn iota(dims: &[usize]) -> DenseTensor {
        let n: usize = dims.iter().product();
        DenseTensor::from_vec(dims, (0..n).map(|x| x as f64).collect())
    }

    #[test]
    fn get_set_roundtrip() {
        let mut t = DenseTensor::zeros(&[2, 3, 4]);
        t.set(&[1, 2, 3], 42.0);
        assert_eq!(t.get(&[1, 2, 3]), 42.0);
        assert_eq!(t.get(&[0, 0, 0]), 0.0);
    }

    #[test]
    fn matricize_mode0_is_reshape() {
        let t = iota(&[2, 3]);
        let m = t.matricize(0);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.row(0), &[0.0, 1.0, 2.0]);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn matricize_last_mode_matches_fibers() {
        let t = iota(&[2, 3]);
        let m = t.matricize(1);
        // Mode-1 fibers of a 2x3: columns of the original matrix.
        assert_eq!(m.rows(), 3);
        assert_eq!(m.row(0), &[0.0, 3.0]);
        assert_eq!(m.row(1), &[1.0, 4.0]);
        assert_eq!(m.row(2), &[2.0, 5.0]);
    }

    #[test]
    fn matricization_preserves_norm() {
        let mut rng = Rng::seed_from(3);
        let t = DenseTensor::random(&[3, 4, 5], &mut rng);
        for n in 0..3 {
            assert!((t.matricize(n).fro_norm() - t.fro_norm()).abs() < 1e-10);
        }
        assert!((t.matricize_split(2).fro_norm() - t.fro_norm()).abs() < 1e-10);
    }

    #[test]
    fn matricize_middle_mode_entries() {
        let t = iota(&[2, 3, 2]);
        let m = t.matricize(1);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 4);
        // Check entry: S[o=1, i=2, inner=1] = element (1,2,1) = 1*6+2*2+1 = 11.
        // Row 2 (i=2), column o*inner+in = 1*2+1 = 3.
        assert_eq!(m[(2, 3)], 11.0);
    }

    #[test]
    fn inner_product_and_norm() {
        let mut rng = Rng::seed_from(4);
        let a = DenseTensor::random(&[4, 4], &mut rng);
        assert!((a.inner(&a) - a.fro_norm().powi(2)).abs() < 1e-10);
    }

    #[test]
    fn random_unit_has_unit_norm() {
        let mut rng = Rng::seed_from(5);
        let t = DenseTensor::random_unit(&[5, 5, 5], &mut rng);
        assert!((t.fro_norm() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = iota(&[2, 6]);
        let r = t.reshape(&[3, 4]);
        assert_eq!(r.data(), t.data());
        assert_eq!(r.dims(), &[3, 4]);
    }
}
