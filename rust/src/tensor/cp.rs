//! CP / CANDECOMP-PARAFAC format (Hitchcock 1927).
//!
//! A CP tensor `S = [[A¹,…,A^N]]` of rank `R` stores one factor matrix per
//! mode, `Aⁿ ∈ R^{dₙ × R}`, and is defined by
//! `S = Σ_r a¹_r ∘ a²_r ∘ … ∘ a^N_r`.

use super::{DenseTensor, TtTensor};
use crate::linalg::Matrix;
use crate::rng::{GaussianSource, Rng};

/// A tensor in CP format.
#[derive(Debug, Clone)]
pub struct CpTensor {
    dims: Vec<usize>,
    rank: usize,
    /// Factor `n` is `dims[n] × rank`, row-major.
    factors: Vec<Matrix>,
}

impl CpTensor {
    /// Build from explicit factor matrices.
    pub fn from_factors(factors: Vec<Matrix>) -> Self {
        assert!(!factors.is_empty());
        let rank = factors[0].cols();
        assert!(rank > 0, "CP rank must be positive");
        for f in &factors {
            assert_eq!(f.cols(), rank, "inconsistent CP rank across factors");
        }
        let dims = factors.iter().map(|f| f.rows()).collect();
        Self { dims, rank, factors }
    }

    /// Random CP tensor with i.i.d. `N(0,1)` factor entries (generic input
    /// generation — *not* the projection-row prescription).
    pub fn random(dims: &[usize], rank: usize, rng: &mut Rng) -> Self {
        let factors = dims
            .iter()
            .map(|&d| Matrix::from_vec(d, rank, rng.gaussian_vec(d * rank, 1.0)))
            .collect();
        Self::from_factors(factors)
    }

    /// Random CP tensor scaled to unit Frobenius norm.
    pub fn random_unit(dims: &[usize], rank: usize, rng: &mut Rng) -> Self {
        let mut t = Self::random(dims, rank, rng);
        let norm = t.fro_norm();
        if norm > 0.0 {
            t.scale(1.0 / norm);
        }
        t
    }

    /// Random CP tensor following **Definition 2** of the paper: all factor
    /// entries i.i.d. `N(0, (1/R)^{1/N})`. One draw is one *row* of the
    /// `f_CP(R)` map.
    pub fn random_projection_row(dims: &[usize], rank: usize, rng: &mut Rng) -> Self {
        let std = GaussianSource::cp_factor_std(dims.len(), rank);
        let factors = dims
            .iter()
            .map(|&d| Matrix::from_vec(d, rank, rng.gaussian_vec(d * rank, std)))
            .collect();
        Self::from_factors(factors)
    }

    /// Mode sizes.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// CP rank `R`.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Order `N`.
    pub fn order(&self) -> usize {
        self.dims.len()
    }

    /// Factor matrix for mode `n` (`dₙ × R`).
    pub fn factor(&self, n: usize) -> &Matrix {
        &self.factors[n]
    }

    /// Number of parameters (the paper's `O(NdR)` storage).
    pub fn num_params(&self) -> usize {
        self.factors.iter().map(|f| f.rows() * f.cols()).sum()
    }

    /// Scale by `s` (absorbed into the first factor).
    pub fn scale(&mut self, s: f64) {
        self.factors[0].scale(s);
    }

    /// Evaluate one entry.
    pub fn get(&self, idx: &[usize]) -> f64 {
        assert_eq!(idx.len(), self.dims.len());
        let mut acc = 0.0;
        for r in 0..self.rank {
            let mut prod = 1.0;
            for (n, &i) in idx.iter().enumerate() {
                prod *= self.factors[n][(i, r)];
            }
            acc += prod;
        }
        acc
    }

    /// Materialize as a dense tensor (small shapes only).
    pub fn to_dense(&self) -> DenseTensor {
        let numel: usize = self.dims.iter().product();
        assert!(
            numel <= (1 << 28),
            "refusing to densify a {numel}-element CP tensor"
        );
        // Progressive Khatri-Rao: M starts as A¹ (d₁ × R), then
        // M ← M ⊙_rows A ⁿ (rowwise Kronecker expansion), ending with the
        // (d₁…d_N) × R matrix whose row-sum over columns is vec(S).
        let mut m: Vec<f64> = self.factors[0].data().to_vec();
        let mut rows = self.dims[0];
        for n in 1..self.dims.len() {
            let d = self.dims[n];
            let f = &self.factors[n];
            let mut next = vec![0.0; rows * d * self.rank];
            for i in 0..rows {
                let mrow = &m[i * self.rank..(i + 1) * self.rank];
                for j in 0..d {
                    let frow = f.row(j);
                    let dst = &mut next[(i * d + j) * self.rank..(i * d + j + 1) * self.rank];
                    for r in 0..self.rank {
                        dst[r] = mrow[r] * frow[r];
                    }
                }
            }
            m = next;
            rows *= d;
        }
        let data: Vec<f64> = m.chunks(self.rank).map(|c| c.iter().sum()).collect();
        DenseTensor::from_vec(&self.dims, data)
    }

    /// Inner product with another CP tensor — `O(N·d·R·R̃)` via the
    /// Hadamard product of per-mode Gram matrices:
    /// `⟨S, T⟩ = Σ_{r,r'} Π_n (AⁿᵀBⁿ)[r,r']`.
    pub fn inner(&self, other: &CpTensor) -> f64 {
        assert_eq!(self.dims, other.dims, "shape mismatch");
        let ra = self.rank;
        let rb = other.rank;
        let mut h = vec![1.0f64; ra * rb];
        let mut g = vec![0.0f64; ra * rb];
        for n in 0..self.dims.len() {
            // G = AᵀB without materializing Aᵀ: rank-1 accumulation over
            // rows keeps both operands streaming contiguously (§Perf).
            g.fill(0.0);
            let fa = &self.factors[n];
            let fb = &other.factors[n];
            for i in 0..self.dims[n] {
                let arow = fa.row(i);
                let brow = fb.row(i);
                for (r, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let dst = &mut g[r * rb..(r + 1) * rb];
                    for (dv, &bv) in dst.iter_mut().zip(brow) {
                        *dv += av * bv;
                    }
                }
            }
            for (hv, gv) in h.iter_mut().zip(&g) {
                *hv *= gv;
            }
        }
        h.iter().sum()
    }

    /// Inner product with a TT tensor — `O(R̃·N·d·R²)`: each rank-one CP
    /// component contracts through the TT chain as a sequence of
    /// matrix-vector products.
    pub fn inner_tt(&self, tt: &TtTensor) -> f64 {
        assert_eq!(self.dims(), tt.dims(), "shape mismatch");
        let n_modes = self.dims.len();
        let mut total = 0.0;
        let mut v: Vec<f64> = Vec::new();
        let mut next: Vec<f64> = Vec::new();
        for r in 0..self.rank {
            // v ← Σ_i a¹_r[i] · G¹[:, i, :]  (1 × r₁ row vector)
            v.clear();
            v.resize(tt.ranks()[1], 0.0);
            let f0 = &self.factors[0];
            let core0 = tt.core(0);
            let r1 = tt.ranks()[1];
            for i in 0..self.dims[0] {
                let a = f0[(i, r)];
                if a == 0.0 {
                    continue;
                }
                for b in 0..r1 {
                    v[b] += a * core0[i * r1 + b];
                }
            }
            // Chain through the remaining cores.
            for n in 1..n_modes {
                let rl = tt.ranks()[n];
                let rr = tt.ranks()[n + 1];
                let d = self.dims[n];
                let core = tt.core(n);
                let f = &self.factors[n];
                next.clear();
                next.resize(rr, 0.0);
                for a in 0..rl {
                    let va = v[a];
                    if va == 0.0 {
                        continue;
                    }
                    for i in 0..d {
                        let coef = va * f[(i, r)];
                        if coef == 0.0 {
                            continue;
                        }
                        let base = (a * d + i) * rr;
                        for b in 0..rr {
                            next[b] += coef * core[base + b];
                        }
                    }
                }
                std::mem::swap(&mut v, &mut next);
            }
            debug_assert_eq!(v.len(), 1);
            total += v[0];
        }
        total
    }

    /// Frobenius norm in CP format.
    pub fn fro_norm(&self) -> f64 {
        self.inner(self).max(0.0).sqrt()
    }

    /// Exact conversion to TT format with all internal ranks equal to `R`:
    /// the standard construction with "diagonal" interior cores
    /// `Gⁿ[r, i, r'] = δ_{rr'} Aⁿ[i, r]`.
    pub fn to_tt(&self) -> TtTensor {
        let n = self.dims.len();
        if n == 1 {
            // Order-1: the tensor is just the row-sum of the factor.
            let d = self.dims[0];
            let mut core = vec![0.0; d];
            for i in 0..d {
                for r in 0..self.rank {
                    core[i] += self.factors[0][(i, r)];
                }
            }
            return TtTensor::from_cores(&self.dims, &[1, 1], vec![core]);
        }
        let r = self.rank;
        let mut ranks = vec![r; n + 1];
        ranks[0] = 1;
        ranks[n] = 1;
        let mut cores = Vec::with_capacity(n);
        // First core: [1, d₁, R] = A¹.
        cores.push(self.factors[0].data().to_vec());
        // Interior cores: [R, dₙ, R] diagonal in (r, r').
        for m in 1..n - 1 {
            let d = self.dims[m];
            let f = &self.factors[m];
            let mut core = vec![0.0; r * d * r];
            for rr in 0..r {
                for i in 0..d {
                    core[(rr * d + i) * r + rr] = f[(i, rr)];
                }
            }
            cores.push(core);
        }
        // Last core: [R, d_N, 1] = A^Nᵀ laid out as (r, i).
        let f = &self.factors[n - 1];
        let d = self.dims[n - 1];
        let mut core = vec![0.0; r * d];
        for rr in 0..r {
            for i in 0..d {
                core[rr * d + i] = f[(i, rr)];
            }
        }
        cores.push(core);
        TtTensor::from_cores(&self.dims, &ranks, cores)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_err;
    use crate::tensor::Shape;

    #[test]
    fn get_matches_dense() {
        let mut rng = Rng::seed_from(1);
        let t = CpTensor::random(&[3, 4, 2], 3, &mut rng);
        let d = t.to_dense();
        for idx in Shape::new(t.dims()).iter_indices() {
            assert!((t.get(&idx) - d.get(&idx)).abs() < 1e-10);
        }
    }

    #[test]
    fn inner_matches_dense() {
        let mut rng = Rng::seed_from(2);
        let a = CpTensor::random(&[3, 4, 2], 3, &mut rng);
        let b = CpTensor::random(&[3, 4, 2], 5, &mut rng);
        let exact = a.to_dense().inner(&b.to_dense());
        let fast = a.inner(&b);
        assert!((exact - fast).abs() < 1e-9 * exact.abs().max(1.0));
    }

    #[test]
    fn inner_tt_matches_dense() {
        let mut rng = Rng::seed_from(3);
        let a = CpTensor::random(&[3, 2, 4, 2], 4, &mut rng);
        let b = TtTensor::random(&[3, 2, 4, 2], 3, &mut rng);
        let exact = a.to_dense().inner(&b.to_dense());
        let fast = a.inner_tt(&b);
        assert!(
            (exact - fast).abs() < 1e-9 * exact.abs().max(1.0),
            "exact={exact} fast={fast}"
        );
    }

    #[test]
    fn norm_matches_dense() {
        let mut rng = Rng::seed_from(4);
        let t = CpTensor::random(&[4, 3, 4], 6, &mut rng);
        assert!((t.fro_norm() - t.to_dense().fro_norm()).abs() < 1e-9);
    }

    #[test]
    fn to_tt_is_exact() {
        let mut rng = Rng::seed_from(5);
        let cp = CpTensor::random(&[3, 4, 2, 3], 4, &mut rng);
        let tt = cp.to_tt();
        assert!(rel_err(tt.to_dense().data(), cp.to_dense().data()) < 1e-12);
        assert_eq!(tt.ranks(), &[1, 4, 4, 4, 1]);
    }

    #[test]
    fn to_tt_order_two() {
        let mut rng = Rng::seed_from(6);
        let cp = CpTensor::random(&[5, 7], 3, &mut rng);
        let tt = cp.to_tt();
        assert!(rel_err(tt.to_dense().data(), cp.to_dense().data()) < 1e-12);
    }

    #[test]
    fn projection_row_variance_follows_definition_2() {
        let mut rng = Rng::seed_from(7);
        let n_modes = 3;
        let r = 8;
        let mut sum = 0.0;
        let mut count = 0usize;
        for _ in 0..100 {
            let t = CpTensor::random_projection_row(&[5; 3], r, &mut rng);
            for n in 0..n_modes {
                for &x in t.factor(n).data() {
                    sum += x * x;
                }
                count += t.factor(n).data().len();
            }
        }
        let var = sum / count as f64;
        let expect = (1.0f64 / r as f64).powf(1.0 / n_modes as f64);
        assert!((var - expect).abs() < 0.02 * expect, "var={var} expect={expect}");
    }

    #[test]
    fn random_unit_norm() {
        let mut rng = Rng::seed_from(8);
        let t = CpTensor::random_unit(&[3; 6], 5, &mut rng);
        assert!((t.fro_norm() - 1.0).abs() < 1e-10);
    }

    #[test]
    fn num_params_matches_formula() {
        let mut rng = Rng::seed_from(9);
        let t = CpTensor::random(&[5; 6], 3, &mut rng);
        // Paper: NdR parameters.
        assert_eq!(t.num_params(), 6 * 5 * 3);
    }

    #[test]
    fn rank_one_is_outer_product() {
        let a = Matrix::from_rows(&[&[1.0], &[2.0]]);
        let b = Matrix::from_rows(&[&[3.0], &[4.0], &[5.0]]);
        let t = CpTensor::from_factors(vec![a, b]);
        let d = t.to_dense();
        assert_eq!(d.get(&[1, 2]), 10.0);
        assert_eq!(d.get(&[0, 0]), 3.0);
    }
}
