//! Tucker decomposition (Tucker 1966) — implemented as the *contrast*
//! format: the paper's introduction singles out Tucker because its core
//! has `R^N` parameters, so Tucker-based sketches (Shi & Anandkumar 2019)
//! "cannot scale to very high-order tensors" while TT/CP grow linearly
//! in `N`. The [`tests::tucker_parameter_growth_is_exponential`] test
//! pins that claim down numerically.
//!
//! `S = C ×₁ U¹ ×₂ U² … ×_N U^N` with core `C ∈ R^{R×…×R}` and factor
//! matrices `Uⁿ ∈ R^{dₙ×R}`.

use super::{DenseTensor, Shape};
use crate::linalg::{matmul, svd, Matrix};
use crate::rng::Rng;

/// A tensor in Tucker format.
#[derive(Debug, Clone)]
pub struct TuckerTensor {
    dims: Vec<usize>,
    rank: usize,
    /// Core tensor, shape `[rank; N]` row-major.
    core: Vec<f64>,
    /// Factor `n` is `dims[n] × rank`.
    factors: Vec<Matrix>,
}

impl TuckerTensor {
    /// Build from explicit core + factors.
    pub fn from_parts(dims: &[usize], rank: usize, core: Vec<f64>, factors: Vec<Matrix>) -> Self {
        assert_eq!(factors.len(), dims.len());
        assert_eq!(core.len(), rank.pow(dims.len() as u32), "core size");
        for (f, &d) in factors.iter().zip(dims) {
            assert_eq!((f.rows(), f.cols()), (d, rank), "factor shape");
        }
        Self { dims: dims.to_vec(), rank, core, factors }
    }

    /// Random Tucker tensor with i.i.d. standard Gaussian core/factors.
    pub fn random(dims: &[usize], rank: usize, rng: &mut Rng) -> Self {
        let n = dims.len();
        let core = rng.gaussian_vec(rank.pow(n as u32), 1.0);
        let factors = dims
            .iter()
            .map(|&d| Matrix::from_vec(d, rank, rng.gaussian_vec(d * rank, 1.0)))
            .collect();
        Self::from_parts(dims, rank, core, factors)
    }

    /// Mode sizes.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Multilinear rank (uniform).
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Number of parameters — `R^N + Σ dₙR` (the exponential core is the
    /// point of this type's existence; compare `TtTensor::num_params`).
    pub fn num_params(&self) -> usize {
        self.core.len() + self.factors.iter().map(|f| f.rows() * f.cols()).sum::<usize>()
    }

    /// Materialize as a dense tensor by successive mode products.
    pub fn to_dense(&self) -> DenseTensor {
        let n = self.dims.len();
        // Current tensor flattened as [done-modes…, remaining core modes],
        // starting with the raw core.
        let mut data = self.core.clone();
        let mut lead = 1usize; // product of already-expanded mode sizes
        for m in 0..n {
            // data is [lead, rank (mode m), rank^{n-m-1}] — expand mode m:
            // out[lead, d_m, tail] = Σ_r U[i, r]·data[lead, r, tail].
            let tail = data.len() / (lead * self.rank);
            let d = self.dims[m];
            let f = &self.factors[m];
            let mut out = vec![0.0; lead * d * tail];
            for l in 0..lead {
                // slice [rank, tail] × Uᵀ → use gemm: U [d, rank] × block.
                let block = &data[l * self.rank * tail..(l + 1) * self.rank * tail];
                let prod = matmul(f.data(), block, d, self.rank, tail);
                out[l * d * tail..(l + 1) * d * tail].copy_from_slice(&prod);
            }
            data = out;
            lead *= d;
        }
        DenseTensor::from_vec(&self.dims, data)
    }

    /// Higher-order SVD (HOSVD): Tucker approximation of a dense tensor
    /// with uniform multilinear rank ≤ `rank`.
    pub fn hosvd(x: &DenseTensor, rank: usize) -> TuckerTensor {
        let n = x.order();
        let rank = rank.min(*x.dims().iter().min().unwrap());
        // Factors: leading left singular vectors of each matricization.
        let factors: Vec<Matrix> = (0..n)
            .map(|m| {
                let mat = x.matricize(m);
                let dec = svd(&mat);
                dec.u.leading_cols(rank.min(dec.u.cols()))
            })
            .collect();
        // Core: C = X ×₁ U¹ᵀ … ×_N U^Nᵀ — same expansion loop with Uᵀ.
        let mut data = x.data().to_vec();
        let mut lead = 1usize;
        let mut cur_dims: Vec<usize> = x.dims().to_vec();
        for m in 0..n {
            let d = cur_dims[m];
            let tail = data.len() / (lead * d);
            let f_t = factors[m].transpose(); // rank × d
            let mut out = vec![0.0; lead * rank * tail];
            for l in 0..lead {
                let block = &data[l * d * tail..(l + 1) * d * tail];
                let prod = matmul(f_t.data(), block, rank, d, tail);
                out[l * rank * tail..(l + 1) * rank * tail].copy_from_slice(&prod);
            }
            data = out;
            cur_dims[m] = rank;
            lead *= rank;
        }
        TuckerTensor::from_parts(x.dims(), rank, data, factors)
    }

    /// Frobenius norm (via the orthonormal-factor invariant when factors
    /// come from HOSVD; in general via densification for small shapes).
    pub fn fro_norm(&self) -> f64 {
        let numel = Shape::new(&self.dims).numel();
        assert!(numel <= (1 << 26), "fro_norm: tensor too large to densify");
        self.to_dense().fro_norm()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_err;
    use crate::tensor::TtTensor;

    #[test]
    fn to_dense_matches_explicit_sum() {
        let mut rng = Rng::seed_from(1);
        let t = TuckerTensor::random(&[3, 4, 2], 2, &mut rng);
        let d = t.to_dense();
        // Explicit: S[i,j,k] = Σ_{a,b,c} C[a,b,c]·U¹[i,a]·U²[j,b]·U³[k,c].
        let r = t.rank();
        for idx in Shape::new(t.dims()).iter_indices() {
            let mut want = 0.0;
            for a in 0..r {
                for b in 0..r {
                    for c in 0..r {
                        want += t.core[(a * r + b) * r + c]
                            * t.factors[0][(idx[0], a)]
                            * t.factors[1][(idx[1], b)]
                            * t.factors[2][(idx[2], c)];
                    }
                }
            }
            assert!((d.get(&idx) - want).abs() < 1e-9);
        }
    }

    #[test]
    fn hosvd_reconstructs_exactly_at_full_rank() {
        let mut rng = Rng::seed_from(2);
        let src = TuckerTensor::random(&[3, 3, 3], 2, &mut rng);
        let dense = src.to_dense();
        let rec = TuckerTensor::hosvd(&dense, 3);
        assert!(rel_err(rec.to_dense().data(), dense.data()) < 1e-9);
        // And rank-2 HOSVD of a rank-2 tensor is exact too.
        let rec2 = TuckerTensor::hosvd(&dense, 2);
        assert!(rel_err(rec2.to_dense().data(), dense.data()) < 1e-8);
    }

    #[test]
    fn hosvd_truncation_degrades_gracefully() {
        let mut rng = Rng::seed_from(3);
        let dense = DenseTensor::random(&[4, 4, 4], &mut rng);
        let full = TuckerTensor::hosvd(&dense, 4);
        let trunc = TuckerTensor::hosvd(&dense, 2);
        // Normalize by the ORIGINAL tensor (first argument of rel_err).
        let err_full = rel_err(dense.data(), full.to_dense().data());
        let err_trunc = rel_err(dense.data(), trunc.to_dense().data());
        assert!(err_full < 1e-9);
        assert!(err_trunc > err_full);
        // HOSVD is an orthogonal projection: error strictly below 100%.
        assert!(err_trunc < 1.0, "err_trunc={err_trunc}");
    }

    /// The paper's introduction claim: TT/CP parameters grow linearly in
    /// N while Tucker's grow exponentially — the reason Tucker-based RP
    /// (Shi & Anandkumar 2019) cannot reach the high-order regime.
    #[test]
    fn tucker_parameter_growth_is_exponential() {
        let mut rng = Rng::seed_from(4);
        let r = 3;
        let mut prev_ratio = 0.0;
        for n in [4usize, 8, 12] {
            let dims = vec![3usize; n];
            let tucker = TuckerTensor::random(&dims, r, &mut rng);
            let tt = TtTensor::random(&dims, r, &mut rng);
            let ratio = tucker.num_params() as f64 / tt.num_params() as f64;
            assert!(ratio > prev_ratio, "ratio must grow with N");
            prev_ratio = ratio;
        }
        // At N=12 the gap is already ~4 orders of magnitude.
        assert!(prev_ratio > 1e3, "ratio at N=12: {prev_ratio}");
    }
}
