//! Tensor-train (TT) format (Oseledets 2011).
//!
//! A TT tensor `S = ⟨⟨G¹,…,G^N⟩⟩` stores one 3rd-order core per mode,
//! `Gⁿ ∈ R^{rₙ₋₁ × dₙ × rₙ}` with boundary ranks `r₀ = r_N = 1`, and is
//! defined entrywise by `S[i₁,…,i_N] = G¹[:,i₁,:]·…·G^N[:,i_N,:]`.
//!
//! This module implements everything the projection layer and experiments
//! need: random generation (both generic and with the paper's Definition 1
//! variance prescription), evaluation, densification, the `O(Ndr³)` TT×TT
//! inner product, norms, TT-SVD of dense tensors and TT-rounding.

use super::{CpTensor, DenseTensor, Shape};
use crate::linalg::{matmul, svd, Matrix};
use crate::rng::{GaussianSource, Rng};

/// A tensor in TT format.
#[derive(Debug, Clone)]
pub struct TtTensor {
    dims: Vec<usize>,
    /// Rank vector of length `N+1`; `ranks[0] = ranks[N] = 1`.
    ranks: Vec<usize>,
    /// Core `n` stored row-major with shape `[ranks[n], dims[n], ranks[n+1]]`.
    cores: Vec<Vec<f64>>,
}

impl TtTensor {
    /// Build from explicit cores. Panics if shapes are inconsistent.
    pub fn from_cores(dims: &[usize], ranks: &[usize], cores: Vec<Vec<f64>>) -> Self {
        let n = dims.len();
        assert_eq!(ranks.len(), n + 1, "rank vector length");
        assert_eq!(ranks[0], 1, "left boundary rank");
        assert_eq!(ranks[n], 1, "right boundary rank");
        assert_eq!(cores.len(), n, "core count");
        for (k, core) in cores.iter().enumerate() {
            assert_eq!(
                core.len(),
                ranks[k] * dims[k] * ranks[k + 1],
                "core {k} size"
            );
        }
        Self { dims: dims.to_vec(), ranks: ranks.to_vec(), cores }
    }

    /// Uniform internal rank vector `[1, r, r, …, r, 1]` clipped to the
    /// maximal attainable TT ranks for the given dims.
    pub fn uniform_ranks(dims: &[usize], r: usize) -> Vec<usize> {
        let n = dims.len();
        let mut ranks = vec![1usize; n + 1];
        for k in 1..n {
            // Max rank at cut k is min(prod(dims[..k]), prod(dims[k..])),
            // computed with saturation to avoid overflow for high orders.
            let left: usize = dims[..k]
                .iter()
                .fold(1usize, |a, &d| a.saturating_mul(d))
                .min(1 << 40);
            let right: usize = dims[k..]
                .iter()
                .fold(1usize, |a, &d| a.saturating_mul(d))
                .min(1 << 40);
            ranks[k] = r.min(left).min(right);
        }
        ranks
    }

    /// Prescribed (unclipped) rank vector `[1, r, …, r, 1]` — the shape
    /// Definition 1 and TT-Toolbox's `tt_rand` use, even when `r` exceeds
    /// the maximal attainable rank at a cut (the parameterization is then
    /// merely redundant, which the paper's analysis allows).
    pub fn prescribed_ranks(dims: &[usize], r: usize) -> Vec<usize> {
        let n = dims.len();
        let mut ranks = vec![r; n + 1];
        ranks[0] = 1;
        ranks[n] = 1;
        ranks
    }

    /// Random TT tensor with i.i.d. `N(0,1)` core entries (generic input
    /// generation — *not* the projection-row prescription).
    pub fn random(dims: &[usize], rank: usize, rng: &mut Rng) -> Self {
        let ranks = Self::prescribed_ranks(dims, rank);
        let cores = (0..dims.len())
            .map(|k| rng.gaussian_vec(ranks[k] * dims[k] * ranks[k + 1], 1.0))
            .collect();
        Self::from_cores(dims, &ranks, cores)
    }

    /// Random TT tensor scaled to unit Frobenius norm (the input
    /// distribution of the paper's §6 experiments, with `rank = R̃ = 10`).
    pub fn random_unit(dims: &[usize], rank: usize, rng: &mut Rng) -> Self {
        let mut t = Self::random(dims, rank, rng);
        let norm = t.fro_norm();
        if norm > 0.0 {
            t.scale(1.0 / norm);
        }
        t
    }

    /// Random TT tensor following **Definition 1** of the paper: core
    /// entries are `N(0, 1/√R)` for boundary cores and `N(0, 1/R)` for
    /// interior cores. One such draw is one *row* of the `f_TT(R)` map.
    pub fn random_projection_row(dims: &[usize], rank: usize, rng: &mut Rng) -> Self {
        let n = dims.len();
        let ranks = Self::prescribed_ranks(dims, rank);
        let cores = (0..n)
            .map(|k| {
                let std = GaussianSource::tt_core_std(k, n, rank);
                rng.gaussian_vec(ranks[k] * dims[k] * ranks[k + 1], std)
            })
            .collect();
        Self::from_cores(dims, &ranks, cores)
    }

    /// Mode sizes.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Rank vector (length `N+1`).
    pub fn ranks(&self) -> &[usize] {
        &self.ranks
    }

    /// Maximal internal rank.
    pub fn max_rank(&self) -> usize {
        self.ranks.iter().copied().max().unwrap_or(1)
    }

    /// Order `N`.
    pub fn order(&self) -> usize {
        self.dims.len()
    }

    /// Core `n` as a flat row-major `[r_n, d_n, r_{n+1}]` buffer.
    pub fn core(&self, n: usize) -> &[f64] {
        &self.cores[n]
    }

    /// Mutable core buffer.
    pub fn core_mut(&mut self, n: usize) -> &mut Vec<f64> {
        &mut self.cores[n]
    }

    /// Number of parameters (the paper's `O(NdR²)` storage).
    pub fn num_params(&self) -> usize {
        self.cores.iter().map(|c| c.len()).sum()
    }

    /// Scale the tensor by `s` (absorbed into the first core).
    pub fn scale(&mut self, s: f64) {
        for x in &mut self.cores[0] {
            *x *= s;
        }
    }

    /// Evaluate a single entry `S[idx]` by the chain of matrix products.
    pub fn get(&self, idx: &[usize]) -> f64 {
        let mut v = Vec::new();
        let mut buf = Vec::new();
        self.get_with(idx, &mut v, &mut buf)
    }

    /// Allocation-free entry evaluation with caller-provided scratch —
    /// the hot path of sparse projections over TT inputs (§Perf).
    pub fn get_with(&self, idx: &[usize], v: &mut Vec<f64>, buf: &mut Vec<f64>) -> f64 {
        assert_eq!(idx.len(), self.dims.len());
        // v starts as the i₁-th row of G¹ (1 × r₁), then v ← v · Gⁿ[:,iₙ,:].
        v.clear();
        v.extend_from_slice(self.core_slice(0, idx[0]));
        for n in 1..self.dims.len() {
            let rl = self.ranks[n];
            let rr = self.ranks[n + 1];
            buf.clear();
            buf.resize(rr, 0.0);
            let core = &self.cores[n];
            let d = self.dims[n];
            let i = idx[n];
            for a in 0..rl {
                let va = v[a];
                if va == 0.0 {
                    continue;
                }
                let base = (a * d + i) * rr;
                for b in 0..rr {
                    buf[b] += va * core[base + b];
                }
            }
            std::mem::swap(v, buf);
        }
        debug_assert_eq!(v.len(), 1);
        v[0]
    }

    /// The slice `Gⁿ[:, i, :]` is not contiguous; this returns the
    /// contiguous row `G¹[0, i, :]` of the first core only.
    fn core_slice(&self, n: usize, i: usize) -> &[f64] {
        debug_assert_eq!(n, 0);
        let rr = self.ranks[1];
        &self.cores[0][i * rr..(i + 1) * rr]
    }

    /// Materialize the full tensor (guard: panics above `max_numel`
    /// elements to catch accidental densification of huge tensors).
    pub fn to_dense(&self) -> DenseTensor {
        let shape = Shape::new(&self.dims);
        let numel = shape.numel();
        assert!(
            numel <= (1 << 28),
            "refusing to densify a {numel}-element TT tensor"
        );
        // Sequential unfolding: T ∈ R^{(d₁…dₙ) × rₙ}, absorbed core by core.
        let mut t: Vec<f64> = self.cores[0].clone(); // (d₁) × r₁ row-major
        let mut rows = self.dims[0];
        for n in 1..self.dims.len() {
            let rl = self.ranks[n];
            let d = self.dims[n];
            let rr = self.ranks[n + 1];
            // T_next[(rows*d), rr] = T[rows, rl] · core[rl, d*rr]
            let next = matmul(&t, &self.cores[n], rows, rl, d * rr);
            t = next;
            rows *= d;
        }
        DenseTensor::from_vec(&self.dims, t)
    }

    /// Inner product `⟨self, other⟩` in TT format — `O(N·d·r³)`, the
    /// complexity the paper states for projecting TT inputs.
    pub fn inner(&self, other: &TtTensor) -> f64 {
        assert_eq!(self.dims, other.dims, "shape mismatch");
        // M ∈ R^{ra × rb} carries the partial contraction; starts 1×1 = [1].
        let mut m: Vec<f64> = vec![1.0];
        let mut ra = 1usize;
        let mut rb = 1usize;
        for n in 0..self.dims.len() {
            let d = self.dims[n];
            let ra2 = self.ranks[n + 1];
            let rb2 = other.ranks[n + 1];
            m = tt_inner_step(&m, &self.cores[n], &other.cores[n], ra, rb, d, ra2, rb2);
            ra = ra2;
            rb = rb2;
        }
        debug_assert_eq!(m.len(), 1);
        m[0]
    }

    /// Frobenius norm, computed in TT format.
    pub fn fro_norm(&self) -> f64 {
        self.inner(self).max(0.0).sqrt()
    }

    /// TT-SVD: decompose a dense tensor into TT format with relative
    /// Frobenius error ≤ `eps` and ranks capped at `max_rank`
    /// (Oseledets 2011, Algorithm 1).
    pub fn tt_svd(x: &DenseTensor, eps: f64, max_rank: usize) -> TtTensor {
        let dims = x.dims().to_vec();
        let n = dims.len();
        // Per-step tolerance so the accumulated error stays ≤ eps‖X‖.
        let step_eps = if n > 1 {
            eps / ((n - 1) as f64).sqrt()
        } else {
            eps
        };
        let mut cores: Vec<Vec<f64>> = Vec::with_capacity(n);
        let mut ranks = vec![1usize; n + 1];
        // C holds the remainder, shaped (r_{k} * d_k) × (d_{k+1}…d_N).
        let mut c = x.data().to_vec();
        let mut c_rows = dims[0];
        let mut c_cols = x.numel() / dims[0];
        for k in 0..n - 1 {
            let mat = Matrix::from_vec(c_rows, c_cols, c.clone());
            let dec = svd(&mat);
            let mut r = dec.rank_for_tolerance(step_eps).max(1);
            r = r.min(max_rank).max(1);
            let trunc = dec.truncate(r);
            // Core k: U reshaped to [r_{k}, d_k, r].
            cores.push(trunc.u.data().to_vec());
            ranks[k + 1] = r;
            // Remainder: diag(s)·Vᵀ, reshaped for the next step.
            let mut sv = trunc.v.transpose(); // r × c_cols
            for i in 0..r {
                let srow = trunc.s[i];
                for x in sv.row_mut(i) {
                    *x *= srow;
                }
            }
            c = sv.into_vec();
            if k + 1 < n - 1 {
                c_rows = r * dims[k + 1];
                c_cols /= dims[k + 1];
            } else {
                c_rows = r;
                c_cols = dims[n - 1];
            }
        }
        // Last core: the remainder itself, [r_{N-1}, d_N, 1].
        cores.push(c);
        TtTensor::from_cores(&dims, &ranks, cores)
    }

    /// TT-rounding: recompress to relative error ≤ `eps`, ranks ≤
    /// `max_rank` (Oseledets 2011, Algorithm 2 — right-to-left QR sweep
    /// followed by a left-to-right truncated-SVD sweep).
    pub fn round(&self, eps: f64, max_rank: usize) -> TtTensor {
        let n = self.order();
        if n == 1 {
            return self.clone();
        }
        let dims = self.dims.clone();
        let mut cores = self.cores.clone();
        let mut ranks = self.ranks.clone();

        // Right-to-left orthogonalization: make cores 2..N right-orthogonal.
        for k in (1..n).rev() {
            let rl = ranks[k];
            let d = dims[k];
            let rr = ranks[k + 1];
            // Row-major view [rl, d*rr]; we need QR of its transpose.
            let mat = Matrix::from_vec(rl, d * rr, cores[k].clone());
            let (q, r) = crate::linalg::qr(&mat.transpose()); // (d*rr) × p, p × rl
            let p = q.cols();
            // New core k: Qᵀ reshaped [p, d, rr].
            cores[k] = q.transpose().into_vec();
            // Absorb Rᵀ (rl × p) into core k-1: [r_{k-1}, d_{k-1}, rl]·(rl×p).
            let rlm = ranks[k - 1];
            let dm = dims[k - 1];
            let absorbed = matmul(&cores[k - 1], r.transpose().data(), rlm * dm, rl, p);
            cores[k - 1] = absorbed;
            ranks[k] = p;
        }

        // Left-to-right truncation sweep.
        let step_eps = eps / ((n - 1) as f64).sqrt();
        let norm = {
            let probe = TtTensor::from_cores(&dims, &ranks, cores.clone());
            probe.fro_norm()
        };
        let abs_tol = step_eps * norm;
        for k in 0..n - 1 {
            let rl = ranks[k];
            let d = dims[k];
            let rr = ranks[k + 1];
            let mat = Matrix::from_vec(rl * d, rr, cores[k].clone());
            let dec = svd(&mat);
            // Rank for absolute tolerance abs_tol.
            let mut r = dec.s.len();
            let mut tail = 0.0;
            while r > 1 {
                let add = dec.s[r - 1] * dec.s[r - 1];
                if (tail + add).sqrt() > abs_tol {
                    break;
                }
                tail += add;
                r -= 1;
            }
            r = r.min(max_rank).max(1);
            let trunc = dec.truncate(r);
            cores[k] = trunc.u.data().to_vec();
            // Carry diag(s)Vᵀ into the next core.
            let mut sv = trunc.v.transpose();
            for i in 0..r {
                let s = trunc.s[i];
                for x in sv.row_mut(i) {
                    *x *= s;
                }
            }
            let next = matmul(sv.data(), &cores[k + 1], r, rr, dims[k + 1] * ranks[k + 2]);
            cores[k + 1] = next;
            ranks[k + 1] = r;
        }
        TtTensor::from_cores(&dims, &ranks, cores)
    }

    /// Convert to CP is not generally possible; but any CP tensor converts
    /// to TT — see [`CpTensor::to_tt`].
    pub fn from_cp(cp: &CpTensor) -> TtTensor {
        cp.to_tt()
    }

    /// TT addition: `self + other` with the standard block construction —
    /// boundary cores concatenate along the free rank, interior cores
    /// form a block-diagonal. Ranks add; use [`TtTensor::round`] to
    /// recompress afterwards.
    pub fn add(&self, other: &TtTensor) -> TtTensor {
        assert_eq!(self.dims, other.dims, "shape mismatch");
        let n = self.order();
        if n == 1 {
            let core: Vec<f64> = self.cores[0]
                .iter()
                .zip(&other.cores[0])
                .map(|(a, b)| a + b)
                .collect();
            return TtTensor::from_cores(&self.dims, &[1, 1], vec![core]);
        }
        let mut ranks = vec![0usize; n + 1];
        ranks[0] = 1;
        ranks[n] = 1;
        for k in 1..n {
            ranks[k] = self.ranks[k] + other.ranks[k];
        }
        let mut cores = Vec::with_capacity(n);
        for m in 0..n {
            let d = self.dims[m];
            let (al, ar) = (self.ranks[m], self.ranks[m + 1]);
            let (bl, br) = (other.ranks[m], other.ranks[m + 1]);
            let (rl, rr) = (ranks[m], ranks[m + 1]);
            let mut core = vec![0.0; rl * d * rr];
            let a = &self.cores[m];
            let b = &other.cores[m];
            // A block at (row offset 0, col offset 0); B block at
            // (row offset rl−bl, col offset rr−br). For boundary cores one
            // of the offsets degenerates (rl = 1 or rr = 1).
            let (a_ro, a_co) = (0usize, 0usize);
            let (b_ro, b_co) = (rl - bl, rr - br);
            for i in 0..d {
                for x in 0..al {
                    for y in 0..ar {
                        core[((a_ro + x) * d + i) * rr + (a_co + y)] +=
                            a[(x * d + i) * ar + y];
                    }
                }
                for x in 0..bl {
                    for y in 0..br {
                        core[((b_ro + x) * d + i) * rr + (b_co + y)] +=
                            b[(x * d + i) * br + y];
                    }
                }
            }
            cores.push(core);
        }
        TtTensor::from_cores(&self.dims, &ranks, cores)
    }
}

/// Incremental TT entry evaluator with prefix caching.
///
/// Evaluating many entries of a TT tensor at *sorted* multi-indices (the
/// sparse-RP-on-TT-input pattern: nonzero positions are generated in
/// increasing linear order) shares long index prefixes between
/// consecutive queries. This evaluator caches the partial products
/// `v_m = G¹[i₁]·…·Gᵐ[:,i_m,:]` and recomputes only from the first mode
/// where the index changed — ~2× fewer chain steps at the paper's
/// medium-order shape (§Perf in EXPERIMENTS.md).
pub struct TtEntryEvaluator<'a> {
    x: &'a TtTensor,
    /// `partials[m]` = row vector after absorbing modes `0..=m`.
    partials: Vec<Vec<f64>>,
    prev: Vec<usize>,
}

impl<'a> TtEntryEvaluator<'a> {
    /// New evaluator for `x`.
    pub fn new(x: &'a TtTensor) -> Self {
        let n = x.order();
        let partials = (0..n).map(|m| vec![0.0; x.ranks[m + 1]]).collect();
        Self { x, partials, prev: vec![usize::MAX; n] }
    }

    /// Invalidate the cache (call between unrelated query streams).
    pub fn reset(&mut self) {
        self.prev.fill(usize::MAX);
    }

    /// Evaluate `x[idx]`, reusing cached prefixes where possible.
    pub fn eval(&mut self, idx: &[usize]) -> f64 {
        let n = self.x.order();
        debug_assert_eq!(idx.len(), n);
        let first_diff = (0..n).find(|&m| idx[m] != self.prev[m]).unwrap_or(n);
        for m in first_diff..n {
            let i = idx[m];
            let rr = self.x.ranks[m + 1];
            if m == 0 {
                let src = self.x.core_slice(0, i);
                self.partials[0].clear();
                self.partials[0].extend_from_slice(src);
            } else {
                let rl = self.x.ranks[m];
                let d = self.x.dims[m];
                let core = &self.x.cores[m];
                // Split-borrow: previous partial vs current.
                let (left, right) = self.partials.split_at_mut(m);
                let v = &left[m - 1];
                let out = &mut right[0];
                out.clear();
                out.resize(rr, 0.0);
                for a in 0..rl {
                    let va = v[a];
                    if va == 0.0 {
                        continue;
                    }
                    let base = (a * d + i) * rr;
                    for b in 0..rr {
                        out[b] += va * core[base + b];
                    }
                }
            }
            self.prev[m] = idx[m];
        }
        self.partials[n - 1][0]
    }
}

/// Precomputed contraction context for repeatedly taking inner products
/// of *one* fixed tensor `x` against many TT tensors (the `f_TT(R)`
/// projection pattern: `k` rows against the same input).
///
/// Two optimizations over calling [`TtTensor::inner`] per row (§Perf in
/// EXPERIMENTS.md):
/// * the permutation of each `x` core from `[rb, d, rb2]` to
///   `[(d·rb), rb2]` — needed to turn the second contraction into a plain
///   GEMM — depends only on `x`, so it is computed **once** here instead
///   of once per row per mode;
/// * all intermediates live in a caller-held scratch buffer, so the
///   per-row cost has zero allocations.
pub struct TtContraction {
    dims: Vec<usize>,
    ranks: Vec<usize>,
    /// Per mode: `x` core permuted to `[(d·rb), rb2]` row-major.
    xperm: Vec<Vec<f64>>,
    /// Scratch buffers (boundary matrix ping-pong + t2).
    scratch: std::cell::RefCell<(Vec<f64>, Vec<f64>, Vec<f64>)>,
}

impl TtContraction {
    /// Build the context for input `x`.
    pub fn new(x: &TtTensor) -> Self {
        let n = x.order();
        let mut xperm = Vec::with_capacity(n);
        for m in 0..n {
            let rb = x.ranks[m];
            let d = x.dims[m];
            let rb2 = x.ranks[m + 1];
            let core = &x.cores[m];
            let mut p = vec![0.0; d * rb * rb2];
            for bi in 0..rb {
                for i in 0..d {
                    let src = &core[(bi * d + i) * rb2..(bi * d + i + 1) * rb2];
                    let dst = (i * rb + bi) * rb2;
                    p[dst..dst + rb2].copy_from_slice(src);
                }
            }
            xperm.push(p);
        }
        Self {
            dims: x.dims.clone(),
            ranks: x.ranks.clone(),
            xperm,
            scratch: std::cell::RefCell::new((Vec::new(), Vec::new(), Vec::new())),
        }
    }

    /// Inner product `⟨row, x⟩` — identical value to `row.inner(x)` but
    /// allocation-free and with the x-side permutation amortized.
    pub fn inner(&self, row: &TtTensor) -> f64 {
        assert_eq!(row.dims(), &self.dims[..], "shape mismatch");
        let mut guard = self.scratch.borrow_mut();
        let (m_buf, next_buf, t2) = &mut *guard;
        m_buf.clear();
        m_buf.push(1.0);
        let mut ra = 1usize;
        let mut rb = 1usize;
        for n in 0..self.dims.len() {
            let d = self.dims[n];
            let ra2 = row.ranks()[n + 1];
            let rb2 = self.ranks[n + 1];
            let a = row.core(n);
            // t2[a2, (i·rb + b)] = Σ_a A[a, i, a2] · M[a, b]
            t2.clear();
            t2.resize(ra2 * d * rb, 0.0);
            for ai in 0..ra {
                let mrow = &m_buf[ai * rb..(ai + 1) * rb];
                let abase = ai * d * ra2;
                for i in 0..d {
                    let arow = &a[abase + i * ra2..abase + (i + 1) * ra2];
                    for (a2, &av) in arow.iter().enumerate() {
                        if av == 0.0 {
                            continue;
                        }
                        let dst = &mut t2[a2 * (d * rb) + i * rb..a2 * (d * rb) + (i + 1) * rb];
                        for (dv, &mv) in dst.iter_mut().zip(mrow) {
                            *dv += av * mv;
                        }
                    }
                }
            }
            // M' = t2 [ra2, d·rb] × xperm[n] [(d·rb), rb2]
            next_buf.clear();
            next_buf.resize(ra2 * rb2, 0.0);
            crate::linalg::matmul_acc(t2, &self.xperm[n], next_buf, ra2, d * rb, rb2);
            std::mem::swap(m_buf, next_buf);
            ra = ra2;
            rb = rb2;
        }
        debug_assert_eq!(m_buf.len(), 1);
        m_buf[0]
    }
}

/// Precomputed right-to-left absorption context for inner products of
/// *one* fixed TT tensor against many **dense** tensors — the shared
/// implementation behind `f_TT(R)`'s dense-input projection and the
/// sketch module's `Y = A·Ω` contraction (previously two duplicated
/// copies of the same chain).
///
/// Running each absorption step as a plain GEMM requires the core
/// `Gⁿ ∈ [rₙ, dₙ·rₙ₊₁]` transposed to `[(dₙ·rₙ₊₁), rₙ]`; that permutation
/// depends only on the TT tensor, so it is computed **once** here instead
/// of once per inner product per mode. [`TtDenseContraction::inner_stacked_into`]
/// additionally folds a whole batch of dense inputs into the leading GEMM
/// dimension: `B` separate chains become one chain of `B×`-taller GEMMs,
/// and each result row of a GEMM depends only on its own input row, so
/// batched outputs are bit-identical to `B` single calls.
pub struct TtDenseContraction {
    dims: Vec<usize>,
    ranks: Vec<usize>,
    /// Per mode: core transposed to `[(dₙ·rₙ₊₁), rₙ]` row-major.
    cores_t: Vec<Vec<f64>>,
}

impl TtDenseContraction {
    /// Build the context for `tt`, transposing every core once.
    pub fn new(tt: &TtTensor) -> Self {
        let n = tt.order();
        let mut cores_t = Vec::with_capacity(n);
        for m in 0..n {
            let rl = tt.ranks[m];
            let cols = tt.dims[m] * tt.ranks[m + 1];
            let core = &tt.cores[m];
            let mut t = vec![0.0; core.len()];
            for a in 0..rl {
                for x in 0..cols {
                    t[x * rl + a] = core[a * cols + x];
                }
            }
            cores_t.push(t);
        }
        Self { dims: tt.dims.clone(), ranks: tt.ranks.clone(), cores_t }
    }

    /// Mode sizes of the fixed TT tensor.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Rank vector of the fixed TT tensor (length `N + 1`).
    pub fn ranks(&self) -> &[usize] {
        &self.ranks
    }

    /// Transposed core `m`, `[(dₘ·rₘ₊₁), rₘ]` row-major — the layout both
    /// the dense chain and the batched compressed-input kernels
    /// (`tensor::batch`) consume.
    pub(crate) fn core_t(&self, m: usize) -> &[f64] {
        &self.cores_t[m]
    }

    /// Total stored parameters (one transposed copy of every core).
    pub fn num_elems(&self) -> usize {
        self.cores_t.iter().map(|c| c.len()).sum()
    }

    /// Reconstruct the raw [`TtTensor`] by transposing the stored cores
    /// back. Cold path (AOT packing, serialization): since this context
    /// became the maps' only resident row layout, the raw-core view is
    /// derived on demand instead of being stored twice — exactly how
    /// `gaussian::matrix()` treats the untransposed matrix.
    pub fn to_tt(&self) -> TtTensor {
        let n = self.dims.len();
        let cores = (0..n)
            .map(|m| {
                let rl = self.ranks[m];
                let cols = self.dims[m] * self.ranks[m + 1];
                let t = &self.cores_t[m];
                let mut core = vec![0.0; rl * cols];
                for a in 0..rl {
                    for x in 0..cols {
                        core[a * cols + x] = t[x * rl + a];
                    }
                }
                core
            })
            .collect();
        TtTensor::from_cores(&self.dims, &self.ranks, cores)
    }

    /// Inner product `⟨tt, x⟩` with a single dense tensor.
    pub fn inner(&self, x: &DenseTensor) -> f64 {
        assert_eq!(x.dims(), &self.dims[..], "shape mismatch");
        let mut out = [0.0];
        let (mut cur, mut next) = (Vec::new(), Vec::new());
        self.inner_stacked_into(x.data(), 1, &mut out, &mut cur, &mut next);
        out[0]
    }

    /// Inner products `⟨tt, x_b⟩` for `batch` dense tensors stacked
    /// row-major in `stacked` (`batch × ∏dims` — exactly the layout of a
    /// row-major matrix whose rows are the tensors). Writes one result per
    /// item into `out[..batch]`; `cur`/`next` are caller-held ping-pong
    /// scratch so steady-state calls allocate nothing.
    pub fn inner_stacked_into(
        &self,
        stacked: &[f64],
        batch: usize,
        out: &mut [f64],
        cur: &mut Vec<f64>,
        next: &mut Vec<f64>,
    ) {
        let n = self.dims.len();
        let numel: usize = self.dims.iter().product();
        assert_eq!(stacked.len(), batch * numel, "stacked batch size");
        assert!(out.len() >= batch, "output buffer size");
        if batch == 0 {
            return;
        }
        // Absorb the last core: cur[B·prefix, r_{N-1}] =
        //   X_mat[B·prefix, d_N] · core_tᴺ[d_N, r_{N-1}].
        let d_last = self.dims[n - 1];
        let r_last = self.ranks[n - 1];
        let mut rows = batch * numel / d_last;
        let mut r = r_last;
        cur.clear();
        cur.resize(rows * r_last, 0.0);
        crate::linalg::matmul_into(stacked, &self.cores_t[n - 1], cur, rows, d_last, r_last);
        // Remaining modes right-to-left: view cur [rows·d, r] as
        // [rows, d·r] (row-major contiguity) and absorb core m.
        for m in (0..n - 1).rev() {
            let d = self.dims[m];
            let rl = self.ranks[m];
            debug_assert_eq!(self.ranks[m + 1], r);
            let pref = rows / d;
            next.clear();
            next.resize(pref * rl, 0.0);
            crate::linalg::matmul_into(cur, &self.cores_t[m], next, pref, d * r, rl);
            std::mem::swap(cur, next);
            rows = pref;
            r = rl;
        }
        debug_assert_eq!(rows, batch);
        debug_assert_eq!(r, 1);
        out[..batch].copy_from_slice(&cur[..batch]);
    }
}

/// One step of the TT×TT inner product: contract boundary matrix `m`
/// (`ra × rb`) with cores `a` (`[ra, d, ra2]`) and `b` (`[rb, d, rb2]`),
/// returning the new boundary (`ra2 × rb2`).
pub(crate) fn tt_inner_step(
    m: &[f64],
    a: &[f64],
    b: &[f64],
    ra: usize,
    rb: usize,
    d: usize,
    ra2: usize,
    rb2: usize,
) -> Vec<f64> {
    // tmp[(d·ra2) × rb] = A_matᵀ (d·ra2 × ra) · M (ra × rb),
    // where A_mat is the row-major [ra, d·ra2] view of core a.
    // Compute tmp directly without forming Aᵀ: tmp = Σ_a A[a,·]ᵀ ⊗ M[a,·].
    let mut tmp = vec![0.0; d * ra2 * rb];
    for ai in 0..ra {
        let arow = &a[ai * d * ra2..(ai + 1) * d * ra2];
        let mrow = &m[ai * rb..(ai + 1) * rb];
        for (x, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let dst = &mut tmp[x * rb..(x + 1) * rb];
            for (dv, &mv) in dst.iter_mut().zip(mrow) {
                *dv += av * mv;
            }
        }
    }
    // Want out[ra2, rb2] = Σ_{i, bi} tmp[i, a2, bi] · b[bi, i, b2].
    // Permute tmp [d, ra2, rb] → t2 [ra2, (d·rb)] and b [rb, d, rb2] →
    // b2 [(d·rb), rb2], then a single GEMM.
    let mut t2 = vec![0.0; ra2 * d * rb];
    for i in 0..d {
        for a2 in 0..ra2 {
            let src = &tmp[(i * ra2 + a2) * rb..(i * ra2 + a2 + 1) * rb];
            let dst_base = a2 * (d * rb) + i * rb;
            t2[dst_base..dst_base + rb].copy_from_slice(src);
        }
    }
    let mut b2 = vec![0.0; d * rb * rb2];
    for bi in 0..rb {
        for i in 0..d {
            let src = &b[(bi * d + i) * rb2..(bi * d + i + 1) * rb2];
            let dst_base = (i * rb + bi) * rb2;
            b2[dst_base..dst_base + rb2].copy_from_slice(src);
        }
    }
    matmul(&t2, &b2, ra2, d * rb, rb2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_err;

    #[test]
    fn get_matches_dense() {
        let mut rng = Rng::seed_from(1);
        let t = TtTensor::random(&[2, 3, 4], 3, &mut rng);
        let d = t.to_dense();
        for idx in Shape::new(t.dims()).iter_indices() {
            assert!((t.get(&idx) - d.get(&idx)).abs() < 1e-10);
        }
    }

    #[test]
    fn inner_matches_dense() {
        let mut rng = Rng::seed_from(2);
        let a = TtTensor::random(&[3, 2, 4, 2], 3, &mut rng);
        let b = TtTensor::random(&[3, 2, 4, 2], 2, &mut rng);
        let exact = a.to_dense().inner(&b.to_dense());
        let fast = a.inner(&b);
        assert!(
            (exact - fast).abs() < 1e-9 * exact.abs().max(1.0),
            "exact={exact} fast={fast}"
        );
    }

    #[test]
    fn norm_matches_dense() {
        let mut rng = Rng::seed_from(3);
        let t = TtTensor::random(&[4, 3, 4], 5, &mut rng);
        assert!((t.fro_norm() - t.to_dense().fro_norm()).abs() < 1e-9);
    }

    #[test]
    fn random_unit_norm() {
        let mut rng = Rng::seed_from(4);
        let t = TtTensor::random_unit(&[3; 8], 5, &mut rng);
        assert!((t.fro_norm() - 1.0).abs() < 1e-10);
    }

    #[test]
    fn uniform_ranks_clip_at_boundaries() {
        let ranks = TtTensor::uniform_ranks(&[2, 2, 2], 10);
        // Cut k=1: min(2, 4) = 2; cut k=2: min(4, 2) = 2.
        assert_eq!(ranks, vec![1, 2, 2, 1]);
        let ranks = TtTensor::uniform_ranks(&[3; 5], 4);
        assert_eq!(ranks, vec![1, 3, 4, 4, 3, 1]);
    }

    #[test]
    fn projection_row_variances_follow_definition_1() {
        // Statistically verify the per-core variances of Definition 1.
        let mut rng = Rng::seed_from(5);
        let n_modes = 4;
        let r = 4;
        let dims = vec![6usize; n_modes];
        let mut sums = vec![0.0f64; n_modes];
        let mut counts = vec![0usize; n_modes];
        for _ in 0..200 {
            let t = TtTensor::random_projection_row(&dims, r, &mut rng);
            for k in 0..n_modes {
                for &x in t.core(k) {
                    sums[k] += x * x;
                }
                counts[k] += t.core(k).len();
            }
        }
        for k in 0..n_modes {
            let var = sums[k] / counts[k] as f64;
            let expect = if k == 0 || k == n_modes - 1 {
                1.0 / (r as f64).sqrt()
            } else {
                1.0 / r as f64
            };
            assert!(
                (var - expect).abs() < 0.05 * expect.max(0.1),
                "core {k}: var={var} expect={expect}"
            );
        }
    }

    #[test]
    fn tt_svd_exact_for_low_rank_input() {
        let mut rng = Rng::seed_from(6);
        let src = TtTensor::random(&[4, 3, 5, 3], 3, &mut rng);
        let dense = src.to_dense();
        let rec = TtTensor::tt_svd(&dense, 1e-12, 64);
        assert!(rel_err(rec.to_dense().data(), dense.data()) < 1e-9);
        // Rank recovery: at most the generating ranks.
        for (got, want) in rec.ranks().iter().zip(src.ranks()) {
            assert!(got <= want, "rank inflation: {got} > {want}");
        }
    }

    #[test]
    fn tt_svd_truncation_error_bounded() {
        let mut rng = Rng::seed_from(7);
        let dense = DenseTensor::random(&[4, 4, 4, 4], &mut rng);
        let eps = 0.3;
        let approx = TtTensor::tt_svd(&dense, eps, 64);
        let err = rel_err(approx.to_dense().data(), dense.data());
        assert!(err <= eps * 1.01, "err={err} > eps={eps}");
    }

    #[test]
    fn rounding_recompresses_inflated_ranks() {
        let mut rng = Rng::seed_from(8);
        let t = TtTensor::random(&[3, 4, 3, 4], 2, &mut rng);
        // Inflate by converting to dense and re-decomposing at high rank…
        let inflated = TtTensor::tt_svd(&t.to_dense(), 1e-14, 64);
        // …then round back down.
        let rounded = inflated.round(1e-10, 64);
        assert!(rel_err(rounded.to_dense().data(), t.to_dense().data()) < 1e-8);
        assert!(rounded.max_rank() <= t.max_rank().max(2));
    }

    #[test]
    fn scale_scales_norm() {
        let mut rng = Rng::seed_from(9);
        let mut t = TtTensor::random(&[3, 3, 3], 2, &mut rng);
        let n0 = t.fro_norm();
        t.scale(2.5);
        assert!((t.fro_norm() - 2.5 * n0).abs() < 1e-9);
    }

    #[test]
    fn num_params_matches_formula() {
        // Paper: (N−2)dR² + 2dR parameters for uniform rank R.
        let t = TtTensor::from_cores(
            &[5; 6],
            &TtTensor::uniform_ranks(&[5; 6], 3),
            TtTensor::uniform_ranks(&[5; 6], 3)
                .windows(2)
                .enumerate()
                .map(|(k, w)| vec![0.0; w[0] * 5 * w[1]].iter().map(|_| k as f64).collect())
                .collect(),
        );
        assert_eq!(t.num_params(), (6 - 2) * 5 * 9 + 2 * 5 * 3);
    }

    #[test]
    fn add_matches_dense_sum_and_rounds_back() {
        let mut rng = Rng::seed_from(24);
        let dims = [3usize, 4, 2, 3];
        let a = TtTensor::random(&dims, 2, &mut rng);
        let b = TtTensor::random(&dims, 3, &mut rng);
        let sum = a.add(&b);
        assert_eq!(sum.ranks()[1], 5);
        let mut want = a.to_dense();
        for (x, y) in want.data_mut().iter_mut().zip(b.to_dense().data()) {
            *x += y;
        }
        assert!(crate::linalg::rel_err(want.data(), sum.to_dense().data()) < 1e-10);
        // a + (−a) rounds to (numerical) zero.
        let mut neg = a.clone();
        neg.scale(-1.0);
        let zero = a.add(&neg);
        assert!(zero.fro_norm() < 1e-8);
    }

    #[test]
    fn add_order_one() {
        let a = TtTensor::from_cores(&[3], &[1, 1], vec![vec![1.0, 2.0, 3.0]]);
        let b = TtTensor::from_cores(&[3], &[1, 1], vec![vec![0.5, 0.5, 0.5]]);
        let s = a.add(&b);
        assert_eq!(s.get(&[1]), 2.5);
    }

    #[test]
    fn entry_evaluator_matches_get_over_sorted_stream() {
        let mut rng = Rng::seed_from(23);
        let x = TtTensor::random(&[3, 4, 2, 3], 3, &mut rng);
        let shape = Shape::new(x.dims());
        let mut eval = TtEntryEvaluator::new(&x);
        // Sorted linear positions (the sparse-row pattern).
        for lin in (0..shape.numel()).step_by(7) {
            let idx = shape.multi(lin);
            assert!((eval.eval(&idx) - x.get(&idx)).abs() < 1e-12, "lin={lin}");
        }
        // Unsorted / repeated queries must also be correct.
        eval.reset();
        for lin in [5usize, 5, 3, 60, 2, 2] {
            let idx = shape.multi(lin);
            assert!((eval.eval(&idx) - x.get(&idx)).abs() < 1e-12);
        }
    }

    #[test]
    fn tt_dense_contraction_matches_densified_inner() {
        let mut rng = Rng::seed_from(25);
        let dims = [3usize, 4, 2, 3];
        let tt = TtTensor::random(&dims, 3, &mut rng);
        let ctx = TtDenseContraction::new(&tt);
        for _ in 0..4 {
            let x = DenseTensor::random(&dims, &mut rng);
            let fast = ctx.inner(&x);
            let slow = tt.to_dense().inner(&x);
            assert!(
                (fast - slow).abs() < 1e-9 * slow.abs().max(1.0),
                "fast={fast} slow={slow}"
            );
        }
    }

    #[test]
    fn tt_dense_contraction_batch_is_bit_identical_to_singles() {
        let mut rng = Rng::seed_from(26);
        let dims = [3usize, 2, 4];
        let tt = TtTensor::random(&dims, 2, &mut rng);
        let ctx = TtDenseContraction::new(&tt);
        for batch in [1usize, 3, 8, 17] {
            let xs: Vec<DenseTensor> =
                (0..batch).map(|_| DenseTensor::random(&dims, &mut rng)).collect();
            let mut stacked = Vec::new();
            for x in &xs {
                stacked.extend_from_slice(x.data());
            }
            let mut out = vec![0.0; batch];
            let (mut a, mut b) = (Vec::new(), Vec::new());
            ctx.inner_stacked_into(&stacked, batch, &mut out, &mut a, &mut b);
            for (x, got) in xs.iter().zip(&out) {
                assert_eq!(got.to_bits(), ctx.inner(x).to_bits(), "batch={batch}");
            }
        }
    }

    #[test]
    fn tt_dense_contraction_roundtrips_to_tt() {
        let mut rng = Rng::seed_from(27);
        let t = TtTensor::random(&[3, 4, 2], 3, &mut rng);
        let ctx = TtDenseContraction::new(&t);
        let back = ctx.to_tt();
        assert_eq!(back.dims(), t.dims());
        assert_eq!(back.ranks(), t.ranks());
        for m in 0..t.order() {
            assert_eq!(back.core(m), t.core(m), "core {m} must round-trip bit-exactly");
        }
        assert_eq!(ctx.num_elems(), t.num_params());
    }

    #[test]
    fn tt_dense_contraction_order_one() {
        let tt = TtTensor::from_cores(&[3], &[1, 1], vec![vec![1.0, 2.0, 3.0]]);
        let x = DenseTensor::from_vec(&[3], vec![4.0, 5.0, 6.0]);
        let ctx = TtDenseContraction::new(&tt);
        assert!((ctx.inner(&x) - 32.0).abs() < 1e-12);
    }

    #[test]
    fn tt_contraction_matches_inner() {
        let mut rng = Rng::seed_from(21);
        let x = TtTensor::random(&[3, 4, 2, 5], 3, &mut rng);
        let ctx = TtContraction::new(&x);
        for _ in 0..5 {
            let row = TtTensor::random_projection_row(&[3, 4, 2, 5], 4, &mut rng);
            let fast = ctx.inner(&row);
            let slow = row.inner(&x);
            assert!(
                (fast - slow).abs() < 1e-10 * slow.abs().max(1.0),
                "fast={fast} slow={slow}"
            );
        }
    }

    #[test]
    fn tt_contraction_handles_nonuniform_ranks() {
        let mut rng = Rng::seed_from(22);
        let dims = [2usize, 3, 2];
        let ranks = [1usize, 2, 3, 1];
        let cores: Vec<Vec<f64>> = (0..3)
            .map(|n| rng.gaussian_vec(ranks[n] * dims[n] * ranks[n + 1], 1.0))
            .collect();
        let x = TtTensor::from_cores(&dims, &ranks, cores);
        let ctx = TtContraction::new(&x);
        let row = TtTensor::random(&dims, 2, &mut rng);
        assert!((ctx.inner(&row) - row.inner(&x)).abs() < 1e-10);
    }

    #[test]
    fn order_one_tensor() {
        let t = TtTensor::from_cores(&[4], &[1, 1], vec![vec![1.0, 2.0, 3.0, 4.0]]);
        assert_eq!(t.get(&[2]), 3.0);
        assert!((t.fro_norm() - 30f64.sqrt()).abs() < 1e-12);
    }
}
