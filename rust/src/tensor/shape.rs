//! Shape arithmetic: dims, strides, multi-index ↔ linear-index mapping.
//!
//! Index convention: we use **row-major** (last index fastest) linear
//! ordering throughout the crate. The paper's identities (matricization
//! round trips, `vec(S) = vec(S₍₁₎)` etc.) hold under any fixed convention
//! — see the paper's footnote: "the specific ordering of the fibers does
//! not matter as long as it is consistent across all reshaping operations."

/// Mode sizes of a tensor plus derived stride helpers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Shape {
    dims: Vec<usize>,
}

impl Shape {
    /// Construct from mode sizes. Every mode must be nonzero.
    pub fn new(dims: &[usize]) -> Self {
        assert!(!dims.is_empty(), "tensors must have at least one mode");
        assert!(dims.iter().all(|&d| d > 0), "zero-sized mode");
        Self { dims: dims.to_vec() }
    }

    /// Mode sizes.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Order `N` (number of modes).
    pub fn order(&self) -> usize {
        self.dims.len()
    }

    /// Total number of elements `d₁·…·d_N`.
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    /// Total number of elements as `f64` (usable when the product overflows
    /// `usize`, e.g. the paper's high-order case `3^25 ≈ 8.5e11`).
    pub fn numel_f64(&self) -> f64 {
        self.dims.iter().map(|&d| d as f64).product()
    }

    /// Row-major strides (last mode has stride 1).
    pub fn strides(&self) -> Vec<usize> {
        let n = self.dims.len();
        let mut s = vec![1usize; n];
        for i in (0..n.saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.dims[i + 1];
        }
        s
    }

    /// Linear index of a multi-index.
    pub fn linear(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.dims.len());
        let strides = self.strides();
        let mut lin = 0;
        for (k, (&i, &s)) in idx.iter().zip(&strides).enumerate() {
            debug_assert!(i < self.dims[k], "index {i} out of range for mode {k}");
            lin += i * s;
        }
        lin
    }

    /// Multi-index of a linear index.
    pub fn multi(&self, lin: usize) -> Vec<usize> {
        let mut idx = vec![0usize; self.dims.len()];
        self.multi_into(lin, &mut idx);
        idx
    }

    /// Allocation-free variant of [`Shape::multi`] writing into `idx`
    /// (hot path of sparse projections over compressed inputs).
    pub fn multi_into(&self, mut lin: usize, idx: &mut [usize]) {
        debug_assert_eq!(idx.len(), self.dims.len());
        // Row-major: peel from the last (fastest) mode without computing
        // the stride vector.
        for k in (0..self.dims.len()).rev() {
            let d = self.dims[k];
            idx[k] = lin % d;
            lin /= d;
        }
    }

    /// Iterate all multi-indices in row-major order.
    pub fn iter_indices(&self) -> IndexIter {
        IndexIter {
            dims: self.dims.clone(),
            current: vec![0; self.dims.len()],
            done: self.numel() == 0,
        }
    }

    /// Shape of the mode-`n` matricization: `d_n × (∏_{m≠n} d_m)`.
    pub fn matricization_shape(&self, n: usize) -> (usize, usize) {
        assert!(n < self.order());
        let rows = self.dims[n];
        (rows, self.numel() / rows)
    }
}

/// Row-major multi-index iterator.
pub struct IndexIter {
    dims: Vec<usize>,
    current: Vec<usize>,
    done: bool,
}

impl Iterator for IndexIter {
    type Item = Vec<usize>;

    fn next(&mut self) -> Option<Vec<usize>> {
        if self.done {
            return None;
        }
        let out = self.current.clone();
        // Increment last mode first (row-major).
        let mut k = self.dims.len();
        loop {
            if k == 0 {
                self.done = true;
                break;
            }
            k -= 1;
            self.current[k] += 1;
            if self.current[k] < self.dims[k] {
                break;
            }
            self.current[k] = 0;
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        let s = Shape::new(&[2, 3, 4]);
        assert_eq!(s.strides(), vec![12, 4, 1]);
        assert_eq!(s.numel(), 24);
        assert_eq!(s.order(), 3);
    }

    #[test]
    fn linear_multi_roundtrip() {
        let s = Shape::new(&[3, 4, 5]);
        for lin in 0..s.numel() {
            let m = s.multi(lin);
            assert_eq!(s.linear(&m), lin);
            for (k, &i) in m.iter().enumerate() {
                assert!(i < s.dims()[k]);
            }
        }
    }

    #[test]
    fn iter_covers_all_indices_in_order() {
        let s = Shape::new(&[2, 3]);
        let all: Vec<Vec<usize>> = s.iter_indices().collect();
        assert_eq!(all.len(), 6);
        assert_eq!(all[0], vec![0, 0]);
        assert_eq!(all[1], vec![0, 1]);
        assert_eq!(all[5], vec![1, 2]);
        for (lin, idx) in all.iter().enumerate() {
            assert_eq!(s.linear(idx), lin);
        }
    }

    #[test]
    fn numel_f64_for_huge_shapes() {
        let s = Shape::new(&[3; 25]);
        assert!((s.numel_f64() - 3f64.powi(25)).abs() < 1.0);
    }

    #[test]
    fn matricization_shape() {
        let s = Shape::new(&[2, 3, 4]);
        assert_eq!(s.matricization_shape(1), (3, 8));
    }

    #[test]
    #[should_panic]
    fn zero_mode_rejected() {
        Shape::new(&[2, 0, 3]);
    }
}
