//! Projection-map persistence.
//!
//! A deployed compression service must answer with the *same* map across
//! restarts and upgrades — seed-determinism (see
//! `coordinator::ProjectionRegistry`) covers restarts, but only explicit
//! serialization protects against RNG/algorithm changes. This module
//! round-trips the two first-class maps through the in-repo JSON codec.

use super::{CpProjection, Projection, TtProjection};
use crate::linalg::Matrix;
use crate::tensor::{CpTensor, TtTensor};
use crate::util::json::{num_arr, obj, usize_arr, Json};

/// Serialize a TT projection map.
pub fn tt_to_json(f: &TtProjection) -> Json {
    obj(vec![
        ("kind", Json::Str("tt".into())),
        ("dims", usize_arr(f.input_dims())),
        ("rank", Json::Num(f.rank() as f64)),
        ("k", Json::Num(f.k() as f64)),
        (
            "rows",
            Json::Arr(
                f.rows()
                    .iter()
                    .map(|row| {
                        obj(vec![
                            ("ranks", usize_arr(row.ranks())),
                            (
                                "cores",
                                Json::Arr(
                                    (0..row.order()).map(|n| num_arr(row.core(n))).collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Deserialize a TT projection map.
pub fn tt_from_json(j: &Json) -> Result<TtProjection, String> {
    expect_kind(j, "tt")?;
    let dims = j.get("dims").and_then(Json::as_usize_vec).ok_or("missing dims")?;
    let rank = j.get("rank").and_then(Json::as_usize).ok_or("missing rank")?;
    let k = j.get("k").and_then(Json::as_usize).ok_or("missing k")?;
    let rows_json = j.get("rows").and_then(Json::as_arr).ok_or("missing rows")?;
    if rows_json.len() != k {
        return Err(format!("row count {} != k {k}", rows_json.len()));
    }
    let rows = rows_json
        .iter()
        .map(|r| {
            let ranks = r.get("ranks").and_then(Json::as_usize_vec).ok_or("missing ranks")?;
            let cores = r
                .get("cores")
                .and_then(Json::as_arr)
                .ok_or("missing cores")?
                .iter()
                .map(num_vec)
                .collect::<Result<Vec<_>, _>>()?;
            Ok(TtTensor::from_cores(&dims, &ranks, cores))
        })
        .collect::<Result<Vec<_>, String>>()?;
    Ok(TtProjection::from_rows(dims, rank, k, rows))
}

/// Serialize a CP projection map.
pub fn cp_to_json(f: &CpProjection) -> Json {
    obj(vec![
        ("kind", Json::Str("cp".into())),
        ("dims", usize_arr(f.input_dims())),
        ("rank", Json::Num(f.rank() as f64)),
        ("k", Json::Num(f.k() as f64)),
        (
            "rows",
            Json::Arr(
                f.rows()
                    .iter()
                    .map(|row| {
                        Json::Arr(
                            (0..row.order())
                                .map(|n| num_arr(row.factor(n).data()))
                                .collect(),
                        )
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Deserialize a CP projection map.
pub fn cp_from_json(j: &Json) -> Result<CpProjection, String> {
    expect_kind(j, "cp")?;
    let dims = j.get("dims").and_then(Json::as_usize_vec).ok_or("missing dims")?;
    let rank = j.get("rank").and_then(Json::as_usize).ok_or("missing rank")?;
    let k = j.get("k").and_then(Json::as_usize).ok_or("missing k")?;
    let rows_json = j.get("rows").and_then(Json::as_arr).ok_or("missing rows")?;
    if rows_json.len() != k {
        return Err(format!("row count {} != k {k}", rows_json.len()));
    }
    let rows = rows_json
        .iter()
        .map(|r| {
            let factors = r
                .as_arr()
                .ok_or("row must be an array of factors")?
                .iter()
                .zip(&dims)
                .map(|(f, &d)| Ok(Matrix::from_vec(d, rank, num_vec(f)?)))
                .collect::<Result<Vec<_>, String>>()?;
            Ok(CpTensor::from_factors(factors))
        })
        .collect::<Result<Vec<_>, String>>()?;
    Ok(CpProjection::from_rows(dims, rank, k, rows))
}

fn expect_kind(j: &Json, want: &str) -> Result<(), String> {
    match j.get("kind").and_then(Json::as_str) {
        Some(k) if k == want => Ok(()),
        Some(k) => Err(format!("expected kind {want:?}, found {k:?}")),
        None => Err("missing kind".into()),
    }
}

fn num_vec(j: &Json) -> Result<Vec<f64>, String> {
    j.as_arr()
        .ok_or("expected array")?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| "expected number".to_string()))
        .collect()
}

impl TtProjection {
    /// Assemble a map from explicit rows (deserialization).
    pub fn from_rows(dims: Vec<usize>, rank: usize, k: usize, rows: Vec<TtTensor>) -> Self {
        assert_eq!(rows.len(), k);
        for r in &rows {
            assert_eq!(r.dims(), &dims[..], "row shape mismatch");
        }
        Self::from_parts(dims, rank, k, rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::TtTensor;

    #[test]
    fn tt_map_roundtrips_exactly() {
        let mut rng = Rng::seed_from(1);
        let dims = [3usize, 4, 3];
        let f = TtProjection::new(&dims, 3, 7, &mut rng);
        let text = tt_to_json(&f).to_string_pretty();
        let g = tt_from_json(&Json::parse(&text).unwrap()).unwrap();
        let x = TtTensor::random_unit(&dims, 2, &mut rng);
        assert_eq!(f.project_tt(&x), g.project_tt(&x), "embeddings must be identical");
        assert_eq!(g.k(), 7);
        assert_eq!(g.rank(), 3);
    }

    #[test]
    fn cp_map_roundtrips_exactly() {
        let mut rng = Rng::seed_from(2);
        let dims = [3usize, 2, 4];
        let f = CpProjection::new(&dims, 4, 5, &mut rng);
        let text = cp_to_json(&f).to_string_compact();
        let g = cp_from_json(&Json::parse(&text).unwrap()).unwrap();
        let x = crate::tensor::CpTensor::random_unit(&dims, 2, &mut rng);
        assert_eq!(f.project_cp(&x), g.project_cp(&x));
    }

    #[test]
    fn kind_mismatch_is_rejected() {
        let mut rng = Rng::seed_from(3);
        let f = TtProjection::new(&[3, 3], 2, 2, &mut rng);
        let j = tt_to_json(&f);
        assert!(cp_from_json(&j).is_err());
    }

    #[test]
    fn corrupted_row_count_is_rejected() {
        let mut rng = Rng::seed_from(4);
        let f = TtProjection::new(&[3, 3], 2, 2, &mut rng);
        let text = tt_to_json(&f).to_string_compact().replace("\"k\":2", "\"k\":3");
        assert!(tt_from_json(&Json::parse(&text).unwrap()).is_err());
    }
}
