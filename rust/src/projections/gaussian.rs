//! Classical dense Gaussian random projection (the JLT of §2.3).
//!
//! `f(x) = (1/√k)·A·vec(x)` with `A ∈ R^{k×D}`, `A_ij ~ N(0,1)` i.i.d.
//! Storage `O(kD)` and projection cost `O(kD)` — the baseline the
//! tensorized maps beat on memory and, for compressed inputs, on time.

use super::Projection;
use crate::linalg::matvec;
use crate::rng::Rng;
use crate::tensor::DenseTensor;

/// Dense Gaussian JL transform.
pub struct GaussianProjection {
    dims: Vec<usize>,
    k: usize,
    /// `k × D` row-major.
    matrix: Vec<f64>,
    scale: f64,
}

impl GaussianProjection {
    /// Draw a fresh map for inputs of shape `dims` into `R^k`.
    ///
    /// Panics if the materialized matrix would exceed ~2^31 entries — at
    /// that point the paper's medium/high-order regimes apply and a
    /// tensorized map must be used instead.
    pub fn new(dims: &[usize], k: usize, rng: &mut Rng) -> Self {
        let d: usize = dims.iter().product();
        let entries = d.checked_mul(k).expect("k·D overflows usize");
        assert!(
            entries <= (1 << 31),
            "dense Gaussian RP with {entries} entries is not materializable; \
             use TtProjection / CpProjection"
        );
        let matrix = rng.gaussian_vec(entries, 1.0);
        Self {
            dims: dims.to_vec(),
            k,
            matrix,
            scale: 1.0 / (k as f64).sqrt(),
        }
    }

    /// Input dimension `D = ∏ dims`.
    pub fn input_dim(&self) -> usize {
        self.dims.iter().product()
    }

    /// Raw projection matrix (row-major `k × D`), used by the AOT runtime
    /// to feed identical parameters to the compiled artifact.
    pub fn matrix(&self) -> &[f64] {
        &self.matrix
    }
}

impl Projection for GaussianProjection {
    fn name(&self) -> String {
        "Gaussian".to_string()
    }

    fn input_dims(&self) -> &[usize] {
        &self.dims
    }

    fn k(&self) -> usize {
        self.k
    }

    fn num_params(&self) -> usize {
        self.matrix.len()
    }

    fn project_dense(&self, x: &DenseTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        let d = self.input_dim();
        let mut y = matvec(&self.matrix, x.data(), self.k, d);
        for v in &mut y {
            *v *= self.scale;
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projections::squared_norm;
    use crate::util::stats::mean;

    #[test]
    fn output_length_is_k() {
        let mut rng = Rng::seed_from(1);
        let f = GaussianProjection::new(&[4, 5], 7, &mut rng);
        let x = DenseTensor::random(&[4, 5], &mut rng);
        assert_eq!(f.project_dense(&x).len(), 7);
    }

    #[test]
    fn expected_isometry() {
        // Average ‖f(x)‖² over many independent maps ≈ ‖x‖².
        let mut rng = Rng::seed_from(2);
        let x = DenseTensor::random_unit(&[6, 6], &mut rng);
        let norms: Vec<f64> = (0..300)
            .map(|_| {
                let f = GaussianProjection::new(&[6, 6], 16, &mut rng);
                squared_norm(&f.project_dense(&x))
            })
            .collect();
        let m = mean(&norms);
        assert!((m - 1.0).abs() < 0.05, "mean={m}");
    }

    #[test]
    fn linearity() {
        let mut rng = Rng::seed_from(3);
        let f = GaussianProjection::new(&[3, 3], 5, &mut rng);
        let a = DenseTensor::random(&[3, 3], &mut rng);
        let b = DenseTensor::random(&[3, 3], &mut rng);
        let mut apb = a.clone();
        for (x, y) in apb.data_mut().iter_mut().zip(b.data()) {
            *x += y;
        }
        let ya = f.project_dense(&a);
        let yb = f.project_dense(&b);
        let yab = f.project_dense(&apb);
        for i in 0..5 {
            assert!((yab[i] - ya[i] - yb[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn num_params_is_k_times_d() {
        let mut rng = Rng::seed_from(4);
        let f = GaussianProjection::new(&[3, 4, 5], 8, &mut rng);
        assert_eq!(f.num_params(), 8 * 60);
    }

    #[test]
    #[should_panic(expected = "not materializable")]
    fn refuses_huge_inputs() {
        let mut rng = Rng::seed_from(5);
        // 3^20 * 10 entries ≫ 2^31.
        let _ = GaussianProjection::new(&[3; 20], 10, &mut rng);
    }
}
