//! Classical dense Gaussian random projection (the JLT of §2.3).
//!
//! `f(x) = (1/√k)·A·vec(x)` with `A ∈ R^{k×D}`, `A_ij ~ N(0,1)` i.i.d.
//! Storage `O(kD)` and projection cost `O(kD)` — the baseline the
//! tensorized maps beat on memory and, for compressed inputs, on time.

use super::{Projection, Workspace};
use crate::linalg::matmul_into;
use crate::rng::Rng;
use crate::tensor::{AnyTensor, DenseTensor};

/// Dense Gaussian JL transform.
pub struct GaussianProjection {
    dims: Vec<usize>,
    k: usize,
    /// `A` stored transposed, `D × k` row-major — the layout both the
    /// single and the batched GEMM kernels consume directly
    /// (`Y = X_stack · Aᵀ`), fixed once at construction so no execution
    /// path transposes anything.
    matrix_t: Vec<f64>,
    scale: f64,
}

impl GaussianProjection {
    /// Draw a fresh map for inputs of shape `dims` into `R^k`.
    ///
    /// Panics if the materialized matrix would exceed ~2^31 entries — at
    /// that point the paper's medium/high-order regimes apply and a
    /// tensorized map must be used instead.
    pub fn new(dims: &[usize], k: usize, rng: &mut Rng) -> Self {
        let d: usize = dims.iter().product();
        let entries = d.checked_mul(k).expect("k·D overflows usize");
        assert!(
            entries <= (1 << 31),
            "dense Gaussian RP with {entries} entries is not materializable; \
             use TtProjection / CpProjection"
        );
        // Draw in the conventional k × D row order (keeps the map drawn
        // from a given seed identical to earlier revisions), then store
        // transposed.
        let matrix = rng.gaussian_vec(entries, 1.0);
        let mut matrix_t = vec![0.0; entries];
        for i in 0..k {
            for p in 0..d {
                matrix_t[p * k + i] = matrix[i * d + p];
            }
        }
        Self {
            dims: dims.to_vec(),
            k,
            matrix_t,
            scale: 1.0 / (k as f64).sqrt(),
        }
    }

    /// Input dimension `D = ∏ dims`.
    pub fn input_dim(&self) -> usize {
        self.dims.iter().product()
    }

    /// Projection matrix materialized row-major `k × D` (the layout the
    /// AOT artifacts compile against); cold path — used once per artifact
    /// registration by `runtime::pack`.
    pub fn matrix(&self) -> Vec<f64> {
        let d = self.input_dim();
        let mut m = vec![0.0; self.matrix_t.len()];
        for p in 0..d {
            for i in 0..self.k {
                m[i * d + p] = self.matrix_t[p * self.k + i];
            }
        }
        m
    }
}

impl Projection for GaussianProjection {
    fn name(&self) -> String {
        "Gaussian".to_string()
    }

    fn input_dims(&self) -> &[usize] {
        &self.dims
    }

    fn k(&self) -> usize {
        self.k
    }

    fn num_params(&self) -> usize {
        self.matrix_t.len()
    }

    fn project_dense(&self, x: &DenseTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        // Single item = batch of one through the same GEMM kernel.
        let d = self.input_dim();
        let mut y = vec![0.0; self.k];
        matmul_into(x.data(), &self.matrix_t, &mut y, 1, d, self.k);
        for v in &mut y {
            *v *= self.scale;
        }
        y
    }

    fn project_batch_into(&self, xs: &[AnyTensor], out: &mut [f64], ws: &mut Workspace) {
        let k = self.k;
        assert_eq!(out.len(), xs.len() * k, "batch output buffer size");
        if xs.is_empty() {
            return;
        }
        if !super::stack_dense_batch(xs, &self.dims, &mut ws.stack) {
            super::fallback_batch_into(self, xs, out);
            return;
        }
        // One packed GEMM over the stacked batch, Y = X_stack · Aᵀ,
        // writing the [B, k] result directly into `out`. Each output row
        // depends only on its own input row with p-ascending accumulation
        // — identical to the single-item kernel, so bit-identical. Dense
        // flushes are the largest GEMMs in the system (B × D × k); above
        // the kernel's flop floor they split row panels across workers
        // (`linalg::gemm` parallel path) without changing any chain.
        let b = xs.len();
        let d = self.input_dim();
        matmul_into(&ws.stack, &self.matrix_t, out, b, d, k);
        for v in out.iter_mut() {
            *v *= self.scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projections::squared_norm;
    use crate::util::stats::mean;

    #[test]
    fn output_length_is_k() {
        let mut rng = Rng::seed_from(1);
        let f = GaussianProjection::new(&[4, 5], 7, &mut rng);
        let x = DenseTensor::random(&[4, 5], &mut rng);
        assert_eq!(f.project_dense(&x).len(), 7);
    }

    #[test]
    fn expected_isometry() {
        // Average ‖f(x)‖² over many independent maps ≈ ‖x‖².
        let mut rng = Rng::seed_from(2);
        let x = DenseTensor::random_unit(&[6, 6], &mut rng);
        let norms: Vec<f64> = (0..300)
            .map(|_| {
                let f = GaussianProjection::new(&[6, 6], 16, &mut rng);
                squared_norm(&f.project_dense(&x))
            })
            .collect();
        let m = mean(&norms);
        assert!((m - 1.0).abs() < 0.05, "mean={m}");
    }

    #[test]
    fn linearity() {
        let mut rng = Rng::seed_from(3);
        let f = GaussianProjection::new(&[3, 3], 5, &mut rng);
        let a = DenseTensor::random(&[3, 3], &mut rng);
        let b = DenseTensor::random(&[3, 3], &mut rng);
        let mut apb = a.clone();
        for (x, y) in apb.data_mut().iter_mut().zip(b.data()) {
            *x += y;
        }
        let ya = f.project_dense(&a);
        let yb = f.project_dense(&b);
        let yab = f.project_dense(&apb);
        for i in 0..5 {
            assert!((yab[i] - ya[i] - yb[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn num_params_is_k_times_d() {
        let mut rng = Rng::seed_from(4);
        let f = GaussianProjection::new(&[3, 4, 5], 8, &mut rng);
        assert_eq!(f.num_params(), 8 * 60);
    }

    #[test]
    #[should_panic(expected = "not materializable")]
    fn refuses_huge_inputs() {
        let mut rng = Rng::seed_from(5);
        // 3^20 * 10 entries ≫ 2^31.
        let _ = GaussianProjection::new(&[3; 20], 10, &mut rng);
    }
}
