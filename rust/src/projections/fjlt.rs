//! Kronecker fast JL transform (Jin, Kolda & Ward 2019) — the related-work
//! baseline of the paper's §4.1 comparison.
//!
//! `f(x) = √(D/k)·S·(H D_s)^⊗·x`: per-mode random sign flips `D_s`,
//! per-mode normalized Walsh-Hadamard transforms `H` (modes are zero-padded
//! to powers of two), then uniform sampling `S` of `k` coordinates.
//!
//! Projecting a rank-one / CP input touches only the factors:
//! `O(R̃·(N·d·log d + k·N))` — matching the complexity the paper quotes.
//! TT inputs fall back to densification, mirroring the paper's remark that
//! low-rank TT tensors have exponentially large CP rank and therefore no
//! efficient path through this transform.

use super::{Projection, Workspace};
use crate::rng::Rng;
use crate::tensor::{AnyTensor, CpTensor, DenseTensor};

/// Kronecker-structured fast JL transform.
pub struct KroneckerFjlt {
    dims: Vec<usize>,
    /// Per-mode padded (power-of-two) sizes.
    padded: Vec<usize>,
    k: usize,
    /// Per-mode sign vectors (length `dims[n]` — signs for real entries).
    signs: Vec<Vec<f64>>,
    /// Sampled multi-indices in the padded index space, one per output.
    samples: Vec<Vec<usize>>,
    scale: f64,
}

impl KroneckerFjlt {
    /// Draw a fresh transform.
    pub fn new(dims: &[usize], k: usize, rng: &mut Rng) -> Self {
        assert!(k >= 1);
        let padded: Vec<usize> = dims.iter().map(|&d| d.next_power_of_two()).collect();
        let signs = dims
            .iter()
            .map(|&d| (0..d).map(|_| rng.sign()).collect())
            .collect();
        let samples = (0..k)
            .map(|_| padded.iter().map(|&p| rng.below(p as u64) as usize).collect())
            .collect();
        let d_pad: f64 = padded.iter().map(|&p| p as f64).product();
        Self {
            dims: dims.to_vec(),
            padded,
            k,
            signs,
            samples,
            // √(D_pad/k): sampling k of D_pad coordinates of an orthonormal
            // transform of the (zero-padded, norm-preserved) input.
            scale: (d_pad / k as f64).sqrt(),
        }
    }

    /// In-place normalized fast Walsh-Hadamard transform (length must be a
    /// power of two).
    fn fwht(buf: &mut [f64]) {
        let n = buf.len();
        debug_assert!(n.is_power_of_two());
        let mut h = 1;
        while h < n {
            let mut i = 0;
            while i < n {
                for j in i..i + h {
                    let x = buf[j];
                    let y = buf[j + h];
                    buf[j] = x + y;
                    buf[j + h] = x - y;
                }
                i += h * 2;
            }
            h *= 2;
        }
        let norm = 1.0 / (n as f64).sqrt();
        for v in buf {
            *v *= norm;
        }
    }

    /// Apply sign-flip + pad + FWHT to a mode-`n` vector.
    fn transform_mode_vec(&self, n: usize, v: &[f64]) -> Vec<f64> {
        let mut buf = vec![0.0; self.padded[n]];
        for (i, &x) in v.iter().enumerate() {
            buf[i] = x * self.signs[n][i];
        }
        Self::fwht(&mut buf);
        buf
    }

    /// Linear index into the padded tensor (row-major, last mode fastest).
    fn padded_linear(&self, idx: &[usize]) -> usize {
        let mut lin = 0usize;
        for (m, &i) in idx.iter().enumerate() {
            lin = lin * self.padded[m] + i;
        }
        lin
    }

    /// Dense projection kernel shared by the single-item and batched
    /// paths: sign-flip + zero-pad into `pad`, FWHT every mode fiber
    /// (scratch in `fiber`), then read the sampled coordinates into
    /// `out[..k]`. All buffers are caller-held, so the batched path reuses
    /// them across items instead of materializing a padded tensor per
    /// call.
    fn dense_project_into(
        &self,
        x: &DenseTensor,
        out: &mut [f64],
        pad: &mut Vec<f64>,
        fiber: &mut Vec<f64>,
    ) {
        let n = self.dims.len();
        let padded_numel: usize = self.padded.iter().product();
        pad.clear();
        pad.resize(padded_numel, 0.0);
        for idx in crate::tensor::Shape::new(&self.dims).iter_indices() {
            pad[self.padded_linear(&idx)] = x.get(&idx) * sign_product(&self.signs, &idx);
        }
        for mode in 0..n {
            let d = self.padded[mode];
            let inner: usize = self.padded[mode + 1..].iter().product();
            let outer: usize = self.padded[..mode].iter().product();
            fiber.clear();
            fiber.resize(d, 0.0);
            for o in 0..outer {
                for inn in 0..inner {
                    for i in 0..d {
                        fiber[i] = pad[(o * d + i) * inner + inn];
                    }
                    Self::fwht(fiber);
                    for i in 0..d {
                        pad[(o * d + i) * inner + inn] = fiber[i];
                    }
                }
            }
        }
        for (o, s) in out.iter_mut().zip(&self.samples) {
            *o = pad[self.padded_linear(s)] * self.scale;
        }
    }
}

impl Projection for KroneckerFjlt {
    fn name(&self) -> String {
        "KronFJLT".to_string()
    }

    fn input_dims(&self) -> &[usize] {
        &self.dims
    }

    fn k(&self) -> usize {
        self.k
    }

    fn num_params(&self) -> usize {
        // Signs + sampled indices; the Hadamard matrices are implicit.
        self.signs.iter().map(|s| s.len()).sum::<usize>() + self.k * self.dims.len()
    }

    fn project_dense(&self, x: &DenseTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        let mut out = vec![0.0; self.k];
        let (mut pad, mut fiber) = (Vec::new(), Vec::new());
        self.dense_project_into(x, &mut out, &mut pad, &mut fiber);
        out
    }

    fn project_batch_into(&self, xs: &[AnyTensor], out: &mut [f64], ws: &mut Workspace) {
        let k = self.k;
        assert_eq!(out.len(), xs.len() * k, "batch output buffer size");
        if !super::dense_batch_uniform(xs, &self.dims) {
            super::fallback_batch_into(self, xs, out);
            return;
        }
        // The FWHT has no cross-item contraction to fold, so the batched
        // win is buffer reuse: one padded scratch + one fiber scratch
        // serve the whole batch instead of a fresh padded tensor per item.
        for (x, dst) in xs.iter().zip(out.chunks_exact_mut(k)) {
            let AnyTensor::Dense(t) = x else { unreachable!() };
            self.dense_project_into(t, dst, &mut ws.chain_a, &mut ws.chain_b);
        }
    }

    fn project_cp(&self, x: &CpTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        let n = self.dims.len();
        let r = x.rank();
        // Transform each factor column: O(R·N·d log d).
        // transformed[mode][r] is the padded, transformed column.
        let transformed: Vec<Vec<Vec<f64>>> = (0..n)
            .map(|mode| {
                (0..r)
                    .map(|comp| {
                        let col: Vec<f64> = (0..self.dims[mode])
                            .map(|i| x.factor(mode)[(i, comp)])
                            .collect();
                        self.transform_mode_vec(mode, &col)
                    })
                    .collect()
            })
            .collect();
        // Evaluate sampled coordinates: O(k·N·R).
        self.samples
            .iter()
            .map(|s| {
                let mut acc = 0.0;
                for comp in 0..r {
                    let mut prod = 1.0;
                    for (mode, &j) in s.iter().enumerate() {
                        prod *= transformed[mode][comp][j];
                    }
                    acc += prod;
                }
                acc * self.scale
            })
            .collect()
    }
}

/// Product of per-mode signs at a multi-index.
fn sign_product(signs: &[Vec<f64>], idx: &[usize]) -> f64 {
    idx.iter()
        .enumerate()
        .map(|(n, &i)| signs[n][i])
        .product()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projections::squared_norm;
    use crate::util::stats::mean;

    #[test]
    fn fwht_is_orthonormal() {
        let mut v = vec![1.0, 2.0, 3.0, 4.0];
        let norm0: f64 = v.iter().map(|x| x * x).sum();
        KroneckerFjlt::fwht(&mut v);
        let norm1: f64 = v.iter().map(|x| x * x).sum();
        assert!((norm0 - norm1).abs() < 1e-10);
        // Applying twice recovers the input (H is an involution).
        KroneckerFjlt::fwht(&mut v);
        assert!((v[0] - 1.0).abs() < 1e-10);
        assert!((v[3] - 4.0).abs() < 1e-10);
    }

    #[test]
    fn cp_path_matches_dense_path() {
        let mut rng = Rng::seed_from(1);
        let dims = [3usize, 4, 2];
        let f = KroneckerFjlt::new(&dims, 7, &mut rng);
        let x = CpTensor::random_unit(&dims, 2, &mut rng);
        let via_cp = f.project_cp(&x);
        let via_dense = f.project_dense(&x.to_dense());
        for (a, b) in via_cp.iter().zip(&via_dense) {
            assert!((a - b).abs() < 1e-9, "cp={a} dense={b}");
        }
    }

    #[test]
    fn expected_isometry() {
        let mut rng = Rng::seed_from(2);
        let dims = [4usize, 4, 4];
        let x = DenseTensor::random_unit(&dims, &mut rng);
        let norms: Vec<f64> = (0..400)
            .map(|_| {
                let f = KroneckerFjlt::new(&dims, 16, &mut rng);
                squared_norm(&f.project_dense(&x))
            })
            .collect();
        let m = mean(&norms);
        assert!((m - 1.0).abs() < 0.12, "mean={m}");
    }

    #[test]
    fn non_power_of_two_modes_are_padded() {
        let mut rng = Rng::seed_from(3);
        let f = KroneckerFjlt::new(&[3, 5], 4, &mut rng);
        let x = DenseTensor::random_unit(&[3, 5], &mut rng);
        let y = f.project_dense(&x);
        assert_eq!(y.len(), 4);
        assert!(y.iter().all(|v| v.is_finite()));
    }
}
