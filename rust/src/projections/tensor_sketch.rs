//! Tensor Sketch (Pham & Pagh 2013) — the count-sketch-based related-work
//! baseline of the paper's §1.
//!
//! Per mode `n`: a hash `hₙ : [dₙ] → [k]` and a sign `sₙ : [dₙ] → ±1`.
//! The sketch of a rank-one tensor `⊗ₙ xₙ` is the circular convolution of
//! the per-mode count-sketches — computed in `O(N(d + k log k))` via FFT —
//! and extends to CP inputs by linearity. Dense inputs use the combined
//! hash `h(i) = Σₙ hₙ(iₙ) mod k`, `s(i) = Πₙ sₙ(iₙ)` in `O(D·N)`.
//!
//! Unlike the tensorized Gaussian maps, the sketch is an *unbiased*
//! estimator of inner products with variance `O(1/k)` per point but no
//! rank knob; it serves as the hashing-family contrast to Definitions 1/2.

use super::Projection;
use crate::linalg::fft::circular_convolve;
use crate::rng::Rng;
use crate::tensor::{CpTensor, DenseTensor, Shape};

/// Count-sketch based tensor sketch.
pub struct TensorSketch {
    dims: Vec<usize>,
    k: usize,
    /// `hashes[n][i] ∈ [k]`.
    hashes: Vec<Vec<usize>>,
    /// `signs[n][i] ∈ {±1}`.
    signs: Vec<Vec<f64>>,
}

impl TensorSketch {
    /// Draw a fresh sketch for inputs of shape `dims` into `R^k`.
    pub fn new(dims: &[usize], k: usize, rng: &mut Rng) -> Self {
        assert!(k >= 1);
        let hashes = dims
            .iter()
            .map(|&d| (0..d).map(|_| rng.below(k as u64) as usize).collect())
            .collect();
        let signs = dims
            .iter()
            .map(|&d| (0..d).map(|_| rng.sign()).collect())
            .collect();
        Self { dims: dims.to_vec(), k, hashes, signs }
    }

    /// Count-sketch of a single mode-`n` vector.
    fn mode_sketch(&self, n: usize, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.k];
        for (i, &x) in v.iter().enumerate() {
            out[self.hashes[n][i]] += self.signs[n][i] * x;
        }
        out
    }
}

impl Projection for TensorSketch {
    fn name(&self) -> String {
        "TensorSketch".to_string()
    }

    fn input_dims(&self) -> &[usize] {
        &self.dims
    }

    fn k(&self) -> usize {
        self.k
    }

    fn num_params(&self) -> usize {
        // One hash index + one sign per mode entry.
        2 * self.dims.iter().sum::<usize>()
    }

    fn project_dense(&self, x: &DenseTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        let shape = Shape::new(x.dims());
        let n = self.dims.len();
        let mut idx = vec![0usize; n];
        let mut out = vec![0.0; self.k];
        for (lin, &v) in x.data().iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            shape.multi_into(lin, &mut idx);
            let mut h = 0usize;
            let mut s = 1.0;
            for m in 0..n {
                h += self.hashes[m][idx[m]];
                s *= self.signs[m][idx[m]];
            }
            out[h % self.k] += s * v;
        }
        out
    }

    fn project_cp(&self, x: &CpTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        let n = self.dims.len();
        let mut out = vec![0.0; self.k];
        let mut col = Vec::new();
        for r in 0..x.rank() {
            // Sketch each mode's column, convolve across modes.
            col.clear();
            col.extend((0..self.dims[0]).map(|i| x.factor(0)[(i, r)]));
            let mut acc = self.mode_sketch(0, &col);
            for m in 1..n {
                col.clear();
                col.extend((0..self.dims[m]).map(|i| x.factor(m)[(i, r)]));
                let cs = self.mode_sketch(m, &col);
                acc = circular_convolve(&acc, &cs);
            }
            for (o, a) in out.iter_mut().zip(&acc) {
                *o += a;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projections::squared_norm;
    use crate::util::stats::mean;

    #[test]
    fn cp_path_matches_dense_path() {
        let mut rng = Rng::seed_from(1);
        let dims = [3usize, 4, 2];
        let f = TensorSketch::new(&dims, 13, &mut rng);
        let x = CpTensor::random_unit(&dims, 3, &mut rng);
        let via_cp = f.project_cp(&x);
        let via_dense = f.project_dense(&x.to_dense());
        for (a, b) in via_cp.iter().zip(&via_dense) {
            assert!((a - b).abs() < 1e-9, "cp={a} dense={b}");
        }
    }

    #[test]
    fn expected_isometry() {
        // E‖S(x)‖² = ‖x‖² for count sketches.
        let mut rng = Rng::seed_from(2);
        let dims = [4usize, 4, 4];
        let x = DenseTensor::random_unit(&dims, &mut rng);
        let norms: Vec<f64> = (0..600)
            .map(|_| {
                let f = TensorSketch::new(&dims, 32, &mut rng);
                squared_norm(&f.project_dense(&x))
            })
            .collect();
        let m = mean(&norms);
        assert!((m - 1.0).abs() < 0.1, "mean={m}");
    }

    #[test]
    fn preserves_inner_products_in_expectation() {
        let mut rng = Rng::seed_from(3);
        let dims = [3usize, 3, 3];
        let a = DenseTensor::random_unit(&dims, &mut rng);
        let b = DenseTensor::random_unit(&dims, &mut rng);
        let exact = a.inner(&b);
        let est: Vec<f64> = (0..800)
            .map(|_| {
                let f = TensorSketch::new(&dims, 32, &mut rng);
                let ya = f.project_dense(&a);
                let yb = f.project_dense(&b);
                ya.iter().zip(&yb).map(|(p, q)| p * q).sum::<f64>()
            })
            .collect();
        let m = mean(&est);
        assert!((m - exact).abs() < 0.08, "estimate {m} vs exact {exact}");
    }

    #[test]
    fn memory_is_linear_in_mode_sizes() {
        let mut rng = Rng::seed_from(4);
        let f = TensorSketch::new(&[5; 8], 64, &mut rng);
        assert_eq!(f.num_params(), 2 * 40);
        assert_eq!(f.k(), 64);
        assert_eq!(f.name(), "TensorSketch");
    }

    #[test]
    fn linearity_over_cp_components() {
        let mut rng = Rng::seed_from(5);
        let dims = [3usize, 4];
        let f = TensorSketch::new(&dims, 9, &mut rng);
        let a = CpTensor::random(&dims, 1, &mut rng);
        let b = CpTensor::random(&dims, 1, &mut rng);
        // Stack a and b into a rank-2 tensor.
        let fa = crate::linalg::Matrix::from_vec(
            3,
            2,
            (0..3).flat_map(|i| [a.factor(0)[(i, 0)], b.factor(0)[(i, 0)]]).collect(),
        );
        let fb = crate::linalg::Matrix::from_vec(
            4,
            2,
            (0..4).flat_map(|i| [a.factor(1)[(i, 0)], b.factor(1)[(i, 0)]]).collect(),
        );
        let ab = CpTensor::from_factors(vec![fa, fb]);
        let ya = f.project_cp(&a);
        let yb = f.project_cp(&b);
        let yab = f.project_cp(&ab);
        for i in 0..9 {
            assert!((yab[i] - ya[i] - yb[i]).abs() < 1e-9);
        }
    }
}
