//! Random projection maps — the paper's core contribution plus every
//! baseline its evaluation compares against.
//!
//! | Map | Paper reference | Structure on rows of `A` | Batched dense kernel (`B` inputs) |
//! |---|---|---|---|
//! | [`GaussianProjection`] | §2.3 | none (dense i.i.d. Gaussian) | one `k×D×B` GEMM, `O(kDB)` |
//! | [`SparseProjection`] | Achlioptas 2003 / Li et al. 2006 | `s`-sparse ±√s | shared nonzero sweep, `O(k(D/s)B)` |
//! | [`TtProjection`] | **Definition 1** | rank-`R` tensor train | batch-folded GEMM chain, `O(kDRB)` |
//! | [`CpProjection`] | **Definition 2** | rank-`R` CP | batch-folded contraction, `O(kDRB)` |
//! | [`TrpProjection`] | Sun et al. 2018 (§3 equivalence) | Khatri-Rao rank-1 average | batch-folded GEMM chain, `O(TDkB)` |
//! | [`KroneckerFjlt`] | Jin et al. 2019 (§4.1 comparison) | per-mode SRHT | workspace-reused FWHT, `O(BD log d)` |
//!
//! All maps implement the [`Projection`] trait, which exposes a
//! format-dispatching [`Projection::project`], per-format fast paths with
//! exactly the complexities the paper states in §3, and a batch-first
//! execution path, [`Projection::project_batch_into`]: the coordinator,
//! the sketch pipeline and the benches all drive whole batches through one
//! call with reusable [`Workspace`] scratch, so the per-call transposes
//! and temporaries of the item-at-a-time path disappear from serving hot
//! loops. Every map's cores/factors are pre-transposed **once at map
//! construction** into the layouts its contraction kernels consume.
//!
//! Batching is not dense-only: a flushed batch of **TT or CP format**
//! inputs — the exact workload the paper optimizes for — is partitioned
//! into shape-groups ([`partition_by_shape`]: dense / per TT rank vector /
//! per CP rank) and each group runs through the blocked compressed-input
//! kernels of `tensor::batch`, one GEMM sequence per group instead of one
//! full contraction chain per item. Items whose dims mismatch the map
//! take the per-item path unchanged.

mod cp;
mod fjlt;
mod gaussian;
pub mod persist;
mod sparse;
mod tensor_sketch;
mod trp;
mod tt;

pub use cp::CpProjection;
pub use fjlt::KroneckerFjlt;
pub use gaussian::GaussianProjection;
pub use sparse::{SparseKind, SparseProjection};
pub use tensor_sketch::TensorSketch;
pub use trp::TrpProjection;
pub use tt::TtProjection;

use crate::tensor::{AnyTensor, CpTensor, DenseTensor, TtTensor};

/// Reusable scratch buffers for the batched projection path.
///
/// **Contract:** a `Workspace` is plain scratch — no call reads state left
/// by a previous call, every kernel fully overwrites what it uses, and any
/// map may be driven with any workspace. Keep one per executing thread
/// (they are cheap when idle): buffers grow to the high-water mark of the
/// batches they serve and are reused, so steady-state batched projection
/// performs no allocation. The coordinator pools them
/// (`coordinator::WorkspacePool`); standalone callers just hold one.
#[derive(Debug, Default)]
pub struct Workspace {
    /// Stacked row-major batch buffer (`B × numel`).
    pub(crate) stack: Vec<f64>,
    /// Contraction-chain ping-pong buffer A.
    pub(crate) chain_a: Vec<f64>,
    /// Contraction-chain ping-pong buffer B.
    pub(crate) chain_b: Vec<f64>,
    /// Per-row batched results (`B`).
    pub(crate) tmp: Vec<f64>,
    /// Compressed-batch boundary/state panel (tensor::batch kernels).
    pub(crate) panel_a: Vec<f64>,
    /// Compressed-batch GEMM operand panel. (A third regroup/staging
    /// panel existed until the TT×TT regroup permutes were fused into
    /// the GEMM's pack prologue / store epilogue —
    /// `linalg::matmul_gather_scatter_acc` — so the kernels no longer
    /// round-trip panels through scratch.)
    pub(crate) panel_b: Vec<f64>,
}

impl Workspace {
    /// New empty workspace (buffers grow on first use).
    pub fn new() -> Self {
        Self::default()
    }
}

/// Per-item fallback for `project_batch_into`: dispatch each input
/// through [`Projection::project`]. One implementation shared by the
/// trait default and every override's non-uniform-batch branch.
pub(crate) fn fallback_batch_into<P: Projection + ?Sized>(
    map: &P,
    xs: &[AnyTensor],
    out: &mut [f64],
) {
    let k = map.k();
    for (x, dst) in xs.iter().zip(out.chunks_exact_mut(k)) {
        dst.copy_from_slice(&map.project(x));
    }
}

/// Batched-kernel eligibility: every item dense with exactly the map's
/// dims. The single source of truth for the fast-path/fallback split —
/// shared by the stacking maps (via [`stack_dense_batch`]) and the
/// non-stacking ones (sparse, FJLT).
pub(crate) fn dense_batch_uniform(xs: &[AnyTensor], dims: &[usize]) -> bool {
    xs.iter()
        .all(|x| matches!(x, AnyTensor::Dense(t) if t.dims() == dims))
}

/// A mixed batch partitioned into the shape-groups the batched kernels
/// consume: one group of all dense items, one group per distinct TT rank
/// vector, one group per distinct CP rank. Groups hold item indices into
/// the original batch in arrival order, so scattered writes land each
/// item's output at its own `out` row.
pub(crate) struct ShapeGroups {
    /// Dense items (uniform by the map-dims check).
    pub dense: Vec<usize>,
    /// TT items, one group per distinct rank vector.
    pub tt: Vec<Vec<usize>>,
    /// CP items, one group per distinct rank.
    pub cp: Vec<Vec<usize>>,
    /// Items whose dims mismatch the map's: they take the per-item path,
    /// which surfaces the same shape-mismatch panic as before.
    pub stragglers: Vec<usize>,
}

/// Partition a batch by `(format, shape)` for the compressed-input batch
/// kernels. The single source of truth for the shape-grouping rules
/// (documented in the README's performance section).
pub(crate) fn partition_by_shape(xs: &[AnyTensor], dims: &[usize]) -> ShapeGroups {
    let mut groups = ShapeGroups {
        dense: Vec::new(),
        tt: Vec::new(),
        cp: Vec::new(),
        stragglers: Vec::new(),
    };
    let mut tt_keys: Vec<Vec<usize>> = Vec::new();
    let mut cp_keys: Vec<usize> = Vec::new();
    for (i, x) in xs.iter().enumerate() {
        if x.dims() != dims {
            groups.stragglers.push(i);
            continue;
        }
        match x {
            AnyTensor::Dense(_) => groups.dense.push(i),
            AnyTensor::Tt(t) => {
                match tt_keys.iter().position(|k| k.as_slice() == t.ranks()) {
                    Some(g) => groups.tt[g].push(i),
                    None => {
                        tt_keys.push(t.ranks().to_vec());
                        groups.tt.push(vec![i]);
                    }
                }
            }
            AnyTensor::Cp(t) => match cp_keys.iter().position(|&r| r == t.rank()) {
                Some(g) => groups.cp[g].push(i),
                None => {
                    cp_keys.push(t.rank());
                    groups.cp.push(vec![i]);
                }
            },
        }
    }
    groups
}

/// Collect the TT items of one shape-group (indices from
/// [`partition_by_shape`], so the format is guaranteed).
pub(crate) fn tt_group_items<'a>(xs: &'a [AnyTensor], group: &[usize]) -> Vec<&'a TtTensor> {
    group
        .iter()
        .map(|&i| match &xs[i] {
            AnyTensor::Tt(t) => t,
            _ => unreachable!("TT shape-group holds a non-TT item"),
        })
        .collect()
}

/// Collect the CP items of one shape-group.
pub(crate) fn cp_group_items<'a>(xs: &'a [AnyTensor], group: &[usize]) -> Vec<&'a CpTensor> {
    group
        .iter()
        .map(|&i| match &xs[i] {
            AnyTensor::Cp(t) => t,
            _ => unreachable!("CP shape-group holds a non-CP item"),
        })
        .collect()
}

/// Scatter a group-local `[group.len(), k]` kernel result into the global
/// batch buffer, applying the map's scale per element — the same final
/// multiply the per-item paths perform, so scattered outputs stay
/// bit-identical to per-item dispatch.
pub(crate) fn scatter_scaled(
    vals: &[f64],
    group: &[usize],
    k: usize,
    scale: f64,
    out: &mut [f64],
) {
    for (gi, &target) in group.iter().enumerate() {
        let src = &vals[gi * k..(gi + 1) * k];
        for (dst, &v) in out[target * k..(target + 1) * k].iter_mut().zip(src) {
            *dst = v * scale;
        }
    }
}

/// Stack the dense items named by `group` (indices from
/// [`partition_by_shape`], format guaranteed) row-major into `stack`.
pub(crate) fn stack_dense_group(xs: &[AnyTensor], group: &[usize], stack: &mut Vec<f64>) {
    stack.clear();
    for &i in group {
        if let AnyTensor::Dense(t) = &xs[i] {
            stack.extend_from_slice(t.data());
        }
    }
}

/// Stack a batch of dense tensors of shape `dims` row-major into `stack`
/// (`B × ∏dims`). Returns `false` — leaving `stack` unspecified — when any
/// item is non-dense or has mismatched dims, in which case callers fall
/// back to per-item dispatch.
pub(crate) fn stack_dense_batch(
    xs: &[AnyTensor],
    dims: &[usize],
    stack: &mut Vec<f64>,
) -> bool {
    if !dense_batch_uniform(xs, dims) {
        return false;
    }
    stack.clear();
    let numel: usize = dims.iter().product();
    stack.reserve(xs.len() * numel);
    for x in xs {
        if let AnyTensor::Dense(t) = x {
            stack.extend_from_slice(t.data());
        }
    }
    true
}

/// A linear map `R^{d₁×…×d_N} → R^k` that (approximately) preserves
/// Euclidean geometry — a Johnson-Lindenstrauss transform.
pub trait Projection: Send + Sync {
    /// Human-readable name including parameters, e.g. `"TT(R=5)"`.
    fn name(&self) -> String;

    /// Input mode sizes `d₁,…,d_N`.
    fn input_dims(&self) -> &[usize];

    /// Embedding dimension `k`.
    fn k(&self) -> usize;

    /// Number of stored parameters (the paper's memory comparison).
    fn num_params(&self) -> usize;

    /// Project a dense input.
    fn project_dense(&self, x: &DenseTensor) -> Vec<f64>;

    /// Project an input given in TT format.
    ///
    /// Default: densify (correct but memory-bound — concrete maps override
    /// with the compressed-format contraction the paper describes).
    fn project_tt(&self, x: &TtTensor) -> Vec<f64> {
        self.project_dense(&x.to_dense())
    }

    /// Project an input given in CP format.
    fn project_cp(&self, x: &CpTensor) -> Vec<f64> {
        self.project_dense(&x.to_dense())
    }

    /// Format-dispatching projection.
    fn project(&self, x: &AnyTensor) -> Vec<f64> {
        match x {
            AnyTensor::Dense(t) => self.project_dense(t),
            AnyTensor::Tt(t) => self.project_tt(t),
            AnyTensor::Cp(t) => self.project_cp(t),
        }
    }

    /// Project a whole batch into a caller-provided buffer laid out
    /// row-major as `[xs.len(), k]`, reusing `ws` for every intermediate.
    ///
    /// Contract: `out.len() == xs.len() * k()`, and on return
    /// `out[b·k..(b+1)·k]` is **bit-identical** to `project(&xs[b])` — the
    /// batched kernels only fold the batch into the leading dimension of
    /// row-independent GEMMs, never reassociate per-item arithmetic
    /// (property-tested in `rust/tests/projection_batch_props.rs`).
    ///
    /// The default dispatches per item (correct for any map); the six
    /// structured maps override it with stacked kernels that amortize
    /// parameter traffic and eliminate per-call allocation.
    fn project_batch_into(&self, xs: &[AnyTensor], out: &mut [f64], ws: &mut Workspace) {
        assert_eq!(out.len(), xs.len() * self.k(), "batch output buffer size");
        let _ = ws;
        fallback_batch_into(self, xs, out);
    }

    /// Allocating convenience wrapper around
    /// [`Projection::project_batch_into`].
    fn project_batch(&self, xs: &[AnyTensor], ws: &mut Workspace) -> Vec<f64> {
        let mut out = vec![0.0; xs.len() * self.k()];
        self.project_batch_into(xs, &mut out, ws);
        out
    }
}

/// Distortion ratio `D(f, X) = | ‖f(X)‖²/‖X‖² − 1 |` — the embedding
/// quality metric of the paper's §6.
pub fn distortion_ratio(projected: &[f64], input_norm: f64) -> f64 {
    let pn2: f64 = projected.iter().map(|v| v * v).sum();
    (pn2 / (input_norm * input_norm) - 1.0).abs()
}

/// Squared norm of a projected vector.
pub fn squared_norm(y: &[f64]) -> f64 {
    y.iter().map(|v| v * v).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn distortion_ratio_of_perfect_isometry_is_zero() {
        // ‖y‖² == ‖x‖² ⇒ distortion 0.
        let y = [3.0, 4.0];
        assert!((distortion_ratio(&y, 5.0) - 0.0).abs() < 1e-12);
    }

    #[test]
    fn distortion_ratio_detects_inflation() {
        let y = [2.0];
        // ‖y‖² = 4, ‖x‖² = 1 ⇒ ratio |4 − 1| = 3.
        assert!((distortion_ratio(&y, 1.0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn dispatch_matches_direct_calls() {
        let mut rng = Rng::seed_from(3);
        let dims = [3usize, 4, 3];
        let f = TtProjection::new(&dims, 2, 8, &mut rng);
        let x = TtTensor::random_unit(&dims, 2, &mut rng);
        let via_dispatch = f.project(&AnyTensor::Tt(x.clone()));
        let direct = f.project_tt(&x);
        assert_eq!(via_dispatch, direct);
    }
}
