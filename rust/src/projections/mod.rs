//! Random projection maps — the paper's core contribution plus every
//! baseline its evaluation compares against.
//!
//! | Map | Paper reference | Structure on rows of `A` |
//! |---|---|---|
//! | [`GaussianProjection`] | §2.3 | none (dense i.i.d. Gaussian) |
//! | [`SparseProjection`] | Achlioptas 2003 / Li et al. 2006 | `s`-sparse ±√s |
//! | [`TtProjection`] | **Definition 1** | rank-`R` tensor train |
//! | [`CpProjection`] | **Definition 2** | rank-`R` CP |
//! | [`TrpProjection`] | Sun et al. 2018 (§3 equivalence) | Khatri-Rao rank-1 average |
//! | [`KroneckerFjlt`] | Jin et al. 2019 (§4.1 comparison) | per-mode SRHT |
//!
//! All maps implement the [`Projection`] trait, which exposes both a
//! format-dispatching [`Projection::project`] and per-format fast paths
//! with exactly the complexities the paper states in §3.

mod cp;
mod fjlt;
mod gaussian;
pub mod persist;
mod sparse;
mod tensor_sketch;
mod trp;
mod tt;

pub use cp::CpProjection;
pub use fjlt::KroneckerFjlt;
pub use gaussian::GaussianProjection;
pub use sparse::{SparseKind, SparseProjection};
pub use tensor_sketch::TensorSketch;
pub use trp::TrpProjection;
pub use tt::TtProjection;

use crate::tensor::{AnyTensor, CpTensor, DenseTensor, TtTensor};

/// A linear map `R^{d₁×…×d_N} → R^k` that (approximately) preserves
/// Euclidean geometry — a Johnson-Lindenstrauss transform.
pub trait Projection: Send + Sync {
    /// Human-readable name including parameters, e.g. `"TT(R=5)"`.
    fn name(&self) -> String;

    /// Input mode sizes `d₁,…,d_N`.
    fn input_dims(&self) -> &[usize];

    /// Embedding dimension `k`.
    fn k(&self) -> usize;

    /// Number of stored parameters (the paper's memory comparison).
    fn num_params(&self) -> usize;

    /// Project a dense input.
    fn project_dense(&self, x: &DenseTensor) -> Vec<f64>;

    /// Project an input given in TT format.
    ///
    /// Default: densify (correct but memory-bound — concrete maps override
    /// with the compressed-format contraction the paper describes).
    fn project_tt(&self, x: &TtTensor) -> Vec<f64> {
        self.project_dense(&x.to_dense())
    }

    /// Project an input given in CP format.
    fn project_cp(&self, x: &CpTensor) -> Vec<f64> {
        self.project_dense(&x.to_dense())
    }

    /// Format-dispatching projection.
    fn project(&self, x: &AnyTensor) -> Vec<f64> {
        match x {
            AnyTensor::Dense(t) => self.project_dense(t),
            AnyTensor::Tt(t) => self.project_tt(t),
            AnyTensor::Cp(t) => self.project_cp(t),
        }
    }
}

/// Distortion ratio `D(f, X) = | ‖f(X)‖²/‖X‖² − 1 |` — the embedding
/// quality metric of the paper's §6.
pub fn distortion_ratio(projected: &[f64], input_norm: f64) -> f64 {
    let pn2: f64 = projected.iter().map(|v| v * v).sum();
    (pn2 / (input_norm * input_norm) - 1.0).abs()
}

/// Squared norm of a projected vector.
pub fn squared_norm(y: &[f64]) -> f64 {
    y.iter().map(|v| v * v).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn distortion_ratio_of_perfect_isometry_is_zero() {
        // ‖y‖² == ‖x‖² ⇒ distortion 0.
        let y = [3.0, 4.0];
        assert!((distortion_ratio(&y, 5.0) - 0.0).abs() < 1e-12);
    }

    #[test]
    fn distortion_ratio_detects_inflation() {
        let y = [2.0];
        // ‖y‖² = 4, ‖x‖² = 1 ⇒ ratio |4 − 1| = 3.
        assert!((distortion_ratio(&y, 1.0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn dispatch_matches_direct_calls() {
        let mut rng = Rng::seed_from(3);
        let dims = [3usize, 4, 3];
        let f = TtProjection::new(&dims, 2, 8, &mut rng);
        let x = TtTensor::random_unit(&dims, 2, &mut rng);
        let via_dispatch = f.project(&AnyTensor::Tt(x.clone()));
        let direct = f.project_tt(&x);
        assert_eq!(via_dispatch, direct);
    }
}
