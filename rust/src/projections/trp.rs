//! `f_TRP(T)` — the Tensor Random Projection of Sun et al. (2018),
//! implemented independently so the paper's §3 equivalence claims can be
//! *tested* rather than assumed:
//!
//! * `f_TRP ≡ f_CP(1)`, and
//! * `f_TRP(T) ≡ f_CP(R)` for `R = T`
//!
//! (exact equality under the factor rescaling `B = (1/T)^{1/2N}·A`, see
//! [`TrpProjection::as_cp_projection`]).
//!
//! `f_TRP(X) = (1/√k)·(A¹ ⊙ A² ⊙ … ⊙ A^N)ᵀ·vec(X)` with `Aⁿ ∈ R^{dₙ×k}`
//! i.i.d. standard normal, `⊙` the column-wise Khatri-Rao product;
//! `f_TRP(T)` averages `T` independent such maps scaled by `1/√T`.

use super::{CpProjection, Projection, Workspace};
use crate::linalg::{matmul_into, Matrix};
use crate::rng::{GaussianSource, Rng};
use crate::tensor::{
    AnyTensor, CpBatchContraction, CpTensor, DenseTensor, TtBatchContraction, TtTensor,
};

/// Khatri-Rao tensor random projection (variance-reduced with `T` terms).
pub struct TrpProjection {
    dims: Vec<usize>,
    k: usize,
    t: usize,
    /// `factors[t][n]` is `Aⁿ` of the `t`-th independent TRP: `dₙ × k`
    /// (the layout the dense GEMM kernels consume).
    factors: Vec<Vec<Matrix>>,
    /// `factors_t[t][n]` is `Aⁿ` transposed to `[k, dₙ]` row-major — the
    /// layout the compressed-input kernels consume, pre-transposed once
    /// at construction like every other map's parameters.
    factors_t: Vec<Vec<Vec<f64>>>,
    scale: f64,
}

impl TrpProjection {
    /// Draw a fresh `f_TRP(T)`; `t = 1` gives the plain TRP.
    pub fn new(dims: &[usize], t: usize, k: usize, rng: &mut Rng) -> Self {
        assert!(t >= 1 && k >= 1);
        let factors: Vec<Vec<Matrix>> = (0..t)
            .map(|_| {
                dims.iter()
                    .map(|&d| Matrix::from_vec(d, k, rng.gaussian_vec(d * k, 1.0)))
                    .collect()
            })
            .collect();
        let factors_t = factors
            .iter()
            .map(|term| {
                term.iter()
                    .map(|a| {
                        let (d, kk) = (a.rows(), a.cols());
                        let ad = a.data();
                        let mut ft = vec![0.0; kk * d];
                        for i in 0..d {
                            for col in 0..kk {
                                ft[col * d + i] = ad[i * kk + col];
                            }
                        }
                        ft
                    })
                    .collect()
            })
            .collect();
        Self {
            dims: dims.to_vec(),
            k,
            t,
            factors,
            factors_t,
            // 1/√k from the JLT scaling, 1/√T from the averaging.
            scale: 1.0 / ((k * t) as f64).sqrt(),
        }
    }

    /// Number of averaged TRPs `T`.
    pub fn t(&self) -> usize {
        self.t
    }

    /// Construct the **exactly equal** `f_CP(R = T)` map: row `i` of the
    /// CP map has factor matrices `Bⁿᵢ[:, t] = (1/T)^{1/2N}·Aⁿ_t[:, i]`.
    ///
    /// With this rescaling the two maps agree entrywise on every input —
    /// the §3 equivalence made concrete.
    pub fn as_cp_projection(&self) -> CpProjection {
        let n = self.dims.len();
        // Definition-2 variance for rank T and order N is (1/T)^{1/N};
        // each standard-normal factor must be scaled by its square root.
        let factor_scale = GaussianSource::cp_factor_std(n, self.t);
        let rows: Vec<CpTensor> = (0..self.k)
            .map(|i| {
                let factors: Vec<Matrix> = (0..n)
                    .map(|mode| {
                        let d = self.dims[mode];
                        let mut m = Matrix::zeros(d, self.t);
                        for t in 0..self.t {
                            let a = &self.factors[t][mode];
                            for row in 0..d {
                                m[(row, t)] = factor_scale * a[(row, i)];
                            }
                        }
                        m
                    })
                    .collect();
                CpTensor::from_factors(factors)
            })
            .collect();
        CpProjection::from_rows(self.dims.clone(), self.t, self.k, rows)
    }

    /// Dense contraction kernel shared by the single-item and batched
    /// paths: project `bsz` tensors stacked row-major in `stacked`,
    /// writing `[bsz, k]` into `out`. For each independent TRP the modes
    /// contract right-to-left with the batch folded into the leading GEMM
    /// dimension; `bsz = 1` is exactly [`Projection::project_dense`], so
    /// batched results are bit-identical by construction.
    fn dense_stacked(
        &self,
        stacked: &[f64],
        bsz: usize,
        out: &mut [f64],
        cur: &mut Vec<f64>,
        next: &mut Vec<f64>,
    ) {
        let n = self.dims.len();
        let kk = self.k;
        for o in out[..bsz * kk].iter_mut() {
            *o = 0.0;
        }
        for t in 0..self.t {
            // First contraction handles the last mode with a plain GEMM:
            // cur[B·prefix, k] = X_mat[B·prefix, d_N] · A^N.
            let d_last = self.dims[n - 1];
            let prefix = stacked.len() / d_last;
            let a_last = &self.factors[t][n - 1];
            cur.clear();
            cur.resize(prefix * kk, 0.0);
            matmul_into(stacked, a_last.data(), cur, prefix, d_last, kk);
            let mut rows = prefix;
            // Remaining modes: column-matched contraction
            // cur[p, i_col] = Σ_i cur[(p·d + i), i_col] · Aⁿ[i, i_col].
            for mode in (0..n - 1).rev() {
                let d = self.dims[mode];
                let pref = rows / d;
                let a = &self.factors[t][mode];
                next.clear();
                next.resize(pref * kk, 0.0);
                for p in 0..pref {
                    let dst = &mut next[p * kk..(p + 1) * kk];
                    for i in 0..d {
                        let src = &cur[(p * d + i) * kk..(p * d + i + 1) * kk];
                        let arow = a.row(i);
                        for c in 0..kk {
                            dst[c] += src[c] * arow[c];
                        }
                    }
                }
                std::mem::swap(cur, next);
                rows = pref;
            }
            debug_assert_eq!(rows, bsz);
            for (acc, &v) in out[..bsz * kk].iter_mut().zip(cur.iter()) {
                *acc += v;
            }
        }
        for v in out[..bsz * kk].iter_mut() {
            *v *= self.scale;
        }
    }
}

impl CpProjection {
    /// Build a CP projection from explicit rows (used by the TRP
    /// equivalence construction and by tests).
    pub fn from_rows(dims: Vec<usize>, rank: usize, k: usize, rows: Vec<CpTensor>) -> Self {
        assert_eq!(rows.len(), k);
        for r in &rows {
            assert_eq!(r.dims(), &dims[..]);
            assert_eq!(r.rank(), rank);
        }
        Self::from_parts(dims, rank, k, rows)
    }
}

impl Projection for TrpProjection {
    fn name(&self) -> String {
        if self.t == 1 {
            "TRP".to_string()
        } else {
            format!("TRP(T={})", self.t)
        }
    }

    fn input_dims(&self) -> &[usize] {
        &self.dims
    }

    fn k(&self) -> usize {
        self.k
    }

    fn num_params(&self) -> usize {
        self.t * self.dims.iter().map(|d| d * self.k).sum::<usize>()
    }

    fn project_dense(&self, x: &DenseTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        let mut y = vec![0.0; self.k];
        let (mut cur, mut next) = (Vec::new(), Vec::new());
        self.dense_stacked(x.data(), 1, &mut y, &mut cur, &mut next);
        y
    }

    fn project_batch_into(&self, xs: &[AnyTensor], out: &mut [f64], ws: &mut Workspace) {
        let k = self.k;
        assert_eq!(out.len(), xs.len() * k, "batch output buffer size");
        if xs.is_empty() {
            return;
        }
        if super::stack_dense_batch(xs, &self.dims, &mut ws.stack) {
            // `dense_stacked` already emits the required [B, k] layout.
            let b = xs.len();
            self.dense_stacked(&ws.stack, b, out, &mut ws.chain_a, &mut ws.chain_b);
            return;
        }
        // Compressed/mixed batch: blocked kernels per shape-group — each
        // averaged Khatri-Rao term is a rank-1 chain, stacked T·k wide.
        let groups = super::partition_by_shape(xs, &self.dims);
        if !groups.dense.is_empty() {
            super::stack_dense_group(xs, &groups.dense, &mut ws.stack);
            ws.tmp.clear();
            ws.tmp.resize(groups.dense.len() * k, 0.0);
            self.dense_stacked(
                &ws.stack,
                groups.dense.len(),
                &mut ws.tmp,
                &mut ws.chain_a,
                &mut ws.chain_b,
            );
            // `dense_stacked` already applied the scale; scatter verbatim.
            for (gi, &target) in groups.dense.iter().enumerate() {
                out[target * k..(target + 1) * k].copy_from_slice(&ws.tmp[gi * k..(gi + 1) * k]);
            }
        }
        for group in &groups.tt {
            let items = super::tt_group_items(xs, group);
            let ctx = TtBatchContraction::for_compressed_rows(&items);
            ws.tmp.clear();
            ws.tmp.resize(group.len() * k, 0.0);
            ctx.inner_trp_into(&self.factors_t, k, &mut ws.tmp, &mut ws.panel_a, &mut ws.panel_b);
            super::scatter_scaled(&ws.tmp, group, k, self.scale, out);
        }
        for group in &groups.cp {
            let items = super::cp_group_items(xs, group);
            let ctx = CpBatchContraction::new(&items);
            ws.tmp.clear();
            ws.tmp.resize(group.len() * k, 0.0);
            ctx.gram_trp_into(&self.factors_t, k, &mut ws.tmp, &mut ws.panel_a, &mut ws.panel_b);
            super::scatter_scaled(&ws.tmp, group, k, self.scale, out);
        }
        for &i in &groups.stragglers {
            out[i * k..(i + 1) * k].copy_from_slice(&self.project(&xs[i]));
        }
    }

    fn project_tt(&self, x: &TtTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        // Compressed-format fast path (the seed densified here, which both
        // lost the paper's cost advantage and refused high-order inputs):
        // a group of one through the blocked kernel the batched path uses,
        // so batched outputs are bit-identical by construction.
        let ctx = TtBatchContraction::for_compressed_rows(&[x]);
        let mut out = vec![0.0; self.k];
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        ctx.inner_trp_into(&self.factors_t, self.k, &mut out, &mut pa, &mut pb);
        for v in &mut out {
            *v *= self.scale;
        }
        out
    }

    fn project_cp(&self, x: &CpTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        let ctx = CpBatchContraction::new(&[x]);
        let mut out = vec![0.0; self.k];
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        ctx.gram_trp_into(&self.factors_t, self.k, &mut out, &mut pa, &mut pb);
        for v in &mut out {
            *v *= self.scale;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::TtTensor;

    #[test]
    fn equivalent_cp_map_agrees_exactly_on_dense_inputs() {
        let mut rng = Rng::seed_from(1);
        let dims = [3usize, 4, 2];
        for t in [1usize, 3] {
            let trp = TrpProjection::new(&dims, t, 6, &mut rng);
            let cp = trp.as_cp_projection();
            let x = DenseTensor::random(&dims, &mut rng);
            let y_trp = trp.project_dense(&x);
            let y_cp = cp.project_dense(&x);
            for (a, b) in y_trp.iter().zip(&y_cp) {
                assert!((a - b).abs() < 1e-9, "T={t}: trp={a} cp={b}");
            }
        }
    }

    #[test]
    fn equivalent_cp_map_agrees_on_tt_inputs() {
        // The CP view unlocks the fast TT-input path; results must match
        // the TRP's dense computation.
        let mut rng = Rng::seed_from(2);
        let dims = [3usize, 3, 3, 3];
        let trp = TrpProjection::new(&dims, 2, 5, &mut rng);
        let cp = trp.as_cp_projection();
        let x = TtTensor::random_unit(&dims, 2, &mut rng);
        let y_fast = cp.project_tt(&x);
        let y_ref = trp.project_dense(&x.to_dense());
        for (a, b) in y_fast.iter().zip(&y_ref) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn trp1_is_rank_one_cp() {
        let mut rng = Rng::seed_from(3);
        let trp = TrpProjection::new(&[4, 4], 1, 3, &mut rng);
        let cp = trp.as_cp_projection();
        assert_eq!(cp.rank(), 1);
        assert_eq!(cp.name(), "CP(R=1)");
    }

    #[test]
    fn compressed_inputs_match_dense_reference() {
        // The TRP's own TT/CP fast paths (the seed densified here) must
        // agree with the dense computation.
        let mut rng = Rng::seed_from(6);
        let dims = [3usize, 3, 2];
        for t in [1usize, 2] {
            let trp = TrpProjection::new(&dims, t, 5, &mut rng);
            let x_tt = TtTensor::random_unit(&dims, 2, &mut rng);
            let y = trp.project_tt(&x_tt);
            let want = trp.project_dense(&x_tt.to_dense());
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-9, "T={t}: tt={a} dense={b}");
            }
            let x_cp = CpTensor::random_unit(&dims, 3, &mut rng);
            let y = trp.project_cp(&x_cp);
            let want = trp.project_dense(&x_cp.to_dense());
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-9, "T={t}: cp={a} dense={b}");
            }
        }
    }

    #[test]
    fn compressed_inputs_work_on_high_order_without_densifying() {
        // d=3, N=25 — the seed's densifying fallback would refuse this.
        let mut rng = Rng::seed_from(7);
        let dims = vec![3usize; 25];
        let trp = TrpProjection::new(&dims, 2, 4, &mut rng);
        let y = trp.project_tt(&TtTensor::random_unit(&dims, 3, &mut rng));
        assert_eq!(y.len(), 4);
        assert!(y.iter().all(|v| v.is_finite()));
        let y = trp.project_cp(&CpTensor::random_unit(&dims, 2, &mut rng));
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn num_params_matches_sun_et_al() {
        // T·k·Σ dₙ parameters.
        let mut rng = Rng::seed_from(4);
        let trp = TrpProjection::new(&[3, 5, 2], 4, 7, &mut rng);
        assert_eq!(trp.num_params(), 4 * 7 * (3 + 5 + 2));
    }

    #[test]
    fn expected_isometry() {
        let mut rng = Rng::seed_from(5);
        let dims = [3usize, 3, 3];
        let x = DenseTensor::random_unit(&dims, &mut rng);
        let norms: Vec<f64> = (0..400)
            .map(|_| {
                let f = TrpProjection::new(&dims, 2, 8, &mut rng);
                crate::projections::squared_norm(&f.project_dense(&x))
            })
            .collect();
        let m = crate::util::stats::mean(&norms);
        assert!((m - 1.0).abs() < 0.1, "mean={m}");
    }
}
