//! `f_CP(R)` — the CP random projection of **Definition 2**.
//!
//! Component `i` is `(1/√k)·⟨[[A¹ᵢ,…,A^Nᵢ]], X⟩` with all factor entries
//! i.i.d. `N(0, (1/R)^{1/N})`. Storage `O(kNdR)`; projecting CP inputs
//! costs `O(kNd·max(R,R̃)²)` and TT inputs `O(kNd·max(R,R̃)³)`.
//!
//! The paper's central negative result: the variance bound carries a
//! `3^{N-1}` factor that the rank `R` cannot mitigate, so this map needs
//! `k` exponential in `N` — implemented here both as a first-class map and
//! as the foil for the TT map in every experiment.

use super::Projection;
use crate::rng::Rng;
use crate::tensor::{CpTensor, DenseTensor, TtTensor};

/// CP random projection map.
pub struct CpProjection {
    dims: Vec<usize>,
    rank: usize,
    k: usize,
    /// The `k` random CP rows.
    rows: Vec<CpTensor>,
    scale: f64,
}

impl CpProjection {
    /// Draw a fresh `f_CP(R)` for inputs of shape `dims` into `R^k`.
    pub fn new(dims: &[usize], rank: usize, k: usize, rng: &mut Rng) -> Self {
        assert!(rank >= 1, "CP rank must be ≥ 1");
        assert!(k >= 1, "embedding dimension must be ≥ 1");
        let rows = (0..k)
            .map(|_| CpTensor::random_projection_row(dims, rank, rng))
            .collect();
        Self {
            dims: dims.to_vec(),
            rank,
            k,
            rows,
            scale: 1.0 / (k as f64).sqrt(),
        }
    }

    /// Assemble a map from pre-built rows (internal; used by the TRP
    /// equivalence construction via [`CpProjection::from_rows`]).
    pub(crate) fn from_parts(dims: Vec<usize>, rank: usize, k: usize, rows: Vec<CpTensor>) -> Self {
        Self {
            dims,
            rank,
            k,
            rows,
            scale: 1.0 / (k as f64).sqrt(),
        }
    }

    /// The CP rank `R` of the map.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// The random CP rows.
    pub fn rows(&self) -> &[CpTensor] {
        &self.rows
    }

    /// Inner product of one CP row with a dense tensor:
    /// `⟨[[A¹,…,A^N]], X⟩ = Σ_r ⟨a¹_r ∘ … ∘ a^N_r, X⟩`, each rank-one term
    /// contracted mode by mode (`O(D)` per component, right-to-left).
    fn row_dense_inner(row: &CpTensor, x: &DenseTensor) -> f64 {
        let dims = x.dims();
        let n = dims.len();
        let mut total = 0.0;
        // Reusable buffers across rank components.
        let mut cur: Vec<f64> = Vec::new();
        for r in 0..row.rank() {
            // Contract the last mode: cur[prefix] = Σ_i X[prefix, i]·a^N[i].
            let d_last = dims[n - 1];
            let prefix = x.numel() / d_last;
            cur.clear();
            cur.resize(prefix, 0.0);
            let f_last = row.factor(n - 1);
            for p in 0..prefix {
                let base = p * d_last;
                let mut acc = 0.0;
                for i in 0..d_last {
                    acc += x.data()[base + i] * f_last[(i, r)];
                }
                cur[p] = acc;
            }
            // Contract remaining modes right-to-left.
            for m in (0..n - 1).rev() {
                let d = dims[m];
                let pref = cur.len() / d;
                let f = row.factor(m);
                for p in 0..pref {
                    let mut acc = 0.0;
                    for i in 0..d {
                        acc += cur[p * d + i] * f[(i, r)];
                    }
                    cur[p] = acc;
                }
                cur.truncate(pref);
            }
            total += cur[0];
        }
        total
    }
}

impl Projection for CpProjection {
    fn name(&self) -> String {
        format!("CP(R={})", self.rank)
    }

    fn input_dims(&self) -> &[usize] {
        &self.dims
    }

    fn k(&self) -> usize {
        self.k
    }

    fn num_params(&self) -> usize {
        self.rows.iter().map(|r| r.num_params()).sum()
    }

    fn project_dense(&self, x: &DenseTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        self.rows
            .iter()
            .map(|row| Self::row_dense_inner(row, x) * self.scale)
            .collect()
    }

    fn project_tt(&self, x: &TtTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        self.rows
            .iter()
            .map(|row| row.inner_tt(x) * self.scale)
            .collect()
    }

    fn project_cp(&self, x: &CpTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        self.rows
            .iter()
            .map(|row| row.inner(x) * self.scale)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projections::squared_norm;
    use crate::util::stats::mean;

    #[test]
    fn all_input_formats_agree() {
        let mut rng = Rng::seed_from(1);
        let dims = [3usize, 2, 4, 2];
        let f = CpProjection::new(&dims, 3, 9, &mut rng);
        let x_cp = CpTensor::random_unit(&dims, 2, &mut rng);
        let y_cp = f.project_cp(&x_cp);
        let y_dense = f.project_dense(&x_cp.to_dense());
        for (a, b) in y_cp.iter().zip(&y_dense) {
            assert!((a - b).abs() < 1e-9, "cp={a} dense={b}");
        }
        let x_tt = TtTensor::random_unit(&dims, 2, &mut rng);
        let y_tt = f.project_tt(&x_tt);
        let y_td = f.project_dense(&x_tt.to_dense());
        for (a, b) in y_tt.iter().zip(&y_td) {
            assert!((a - b).abs() < 1e-9, "tt={a} dense={b}");
        }
    }

    #[test]
    fn expected_isometry_over_maps() {
        // Theorem 1: E‖f_CP(X)‖² = ‖X‖²_F.
        let mut rng = Rng::seed_from(2);
        let dims = [3usize, 3, 3];
        let x = CpTensor::random_unit(&dims, 2, &mut rng);
        let norms: Vec<f64> = (0..500)
            .map(|_| {
                let f = CpProjection::new(&dims, 2, 8, &mut rng);
                squared_norm(&f.project_cp(&x))
            })
            .collect();
        let m = mean(&norms);
        assert!((m - 1.0).abs() < 0.1, "mean={m}");
    }

    #[test]
    fn num_params_matches_paper_formula() {
        // NdR per row, k rows.
        let mut rng = Rng::seed_from(3);
        let (d, n, r, k) = (5usize, 6usize, 4usize, 3usize);
        let f = CpProjection::new(&vec![d; n], r, k, &mut rng);
        assert_eq!(f.num_params(), k * n * d * r);
    }

    #[test]
    fn works_on_high_order_without_densifying() {
        let mut rng = Rng::seed_from(4);
        let dims = vec![3usize; 25];
        let f = CpProjection::new(&dims, 4, 4, &mut rng);
        let x = TtTensor::random_unit(&dims, 3, &mut rng);
        let y = f.project_tt(&x);
        assert_eq!(y.len(), 4);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn cp_memory_is_below_tt_memory_at_matched_rank() {
        // The paper compares ranks giving ≈ equal parameter counts;
        // at the *same* rank CP stores ~R× fewer parameters.
        let mut rng = Rng::seed_from(5);
        let dims = vec![3usize; 8];
        let f_cp = CpProjection::new(&dims, 10, 4, &mut rng);
        let f_tt = crate::projections::TtProjection::new(&dims, 10, 4, &mut rng);
        assert!(f_cp.num_params() < f_tt.num_params());
    }

    #[test]
    fn name_includes_rank() {
        let mut rng = Rng::seed_from(6);
        let f = CpProjection::new(&[3, 3], 25, 2, &mut rng);
        assert_eq!(f.name(), "CP(R=25)");
    }
}
