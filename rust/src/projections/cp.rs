//! `f_CP(R)` — the CP random projection of **Definition 2**.
//!
//! Component `i` is `(1/√k)·⟨[[A¹ᵢ,…,A^Nᵢ]], X⟩` with all factor entries
//! i.i.d. `N(0, (1/R)^{1/N})`. Storage `O(kNdR)`; projecting CP inputs
//! costs `O(kNd·max(R,R̃)²)` and TT inputs `O(kNd·max(R,R̃)³)`.
//!
//! The paper's central negative result: the variance bound carries a
//! `3^{N-1}` factor that the rank `R` cannot mitigate, so this map needs
//! `k` exponential in `N` — implemented here both as a first-class map and
//! as the foil for the TT map in every experiment.
//!
//! The `k` rows are resident **once**, as the transposed `[R, dₙ]` factor
//! layout every execution path consumes; the raw factor matrices are
//! derived on demand by [`CpProjection::rows`] for the cold paths.

use super::{Projection, Workspace};
use crate::linalg::Matrix;
use crate::rng::Rng;
use crate::tensor::{
    AnyTensor, CpBatchContraction, CpTensor, DenseTensor, TtBatchContraction, TtTensor,
};

/// CP random projection map.
pub struct CpProjection {
    dims: Vec<usize>,
    rank: usize,
    k: usize,
    /// Per row, per mode: the factor transposed to `[R, dₙ]` row-major so
    /// each rank component's column is a contiguous slice — precomputed
    /// once at construction, consumed by the dense contraction kernel,
    /// the Gram kernels and the right-to-left compressed chains. The
    /// rows' only resident copy.
    rows_t: Vec<Vec<Vec<f64>>>,
    scale: f64,
}

impl CpProjection {
    /// Draw a fresh `f_CP(R)` for inputs of shape `dims` into `R^k`.
    pub fn new(dims: &[usize], rank: usize, k: usize, rng: &mut Rng) -> Self {
        assert!(rank >= 1, "CP rank must be ≥ 1");
        assert!(k >= 1, "embedding dimension must be ≥ 1");
        let rows = (0..k)
            .map(|_| CpTensor::random_projection_row(dims, rank, rng))
            .collect();
        Self::from_parts(dims.to_vec(), rank, k, rows)
    }

    /// Assemble a map from pre-built rows (internal; used by the TRP
    /// equivalence construction via [`CpProjection::from_rows`]). The raw
    /// factors are transposed into the resident layout and dropped.
    pub(crate) fn from_parts(dims: Vec<usize>, rank: usize, k: usize, rows: Vec<CpTensor>) -> Self {
        let rows_t = rows
            .iter()
            .map(|row| {
                assert_eq!(row.rank(), rank, "row rank mismatch");
                (0..dims.len())
                    .map(|m| {
                        let f = row.factor(m);
                        let d = dims[m];
                        let mut t = vec![0.0; row.rank() * d];
                        for r in 0..row.rank() {
                            for i in 0..d {
                                t[r * d + i] = f[(i, r)];
                            }
                        }
                        t
                    })
                    .collect()
            })
            .collect();
        Self {
            dims,
            rank,
            k,
            rows_t,
            scale: 1.0 / (k as f64).sqrt(),
        }
    }

    /// The CP rank `R` of the map.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// The random CP rows in raw factor layout, derived on demand from
    /// the resident transposed factors (cold path: AOT packing and JSON
    /// serialization; bit-exact round-trip).
    pub fn rows(&self) -> Vec<CpTensor> {
        self.rows_t
            .iter()
            .map(|row| {
                let factors = row
                    .iter()
                    .zip(&self.dims)
                    .map(|(t, &d)| {
                        let mut f = Matrix::zeros(d, self.rank);
                        for r in 0..self.rank {
                            for i in 0..d {
                                f[(i, r)] = t[r * d + i];
                            }
                        }
                        f
                    })
                    .collect();
                CpTensor::from_factors(factors)
            })
            .collect()
    }

    /// Stored parameter count — one transposed copy of every factor (the
    /// seed stored every row twice: raw + transposed).
    pub fn resident_params(&self) -> usize {
        self.rows_t
            .iter()
            .map(|row| row.iter().map(Vec::len).sum::<usize>())
            .sum()
    }

    /// Inner products of one CP row with `bsz` dense tensors stacked
    /// row-major in `stacked`:
    /// `⟨[[A¹,…,A^N]], X⟩ = Σ_r ⟨a¹_r ∘ … ∘ a^N_r, X⟩`, each rank-one term
    /// contracted mode by mode right-to-left with the batch folded into
    /// the leading (prefix) dimension. `bsz = 1` is the single-item path,
    /// so batched results are bit-identical by construction.
    fn row_dense_stacked(
        ft: &[Vec<f64>],
        rank: usize,
        dims: &[usize],
        stacked: &[f64],
        bsz: usize,
        out: &mut [f64],
        cur: &mut Vec<f64>,
    ) {
        let n = dims.len();
        debug_assert_eq!(stacked.len() % bsz.max(1), 0);
        for o in out[..bsz].iter_mut() {
            *o = 0.0;
        }
        for r in 0..rank {
            // Contract the last mode: cur[B·prefix] = Σ_i X[·, i]·a^N_r[i].
            let d_last = dims[n - 1];
            let prefix = stacked.len() / d_last;
            cur.clear();
            cur.resize(prefix, 0.0);
            let f_last = &ft[n - 1][r * d_last..(r + 1) * d_last];
            for p in 0..prefix {
                let base = p * d_last;
                let mut acc = 0.0;
                for (i, &fv) in f_last.iter().enumerate() {
                    acc += stacked[base + i] * fv;
                }
                cur[p] = acc;
            }
            // Contract remaining modes right-to-left.
            for m in (0..n - 1).rev() {
                let d = dims[m];
                let pref = cur.len() / d;
                let f = &ft[m][r * d..(r + 1) * d];
                for p in 0..pref {
                    let mut acc = 0.0;
                    for (i, &fv) in f.iter().enumerate() {
                        acc += cur[p * d + i] * fv;
                    }
                    cur[p] = acc;
                }
                cur.truncate(pref);
            }
            for (o, &v) in out[..bsz].iter_mut().zip(cur.iter()) {
                *o += v;
            }
        }
    }

    /// Dense kernel over an explicit target list (the mixed-batch dense
    /// shape-group): identical arithmetic to the uniform path, scattered
    /// writes.
    fn dense_group_into(
        &self,
        stacked: &[f64],
        targets: &[usize],
        out: &mut [f64],
        tmp: &mut Vec<f64>,
        cur: &mut Vec<f64>,
    ) {
        let k = self.k;
        tmp.clear();
        tmp.resize(targets.len(), 0.0);
        for (i, ft) in self.rows_t.iter().enumerate() {
            Self::row_dense_stacked(ft, self.rank, &self.dims, stacked, targets.len(), tmp, cur);
            for (&target, &v) in targets.iter().zip(tmp.iter()) {
                out[target * k + i] = v * self.scale;
            }
        }
    }
}

impl Projection for CpProjection {
    fn name(&self) -> String {
        format!("CP(R={})", self.rank)
    }

    fn input_dims(&self) -> &[usize] {
        &self.dims
    }

    fn k(&self) -> usize {
        self.k
    }

    fn num_params(&self) -> usize {
        self.resident_params()
    }

    fn project_dense(&self, x: &DenseTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        let mut cur = Vec::new();
        let mut one = [0.0];
        self.rows_t
            .iter()
            .map(|ft| {
                Self::row_dense_stacked(ft, self.rank, &self.dims, x.data(), 1, &mut one, &mut cur);
                one[0] * self.scale
            })
            .collect()
    }

    fn project_batch_into(&self, xs: &[AnyTensor], out: &mut [f64], ws: &mut Workspace) {
        let k = self.k;
        assert_eq!(out.len(), xs.len() * k, "batch output buffer size");
        if xs.is_empty() {
            return;
        }
        if super::stack_dense_batch(xs, &self.dims, &mut ws.stack) {
            let b = xs.len();
            ws.tmp.clear();
            ws.tmp.resize(b, 0.0);
            for (i, ft) in self.rows_t.iter().enumerate() {
                Self::row_dense_stacked(
                    ft,
                    self.rank,
                    &self.dims,
                    &ws.stack,
                    b,
                    &mut ws.tmp,
                    &mut ws.chain_a,
                );
                for (bi, &v) in ws.tmp.iter().enumerate() {
                    out[bi * k + i] = v * self.scale;
                }
            }
            return;
        }
        // Compressed/mixed batch: blocked kernels per shape-group.
        let groups = super::partition_by_shape(xs, &self.dims);
        if !groups.dense.is_empty() {
            super::stack_dense_group(xs, &groups.dense, &mut ws.stack);
            // Split-borrow the workspace fields the helper needs.
            let (stack, tmp, cur) = (&ws.stack, &mut ws.tmp, &mut ws.chain_a);
            self.dense_group_into(stack, &groups.dense, out, tmp, cur);
        }
        for group in &groups.tt {
            let items = super::tt_group_items(xs, group);
            let ctx = TtBatchContraction::for_compressed_rows(&items);
            ws.tmp.clear();
            ws.tmp.resize(group.len() * k, 0.0);
            ctx.inner_cp_rows_into(
                &self.rows_t,
                self.rank,
                &mut ws.tmp,
                &mut ws.panel_a,
                &mut ws.panel_b,
            );
            super::scatter_scaled(&ws.tmp, group, k, self.scale, out);
        }
        for group in &groups.cp {
            let items = super::cp_group_items(xs, group);
            let ctx = CpBatchContraction::new(&items);
            ws.tmp.clear();
            ws.tmp.resize(group.len() * k, 0.0);
            ctx.gram_cp_rows_into(
                &self.rows_t,
                self.rank,
                &mut ws.tmp,
                &mut ws.panel_a,
                &mut ws.panel_b,
            );
            super::scatter_scaled(&ws.tmp, group, k, self.scale, out);
        }
        for &i in &groups.stragglers {
            out[i * k..(i + 1) * k].copy_from_slice(&self.project(&xs[i]));
        }
    }

    fn project_tt(&self, x: &TtTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        // Group of one through the blocked kernel the batched path uses —
        // batched outputs are bit-identical by construction.
        let ctx = TtBatchContraction::for_compressed_rows(&[x]);
        let mut out = vec![0.0; self.k];
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        ctx.inner_cp_rows_into(&self.rows_t, self.rank, &mut out, &mut pa, &mut pb);
        for v in &mut out {
            *v *= self.scale;
        }
        out
    }

    fn project_cp(&self, x: &CpTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        let ctx = CpBatchContraction::new(&[x]);
        let mut out = vec![0.0; self.k];
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        ctx.gram_cp_rows_into(&self.rows_t, self.rank, &mut out, &mut pa, &mut pb);
        for v in &mut out {
            *v *= self.scale;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projections::squared_norm;
    use crate::util::stats::mean;

    #[test]
    fn all_input_formats_agree() {
        let mut rng = Rng::seed_from(1);
        let dims = [3usize, 2, 4, 2];
        let f = CpProjection::new(&dims, 3, 9, &mut rng);
        let x_cp = CpTensor::random_unit(&dims, 2, &mut rng);
        let y_cp = f.project_cp(&x_cp);
        let y_dense = f.project_dense(&x_cp.to_dense());
        for (a, b) in y_cp.iter().zip(&y_dense) {
            assert!((a - b).abs() < 1e-9, "cp={a} dense={b}");
        }
        let x_tt = TtTensor::random_unit(&dims, 2, &mut rng);
        let y_tt = f.project_tt(&x_tt);
        let y_td = f.project_dense(&x_tt.to_dense());
        for (a, b) in y_tt.iter().zip(&y_td) {
            assert!((a - b).abs() < 1e-9, "tt={a} dense={b}");
        }
    }

    #[test]
    fn expected_isometry_over_maps() {
        // Theorem 1: E‖f_CP(X)‖² = ‖X‖²_F.
        let mut rng = Rng::seed_from(2);
        let dims = [3usize, 3, 3];
        let x = CpTensor::random_unit(&dims, 2, &mut rng);
        let norms: Vec<f64> = (0..500)
            .map(|_| {
                let f = CpProjection::new(&dims, 2, 8, &mut rng);
                squared_norm(&f.project_cp(&x))
            })
            .collect();
        let m = mean(&norms);
        assert!((m - 1.0).abs() < 0.1, "mean={m}");
    }

    #[test]
    fn num_params_matches_paper_formula() {
        // NdR per row, k rows.
        let mut rng = Rng::seed_from(3);
        let (d, n, r, k) = (5usize, 6usize, 4usize, 3usize);
        let f = CpProjection::new(&vec![d; n], r, k, &mut rng);
        assert_eq!(f.num_params(), k * n * d * r);
    }

    #[test]
    fn parameters_are_resident_once() {
        // Memory dedup: only the transposed factor layout is resident;
        // the raw rows derive on demand and round-trip bit-exactly.
        let mut rng = Rng::seed_from(9);
        let dims = [3usize, 4, 2];
        let f = CpProjection::new(&dims, 3, 5, &mut rng);
        assert_eq!(f.resident_params(), f.num_params());
        let rows = f.rows();
        assert_eq!(rows.len(), 5);
        let g = CpProjection::from_rows(dims.to_vec(), 3, 5, rows);
        let x = CpTensor::random_unit(&dims, 2, &mut rng);
        assert_eq!(f.project_cp(&x), g.project_cp(&x), "derived rows must round-trip");
    }

    #[test]
    fn works_on_high_order_without_densifying() {
        let mut rng = Rng::seed_from(4);
        let dims = vec![3usize; 25];
        let f = CpProjection::new(&dims, 4, 4, &mut rng);
        let x = TtTensor::random_unit(&dims, 3, &mut rng);
        let y = f.project_tt(&x);
        assert_eq!(y.len(), 4);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn cp_memory_is_below_tt_memory_at_matched_rank() {
        // The paper compares ranks giving ≈ equal parameter counts;
        // at the *same* rank CP stores ~R× fewer parameters.
        let mut rng = Rng::seed_from(5);
        let dims = vec![3usize; 8];
        let f_cp = CpProjection::new(&dims, 10, 4, &mut rng);
        let f_tt = crate::projections::TtProjection::new(&dims, 10, 4, &mut rng);
        assert!(f_cp.num_params() < f_tt.num_params());
    }

    #[test]
    fn name_includes_rank() {
        let mut rng = Rng::seed_from(6);
        let f = CpProjection::new(&[3, 3], 25, 2, &mut rng);
        assert_eq!(f.name(), "CP(R=25)");
    }
}
