//! Sparse and very-sparse random projections (Achlioptas 2003; Li,
//! Hastie & Church 2006) — the strongest classical baselines in the
//! paper's Figures 1 (medium), 2 and 4.
//!
//! Rows have i.i.d. entries `±√s` with probability `1/(2s)` each and `0`
//! otherwise; `s = 3` (Achlioptas) or `s = √D` (very sparse). Rows are
//! stored compressed (indices + values), so memory is `O(kD/s)` and dense
//! projection costs `O(kD/s)`.
//!
//! For inputs in TT/CP format the projection evaluates only the input
//! entries under the nonzeros (`O(k·(D/s)·N·r²)` for TT) — this is the
//! very-sparse-RP-on-TT-input series of Figure 2, and is precisely where
//! the tensorized maps win.

use super::{Projection, Workspace};
use crate::rng::{Rng, SparseEntry, SparseSampler};
use crate::tensor::{AnyTensor, CpTensor, DenseTensor, Shape, TtTensor};

/// Which sparsity regime a [`SparseProjection`] uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SparseKind {
    /// Achlioptas' database-friendly scheme, `s = 3`.
    Achlioptas,
    /// Li et al.'s very sparse scheme, `s = √D`.
    VerySparse,
}

/// Sparse JL transform with compressed rows.
pub struct SparseProjection {
    dims: Vec<usize>,
    k: usize,
    kind: SparseKind,
    /// Compressed rows: sorted (index, value) pairs.
    rows: Vec<Vec<SparseEntry>>,
    scale: f64,
}

impl SparseProjection {
    /// Draw a fresh sparse map.
    pub fn new(dims: &[usize], k: usize, kind: SparseKind, rng: &mut Rng) -> Self {
        let d: usize = dims.iter().product();
        let sampler = match kind {
            SparseKind::Achlioptas => SparseSampler::achlioptas(),
            SparseKind::VerySparse => SparseSampler::very_sparse(d),
        };
        let rows = (0..k).map(|_| sampler.sample_row(d, rng)).collect();
        Self {
            dims: dims.to_vec(),
            k,
            kind,
            rows,
            scale: 1.0 / (k as f64).sqrt(),
        }
    }

    /// The sparsity parameter `s` in use.
    pub fn s(&self) -> f64 {
        match self.kind {
            SparseKind::Achlioptas => 3.0,
            SparseKind::VerySparse => {
                (self.dims.iter().product::<usize>() as f64).sqrt().max(1.0)
            }
        }
    }

    /// Total stored nonzeros.
    pub fn total_nnz(&self) -> usize {
        self.rows.iter().map(|r| r.len()).sum()
    }
}

impl Projection for SparseProjection {
    fn name(&self) -> String {
        match self.kind {
            SparseKind::Achlioptas => "Sparse(s=3)".to_string(),
            SparseKind::VerySparse => "VerySparse".to_string(),
        }
    }

    fn input_dims(&self) -> &[usize] {
        &self.dims
    }

    fn k(&self) -> usize {
        self.k
    }

    fn num_params(&self) -> usize {
        // index + value per stored nonzero.
        2 * self.total_nnz()
    }

    fn project_dense(&self, x: &DenseTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        let data = x.data();
        self.rows
            .iter()
            .map(|row| {
                let mut acc = 0.0;
                for e in row {
                    acc += e.value * data[e.index];
                }
                acc * self.scale
            })
            .collect()
    }

    fn project_tt(&self, x: &TtTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        let shape = Shape::new(x.dims());
        // Allocation-free inner loop with prefix-cached TT evaluation:
        // row nonzeros are sorted, so consecutive entries share long index
        // prefixes the evaluator skips recomputing.
        let mut idx = vec![0usize; x.order()];
        let mut eval = crate::tensor::TtEntryEvaluator::new(x);
        self.rows
            .iter()
            .map(|row| {
                let mut acc = 0.0;
                for e in row {
                    shape.multi_into(e.index, &mut idx);
                    acc += e.value * eval.eval(&idx);
                }
                acc * self.scale
            })
            .collect()
    }

    fn project_cp(&self, x: &CpTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        let shape = Shape::new(x.dims());
        let mut idx = vec![0usize; x.order()];
        self.rows
            .iter()
            .map(|row| {
                let mut acc = 0.0;
                for e in row {
                    shape.multi_into(e.index, &mut idx);
                    acc += e.value * x.get(&idx);
                }
                acc * self.scale
            })
            .collect()
    }

    fn project_batch_into(&self, xs: &[AnyTensor], out: &mut [f64], ws: &mut Workspace) {
        let k = self.k;
        assert_eq!(out.len(), xs.len() * k, "batch output buffer size");
        let _ = ws; // compressed rows need no scratch
        if !super::dense_batch_uniform(xs, &self.dims) {
            super::fallback_batch_into(self, xs, out);
            return;
        }
        // Dense batch: sweep each compressed row once and contract it
        // against every item while its (index, value) pairs are hot in
        // cache — the sparse analogue of the stacked GEMM (a dense GEMM
        // would materialize the rows and forfeit the O(D/s) sparsity).
        // Entry order per (row, item) matches `project_dense`, so the
        // accumulation is bit-identical.
        for (ri, row) in self.rows.iter().enumerate() {
            for (bi, x) in xs.iter().enumerate() {
                let AnyTensor::Dense(t) = x else { unreachable!() };
                let data = t.data();
                let mut acc = 0.0;
                for e in row {
                    acc += e.value * data[e.index];
                }
                out[bi * k + ri] = acc * self.scale;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projections::squared_norm;
    use crate::util::stats::mean;

    #[test]
    fn tt_path_matches_dense_path() {
        let mut rng = Rng::seed_from(1);
        let dims = [3usize, 4, 3, 2];
        let f = SparseProjection::new(&dims, 9, SparseKind::VerySparse, &mut rng);
        let x = TtTensor::random_unit(&dims, 3, &mut rng);
        let via_tt = f.project_tt(&x);
        let via_dense = f.project_dense(&x.to_dense());
        for (a, b) in via_tt.iter().zip(&via_dense) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn cp_path_matches_dense_path() {
        let mut rng = Rng::seed_from(2);
        let dims = [3usize, 4, 3];
        let f = SparseProjection::new(&dims, 6, SparseKind::Achlioptas, &mut rng);
        let x = CpTensor::random_unit(&dims, 3, &mut rng);
        let via_cp = f.project_cp(&x);
        let via_dense = f.project_dense(&x.to_dense());
        for (a, b) in via_cp.iter().zip(&via_dense) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn expected_isometry_achlioptas() {
        let mut rng = Rng::seed_from(3);
        let dims = [8usize, 8];
        let x = DenseTensor::random_unit(&dims, &mut rng);
        let norms: Vec<f64> = (0..400)
            .map(|_| {
                let f = SparseProjection::new(&dims, 16, SparseKind::Achlioptas, &mut rng);
                squared_norm(&f.project_dense(&x))
            })
            .collect();
        let m = mean(&norms);
        assert!((m - 1.0).abs() < 0.06, "mean={m}");
    }

    #[test]
    fn very_sparse_memory_is_sublinear() {
        let mut rng = Rng::seed_from(4);
        let dims = [4usize; 6]; // D = 4096, s = 64, ~64 nnz per row
        let f = SparseProjection::new(&dims, 10, SparseKind::VerySparse, &mut rng);
        let dense_params = 10 * 4096;
        assert!(
            f.num_params() < dense_params / 10,
            "nnz params {} should be ≪ dense {}",
            f.num_params(),
            dense_params
        );
    }

    #[test]
    fn name_and_s() {
        let mut rng = Rng::seed_from(5);
        let f = SparseProjection::new(&[10, 10], 2, SparseKind::VerySparse, &mut rng);
        assert_eq!(f.name(), "VerySparse");
        assert!((f.s() - 10.0).abs() < 1e-12);
    }
}
