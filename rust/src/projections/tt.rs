//! `f_TT(R)` — the tensor-train random projection of **Definition 1**,
//! the paper's headline contribution.
//!
//! Component `i` of the map is `(1/√k)·⟨⟨⟨G¹ᵢ,…,G^Nᵢ⟩⟩, X⟩` with Gaussian
//! cores (`Var = 1/√R` boundary, `1/R` interior). Storage `O(kNdR²)`;
//! projection cost `O(kNd·max(R,R̃)³)` for rank-`R̃` TT or CP inputs.
//!
//! The `k` rows are resident **once**, as the pre-transposed
//! [`TtDenseContraction`] contexts every execution path (dense and
//! compressed, single and batched) consumes; the raw-core view is derived
//! on demand by [`TtProjection::rows`] for the cold paths (AOT packing,
//! serialization), mirroring `gaussian::matrix()`.

use super::{Projection, Workspace};
use crate::rng::Rng;
use crate::tensor::{
    AnyTensor, CpBatchContraction, CpTensor, DenseTensor, TtBatchContraction, TtDenseContraction,
    TtTensor,
};

/// Tensor-train random projection map.
pub struct TtProjection {
    dims: Vec<usize>,
    rank: usize,
    k: usize,
    /// Per-row contraction contexts: every row's cores transposed once at
    /// construction into the GEMM layout shared by the dense chain and
    /// the compressed-input batch kernels — the rows' only resident copy.
    row_ctxs: Vec<TtDenseContraction>,
    scale: f64,
}

impl TtProjection {
    /// Draw a fresh `f_TT(R)` for inputs of shape `dims` into `R^k`.
    pub fn new(dims: &[usize], rank: usize, k: usize, rng: &mut Rng) -> Self {
        assert!(rank >= 1, "TT rank must be ≥ 1");
        assert!(k >= 1, "embedding dimension must be ≥ 1");
        let rows = (0..k)
            .map(|_| TtTensor::random_projection_row(dims, rank, rng))
            .collect();
        Self::from_parts(dims.to_vec(), rank, k, rows)
    }

    /// Assemble a map from pre-built rows (deserialization path; see
    /// [`TtProjection::from_rows`]). The raw rows are transposed into the
    /// resident contraction layout and dropped.
    pub(crate) fn from_parts(dims: Vec<usize>, rank: usize, k: usize, rows: Vec<TtTensor>) -> Self {
        let row_ctxs = rows.iter().map(TtDenseContraction::new).collect();
        Self {
            dims,
            rank,
            k,
            row_ctxs,
            scale: 1.0 / (k as f64).sqrt(),
        }
    }

    /// The TT rank `R` of the map.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// The random TT rows in raw-core layout, derived on demand from the
    /// resident transposed contexts (cold path: AOT packing and JSON
    /// serialization; bit-exact round-trip).
    pub fn rows(&self) -> Vec<TtTensor> {
        self.row_ctxs.iter().map(|c| c.to_tt()).collect()
    }

    /// Stored parameter count — one transposed copy of every core. The
    /// memory-dedup regression test pins this to [`Projection::num_params`]
    /// (the seed stored every row twice: raw + transposed).
    pub fn resident_params(&self) -> usize {
        self.row_ctxs.iter().map(|c| c.num_elems()).sum()
    }

    /// Parallel TT-input projection: shard the `k` rows across `threads`
    /// workers (each with its own panel scratch). Bit-identical to
    /// [`Projection::project_tt`] — the batch kernel's stacked GEMMs
    /// compute each row's chain independently, so row-subsets reproduce
    /// the full map's values exactly. Used by the experiment sweeps when
    /// a single very large projection dominates (e.g. k ≥ 1000).
    pub fn project_tt_parallel(&self, x: &TtTensor, threads: usize) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        if threads <= 1 || self.k < 2 * threads {
            return self.project_tt(x);
        }
        let ctx = TtBatchContraction::for_tt_map(&[x]);
        let chunk = self.k.div_ceil(threads);
        let chunks: Vec<&[TtDenseContraction]> = self.row_ctxs.chunks(chunk).collect();
        let parts = crate::util::threadpool::par_map(chunks, threads, |rows| {
            let mut out = vec![0.0; rows.len()];
            let (mut pa, mut pb) = (Vec::new(), Vec::new());
            ctx.inner_tt_rows_into(rows, &mut out, &mut pa, &mut pb);
            for v in &mut out {
                *v *= self.scale;
            }
            out
        });
        parts.into_iter().flatten().collect()
    }
}

impl Projection for TtProjection {
    fn name(&self) -> String {
        format!("TT(R={})", self.rank)
    }

    fn input_dims(&self) -> &[usize] {
        &self.dims
    }

    fn k(&self) -> usize {
        self.k
    }

    fn num_params(&self) -> usize {
        self.resident_params()
    }

    fn project_dense(&self, x: &DenseTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        // Single item = batch of one through the same pre-transposed
        // contraction contexts (see `row_ctxs`).
        let (mut cur, mut next) = (Vec::new(), Vec::new());
        let mut one = [0.0];
        self.row_ctxs
            .iter()
            .map(|ctx| {
                ctx.inner_stacked_into(x.data(), 1, &mut one, &mut cur, &mut next);
                one[0] * self.scale
            })
            .collect()
    }

    fn project_batch_into(&self, xs: &[AnyTensor], out: &mut [f64], ws: &mut Workspace) {
        let k = self.k;
        assert_eq!(out.len(), xs.len() * k, "batch output buffer size");
        if xs.is_empty() {
            return;
        }
        if super::stack_dense_batch(xs, &self.dims, &mut ws.stack) {
            // Uniform dense batch: fold all B inputs into the leading GEMM
            // dimension of each row's absorption chain — one chain of
            // B×-taller GEMMs per row instead of B separate chains.
            let b = xs.len();
            ws.tmp.clear();
            ws.tmp.resize(b, 0.0);
            for (i, ctx) in self.row_ctxs.iter().enumerate() {
                ctx.inner_stacked_into(&ws.stack, b, &mut ws.tmp, &mut ws.chain_a, &mut ws.chain_b);
                for (bi, &v) in ws.tmp.iter().enumerate() {
                    out[bi * k + i] = v * self.scale;
                }
            }
            return;
        }
        // Compressed/mixed batch: one blocked kernel per shape-group —
        // the per-item contraction chains fold into k + B GEMMs per mode
        // (TT groups) or one stacked GEMM per row per mode (CP groups).
        let groups = super::partition_by_shape(xs, &self.dims);
        if !groups.dense.is_empty() {
            super::stack_dense_group(xs, &groups.dense, &mut ws.stack);
            ws.tmp.clear();
            ws.tmp.resize(groups.dense.len(), 0.0);
            for (i, ctx) in self.row_ctxs.iter().enumerate() {
                ctx.inner_stacked_into(
                    &ws.stack,
                    groups.dense.len(),
                    &mut ws.tmp,
                    &mut ws.chain_a,
                    &mut ws.chain_b,
                );
                for (&target, &v) in groups.dense.iter().zip(ws.tmp.iter()) {
                    out[target * k + i] = v * self.scale;
                }
            }
        }
        for group in &groups.tt {
            let items = super::tt_group_items(xs, group);
            let ctx = TtBatchContraction::for_tt_map(&items);
            ws.tmp.clear();
            ws.tmp.resize(group.len() * k, 0.0);
            ctx.inner_tt_rows_into(&self.row_ctxs, &mut ws.tmp, &mut ws.panel_a, &mut ws.panel_b);
            super::scatter_scaled(&ws.tmp, group, k, self.scale, out);
        }
        for group in &groups.cp {
            let items = super::cp_group_items(xs, group);
            let ctx = CpBatchContraction::new(&items);
            ws.tmp.clear();
            ws.tmp.resize(group.len() * k, 0.0);
            ctx.inner_tt_rows_into(&self.row_ctxs, &mut ws.tmp, &mut ws.panel_a, &mut ws.panel_b);
            super::scatter_scaled(&ws.tmp, group, k, self.scale, out);
        }
        for &i in &groups.stragglers {
            out[i * k..(i + 1) * k].copy_from_slice(&self.project(&xs[i]));
        }
    }

    fn project_tt(&self, x: &TtTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        // Group of one through the same blocked kernel the batched path
        // uses — batched outputs are bit-identical by construction.
        let ctx = TtBatchContraction::for_tt_map(&[x]);
        let mut out = vec![0.0; self.k];
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        ctx.inner_tt_rows_into(&self.row_ctxs, &mut out, &mut pa, &mut pb);
        for v in &mut out {
            *v *= self.scale;
        }
        out
    }

    fn project_cp(&self, x: &CpTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        let ctx = CpBatchContraction::new(&[x]);
        let mut out = vec![0.0; self.k];
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        ctx.inner_tt_rows_into(&self.row_ctxs, &mut out, &mut pa, &mut pb);
        for v in &mut out {
            *v *= self.scale;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projections::squared_norm;
    use crate::util::stats::{mean, variance};

    #[test]
    fn all_input_formats_agree() {
        let mut rng = Rng::seed_from(1);
        let dims = [3usize, 4, 2, 3];
        let f = TtProjection::new(&dims, 3, 11, &mut rng);
        let x_tt = TtTensor::random_unit(&dims, 2, &mut rng);
        let x_dense = x_tt.to_dense();
        let y_tt = f.project_tt(&x_tt);
        let y_dense = f.project_dense(&x_dense);
        for (a, b) in y_tt.iter().zip(&y_dense) {
            assert!((a - b).abs() < 1e-9, "tt={a} dense={b}");
        }
        // CP input: build a CP tensor and compare against its dense form.
        let x_cp = CpTensor::random_unit(&dims, 2, &mut rng);
        let y_cp = f.project_cp(&x_cp);
        let y_cd = f.project_dense(&x_cp.to_dense());
        for (a, b) in y_cp.iter().zip(&y_cd) {
            assert!((a - b).abs() < 1e-9, "cp={a} dense={b}");
        }
    }

    #[test]
    fn expected_isometry_over_maps() {
        // Theorem 1: E‖f_TT(X)‖² = ‖X‖²_F.
        let mut rng = Rng::seed_from(2);
        let dims = [3usize, 3, 3, 3];
        let x = TtTensor::random_unit(&dims, 2, &mut rng);
        // Larger k lowers the per-trial variance (Theorem 1), so the
        // CLT tolerance can stay tight without many more trials.
        let norms: Vec<f64> = (0..500)
            .map(|_| {
                let f = TtProjection::new(&dims, 2, 32, &mut rng);
                squared_norm(&f.project_tt(&x))
            })
            .collect();
        let m = mean(&norms);
        assert!((m - 1.0).abs() < 0.15, "mean={m}");
    }

    #[test]
    fn variance_decreases_with_k() {
        // Theorem 1: Var(‖f(X)‖²) ≤ C/k — doubling k should roughly halve
        // the variance. Checked with generous tolerance.
        let mut rng = Rng::seed_from(3);
        let dims = [3usize; 4];
        let x = TtTensor::random_unit(&dims, 2, &mut rng);
        let sample = |k: usize, rng: &mut Rng| -> f64 {
            let vals: Vec<f64> = (0..300)
                .map(|_| {
                    let f = TtProjection::new(&dims, 3, k, rng);
                    squared_norm(&f.project_tt(&x))
                })
                .collect();
            variance(&vals)
        };
        let v_small = sample(4, &mut rng);
        let v_large = sample(32, &mut rng);
        assert!(
            v_large < v_small * 0.45,
            "variance should shrink ~8x: k=4 → {v_small}, k=32 → {v_large}"
        );
    }

    #[test]
    fn num_params_matches_paper_formula() {
        // (N−2)dR² + 2dR per row, k rows.
        let mut rng = Rng::seed_from(4);
        let (d, n, r, k) = (5usize, 6usize, 3usize, 7usize);
        let f = TtProjection::new(&vec![d; n], r, k, &mut rng);
        assert_eq!(f.num_params(), k * ((n - 2) * d * r * r + 2 * d * r));
    }

    #[test]
    fn parameters_are_resident_once() {
        // Memory dedup: the seed stored every row twice (raw cores for the
        // compressed paths + transposed contexts for the dense GEMMs); now
        // only the transposed layout is resident and the raw view derives
        // on demand, bit-exactly.
        let mut rng = Rng::seed_from(8);
        let dims = [3usize, 4, 3];
        let f = TtProjection::new(&dims, 3, 6, &mut rng);
        assert_eq!(f.resident_params(), f.num_params());
        let rows = f.rows();
        assert_eq!(rows.len(), 6);
        let g = TtProjection::from_rows(dims.to_vec(), 3, 6, rows);
        let x = TtTensor::random_unit(&dims, 2, &mut rng);
        assert_eq!(f.project_tt(&x), g.project_tt(&x), "derived rows must round-trip");
    }

    #[test]
    fn linearity_on_tt_inputs() {
        let mut rng = Rng::seed_from(5);
        let dims = [2usize, 3, 2];
        let f = TtProjection::new(&dims, 2, 6, &mut rng);
        let a = TtTensor::random(&dims, 2, &mut rng);
        let y_a = f.project_tt(&a);
        let mut a2 = a.clone();
        a2.scale(2.0);
        let y_a2 = f.project_tt(&a2);
        for i in 0..6 {
            assert!((y_a2[i] - 2.0 * y_a[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn works_on_high_order_without_densifying() {
        // d=3, N=25: dense dim ≈ 8.5e11 — must still run fast in TT format.
        let mut rng = Rng::seed_from(6);
        let dims = vec![3usize; 25];
        let f = TtProjection::new(&dims, 2, 4, &mut rng);
        let x = TtTensor::random_unit(&dims, 3, &mut rng);
        let y = f.project_tt(&x);
        assert_eq!(y.len(), 4);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn parallel_projection_is_bit_identical() {
        let mut rng = Rng::seed_from(31);
        let dims = vec![3usize; 8];
        let f = TtProjection::new(&dims, 4, 64, &mut rng);
        let x = TtTensor::random_unit(&dims, 5, &mut rng);
        let serial = f.project_tt(&x);
        for threads in [1usize, 2, 4, 7] {
            assert_eq!(f.project_tt_parallel(&x, threads), serial, "threads={threads}");
        }
    }

    #[test]
    fn name_includes_rank() {
        let mut rng = Rng::seed_from(7);
        let f = TtProjection::new(&[3, 3], 5, 2, &mut rng);
        assert_eq!(f.name(), "TT(R=5)");
    }
}
