//! `f_TT(R)` — the tensor-train random projection of **Definition 1**,
//! the paper's headline contribution.
//!
//! Component `i` of the map is `(1/√k)·⟨⟨⟨G¹ᵢ,…,G^Nᵢ⟩⟩, X⟩` with Gaussian
//! cores (`Var = 1/√R` boundary, `1/R` interior). Storage `O(kNdR²)`;
//! projection cost `O(kNd·max(R,R̃)³)` for rank-`R̃` TT or CP inputs.

use super::{Projection, Workspace};
use crate::rng::Rng;
use crate::tensor::{CpTensor, DenseTensor, TtDenseContraction, TtTensor};

/// Tensor-train random projection map.
pub struct TtProjection {
    dims: Vec<usize>,
    rank: usize,
    k: usize,
    /// The `k` random TT rows.
    rows: Vec<TtTensor>,
    /// Per-row dense-contraction contexts: every row's cores transposed
    /// once at construction into the GEMM layout, so the dense projection
    /// hot loop (single *and* batched) performs no per-call transpose.
    row_ctxs: Vec<TtDenseContraction>,
    scale: f64,
}

impl TtProjection {
    /// Draw a fresh `f_TT(R)` for inputs of shape `dims` into `R^k`.
    pub fn new(dims: &[usize], rank: usize, k: usize, rng: &mut Rng) -> Self {
        assert!(rank >= 1, "TT rank must be ≥ 1");
        assert!(k >= 1, "embedding dimension must be ≥ 1");
        let rows = (0..k)
            .map(|_| TtTensor::random_projection_row(dims, rank, rng))
            .collect();
        Self::from_parts(dims.to_vec(), rank, k, rows)
    }

    /// Assemble a map from pre-built rows (deserialization path; see
    /// [`TtProjection::from_rows`]).
    pub(crate) fn from_parts(dims: Vec<usize>, rank: usize, k: usize, rows: Vec<TtTensor>) -> Self {
        let row_ctxs = rows.iter().map(TtDenseContraction::new).collect();
        Self {
            dims,
            rank,
            k,
            rows,
            row_ctxs,
            scale: 1.0 / (k as f64).sqrt(),
        }
    }

    /// The TT rank `R` of the map.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// The random TT rows (used by the AOT runtime to feed the compiled
    /// artifact the same parameters the native engine uses).
    pub fn rows(&self) -> &[TtTensor] {
        &self.rows
    }

    /// Parallel TT-input projection: shard the `k` rows across `threads`
    /// workers (each with its own contraction scratch). Bit-identical to
    /// [`Projection::project_tt`]; used by the experiment sweeps when a
    /// single very large projection dominates (e.g. k ≥ 1000).
    pub fn project_tt_parallel(&self, x: &TtTensor, threads: usize) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        if threads <= 1 || self.k < 2 * threads {
            return self.project_tt(x);
        }
        let chunk = self.k.div_ceil(threads);
        let chunks: Vec<&[TtTensor]> = self.rows.chunks(chunk).collect();
        let parts = crate::util::threadpool::par_map(chunks, threads, |rows| {
            let ctx = crate::tensor::TtContraction::new(x);
            rows.iter()
                .map(|row| ctx.inner(row) * self.scale)
                .collect::<Vec<f64>>()
        });
        parts.into_iter().flatten().collect()
    }

}

impl Projection for TtProjection {
    fn name(&self) -> String {
        format!("TT(R={})", self.rank)
    }

    fn input_dims(&self) -> &[usize] {
        &self.dims
    }

    fn k(&self) -> usize {
        self.k
    }

    fn num_params(&self) -> usize {
        self.rows.iter().map(|r| r.num_params()).sum()
    }

    fn project_dense(&self, x: &DenseTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        // Single item = batch of one through the same pre-transposed
        // contraction contexts (see `row_ctxs`).
        let (mut cur, mut next) = (Vec::new(), Vec::new());
        let mut one = [0.0];
        self.row_ctxs
            .iter()
            .map(|ctx| {
                ctx.inner_stacked_into(x.data(), 1, &mut one, &mut cur, &mut next);
                one[0] * self.scale
            })
            .collect()
    }

    fn project_batch_into(
        &self,
        xs: &[crate::tensor::AnyTensor],
        out: &mut [f64],
        ws: &mut Workspace,
    ) {
        let k = self.k;
        assert_eq!(out.len(), xs.len() * k, "batch output buffer size");
        if xs.is_empty() {
            return;
        }
        if !super::stack_dense_batch(xs, &self.dims, &mut ws.stack) {
            // Compressed/mixed formats: per-item dispatch (bit-identical
            // by definition; the TT/CP fast paths already amortize the
            // per-input contraction context across the k rows).
            super::fallback_batch_into(self, xs, out);
            return;
        }
        // Dense batch: fold all B inputs into the leading GEMM dimension
        // of each row's absorption chain — one chain of B×-taller GEMMs
        // per row instead of B separate chains.
        let b = xs.len();
        ws.tmp.clear();
        ws.tmp.resize(b, 0.0);
        for (i, ctx) in self.row_ctxs.iter().enumerate() {
            ctx.inner_stacked_into(&ws.stack, b, &mut ws.tmp, &mut ws.chain_a, &mut ws.chain_b);
            for (bi, &v) in ws.tmp.iter().enumerate() {
                out[bi * k + i] = v * self.scale;
            }
        }
    }

    fn project_tt(&self, x: &TtTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        // Amortize the x-side core permutation across all k rows and run
        // the per-row chain allocation-free (see TtContraction).
        let ctx = crate::tensor::TtContraction::new(x);
        self.rows
            .iter()
            .map(|row| ctx.inner(row) * self.scale)
            .collect()
    }

    fn project_cp(&self, x: &CpTensor) -> Vec<f64> {
        assert_eq!(x.dims(), self.input_dims(), "input shape mismatch");
        self.rows
            .iter()
            .map(|row| x.inner_tt(row) * self.scale)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projections::squared_norm;
    use crate::util::stats::{mean, variance};

    #[test]
    fn all_input_formats_agree() {
        let mut rng = Rng::seed_from(1);
        let dims = [3usize, 4, 2, 3];
        let f = TtProjection::new(&dims, 3, 11, &mut rng);
        let x_tt = TtTensor::random_unit(&dims, 2, &mut rng);
        let x_dense = x_tt.to_dense();
        let y_tt = f.project_tt(&x_tt);
        let y_dense = f.project_dense(&x_dense);
        for (a, b) in y_tt.iter().zip(&y_dense) {
            assert!((a - b).abs() < 1e-9, "tt={a} dense={b}");
        }
        // CP input: build a CP tensor and compare against its dense form.
        let x_cp = CpTensor::random_unit(&dims, 2, &mut rng);
        let y_cp = f.project_cp(&x_cp);
        let y_cd = f.project_dense(&x_cp.to_dense());
        for (a, b) in y_cp.iter().zip(&y_cd) {
            assert!((a - b).abs() < 1e-9, "cp={a} dense={b}");
        }
    }

    #[test]
    fn expected_isometry_over_maps() {
        // Theorem 1: E‖f_TT(X)‖² = ‖X‖²_F.
        let mut rng = Rng::seed_from(2);
        let dims = [3usize, 3, 3, 3];
        let x = TtTensor::random_unit(&dims, 2, &mut rng);
        // Larger k lowers the per-trial variance (Theorem 1), so the
        // CLT tolerance can stay tight without many more trials.
        let norms: Vec<f64> = (0..500)
            .map(|_| {
                let f = TtProjection::new(&dims, 2, 32, &mut rng);
                squared_norm(&f.project_tt(&x))
            })
            .collect();
        let m = mean(&norms);
        assert!((m - 1.0).abs() < 0.15, "mean={m}");
    }

    #[test]
    fn variance_decreases_with_k() {
        // Theorem 1: Var(‖f(X)‖²) ≤ C/k — doubling k should roughly halve
        // the variance. Checked with generous tolerance.
        let mut rng = Rng::seed_from(3);
        let dims = [3usize; 4];
        let x = TtTensor::random_unit(&dims, 2, &mut rng);
        let sample = |k: usize, rng: &mut Rng| -> f64 {
            let vals: Vec<f64> = (0..300)
                .map(|_| {
                    let f = TtProjection::new(&dims, 3, k, rng);
                    squared_norm(&f.project_tt(&x))
                })
                .collect();
            variance(&vals)
        };
        let v_small = sample(4, &mut rng);
        let v_large = sample(32, &mut rng);
        assert!(
            v_large < v_small * 0.45,
            "variance should shrink ~8x: k=4 → {v_small}, k=32 → {v_large}"
        );
    }

    #[test]
    fn num_params_matches_paper_formula() {
        // (N−2)dR² + 2dR per row, k rows.
        let mut rng = Rng::seed_from(4);
        let (d, n, r, k) = (5usize, 6usize, 3usize, 7usize);
        let f = TtProjection::new(&vec![d; n], r, k, &mut rng);
        assert_eq!(f.num_params(), k * ((n - 2) * d * r * r + 2 * d * r));
    }

    #[test]
    fn linearity_on_tt_inputs() {
        let mut rng = Rng::seed_from(5);
        let dims = [2usize, 3, 2];
        let f = TtProjection::new(&dims, 2, 6, &mut rng);
        let a = TtTensor::random(&dims, 2, &mut rng);
        let y_a = f.project_tt(&a);
        let mut a2 = a.clone();
        a2.scale(2.0);
        let y_a2 = f.project_tt(&a2);
        for i in 0..6 {
            assert!((y_a2[i] - 2.0 * y_a[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn works_on_high_order_without_densifying() {
        // d=3, N=25: dense dim ≈ 8.5e11 — must still run fast in TT format.
        let mut rng = Rng::seed_from(6);
        let dims = vec![3usize; 25];
        let f = TtProjection::new(&dims, 2, 4, &mut rng);
        let x = TtTensor::random_unit(&dims, 3, &mut rng);
        let y = f.project_tt(&x);
        assert_eq!(y.len(), 4);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn parallel_projection_is_bit_identical() {
        let mut rng = Rng::seed_from(31);
        let dims = vec![3usize; 8];
        let f = TtProjection::new(&dims, 4, 64, &mut rng);
        let x = TtTensor::random_unit(&dims, 5, &mut rng);
        let serial = f.project_tt(&x);
        for threads in [1usize, 2, 4, 7] {
            assert_eq!(f.project_tt_parallel(&x, threads), serial, "threads={threads}");
        }
    }

    #[test]
    fn name_includes_rank() {
        let mut rng = Rng::seed_from(7);
        let f = TtProjection::new(&[3, 3], 5, 2, &mut rng);
        assert_eq!(f.name(), "TT(R=5)");
    }
}
