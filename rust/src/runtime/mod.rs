//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them on the request path — Python is never involved at
//! runtime.
//!
//! Flow: `artifacts/manifest.json` → [`Manifest`] → [`PjrtEngine::load_dir`]
//! (`HloModuleProto::from_text_file` → `client.compile`) → [`PjrtEngine::execute`]
//! with packed f32 literals ([`pack`]).

mod artifact;
mod engine;
pub mod pack;

pub use artifact::{ArtifactKind, ArtifactSpec, Manifest, ParamSpec};
pub use engine::{ExecStats, PjrtEngine};
