//! Packing native tensors into the stacked f32 layouts the AOT artifacts
//! expect (see `python/compile/kernels/ref.py` for the layout contract).
//!
//! All layouts are row-major:
//!
//! * TT projection rows → `g_first [k,d,R]`, `g_mid [k,N-2,R,d,R]`,
//!   `g_last [k,R,d]`;
//! * TT input batch → `x_first [B,d,R̃]`, `x_mid [B,N-2,R̃,d,R̃]`,
//!   `x_last [B,R̃,d]`;
//! * CP projection rows → `a [k,N,d,R]`; CP input batch → `x [B,N,d,R̃]`;
//! * dense → `w [k,D]`, `x [B,D]`.
//!
//! Batches smaller than the compiled `B` are zero-padded; the caller slices
//! the first `b·k` outputs.
//!
//! This module is the **ahead-of-time** packing story: f32, whole-tensor
//! layouts fixed by the compiled PJRT artifact, produced once per
//! registration. Its serving-time counterpart lives in `linalg::gemm`,
//! which packs f64 operands into `MR`/`NR` micro-panels *per GEMM call*
//! (zero-padded edge lanes, gather-based A access) for the native packed
//! kernel — same idea (restructure memory once so the hot loop streams
//! contiguously), different layout contract and precision, so the two
//! deliberately do not share code.

use crate::projections::{CpProjection, GaussianProjection, TtProjection};
use crate::tensor::{CpTensor, DenseTensor, TtTensor};
use anyhow::{bail, Result};

/// Check that a TT tensor has the uniform shape an artifact expects.
fn check_tt_uniform(t: &TtTensor, n: usize, d: usize, r: usize, what: &str) -> Result<()> {
    if t.dims() != vec![d; n].as_slice() {
        bail!("{what}: dims {:?} != [{d}; {n}]", t.dims());
    }
    let want = TtTensor::prescribed_ranks(&vec![d; n], r);
    if t.ranks() != want.as_slice() {
        bail!("{what}: ranks {:?} != {want:?}", t.ranks());
    }
    Ok(())
}

/// Pack the rows of a [`TtProjection`] into `(g_first, g_mid, g_last)`.
pub fn pack_tt_projection(
    f: &TtProjection,
    n: usize,
    d: usize,
    r: usize,
) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
    // Cold path: the raw-core rows are derived on demand from the map's
    // resident transposed layout (once per artifact registration).
    let rows = f.rows();
    let k = rows.len();
    let mut g_first = Vec::with_capacity(k * d * r);
    let mut g_mid = Vec::with_capacity(k * (n - 2) * r * d * r);
    let mut g_last = Vec::with_capacity(k * r * d);
    for row in &rows {
        check_tt_uniform(row, n, d, r, "projection row")?;
        push_tt_cores(row, n, &mut g_first, &mut g_mid, &mut g_last);
    }
    Ok((g_first, g_mid, g_last))
}

/// Pack a batch of TT inputs into `(x_first, x_mid, x_last)`, zero-padding
/// to `batch` items.
pub fn pack_tt_inputs(
    xs: &[&TtTensor],
    batch: usize,
    n: usize,
    d: usize,
    rt: usize,
) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
    if xs.len() > batch {
        bail!("batch overflow: {} > {batch}", xs.len());
    }
    let mut x_first = Vec::with_capacity(batch * d * rt);
    let mut x_mid = Vec::with_capacity(batch * (n - 2) * rt * d * rt);
    let mut x_last = Vec::with_capacity(batch * rt * d);
    for x in xs {
        check_tt_uniform(x, n, d, rt, "input")?;
        push_tt_cores(x, n, &mut x_first, &mut x_mid, &mut x_last);
    }
    // Zero-pad the remaining slots.
    x_first.resize(batch * d * rt, 0.0);
    x_mid.resize(batch * (n - 2) * rt * d * rt, 0.0);
    x_last.resize(batch * rt * d, 0.0);
    Ok((x_first, x_mid, x_last))
}

/// Append one TT tensor's cores to the stacked buffers.
///
/// The native core layouts already match: core 0 is `[1,d,r] ≡ [d,r]`,
/// interior cores are `[r,d,r]`, the last core is `[r,d,1] ≡ [r,d]`.
fn push_tt_cores(
    t: &TtTensor,
    n: usize,
    first: &mut Vec<f32>,
    mid: &mut Vec<f32>,
    last: &mut Vec<f32>,
) {
    first.extend(t.core(0).iter().map(|&v| v as f32));
    for m in 1..n - 1 {
        mid.extend(t.core(m).iter().map(|&v| v as f32));
    }
    last.extend(t.core(n - 1).iter().map(|&v| v as f32));
}

/// Pack the rows of a [`CpProjection`] into `a [k,N,d,R]`.
pub fn pack_cp_projection(f: &CpProjection, n: usize, d: usize, r: usize) -> Result<Vec<f32>> {
    let rows = f.rows();
    let mut a = Vec::with_capacity(rows.len() * n * d * r);
    for row in &rows {
        if row.dims() != vec![d; n].as_slice() || row.rank() != r {
            bail!(
                "projection row: dims {:?} rank {} != ([{d};{n}], {r})",
                row.dims(),
                row.rank()
            );
        }
        for mode in 0..n {
            // Factor is d×R row-major — exactly the [d, R] slab we need.
            a.extend(row.factor(mode).data().iter().map(|&v| v as f32));
        }
    }
    Ok(a)
}

/// Pack a batch of CP inputs into `x [B,N,d,R̃]`, zero-padded.
pub fn pack_cp_inputs(
    xs: &[&CpTensor],
    batch: usize,
    n: usize,
    d: usize,
    rt: usize,
) -> Result<Vec<f32>> {
    if xs.len() > batch {
        bail!("batch overflow: {} > {batch}", xs.len());
    }
    let mut out = Vec::with_capacity(batch * n * d * rt);
    for x in xs {
        if x.dims() != vec![d; n].as_slice() || x.rank() != rt {
            bail!("input: dims {:?} rank {} != ([{d};{n}], {rt})", x.dims(), x.rank());
        }
        for mode in 0..n {
            out.extend(x.factor(mode).data().iter().map(|&v| v as f32));
        }
    }
    out.resize(batch * n * d * rt, 0.0);
    Ok(out)
}

/// Pack a dense Gaussian projection matrix into `w [k,D]`.
pub fn pack_dense_projection(f: &GaussianProjection) -> Vec<f32> {
    f.matrix().iter().map(|&v| v as f32).collect()
}

/// Pack a batch of dense inputs into `x [B,D]`, zero-padded.
pub fn pack_dense_inputs(xs: &[&DenseTensor], batch: usize, dim: usize) -> Result<Vec<f32>> {
    if xs.len() > batch {
        bail!("batch overflow: {} > {batch}", xs.len());
    }
    let mut out = Vec::with_capacity(batch * dim);
    for x in xs {
        if x.numel() != dim {
            bail!("input numel {} != {dim}", x.numel());
        }
        out.extend(x.data().iter().map(|&v| v as f32));
    }
    out.resize(batch * dim, 0.0);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn tt_pack_shapes() {
        let mut rng = Rng::seed_from(1);
        let (n, d, r, k) = (5usize, 3usize, 2usize, 4usize);
        let f = TtProjection::new(&vec![d; n], r, k, &mut rng);
        let (gf, gm, gl) = pack_tt_projection(&f, n, d, r).unwrap();
        assert_eq!(gf.len(), k * d * r);
        assert_eq!(gm.len(), k * (n - 2) * r * d * r);
        assert_eq!(gl.len(), k * r * d);
    }

    #[test]
    fn tt_inputs_pad_with_zeros() {
        let mut rng = Rng::seed_from(2);
        let (n, d, rt, b) = (4usize, 3usize, 2usize, 3usize);
        let x = TtTensor::random(&vec![d; n], rt, &mut rng);
        let (xf, xm, xl) = pack_tt_inputs(&[&x], b, n, d, rt).unwrap();
        assert_eq!(xf.len(), b * d * rt);
        // Slots beyond the first item are zero.
        assert!(xf[d * rt..].iter().all(|&v| v == 0.0));
        assert!(xm[(n - 2) * rt * d * rt..].iter().all(|&v| v == 0.0));
        assert!(xl[rt * d..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn tt_pack_rejects_wrong_rank() {
        let mut rng = Rng::seed_from(3);
        let x = TtTensor::random(&[3; 4], 5, &mut rng);
        assert!(pack_tt_inputs(&[&x], 2, 4, 3, 2).is_err());
    }

    #[test]
    fn tt_pack_rejects_batch_overflow() {
        let mut rng = Rng::seed_from(4);
        let x = TtTensor::random(&[3; 4], 2, &mut rng);
        assert!(pack_tt_inputs(&[&x, &x, &x], 2, 4, 3, 2).is_err());
    }

    #[test]
    fn cp_pack_shapes_and_padding() {
        let mut rng = Rng::seed_from(5);
        let (n, d, r, k, b) = (4usize, 3usize, 2usize, 5usize, 4usize);
        let f = CpProjection::new(&vec![d; n], r, k, &mut rng);
        let a = pack_cp_projection(&f, n, d, r).unwrap();
        assert_eq!(a.len(), k * n * d * r);
        let x = CpTensor::random(&vec![d; n], 3, &mut rng);
        let xp = pack_cp_inputs(&[&x], b, n, d, 3).unwrap();
        assert_eq!(xp.len(), b * n * d * 3);
        assert!(xp[n * d * 3..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn dense_pack() {
        let mut rng = Rng::seed_from(6);
        let f = GaussianProjection::new(&[4, 4], 3, &mut rng);
        assert_eq!(pack_dense_projection(&f).len(), 3 * 16);
        let x = DenseTensor::random(&[4, 4], &mut rng);
        let xp = pack_dense_inputs(&[&x], 2, 16).unwrap();
        assert_eq!(xp.len(), 32);
        assert!(xp[16..].iter().all(|&v| v == 0.0));
    }
}
