//! The PJRT execution engine: compile once, execute per batch.
//!
//! Adapted from /opt/xla-example/load_hlo: HLO *text* is the interchange
//! format (xla_extension 0.5.1 rejects jax≥0.5 serialized protos), parsed
//! by `HloModuleProto::from_text_file`, compiled by the PJRT CPU client and
//! executed with f32 literal inputs.

use super::{ArtifactSpec, Manifest};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

/// Execution statistics for one artifact.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    /// Number of executed batches.
    pub executions: u64,
    /// Total wall time spent inside PJRT execute (seconds).
    pub total_secs: f64,
}

struct Loaded {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    stats: Mutex<ExecStats>,
}

/// A PJRT CPU client plus the compiled artifact set.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    loaded: HashMap<String, Loaded>,
}

impl PjrtEngine {
    /// Create an engine backed by the PJRT CPU client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self { client, loaded: HashMap::new() })
    }

    /// Platform name reported by PJRT (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile every artifact in `dir` (per its manifest).
    /// Returns the number of compiled artifacts.
    pub fn load_dir(&mut self, dir: &Path) -> Result<usize> {
        let manifest = Manifest::load(dir)?;
        let mut n = 0;
        for spec in &manifest.artifacts {
            self.load_artifact(dir, spec.clone())
                .with_context(|| format!("loading artifact {}", spec.name))?;
            n += 1;
        }
        Ok(n)
    }

    /// Load and compile a single artifact.
    pub fn load_artifact(&mut self, dir: &Path, spec: ArtifactSpec) -> Result<()> {
        let path = dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", spec.name))?;
        self.loaded.insert(
            spec.name.clone(),
            Loaded { spec, exe, stats: Mutex::new(ExecStats::default()) },
        );
        Ok(())
    }

    /// Names of all compiled artifacts.
    pub fn artifact_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.loaded.keys().cloned().collect();
        names.sort();
        names
    }

    /// Spec of a compiled artifact.
    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.loaded.get(name).map(|l| &l.spec)
    }

    /// Execute artifact `name` with the given flat f32 parameter buffers
    /// (one per manifest param, row-major). Returns the flat `[B, k]`
    /// output as f64 (the crate-wide numeric type).
    pub fn execute(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<f64>> {
        let loaded = self
            .loaded
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
        let spec = &loaded.spec;
        if inputs.len() != spec.params.len() {
            bail!(
                "artifact {name}: expected {} inputs, got {}",
                spec.params.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, param) in inputs.iter().zip(&spec.params) {
            if buf.len() != param.numel() {
                bail!(
                    "artifact {name}: param {} needs {} elements, got {}",
                    param.name,
                    param.numel(),
                    buf.len()
                );
            }
            let dims: Vec<i64> = param.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(buf)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshaping {}: {e}", param.name))?;
            literals.push(lit);
        }
        let t = crate::util::Timer::start();
        let result = loaded
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e}"))?;
        // Graphs are lowered with return_tuple=True → unwrap the 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("untupling result of {name}: {e}"))?;
        let values: Vec<f32> = out
            .to_vec::<f32>()
            .map_err(|e| anyhow!("reading result of {name}: {e}"))?;
        let expected: usize = spec.output_shape.iter().product();
        if values.len() != expected {
            bail!(
                "artifact {name}: output has {} elements, expected {expected}",
                values.len()
            );
        }
        {
            let mut stats = crate::util::sync::lock_recover(&loaded.stats);
            stats.executions += 1;
            stats.total_secs += t.elapsed_secs();
        }
        Ok(values.into_iter().map(|v| v as f64).collect())
    }

    /// Execution statistics for an artifact.
    pub fn stats(&self, name: &str) -> Option<ExecStats> {
        self.loaded.get(name).map(|l| *crate::util::sync::lock_recover(&l.stats))
    }
}

// SAFETY: the PJRT client and its loaded executables are internally
// synchronized (PJRT's C API is thread-safe for execution), and every
// piece of engine state this crate adds on top is either immutable after
// load (specs, executable handles) or behind a `Mutex` (per-artifact
// stats). The xla binding just doesn't mark the FFI handles; execution
// from the coordinator worker pool requires Send.
unsafe impl Send for PjrtEngine {}
// SAFETY: shared references only read immutable artifact metadata or go
// through the stats `Mutex`; the FFI execution entry point is safe to
// call concurrently (see the Send justification above).
unsafe impl Sync for PjrtEngine {}

#[cfg(test)]
mod tests {
    // PJRT-backed tests live in rust/tests/runtime_pjrt.rs (they need the
    // artifacts directory built by `make artifacts`). Here we only test
    // pure logic that needs no client.

    #[test]
    fn exec_stats_default_is_zero() {
        let s = super::ExecStats::default();
        assert_eq!(s.executions, 0);
        assert_eq!(s.total_secs, 0.0);
    }
}
