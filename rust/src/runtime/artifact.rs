//! Manifest parsing: the contract between the Python AOT pipeline and the
//! Rust runtime.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing each
//! compiled artifact: parameter order and shapes, output shape, map kind
//! and hyperparameters. This module parses it with `util::json` into typed
//! [`ArtifactSpec`]s; shape consistency is validated eagerly so a stale
//! manifest fails at load time, not mid-request.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Which projection map an artifact implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// `f_TT(R)` on TT-format inputs.
    Tt,
    /// `f_CP(R)` on CP-format inputs.
    Cp,
    /// Dense Gaussian RP on vectorized inputs.
    Dense,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "tt" => Ok(Self::Tt),
            "cp" => Ok(Self::Cp),
            "dense" => Ok(Self::Dense),
            other => bail!("unknown artifact kind {other:?}"),
        }
    }
}

/// One named parameter of a compiled function.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamSpec {
    /// Parameter name (documentation; order is what matters).
    pub name: String,
    /// Dense row-major shape.
    pub shape: Vec<usize>,
}

impl ParamSpec {
    /// Total element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Full description of one compiled artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Unique artifact name (also the HLO file stem).
    pub name: String,
    /// Map kind.
    pub kind: ArtifactKind,
    /// HLO text file, relative to the artifact directory.
    pub file: String,
    /// Embedding dimension `k`.
    pub k: usize,
    /// Compiled request batch size `B` (the batcher pads to this).
    pub batch: usize,
    /// `1/√k` scaling baked into the graph.
    pub scale: f64,
    /// Whether the graph routes through the Pallas kernels.
    pub use_pallas: bool,
    /// Ordered function parameters.
    pub params: Vec<ParamSpec>,
    /// Output shape `[B, k]`.
    pub output_shape: Vec<usize>,
    /// Tensor order `N` (TT/CP kinds).
    pub n_modes: Option<usize>,
    /// Mode size `d` (TT/CP kinds).
    pub dim: Option<usize>,
    /// Projection rank `R` (TT/CP kinds).
    pub rank: Option<usize>,
    /// Input rank `R̃` (TT/CP kinds).
    pub input_rank: Option<usize>,
    /// Vectorized input dimension `D` (dense kind).
    pub input_dim: Option<usize>,
}

impl ArtifactSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let get_str = |key: &str| -> Result<String> {
            Ok(j.get(key)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("manifest entry missing string field {key:?}"))?
                .to_string())
        };
        let get_usize = |key: &str| -> Result<usize> {
            j.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest entry missing integer field {key:?}"))
        };
        let params = j
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest entry missing params"))?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("param missing name"))?
                        .to_string(),
                    shape: p
                        .get("shape")
                        .and_then(Json::as_usize_vec)
                        .ok_or_else(|| anyhow!("param missing shape"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let spec = ArtifactSpec {
            name: get_str("name")?,
            kind: ArtifactKind::parse(&get_str("kind")?)?,
            file: get_str("file")?,
            k: get_usize("k")?,
            batch: get_usize("batch")?,
            scale: j
                .get("scale")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("manifest entry missing scale"))?,
            use_pallas: j.get("use_pallas").and_then(Json::as_bool).unwrap_or(false),
            params,
            output_shape: j
                .get("output_shape")
                .and_then(Json::as_usize_vec)
                .ok_or_else(|| anyhow!("manifest entry missing output_shape"))?,
            n_modes: j.get("n_modes").and_then(Json::as_usize),
            dim: j.get("dim").and_then(Json::as_usize),
            rank: j.get("rank").and_then(Json::as_usize),
            input_rank: j.get("input_rank").and_then(Json::as_usize),
            input_dim: j.get("input_dim").and_then(Json::as_usize),
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Cross-field consistency checks.
    pub fn validate(&self) -> Result<()> {
        if self.output_shape != [self.batch, self.k] {
            bail!(
                "artifact {}: output_shape {:?} != [batch, k] = [{}, {}]",
                self.name,
                self.output_shape,
                self.batch,
                self.k
            );
        }
        let expected_scale = 1.0 / (self.k as f64).sqrt();
        if (self.scale - expected_scale).abs() > 1e-9 {
            bail!("artifact {}: scale {} != 1/√k", self.name, self.scale);
        }
        match self.kind {
            ArtifactKind::Tt => {
                let (n, d, r, rt) = self.tt_meta()?;
                let want = vec![
                    vec![self.k, d, r],
                    vec![self.k, n - 2, r, d, r],
                    vec![self.k, r, d],
                    vec![self.batch, d, rt],
                    vec![self.batch, n - 2, rt, d, rt],
                    vec![self.batch, rt, d],
                ];
                let got: Vec<Vec<usize>> =
                    self.params.iter().map(|p| p.shape.clone()).collect();
                if got != want {
                    bail!("artifact {}: TT param shapes {got:?} != {want:?}", self.name);
                }
            }
            ArtifactKind::Cp => {
                let n = self.n_modes.ok_or_else(|| anyhow!("cp missing n_modes"))?;
                let d = self.dim.ok_or_else(|| anyhow!("cp missing dim"))?;
                let r = self.rank.ok_or_else(|| anyhow!("cp missing rank"))?;
                let rt = self
                    .input_rank
                    .ok_or_else(|| anyhow!("cp missing input_rank"))?;
                let want = vec![
                    vec![self.k, n, d, r],
                    vec![self.batch, n, d, rt],
                ];
                let got: Vec<Vec<usize>> =
                    self.params.iter().map(|p| p.shape.clone()).collect();
                if got != want {
                    bail!("artifact {}: CP param shapes {got:?} != {want:?}", self.name);
                }
            }
            ArtifactKind::Dense => {
                let dd = self
                    .input_dim
                    .ok_or_else(|| anyhow!("dense missing input_dim"))?;
                let want = vec![vec![self.k, dd], vec![self.batch, dd]];
                let got: Vec<Vec<usize>> =
                    self.params.iter().map(|p| p.shape.clone()).collect();
                if got != want {
                    bail!(
                        "artifact {}: dense param shapes {got:?} != {want:?}",
                        self.name
                    );
                }
            }
        }
        Ok(())
    }

    /// `(N, d, R, R̃)` for TT artifacts.
    pub fn tt_meta(&self) -> Result<(usize, usize, usize, usize)> {
        Ok((
            self.n_modes.ok_or_else(|| anyhow!("tt missing n_modes"))?,
            self.dim.ok_or_else(|| anyhow!("tt missing dim"))?,
            self.rank.ok_or_else(|| anyhow!("tt missing rank"))?,
            self.input_rank
                .ok_or_else(|| anyhow!("tt missing input_rank"))?,
        ))
    }

    /// Uniform input mode sizes `[d; N]` for TT/CP artifacts.
    pub fn input_dims(&self) -> Option<Vec<usize>> {
        match (self.n_modes, self.dim) {
            (Some(n), Some(d)) => Some(vec![d; n]),
            _ => None,
        }
    }
}

/// A parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest (and artifacts) live in.
    pub dir: PathBuf,
    /// All artifact specs.
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {}", mpath.display()))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (separated from I/O for tests).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let version = j
            .get("format_version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing format_version"))?;
        if version != 1 {
            bail!("unsupported manifest format_version {version}");
        }
        let artifacts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
            .iter()
            .map(ArtifactSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// Find an artifact by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format_version": 1,
      "artifacts": [
        {
          "name": "tt_rp_tiny", "kind": "tt", "file": "tt_rp_tiny.hlo.txt",
          "dtype": "f32", "k": 4, "batch": 2, "scale": 0.5, "use_pallas": true,
          "n_modes": 4, "dim": 3, "rank": 2, "input_rank": 2,
          "params": [
            {"name": "g_first", "shape": [4, 3, 2]},
            {"name": "g_mid",   "shape": [4, 2, 2, 3, 2]},
            {"name": "g_last",  "shape": [4, 2, 3]},
            {"name": "x_first", "shape": [2, 3, 2]},
            {"name": "x_mid",   "shape": [2, 2, 2, 3, 2]},
            {"name": "x_last",  "shape": [2, 2, 3]}
          ],
          "output_shape": [2, 4]
        }
      ]
    }"#;

    #[test]
    fn parses_valid_manifest() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.get("tt_rp_tiny").unwrap();
        assert_eq!(a.kind, ArtifactKind::Tt);
        assert_eq!(a.k, 4);
        assert_eq!(a.tt_meta().unwrap(), (4, 3, 2, 2));
        assert_eq!(a.input_dims().unwrap(), vec![3, 3, 3, 3]);
        assert_eq!(a.params[1].numel(), 4 * 2 * 2 * 3 * 2);
    }

    #[test]
    fn rejects_wrong_output_shape() {
        let bad = SAMPLE.replace("\"output_shape\": [2, 4]", "\"output_shape\": [4, 2]");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn rejects_wrong_scale() {
        let bad = SAMPLE.replace("\"scale\": 0.5", "\"scale\": 0.7");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn rejects_wrong_param_shape() {
        let bad = SAMPLE.replace("[4, 3, 2]", "[4, 3, 3]");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn rejects_unknown_kind() {
        let bad = SAMPLE.replace("\"kind\": \"tt\"", "\"kind\": \"tucker\"");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn rejects_future_format_version() {
        let bad = SAMPLE.replace("\"format_version\": 1", "\"format_version\": 2");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn loads_repo_manifest_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.artifacts.is_empty());
            assert!(m.get("tt_rp_medium").is_some());
        }
    }
}
