//! # Tensorized Random Projections
//!
//! A production-grade reproduction of *"Tensorized Random Projections"*
//! (Rakhshan & Rabusseau, AISTATS 2020).
//!
//! The paper introduces two tensorized Johnson-Lindenstrauss transforms,
//! `f_TT(R)` and `f_CP(R)`, that replace the dense Gaussian matrix of a
//! classical random projection with rows constrained to low-rank tensor
//! train (TT) or CP structure. This crate implements:
//!
//! * the full tensor algebra substrate ([`tensor`], [`linalg`]) — dense
//!   tensors, TT and CP formats, matricizations, inner products, norms;
//! * the projection library ([`projections`]) — Gaussian, sparse,
//!   very-sparse, TT(R), CP(R), TRP and Kronecker-FJLT maps with fast
//!   paths for inputs given in TT or CP format;
//! * the theoretical bounds from the paper ([`theory`]) used both for
//!   validation and for auto-sizing projections;
//! * a serving coordinator ([`coordinator`]) — request router, dynamic
//!   batcher, worker pool and metrics — which executes projections either
//!   through the native Rust engine or through AOT-compiled XLA artifacts
//!   ([`runtime`]) produced by the JAX/Pallas build path in `python/`;
//! * a similarity-search index subsystem ([`index`]) — flat exact-scan and
//!   random-hyperplane LSH backends over the projected embeddings, served
//!   through the coordinator as `insert`/`query`/`delete`/`stats` wire ops
//!   (the workload that consumes the JL distance-preservation guarantee);
//! * an observability layer ([`obs`]) — lock-free request tracing drained
//!   to rotated JSONL, a per-signature metrics registry with per-stage
//!   latency histograms, and GEMM shape-bucket profiling, exported over
//!   the wire via the `metrics` op and rendered by `trp metrics`;
//! * the experiment harness ([`experiments`]) regenerating every figure of
//!   the paper's evaluation section;
//! * a self-auditing static analysis ([`analysis`]) — the `trp lint`
//!   determinism & concurrency pass (float total orders, FMA-free numeric
//!   core, panic-free serving path, ordered iteration, audited `unsafe`,
//!   justified `Relaxed`) run over this very source tree and enforced as
//!   a tier-1 gate.
//!
//! ## Quickstart
//!
//! ```
//! use tensorized_rp::prelude::*;
//!
//! let mut rng = Rng::seed_from(42);
//! // A 12-mode, 3-dimensional unit-norm tensor in TT format (rank 10).
//! let x = TtTensor::random_unit(&[3; 12], 10, &mut rng);
//! // A TT(5) tensorized random projection into R^64.
//! let f = TtProjection::new(&[3; 12], 5, 64, &mut rng);
//! let y = f.project_tt(&x);
//! let distortion = (y.iter().map(|v| v * v).sum::<f64>() - 1.0).abs();
//! assert!(distortion < 1.0);
//! ```

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod index;
pub mod linalg;
pub mod obs;
pub mod projections;
pub mod rng;
pub mod runtime;
pub mod sketch;
pub mod tensor;
pub mod theory;
pub mod util;

/// Convenient re-exports of the most commonly used types.
pub mod prelude {
    pub use crate::projections::{
        CpProjection, GaussianProjection, Projection, SparseProjection, TtProjection,
    };
    pub use crate::rng::Rng;
    pub use crate::tensor::{CpTensor, DenseTensor, TtTensor};
}
