//! Input-tensor generators for the paper's experiment regimes (§6).
//!
//! The paper generates unit-norm tensors *in the TT format* with rank
//! `R̃ = 10`, for three regimes: small-order `(d=15, N=3)`, medium-order
//! `(d=3, N=12)` and high-order `(d=3, N=25)`.

use crate::rng::Rng;
use crate::tensor::{AnyTensor, CpTensor, DenseTensor, TtTensor};

/// The paper's three input regimes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Regime {
    /// `d = 15, N = 3` (dense dim 3 375) — Gaussian RP feasible.
    Small,
    /// `d = 3, N = 12` (dense dim 531 441) — very sparse RP feasible.
    Medium,
    /// `d = 3, N = 25` (dense dim ≈ 8.5·10¹¹) — tensorized maps only.
    High,
}

impl Regime {
    /// Mode sizes of this regime.
    pub fn dims(&self) -> Vec<usize> {
        match self {
            Regime::Small => vec![15; 3],
            Regime::Medium => vec![3; 12],
            Regime::High => vec![3; 25],
        }
    }

    /// The paper's input TT rank `R̃`.
    pub fn input_rank(&self) -> usize {
        10
    }

    /// Whether the dense input dimension is materializable.
    pub fn dense_feasible(&self) -> bool {
        matches!(self, Regime::Small | Regime::Medium)
    }

    /// Parse from the CLI name.
    pub fn parse(s: &str) -> Option<Regime> {
        match s {
            "small" => Some(Regime::Small),
            "medium" => Some(Regime::Medium),
            "high" => Some(Regime::High),
            _ => None,
        }
    }

    /// CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            Regime::Small => "small",
            Regime::Medium => "medium",
            Regime::High => "high",
        }
    }
}

/// Unit-norm TT input of the regime (the paper's default input).
pub fn regime_input(regime: Regime, rng: &mut Rng) -> TtTensor {
    TtTensor::random_unit(&regime.dims(), regime.input_rank(), rng)
}

/// Unit-norm CP input with the same shape (for the Figure 2/4 CP-input
/// timing series).
pub fn regime_cp_input(regime: Regime, rng: &mut Rng) -> CpTensor {
    CpTensor::random_unit(&regime.dims(), regime.input_rank(), rng)
}

/// Unit-norm tensor in the requested format.
pub fn unit_input(dims: &[usize], rank: usize, format: &str, rng: &mut Rng) -> AnyTensor {
    match format {
        "tt" => AnyTensor::Tt(TtTensor::random_unit(dims, rank, rng)),
        "cp" => AnyTensor::Cp(CpTensor::random_unit(dims, rank, rng)),
        "dense" => AnyTensor::Dense(DenseTensor::random_unit(dims, rng)),
        other => panic!("unknown input format {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regime_shapes() {
        assert_eq!(Regime::Small.dims(), vec![15, 15, 15]);
        assert_eq!(Regime::Medium.dims().len(), 12);
        assert_eq!(Regime::High.dims().len(), 25);
        assert!(Regime::Small.dense_feasible());
        assert!(!Regime::High.dense_feasible());
    }

    #[test]
    fn regime_inputs_are_unit_norm() {
        let mut rng = Rng::seed_from(1);
        for r in [Regime::Small, Regime::Medium, Regime::High] {
            let x = regime_input(r, &mut rng);
            assert!((x.fro_norm() - 1.0).abs() < 1e-9, "{:?}", r);
        }
    }

    #[test]
    fn parse_roundtrip() {
        for r in [Regime::Small, Regime::Medium, Regime::High] {
            assert_eq!(Regime::parse(r.name()), Some(r));
        }
        assert_eq!(Regime::parse("huge"), None);
    }

    #[test]
    fn unit_input_formats() {
        let mut rng = Rng::seed_from(2);
        let t = unit_input(&[3; 4], 2, "tt", &mut rng);
        assert!((t.fro_norm() - 1.0).abs() < 1e-9);
        let c = unit_input(&[3; 4], 2, "cp", &mut rng);
        assert!((c.fro_norm() - 1.0).abs() < 1e-9);
        let d = unit_input(&[3, 3], 0, "dense", &mut rng);
        assert!((d.fro_norm() - 1.0).abs() < 1e-9);
    }
}
