//! Workload and dataset generation.
//!
//! * [`inputs`] — the unit-norm random TT/CP/dense tensors of §6,
//! * [`images`] — the CIFAR-10 substitute for Appendix B.1 (synthetic
//!   natural-image-like data; loads real CIFAR batches when present),
//! * [`workload`] — request traces for the serving benches/examples.

pub mod images;
pub mod inputs;
pub mod workload;
