//! Image data for the Appendix B.1 pairwise-distance experiment.
//!
//! The paper uses the first 50 CIFAR-10 images reshaped to
//! `4×4×4×4×4×3` tensors. No dataset download is possible offline, so
//! the default source is a **synthetic natural-image model**: a low-pass
//! filtered Gaussian random field with a `1/f²`-type power spectrum per
//! channel (natural images are famously `1/f`-correlated). The experiment
//! only exercises pairwise ℓ₂ geometry of spatially-correlated,
//! non-isotropic vectors, which the random field reproduces; see
//! DESIGN.md §5 for the substitution rationale.
//!
//! If a real CIFAR-10 binary batch (`data_batch_1.bin`, the standard
//! 3073-byte-record format) is present, [`load_images`] uses it instead.

use crate::rng::Rng;
use crate::tensor::DenseTensor;
use std::path::Path;

/// Side length of the square images.
pub const SIDE: usize = 32;
/// Color channels.
pub const CHANNELS: usize = 3;
/// The tensorization the paper uses: `4×4×4×4×4×3` (4⁵·3 = 3072 = 32·32·3).
pub const TENSOR_DIMS: [usize; 6] = [4, 4, 4, 4, 4, 3];

/// One image as a flat `[channel][row][col]` f64 buffer in `[0, 1]`.
#[derive(Debug, Clone)]
pub struct Image {
    /// `CHANNELS·SIDE·SIDE` values.
    pub pixels: Vec<f64>,
}

impl Image {
    /// Reshape to the paper's `4×4×4×4×4×3` tensor, normalized to unit
    /// Frobenius norm (as the paper normalizes its inputs).
    pub fn to_tensor(&self) -> DenseTensor {
        // Reorder [c][y][x] → row-major over (y₁,y₂ … spatial splits, c):
        // the exact fiber ordering is immaterial (consistent reshape); we
        // keep channel as the trailing mode as in the paper's 4×…×4×3.
        let mut data = vec![0.0; self.pixels.len()];
        let spatial = SIDE * SIDE;
        for y in 0..SIDE {
            for x in 0..SIDE {
                for c in 0..CHANNELS {
                    data[(y * SIDE + x) * CHANNELS + c] = self.pixels[c * spatial + y * SIDE + x];
                }
            }
        }
        let mut t = DenseTensor::from_vec(&TENSOR_DIMS, data);
        let n = t.fro_norm();
        if n > 0.0 {
            t.scale(1.0 / n);
        }
        t
    }
}

/// Synthesize one natural-image-like sample: per channel, a Gaussian
/// random field built from a small number of low-frequency cosine modes
/// with `1/f²` amplitude decay, plus mild white noise.
pub fn synthetic_image(rng: &mut Rng) -> Image {
    let mut pixels = vec![0.0; CHANNELS * SIDE * SIDE];
    // Shared luminance field + per-channel variation (images have highly
    // correlated channels).
    let lum = random_field(rng);
    for c in 0..CHANNELS {
        let chroma = random_field(rng);
        for i in 0..SIDE * SIDE {
            let v = 0.75 * lum[i] + 0.25 * chroma[i] + 0.02 * rng.gaussian();
            pixels[c * SIDE * SIDE + i] = 0.5 + 0.5 * v.tanh();
        }
    }
    Image { pixels }
}

/// One `SIDE×SIDE` random field with 1/f² spectrum (zero mean, ~unit std).
fn random_field(rng: &mut Rng) -> Vec<f64> {
    let max_freq = 8usize;
    let mut field = vec![0.0f64; SIDE * SIDE];
    let mut power = 0.0;
    for fy in 0..max_freq {
        for fx in 0..max_freq {
            if fx == 0 && fy == 0 {
                continue;
            }
            let f2 = (fx * fx + fy * fy) as f64;
            let amp = 1.0 / f2; // 1/f² power spectrum
            let phase = rng.uniform_in(0.0, std::f64::consts::TAU);
            let coef = amp * rng.gaussian();
            power += coef * coef / 2.0;
            let wx = std::f64::consts::TAU * fx as f64 / SIDE as f64;
            let wy = std::f64::consts::TAU * fy as f64 / SIDE as f64;
            for y in 0..SIDE {
                for x in 0..SIDE {
                    field[y * SIDE + x] += coef * (wx * x as f64 + wy * y as f64 + phase).cos();
                }
            }
        }
    }
    let norm = power.sqrt().max(1e-12);
    for v in &mut field {
        *v /= norm;
    }
    field
}

/// Load `n` images: real CIFAR-10 when `cifar_path` exists, synthetic
/// otherwise. Deterministic in `seed` for the synthetic source.
pub fn load_images(n: usize, cifar_path: Option<&Path>, seed: u64) -> (Vec<Image>, &'static str) {
    if let Some(p) = cifar_path {
        if p.exists() {
            if let Ok(images) = load_cifar_batch(p, n) {
                return (images, "cifar10");
            }
        }
    }
    let mut rng = Rng::seed_from(seed);
    ((0..n).map(|_| synthetic_image(&mut rng)).collect(), "synthetic")
}

/// Parse the standard CIFAR-10 binary batch format: 10 000 records of
/// 1 label byte + 3072 pixel bytes (channel-major).
pub fn load_cifar_batch(path: &Path, n: usize) -> std::io::Result<Vec<Image>> {
    let bytes = std::fs::read(path)?;
    const REC: usize = 3073;
    let available = bytes.len() / REC;
    let take = n.min(available);
    let mut images = Vec::with_capacity(take);
    for i in 0..take {
        let rec = &bytes[i * REC + 1..(i + 1) * REC];
        images.push(Image {
            pixels: rec.iter().map(|&b| b as f64 / 255.0).collect(),
        });
    }
    Ok(images)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_dims_multiply_to_pixel_count() {
        let numel: usize = TENSOR_DIMS.iter().product();
        assert_eq!(numel, CHANNELS * SIDE * SIDE);
    }

    #[test]
    fn synthetic_images_are_deterministic_and_unit_norm() {
        let (a, src) = load_images(3, None, 42);
        let (b, _) = load_images(3, None, 42);
        assert_eq!(src, "synthetic");
        assert_eq!(a[0].pixels, b[0].pixels);
        for img in &a {
            let t = img.to_tensor();
            assert!((t.fro_norm() - 1.0).abs() < 1e-9);
            assert_eq!(t.dims(), &TENSOR_DIMS);
        }
    }

    #[test]
    fn synthetic_images_are_spatially_correlated() {
        // Neighboring pixels must correlate far more than distant ones —
        // the property that distinguishes image-like data from white noise.
        let mut rng = Rng::seed_from(7);
        let img = synthetic_image(&mut rng);
        let ch = &img.pixels[..SIDE * SIDE];
        let mean: f64 = ch.iter().sum::<f64>() / ch.len() as f64;
        let mut num_adj = 0.0;
        let mut num_far = 0.0;
        let mut den = 0.0;
        for y in 0..SIDE {
            for x in 0..SIDE - 1 {
                num_adj += (ch[y * SIDE + x] - mean) * (ch[y * SIDE + x + 1] - mean);
            }
            for x in 0..SIDE - 16 {
                num_far += (ch[y * SIDE + x] - mean) * (ch[y * SIDE + x + 16] - mean);
            }
            for x in 0..SIDE {
                den += (ch[y * SIDE + x] - mean) * (ch[y * SIDE + x] - mean);
            }
        }
        let corr_adj = num_adj / den;
        let corr_far = num_far / den;
        assert!(corr_adj > 0.5, "adjacent corr {corr_adj}");
        assert!(corr_adj > corr_far.abs() + 0.2, "adj {corr_adj} vs far {corr_far}");
    }

    #[test]
    fn pixel_values_in_unit_interval() {
        let mut rng = Rng::seed_from(9);
        let img = synthetic_image(&mut rng);
        assert!(img.pixels.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }

    #[test]
    fn cifar_loader_parses_record_format() {
        // Fabricate a 2-record batch file.
        let dir = std::env::temp_dir().join("trp_test_cifar");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("data_batch_1.bin");
        let mut bytes = vec![0u8; 2 * 3073];
        bytes[0] = 7; // label
        bytes[1] = 255; // first pixel
        bytes[3073] = 2;
        bytes[3074] = 128;
        std::fs::write(&path, &bytes).unwrap();
        let images = load_cifar_batch(&path, 5).unwrap();
        assert_eq!(images.len(), 2);
        assert!((images[0].pixels[0] - 1.0).abs() < 1e-9);
        assert!((images[1].pixels[0] - 128.0 / 255.0).abs() < 1e-9);
        let (loaded, src) = load_images(2, Some(&path), 0);
        assert_eq!(src, "cifar10");
        assert_eq!(loaded.len(), 2);
    }
}
