//! Serving workload traces for the coordinator benches and the
//! `serve_compress` end-to-end example.

use super::inputs::Regime;
use crate::rng::Rng;
use crate::tensor::{AnyTensor, CpTensor, TtTensor};

/// Mix of payload formats in a trace (weights need not sum to 1).
#[derive(Debug, Clone, Copy)]
pub struct FormatMix {
    /// Weight of TT-format requests.
    pub tt: f64,
    /// Weight of CP-format requests.
    pub cp: f64,
}

impl Default for FormatMix {
    fn default() -> Self {
        Self { tt: 0.8, cp: 0.2 }
    }
}

/// A generated request trace: payloads plus arrival offsets.
#[derive(Debug)]
pub struct Trace {
    /// Payloads in arrival order.
    pub payloads: Vec<AnyTensor>,
    /// Arrival time offsets in µs (non-decreasing; Poisson arrivals).
    pub arrivals_us: Vec<u64>,
}

/// Generate a Poisson-arrival trace of `n` requests at `rate_per_sec`,
/// with payload shapes from `regime` and format mix `mix`.
///
/// TT payloads use the regime's input rank so they match the compiled
/// artifact signature; CP payloads likewise.
pub fn poisson_trace(
    n: usize,
    rate_per_sec: f64,
    regime: Regime,
    mix: FormatMix,
    seed: u64,
) -> Trace {
    assert!(rate_per_sec > 0.0);
    let mut rng = Rng::seed_from(seed);
    let dims = regime.dims();
    let rank = regime.input_rank();
    let total = (mix.tt + mix.cp).max(1e-12);
    let mut payloads = Vec::with_capacity(n);
    let mut arrivals = Vec::with_capacity(n);
    let mut t_us = 0.0f64;
    for _ in 0..n {
        // Exponential inter-arrival.
        let u = rng.uniform().max(f64::MIN_POSITIVE);
        t_us += -u.ln() / rate_per_sec * 1e6;
        arrivals.push(t_us as u64);
        let pick = rng.uniform() * total;
        if pick < mix.tt {
            payloads.push(AnyTensor::Tt(TtTensor::random_unit(&dims, rank, &mut rng)));
        } else {
            payloads.push(AnyTensor::Cp(CpTensor::random_unit(&dims, rank, &mut rng)));
        }
    }
    Trace { payloads, arrivals_us: arrivals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Format;

    #[test]
    fn trace_has_sorted_arrivals_and_right_count() {
        let t = poisson_trace(50, 1000.0, Regime::Medium, FormatMix::default(), 1);
        assert_eq!(t.payloads.len(), 50);
        assert_eq!(t.arrivals_us.len(), 50);
        for w in t.arrivals_us.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn trace_mix_respects_weights() {
        let t = poisson_trace(
            400,
            1000.0,
            Regime::Medium,
            FormatMix { tt: 1.0, cp: 0.0 },
            2,
        );
        assert!(t.payloads.iter().all(|p| p.format() == Format::Tt));
        let t2 = poisson_trace(
            200,
            1000.0,
            Regime::Medium,
            FormatMix { tt: 0.5, cp: 0.5 },
            3,
        );
        let n_tt = t2.payloads.iter().filter(|p| p.format() == Format::Tt).count();
        assert!(n_tt > 50 && n_tt < 150, "n_tt={n_tt}");
    }

    #[test]
    fn mean_interarrival_matches_rate() {
        let t = poisson_trace(2000, 10_000.0, Regime::Medium, FormatMix::default(), 4);
        let total_s = *t.arrivals_us.last().unwrap() as f64 / 1e6;
        let rate = 2000.0 / total_s;
        assert!((rate - 10_000.0).abs() < 1_500.0, "rate={rate}");
    }

    #[test]
    fn trace_is_deterministic() {
        let a = poisson_trace(10, 100.0, Regime::Small, FormatMix::default(), 9);
        let b = poisson_trace(10, 100.0, Regime::Small, FormatMix::default(), 9);
        assert_eq!(a.arrivals_us, b.arrivals_us);
    }
}
