//! Figure 4 (Appendix B.2): embedding time vs input dimension `d^N` for
//! the medium-order family `d = 3, N ∈ {8, 11, 12, 13}`, with the input
//! in TT format (left panel) or CP format (right panel).
//!
//! Baselines: Gaussian RP (while the `k×d^N` matrix is materializable)
//! and very sparse RP — mirroring the paper, the Gaussian series stops
//! where memory runs out.

use super::MapSpec;
use crate::rng::Rng;
use crate::tensor::{AnyTensor, CpTensor, TtTensor};
use crate::util::csv::CsvTable;
use crate::util::Timer;

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct Fig4Config {
    /// Orders to sweep (paper: 8, 11, 12, 13).
    pub orders: Vec<usize>,
    /// Mode size (paper: 3).
    pub dim: usize,
    /// Input rank (paper: 10).
    pub input_rank: usize,
    /// Embedding dimension (fixed across the sweep).
    pub k: usize,
    /// Timed repetitions (median reported).
    pub reps: usize,
    /// Master seed.
    pub seed: u64,
}

impl Fig4Config {
    /// Paper-style defaults.
    pub fn paper() -> Self {
        Self {
            orders: vec![8, 11, 12, 13],
            dim: 3,
            input_rank: 10,
            k: 50,
            reps: 3,
            seed: 0xF164,
        }
    }

    /// Reduced settings for smoke tests.
    pub fn quick() -> Self {
        Self {
            orders: vec![5, 7],
            input_rank: 4,
            k: 10,
            reps: 1,
            ..Self::paper()
        }
    }
}

/// Series of the figure.
pub fn series() -> Vec<MapSpec> {
    vec![
        MapSpec::Tt(5),
        MapSpec::Tt(10),
        MapSpec::Cp(25),
        MapSpec::Cp(100),
        MapSpec::Gaussian,
        MapSpec::VerySparse,
    ]
}

/// One timing row.
#[derive(Debug, Clone)]
pub struct Fig4Row {
    /// `"tt"` or `"cp"` input format (panel).
    pub input_format: String,
    /// Series label.
    pub map: String,
    /// Tensor order `N`.
    pub order: usize,
    /// Input dimension `d^N`.
    pub numel: f64,
    /// Median seconds per projection.
    pub secs: f64,
}

/// Run both panels.
pub fn run(cfg: &Fig4Config) -> Vec<Fig4Row> {
    let mut rng = Rng::seed_from(cfg.seed);
    let mut rows = Vec::new();
    for &n in &cfg.orders {
        let dims = vec![cfg.dim; n];
        let numel = crate::tensor::Shape::new(&dims).numel_f64();
        let x_tt = AnyTensor::Tt(TtTensor::random_unit(&dims, cfg.input_rank, &mut rng));
        let x_cp = AnyTensor::Cp(CpTensor::random_unit(&dims, cfg.input_rank, &mut rng));
        for (panel, x) in [("tt", &x_tt), ("cp", &x_cp)] {
            for spec in series() {
                if !spec.feasible(numel) {
                    continue; // Gaussian drops out at large d^N, as in the paper.
                }
                let f = spec.build(&dims, cfg.k, &mut rng);
                let mut times = Vec::with_capacity(cfg.reps);
                std::hint::black_box(f.project(x));
                for _ in 0..cfg.reps {
                    let t = Timer::start();
                    std::hint::black_box(f.project(x));
                    times.push(t.elapsed_secs());
                }
                times.sort_by(f64::total_cmp);
                rows.push(Fig4Row {
                    input_format: panel.to_string(),
                    map: spec.label(),
                    order: n,
                    numel,
                    secs: times[times.len() / 2],
                });
            }
        }
    }
    rows
}

/// Render rows as CSV.
pub fn to_csv(rows: &[Fig4Row]) -> CsvTable {
    let mut t = CsvTable::new(&["input_format", "map", "order", "numel", "median_secs"]);
    for r in rows {
        t.push_row(vec![
            r.input_format.clone(),
            r.map.clone(),
            r.order.to_string(),
            format!("{:.3e}", r.numel),
            format!("{:.6e}", r.secs),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_produces_rows_for_both_panels() {
        let cfg = Fig4Config::quick();
        let rows = run(&cfg);
        assert!(!rows.is_empty());
        assert!(rows.iter().any(|r| r.input_format == "tt"));
        assert!(rows.iter().any(|r| r.input_format == "cp"));
        assert!(rows.iter().all(|r| r.secs.is_finite()));
    }

    #[test]
    fn gaussian_drops_out_at_infeasible_sizes() {
        let cfg = Fig4Config {
            orders: vec![16], // 3^16 ≈ 43M, k×D ≫ 2^24
            reps: 1,
            k: 4,
            input_rank: 2,
            ..Fig4Config::paper()
        };
        let rows = run(&cfg);
        assert!(rows.iter().all(|r| r.map != "gaussian"));
        assert!(rows.iter().any(|r| r.map.starts_with("tt_")));
    }
}
