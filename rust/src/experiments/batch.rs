//! Batch-size sweep: item-at-a-time `project` vs the batch-first
//! `project_batch_into` path, per map family and **per input format**.
//!
//! This is the serving-layer counterpart of Figure 2's embedding-time
//! sweep: instead of varying `k`, it varies the flushed batch size `B`
//! (the coordinator's `native_max_batch`) and reports per-input time for
//! both execution routes. Dense inputs sweep all six maps; TT-format and
//! CP-format inputs sweep the three tensorized maps (TT/CP/TRP) whose
//! batched compressed-input kernels this repository implements — the
//! exact workload the paper's efficiency claim is about. The batched
//! path's trajectory is tracked across PRs (`cargo bench --bench
//! batch_sweep` and `trp experiment batch` both emit
//! `BENCH_batch_sweep.json`).

use crate::linalg::gemm;
use crate::projections::{
    CpProjection, GaussianProjection, KroneckerFjlt, Projection, SparseKind, SparseProjection,
    TrpProjection, TtProjection, Workspace,
};
use crate::rng::Rng;
use crate::tensor::{AnyTensor, CpTensor, DenseTensor, TtTensor};
use crate::util::bench::{bench, BenchConfig};
use crate::util::csv::CsvTable;
use crate::util::json::{num_arr, obj, Json};

/// Configuration of the batch-size sweep.
#[derive(Debug, Clone)]
pub struct BatchSweepConfig {
    /// Input mode sizes (dense inputs materialize `∏dims`).
    pub dims: Vec<usize>,
    /// Embedding dimension.
    pub k: usize,
    /// Rank `R̃` of the TT/CP-format inputs.
    pub input_rank: usize,
    /// Flushed batch sizes to sweep.
    pub batch_sizes: Vec<usize>,
    /// Timing profile.
    pub bench: BenchConfig,
    /// Input/map seed.
    pub seed: u64,
}

impl BatchSweepConfig {
    /// Full sweep: the paper's medium-order shape, B ∈ {1, 4, 16, 64}.
    pub fn paper() -> Self {
        Self {
            dims: vec![3; 8],
            k: 64,
            input_rank: 5,
            batch_sizes: vec![1, 4, 16, 64],
            bench: BenchConfig::default(),
            seed: 0xBA7C4,
        }
    }

    /// Reduced sweep for smoke runs.
    pub fn quick() -> Self {
        Self {
            dims: vec![3; 6],
            k: 16,
            input_rank: 3,
            batch_sizes: vec![1, 4, 16],
            bench: BenchConfig::quick(),
            seed: 0xBA7C4,
        }
    }
}

/// One (map, input format, batch size) measurement.
#[derive(Debug, Clone)]
pub struct BatchRow {
    /// Map label (`Projection::name`).
    pub map: String,
    /// Input format label: `dense`, `tt` or `cp`.
    pub input: String,
    /// Flushed batch size `B`.
    pub batch: usize,
    /// Median per-input time through a `project` loop (µs).
    pub item_us: f64,
    /// Median per-input time through one `project_batch_into` call (µs).
    pub batched_us: f64,
    /// `item_us / batched_us`.
    pub speedup: f64,
}

/// The six maps at serving-default ranks; the flag marks the tensorized
/// maps that run the compressed-input batch kernels (TT/CP-format sweeps
/// cover exactly those).
fn maps(dims: &[usize], k: usize, rng: &mut Rng) -> Vec<(Box<dyn Projection>, bool)> {
    vec![
        (Box::new(GaussianProjection::new(dims, k, rng)) as Box<dyn Projection>, false),
        (Box::new(SparseProjection::new(dims, k, SparseKind::VerySparse, rng)), false),
        (Box::new(TtProjection::new(dims, 5, k, rng)), true),
        (Box::new(CpProjection::new(dims, 5, k, rng)), true),
        (Box::new(TrpProjection::new(dims, 2, k, rng)), true),
        (Box::new(KroneckerFjlt::new(dims, k, rng)), false),
    ]
}

/// Measure one `(map, input set)` pair over the configured batch sizes;
/// both routes see identical inputs and the same drawn map, so rows
/// differ only in execution path.
fn sweep_inputs(
    map: &dyn Projection,
    input: &str,
    inputs: &[AnyTensor],
    cfg: &BatchSweepConfig,
    ws: &mut Workspace,
    rows: &mut Vec<BatchRow>,
) {
    for &b in &cfg.batch_sizes {
        let xs = &inputs[..b];
        let r_item = bench(&format!("{}/{input}/item/B{b}", map.name()), cfg.bench, || {
            let mut acc = 0.0;
            for x in xs {
                acc += map.project(x)[0];
            }
            acc
        });
        let mut out = vec![0.0; b * map.k()];
        let r_batch = bench(&format!("{}/{input}/batch/B{b}", map.name()), cfg.bench, || {
            map.project_batch_into(xs, &mut out, ws);
            out[0]
        });
        let item_us = r_item.median_secs() * 1e6 / b as f64;
        let batched_us = r_batch.median_secs() * 1e6 / b as f64;
        rows.push(BatchRow {
            map: map.name(),
            input: input.to_string(),
            batch: b,
            item_us,
            batched_us,
            speedup: item_us / batched_us.max(1e-12),
        });
    }
}

/// Run the sweep.
pub fn run(cfg: &BatchSweepConfig) -> Vec<BatchRow> {
    let mut rng = Rng::seed_from(cfg.seed);
    let maps = maps(&cfg.dims, cfg.k, &mut rng);
    let max_b = cfg.batch_sizes.iter().copied().max().unwrap_or(1);
    let dense_inputs: Vec<AnyTensor> = (0..max_b)
        .map(|_| AnyTensor::Dense(DenseTensor::random_unit(&cfg.dims, &mut rng)))
        .collect();
    let tt_inputs: Vec<AnyTensor> = (0..max_b)
        .map(|_| AnyTensor::Tt(TtTensor::random_unit(&cfg.dims, cfg.input_rank, &mut rng)))
        .collect();
    let cp_inputs: Vec<AnyTensor> = (0..max_b)
        .map(|_| AnyTensor::Cp(CpTensor::random_unit(&cfg.dims, cfg.input_rank, &mut rng)))
        .collect();
    let mut rows = Vec::new();
    let mut ws = Workspace::new();
    for (map, compressed) in &maps {
        sweep_inputs(map.as_ref(), "dense", &dense_inputs, cfg, &mut ws, &mut rows);
        if *compressed {
            sweep_inputs(map.as_ref(), "tt", &tt_inputs, cfg, &mut ws, &mut rows);
            sweep_inputs(map.as_ref(), "cp", &cp_inputs, cfg, &mut ws, &mut rows);
        }
    }
    rows
}

/// GFLOP/s of the packed kernel vs the frozen PR 5 scalar kernel
/// (`linalg::gemm::reference`) on one GEMM shape from the sweep's hot
/// paths.
#[derive(Debug, Clone)]
pub struct KernelRow {
    /// Which hot path issues the shape.
    pub shape: String,
    /// GEMM dimensions (`m×k×n`).
    pub m: usize,
    /// Reduction dimension.
    pub k: usize,
    /// Output columns.
    pub n: usize,
    /// Packed/SIMD kernel throughput (GFLOP/s, median).
    pub packed_gflops: f64,
    /// Frozen PR 5 scalar kernel throughput (GFLOP/s, median).
    pub reference_gflops: f64,
    /// `packed_gflops / reference_gflops`.
    pub speedup: f64,
}

/// The GEMM shape mix the batch sweep actually issues at `cfg`'s sizes:
/// the dense-flush stacked GEMM, a flat-index scoring scan, and the
/// TT-map chain's two per-mode GEMMs (absorb-row, absorb-input with the
/// regroups now fused into it). `maps()` pins the TT map rank at 5, so
/// the chain shapes use it too.
fn kernel_shapes(cfg: &BatchSweepConfig) -> Vec<(String, usize, usize, usize)> {
    let d_total: usize = cfg.dims.iter().product();
    let b_max = cfg.batch_sizes.iter().copied().max().unwrap_or(1);
    let d = cfg.dims[0];
    let map_rank = 5usize;
    let k2 = cfg.k * map_rank;
    vec![
        ("dense_flush".into(), b_max, d_total, cfg.k),
        ("flat_scan".into(), 256, cfg.k, 32),
        ("tt_absorb_row".into(), d * map_rank, map_rank, b_max.min(16) * cfg.input_rank),
        ("tt_absorb_input".into(), k2, d * cfg.input_rank, cfg.input_rank),
    ]
}

/// Micro-benchmark the kernel on the sweep's shape mix: both the live
/// packed kernel and the frozen PR 5 baseline see identical operands.
pub fn kernel_bench(cfg: &BatchSweepConfig) -> Vec<KernelRow> {
    let mut rng = Rng::seed_from(cfg.seed ^ 0x6E41);
    let mut rows = Vec::new();
    for (shape, m, kk, n) in kernel_shapes(cfg) {
        let a = rng.gaussian_vec(m * kk, 1.0);
        let b = rng.gaussian_vec(kk * n, 1.0);
        let mut c = vec![0.0; m * n];
        let r_new = bench(&format!("kernel/{shape}/packed"), cfg.bench, || {
            gemm::matmul_into(&a, &b, &mut c, m, kk, n);
            c[0]
        });
        let r_ref = bench(&format!("kernel/{shape}/reference"), cfg.bench, || {
            gemm::reference::matmul_into(&a, &b, &mut c, m, kk, n);
            c[0]
        });
        let flops = (2 * m * kk * n) as f64;
        let packed_gflops = flops / r_new.median_secs().max(1e-12) / 1e9;
        let reference_gflops = flops / r_ref.median_secs().max(1e-12) / 1e9;
        rows.push(KernelRow {
            shape,
            m,
            k: kk,
            n,
            packed_gflops,
            reference_gflops,
            speedup: packed_gflops / reference_gflops.max(1e-12),
        });
    }
    rows
}

/// Trace-overhead measurement: the B = 16 batched-TT serving point run
/// through a real coordinator with tracing off, then on. The contract is
/// twofold: the two response streams must be bit-identical (spans carry
/// ids, stage tags and timestamps — never numeric payload), and the
/// enabled-path cost per request must stay small (≤ 3% tripwire).
#[derive(Debug, Clone)]
pub struct TraceOverheadRow {
    /// Pipelined batch size of the measured point.
    pub batch: usize,
    /// Requests timed per run (after warmup).
    pub requests: usize,
    /// Per-request wall time with tracing off (µs).
    pub off_us_per_req: f64,
    /// Per-request wall time with tracing + GEMM profiling on (µs).
    pub on_us_per_req: f64,
    /// `on/off − 1` (small negative values are machine noise).
    pub overhead_frac: f64,
    /// Whether the two embedding streams were bit-identical.
    pub identical: bool,
}

/// Measure [`TraceOverheadRow`] on `cfg`'s shape: two coordinators with
/// the same master seed (hence identical maps), one traced into a temp
/// dir, fed the same pipelined TT-format rounds.
pub fn trace_overhead(cfg: &BatchSweepConfig) -> TraceOverheadRow {
    use crate::coordinator::{Coordinator, CoordinatorConfig, ProjectRequest};
    let b = 16usize;
    let warmup = 2usize;
    let rounds = 6usize;
    let mut rng = Rng::seed_from(cfg.seed ^ 0x0B5E);
    let inputs: Vec<AnyTensor> = (0..b)
        .map(|_| AnyTensor::Tt(TtTensor::random_unit(&cfg.dims, cfg.input_rank, &mut rng)))
        .collect();
    let run_once = |trace: Option<crate::obs::TraceConfig>| -> (f64, Vec<Vec<f64>>) {
        // The serve path switches GEMM profiling on with tracing; mirror
        // that here and switch it back off so runs stay comparable.
        crate::obs::set_gemm_profiling(trace.is_some());
        let coord = Coordinator::start(
            CoordinatorConfig {
                master_seed: cfg.seed,
                default_k: cfg.k,
                trace,
                ..Default::default()
            },
            None,
        );
        let mut outs = Vec::new();
        let mut timed = 0.0f64;
        let mut id = 0u64;
        for round in 0..(warmup + rounds) {
            let t0 = std::time::Instant::now();
            let rxs: Vec<_> = inputs
                .iter()
                .map(|x| {
                    id += 1;
                    coord.submit(ProjectRequest::new(id, x.clone()))
                })
                .collect();
            let embs: Vec<Vec<f64>> = rxs
                .into_iter()
                .map(|rx| rx.recv().expect("coordinator alive").expect("project ok").embedding)
                .collect();
            if round >= warmup {
                timed += t0.elapsed().as_secs_f64();
                outs.extend(embs);
            }
        }
        coord.shutdown();
        crate::obs::set_gemm_profiling(false);
        (timed * 1e6 / (rounds * b) as f64, outs)
    };
    let (off_us, e_off) = run_once(None);
    let dir = std::env::temp_dir().join(format!("trp_trace_overhead_{}", std::process::id()));
    let (on_us, e_on) = run_once(Some(crate::obs::TraceConfig::new(&dir)));
    let _ = std::fs::remove_dir_all(&dir);
    TraceOverheadRow {
        batch: b,
        requests: rounds * b,
        off_us_per_req: off_us,
        on_us_per_req: on_us,
        overhead_frac: on_us / off_us.max(1e-12) - 1.0,
        identical: e_off == e_on,
    }
}

/// WAL-overhead measurement: the B = 16 pipelined **insert** serving
/// point run through a real coordinator with the write-ahead log off,
/// then on (fsync mode `flush`, i.e. one group-commit fsync per touched
/// lane per flush). The contract is twofold: the two serving streams
/// must be bit-identical (the log is written ahead of the same apply,
/// never a different one), and WAL-on must retain ≥ 80% of WAL-off
/// insert throughput.
#[derive(Debug, Clone)]
pub struct WalOverheadRow {
    /// Pipelined batch size of the measured point.
    pub batch: usize,
    /// Inserts timed per run (after warmup).
    pub requests: usize,
    /// Per-insert wall time with the WAL off (µs).
    pub off_us_per_req: f64,
    /// Per-insert wall time with the WAL on (µs).
    pub on_us_per_req: f64,
    /// WAL-on throughput as a fraction of WAL-off (`off_us / on_us`).
    pub retained_frac: f64,
    /// Whether insert embeddings and post-ingest neighbor lists were
    /// bit-identical across the two runs.
    pub identical: bool,
}

/// Measure [`WalOverheadRow`] on `cfg`'s shape: two coordinators with
/// the same master seed (hence identical maps), one logging into a temp
/// WAL dir, fed the same pipelined TT-format insert rounds and then the
/// same probe queries.
pub fn wal_overhead(cfg: &BatchSweepConfig) -> WalOverheadRow {
    use crate::coordinator::{Coordinator, CoordinatorConfig, ProjectRequest};
    let b = 16usize;
    let warmup = 2usize;
    let rounds = 6usize;
    let mut rng = Rng::seed_from(cfg.seed ^ 0x3A1D);
    let inputs: Vec<AnyTensor> = (0..(warmup + rounds) * b)
        .map(|_| AnyTensor::Tt(TtTensor::random_unit(&cfg.dims, cfg.input_rank, &mut rng)))
        .collect();
    let probes: Vec<AnyTensor> = (0..4)
        .map(|_| AnyTensor::Tt(TtTensor::random_unit(&cfg.dims, cfg.input_rank, &mut rng)))
        .collect();
    let run_once = |wal: Option<&std::path::Path>| -> (f64, Vec<Vec<f64>>) {
        let coord = Coordinator::start(
            CoordinatorConfig {
                master_seed: cfg.seed,
                default_k: cfg.k,
                snapshot_dir: wal.map(|d| d.join("snap")),
                wal_dir: wal.map(|d| d.join("wal")),
                ..Default::default()
            },
            None,
        );
        let mut outs = Vec::new();
        let mut timed = 0.0f64;
        let mut id = 0u64;
        for round in 0..(warmup + rounds) {
            let xs = &inputs[round * b..(round + 1) * b];
            let t0 = std::time::Instant::now();
            let rxs: Vec<_> = xs
                .iter()
                .map(|x| {
                    id += 1;
                    coord.submit(ProjectRequest::insert(id, x.clone()))
                })
                .collect();
            let embs: Vec<Vec<f64>> = rxs
                .into_iter()
                .map(|rx| rx.recv().expect("coordinator alive").expect("insert ok").embedding)
                .collect();
            if round >= warmup {
                timed += t0.elapsed().as_secs_f64();
                outs.extend(embs);
            }
        }
        // Probe queries after ingest: an ordering or apply divergence
        // would surface here even if per-insert embeddings agree.
        for (i, p) in probes.iter().enumerate() {
            let resp = coord
                .project_blocking(ProjectRequest::query(90_000 + i as u64, p.clone(), 8))
                .expect("query ok");
            outs.push(
                resp.neighbors
                    .expect("neighbors present")
                    .iter()
                    .flat_map(|n| [n.id as f64, n.dist])
                    .collect(),
            );
        }
        coord.shutdown();
        (timed * 1e6 / (rounds * b) as f64, outs)
    };
    let (off_us, s_off) = run_once(None);
    let dir = std::env::temp_dir().join(format!("trp_wal_overhead_{}", std::process::id()));
    let (on_us, s_on) = run_once(Some(&dir));
    let _ = std::fs::remove_dir_all(&dir);
    WalOverheadRow {
        batch: b,
        requests: rounds * b,
        off_us_per_req: off_us,
        on_us_per_req: on_us,
        retained_frac: off_us / on_us.max(1e-12),
        identical: s_off == s_on,
    }
}

/// Render rows as the CSV written under `results/`.
pub fn to_csv(rows: &[BatchRow]) -> CsvTable {
    let mut t = CsvTable::new(&[
        "map",
        "input",
        "batch",
        "item_us_per_input",
        "batched_us_per_input",
        "speedup",
    ]);
    for r in rows {
        t.push_row(vec![
            r.map.clone(),
            r.input.clone(),
            r.batch.to_string(),
            format!("{:.3}", r.item_us),
            format!("{:.3}", r.batched_us),
            format!("{:.2}", r.speedup),
        ]);
    }
    t
}

/// Machine-readable trajectory document (`BENCH_batch_sweep.json`): one
/// series per `(map, input format)` with batched/item throughput and
/// speedup over `B`, plus a top-level `kernel` array of GFLOP/s rows
/// (packed vs frozen-PR 5 kernel) when the micro-benchmark ran. Shared
/// by the bench binary and `trp experiment batch` so both emit the same
/// schema. `trace` adds the `trace_overhead` entry and `wal` the
/// `wal_overhead` entry (each null when its measurement didn't run).
pub fn to_json(
    cfg: &BatchSweepConfig,
    rows: &[BatchRow],
    kernel: &[KernelRow],
    trace: Option<&TraceOverheadRow>,
    wal: Option<&WalOverheadRow>,
) -> Json {
    let mut keys: Vec<(String, String)> = Vec::new();
    for r in rows {
        let key = (r.map.clone(), r.input.clone());
        if keys.last() != Some(&key) {
            keys.push(key);
        }
    }
    let series: Vec<Json> = keys
        .iter()
        .map(|(name, input)| {
            let per: Vec<_> = rows
                .iter()
                .filter(|r| &r.map == name && &r.input == input)
                .collect();
            obj(vec![
                ("map", Json::Str(name.clone())),
                ("input", Json::Str(input.clone())),
                (
                    "batch_sizes",
                    Json::Arr(per.iter().map(|r| Json::Num(r.batch as f64)).collect()),
                ),
                (
                    "batched_throughput_per_s",
                    num_arr(
                        &per.iter()
                            .map(|r| 1e6 / r.batched_us.max(1e-12))
                            .collect::<Vec<f64>>(),
                    ),
                ),
                (
                    "item_throughput_per_s",
                    num_arr(
                        &per.iter()
                            .map(|r| 1e6 / r.item_us.max(1e-12))
                            .collect::<Vec<f64>>(),
                    ),
                ),
                ("speedup", num_arr(&per.iter().map(|r| r.speedup).collect::<Vec<f64>>())),
            ])
        })
        .collect();
    let kernel_rows: Vec<Json> = kernel
        .iter()
        .map(|r| {
            obj(vec![
                ("shape", Json::Str(r.shape.clone())),
                ("m", Json::Num(r.m as f64)),
                ("k", Json::Num(r.k as f64)),
                ("n", Json::Num(r.n as f64)),
                ("packed_gflops", Json::Num(r.packed_gflops)),
                ("reference_gflops", Json::Num(r.reference_gflops)),
                ("speedup", Json::Num(r.speedup)),
            ])
        })
        .collect();
    obj(vec![
        ("bench", Json::Str("batch_sweep".into())),
        ("dims", Json::Arr(cfg.dims.iter().map(|&d| Json::Num(d as f64)).collect())),
        ("k", Json::Num(cfg.k as f64)),
        ("input_rank", Json::Num(cfg.input_rank as f64)),
        ("series", Json::Arr(series)),
        ("kernel", Json::Arr(kernel_rows)),
        (
            "trace_overhead",
            match trace {
                Some(t) => obj(vec![
                    ("batch", Json::Num(t.batch as f64)),
                    ("requests", Json::Num(t.requests as f64)),
                    ("off_us_per_req", Json::Num(t.off_us_per_req)),
                    ("on_us_per_req", Json::Num(t.on_us_per_req)),
                    ("overhead_frac", Json::Num(t.overhead_frac)),
                    ("identical", Json::Bool(t.identical)),
                ]),
                None => Json::Null,
            },
        ),
        (
            "wal_overhead",
            match wal {
                Some(w) => obj(vec![
                    ("batch", Json::Num(w.batch as f64)),
                    ("requests", Json::Num(w.requests as f64)),
                    ("off_us_per_req", Json::Num(w.off_us_per_req)),
                    ("on_us_per_req", Json::Num(w.on_us_per_req)),
                    ("retained_frac", Json::Num(w.retained_frac)),
                    ("identical", Json::Bool(w.identical)),
                ]),
                None => Json::Null,
            },
        ),
    ])
}

/// Print the acceptance tripwire verdicts (report, don't panic: machine
/// load varies): batched TT-map throughput ≥ 2× item-at-a-time at B = 16
/// on dense **and** TT-format inputs.
pub fn print_verdict(rows: &[BatchRow]) {
    for r in rows.iter().filter(|r| r.map.starts_with("TT(") && r.batch == 16) {
        let verdict = if r.speedup >= 2.0 { "PASS" } else { "MISS" };
        println!(
            "[batch_sweep] TT {} B=16 batched speedup: {:.2}x ({verdict}, target ≥ 2x)",
            r.input, r.speedup
        );
    }
}

/// Print the tracing tripwire: responses bit-identical with tracing on
/// vs off, and the enabled-path cost per request small.
pub fn print_trace_verdict(t: &TraceOverheadRow) {
    let verdict = if t.identical { "PASS" } else { "FAIL" };
    println!(
        "[trace_overhead] B={} identical={} ({verdict}) off={:.1}µs/req on={:.1}µs/req \
         overhead={:+.1}% (target ≤ 3%)",
        t.batch,
        t.identical,
        t.off_us_per_req,
        t.on_us_per_req,
        t.overhead_frac * 100.0
    );
}

/// Print the WAL tripwire: responses bit-identical with the log on vs
/// off, and WAL-on insert throughput retaining ≥ 80% of WAL-off.
pub fn print_wal_verdict(w: &WalOverheadRow) {
    let verdict = if w.identical && w.retained_frac >= 0.8 { "PASS" } else { "MISS" };
    println!(
        "[wal_overhead] B={} identical={} off={:.1}µs/req on={:.1}µs/req \
         retained={:.1}% ({verdict}, target ≥ 80% and bit-identical)",
        w.batch,
        w.identical,
        w.off_us_per_req,
        w.on_us_per_req,
        w.retained_frac * 100.0
    );
}

/// Print the kernel tripwire: packed kernel ≥ 2× the frozen PR 5 scalar
/// kernel on the dominant (largest-flop) sweep shapes.
pub fn print_kernel_verdict(rows: &[KernelRow]) {
    for r in rows {
        let verdict = if r.speedup >= 2.0 { "PASS" } else { "MISS" };
        println!(
            "[kernel_bench] {} ({}x{}x{}): {:.2} GFLOP/s vs {:.2} reference = {:.2}x ({verdict}, target ≥ 2x on dominant shapes)",
            r.shape, r.m, r.k, r.n, r.packed_gflops, r.reference_gflops, r.speedup
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> BatchSweepConfig {
        BatchSweepConfig {
            dims: vec![3, 4],
            k: 4,
            input_rank: 2,
            batch_sizes: vec![1, 3],
            bench: BenchConfig { warmup: 0, samples: 1, min_time_secs: 0.0 },
            seed: 9,
        }
    }

    #[test]
    fn sweep_covers_all_maps_formats_and_batches() {
        let rows = run(&tiny());
        // 6 maps × dense + 3 tensorized maps × {tt, cp}, × 2 batch sizes.
        assert_eq!(rows.len(), (6 + 3 * 2) * 2);
        for r in &rows {
            assert!(r.item_us > 0.0 && r.batched_us > 0.0 && r.speedup.is_finite());
        }
        let mut tt_curves = 0;
        for r in &rows {
            if r.map.starts_with("TT(") && r.input == "tt" {
                tt_curves += 1;
            }
        }
        assert_eq!(tt_curves, 2, "TT-input curve must exist for the TT map");
    }

    #[test]
    fn csv_has_one_row_per_measurement() {
        let rows = run(&tiny());
        assert_eq!(to_csv(&rows).len(), rows.len());
    }

    #[test]
    fn json_has_one_series_per_map_input_pair() {
        let cfg = tiny();
        let rows = run(&cfg);
        let doc = to_json(&cfg, &rows, &[], None, None);
        let series = doc.get("series").and_then(Json::as_arr).expect("series array");
        assert_eq!(series.len(), 6 + 3 * 2);
        for s in series {
            let b = s.get("batch_sizes").and_then(Json::as_arr).expect("batch sizes");
            assert_eq!(b.len(), cfg.batch_sizes.len());
        }
        // Kernel array is present even when the micro-benchmark didn't run.
        let kernel = doc.get("kernel").and_then(Json::as_arr).expect("kernel array");
        assert!(kernel.is_empty());
        assert_eq!(doc.get("trace_overhead"), Some(&Json::Null));
        assert_eq!(doc.get("wal_overhead"), Some(&Json::Null));
    }

    #[test]
    fn trace_overhead_is_bit_identical_and_serializes() {
        let cfg = tiny();
        let t = trace_overhead(&cfg);
        assert!(t.identical, "tracing must not perturb embeddings");
        assert_eq!(t.batch, 16);
        assert!(t.off_us_per_req > 0.0 && t.on_us_per_req > 0.0);
        let doc = to_json(&cfg, &[], &[], Some(&t), None);
        let entry = doc.get("trace_overhead").expect("trace_overhead entry");
        assert_eq!(entry.get("identical").and_then(Json::as_bool), Some(true));
        assert!(entry.get("overhead_frac").and_then(Json::as_f64).is_some());
    }

    #[test]
    fn wal_overhead_is_bit_identical_and_serializes() {
        let cfg = tiny();
        let w = wal_overhead(&cfg);
        assert!(w.identical, "the write-ahead log must not perturb responses");
        assert_eq!(w.batch, 16);
        assert!(w.off_us_per_req > 0.0 && w.on_us_per_req > 0.0);
        assert!(w.retained_frac > 0.0 && w.retained_frac.is_finite());
        let doc = to_json(&cfg, &[], &[], None, Some(&w));
        let entry = doc.get("wal_overhead").expect("wal_overhead entry");
        assert_eq!(entry.get("identical").and_then(Json::as_bool), Some(true));
        assert!(entry.get("retained_frac").and_then(Json::as_f64).is_some());
    }

    #[test]
    fn kernel_bench_covers_shape_mix_and_serializes() {
        let cfg = tiny();
        let krows = kernel_bench(&cfg);
        assert_eq!(krows.len(), 4, "one row per hot-path shape");
        for r in &krows {
            assert!(r.m > 0 && r.k > 0 && r.n > 0);
            assert!(r.packed_gflops > 0.0 && r.reference_gflops > 0.0);
            assert!(r.speedup.is_finite());
        }
        let doc = to_json(&cfg, &run(&cfg), &krows, None, None);
        let kernel = doc.get("kernel").and_then(Json::as_arr).expect("kernel array");
        assert_eq!(kernel.len(), krows.len());
        for (j, r) in kernel.iter().zip(&krows) {
            assert_eq!(j.get("m").and_then(Json::as_f64), Some(r.m as f64));
            assert!(j.get("speedup").and_then(Json::as_f64).is_some());
        }
    }
}
