//! Batch-size sweep: item-at-a-time `project` vs the batch-first
//! `project_batch_into` path, per map family on dense inputs.
//!
//! This is the serving-layer counterpart of Figure 2's embedding-time
//! sweep: instead of varying `k`, it varies the flushed batch size `B`
//! (the coordinator's `native_max_batch`) and reports per-input time for
//! both execution routes, so the batched path's trajectory is tracked
//! across PRs (`cargo bench --bench batch_sweep` emits
//! `BENCH_batch_sweep.json`).

use crate::projections::{
    CpProjection, GaussianProjection, KroneckerFjlt, Projection, SparseKind, SparseProjection,
    TrpProjection, TtProjection, Workspace,
};
use crate::rng::Rng;
use crate::tensor::{AnyTensor, DenseTensor};
use crate::util::bench::{bench, BenchConfig};
use crate::util::csv::CsvTable;

/// Configuration of the batch-size sweep.
#[derive(Debug, Clone)]
pub struct BatchSweepConfig {
    /// Input mode sizes (inputs are dense, so `∏dims` must materialize).
    pub dims: Vec<usize>,
    /// Embedding dimension.
    pub k: usize,
    /// Flushed batch sizes to sweep.
    pub batch_sizes: Vec<usize>,
    /// Timing profile.
    pub bench: BenchConfig,
    /// Input/map seed.
    pub seed: u64,
}

impl BatchSweepConfig {
    /// Full sweep: the paper's medium-order shape, B ∈ {1, 4, 16, 64}.
    pub fn paper() -> Self {
        Self {
            dims: vec![3; 8],
            k: 64,
            batch_sizes: vec![1, 4, 16, 64],
            bench: BenchConfig::default(),
            seed: 0xBA7C4,
        }
    }

    /// Reduced sweep for smoke runs.
    pub fn quick() -> Self {
        Self {
            dims: vec![3; 6],
            k: 16,
            batch_sizes: vec![1, 4, 16],
            bench: BenchConfig::quick(),
            seed: 0xBA7C4,
        }
    }
}

/// One (map, batch size) measurement.
#[derive(Debug, Clone)]
pub struct BatchRow {
    /// Map label (`Projection::name`).
    pub map: String,
    /// Flushed batch size `B`.
    pub batch: usize,
    /// Median per-input time through a `project` loop (µs).
    pub item_us: f64,
    /// Median per-input time through one `project_batch_into` call (µs).
    pub batched_us: f64,
    /// `item_us / batched_us`.
    pub speedup: f64,
}

/// The six maps at serving-default ranks.
fn maps(dims: &[usize], k: usize, rng: &mut Rng) -> Vec<Box<dyn Projection>> {
    vec![
        Box::new(GaussianProjection::new(dims, k, rng)),
        Box::new(SparseProjection::new(dims, k, SparseKind::VerySparse, rng)),
        Box::new(TtProjection::new(dims, 5, k, rng)),
        Box::new(CpProjection::new(dims, 5, k, rng)),
        Box::new(TrpProjection::new(dims, 2, k, rng)),
        Box::new(KroneckerFjlt::new(dims, k, rng)),
    ]
}

/// Run the sweep; both routes see identical inputs and the same drawn map,
/// so rows differ only in execution path.
pub fn run(cfg: &BatchSweepConfig) -> Vec<BatchRow> {
    let mut rng = Rng::seed_from(cfg.seed);
    let maps = maps(&cfg.dims, cfg.k, &mut rng);
    let max_b = cfg.batch_sizes.iter().copied().max().unwrap_or(1);
    let inputs: Vec<AnyTensor> = (0..max_b)
        .map(|_| AnyTensor::Dense(DenseTensor::random_unit(&cfg.dims, &mut rng)))
        .collect();
    let mut rows = Vec::new();
    let mut ws = Workspace::new();
    for map in &maps {
        for &b in &cfg.batch_sizes {
            let xs = &inputs[..b];
            let r_item = bench(&format!("{}/item/B{b}", map.name()), cfg.bench, || {
                let mut acc = 0.0;
                for x in xs {
                    acc += map.project(x)[0];
                }
                acc
            });
            let mut out = vec![0.0; b * map.k()];
            let r_batch = bench(&format!("{}/batch/B{b}", map.name()), cfg.bench, || {
                map.project_batch_into(xs, &mut out, &mut ws);
                out[0]
            });
            let item_us = r_item.median_secs() * 1e6 / b as f64;
            let batched_us = r_batch.median_secs() * 1e6 / b as f64;
            rows.push(BatchRow {
                map: map.name(),
                batch: b,
                item_us,
                batched_us,
                speedup: item_us / batched_us.max(1e-12),
            });
        }
    }
    rows
}

/// Render rows as the CSV written under `results/`.
pub fn to_csv(rows: &[BatchRow]) -> CsvTable {
    let mut t = CsvTable::new(&[
        "map",
        "batch",
        "item_us_per_input",
        "batched_us_per_input",
        "speedup",
    ]);
    for r in rows {
        t.push_row(vec![
            r.map.clone(),
            r.batch.to_string(),
            format!("{:.3}", r.item_us),
            format!("{:.3}", r.batched_us),
            format!("{:.2}", r.speedup),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> BatchSweepConfig {
        BatchSweepConfig {
            dims: vec![3, 4],
            k: 4,
            batch_sizes: vec![1, 3],
            bench: BenchConfig { warmup: 0, samples: 1, min_time_secs: 0.0 },
            seed: 9,
        }
    }

    #[test]
    fn sweep_covers_all_maps_and_batches() {
        let rows = run(&tiny());
        assert_eq!(rows.len(), 6 * 2);
        for r in &rows {
            assert!(r.item_us > 0.0 && r.batched_us > 0.0 && r.speedup.is_finite());
        }
    }

    #[test]
    fn csv_has_one_row_per_measurement() {
        let rows = run(&tiny());
        assert_eq!(to_csv(&rows).len(), rows.len());
    }
}
