//! Figure 3 (Appendix B.1): pairwise-distance preservation on image data.
//!
//! 50 images (CIFAR-10 when available, synthetic natural-image model
//! otherwise — DESIGN.md §5) reshaped to `4×4×4×4×4×3`, normalized; the
//! metric is the mean pairwise ratio
//! `(1/(n(n−1)))·Σ_{i≠j} ‖f(x_i)−f(x_j)‖ / ‖x_i−x_j‖` and its std over
//! trials. Panels pair ranks so parameter counts match: rank 1 (TT1/CP1),
//! ranks 3–10 (TT3/CP10), ranks 5–25 (TT5/CP25); Gaussian RP everywhere.

use super::MapSpec;
use crate::data::images::{load_images, TENSOR_DIMS};
use crate::rng::Rng;
use crate::tensor::DenseTensor;
use crate::util::csv::CsvTable;
use std::path::PathBuf;

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct Fig3Config {
    /// Number of images (paper: 50).
    pub n_images: usize,
    /// Embedding dimensions to sweep.
    pub ks: Vec<usize>,
    /// Map redraws per point (paper: 100).
    pub trials: usize,
    /// Master seed.
    pub seed: u64,
    /// Optional CIFAR-10 binary batch path.
    pub cifar_path: Option<PathBuf>,
    /// Worker threads.
    pub threads: usize,
}

impl Fig3Config {
    /// Paper-style defaults (trials reduced from 100 to 25: the dense
    /// Gaussian redraw dominates; scale up via --trials for publication
    /// runs).
    pub fn paper() -> Self {
        Self {
            n_images: 50,
            ks: vec![5, 10, 25, 50, 100],
            trials: 25,
            seed: 0xF163,
            cifar_path: Some(PathBuf::from("data/cifar-10-batches-bin/data_batch_1.bin")),
            threads: super::default_threads(),
        }
    }

    /// Reduced settings for smoke tests.
    pub fn quick() -> Self {
        Self {
            n_images: 8,
            ks: vec![10, 40],
            trials: 4,
            ..Self::paper()
        }
    }
}

/// The three paper panels: (panel label, TT rank, CP rank).
pub fn panels() -> Vec<(&'static str, usize, usize)> {
    vec![("rank1", 1, 1), ("rank3_10", 3, 10), ("rank5_25", 5, 25)]
}

/// One output row.
#[derive(Debug, Clone)]
pub struct Fig3Row {
    /// Panel label.
    pub panel: String,
    /// Series label.
    pub map: String,
    /// Embedding dimension.
    pub k: usize,
    /// Mean pairwise-distance ratio (1.0 = perfect).
    pub mean_ratio: f64,
    /// Std of the ratio across trials.
    pub std_ratio: f64,
    /// Data source (`"cifar10"` or `"synthetic"`).
    pub source: String,
}

/// Mean pairwise ratio for one drawn map over the image set.
fn pairwise_ratio(f: &dyn crate::projections::Projection, tensors: &[DenseTensor]) -> f64 {
    let n = tensors.len();
    // Project each image once; use linearity for pair differences.
    let projected: Vec<Vec<f64>> = tensors.iter().map(|t| f.project_dense(t)).collect();
    let mut acc = 0.0;
    let mut count = 0usize;
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            let dx = tensors[i].sub(&tensors[j]).fro_norm();
            if dx < 1e-12 {
                continue;
            }
            let dy: f64 = projected[i]
                .iter()
                .zip(&projected[j])
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            acc += dy / dx;
            count += 1;
        }
    }
    acc / count as f64
}

/// Run the full sweep.
pub fn run(cfg: &Fig3Config) -> Vec<Fig3Row> {
    let (images, source) = load_images(cfg.n_images, cfg.cifar_path.as_deref(), cfg.seed);
    let tensors: Vec<DenseTensor> = images.iter().map(|im| im.to_tensor()).collect();
    let dims = TENSOR_DIMS.to_vec();
    let mut rows = Vec::new();
    for (panel, tt_rank, cp_rank) in panels() {
        let specs = vec![MapSpec::Gaussian, MapSpec::Tt(tt_rank), MapSpec::Cp(cp_rank)];
        for spec in specs {
            for &k in &cfg.ks {
                let trial_ids: Vec<u64> = (0..cfg.trials as u64).collect();
                let seed = crate::rng::derive_seed(cfg.seed, (k * 31 + tt_rank) as u64);
                let ratios = crate::util::threadpool::par_map(trial_ids, cfg.threads, |t| {
                    let mut rng = Rng::seed_from(crate::rng::derive_seed(seed, t));
                    let f = spec.build(&dims, k, &mut rng);
                    pairwise_ratio(f.as_ref(), &tensors)
                });
                let s = crate::util::stats::Summary::of(&ratios);
                rows.push(Fig3Row {
                    panel: panel.to_string(),
                    map: spec.label(),
                    k,
                    mean_ratio: s.mean,
                    std_ratio: s.std,
                    source: source.to_string(),
                });
            }
        }
    }
    rows
}

/// Render rows as CSV.
pub fn to_csv(rows: &[Fig3Row]) -> CsvTable {
    let mut t = CsvTable::new(&["panel", "map", "k", "mean_ratio", "std_ratio", "source"]);
    for r in rows {
        t.push_row(vec![
            r.panel.clone(),
            r.map.clone(),
            r.k.to_string(),
            format!("{:.6}", r.mean_ratio),
            format!("{:.6}", r.std_ratio),
            r.source.clone(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_covers_all_panels() {
        let mut cfg = Fig3Config::quick();
        cfg.n_images = 5;
        cfg.ks = vec![16];
        cfg.trials = 3;
        cfg.cifar_path = None;
        let rows = run(&cfg);
        // 3 panels × 3 series × 1 k.
        assert_eq!(rows.len(), 9);
        for r in &rows {
            assert!(r.mean_ratio.is_finite() && r.mean_ratio > 0.0, "{r:?}");
            assert_eq!(r.source, "synthetic");
        }
    }

    #[test]
    fn ratios_concentrate_near_one_for_large_k() {
        let mut cfg = Fig3Config::quick();
        cfg.n_images = 6;
        cfg.ks = vec![128];
        cfg.trials = 4;
        cfg.cifar_path = None;
        let rows = run(&cfg);
        // Gaussian at k=128 must sit well within 25% of 1.0.
        let g = rows
            .iter()
            .find(|r| r.map == "gaussian" && r.panel == "rank1")
            .unwrap();
        assert!(
            (g.mean_ratio - 1.0).abs() < 0.25,
            "gaussian ratio {g:?}"
        );
    }
}
