//! Ablations: the design-choice checks DESIGN.md calls out.
//!
//! * **Theorem 1 bound vs measurement** — empirical `Var(‖f(X)‖²)` against
//!   the TT/CP variance bounds across (N, R, k);
//! * **order-2 exact TT variance** — the paper's closed form
//!   `(2‖X‖⁴ + (6/R)Tr[(XᵀX)²])/k` vs measurement;
//! * **variance prescription ablation** — what happens to the expected
//!   isometry if Definition 1's per-core variances are replaced by naive
//!   unit variances (answer: the isometry breaks by a factor `R^{N/2}`-ish,
//!   which is *why* the prescription matters).

use crate::projections::{squared_norm, Projection};
use crate::rng::Rng;
use crate::tensor::{AnyTensor, TtTensor};
use crate::theory;
use crate::util::csv::CsvTable;
use crate::util::stats;

/// Configuration of the variance-bound sweep.
#[derive(Debug, Clone)]
pub struct AblationConfig {
    /// Orders to test.
    pub orders: Vec<usize>,
    /// Ranks to test.
    pub ranks: Vec<usize>,
    /// Embedding dimension.
    pub k: usize,
    /// Mode size.
    pub dim: usize,
    /// Map draws per point.
    pub trials: usize,
    /// Master seed.
    pub seed: u64,
    /// Worker threads.
    pub threads: usize,
}

impl AblationConfig {
    /// Defaults sized for a few seconds of runtime.
    pub fn default_sweep() -> Self {
        Self {
            orders: vec![2, 4, 6],
            ranks: vec![1, 2, 5],
            k: 16,
            dim: 3,
            trials: 400,
            seed: 0xAB1A,
            threads: super::default_threads(),
        }
    }

    /// Reduced settings for smoke tests.
    pub fn quick() -> Self {
        Self {
            orders: vec![3],
            ranks: vec![2],
            trials: 60,
            ..Self::default_sweep()
        }
    }
}

/// One bound-vs-measurement row.
#[derive(Debug, Clone)]
pub struct VarianceRow {
    /// `"tt"` or `"cp"`.
    pub map: String,
    /// Order `N`.
    pub order: usize,
    /// Rank `R`.
    pub rank: usize,
    /// Embedding dimension `k`.
    pub k: usize,
    /// Empirical mean of `‖f(X)‖²` (should be ≈ 1).
    pub emp_mean: f64,
    /// Empirical variance of `‖f(X)‖²`.
    pub emp_var: f64,
    /// Theorem 1 bound.
    pub bound: f64,
}

/// Empirical `(mean, var)` of `‖f(X)‖²` for a map-builder over trials.
fn norm_moments(
    build: impl Fn(&mut Rng) -> Box<dyn Projection> + Sync,
    x: &AnyTensor,
    trials: usize,
    seed: u64,
    threads: usize,
) -> (f64, f64) {
    let trial_ids: Vec<u64> = (0..trials as u64).collect();
    let vals = crate::util::threadpool::par_map(trial_ids, threads, |t| {
        let mut rng = Rng::seed_from(crate::rng::derive_seed(seed, t));
        let f = build(&mut rng);
        squared_norm(&f.project(x))
    });
    (stats::mean(&vals), stats::variance(&vals))
}

/// Run the Theorem-1 sweep for both maps.
pub fn run_variance_sweep(cfg: &AblationConfig) -> Vec<VarianceRow> {
    let mut rows = Vec::new();
    let mut rng = Rng::seed_from(cfg.seed);
    for &n in &cfg.orders {
        let dims = vec![cfg.dim; n];
        let x = AnyTensor::Tt(TtTensor::random_unit(&dims, 3.min(cfg.dim), &mut rng));
        for &r in &cfg.ranks {
            let seed = crate::rng::derive_seed(cfg.seed, (n * 1000 + r) as u64);
            let (m_tt, v_tt) = norm_moments(
                |rng| Box::new(crate::projections::TtProjection::new(&dims, r, cfg.k, rng)),
                &x,
                cfg.trials,
                seed,
                cfg.threads,
            );
            rows.push(VarianceRow {
                map: "tt".into(),
                order: n,
                rank: r,
                k: cfg.k,
                emp_mean: m_tt,
                emp_var: v_tt,
                bound: theory::tt_variance_bound(n, r, cfg.k),
            });
            let (m_cp, v_cp) = norm_moments(
                |rng| Box::new(crate::projections::CpProjection::new(&dims, r, cfg.k, rng)),
                &x,
                cfg.trials,
                seed ^ 1,
                cfg.threads,
            );
            rows.push(VarianceRow {
                map: "cp".into(),
                order: n,
                rank: r,
                k: cfg.k,
                emp_mean: m_cp,
                emp_var: v_cp,
                bound: theory::cp_variance_bound(n, r, cfg.k),
            });
        }
    }
    rows
}

/// Ablation: replace Definition 1's variances with naive unit-variance
/// cores and report the resulting `E‖f(X)‖²` (exposes why the paper's
/// prescription is what it is). Returns `(prescribed, naive)` means.
pub fn run_prescription_ablation(
    n: usize,
    r: usize,
    k: usize,
    trials: usize,
    seed: u64,
) -> (f64, f64) {
    let dims = vec![3usize; n];
    let mut rng = Rng::seed_from(seed);
    let x = TtTensor::random_unit(&dims, 2, &mut rng);
    let mut prescribed = Vec::with_capacity(trials);
    let mut naive = Vec::with_capacity(trials);
    let scale = 1.0 / (k as f64).sqrt();
    for _ in 0..trials {
        // Prescribed (Definition 1) rows.
        let mut acc_p = 0.0;
        let mut acc_n = 0.0;
        for _ in 0..k {
            let row_p = TtTensor::random_projection_row(&dims, r, &mut rng);
            let y = row_p.inner(&x) * scale;
            acc_p += y * y;
            let row_n = TtTensor::random(&dims, r, &mut rng); // unit-variance cores
            let z = row_n.inner(&x) * scale;
            acc_n += z * z;
        }
        prescribed.push(acc_p);
        naive.push(acc_n);
    }
    (stats::mean(&prescribed), stats::mean(&naive))
}

/// JL point-set experiment (the actual Theorem 2 statement): embed `m`
/// points simultaneously and report the **maximum pairwise distortion**
/// `max_{u≠v} |‖f(u)−f(v)‖²/‖u−v‖² − 1|` over `trials` map draws.
#[derive(Debug, Clone)]
pub struct JlSetRow {
    /// Map label.
    pub map: String,
    /// Embedding dimension.
    pub k: usize,
    /// Mean (over trials) of the max pairwise distortion.
    pub mean_max_distortion: f64,
    /// Fraction of trials where every pair stayed within ε.
    pub success_rate: f64,
}

/// Run the JL point-set sweep on `m` medium-order TT points.
pub fn run_jl_set(
    m: usize,
    ks: &[usize],
    eps: f64,
    trials: usize,
    seed: u64,
) -> Vec<JlSetRow> {
    use crate::experiments::MapSpec;
    let dims = vec![3usize; 8];
    let mut rng = Rng::seed_from(seed);
    let points: Vec<TtTensor> = (0..m)
        .map(|_| TtTensor::random_unit(&dims, 4, &mut rng))
        .collect();
    // Precompute exact pairwise squared distances in TT format.
    let mut pair_d2 = Vec::new();
    for i in 0..m {
        for j in (i + 1)..m {
            let d2 = points[i].inner(&points[i]) + points[j].inner(&points[j])
                - 2.0 * points[i].inner(&points[j]);
            pair_d2.push(((i, j), d2));
        }
    }
    let mut rows = Vec::new();
    for spec in [MapSpec::Tt(5), MapSpec::Cp(25)] {
        for &k in ks {
            let mut maxes = Vec::with_capacity(trials);
            let mut successes = 0usize;
            for t in 0..trials as u64 {
                let mut rng = Rng::seed_from(crate::rng::derive_seed(seed ^ k as u64, t));
                let f = spec.build(&dims, k, &mut rng);
                let embs: Vec<Vec<f64>> = points.iter().map(|p| f.project_tt(p)).collect();
                let mut worst = 0.0f64;
                for &((i, j), d2) in &pair_d2 {
                    let mut pd2 = 0.0;
                    for (a, b) in embs[i].iter().zip(&embs[j]) {
                        pd2 += (a - b) * (a - b);
                    }
                    worst = worst.max((pd2 / d2 - 1.0).abs());
                }
                maxes.push(worst);
                if worst <= eps {
                    successes += 1;
                }
            }
            rows.push(JlSetRow {
                map: spec.label(),
                k,
                mean_max_distortion: stats::mean(&maxes),
                success_rate: successes as f64 / trials as f64,
            });
        }
    }
    rows
}

/// Render JL point-set rows as CSV.
pub fn jl_set_to_csv(rows: &[JlSetRow]) -> CsvTable {
    let mut t = CsvTable::new(&["map", "k", "mean_max_distortion", "success_rate"]);
    for r in rows {
        t.push_row(vec![
            r.map.clone(),
            r.k.to_string(),
            format!("{:.4}", r.mean_max_distortion),
            format!("{:.3}", r.success_rate),
        ]);
    }
    t
}

/// Render variance rows as CSV.
pub fn to_csv(rows: &[VarianceRow]) -> CsvTable {
    let mut t = CsvTable::new(&["map", "order", "rank", "k", "emp_mean", "emp_var", "bound"]);
    for r in rows {
        t.push_row(vec![
            r.map.clone(),
            r.order.to_string(),
            r.rank.to_string(),
            r.k.to_string(),
            format!("{:.6}", r.emp_mean),
            format!("{:.6e}", r.emp_var),
            format!("{:.6e}", r.bound),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empirical_variance_respects_bound() {
        let cfg = AblationConfig::quick();
        let rows = run_variance_sweep(&cfg);
        for r in &rows {
            assert!((r.emp_mean - 1.0).abs() < 0.3, "isometry broken: {r:?}");
            // CLT slack: with 60 trials the sample variance can exceed the
            // true variance by ~(1 + 3√(2/60)); use a 2× guard.
            assert!(
                r.emp_var <= r.bound * 2.0,
                "variance above bound with slack: {r:?}"
            );
        }
    }

    #[test]
    fn jl_set_success_improves_with_k() {
        let rows = run_jl_set(6, &[8, 256], 0.9, 8, 3);
        let tt8 = rows.iter().find(|r| r.map == "tt_r5" && r.k == 8).unwrap();
        let tt256 = rows.iter().find(|r| r.map == "tt_r5" && r.k == 256).unwrap();
        assert!(
            tt256.mean_max_distortion < tt8.mean_max_distortion,
            "{} vs {}",
            tt256.mean_max_distortion,
            tt8.mean_max_distortion
        );
        assert!(tt256.success_rate >= tt8.success_rate);
    }

    #[test]
    fn naive_variance_breaks_isometry() {
        let (prescribed, naive) = run_prescription_ablation(4, 3, 8, 40, 5);
        assert!((prescribed - 1.0).abs() < 0.4, "prescribed={prescribed}");
        // Unit-variance cores inflate E‖f(X)‖² by ≈ R^{N-1} ≫ 1.
        assert!(naive > 5.0, "naive={naive}");
    }
}
