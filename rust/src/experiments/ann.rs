//! ANN recall/QPS sweep: retrieval quality of the similarity-search
//! subsystem as a function of the projection dimension `m`, for TT vs CP
//! vs dense Gaussian maps.
//!
//! This re-validates the paper's core claim — TT needs a smaller embedding
//! dimension than CP for the same distortion (Theorem 2's `k_CP/k_TT`
//! ratio) — as an *end-to-end retrieval* measurement: recall@`topk` of
//! projected-space nearest neighbours against exact original-space
//! (TT-format) nearest neighbours, on a clustered corpus where neighbour
//! structure is planted rather than uniform. Both index backends run on
//! the same embeddings, so the sweep also tracks the LSH backend's recall
//! floor and the flat/LSH QPS trade-off.
//!
//! `trp experiment ann [--quick]` prints the table, writes
//! `results/ann_sweep.csv` and emits the machine-readable trajectory
//! `BENCH_ann_sweep.json` (also produced by `cargo bench --bench
//! ann_sweep`).

use crate::experiments::MapSpec;
use crate::index::{AnnIndex, BackendKind, LshConfig, Neighbor, ShardedIndex};
use crate::projections::{Projection, Workspace};
use crate::rng::{derive_seed, Rng};
use crate::tensor::{AnyTensor, TtTensor};
use crate::util::csv::CsvTable;
use crate::util::json::{num_arr, obj, Json};

/// Configuration of the ANN sweep.
#[derive(Debug, Clone)]
pub struct AnnSweepConfig {
    /// Input mode sizes (corpus items are TT tensors of this shape).
    pub dims: Vec<usize>,
    /// TT rank of corpus/query tensors.
    pub input_rank: usize,
    /// Stored items.
    pub n_corpus: usize,
    /// Queries per measurement.
    pub n_queries: usize,
    /// Neighbours retrieved per query (recall@topk).
    pub topk: usize,
    /// Projection dimensions `m` to sweep.
    pub ms: Vec<usize>,
    /// TT rank of the `f_TT(R)` map.
    pub tt_rank: usize,
    /// CP rank of the `f_CP(R)` map.
    pub cp_rank: usize,
    /// LSH backend shape.
    pub lsh: LshConfig,
    /// Shard counts to sweep (QPS-vs-shard-count series; recall is
    /// asserted bit-identical across counts — the sharding contract).
    pub shards: Vec<usize>,
    /// Master seed (corpus, maps and hash planes all derive from it).
    pub seed: u64,
}

impl AnnSweepConfig {
    /// Full sweep: 10-mode inputs (ambient dim 3¹⁰ = 59 049), m up to 64.
    pub fn paper() -> Self {
        Self {
            dims: vec![3; 10],
            input_rank: 5,
            n_corpus: 256,
            n_queries: 32,
            topk: 10,
            ms: vec![4, 6, 8, 12, 16, 24, 32, 64],
            tt_rank: 5,
            cp_rank: 5,
            lsh: LshConfig::default(),
            shards: vec![1, 2, 4],
            seed: 0xA22,
        }
    }

    /// Reduced sweep for smoke runs.
    pub fn quick() -> Self {
        Self {
            dims: vec![3; 7],
            input_rank: 3,
            n_corpus: 48,
            n_queries: 8,
            topk: 5,
            ms: vec![4, 8, 16],
            tt_rank: 3,
            cp_rank: 3,
            lsh: LshConfig { tables: 6, bits: 8, probes: 4 },
            shards: vec![1, 2],
            seed: 0xA22,
        }
    }
}

/// One (map, m) measurement.
#[derive(Debug, Clone)]
pub struct AnnRow {
    /// Map label ([`MapSpec::label`]).
    pub map: String,
    /// Projection dimension `m`.
    pub m: usize,
    /// Index shard count of this measurement.
    pub shards: usize,
    /// recall@topk of the flat (exact projected-space) backend.
    pub flat_recall: f64,
    /// recall@topk of the LSH backend.
    pub lsh_recall: f64,
    /// Flat-backend query throughput (queries/s).
    pub flat_qps: f64,
    /// LSH-backend query throughput (queries/s).
    pub lsh_qps: f64,
    /// Stored parameters of the projection map.
    pub map_params: usize,
}

/// Clustered corpus + queries: TT tensors additively jittered around
/// shared cluster centres (`x = normalize(c + σ·noise)`, all in TT
/// format — the sum raises the TT rank, which the projection fast paths
/// handle), so nearest neighbours are meaningful (a query's true
/// neighbours are its own cluster) instead of the degenerate
/// uniform-random case where all distances coincide. Cluster size tracks
/// `topk`, so recall measures cluster recovery: within-cluster squared
/// distances are ≈ `2σ²/(1+σ²)` while cross-cluster ones are ≈ 2, a
/// margin the JL maps must preserve.
fn clustered_inputs(cfg: &AnnSweepConfig, rng: &mut Rng) -> (Vec<TtTensor>, Vec<TtTensor>) {
    let n_centers = (cfg.n_corpus / cfg.topk.max(1)).max(2);
    let sigma = 0.35;
    let centers: Vec<TtTensor> = (0..n_centers)
        .map(|_| TtTensor::random_unit(&cfg.dims, cfg.input_rank, rng))
        .collect();
    let jitter = |center: &TtTensor, rng: &mut Rng| -> TtTensor {
        let mut noise = TtTensor::random_unit(&cfg.dims, cfg.input_rank, rng);
        noise.scale(sigma);
        let mut t = center.add(&noise);
        let norm = t.fro_norm();
        if norm > 0.0 {
            t.scale(1.0 / norm);
        }
        t
    };
    let corpus: Vec<TtTensor> = (0..cfg.n_corpus)
        .map(|i| jitter(&centers[i % n_centers], rng))
        .collect();
    let queries: Vec<TtTensor> = (0..cfg.n_queries)
        .map(|i| jitter(&centers[i % n_centers], rng))
        .collect();
    (corpus, queries)
}

/// Exact original-space top-`topk` ids per query, computed entirely in TT
/// format (`‖x−q‖² = ‖x‖² + ‖q‖² − 2⟨x,q⟩`, no densification).
fn true_neighbors(corpus: &[TtTensor], queries: &[TtTensor], topk: usize) -> Vec<Vec<u64>> {
    let corpus_n2: Vec<f64> = corpus
        .iter()
        .map(|x| {
            let n = x.fro_norm();
            n * n
        })
        .collect();
    queries
        .iter()
        .map(|q| {
            let qn = q.fro_norm();
            let qn2 = qn * qn;
            let mut sel = crate::index::TopK::new(topk);
            for (i, x) in corpus.iter().enumerate() {
                let d2 = (corpus_n2[i] + qn2 - 2.0 * q.inner(x)).max(0.0);
                sel.offer(i as u64, d2.sqrt());
            }
            sel.into_sorted().into_iter().map(|n| n.id).collect()
        })
        .collect()
}

/// Mean recall of retrieved neighbour sets against the true id sets.
pub fn recall(results: &[Vec<Neighbor>], truth: &[Vec<u64>]) -> f64 {
    let mut hits = 0usize;
    let mut total = 0usize;
    for (res, t) in results.iter().zip(truth) {
        total += t.len();
        hits += res.iter().filter(|n| t.contains(&n.id)).count();
    }
    if total == 0 {
        0.0
    } else {
        hits as f64 / total as f64
    }
}

/// Whether the dense Gaussian baseline is worth materializing at this
/// size (`m·D` matrix entries; beyond the bound the tensorized maps are
/// the whole point).
fn gaussian_feasible(dims: &[usize], m: usize) -> bool {
    let d: usize = dims.iter().product();
    d.saturating_mul(m) <= (1 << 24)
}

/// Run the sweep. Skipped (infeasible) Gaussian cells are logged, not
/// silently dropped.
pub fn run(cfg: &AnnSweepConfig) -> Vec<AnnRow> {
    let mut rng = Rng::seed_from(cfg.seed);
    let (corpus, queries) = clustered_inputs(cfg, &mut rng);
    let truth = true_neighbors(&corpus, &queries, cfg.topk);
    let specs = [
        MapSpec::Tt(cfg.tt_rank),
        MapSpec::Cp(cfg.cp_rank),
        MapSpec::Gaussian,
    ];
    let mut rows = Vec::new();
    let mut ws = Workspace::new();
    let corpus_any: Vec<AnyTensor> = corpus.iter().map(|t| AnyTensor::Tt(t.clone())).collect();
    let query_any: Vec<AnyTensor> = queries.iter().map(|t| AnyTensor::Tt(t.clone())).collect();
    let topks = vec![cfg.topk; cfg.n_queries];
    for (si, spec) in specs.iter().enumerate() {
        for (mi, &m) in cfg.ms.iter().enumerate() {
            if matches!(spec, MapSpec::Gaussian) && !gaussian_feasible(&cfg.dims, m) {
                eprintln!("[ann] skipping gaussian at m={m}: dense matrix not materializable");
                continue;
            }
            let stream = ((si as u64) << 32) | mi as u64;
            let mut map_rng = Rng::seed_from(derive_seed(cfg.seed, stream));
            let map = spec.build(&cfg.dims, m, &mut map_rng);
            // Batch-first embedding of corpus and queries.
            let emb = map.project_batch(&corpus_any, &mut ws);
            let qemb = map.project_batch(&query_any, &mut ws);
            // Same embeddings into both backends, across the shard-count
            // axis (scatter-gather over S partitions; S = 1 is the plain
            // unsharded scan).
            let index_seed = derive_seed(cfg.seed, 0xB00 ^ stream);
            let mut baseline: Option<(Vec<Vec<Neighbor>>, Vec<Vec<Neighbor>>)> = None;
            for &s in &cfg.shards {
                let mut flat = ShardedIndex::new(BackendKind::Flat, m, &cfg.lsh, index_seed, s);
                let mut lsh = ShardedIndex::new(BackendKind::Lsh, m, &cfg.lsh, index_seed, s);
                for (i, row) in emb.chunks_exact(m).enumerate() {
                    flat.insert(i as u64, row);
                    lsh.insert(i as u64, row);
                }
                let t0 = std::time::Instant::now();
                let flat_res = flat.query_batch(&qemb, &topks, &mut ws);
                let flat_secs = t0.elapsed().as_secs_f64();
                let t0 = std::time::Instant::now();
                let lsh_res = lsh.query_batch(&qemb, &topks, &mut ws);
                let lsh_secs = t0.elapsed().as_secs_f64();
                // The sharding contract, checked live on every cell:
                // answers must be bit-identical across shard counts.
                match &baseline {
                    None => baseline = Some((flat_res.clone(), lsh_res.clone())),
                    Some((f0, l0)) => {
                        assert_eq!(&flat_res, f0, "sharded flat answers must be bit-identical");
                        assert_eq!(&lsh_res, l0, "sharded LSH answers must be bit-identical");
                    }
                }
                rows.push(AnnRow {
                    map: spec.label(),
                    m,
                    shards: s,
                    flat_recall: recall(&flat_res, &truth),
                    lsh_recall: recall(&lsh_res, &truth),
                    flat_qps: cfg.n_queries as f64 / flat_secs.max(1e-9),
                    lsh_qps: cfg.n_queries as f64 / lsh_secs.max(1e-9),
                    map_params: map.num_params(),
                });
            }
        }
    }
    rows
}

/// Render rows as the CSV written under `results/`.
pub fn to_csv(rows: &[AnnRow]) -> CsvTable {
    let mut t = CsvTable::new(&[
        "map",
        "m",
        "shards",
        "flat_recall",
        "lsh_recall",
        "flat_qps",
        "lsh_qps",
        "map_params",
    ]);
    for r in rows {
        t.push_row(vec![
            r.map.clone(),
            r.m.to_string(),
            r.shards.to_string(),
            format!("{:.4}", r.flat_recall),
            format!("{:.4}", r.lsh_recall),
            format!("{:.1}", r.flat_qps),
            format!("{:.1}", r.lsh_qps),
            r.map_params.to_string(),
        ]);
    }
    t
}

/// Machine-readable trajectory document (`BENCH_ann_sweep.json`): one
/// series per `(map, shard count)` — recall curves are shard-invariant by
/// the sharding contract, while the QPS curves expose the scatter-gather
/// overhead/scaling across the shard axis.
pub fn to_json(cfg: &AnnSweepConfig, rows: &[AnnRow]) -> Json {
    let mut groups: Vec<(String, usize)> = Vec::new();
    for r in rows {
        let g = (r.map.clone(), r.shards);
        if !groups.contains(&g) {
            groups.push(g);
        }
    }
    let series: Vec<Json> = groups
        .iter()
        .map(|(name, shards)| {
            let per: Vec<&AnnRow> = rows
                .iter()
                .filter(|r| &r.map == name && r.shards == *shards)
                .collect();
            obj(vec![
                ("map", Json::Str(name.clone())),
                ("shards", Json::Num(*shards as f64)),
                (
                    "ms",
                    Json::Arr(per.iter().map(|r| Json::Num(r.m as f64)).collect()),
                ),
                (
                    "flat_recall",
                    num_arr(&per.iter().map(|r| r.flat_recall).collect::<Vec<f64>>()),
                ),
                (
                    "lsh_recall",
                    num_arr(&per.iter().map(|r| r.lsh_recall).collect::<Vec<f64>>()),
                ),
                (
                    "flat_qps",
                    num_arr(&per.iter().map(|r| r.flat_qps).collect::<Vec<f64>>()),
                ),
                (
                    "lsh_qps",
                    num_arr(&per.iter().map(|r| r.lsh_qps).collect::<Vec<f64>>()),
                ),
            ])
        })
        .collect();
    obj(vec![
        ("bench", Json::Str("ann_sweep".into())),
        (
            "dims",
            Json::Arr(cfg.dims.iter().map(|&d| Json::Num(d as f64)).collect()),
        ),
        ("topk", Json::Num(cfg.topk as f64)),
        ("n_corpus", Json::Num(cfg.n_corpus as f64)),
        ("n_queries", Json::Num(cfg.n_queries as f64)),
        (
            "shards",
            Json::Arr(cfg.shards.iter().map(|&s| Json::Num(s as f64)).collect()),
        ),
        ("series", Json::Arr(series)),
    ])
}

/// The paper-claim verdict: the smallest `m` where TT reaches
/// recall@topk ≥ 0.9 on the flat backend while CP at the same `m` is
/// strictly lower. Returns `(m, tt_recall, cp_recall)` when found.
pub fn tt_beats_cp_at(rows: &[AnnRow]) -> Option<(usize, f64, f64)> {
    let mut ms: Vec<usize> = rows.iter().map(|r| r.m).collect();
    ms.sort_unstable();
    ms.dedup();
    for m in ms {
        let tt = rows
            .iter()
            .find(|r| r.m == m && r.map.starts_with("tt_"))
            .map(|r| r.flat_recall);
        let cp = rows
            .iter()
            .find(|r| r.m == m && r.map.starts_with("cp_"))
            .map(|r| r.flat_recall);
        if let (Some(tt), Some(cp)) = (tt, cp) {
            if tt >= 0.9 && cp < tt {
                return Some((m, tt, cp));
            }
        }
    }
    None
}

/// Print the acceptance verdict (report, don't panic: it is a statistical
/// claim and machine/seed variation is expected at small sweep sizes).
pub fn print_verdict(rows: &[AnnRow]) {
    match tt_beats_cp_at(rows) {
        Some((m, tt, cp)) => println!(
            "[ann] PASS: TT recall {tt:.3} ≥ 0.9 at m={m} with CP strictly lower ({cp:.3})"
        ),
        None => println!(
            "[ann] MISS: no m with TT recall ≥ 0.9 and CP strictly lower — inspect the table"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> AnnSweepConfig {
        AnnSweepConfig {
            dims: vec![3; 5],
            input_rank: 2,
            n_corpus: 24,
            n_queries: 4,
            topk: 3,
            ms: vec![4, 16],
            tt_rank: 2,
            cp_rank: 2,
            lsh: LshConfig { tables: 4, bits: 6, probes: 2 },
            shards: vec![1, 3],
            seed: 11,
        }
    }

    #[test]
    fn sweep_covers_all_feasible_cells() {
        let rows = run(&tiny());
        // 3 maps × 2 ms × 2 shard counts, all feasible at this size.
        // (`run` itself asserts recall is bit-identical across the shard
        // axis — the sharding contract, checked live on every cell.)
        assert_eq!(rows.len(), 12);
        for r in &rows {
            assert!((0.0..=1.0).contains(&r.flat_recall), "{r:?}");
            assert!((0.0..=1.0).contains(&r.lsh_recall), "{r:?}");
            assert!(r.flat_qps > 0.0 && r.lsh_qps > 0.0);
            assert!(r.map_params > 0);
        }
        for pair in rows.chunks_exact(2) {
            assert_eq!((pair[0].shards, pair[1].shards), (1, 3));
            assert_eq!(pair[0].flat_recall, pair[1].flat_recall);
            assert_eq!(pair[0].lsh_recall, pair[1].lsh_recall);
        }
    }

    #[test]
    fn sweep_is_deterministic_in_seed() {
        let a = run(&tiny());
        let b = run(&tiny());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.map, y.map);
            assert_eq!(x.m, y.m);
            assert_eq!(x.flat_recall, y.flat_recall);
            assert_eq!(x.lsh_recall, y.lsh_recall);
        }
    }

    #[test]
    fn recall_helper_counts_hits() {
        let results = vec![vec![
            Neighbor { id: 1, dist: 0.0 },
            Neighbor { id: 2, dist: 1.0 },
        ]];
        let truth = vec![vec![1u64, 3u64]];
        assert!((recall(&results, &truth) - 0.5).abs() < 1e-12);
        assert_eq!(recall(&[], &[]), 0.0);
    }

    #[test]
    fn csv_and_json_cover_all_rows() {
        let cfg = tiny();
        let rows = run(&cfg);
        assert_eq!(to_csv(&rows).len(), rows.len());
        let doc = to_json(&cfg, &rows);
        let series = doc.get("series").and_then(Json::as_arr).unwrap();
        assert_eq!(series.len(), 6, "one series per (map family, shard count)");
        for s in series {
            let shards = s.get("shards").and_then(Json::as_usize).unwrap();
            assert!(shards == 1 || shards == 3);
            assert_eq!(
                s.get("ms").and_then(Json::as_arr).unwrap().len(),
                cfg.ms.len(),
                "every m belongs to exactly one (map, shards) series"
            );
        }
    }

    #[test]
    fn ground_truth_self_query_hits_itself() {
        let mut rng = Rng::seed_from(5);
        let dims = vec![3usize; 5];
        let corpus: Vec<TtTensor> = (0..10)
            .map(|_| TtTensor::random_unit(&dims, 2, &mut rng))
            .collect();
        // Query = corpus item 4: its nearest true neighbour is itself.
        let truth = true_neighbors(&corpus, &corpus[4..5], 3);
        assert_eq!(truth[0][0], 4);
    }
}
