//! Figure 1: distortion ratio vs embedding dimension `k` for the three
//! input regimes (small / medium / high order).
//!
//! Series (matching the paper's legends):
//! * small:  Gaussian, TT(2,5,10), CP(4,25,100)
//! * medium: very sparse RP, TT(2,5,10), CP(4,25,100)
//! * high:   TT(2,5,10), CP(4,25,100)  (dense/sparse infeasible)
//!
//! The rank pairs are chosen by the paper so TT(R) and CP(R') have
//! roughly equal parameter counts: `(N−2)dR² + 2dR ≈ NdR'`.

use super::{mean_distortion, MapSpec};
use crate::data::inputs::{regime_input, Regime};
use crate::rng::Rng;
use crate::tensor::AnyTensor;
use crate::util::csv::CsvTable;

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct Fig1Config {
    /// Input regime.
    pub regime: Regime,
    /// Embedding dimensions to sweep.
    pub ks: Vec<usize>,
    /// Independent map draws per point (paper: 100).
    pub trials: usize,
    /// Master seed.
    pub seed: u64,
    /// Worker threads.
    pub threads: usize,
}

impl Fig1Config {
    /// Paper-faithful defaults for a regime.
    pub fn paper(regime: Regime) -> Self {
        Self {
            regime,
            ks: vec![5, 10, 20, 50, 100, 200],
            trials: 100,
            seed: 0xF161,
            threads: super::default_threads(),
        }
    }

    /// Reduced settings for smoke tests / quick benches.
    pub fn quick(regime: Regime) -> Self {
        Self {
            ks: vec![5, 20, 80],
            trials: 12,
            ..Self::paper(regime)
        }
    }
}

/// The projection series for a regime.
pub fn series_for(regime: Regime) -> Vec<MapSpec> {
    let tensorized = [
        MapSpec::Tt(2),
        MapSpec::Tt(5),
        MapSpec::Tt(10),
        MapSpec::Cp(4),
        MapSpec::Cp(25),
        MapSpec::Cp(100),
    ];
    let mut out: Vec<MapSpec> = Vec::new();
    match regime {
        Regime::Small => out.push(MapSpec::Gaussian),
        Regime::Medium => out.push(MapSpec::VerySparse),
        Regime::High => {}
    }
    out.extend(tensorized);
    out
}

/// One output row.
#[derive(Debug, Clone)]
pub struct Fig1Row {
    /// Series label.
    pub map: String,
    /// Embedding dimension.
    pub k: usize,
    /// Mean distortion ratio over trials.
    pub mean: f64,
    /// Std of the distortion ratio.
    pub std: f64,
}

/// Run the sweep; returns all rows.
pub fn run(cfg: &Fig1Config) -> Vec<Fig1Row> {
    let mut rng = Rng::seed_from(cfg.seed);
    let x = AnyTensor::Tt(regime_input(cfg.regime, &mut rng));
    let numel = crate::tensor::Shape::new(x.dims()).numel_f64();
    let mut rows = Vec::new();
    for spec in series_for(cfg.regime) {
        if !spec.feasible(numel) {
            continue;
        }
        for &k in &cfg.ks {
            let (mean, std) = mean_distortion(
                spec,
                &x,
                k,
                cfg.trials,
                crate::rng::derive_seed(cfg.seed, k as u64),
                cfg.threads,
            );
            rows.push(Fig1Row { map: spec.label(), k, mean, std });
        }
    }
    rows
}

/// Render rows as the CSV the bench target writes.
pub fn to_csv(regime: Regime, rows: &[Fig1Row]) -> CsvTable {
    let mut t = CsvTable::new(&["case", "map", "k", "mean_distortion", "std_distortion"]);
    for r in rows {
        t.push_row(vec![
            regime.name().to_string(),
            r.map.clone(),
            r.k.to_string(),
            format!("{:.6}", r.mean),
            format!("{:.6}", r.std),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_match_paper_legends() {
        let small = series_for(Regime::Small);
        assert!(small.contains(&MapSpec::Gaussian));
        assert!(!small.contains(&MapSpec::VerySparse));
        let medium = series_for(Regime::Medium);
        assert!(medium.contains(&MapSpec::VerySparse));
        let high = series_for(Regime::High);
        assert_eq!(high.len(), 6, "high order: tensorized maps only");
    }

    #[test]
    fn quick_run_produces_all_rows() {
        let mut cfg = Fig1Config::quick(Regime::Small);
        cfg.ks = vec![4, 16];
        cfg.trials = 4;
        let rows = run(&cfg);
        // 7 series × 2 k values.
        assert_eq!(rows.len(), 14);
        assert!(rows.iter().all(|r| r.mean.is_finite() && r.mean >= 0.0));
        let csv = to_csv(Regime::Small, &rows);
        assert_eq!(csv.len(), 14);
    }

    #[test]
    fn tt_beats_cp_at_high_order_quickcheck() {
        // A coarse version of the paper's headline claim, cheap enough for
        // unit tests: at N=25 with matched parameter budgets, TT(5)
        // distorts far less than CP(25).
        let cfg = Fig1Config {
            regime: Regime::High,
            ks: vec![50],
            trials: 8,
            seed: 11,
            threads: 2,
        };
        let mut rng = Rng::seed_from(cfg.seed);
        let x = AnyTensor::Tt(regime_input(cfg.regime, &mut rng));
        let (tt, _) = mean_distortion(MapSpec::Tt(5), &x, 50, cfg.trials, 5, 2);
        let (cp, _) = mean_distortion(MapSpec::Cp(25), &x, 50, cfg.trials, 5, 2);
        assert!(
            tt < cp,
            "TT should dominate CP at high order: tt={tt:.3} cp={cp:.3}"
        );
    }
}
