//! Experiment harness: one module per figure of the paper's evaluation,
//! plus the ablation suite. Each regenerator prints a table and writes a
//! CSV under `results/` (see DESIGN.md §4 for the experiment index).

pub mod ablations;
pub mod ann;
pub mod batch;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;

use crate::projections::{
    CpProjection, GaussianProjection, Projection, SparseKind, SparseProjection, TtProjection,
};
use crate::rng::Rng;
use crate::tensor::AnyTensor;

/// A projection-map family + hyperparameters, instantiable per trial.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MapSpec {
    /// Dense Gaussian RP.
    Gaussian,
    /// Very sparse RP (Li et al., `s = √D`).
    VerySparse,
    /// `f_TT(R)`.
    Tt(usize),
    /// `f_CP(R)`.
    Cp(usize),
}

impl MapSpec {
    /// Series label used in tables/CSV (matches the paper's legends).
    pub fn label(&self) -> String {
        match self {
            MapSpec::Gaussian => "gaussian".into(),
            MapSpec::VerySparse => "very_sparse".into(),
            MapSpec::Tt(r) => format!("tt_r{r}"),
            MapSpec::Cp(r) => format!("cp_r{r}"),
        }
    }

    /// Draw a fresh map of this spec.
    pub fn build(&self, dims: &[usize], k: usize, rng: &mut Rng) -> Box<dyn Projection> {
        match self {
            MapSpec::Gaussian => Box::new(GaussianProjection::new(dims, k, rng)),
            MapSpec::VerySparse => {
                Box::new(SparseProjection::new(dims, k, SparseKind::VerySparse, rng))
            }
            MapSpec::Tt(r) => Box::new(TtProjection::new(dims, *r, k, rng)),
            MapSpec::Cp(r) => Box::new(CpProjection::new(dims, *r, k, rng)),
        }
    }

    /// Whether this spec can handle the given dense input dimension.
    pub fn feasible(&self, numel_f64: f64) -> bool {
        match self {
            // Dense matrix k×D must materialize.
            MapSpec::Gaussian => numel_f64 <= (1 << 24) as f64,
            // Sparse rows index into [D]; the practical bound is usize
            // indexing (time is handled by the k-grids).
            MapSpec::VerySparse => numel_f64 <= (1u64 << 40) as f64,
            MapSpec::Tt(_) | MapSpec::Cp(_) => true,
        }
    }
}

/// Mean (and std) distortion ratio of `spec` on input `x` over `trials`
/// independent map draws — the quantity plotted in Figure 1.
pub fn mean_distortion(
    spec: MapSpec,
    x: &AnyTensor,
    k: usize,
    trials: usize,
    seed: u64,
    threads: usize,
) -> (f64, f64) {
    let input_norm = x.fro_norm();
    let dims = x.dims().to_vec();
    let trial_ids: Vec<u64> = (0..trials as u64).collect();
    let ds = crate::util::threadpool::par_map(trial_ids, threads, |t| {
        let mut rng = Rng::seed_from(crate::rng::derive_seed(seed, t));
        let f = spec.build(&dims, k, &mut rng);
        let y = f.project(x);
        crate::projections::distortion_ratio(&y, input_norm)
    });
    let s = crate::util::stats::Summary::of(&ds);
    (s.mean, s.std)
}

/// Default number of worker threads for experiment sweeps.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::TtTensor;

    #[test]
    fn labels_are_stable() {
        assert_eq!(MapSpec::Tt(5).label(), "tt_r5");
        assert_eq!(MapSpec::Cp(25).label(), "cp_r25");
        assert_eq!(MapSpec::Gaussian.label(), "gaussian");
    }

    #[test]
    fn feasibility_gates_dense_maps() {
        assert!(!MapSpec::Gaussian.feasible(3f64.powi(25)));
        assert!(MapSpec::Tt(5).feasible(3f64.powi(25)));
        assert!(MapSpec::Gaussian.feasible(3375.0));
    }

    #[test]
    fn mean_distortion_decreases_with_k() {
        let mut rng = Rng::seed_from(1);
        let x = AnyTensor::Tt(TtTensor::random_unit(&[3; 5], 3, &mut rng));
        let (d_small, _) = mean_distortion(MapSpec::Tt(5), &x, 4, 30, 7, 2);
        let (d_large, _) = mean_distortion(MapSpec::Tt(5), &x, 128, 30, 7, 2);
        assert!(
            d_large < d_small,
            "distortion should shrink with k: {d_small} vs {d_large}"
        );
    }

    #[test]
    fn mean_distortion_is_deterministic_in_seed() {
        let mut rng = Rng::seed_from(2);
        let x = AnyTensor::Tt(TtTensor::random_unit(&[3; 4], 2, &mut rng));
        let a = mean_distortion(MapSpec::Cp(4), &x, 8, 10, 3, 2);
        let b = mean_distortion(MapSpec::Cp(4), &x, 8, 10, 3, 4);
        assert_eq!(a.0, b.0, "thread count must not change results");
    }
}
