//! Figure 2: embedding time vs `k` for the medium-order case, with the
//! input given in TT format (top panel) or CP format (bottom panel).
//!
//! The paper's observations to reproduce:
//! * `f_TT(R)` is fastest on TT inputs, `f_CP(R)` on CP inputs;
//! * `f_TT(R)` beats very sparse RP at every `k`, while `f_CP(100)` does
//!   not.

use super::MapSpec;
use crate::data::inputs::{regime_cp_input, regime_input, Regime};
use crate::rng::Rng;
use crate::tensor::AnyTensor;
use crate::util::csv::CsvTable;
use crate::util::Timer;

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct Fig2Config {
    /// Embedding dimensions to sweep.
    pub ks: Vec<usize>,
    /// Timed repetitions per point (median reported).
    pub reps: usize,
    /// Master seed.
    pub seed: u64,
}

impl Fig2Config {
    /// Paper-style defaults.
    pub fn paper() -> Self {
        Self { ks: vec![10, 25, 50, 100, 250, 500], reps: 5, seed: 0xF162 }
    }

    /// Reduced settings for smoke tests.
    pub fn quick() -> Self {
        Self { ks: vec![10, 50], reps: 2, seed: 0xF162 }
    }
}

/// Map series of the figure.
pub fn series() -> Vec<MapSpec> {
    vec![
        MapSpec::Tt(2),
        MapSpec::Tt(5),
        MapSpec::Tt(10),
        MapSpec::Cp(4),
        MapSpec::Cp(25),
        MapSpec::Cp(100),
        MapSpec::VerySparse,
    ]
}

/// One timing row.
#[derive(Debug, Clone)]
pub struct Fig2Row {
    /// `"tt"` or `"cp"` — the input format (panel).
    pub input_format: String,
    /// Series label.
    pub map: String,
    /// Embedding dimension.
    pub k: usize,
    /// Median seconds to project the input once.
    pub secs: f64,
}

/// Median time to apply `f` to `x`, over `reps` repetitions.
fn time_projection(f: &dyn crate::projections::Projection, x: &AnyTensor, reps: usize) -> f64 {
    let mut times = Vec::with_capacity(reps);
    // One warmup.
    std::hint::black_box(f.project(x));
    for _ in 0..reps {
        let t = Timer::start();
        std::hint::black_box(f.project(x));
        times.push(t.elapsed_secs());
    }
    times.sort_by(f64::total_cmp);
    times[times.len() / 2]
}

/// Run both panels.
pub fn run(cfg: &Fig2Config) -> Vec<Fig2Row> {
    let mut rng = Rng::seed_from(cfg.seed);
    let regime = Regime::Medium;
    let x_tt = AnyTensor::Tt(regime_input(regime, &mut rng));
    let x_cp = AnyTensor::Cp(regime_cp_input(regime, &mut rng));
    let dims = regime.dims();
    let mut rows = Vec::new();
    for (panel, x) in [("tt", &x_tt), ("cp", &x_cp)] {
        for spec in series() {
            for &k in &cfg.ks {
                let f = spec.build(&dims, k, &mut rng);
                let secs = time_projection(f.as_ref(), x, cfg.reps);
                rows.push(Fig2Row {
                    input_format: panel.to_string(),
                    map: spec.label(),
                    k,
                    secs,
                });
            }
        }
    }
    rows
}

/// Render rows as CSV.
pub fn to_csv(rows: &[Fig2Row]) -> CsvTable {
    let mut t = CsvTable::new(&["input_format", "map", "k", "median_secs"]);
    for r in rows {
        t.push_row(vec![
            r.input_format.clone(),
            r.map.clone(),
            r.k.to_string(),
            format!("{:.6e}", r.secs),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_covers_both_panels() {
        let mut cfg = Fig2Config::quick();
        cfg.ks = vec![8];
        cfg.reps = 1;
        let rows = run(&cfg);
        // 7 series × 1 k × 2 panels.
        assert_eq!(rows.len(), 14);
        assert!(rows.iter().all(|r| r.secs >= 0.0));
        assert!(rows.iter().any(|r| r.input_format == "tt"));
        assert!(rows.iter().any(|r| r.input_format == "cp"));
    }

    #[test]
    fn tt_map_on_tt_input_beats_very_sparse() {
        // The paper's Fig 2 claim (top panel): f_TT is always faster than
        // very sparse RP on TT inputs. Checked at one medium k.
        let mut rng = Rng::seed_from(3);
        let regime = Regime::Medium;
        let x = AnyTensor::Tt(regime_input(regime, &mut rng));
        let dims = regime.dims();
        let k = 50;
        let f_tt = MapSpec::Tt(10).build(&dims, k, &mut rng);
        let f_vs = MapSpec::VerySparse.build(&dims, k, &mut rng);
        let t_tt = time_projection(f_tt.as_ref(), &x, 3);
        let t_vs = time_projection(f_vs.as_ref(), &x, 3);
        assert!(
            t_tt < t_vs,
            "TT(10) should beat very sparse on TT input: {t_tt:.2e} vs {t_vs:.2e}"
        );
    }
}
