//! GEMM kernel profiling by shape bucket.
//!
//! Rides the public `linalg::gemm` entry points (the microkernel is
//! untouched): when enabled, each call records its wall time and flop
//! count under a shape bucket whose dims are rounded up to powers of two
//! — so `63×250×64` and `64×256×64` aggregate together and the profile
//! stays a handful of rows instead of one per exact shape.
//!
//! The enable flag is a single relaxed atomic load on the disabled path
//! (the same idiom as `linalg::gemm::gemm_threads`). It is switched on
//! together with request tracing (`trp serve --trace-dir`) and directly
//! by tests/benches via [`set_gemm_profiling`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

static ENABLED: AtomicBool = AtomicBool::new(false);

type ShapeKey = (usize, usize, usize);

#[derive(Debug, Default, Clone, Copy)]
struct Agg {
    calls: u64,
    flops: u64,
    time_us: u64,
}

fn table() -> &'static Mutex<HashMap<ShapeKey, Agg>> {
    static TABLE: OnceLock<Mutex<HashMap<ShapeKey, Agg>>> = OnceLock::new();
    TABLE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Is profiling on? (One relaxed load — the entire disabled-path cost.)
#[inline]
pub fn gemm_profiling_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Toggle profiling process-wide.
pub fn set_gemm_profiling(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Record one profiled GEMM call of logical shape `m×k×n` that took
/// `dur_us`. Called by `linalg::gemm` only when profiling is enabled.
pub fn gemm_record(m: usize, k: usize, n: usize, dur_us: u64) {
    let key =
        (m.next_power_of_two().max(1), k.next_power_of_two().max(1), n.next_power_of_two().max(1));
    let flops = 2u64
        .saturating_mul(m as u64)
        .saturating_mul(k as u64)
        .saturating_mul(n as u64);
    let mut t = table().lock().unwrap();
    let agg = t.entry(key).or_default();
    agg.calls += 1;
    agg.flops = agg.flops.saturating_add(flops);
    agg.time_us = agg.time_us.saturating_add(dur_us);
}

/// Aggregated profile of one GEMM shape bucket.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GemmShapeStat {
    /// Bucket upper bound for `m` (power of two).
    pub m: usize,
    /// Bucket upper bound for `k`.
    pub k: usize,
    /// Bucket upper bound for `n`.
    pub n: usize,
    /// Calls aggregated into this bucket.
    pub calls: u64,
    /// Total `2·m·k·n` flops of the *actual* shapes (not the bucket
    /// bounds).
    pub flops: u64,
    /// Total wall time in µs.
    pub time_us: u64,
}

impl GemmShapeStat {
    /// Achieved throughput in GFLOP/s (0 when no time was observed).
    pub fn gflops(&self) -> f64 {
        if self.time_us == 0 {
            return 0.0;
        }
        self.flops as f64 / (self.time_us as f64 * 1e3)
    }
}

/// Current profile, busiest bucket (by time) first.
pub fn gemm_stats_snapshot() -> Vec<GemmShapeStat> {
    let t = table().lock().unwrap();
    let mut out: Vec<GemmShapeStat> = t
        .iter()
        .map(|(&(m, k, n), a)| GemmShapeStat {
            m,
            k,
            n,
            calls: a.calls,
            flops: a.flops,
            time_us: a.time_us,
        })
        .collect();
    out.sort_by(|a, b| b.time_us.cmp(&a.time_us).then((b.m, b.k, b.n).cmp(&(a.m, a.k, a.n))));
    out
}

/// Clear the profile (process-wide; tests and `reset`ing snapshots).
pub fn reset_gemm_stats() {
    table().lock().unwrap().clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_bucket_to_powers_of_two() {
        reset_gemm_stats();
        gemm_record(63, 250, 64, 10);
        gemm_record(64, 256, 64, 30);
        gemm_record(4, 4, 4, 1);
        let snap = gemm_stats_snapshot();
        let big = snap.iter().find(|s| (s.m, s.k, s.n) == (64, 256, 64)).expect("merged bucket");
        assert_eq!(big.calls, 2);
        assert_eq!(big.time_us, 40);
        assert_eq!(big.flops, 2 * 63 * 250 * 64 + 2 * 64 * 256 * 64);
        assert!(big.gflops() > 0.0);
        // The table is process-global and other tests may profile their
        // own GEMMs concurrently, so assert the ordering *property*
        // rather than which bucket is globally busiest.
        assert!(snap.windows(2).all(|w| w[0].time_us >= w[1].time_us), "sorted busiest first");
        reset_gemm_stats();
        assert!(gemm_stats_snapshot().is_empty());
    }

    #[test]
    fn flag_toggles_and_restores() {
        let was = gemm_profiling_enabled();
        set_gemm_profiling(true);
        assert!(gemm_profiling_enabled());
        set_gemm_profiling(was);
        assert_eq!(gemm_profiling_enabled(), was);
    }
}
