//! Per-signature metrics registry + the full observability snapshot.
//!
//! Keyed like the projection-map registry: one [`SigMetrics`] per map
//! signature label, created lazily on first traffic. Each entry carries
//! request/op counters plus per-stage log-bucketed latency histograms,
//! so a slow query is attributable to batcher wait vs GEMM vs shard
//! fan-out vs reply — per signature, not just globally.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::{bucket_index, LatencyHistogram, MetricsSnapshot, BUCKETS};
use crate::obs::gemm_stats::GemmShapeStat;
use crate::obs::trace::TraceStats;
use crate::util::json::{obj, Json};

/// Number of per-signature stage histograms.
pub const STAGE_COUNT: usize = 9;

/// Name of the per-signature end-to-end pseudo-stage exported alongside
/// the pipeline stages (submit → reply send, per request). The SLO
/// engine evaluates latency objectives against this histogram.
pub const E2E_STAGE: &str = "e2e";

/// Pipeline stages with a per-signature latency histogram.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Submit → flush start (batcher + queue wait), per request.
    QueueWait,
    /// First enqueue → worker pickup of the flush, per flush.
    FlushAssembly,
    /// `project_batch_into` wall time, per flush.
    Project,
    /// Wait for a shard lane's sequencer turn, per shard pass.
    LaneWait,
    /// In-turn index work (inserts/deletes/batched query scoring), per
    /// shard pass.
    IndexScan,
    /// k-way merge of per-shard query candidates, per flush.
    Merge,
    /// Reply construction + channel send fan-out, per flush.
    Reply,
    /// Off-turn snapshot file writes, per snapshot.
    SnapshotWrite,
    /// WAL group-commit fsync (all touched lanes), per flush that
    /// actually synced — the price of an acked-⇒-durable flush.
    WalFsync,
}

impl Stage {
    /// Every stage, in histogram-slot order.
    pub const ALL: [Stage; STAGE_COUNT] = [
        Stage::QueueWait,
        Stage::FlushAssembly,
        Stage::Project,
        Stage::LaneWait,
        Stage::IndexScan,
        Stage::Merge,
        Stage::Reply,
        Stage::SnapshotWrite,
        Stage::WalFsync,
    ];

    /// Stable exported name.
    pub fn name(self) -> &'static str {
        match self {
            Stage::QueueWait => "queue_wait",
            Stage::FlushAssembly => "flush_assembly",
            Stage::Project => "project_gemm",
            Stage::LaneWait => "lane_wait",
            Stage::IndexScan => "index_scan",
            Stage::Merge => "merge",
            Stage::Reply => "reply",
            Stage::SnapshotWrite => "snapshot_write",
            Stage::WalFsync => "wal_fsync",
        }
    }
}

/// Counters + stage histograms for one map signature.
#[derive(Debug)]
pub struct SigMetrics {
    /// Requests routed to this signature (any op).
    pub requests: AtomicU64,
    /// `project` ops served.
    pub projects: AtomicU64,
    /// `insert` ops served.
    pub inserts: AtomicU64,
    /// `query` ops served.
    pub queries: AtomicU64,
    /// `delete` ops served.
    pub deletes: AtomicU64,
    /// Error replies sent for this signature.
    pub errors: AtomicU64,
    /// Native flushes executed for this signature.
    pub flushes: AtomicU64,
    /// Gauge: WAL records appended since the last checkpoint (the replay
    /// cost a crash would incur right now; 0 with the WAL off). Stored
    /// by the coordinator's gauge refresh at snapshot time.
    pub wal_lag: AtomicU64,
    stages: [LatencyHistogram; STAGE_COUNT],
    /// End-to-end latency per request of this signature (submit → reply
    /// send) — the histogram latency SLOs are evaluated against.
    e2e: LatencyHistogram,
    /// Per-bucket exemplars: the last trace id (+1, so 0 = none) that
    /// landed in each stage-histogram bucket. Last-writer-wins relaxed
    /// stores — an exemplar is a sample, not a counter.
    stage_exemplars: [[AtomicU64; BUCKETS]; STAGE_COUNT],
    e2e_exemplars: [AtomicU64; BUCKETS],
}

impl Default for SigMetrics {
    fn default() -> Self {
        Self {
            requests: AtomicU64::new(0),
            projects: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
            queries: AtomicU64::new(0),
            deletes: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            flushes: AtomicU64::new(0),
            wal_lag: AtomicU64::new(0),
            stages: std::array::from_fn(|_| LatencyHistogram::new()),
            e2e: LatencyHistogram::new(),
            stage_exemplars: std::array::from_fn(|_| std::array::from_fn(|_| AtomicU64::new(0))),
            e2e_exemplars: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

impl SigMetrics {
    /// The histogram of one stage.
    pub fn stage(&self, s: Stage) -> &LatencyHistogram {
        &self.stages[s as usize]
    }

    /// Record one observation into a stage histogram.
    pub fn record_stage(&self, s: Stage, us: u64) {
        self.stages[s as usize].record(us);
    }

    /// Record one observation and, when a trace context is attached,
    /// stamp it as the bucket's exemplar — linking a hot histogram
    /// bucket to a concrete request's span waterfall.
    pub fn record_stage_traced(&self, s: Stage, us: u64, trace: Option<u64>) {
        self.stages[s as usize].record(us);
        if let Some(t) = trace {
            self.stage_exemplars[s as usize][bucket_index(us)]
                .store(t.wrapping_add(1), Ordering::Relaxed);
        }
    }

    /// Record one end-to-end observation (submit → reply send) with an
    /// optional trace-context exemplar.
    pub fn record_e2e(&self, us: u64, trace: Option<u64>) {
        self.e2e.record(us);
        if let Some(t) = trace {
            self.e2e_exemplars[bucket_index(us)].store(t.wrapping_add(1), Ordering::Relaxed);
        }
    }
}

fn exemplar_vec(row: &[AtomicU64; BUCKETS]) -> Vec<u64> {
    row.iter().map(|e| e.load(Ordering::Relaxed)).collect()
}

/// Lazily-populated map signature → [`SigMetrics`], mirroring how the
/// projection registry keys its maps.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    sigs: Mutex<HashMap<String, Arc<SigMetrics>>>,
}

impl MetricsRegistry {
    /// New empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// The signature's metrics, created on first use. Callers hold the
    /// returned `Arc` for the duration of a flush so recording is pure
    /// atomics.
    pub fn get(&self, label: &str) -> Arc<SigMetrics> {
        let mut m = self.sigs.lock().unwrap();
        Arc::clone(m.entry(label.to_string()).or_default())
    }

    /// Number of signatures seen.
    pub fn len(&self) -> usize {
        self.sigs.lock().unwrap().len()
    }

    /// True when no signature has reported yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Point-in-time copy of every signature, sorted by label for
    /// deterministic exposition.
    pub fn snapshot(&self) -> Vec<SigSnapshot> {
        let m = self.sigs.lock().unwrap();
        let mut out: Vec<SigSnapshot> =
            m.iter().map(|(label, sig)| SigSnapshot::capture(label, sig)).collect();
        out.sort_by(|a, b| a.signature.cmp(&b.signature));
        out
    }
}

/// Point-in-time copy of one stage histogram.
#[derive(Debug, Clone, PartialEq)]
pub struct StageSnapshot {
    /// Stage name (see [`Stage::name`]).
    pub stage: String,
    /// Observation count.
    pub count: u64,
    /// Mean µs.
    pub mean_us: f64,
    /// Interpolated p50 µs.
    pub p50_us: u64,
    /// Interpolated p99 µs.
    pub p99_us: u64,
    /// Raw log₂ bucket counts (bucket b covers `[2^b, 2^(b+1))` µs).
    pub buckets: Vec<u64>,
    /// Per-bucket exemplar trace ids, encoded `trace_id + 1` (0 = no
    /// exemplar). Aligned with `buckets`.
    pub exemplars: Vec<u64>,
}

/// Point-in-time copy of one signature's metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct SigSnapshot {
    /// Signature label (map kind/dims/k).
    pub signature: String,
    /// See [`SigMetrics::requests`].
    pub requests: u64,
    /// See [`SigMetrics::projects`].
    pub projects: u64,
    /// See [`SigMetrics::inserts`].
    pub inserts: u64,
    /// See [`SigMetrics::queries`].
    pub queries: u64,
    /// See [`SigMetrics::deletes`].
    pub deletes: u64,
    /// See [`SigMetrics::errors`].
    pub errors: u64,
    /// See [`SigMetrics::flushes`].
    pub flushes: u64,
    /// See [`SigMetrics::wal_lag`].
    pub wal_lag: u64,
    /// Non-empty stage histograms, in [`Stage::ALL`] order.
    pub stages: Vec<StageSnapshot>,
}

impl SigSnapshot {
    fn capture(label: &str, sig: &SigMetrics) -> Self {
        let mut stages: Vec<StageSnapshot> = Stage::ALL
            .iter()
            .filter_map(|&s| {
                let h = sig.stage(s);
                if h.count() == 0 {
                    return None;
                }
                Some(StageSnapshot {
                    stage: s.name().to_string(),
                    count: h.count(),
                    mean_us: h.mean_us(),
                    p50_us: h.quantile_us(0.50),
                    p99_us: h.quantile_us(0.99),
                    buckets: h.bucket_counts(),
                    exemplars: exemplar_vec(&sig.stage_exemplars[s as usize]),
                })
            })
            .collect();
        if sig.e2e.count() > 0 {
            stages.push(StageSnapshot {
                stage: E2E_STAGE.to_string(),
                count: sig.e2e.count(),
                mean_us: sig.e2e.mean_us(),
                p50_us: sig.e2e.quantile_us(0.50),
                p99_us: sig.e2e.quantile_us(0.99),
                buckets: sig.e2e.bucket_counts(),
                exemplars: exemplar_vec(&sig.e2e_exemplars),
            });
        }
        Self {
            signature: label.to_string(),
            requests: sig.requests.load(Ordering::Relaxed),
            projects: sig.projects.load(Ordering::Relaxed),
            inserts: sig.inserts.load(Ordering::Relaxed),
            queries: sig.queries.load(Ordering::Relaxed),
            deletes: sig.deletes.load(Ordering::Relaxed),
            errors: sig.errors.load(Ordering::Relaxed),
            flushes: sig.flushes.load(Ordering::Relaxed),
            wal_lag: sig.wal_lag.load(Ordering::Relaxed),
            stages,
        }
    }
}

/// Point-in-time status of one SLO objective, exported in the snapshot
/// so `trp slo` and Prometheus scrapes see burn rates without touching
/// the engine's internals.
#[derive(Debug, Clone, PartialEq)]
pub struct SloStatusSnapshot {
    /// Signature the objective applies to (`*` = every signature).
    pub signature: String,
    /// Objective kind: `p99_latency_us` or `error_rate`.
    pub objective: String,
    /// Objective target (µs for latency, fraction for error rate).
    pub target: f64,
    /// Burn rate over the fast window (1.0 = consuming budget exactly
    /// at the sustainable rate).
    pub fast_burn: f64,
    /// Burn rate over the slow window.
    pub slow_burn: f64,
    /// Whether the alarm is currently firing (both windows over the
    /// burn threshold).
    pub firing: bool,
}

/// The full observability picture, as returned by the `metrics` wire op.
#[derive(Debug, Clone, PartialEq)]
pub struct ObsSnapshot {
    /// Global service counters + end-to-end latency.
    pub global: MetricsSnapshot,
    /// Per-signature breakdown.
    pub signatures: Vec<SigSnapshot>,
    /// GEMM kernel profile by shape bucket (empty unless profiling is
    /// enabled — it is switched on together with tracing).
    pub gemm: Vec<GemmShapeStat>,
    /// Trace recorder counters.
    pub trace: TraceStats,
    /// SLO objective statuses (empty unless `trp serve --slo` loaded a
    /// policy file).
    pub slo: Vec<SloStatusSnapshot>,
}

fn u(v: Option<&Json>) -> u64 {
    v.and_then(Json::as_f64).unwrap_or(0.0) as u64
}

fn f(v: Option<&Json>) -> f64 {
    v.and_then(Json::as_f64).unwrap_or(0.0)
}

fn global_to_json(g: &MetricsSnapshot) -> Json {
    let n = |x: u64| Json::Num(x as f64);
    obj(vec![
        ("submitted", n(g.submitted)),
        ("completed", n(g.completed)),
        ("failed", n(g.failed)),
        ("pjrt_batches", n(g.pjrt_batches)),
        ("native_batches", n(g.native_batches)),
        ("native_requests", n(g.native_requests)),
        ("pjrt_requests", n(g.pjrt_requests)),
        ("padded_slots", n(g.padded_slots)),
        ("native_flush_max", n(g.native_flush_max)),
        ("index_inserts", n(g.index_inserts)),
        ("index_deletes", n(g.index_deletes)),
        ("index_queries", n(g.index_queries)),
        ("index_snapshots", n(g.index_snapshots)),
        ("index_restores", n(g.index_restores)),
        ("index_shard_max_skew", n(g.index_shard_max_skew)),
        ("index_shard_parallel", n(g.index_shard_parallel)),
        ("index_shard_skew_now", n(g.index_shard_skew_now)),
        ("index_shard_parallel_now", n(g.index_shard_parallel_now)),
        ("wal_appends", n(g.wal_appends)),
        ("wal_fsyncs", n(g.wal_fsyncs)),
        ("wal_replayed", n(g.wal_replayed)),
        ("mean_latency_us", Json::Num(g.mean_latency_us)),
        ("p50_latency_us", n(g.p50_latency_us)),
        ("p99_latency_us", n(g.p99_latency_us)),
    ])
}

fn global_from_json(v: &Json) -> MetricsSnapshot {
    MetricsSnapshot {
        submitted: u(v.get("submitted")),
        completed: u(v.get("completed")),
        failed: u(v.get("failed")),
        pjrt_batches: u(v.get("pjrt_batches")),
        native_batches: u(v.get("native_batches")),
        native_requests: u(v.get("native_requests")),
        pjrt_requests: u(v.get("pjrt_requests")),
        padded_slots: u(v.get("padded_slots")),
        native_flush_max: u(v.get("native_flush_max")),
        index_inserts: u(v.get("index_inserts")),
        index_deletes: u(v.get("index_deletes")),
        index_queries: u(v.get("index_queries")),
        index_snapshots: u(v.get("index_snapshots")),
        index_restores: u(v.get("index_restores")),
        index_shard_max_skew: u(v.get("index_shard_max_skew")),
        index_shard_parallel: u(v.get("index_shard_parallel")),
        index_shard_skew_now: u(v.get("index_shard_skew_now")),
        index_shard_parallel_now: u(v.get("index_shard_parallel_now")),
        wal_appends: u(v.get("wal_appends")),
        wal_fsyncs: u(v.get("wal_fsyncs")),
        wal_replayed: u(v.get("wal_replayed")),
        mean_latency_us: f(v.get("mean_latency_us")),
        p50_latency_us: u(v.get("p50_latency_us")),
        p99_latency_us: u(v.get("p99_latency_us")),
    }
}

impl ObsSnapshot {
    /// Wire encoding.
    pub fn to_json(&self) -> Json {
        let sigs = self
            .signatures
            .iter()
            .map(|s| {
                let stages = s
                    .stages
                    .iter()
                    .map(|st| {
                        obj(vec![
                            ("stage", Json::Str(st.stage.clone())),
                            ("count", Json::Num(st.count as f64)),
                            ("mean_us", Json::Num(st.mean_us)),
                            ("p50_us", Json::Num(st.p50_us as f64)),
                            ("p99_us", Json::Num(st.p99_us as f64)),
                            (
                                "buckets",
                                Json::Arr(
                                    st.buckets.iter().map(|&b| Json::Num(b as f64)).collect(),
                                ),
                            ),
                            (
                                "exemplars",
                                Json::Arr(
                                    st.exemplars.iter().map(|&e| Json::Num(e as f64)).collect(),
                                ),
                            ),
                        ])
                    })
                    .collect();
                obj(vec![
                    ("signature", Json::Str(s.signature.clone())),
                    ("requests", Json::Num(s.requests as f64)),
                    ("projects", Json::Num(s.projects as f64)),
                    ("inserts", Json::Num(s.inserts as f64)),
                    ("queries", Json::Num(s.queries as f64)),
                    ("deletes", Json::Num(s.deletes as f64)),
                    ("errors", Json::Num(s.errors as f64)),
                    ("flushes", Json::Num(s.flushes as f64)),
                    ("wal_lag", Json::Num(s.wal_lag as f64)),
                    ("stages", Json::Arr(stages)),
                ])
            })
            .collect();
        let gemm = self
            .gemm
            .iter()
            .map(|g| {
                obj(vec![
                    ("m", Json::Num(g.m as f64)),
                    ("k", Json::Num(g.k as f64)),
                    ("n", Json::Num(g.n as f64)),
                    ("calls", Json::Num(g.calls as f64)),
                    ("flops", Json::Num(g.flops as f64)),
                    ("time_us", Json::Num(g.time_us as f64)),
                ])
            })
            .collect();
        let slo = self
            .slo
            .iter()
            .map(|s| {
                obj(vec![
                    ("signature", Json::Str(s.signature.clone())),
                    ("objective", Json::Str(s.objective.clone())),
                    ("target", Json::Num(s.target)),
                    ("fast_burn", Json::Num(s.fast_burn)),
                    ("slow_burn", Json::Num(s.slow_burn)),
                    ("firing", Json::Bool(s.firing)),
                ])
            })
            .collect();
        obj(vec![
            ("global", global_to_json(&self.global)),
            ("signatures", Json::Arr(sigs)),
            ("gemm", Json::Arr(gemm)),
            (
                "trace",
                obj(vec![
                    ("enabled", Json::Bool(self.trace.enabled)),
                    ("recorded", Json::Num(self.trace.recorded as f64)),
                    ("dropped", Json::Num(self.trace.dropped as f64)),
                    ("written", Json::Num(self.trace.written as f64)),
                    ("rotations", Json::Num(self.trace.rotations as f64)),
                ]),
            ),
            ("slo", Json::Arr(slo)),
        ])
    }

    /// Inverse of [`ObsSnapshot::to_json`].
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let global = global_from_json(v.get("global").ok_or("metrics missing 'global'")?);
        let mut signatures = Vec::new();
        if let Some(arr) = v.get("signatures").and_then(Json::as_arr) {
            for s in arr {
                let mut stages = Vec::new();
                if let Some(sts) = s.get("stages").and_then(Json::as_arr) {
                    for st in sts {
                        stages.push(StageSnapshot {
                            stage: st
                                .get("stage")
                                .and_then(Json::as_str)
                                .unwrap_or_default()
                                .to_string(),
                            count: u(st.get("count")),
                            mean_us: f(st.get("mean_us")),
                            p50_us: u(st.get("p50_us")),
                            p99_us: u(st.get("p99_us")),
                            buckets: st
                                .get("buckets")
                                .and_then(Json::as_arr)
                                .map(|b| {
                                    b.iter()
                                        .map(|x| x.as_f64().unwrap_or(0.0) as u64)
                                        .collect()
                                })
                                .unwrap_or_default(),
                            exemplars: st
                                .get("exemplars")
                                .and_then(Json::as_arr)
                                .map(|b| {
                                    b.iter()
                                        .map(|x| x.as_f64().unwrap_or(0.0) as u64)
                                        .collect()
                                })
                                .unwrap_or_default(),
                        });
                    }
                }
                signatures.push(SigSnapshot {
                    signature: s
                        .get("signature")
                        .and_then(Json::as_str)
                        .unwrap_or_default()
                        .to_string(),
                    requests: u(s.get("requests")),
                    projects: u(s.get("projects")),
                    inserts: u(s.get("inserts")),
                    queries: u(s.get("queries")),
                    deletes: u(s.get("deletes")),
                    errors: u(s.get("errors")),
                    flushes: u(s.get("flushes")),
                    wal_lag: u(s.get("wal_lag")),
                    stages,
                });
            }
        }
        let mut gemm = Vec::new();
        if let Some(arr) = v.get("gemm").and_then(Json::as_arr) {
            for g in arr {
                gemm.push(GemmShapeStat {
                    m: u(g.get("m")) as usize,
                    k: u(g.get("k")) as usize,
                    n: u(g.get("n")) as usize,
                    calls: u(g.get("calls")),
                    flops: u(g.get("flops")),
                    time_us: u(g.get("time_us")),
                });
            }
        }
        let trace = match v.get("trace") {
            Some(t) => TraceStats {
                enabled: t.get("enabled").and_then(Json::as_bool).unwrap_or(false),
                recorded: u(t.get("recorded")),
                dropped: u(t.get("dropped")),
                written: u(t.get("written")),
                rotations: u(t.get("rotations")),
            },
            None => TraceStats::default(),
        };
        let mut slo = Vec::new();
        if let Some(arr) = v.get("slo").and_then(Json::as_arr) {
            for s in arr {
                slo.push(SloStatusSnapshot {
                    signature: s
                        .get("signature")
                        .and_then(Json::as_str)
                        .unwrap_or_default()
                        .to_string(),
                    objective: s
                        .get("objective")
                        .and_then(Json::as_str)
                        .unwrap_or_default()
                        .to_string(),
                    target: f(s.get("target")),
                    fast_burn: f(s.get("fast_burn")),
                    slow_burn: f(s.get("slow_burn")),
                    firing: s.get("firing").and_then(Json::as_bool).unwrap_or(false),
                });
            }
        }
        Ok(Self { global, signatures, gemm, trace, slo })
    }

    /// Prometheus-style text exposition (`trp metrics`).
    pub fn to_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let g = &self.global;
        let mut counter = |name: &str, v: u64| {
            let _ = writeln!(out, "# TYPE trp_{name} counter\ntrp_{name} {v}");
        };
        counter("submitted_total", g.submitted);
        counter("completed_total", g.completed);
        counter("failed_total", g.failed);
        counter("native_batches_total", g.native_batches);
        counter("native_requests_total", g.native_requests);
        counter("pjrt_batches_total", g.pjrt_batches);
        counter("pjrt_requests_total", g.pjrt_requests);
        counter("padded_slots_total", g.padded_slots);
        counter("index_inserts_total", g.index_inserts);
        counter("index_deletes_total", g.index_deletes);
        counter("index_queries_total", g.index_queries);
        counter("index_snapshots_total", g.index_snapshots);
        counter("index_restores_total", g.index_restores);
        counter("wal_appends_total", g.wal_appends);
        counter("wal_fsyncs_total", g.wal_fsyncs);
        counter("wal_replayed_total", g.wal_replayed);
        let mut gauge = |name: &str, v: f64| {
            let _ = writeln!(out, "# TYPE trp_{name} gauge\ntrp_{name} {v}");
        };
        gauge("native_flush_max", g.native_flush_max as f64);
        gauge("index_shard_max_skew_highwater", g.index_shard_max_skew as f64);
        gauge("index_shard_parallel_highwater", g.index_shard_parallel as f64);
        gauge("index_shard_max_skew", g.index_shard_skew_now as f64);
        gauge("index_shard_parallel", g.index_shard_parallel_now as f64);
        gauge("e2e_latency_mean_us", g.mean_latency_us);
        gauge("e2e_latency_us{quantile=\"0.5\"}", g.p50_latency_us as f64);
        gauge("e2e_latency_us{quantile=\"0.99\"}", g.p99_latency_us as f64);
        let _ = writeln!(out, "# TYPE trp_sig_ops_total counter");
        for s in &self.signatures {
            for (op, v) in [
                ("project", s.projects),
                ("insert", s.inserts),
                ("query", s.queries),
                ("delete", s.deletes),
                ("error", s.errors),
            ] {
                let _ = writeln!(
                    out,
                    "trp_sig_ops_total{{sig=\"{}\",op=\"{op}\"}} {v}",
                    s.signature
                );
            }
            let _ = writeln!(
                out,
                "trp_sig_flushes_total{{sig=\"{}\"}} {}",
                s.signature, s.flushes
            );
        }
        let _ = writeln!(out, "# TYPE trp_index_wal_lag gauge");
        for s in &self.signatures {
            let _ = writeln!(
                out,
                "trp_index_wal_lag{{sig=\"{}\"}} {}",
                s.signature, s.wal_lag
            );
        }
        let _ = writeln!(out, "# TYPE trp_stage_latency_us summary");
        for s in &self.signatures {
            for st in &s.stages {
                let sig = &s.signature;
                let stage = &st.stage;
                let _ = writeln!(
                    out,
                    "trp_stage_latency_us{{sig=\"{sig}\",stage=\"{stage}\",quantile=\"0.5\"}} {}",
                    st.p50_us
                );
                let _ = writeln!(
                    out,
                    "trp_stage_latency_us{{sig=\"{sig}\",stage=\"{stage}\",quantile=\"0.99\"}} {}",
                    st.p99_us
                );
                let _ = writeln!(
                    out,
                    "trp_stage_latency_us_count{{sig=\"{sig}\",stage=\"{stage}\"}} {}",
                    st.count
                );
                let _ = writeln!(
                    out,
                    "trp_stage_latency_us_mean{{sig=\"{sig}\",stage=\"{stage}\"}} {:.1}",
                    st.mean_us
                );
            }
        }
        if self.signatures.iter().any(|s| s.stages.iter().any(|st| st.exemplars.iter().any(|&e| e != 0))) {
            let _ = writeln!(out, "# TYPE trp_stage_exemplar_trace_id gauge");
            for s in &self.signatures {
                for st in &s.stages {
                    for (b, &e) in st.exemplars.iter().enumerate() {
                        if e != 0 {
                            let _ = writeln!(
                                out,
                                "trp_stage_exemplar_trace_id{{sig=\"{}\",stage=\"{}\",bucket=\"{b}\"}} {}",
                                s.signature,
                                st.stage,
                                e - 1
                            );
                        }
                    }
                }
            }
        }
        if !self.slo.is_empty() {
            let _ = writeln!(out, "# TYPE trp_slo_burn_rate gauge");
            for s in &self.slo {
                for (window, burn) in [("fast", s.fast_burn), ("slow", s.slow_burn)] {
                    let _ = writeln!(
                        out,
                        "trp_slo_burn_rate{{sig=\"{}\",objective=\"{}\",window=\"{window}\"}} {burn}",
                        s.signature, s.objective
                    );
                }
            }
            let _ = writeln!(out, "# TYPE trp_slo_firing gauge");
            for s in &self.slo {
                let _ = writeln!(
                    out,
                    "trp_slo_firing{{sig=\"{}\",objective=\"{}\"}} {}",
                    s.signature,
                    s.objective,
                    u64::from(s.firing)
                );
            }
        }
        if !self.gemm.is_empty() {
            let _ = writeln!(out, "# TYPE trp_gemm_time_us_total counter");
            for gs in &self.gemm {
                let shape = format!("{}x{}x{}", gs.m, gs.k, gs.n);
                let _ = writeln!(out, "trp_gemm_calls_total{{shape=\"{shape}\"}} {}", gs.calls);
                let _ = writeln!(out, "trp_gemm_flops_total{{shape=\"{shape}\"}} {}", gs.flops);
                let _ =
                    writeln!(out, "trp_gemm_time_us_total{{shape=\"{shape}\"}} {}", gs.time_us);
            }
        }
        let t = &self.trace;
        let _ = writeln!(out, "# TYPE trp_trace_spans_total counter");
        let _ = writeln!(out, "trp_trace_enabled {}", u64::from(t.enabled));
        let _ = writeln!(out, "trp_trace_spans_total {}", t.recorded);
        let _ = writeln!(out, "trp_trace_spans_dropped_total {}", t.dropped);
        let _ = writeln!(out, "trp_trace_spans_written_total {}", t.written);
        let _ = writeln!(out, "trp_trace_rotations_total {}", t.rotations);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ObsSnapshot {
        let reg = MetricsRegistry::new();
        let sig = reg.get("tt-r5/3x3x3/k64");
        sig.requests.fetch_add(4, Ordering::Relaxed);
        sig.queries.fetch_add(2, Ordering::Relaxed);
        sig.wal_lag.store(3, Ordering::Relaxed);
        sig.record_stage(Stage::QueueWait, 120);
        sig.record_stage_traced(Stage::Project, 900, Some(77));
        sig.record_stage(Stage::Project, 1_800);
        sig.record_e2e(2_500, Some(78));
        let global = crate::coordinator::Metrics::new().snapshot();
        ObsSnapshot {
            global,
            signatures: reg.snapshot(),
            gemm: vec![GemmShapeStat { m: 16, k: 64, n: 64, calls: 3, flops: 393_216, time_us: 42 }],
            trace: TraceStats { enabled: true, recorded: 10, dropped: 1, written: 9, rotations: 0 },
            slo: vec![SloStatusSnapshot {
                signature: "*".to_string(),
                objective: "p99_latency_us".to_string(),
                target: 5000.0,
                fast_burn: 0.5,
                slow_burn: 0.25,
                firing: false,
            }],
        }
    }

    #[test]
    fn registry_is_per_signature() {
        let reg = MetricsRegistry::new();
        reg.get("a").inserts.fetch_add(3, Ordering::Relaxed);
        reg.get("b").inserts.fetch_add(5, Ordering::Relaxed);
        let snap = reg.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].signature, "a");
        assert_eq!(snap[0].inserts, 3);
        assert_eq!(snap[1].inserts, 5);
        // Re-fetching the same label returns the same underlying entry.
        assert_eq!(reg.get("a").inserts.load(Ordering::Relaxed), 3);
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn snapshot_json_roundtrips() {
        let snap = sample();
        let text = snap.to_json().to_string_compact();
        let back = ObsSnapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.signatures, snap.signatures);
        assert_eq!(back.gemm, snap.gemm);
        assert_eq!(back.trace, snap.trace);
        assert_eq!(back.global, snap.global);
        assert_eq!(back.slo, snap.slo);
    }

    #[test]
    fn exemplars_land_in_the_matching_bucket() {
        let reg = MetricsRegistry::new();
        let sig = reg.get("x");
        sig.record_stage_traced(Stage::Project, 900, Some(41));
        sig.record_stage(Stage::Project, 900); // no context: exemplar kept
        sig.record_e2e(10, None); // no context: e2e exemplar stays empty
        sig.record_e2e(10, Some(42));
        let snap = reg.snapshot();
        let project = snap[0].stages.iter().find(|s| s.stage == "project_gemm").unwrap();
        let b = crate::coordinator::bucket_index(900);
        assert_eq!(project.exemplars[b], 41 + 1, "exemplar encodes trace_id + 1");
        assert_eq!(project.buckets[b], 2);
        // Every nonzero exemplar sits in a nonzero bucket.
        for st in &snap[0].stages {
            for (i, &e) in st.exemplars.iter().enumerate() {
                if e != 0 {
                    assert!(st.buckets[i] > 0, "exemplar without observations in {}", st.stage);
                }
            }
        }
        let e2e = snap[0].stages.iter().find(|s| s.stage == E2E_STAGE).unwrap();
        assert_eq!(e2e.count, 2);
        assert_eq!(e2e.exemplars[crate::coordinator::bucket_index(10)], 42 + 1);
    }

    #[test]
    fn empty_stages_are_omitted() {
        let reg = MetricsRegistry::new();
        let sig = reg.get("x");
        sig.record_stage(Stage::Reply, 10);
        let snap = reg.snapshot();
        assert_eq!(snap[0].stages.len(), 1);
        assert_eq!(snap[0].stages[0].stage, "reply");
    }

    #[test]
    fn prometheus_dump_names_required_stages() {
        let text = sample().to_prometheus();
        assert!(text.contains("trp_submitted_total"));
        assert!(text.contains("stage=\"queue_wait\""));
        assert!(text.contains("stage=\"project_gemm\""));
        assert!(text.contains("trp_gemm_time_us_total{shape=\"16x64x64\"} 42"));
        assert!(text.contains("trp_trace_spans_dropped_total 1"));
        assert!(text.contains("trp_index_wal_lag{sig=\"tt-r5/3x3x3/k64\"} 3"));
        assert!(text.contains("trp_wal_appends_total"));
        // Exemplars export the decoded trace id for nonzero buckets only.
        let b = crate::coordinator::bucket_index(900);
        assert!(text.contains(&format!(
            "trp_stage_exemplar_trace_id{{sig=\"tt-r5/3x3x3/k64\",stage=\"project_gemm\",bucket=\"{b}\"}} 77"
        )));
        assert!(text.contains("stage=\"e2e\""));
        assert!(text.contains(
            "trp_slo_burn_rate{sig=\"*\",objective=\"p99_latency_us\",window=\"fast\"} 0.5"
        ));
        assert!(text.contains("trp_slo_firing{sig=\"*\",objective=\"p99_latency_us\"} 0"));
    }
}
