//! Lock-free request tracing: a fixed-size span ring drained to rotated
//! JSONL files.
//!
//! Producers call [`TraceRecorder::record`] from any thread; the cost is
//! one CAS plus a couple of relaxed stores (Vyukov bounded-MPMC slot
//! protocol). When the ring is full the span is dropped and counted —
//! recording never blocks and never allocates, so tracing cannot perturb
//! request execution. A single drainer thread owns all file IO: it pops
//! spans, serializes one JSONL line each, and rotates the output file
//! once it crosses the configured size cap (`trace.jsonl` →
//! `trace.jsonl.1` → … up to `keep_files` generations, the daemon-log
//! idiom).

// lint:allow-file(relaxed-handoff): Vyukov MPMC ring — the per-slot `seq` acquire/release stamps order every payload access; the position counters are reservation cursors whose races are resolved by the CAS, so their loads may be Relaxed.

use std::cell::UnsafeCell;
use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Stage tags every traced serving pipeline must emit at least once for
/// a request that flows the full native path: socket read + decode,
/// batcher wait, flush assembly, projection GEMM, index phase, reply
/// fan-out, socket write. `trp metrics --check-trace` asserts coverage.
pub const REQUIRED_STAGES: [&str; 7] =
    ["recv", "queue", "assemble", "project", "index", "reply", "write"];

/// Stage tags that are valid but only appear for specific workloads
/// (off-turn snapshot writes).
pub const OPTIONAL_STAGES: [&str; 1] = ["snapshot"];

/// One timed stage of a request's (or flush's) life; serializes to one
/// JSONL line. `Copy` so ring slots move it without drop glue.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Span {
    /// Stage tag (one of [`REQUIRED_STAGES`] / [`OPTIONAL_STAGES`]).
    pub stage: &'static str,
    /// Request id, when the span belongs to a single request.
    pub req: Option<u64>,
    /// Flush id, when the span belongs to a batched flush.
    pub flush: Option<u64>,
    /// Index shard, for per-shard index phases.
    pub shard: Option<u32>,
    /// Trace-context id: client-supplied or dispatcher-assigned. For
    /// flush-level spans this is the first batched request's context.
    pub trace: Option<u64>,
    /// Interned signature id (see [`TraceRecorder::intern`]), resolved
    /// through the `{"meta":"sig",…}` records in the same stream.
    pub sig: Option<u32>,
    /// Start tick (µs on the coordinator clock — µs since server start).
    pub start_us: u64,
    /// Duration in µs.
    pub dur_us: u64,
}

impl Span {
    /// The span's JSONL line (no trailing newline). Hand-formatted: every
    /// field is an integer or a static identifier, so no escaping is
    /// needed and the drainer stays allocation-light.
    pub fn to_jsonl(&self) -> String {
        let mut s = String::with_capacity(128);
        s.push_str("{\"stage\":\"");
        s.push_str(self.stage);
        s.push('"');
        for (name, v) in [("req", self.req), ("flush", self.flush)] {
            s.push_str(",\"");
            s.push_str(name);
            s.push_str("\":");
            match v {
                Some(x) => s.push_str(&x.to_string()),
                None => s.push_str("null"),
            }
        }
        s.push_str(",\"shard\":");
        match self.shard {
            Some(x) => s.push_str(&x.to_string()),
            None => s.push_str("null"),
        }
        s.push_str(",\"trace\":");
        match self.trace {
            Some(x) => s.push_str(&x.to_string()),
            None => s.push_str("null"),
        }
        s.push_str(",\"sig\":");
        match self.sig {
            Some(x) => s.push_str(&x.to_string()),
            None => s.push_str("null"),
        }
        s.push_str(",\"start_us\":");
        s.push_str(&self.start_us.to_string());
        s.push_str(",\"dur_us\":");
        s.push_str(&self.dur_us.to_string());
        s.push('}');
        s
    }
}

/// One ring slot: a sequence stamp (the Vyukov handshake) plus the span
/// payload, written only by the producer that won the slot's CAS.
struct Slot {
    seq: AtomicUsize,
    span: UnsafeCell<Span>,
}

/// Bounded lock-free MPMC span queue (Vyukov protocol). Capacity is a
/// power of two; a push against a full ring drops the span and counts it
/// rather than blocking — tracing must never back-pressure serving.
pub struct SpanRing {
    slots: Box<[Slot]>,
    mask: usize,
    enqueue_pos: AtomicUsize,
    dequeue_pos: AtomicUsize,
    dropped: AtomicU64,
}

// SAFETY: slot payloads are only written by the producer that CAS-won
// `enqueue_pos` for that slot and only read by the consumer that CAS-won
// `dequeue_pos`, with the acquire/release `seq` stamp ordering the two.
unsafe impl Send for SpanRing {}
// SAFETY: shared-reference access is the whole point of the ring — every
// slot access is mediated by the CAS/seq protocol described above.
unsafe impl Sync for SpanRing {}

impl SpanRing {
    /// New ring with capacity rounded up to a power of two (minimum 2).
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(2).next_power_of_two();
        let slots = (0..cap)
            .map(|i| Slot { seq: AtomicUsize::new(i), span: UnsafeCell::new(Span::default()) })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        Self {
            slots,
            mask: cap - 1,
            enqueue_pos: AtomicUsize::new(0),
            dequeue_pos: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Capacity (always a power of two).
    pub fn capacity(&self) -> usize {
        self.mask + 1
    }

    /// Spans dropped against a full ring since creation.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Enqueue; returns `false` (and counts a drop) when the ring is full.
    pub fn push(&self, span: Span) -> bool {
        let mut pos = self.enqueue_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - pos as isize;
            if diff == 0 {
                match self.enqueue_pos.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: winning the CAS gives this thread sole
                        // write access to the slot until the release
                        // store below publishes it.
                        unsafe { *slot.span.get() = span };
                        slot.seq.store(pos.wrapping_add(1), Ordering::Release);
                        return true;
                    }
                    Err(p) => pos = p,
                }
            } else if diff < 0 {
                self.dropped.fetch_add(1, Ordering::Relaxed);
                return false;
            } else {
                pos = self.enqueue_pos.load(Ordering::Relaxed);
            }
        }
    }

    /// Dequeue the oldest span, if any.
    pub fn pop(&self) -> Option<Span> {
        let mut pos = self.dequeue_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - pos.wrapping_add(1) as isize;
            if diff == 0 {
                match self.dequeue_pos.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: winning the CAS gives this thread sole
                        // read access; the release store recycles the
                        // slot for the producer one lap ahead.
                        let span = unsafe { *slot.span.get() };
                        slot.seq.store(pos.wrapping_add(self.mask + 1), Ordering::Release);
                        return Some(span);
                    }
                    Err(p) => pos = p,
                }
            } else if diff < 0 {
                return None;
            } else {
                pos = self.dequeue_pos.load(Ordering::Relaxed);
            }
        }
    }
}

/// Where and how the drainer writes trace output.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Directory for `trace.jsonl` (+ rotated generations). Created if
    /// missing.
    pub dir: PathBuf,
    /// Span ring capacity (rounded up to a power of two).
    pub ring_capacity: usize,
    /// Rotate the current file once it exceeds this many bytes.
    pub max_file_bytes: u64,
    /// Rotated generations kept (`trace.jsonl.1` … `.keep_files`).
    pub keep_files: usize,
}

impl TraceConfig {
    /// Defaults: 64 Ki spans in flight, 8 MiB files, 4 generations.
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self {
            dir: dir.into(),
            ring_capacity: 1 << 16,
            max_file_bytes: 8 * 1024 * 1024,
            keep_files: 4,
        }
    }
}

/// Point-in-time trace counters (exported in the metrics snapshot).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TraceStats {
    /// Whether a recorder is attached at all.
    pub enabled: bool,
    /// Spans offered to the ring (including dropped ones).
    pub recorded: u64,
    /// Spans dropped against a full ring.
    pub dropped: u64,
    /// JSONL lines written to disk.
    pub written: u64,
    /// File rotations performed.
    pub rotations: u64,
}

/// The shared tracing endpoint: producers record spans, one drainer
/// thread persists them. Dropping the coordinator calls [`shutdown`]
/// (via the owner) which drains the ring before the thread exits, so
/// files are complete once the server has stopped.
///
/// [`shutdown`]: TraceRecorder::shutdown
pub struct TraceRecorder {
    ring: SpanRing,
    epoch: Instant,
    recorded: AtomicU64,
    written: AtomicU64,
    rotations: AtomicU64,
    stop: AtomicBool,
    drainer: Mutex<Option<JoinHandle<()>>>,
    /// Interned signature labels, indexed by the ids spans carry in
    /// their `sig` field. The drainer publishes them as
    /// `{"meta":"sig",…}` records so offline analysis can resolve them.
    interned: Mutex<Vec<String>>,
}

impl std::fmt::Debug for TraceRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceRecorder").field("stats", &self.stats()).finish()
    }
}

impl TraceRecorder {
    /// Start a recorder + drainer thread writing under `cfg.dir`.
    /// `epoch` must be the coordinator's clock epoch so span timestamps
    /// line up with `queued_us`/`exec_us` in responses.
    pub fn start(cfg: TraceConfig, epoch: Instant) -> std::io::Result<Arc<Self>> {
        fs::create_dir_all(&cfg.dir)?;
        let rec = Arc::new(Self {
            ring: SpanRing::new(cfg.ring_capacity),
            epoch,
            recorded: AtomicU64::new(0),
            written: AtomicU64::new(0),
            rotations: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            drainer: Mutex::new(None),
            interned: Mutex::new(Vec::new()),
        });
        let rec2 = Arc::clone(&rec);
        let handle = std::thread::Builder::new()
            .name("trp-trace".into())
            .spawn(move || rec2.drain_loop(&cfg))?;
        *rec.drainer.lock().unwrap() = Some(handle);
        Ok(rec)
    }

    /// Microseconds since the coordinator epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Record one span (lock-free; drops + counts when the ring is full).
    pub fn record(&self, span: Span) {
        self.recorded.fetch_add(1, Ordering::Relaxed);
        self.ring.push(span);
    }

    /// Intern a signature label, returning the id spans should carry in
    /// their `sig` field. Called once per flush (not per span), so a
    /// short mutex-guarded scan is fine; the signature population is a
    /// handful of entries.
    pub fn intern(&self, label: &str) -> u32 {
        let mut st = self.interned.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(pos) = st.iter().position(|l| l == label) {
            return pos as u32;
        }
        st.push(label.to_string());
        (st.len() - 1) as u32
    }

    /// Current counters.
    pub fn stats(&self) -> TraceStats {
        TraceStats {
            enabled: true,
            recorded: self.recorded.load(Ordering::Relaxed),
            dropped: self.ring.dropped(),
            written: self.written.load(Ordering::Relaxed),
            rotations: self.rotations.load(Ordering::Relaxed),
        }
    }

    /// Stop the drainer after it has flushed every recorded span.
    /// Idempotent; called by the coordinator's shutdown.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let handle = self.drainer.lock().unwrap().take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }

    fn drain_loop(&self, cfg: &TraceConfig) {
        let path = cfg.dir.join("trace.jsonl");
        let mut out = match open_append(&path) {
            Ok(o) => o,
            Err(_) => return,
        };
        let mut bytes = out.1;
        // Anchor the span clock to wall time at the top of every file so
        // traces from different processes align on one timeline.
        bytes += write_meta(&mut out.0, &self.anchor_meta());
        let mut emitted_sigs = 0usize;
        loop {
            // Publish newly interned signature labels before sweeping, so
            // a sig record normally precedes the spans that reference it.
            for line in self.sig_meta_lines(&mut emitted_sigs) {
                bytes += write_meta(&mut out.0, &line);
            }
            let mut drained = false;
            while let Some(span) = self.ring.pop() {
                drained = true;
                let mut line = span.to_jsonl();
                line.push('\n');
                if out.0.write_all(line.as_bytes()).is_ok() {
                    self.written.fetch_add(1, Ordering::Relaxed);
                    bytes += line.len() as u64;
                }
                if bytes >= cfg.max_file_bytes {
                    let _ = out.0.flush();
                    rotate(cfg, &path);
                    self.rotations.fetch_add(1, Ordering::Relaxed);
                    match open_append(&path) {
                        Ok(o) => {
                            out = o;
                            bytes = out.1;
                        }
                        Err(_) => return,
                    }
                    // Every generation must stand alone: re-anchor the
                    // clock and re-publish the full signature table.
                    bytes += write_meta(&mut out.0, &self.anchor_meta());
                    emitted_sigs = 0;
                    for line in self.sig_meta_lines(&mut emitted_sigs) {
                        bytes += write_meta(&mut out.0, &line);
                    }
                }
            }
            let _ = out.0.flush();
            if self.stop.load(Ordering::SeqCst) && !drained {
                // Producers stopped before `stop` was set, so a sweep
                // that found nothing means the ring is dry: seal the
                // stream with the final counters and exit.
                write_meta(&mut out.0, &self.stats_meta());
                let _ = out.0.flush();
                return;
            }
            if !drained {
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }

    /// `{"meta":"anchor",…}` line mapping the span clock onto wall time:
    /// `wall_us(span) = unix_us + (span.start_us - epoch_us)`.
    fn anchor_meta(&self) -> String {
        let unix_us = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0);
        format!(
            "{{\"meta\":\"anchor\",\"unix_us\":{unix_us},\"epoch_us\":{},\"pid\":{}}}",
            self.now_us(),
            std::process::id()
        )
    }

    /// `{"meta":"stats",…}` line with the final counters — lets offline
    /// analysis prove zero ring drops without a live server.
    fn stats_meta(&self) -> String {
        let s = self.stats();
        format!(
            "{{\"meta\":\"stats\",\"recorded\":{},\"dropped\":{},\"written\":{},\"rotations\":{}}}",
            s.recorded, s.dropped, s.written, s.rotations
        )
    }

    /// `{"meta":"sig",…}` lines for interned labels not yet published to
    /// the current file; advances `next` past them.
    fn sig_meta_lines(&self, next: &mut usize) -> Vec<String> {
        let fresh: Vec<String> = {
            let st = self.interned.lock().unwrap_or_else(|e| e.into_inner());
            if *next >= st.len() {
                return Vec::new();
            }
            st[*next..].to_vec()
        };
        let lines = fresh
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let esc = l.replace('\\', "\\\\").replace('"', "\\\"");
                format!("{{\"meta\":\"sig\",\"id\":{},\"label\":\"{esc}\"}}", *next + i)
            })
            .collect();
        *next += fresh.len();
        lines
    }
}

/// Write one meta line (newline appended); returns the bytes written so
/// rotation accounting includes meta records, while `written` — which
/// counts *spans* — does not.
fn write_meta(w: &mut BufWriter<File>, line: &str) -> u64 {
    let mut line = line.to_string();
    line.push('\n');
    if w.write_all(line.as_bytes()).is_ok() {
        line.len() as u64
    } else {
        0
    }
}

/// Open (append) the current trace file; returns the writer and its
/// existing size so rotation accounting survives recorder restarts.
fn open_append(path: &Path) -> std::io::Result<(BufWriter<File>, u64)> {
    let file = OpenOptions::new().create(true).append(true).open(path)?;
    let len = file.metadata().map(|m| m.len()).unwrap_or(0);
    Ok((BufWriter::new(file), len))
}

/// Shift `trace.jsonl.{i}` → `.{i+1}` (oldest beyond `keep_files`
/// falls off), then retire the current file to `.1`.
fn rotate(cfg: &TraceConfig, path: &Path) {
    for i in (1..cfg.keep_files.max(1)).rev() {
        let from = path.with_extension(format!("jsonl.{i}"));
        let to = path.with_extension(format!("jsonl.{}", i + 1));
        let _ = fs::rename(&from, &to);
    }
    let _ = fs::rename(path, path.with_extension("jsonl.1"));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_roundtrips_in_order() {
        let r = SpanRing::new(8);
        for i in 0..5u64 {
            assert!(r.push(Span { req: Some(i), stage: "queue", ..Span::default() }));
        }
        for i in 0..5u64 {
            assert_eq!(r.pop().unwrap().req, Some(i));
        }
        assert!(r.pop().is_none());
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn full_ring_drops_and_counts() {
        let r = SpanRing::new(4);
        for _ in 0..4 {
            assert!(r.push(Span::default()));
        }
        assert!(!r.push(Span::default()));
        assert_eq!(r.dropped(), 1);
        // Popping frees a slot again.
        assert!(r.pop().is_some());
        assert!(r.push(Span::default()));
    }

    #[test]
    fn concurrent_producers_lose_nothing_with_room() {
        let r = Arc::new(SpanRing::new(1 << 12));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let r = Arc::clone(&r);
            handles.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    r.push(Span { req: Some(t * 1000 + i), stage: "recv", ..Span::default() });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut seen = 0;
        while r.pop().is_some() {
            seen += 1;
        }
        assert_eq!(seen, 2000);
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn span_jsonl_parses_and_roundtrips_fields() {
        let span = Span {
            stage: "index",
            req: None,
            flush: Some(7),
            shard: Some(2),
            trace: Some(9001),
            sig: Some(1),
            start_us: 123,
            dur_us: 45,
        };
        let line = span.to_jsonl();
        let v = crate::util::json::Json::parse(&line).expect("valid JSON");
        assert_eq!(v.get("stage").and_then(|s| s.as_str()), Some("index"));
        assert!(matches!(v.get("req"), Some(crate::util::json::Json::Null)));
        assert_eq!(v.get("flush").and_then(|s| s.as_usize()), Some(7));
        assert_eq!(v.get("shard").and_then(|s| s.as_usize()), Some(2));
        assert_eq!(v.get("trace").and_then(|s| s.as_usize()), Some(9001));
        assert_eq!(v.get("sig").and_then(|s| s.as_usize()), Some(1));
        assert_eq!(v.get("dur_us").and_then(|s| s.as_usize()), Some(45));
        // Context-free spans serialize trace/sig as null.
        let bare = Span { stage: "recv", ..Span::default() }.to_jsonl();
        let v = crate::util::json::Json::parse(&bare).expect("valid JSON");
        assert!(matches!(v.get("trace"), Some(crate::util::json::Json::Null)));
        assert!(matches!(v.get("sig"), Some(crate::util::json::Json::Null)));
    }

    #[test]
    fn recorder_writes_and_rotates_jsonl() {
        let dir = std::env::temp_dir().join(format!("trp_trace_test_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let mut cfg = TraceConfig::new(&dir);
        cfg.max_file_bytes = 256; // force rotation quickly
        cfg.keep_files = 2;
        let rec = TraceRecorder::start(cfg, Instant::now()).unwrap();
        for i in 0..64u64 {
            rec.record(Span {
                stage: "recv",
                req: Some(i),
                start_us: rec.now_us(),
                ..Span::default()
            });
        }
        rec.shutdown();
        let stats = rec.stats();
        assert_eq!(stats.recorded, 64);
        assert_eq!(stats.written, 64, "meta records must not count as written spans");
        assert!(stats.rotations >= 1, "256-byte cap must rotate");
        // Every surviving line parses, and every generation opens with a
        // wall-clock anchor so it can be analyzed in isolation.
        let mut lines = 0;
        for name in ["trace.jsonl", "trace.jsonl.1", "trace.jsonl.2"] {
            let p = dir.join(name);
            if let Ok(text) = fs::read_to_string(&p) {
                for (i, line) in text.lines().enumerate() {
                    let v = crate::util::json::Json::parse(line).expect("line parses");
                    if i == 0 {
                        assert_eq!(
                            v.get("meta").and_then(|m| m.as_str()),
                            Some("anchor"),
                            "{name} must open with an anchor record"
                        );
                        assert!(v.get("unix_us").and_then(|u| u.as_usize()).is_some());
                        assert!(v.get("epoch_us").and_then(|u| u.as_usize()).is_some());
                    }
                    lines += 1;
                }
            }
        }
        assert!(lines > 0);
        // The live file is sealed with a stats record proving zero drops.
        let text = fs::read_to_string(dir.join("trace.jsonl")).unwrap();
        let last = text.lines().last().expect("nonempty live file");
        let v = crate::util::json::Json::parse(last).unwrap();
        assert_eq!(v.get("meta").and_then(|m| m.as_str()), Some("stats"));
        assert_eq!(v.get("dropped").and_then(|d| d.as_usize()), Some(0));
        assert_eq!(v.get("written").and_then(|d| d.as_usize()), Some(64));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn interned_signatures_are_stable_and_published() {
        let dir = std::env::temp_dir().join(format!("trp_trace_sig_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let rec = TraceRecorder::start(TraceConfig::new(&dir), Instant::now()).unwrap();
        let a = rec.intern("tt-r5/3x3x3x3/k12");
        let b = rec.intern("dense/3x3x3x3/k12");
        assert_eq!(rec.intern("tt-r5/3x3x3x3/k12"), a, "re-interning must dedupe");
        assert_ne!(a, b);
        rec.record(Span { stage: "project", sig: Some(a), ..Span::default() });
        rec.shutdown();
        let text = fs::read_to_string(dir.join("trace.jsonl")).unwrap();
        let mut labels = std::collections::BTreeMap::new();
        for line in text.lines() {
            let v = crate::util::json::Json::parse(line).unwrap();
            if v.get("meta").and_then(|m| m.as_str()) == Some("sig") {
                labels.insert(
                    v.get("id").and_then(|i| i.as_usize()).unwrap(),
                    v.get("label").and_then(|l| l.as_str()).unwrap().to_string(),
                );
            }
        }
        assert_eq!(labels.get(&(a as usize)).map(String::as_str), Some("tt-r5/3x3x3x3/k12"));
        assert_eq!(labels.get(&(b as usize)).map(String::as_str), Some("dense/3x3x3x3/k12"));
        let _ = fs::remove_dir_all(&dir);
    }
}
