//! Offline trace analysis: `trp trace analyze`.
//!
//! Reads the rotated JSONL span stream a [`super::TraceRecorder`] wrote
//! (`trace.jsonl.N` … `trace.jsonl`, oldest generation first), stitches
//! the generations back into one timeline using the per-file
//! `{"meta":"anchor",…}` records, and reconstructs each request's
//! waterfall:
//!
//! ```text
//!   recv → queue → assemble → project → index(shard*) → reply → write
//! ```
//!
//! Request spans (`recv`, `queue`, `write`) are joined to flush spans
//! (`assemble`, `project`, `index`, `reply`, `snapshot`) through the
//! queue span, which carries both the request id and the flush id. A
//! request instance is keyed by its queue span — not its request id —
//! so clients that reuse ids across invocations cannot alias two
//! requests into one.
//!
//! On top of the waterfalls the analyzer derives per-signature
//! critical-path attribution (which stage the p50/p99 actually lives
//! in; per-shard index time enters as the *max* across shards, since
//! shards scan in parallel), flush fan-out statistics, a `--diff` mode
//! comparing two trace directories, and a `--gate` mode that fails
//! loudly unless ≥ `min_frac` of requests reconstruct with full stage
//! coverage and the sealed stats record proves zero ring drops.

use crate::util::json::{obj, Json};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One span parsed back off disk, with its start mapped onto the
/// wall-clock timeline via the generation's anchor record.
#[derive(Debug, Clone)]
struct ParsedSpan {
    stage: String,
    req: Option<u64>,
    flush: Option<u64>,
    shard: Option<u32>,
    trace: Option<u64>,
    sig: Option<u32>,
    /// Wall-clock start in µs (`anchor.unix_us + start_us − anchor.epoch_us`).
    wall_us: i64,
    dur_us: u64,
}

/// Everything read from one trace directory.
#[derive(Debug, Default)]
struct TraceStream {
    spans: Vec<ParsedSpan>,
    /// Interned signature id → label (from `{"meta":"sig",…}` records).
    sig_labels: BTreeMap<u32, String>,
    /// Final recorder counters, when the stream was sealed cleanly.
    stats: Option<StreamStats>,
    /// Lines that failed to parse (a killed writer can truncate the
    /// last line; tolerated but reported).
    malformed_lines: u64,
    files_read: usize,
}

/// The sealed `{"meta":"stats",…}` counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamStats {
    /// Spans offered to the ring.
    pub recorded: u64,
    /// Spans dropped against a full ring.
    pub dropped: u64,
    /// Span lines written.
    pub written: u64,
    /// File rotations performed.
    pub rotations: u64,
}

/// Per-stage latency attribution within one signature.
#[derive(Debug, Clone, PartialEq)]
pub struct StagePath {
    /// Stage tag.
    pub stage: String,
    /// Median stage duration across reconstructed requests, µs.
    pub p50_us: u64,
    /// p99 stage duration, µs.
    pub p99_us: u64,
    /// Share of the signature's summed critical-path time spent here.
    pub share: f64,
}

/// Critical-path summary of one signature.
#[derive(Debug, Clone, PartialEq)]
pub struct SigPath {
    /// Signature label (or `sig<N>`/`unknown` when unresolvable).
    pub signature: String,
    /// Reconstructed requests attributed to this signature.
    pub count: u64,
    /// End-to-end p50 (recv start → write end), µs.
    pub e2e_p50_us: u64,
    /// End-to-end p99, µs.
    pub e2e_p99_us: u64,
    /// Stage breakdown in pipeline order.
    pub stages: Vec<StagePath>,
}

/// Flush fan-out statistics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FanOut {
    /// Flushes observed.
    pub flushes: u64,
    /// Smallest batch.
    pub min_items: u64,
    /// Mean batch size.
    pub mean_items: f64,
    /// Largest batch.
    pub max_items: u64,
}

/// One bar of the slowest-request waterfall.
#[derive(Debug, Clone, PartialEq)]
pub struct WaterfallRow {
    /// Stage tag (`index` rows repeat per shard).
    pub stage: String,
    /// Shard, for per-shard rows.
    pub shard: Option<u32>,
    /// Offset from the request's first span, µs.
    pub offset_us: u64,
    /// Duration, µs.
    pub dur_us: u64,
}

/// The slowest reconstructed request, for the terminal waterfall.
#[derive(Debug, Clone, PartialEq)]
pub struct Waterfall {
    /// Request id.
    pub req: u64,
    /// Trace-context id, when the request carried one.
    pub trace: Option<u64>,
    /// Signature label.
    pub signature: String,
    /// End-to-end µs.
    pub total_us: u64,
    /// Bars in start order.
    pub rows: Vec<WaterfallRow>,
}

/// Full analysis of one trace directory.
#[derive(Debug, Clone, PartialEq)]
pub struct AnalyzeReport {
    /// Trace directory analyzed.
    pub dir: String,
    /// Rotation generations read.
    pub files_read: usize,
    /// Request instances observed (one per queue span).
    pub requests: u64,
    /// Requests whose full waterfall reconstructed.
    pub reconstructed: u64,
    /// `reconstructed / requests` (1.0 when there were no requests).
    pub reconstructed_frac: f64,
    /// Distinct stage tags seen.
    pub stages_covered: Vec<String>,
    /// Required stages never seen (empty = full coverage).
    pub missing_stages: Vec<String>,
    /// Ring drops per the sealed stats record (`None` = stream was not
    /// sealed, e.g. the server was killed).
    pub ring_dropped: Option<u64>,
    /// Span lines that failed to parse.
    pub malformed_lines: u64,
    /// Flush fan-out.
    pub fanout: FanOut,
    /// Per-signature critical paths, sorted by label.
    pub signatures: Vec<SigPath>,
    /// The slowest reconstructed request.
    pub slowest: Option<Waterfall>,
}

/// Stage tags in pipeline order, used for attribution and display.
const PATH_STAGES: [&str; 7] =
    ["recv", "queue", "assemble", "project", "index", "reply", "write"];

/// List the generations of one trace directory, oldest first:
/// `trace.jsonl.<highest>` … `trace.jsonl.1`, then `trace.jsonl`.
fn generation_files(dir: &Path) -> Result<Vec<PathBuf>, String> {
    let mut suffixes: Vec<u64> = Vec::new();
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("read {}: {e}", dir.display()))?;
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some(suffix) = name.strip_prefix("trace.jsonl.") {
            if let Ok(n) = suffix.parse::<u64>() {
                suffixes.push(n);
            }
        }
    }
    suffixes.sort_unstable_by(|a, b| b.cmp(a));
    let mut files: Vec<PathBuf> =
        suffixes.iter().map(|n| dir.join(format!("trace.jsonl.{n}"))).collect();
    let live = dir.join("trace.jsonl");
    if live.is_file() {
        files.push(live);
    }
    if files.is_empty() {
        return Err(format!("no trace.jsonl* files under {}", dir.display()));
    }
    Ok(files)
}

fn get_u64(v: &Json, key: &str) -> Option<u64> {
    v.get(key).and_then(|x| x.as_usize()).map(|x| x as u64)
}

/// Parse every generation of `dir` into one stitched stream.
fn read_stream(dir: &Path) -> Result<TraceStream, String> {
    let files = generation_files(dir)?;
    let mut stream = TraceStream { files_read: files.len(), ..TraceStream::default() };
    for path in &files {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        // Fallback when a generation lost its anchor (killed mid-open):
        // raw ticks still order spans within the file.
        let mut anchor: (i64, i64) = (0, 0);
        for line in text.lines() {
            let Ok(v) = Json::parse(line) else {
                stream.malformed_lines += 1;
                continue;
            };
            if let Some(meta) = v.get("meta").and_then(|m| m.as_str()) {
                match meta {
                    "anchor" => {
                        let unix = get_u64(&v, "unix_us").unwrap_or(0) as i64;
                        let epoch = get_u64(&v, "epoch_us").unwrap_or(0) as i64;
                        anchor = (unix, epoch);
                    }
                    "sig" => {
                        if let (Some(id), Some(label)) = (
                            get_u64(&v, "id"),
                            v.get("label").and_then(|l| l.as_str()),
                        ) {
                            stream.sig_labels.insert(id as u32, label.to_string());
                        }
                    }
                    "stats" => {
                        stream.stats = Some(StreamStats {
                            recorded: get_u64(&v, "recorded").unwrap_or(0),
                            dropped: get_u64(&v, "dropped").unwrap_or(0),
                            written: get_u64(&v, "written").unwrap_or(0),
                            rotations: get_u64(&v, "rotations").unwrap_or(0),
                        });
                    }
                    _ => stream.malformed_lines += 1,
                }
                continue;
            }
            let Some(stage) = v.get("stage").and_then(|s| s.as_str()) else {
                stream.malformed_lines += 1;
                continue;
            };
            let start_us = get_u64(&v, "start_us").unwrap_or(0) as i64;
            stream.spans.push(ParsedSpan {
                stage: stage.to_string(),
                req: get_u64(&v, "req"),
                flush: get_u64(&v, "flush"),
                shard: get_u64(&v, "shard").map(|s| s as u32),
                trace: get_u64(&v, "trace"),
                sig: get_u64(&v, "sig").map(|s| s as u32),
                wall_us: anchor.0 + (start_us - anchor.1),
                dur_us: get_u64(&v, "dur_us").unwrap_or(0),
            });
        }
    }
    Ok(stream)
}

/// One flush's spans, indexed by role.
#[derive(Debug, Default)]
struct FlushGroup {
    assemble: Option<usize>,
    project: Option<usize>,
    index: Vec<usize>,
    reply: Option<usize>,
    snapshot: Option<usize>,
    sig: Option<u32>,
    items: u64,
}

/// One reconstructed (or partial) request instance.
#[derive(Debug)]
struct Instance {
    req: u64,
    trace: Option<u64>,
    sig: Option<u32>,
    flush: Option<u64>,
    queue: usize,
    recv: Option<usize>,
    write: Option<usize>,
}

fn quantile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let pos = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[pos.min(sorted.len() - 1)]
}

/// Analyze one trace directory.
pub fn analyze_dir(dir: &Path) -> Result<AnalyzeReport, String> {
    let stream = read_stream(dir)?;
    let spans = &stream.spans;

    // Flush-level grouping.
    let mut flushes: BTreeMap<u64, FlushGroup> = BTreeMap::new();
    for (i, s) in spans.iter().enumerate() {
        let Some(f) = s.flush else { continue };
        let g = flushes.entry(f).or_default();
        match s.stage.as_str() {
            "assemble" => g.assemble = Some(i),
            "project" => g.project = Some(i),
            "index" => g.index.push(i),
            "reply" => g.reply = Some(i),
            "snapshot" => g.snapshot = Some(i),
            "queue" => g.items += 1,
            _ => {}
        }
        if g.sig.is_none() {
            g.sig = s.sig;
        }
    }

    // Request instances: one per queue span, joined to recv/write spans
    // of the same request id in arrival order (i-th queue instance of an
    // id pairs with its i-th recv and i-th write).
    let mut recvs: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
    let mut writes: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
    let mut queues: Vec<usize> = Vec::new();
    for (i, s) in spans.iter().enumerate() {
        match (s.stage.as_str(), s.req) {
            ("recv", Some(r)) => recvs.entry(r).or_default().push(i),
            ("write", Some(r)) => writes.entry(r).or_default().push(i),
            ("queue", Some(_)) => queues.push(i),
            _ => {}
        }
    }
    for list in recvs.values_mut().chain(writes.values_mut()) {
        list.sort_by_key(|&i| spans[i].wall_us);
    }
    queues.sort_by_key(|&i| spans[i].wall_us);
    let has_net_spans = !recvs.is_empty();
    let mut recv_cursor: BTreeMap<u64, usize> = BTreeMap::new();
    let mut write_cursor: BTreeMap<u64, usize> = BTreeMap::new();
    let mut instances: Vec<Instance> = Vec::new();
    for &q in &queues {
        let s = &spans[q];
        let req = match s.req {
            Some(r) => r,
            None => continue,
        };
        let next = |map: &BTreeMap<u64, Vec<usize>>, cur: &mut BTreeMap<u64, usize>| {
            let pos = cur.entry(req).or_insert(0);
            let idx = map.get(&req).and_then(|l| l.get(*pos)).copied();
            if idx.is_some() {
                *pos += 1;
            }
            idx
        };
        instances.push(Instance {
            req,
            trace: s.trace,
            sig: s.sig,
            flush: s.flush,
            queue: q,
            recv: next(&recvs, &mut recv_cursor),
            write: next(&writes, &mut write_cursor),
        });
    }

    // Reconstruction: queue + a complete flush, and — when the stream
    // contains network spans at all — the request's recv and write.
    let complete = |inst: &Instance| -> bool {
        let Some(f) = inst.flush else { return false };
        let Some(g) = flushes.get(&f) else { return false };
        let flush_ok = g.assemble.is_some() && g.project.is_some() && g.reply.is_some();
        let net_ok = !has_net_spans || (inst.recv.is_some() && inst.write.is_some());
        flush_ok && net_ok
    };

    let requests = instances.len() as u64;
    let mut reconstructed = 0u64;
    // Per-signature accumulators: stage name → durations, plus e2e.
    let mut by_sig: BTreeMap<String, (Vec<u64>, BTreeMap<&'static str, Vec<u64>>)> =
        BTreeMap::new();
    let mut slowest: Option<(u64, usize)> = None; // (e2e, instance idx)
    for (idx, inst) in instances.iter().enumerate() {
        if !complete(inst) {
            continue;
        }
        reconstructed += 1;
        let g = &flushes[&inst.flush.unwrap_or(0)];
        let label = inst
            .sig
            .or(g.sig)
            .map(|id| {
                stream
                    .sig_labels
                    .get(&id)
                    .cloned()
                    .unwrap_or_else(|| format!("sig{id}"))
            })
            .unwrap_or_else(|| "unknown".to_string());
        let dur = |i: Option<usize>| i.map(|i| spans[i].dur_us).unwrap_or(0);
        let index_max = g.index.iter().map(|&i| spans[i].dur_us).max().unwrap_or(0);
        let stage_durs: [(&'static str, u64); 7] = [
            ("recv", dur(inst.recv)),
            ("queue", dur(Some(inst.queue))),
            ("assemble", dur(g.assemble)),
            ("project", dur(g.project)),
            ("index", index_max),
            ("reply", dur(g.reply)),
            ("write", dur(inst.write)),
        ];
        let first = inst.recv.unwrap_or(inst.queue);
        let last = inst
            .write
            .or(g.reply)
            .unwrap_or(inst.queue);
        let e2e = (spans[last].wall_us + spans[last].dur_us as i64)
            .saturating_sub(spans[first].wall_us)
            .max(0) as u64;
        let entry = by_sig.entry(label).or_default();
        entry.0.push(e2e);
        for (name, d) in stage_durs {
            entry.1.entry(name).or_default().push(d);
        }
        if slowest.map(|(t, _)| e2e > t).unwrap_or(true) {
            slowest = Some((e2e, idx));
        }
    }

    // Stage coverage.
    let mut covered: Vec<String> = Vec::new();
    for s in spans {
        if !covered.contains(&s.stage) {
            covered.push(s.stage.clone());
        }
    }
    covered.sort();
    let missing: Vec<String> = super::trace::REQUIRED_STAGES
        .iter()
        .filter(|r| !covered.iter().any(|c| c.as_str() == **r))
        .map(|r| r.to_string())
        .collect();

    // Fan-out over flushes that actually batched requests.
    let sizes: Vec<u64> =
        flushes.values().map(|g| g.items).filter(|&n| n > 0).collect();
    let fanout = FanOut {
        flushes: sizes.len() as u64,
        min_items: sizes.iter().copied().min().unwrap_or(0),
        mean_items: if sizes.is_empty() {
            0.0
        } else {
            sizes.iter().sum::<u64>() as f64 / sizes.len() as f64
        },
        max_items: sizes.iter().copied().max().unwrap_or(0),
    };

    // Per-signature critical paths.
    let mut signatures: Vec<SigPath> = Vec::new();
    for (label, (mut e2e, stages)) in by_sig {
        e2e.sort_unstable();
        let total_mean_sum: f64 = stages
            .values()
            .map(|v| v.iter().sum::<u64>() as f64)
            .sum::<f64>()
            .max(1.0);
        let mut rows = Vec::new();
        for name in PATH_STAGES {
            let Some(durs) = stages.get(name) else { continue };
            let mut sorted = durs.clone();
            sorted.sort_unstable();
            rows.push(StagePath {
                stage: name.to_string(),
                p50_us: quantile(&sorted, 0.50),
                p99_us: quantile(&sorted, 0.99),
                share: durs.iter().sum::<u64>() as f64 / total_mean_sum,
            });
        }
        signatures.push(SigPath {
            signature: label,
            count: e2e.len() as u64,
            e2e_p50_us: quantile(&e2e, 0.50),
            e2e_p99_us: quantile(&e2e, 0.99),
            stages: rows,
        });
    }

    // The slowest request's waterfall.
    let slowest = slowest.map(|(total, idx)| {
        let inst = &instances[idx];
        let g = &flushes[&inst.flush.unwrap_or(0)];
        let mut picks: Vec<usize> = Vec::new();
        if let Some(r) = inst.recv {
            picks.push(r);
        }
        picks.push(inst.queue);
        for i in [g.assemble, g.project, g.reply, g.snapshot].into_iter().flatten() {
            picks.push(i);
        }
        picks.extend(g.index.iter().copied());
        if let Some(w) = inst.write {
            picks.push(w);
        }
        picks.sort_by_key(|&i| spans[i].wall_us);
        let t0 = picks.first().map(|&i| spans[i].wall_us).unwrap_or(0);
        let rows = picks
            .iter()
            .map(|&i| WaterfallRow {
                stage: spans[i].stage.clone(),
                shard: spans[i].shard,
                offset_us: (spans[i].wall_us - t0).max(0) as u64,
                dur_us: spans[i].dur_us,
            })
            .collect();
        let signature = inst
            .sig
            .or(g.sig)
            .and_then(|id| stream.sig_labels.get(&id).cloned())
            .unwrap_or_else(|| "unknown".to_string());
        Waterfall { req: inst.req, trace: inst.trace, signature, total_us: total, rows }
    });

    Ok(AnalyzeReport {
        dir: dir.display().to_string(),
        files_read: stream.files_read,
        requests,
        reconstructed,
        reconstructed_frac: if requests == 0 {
            1.0
        } else {
            reconstructed as f64 / requests as f64
        },
        stages_covered: covered,
        missing_stages: missing,
        ring_dropped: stream.stats.map(|s| s.dropped),
        malformed_lines: stream.malformed_lines,
        fanout,
        signatures,
        slowest,
    })
}

impl AnalyzeReport {
    /// The report as a JSON document (the `--json` output).
    pub fn to_json(&self) -> Json {
        let sig_json = |p: &SigPath| {
            obj(vec![
                ("signature", Json::Str(p.signature.clone())),
                ("count", Json::Num(p.count as f64)),
                ("e2e_p50_us", Json::Num(p.e2e_p50_us as f64)),
                ("e2e_p99_us", Json::Num(p.e2e_p99_us as f64)),
                (
                    "stages",
                    Json::Arr(
                        p.stages
                            .iter()
                            .map(|s| {
                                obj(vec![
                                    ("stage", Json::Str(s.stage.clone())),
                                    ("p50_us", Json::Num(s.p50_us as f64)),
                                    ("p99_us", Json::Num(s.p99_us as f64)),
                                    ("share", Json::Num(s.share)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ])
        };
        let mut pairs = vec![
            ("dir", Json::Str(self.dir.clone())),
            ("files_read", Json::Num(self.files_read as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("reconstructed", Json::Num(self.reconstructed as f64)),
            ("reconstructed_frac", Json::Num(self.reconstructed_frac)),
            (
                "stages_covered",
                Json::Arr(self.stages_covered.iter().cloned().map(Json::Str).collect()),
            ),
            (
                "missing_stages",
                Json::Arr(self.missing_stages.iter().cloned().map(Json::Str).collect()),
            ),
            (
                "ring_dropped",
                self.ring_dropped.map(|d| Json::Num(d as f64)).unwrap_or(Json::Null),
            ),
            ("malformed_lines", Json::Num(self.malformed_lines as f64)),
            (
                "fanout",
                obj(vec![
                    ("flushes", Json::Num(self.fanout.flushes as f64)),
                    ("min_items", Json::Num(self.fanout.min_items as f64)),
                    ("mean_items", Json::Num(self.fanout.mean_items)),
                    ("max_items", Json::Num(self.fanout.max_items as f64)),
                ]),
            ),
            ("signatures", Json::Arr(self.signatures.iter().map(sig_json).collect())),
        ];
        if let Some(w) = &self.slowest {
            pairs.push((
                "slowest",
                obj(vec![
                    ("req", Json::Num(w.req as f64)),
                    (
                        "trace",
                        w.trace.map(|t| Json::Num(t as f64)).unwrap_or(Json::Null),
                    ),
                    ("signature", Json::Str(w.signature.clone())),
                    ("total_us", Json::Num(w.total_us as f64)),
                ]),
            ));
        }
        obj(pairs)
    }

    /// Gate the report: `Ok(())` when at least `min_frac` of requests
    /// reconstructed, every required stage appeared, and the sealed
    /// stats record proves zero ring drops. Failures list every broken
    /// condition.
    pub fn gate(&self, min_frac: f64) -> Result<(), Vec<String>> {
        let mut failures = Vec::new();
        if self.requests == 0 {
            failures.push("no requests found in the trace stream".to_string());
        }
        if self.reconstructed_frac < min_frac {
            failures.push(format!(
                "reconstructed {}/{} requests ({:.4}) < required {:.4}",
                self.reconstructed, self.requests, self.reconstructed_frac, min_frac
            ));
        }
        if !self.missing_stages.is_empty() {
            failures.push(format!(
                "required stages never observed: {}",
                self.missing_stages.join(", ")
            ));
        }
        match self.ring_dropped {
            Some(0) => {}
            Some(d) => failures.push(format!("span ring dropped {d} spans")),
            None => failures.push(
                "stream is not sealed (no stats record) — cannot prove zero drops"
                    .to_string(),
            ),
        }
        if failures.is_empty() {
            Ok(())
        } else {
            Err(failures)
        }
    }

    /// Human-readable report: summary, per-signature critical paths, and
    /// the slowest request's waterfall.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "trace analysis of {} ({} generation{})\n",
            self.dir,
            self.files_read,
            if self.files_read == 1 { "" } else { "s" }
        ));
        out.push_str(&format!(
            "  requests {}  reconstructed {} ({:.1}%)  ring_dropped {}  malformed {}\n",
            self.requests,
            self.reconstructed,
            self.reconstructed_frac * 100.0,
            self.ring_dropped.map(|d| d.to_string()).unwrap_or_else(|| "?".to_string()),
            self.malformed_lines,
        ));
        if !self.missing_stages.is_empty() {
            out.push_str(&format!("  MISSING stages: {}\n", self.missing_stages.join(", ")));
        }
        out.push_str(&format!(
            "  flush fan-out: {} flushes, {}–{} items (mean {:.2})\n",
            self.fanout.flushes, self.fanout.min_items, self.fanout.max_items,
            self.fanout.mean_items,
        ));
        for sig in &self.signatures {
            out.push_str(&format!(
                "\n  {}  n={}  e2e p50 {}µs  p99 {}µs\n",
                sig.signature, sig.count, sig.e2e_p50_us, sig.e2e_p99_us
            ));
            for st in &sig.stages {
                out.push_str(&format!(
                    "    {:<9} p50 {:>8}µs  p99 {:>8}µs  {:>5.1}%\n",
                    st.stage,
                    st.p50_us,
                    st.p99_us,
                    st.share * 100.0
                ));
            }
        }
        if let Some(w) = &self.slowest {
            out.push('\n');
            out.push_str(&render_waterfall(w));
        }
        out
    }
}

/// ASCII waterfall of one request, 48 columns of timeline.
pub fn render_waterfall(w: &Waterfall) -> String {
    const COLS: u64 = 48;
    let mut out = format!(
        "  slowest request: req={} trace={} sig={} total={}µs\n",
        w.req,
        w.trace.map(|t| t.to_string()).unwrap_or_else(|| "-".to_string()),
        w.signature,
        w.total_us
    );
    let span_end = w.rows.iter().map(|r| r.offset_us + r.dur_us).max().unwrap_or(1);
    let scale = span_end.max(1);
    for r in &w.rows {
        let lead = (r.offset_us * COLS / scale).min(COLS - 1);
        let mut width = (r.dur_us * COLS).div_ceil(scale);
        width = width.clamp(1, COLS - lead);
        let tag = match r.shard {
            Some(s) => format!("{}/{s}", r.stage),
            None => r.stage.clone(),
        };
        out.push_str(&format!(
            "    {:<10} |{}{}{}| {}µs\n",
            tag,
            " ".repeat(lead as usize),
            "█".repeat(width as usize),
            " ".repeat((COLS - lead - width) as usize),
            r.dur_us
        ));
    }
    out
}

/// One row of a `--diff` comparison.
#[derive(Debug, Clone, PartialEq)]
pub struct DiffRow {
    /// Signature label.
    pub signature: String,
    /// Stage tag, or `e2e`.
    pub stage: String,
    /// p99 in the baseline directory, µs.
    pub a_p99_us: u64,
    /// p99 in the candidate directory, µs.
    pub b_p99_us: u64,
    /// Relative change of p99, percent (positive = regression).
    pub delta_pct: f64,
}

/// Compare two analyzed directories signature-by-signature.
pub fn diff_reports(a: &AnalyzeReport, b: &AnalyzeReport) -> Vec<DiffRow> {
    let mut rows = Vec::new();
    for sa in &a.signatures {
        let Some(sb) = b.signatures.iter().find(|s| s.signature == sa.signature) else {
            continue;
        };
        let pct = |x: u64, y: u64| {
            if x == 0 {
                0.0
            } else {
                (y as f64 - x as f64) / x as f64 * 100.0
            }
        };
        rows.push(DiffRow {
            signature: sa.signature.clone(),
            stage: "e2e".to_string(),
            a_p99_us: sa.e2e_p99_us,
            b_p99_us: sb.e2e_p99_us,
            delta_pct: pct(sa.e2e_p99_us, sb.e2e_p99_us),
        });
        for st_a in &sa.stages {
            let Some(st_b) = sb.stages.iter().find(|s| s.stage == st_a.stage) else {
                continue;
            };
            rows.push(DiffRow {
                signature: sa.signature.clone(),
                stage: st_a.stage.clone(),
                a_p99_us: st_a.p99_us,
                b_p99_us: st_b.p99_us,
                delta_pct: pct(st_a.p99_us, st_b.p99_us),
            });
        }
    }
    rows
}

/// Diff rows as JSON.
pub fn diff_to_json(rows: &[DiffRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("signature", Json::Str(r.signature.clone())),
                    ("stage", Json::Str(r.stage.clone())),
                    ("a_p99_us", Json::Num(r.a_p99_us as f64)),
                    ("b_p99_us", Json::Num(r.b_p99_us as f64)),
                    ("delta_pct", Json::Num(r.delta_pct)),
                ])
            })
            .collect(),
    )
}

/// Diff rows as a terminal table.
pub fn render_diff(rows: &[DiffRow]) -> String {
    let mut out = String::from(
        "  signature                     stage      a_p99(µs)  b_p99(µs)   Δ%\n",
    );
    for r in rows {
        out.push_str(&format!(
            "  {:<29} {:<9} {:>9} {:>10} {:>+7.1}\n",
            r.signature, r.stage, r.a_p99_us, r.b_p99_us, r.delta_pct
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "trp_analyze_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn span_line(
        stage: &str,
        req: Option<u64>,
        flush: Option<u64>,
        shard: Option<u32>,
        trace: Option<u64>,
        sig: Option<u32>,
        start: u64,
        dur: u64,
    ) -> String {
        let n = |v: Option<u64>| v.map(|x| x.to_string()).unwrap_or_else(|| "null".into());
        format!(
            "{{\"stage\":\"{stage}\",\"req\":{},\"flush\":{},\"shard\":{},\"trace\":{},\
             \"sig\":{},\"start_us\":{start},\"dur_us\":{dur}}}",
            n(req),
            n(flush),
            n(shard.map(u64::from)),
            n(trace),
            n(sig.map(u64::from)),
        )
    }

    /// Write one request's full waterfall; `base` staggers the clock and
    /// `slow` stretches the project stage 10× (shifting everything after
    /// it, as a real regression would).
    fn full_request(
        out: &mut Vec<String>,
        req: u64,
        flush: u64,
        trace: u64,
        base: u64,
        slow: bool,
    ) {
        let project_dur = if slow { 400 } else { 40 };
        let t_index = base + 28 + project_dur;
        out.push(span_line("recv", Some(req), None, None, Some(trace), None, base, 5));
        out.push(span_line(
            "queue", Some(req), Some(flush), None, Some(trace), Some(0), base + 5, 20,
        ));
        out.push(span_line(
            "assemble", None, Some(flush), None, Some(trace), Some(0), base + 25, 3,
        ));
        out.push(span_line(
            "project", None, Some(flush), None, Some(trace), Some(0), base + 28,
            project_dur,
        ));
        out.push(span_line(
            "index", None, Some(flush), Some(0), Some(trace), Some(0), t_index, 7,
        ));
        out.push(span_line(
            "index", None, Some(flush), Some(1), Some(trace), Some(0), t_index, 9,
        ));
        out.push(span_line(
            "reply", None, Some(flush), None, Some(trace), Some(0), t_index + 10, 4,
        ));
        out.push(span_line(
            "write", Some(req), None, None, Some(trace), None, t_index + 15, 6,
        ));
    }

    fn write_dir(dir: &Path, slow: bool) {
        // Generation .1 holds request 1; the live file holds request 2 —
        // the analyzer must stitch both through their own anchors.
        let mut gen1 = vec![
            "{\"meta\":\"anchor\",\"unix_us\":1000000,\"epoch_us\":0,\"pid\":1}".to_string(),
            "{\"meta\":\"sig\",\"id\":0,\"label\":\"tt-r2/d[3,3]/k8\"}".to_string(),
        ];
        full_request(&mut gen1, 1, 100, 71, 0, slow);
        let mut live = vec![
            "{\"meta\":\"anchor\",\"unix_us\":1001000,\"epoch_us\":1000,\"pid\":1}".to_string(),
            "{\"meta\":\"sig\",\"id\":0,\"label\":\"tt-r2/d[3,3]/k8\"}".to_string(),
        ];
        full_request(&mut live, 2, 101, 72, 1000, slow);
        live.push(
            "{\"meta\":\"stats\",\"recorded\":16,\"dropped\":0,\"written\":16,\"rotations\":1}"
                .to_string(),
        );
        let mut f = std::fs::File::create(dir.join("trace.jsonl.1")).unwrap();
        writeln!(f, "{}", gen1.join("\n")).unwrap();
        let mut f = std::fs::File::create(dir.join("trace.jsonl")).unwrap();
        writeln!(f, "{}", live.join("\n")).unwrap();
    }

    #[test]
    fn reconstructs_requests_across_rotated_generations() {
        let dir = temp_dir("stitch");
        write_dir(&dir, false);
        let report = analyze_dir(&dir).unwrap();
        assert_eq!(report.files_read, 2);
        assert_eq!(report.requests, 2);
        assert_eq!(report.reconstructed, 2);
        assert_eq!(report.reconstructed_frac, 1.0);
        assert!(report.missing_stages.is_empty(), "{:?}", report.missing_stages);
        assert_eq!(report.ring_dropped, Some(0));
        assert_eq!(report.fanout.flushes, 2);
        assert_eq!(report.fanout.max_items, 1);
        assert_eq!(report.signatures.len(), 1);
        let sig = &report.signatures[0];
        assert_eq!(sig.signature, "tt-r2/d[3,3]/k8");
        assert_eq!(sig.count, 2);
        // recv@0 → write end @ base+28+40+15+6 = 89 on each generation's
        // timeline.
        assert_eq!(sig.e2e_p50_us, 89);
        // Parallel shards enter as the max (9), not the sum (16).
        let index = sig.stages.iter().find(|s| s.stage == "index").unwrap();
        assert_eq!(index.p50_us, 9);
        // Project dominates the critical path.
        let project = sig.stages.iter().find(|s| s.stage == "project").unwrap();
        assert!(project.share > 0.3, "share={}", project.share);
        report.gate(0.99).unwrap();
        // The waterfall names every pipeline stage.
        let text = report.render();
        for stage in PATH_STAGES {
            assert!(text.contains(stage), "render must mention {stage}");
        }
        // JSON output parses back.
        let j = report.to_json().to_string_compact();
        let v = Json::parse(&j).unwrap();
        assert_eq!(v.get("reconstructed").and_then(|x| x.as_usize()), Some(2));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gate_fails_on_drops_missing_stages_and_partial_requests() {
        let dir = temp_dir("gate");
        let lines = [
            "{\"meta\":\"anchor\",\"unix_us\":1000,\"epoch_us\":0,\"pid\":1}".to_string(),
            // A queue span with no flush group: cannot reconstruct.
            span_line("queue", Some(1), Some(9), None, None, None, 0, 10),
            "{\"meta\":\"stats\",\"recorded\":5,\"dropped\":3,\"written\":2,\"rotations\":0}"
                .to_string(),
        ];
        std::fs::write(dir.join("trace.jsonl"), lines.join("\n")).unwrap();
        let report = analyze_dir(&dir).unwrap();
        assert_eq!(report.requests, 1);
        assert_eq!(report.reconstructed, 0);
        let failures = report.gate(0.99).unwrap_err();
        let text = failures.join("; ");
        assert!(text.contains("dropped 3"), "{text}");
        assert!(text.contains("required stages never observed"), "{text}");
        assert!(text.contains("reconstructed 0/1"), "{text}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unsealed_stream_cannot_prove_zero_drops() {
        let dir = temp_dir("unsealed");
        std::fs::write(
            dir.join("trace.jsonl"),
            "{\"meta\":\"anchor\",\"unix_us\":1000,\"epoch_us\":0,\"pid\":1}\n",
        )
        .unwrap();
        let report = analyze_dir(&dir).unwrap();
        assert_eq!(report.ring_dropped, None);
        let failures = report.gate(0.5).unwrap_err();
        assert!(failures.iter().any(|f| f.contains("not sealed")), "{failures:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn diff_flags_the_regressed_stage() {
        let dir_a = temp_dir("diff_a");
        let dir_b = temp_dir("diff_b");
        write_dir(&dir_a, false);
        write_dir(&dir_b, true); // project is 10× slower
        let a = analyze_dir(&dir_a).unwrap();
        let b = analyze_dir(&dir_b).unwrap();
        let rows = diff_reports(&a, &b);
        let project = rows
            .iter()
            .find(|r| r.stage == "project")
            .expect("project row present");
        assert!(project.delta_pct > 500.0, "delta={}", project.delta_pct);
        let recv = rows.iter().find(|r| r.stage == "recv").unwrap();
        assert_eq!(recv.delta_pct, 0.0);
        let e2e = rows.iter().find(|r| r.stage == "e2e").unwrap();
        assert!(e2e.delta_pct > 100.0);
        // Render + JSON don't panic and mention the signature.
        assert!(render_diff(&rows).contains("tt-r2/d[3,3]/k8"));
        let j = diff_to_json(&rows).to_string_compact();
        assert!(Json::parse(&j).is_ok());
        let _ = std::fs::remove_dir_all(&dir_a);
        let _ = std::fs::remove_dir_all(&dir_b);
    }

    #[test]
    fn tolerates_truncated_tail_lines() {
        let dir = temp_dir("trunc");
        let mut lines = vec![
            "{\"meta\":\"anchor\",\"unix_us\":1000,\"epoch_us\":0,\"pid\":1}".to_string(),
        ];
        full_request(&mut lines, 1, 5, 9, 0, false);
        let mut text = lines.join("\n");
        text.push_str("\n{\"stage\":\"re"); // killed mid-write
        std::fs::write(dir.join("trace.jsonl"), text).unwrap();
        let report = analyze_dir(&dir).unwrap();
        assert_eq!(report.requests, 1);
        assert_eq!(report.reconstructed, 1);
        assert_eq!(report.malformed_lines, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
