//! Declarative SLOs evaluated as multi-window burn rates.
//!
//! `trp serve --slo objectives.toml` loads a set of per-signature
//! [`Objective`]s (p99 latency in µs, error rate as a fraction) and
//! starts one [`SloEngine`] sampler thread. Every poll tick the engine
//! snapshots the always-on [`MetricsRegistry`], derives a cumulative
//! (bad, total) counter pair per objective, and computes the burn rate
//! over a fast and a slow window:
//!
//! ```text
//!   burn(window) = (Δbad / Δtotal over the window) / error_budget
//! ```
//!
//! where the error budget is `0.01` for p99 objectives (1% of requests
//! may exceed the target) and the configured rate for error-rate
//! objectives. An alarm fires when *both* windows exceed the burn
//! threshold — the fast window catches the regression quickly, the slow
//! window keeps one noisy tick from paging — and clears when either
//! window drops back below it. Transitions are appended as JSONL to the
//! alarms file (fsynced per record, like the WAL) and the current
//! status is exported in every [`super::ObsSnapshot`].
//!
//! The engine only *reads* metrics: responses stay bit-identical with
//! SLOs configured or not. Config parsing is a hand-rolled TOML subset
//! (`key = value` scalars and `[[objective]]` tables) so the binary
//! stays dependency-free.

use super::registry::{MetricsRegistry, SigSnapshot, SloStatusSnapshot, E2E_STAGE};
use crate::coordinator::bucket_index;
use crate::util::sync::lock_recover;
use std::collections::VecDeque;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Error budget for p99 latency objectives: 1% of requests may exceed
/// the target before the budget is consumed at burn rate 1.0.
const LATENCY_BUDGET: f64 = 0.01;

/// One service-level objective, bound to a map signature.
#[derive(Debug, Clone, PartialEq)]
pub struct Objective {
    /// Signature label the objective applies to; `*` matches every
    /// signature (counters are summed across matches).
    pub signature: String,
    /// p99 end-to-end latency target in µs (an observation counts
    /// against the budget when it lands in a histogram bucket strictly
    /// above the target's bucket).
    pub p99_latency_us: Option<u64>,
    /// Error-rate target as a fraction of requests (also the budget).
    pub error_rate: Option<f64>,
    /// Fast burn window, seconds.
    pub fast_window_s: f64,
    /// Slow burn window, seconds.
    pub slow_window_s: f64,
    /// Burn threshold: fires when both windows are at or above it.
    pub burn_threshold: f64,
}

impl Default for Objective {
    fn default() -> Self {
        Self {
            signature: "*".to_string(),
            p99_latency_us: None,
            error_rate: None,
            fast_window_s: 300.0,
            slow_window_s: 3600.0,
            burn_threshold: 14.0,
        }
    }
}

/// Parsed `--slo` configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SloConfig {
    /// Objectives, in file order.
    pub objectives: Vec<Objective>,
    /// Sampler poll interval in milliseconds.
    pub poll_interval_ms: u64,
    /// Where alarm transitions are appended as JSONL (`None` = no
    /// alarm log, status export only).
    pub alarms_path: Option<PathBuf>,
}

impl Default for SloConfig {
    fn default() -> Self {
        Self { objectives: Vec::new(), poll_interval_ms: 1000, alarms_path: None }
    }
}

/// One scalar value in the TOML subset.
enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl TomlValue {
    fn parse(raw: &str, line_no: usize) -> Result<TomlValue, String> {
        let raw = raw.trim();
        if let Some(rest) = raw.strip_prefix('"') {
            let Some(inner) = rest.strip_suffix('"') else {
                return Err(format!("line {line_no}: unterminated string"));
            };
            return Ok(TomlValue::Str(inner.to_string()));
        }
        match raw {
            "true" => return Ok(TomlValue::Bool(true)),
            "false" => return Ok(TomlValue::Bool(false)),
            _ => {}
        }
        raw.parse::<f64>()
            .map(TomlValue::Num)
            .map_err(|_| format!("line {line_no}: expected string, number or bool, got `{raw}`"))
    }

    fn as_num(&self, key: &str, line_no: usize) -> Result<f64, String> {
        match self {
            TomlValue::Num(n) => Ok(*n),
            _ => Err(format!("line {line_no}: `{key}` must be a number")),
        }
    }

    fn as_str(&self, key: &str, line_no: usize) -> Result<&str, String> {
        match self {
            TomlValue::Str(s) => Ok(s),
            _ => Err(format!("line {line_no}: `{key}` must be a quoted string")),
        }
    }
}

/// Strip a `#` comment that starts outside any string literal.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

impl SloConfig {
    /// Parse the TOML subset: top-level `key = value` pairs
    /// (`poll_interval_ms`, `alarms_path`) and `[[objective]]` tables
    /// with `signature`, `p99_latency_us`, `error_rate`,
    /// `fast_window_s`, `slow_window_s`, `burn_threshold` keys.
    pub fn parse_toml(text: &str) -> Result<SloConfig, String> {
        let mut cfg = SloConfig::default();
        let mut current: Option<Objective> = None;
        for (idx, raw_line) in text.lines().enumerate() {
            let line_no = idx + 1;
            let line = strip_comment(raw_line).trim();
            if line.is_empty() {
                continue;
            }
            if line == "[[objective]]" {
                if let Some(obj) = current.take() {
                    validate_objective(&obj)?;
                    cfg.objectives.push(obj);
                }
                current = Some(Objective::default());
                continue;
            }
            if line.starts_with('[') {
                return Err(format!(
                    "line {line_no}: unknown table `{line}` (only [[objective]] is supported)"
                ));
            }
            let Some((key, raw_val)) = line.split_once('=') else {
                return Err(format!("line {line_no}: expected `key = value`, got `{line}`"));
            };
            let key = key.trim();
            let val = TomlValue::parse(raw_val, line_no)?;
            match current.as_mut() {
                Some(obj) => match key {
                    "signature" => obj.signature = val.as_str(key, line_no)?.to_string(),
                    "p99_latency_us" => {
                        let n = val.as_num(key, line_no)?;
                        if n < 1.0 || n.fract() != 0.0 {
                            return Err(format!(
                                "line {line_no}: `p99_latency_us` must be a positive integer"
                            ));
                        }
                        obj.p99_latency_us = Some(n as u64);
                    }
                    "error_rate" => {
                        let n = val.as_num(key, line_no)?;
                        if !(n > 0.0 && n <= 1.0) {
                            return Err(format!(
                                "line {line_no}: `error_rate` must be in (0, 1]"
                            ));
                        }
                        obj.error_rate = Some(n);
                    }
                    "fast_window_s" => obj.fast_window_s = positive(&val, key, line_no)?,
                    "slow_window_s" => obj.slow_window_s = positive(&val, key, line_no)?,
                    "burn_threshold" => obj.burn_threshold = positive(&val, key, line_no)?,
                    _ => return Err(format!("line {line_no}: unknown objective key `{key}`")),
                },
                None => match key {
                    "poll_interval_ms" => {
                        let n = val.as_num(key, line_no)?;
                        if n < 1.0 || n.fract() != 0.0 {
                            return Err(format!(
                                "line {line_no}: `poll_interval_ms` must be a positive integer"
                            ));
                        }
                        cfg.poll_interval_ms = n as u64;
                    }
                    "alarms_path" => {
                        cfg.alarms_path = Some(PathBuf::from(val.as_str(key, line_no)?));
                    }
                    _ => return Err(format!("line {line_no}: unknown top-level key `{key}`")),
                },
            }
        }
        if let Some(obj) = current.take() {
            validate_objective(&obj)?;
            cfg.objectives.push(obj);
        }
        if cfg.objectives.is_empty() {
            return Err("no [[objective]] tables found".to_string());
        }
        Ok(cfg)
    }

    /// Read and parse an SLO file.
    pub fn load(path: &Path) -> Result<SloConfig, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Self::parse_toml(&text).map_err(|e| format!("{}: {e}", path.display()))
    }
}

fn positive(val: &TomlValue, key: &str, line_no: usize) -> Result<f64, String> {
    let n = val.as_num(key, line_no)?;
    if n > 0.0 {
        Ok(n)
    } else {
        Err(format!("line {line_no}: `{key}` must be positive"))
    }
}

fn validate_objective(obj: &Objective) -> Result<(), String> {
    if obj.p99_latency_us.is_none() && obj.error_rate.is_none() {
        return Err(format!(
            "objective for `{}` sets neither p99_latency_us nor error_rate",
            obj.signature
        ));
    }
    if obj.fast_window_s > obj.slow_window_s {
        return Err(format!(
            "objective for `{}`: fast_window_s must not exceed slow_window_s",
            obj.signature
        ));
    }
    Ok(())
}

/// What an objective counts: requests over the latency target, or
/// error replies.
#[derive(Debug, Clone, Copy, PartialEq)]
enum CheckKind {
    /// Bad = e2e observations in buckets strictly above the target's.
    P99Latency(u64),
    /// Bad = error replies; the rate is also the budget.
    ErrorRate(f64),
}

impl CheckKind {
    fn name(self) -> &'static str {
        match self {
            CheckKind::P99Latency(_) => "p99_latency_us",
            CheckKind::ErrorRate(_) => "error_rate",
        }
    }

    fn target(self) -> f64 {
        match self {
            CheckKind::P99Latency(us) => us as f64,
            CheckKind::ErrorRate(r) => r,
        }
    }

    fn budget(self) -> f64 {
        match self {
            CheckKind::P99Latency(_) => LATENCY_BUDGET,
            CheckKind::ErrorRate(r) => r,
        }
    }
}

/// One evaluated check: an objective expanded per kind, with its sample
/// history and alarm state. Owned by the sampler thread.
struct CheckState {
    signature: String,
    kind: CheckKind,
    fast_window_s: f64,
    slow_window_s: f64,
    burn_threshold: f64,
    /// `(t_seconds, cumulative bad, cumulative total)` samples, oldest
    /// first, pruned to the slow window (plus one boundary sample).
    samples: VecDeque<(f64, u64, u64)>,
    firing: bool,
}

impl CheckState {
    /// Cumulative (bad, total) for this check across matching
    /// signatures of one registry snapshot.
    fn accumulate(&self, sigs: &[SigSnapshot]) -> (u64, u64) {
        let mut bad = 0u64;
        let mut total = 0u64;
        for sig in sigs {
            if self.signature != "*" && self.signature != sig.signature {
                continue;
            }
            match self.kind {
                CheckKind::P99Latency(target_us) => {
                    if let Some(e2e) = sig.stages.iter().find(|s| s.stage == E2E_STAGE) {
                        let cut = bucket_index(target_us);
                        for (b, &n) in e2e.buckets.iter().enumerate() {
                            if b > cut {
                                bad += n;
                            }
                        }
                        total += e2e.count;
                    }
                }
                CheckKind::ErrorRate(_) => {
                    bad += sig.errors;
                    total += sig.requests;
                }
            }
        }
        (bad, total)
    }

    /// Burn rate over one trailing window ending at the newest sample.
    fn window_burn(&self, now_s: f64, window_s: f64) -> f64 {
        let Some(&(_, bad1, total1)) = self.samples.back() else {
            return 0.0;
        };
        // Reference point: the newest sample at or before the window
        // start; before one window of history exists, the oldest.
        let start = now_s - window_s;
        let mut reference = None;
        for &s in self.samples.iter() {
            if s.0 <= start {
                reference = Some(s);
            } else {
                break;
            }
        }
        let (_, bad0, total0) =
            reference.unwrap_or_else(|| *self.samples.front().unwrap_or(&(0.0, 0, 0)));
        let d_total = total1.saturating_sub(total0);
        if d_total == 0 {
            return 0.0; // No traffic in the window consumes no budget.
        }
        let d_bad = bad1.saturating_sub(bad0);
        (d_bad as f64 / d_total as f64) / self.kind.budget()
    }

    /// Record one sample, prune history, and return the new status +
    /// whether the alarm state changed.
    fn tick(&mut self, now_s: f64, sigs: &[SigSnapshot]) -> (SloStatusSnapshot, bool) {
        let (bad, total) = self.accumulate(sigs);
        self.samples.push_back((now_s, bad, total));
        // Keep one sample at or beyond the slow-window boundary so the
        // reference lookup always has an anchor.
        while self.samples.len() > 2
            && self.samples[1].0 <= now_s - self.slow_window_s
        {
            self.samples.pop_front();
        }
        let fast_burn = self.window_burn(now_s, self.fast_window_s);
        let slow_burn = self.window_burn(now_s, self.slow_window_s);
        let firing = fast_burn >= self.burn_threshold && slow_burn >= self.burn_threshold;
        let changed = firing != self.firing;
        self.firing = firing;
        let status = SloStatusSnapshot {
            signature: self.signature.clone(),
            objective: self.kind.name().to_string(),
            target: self.kind.target(),
            fast_burn,
            slow_burn,
            firing,
        };
        (status, changed)
    }
}

/// Background evaluator: one thread sampling the metrics registry,
/// exporting burn rates, and appending alarm transitions.
pub struct SloEngine {
    registry: Arc<MetricsRegistry>,
    poll_interval_ms: u64,
    status: Mutex<Vec<SloStatusSnapshot>>,
    stop: AtomicBool,
    /// Wakes the sampler early at shutdown (poll intervals can be long).
    gate: (Mutex<()>, Condvar),
    alarms: Option<Mutex<File>>,
    worker: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl SloEngine {
    /// Start the sampler thread. Fails only when the alarms file cannot
    /// be opened — a bad objective list is rejected at parse time.
    pub fn start(cfg: SloConfig, registry: Arc<MetricsRegistry>) -> std::io::Result<Arc<Self>> {
        let alarms = match &cfg.alarms_path {
            Some(path) => {
                if let Some(parent) = path.parent() {
                    if !parent.as_os_str().is_empty() {
                        std::fs::create_dir_all(parent)?;
                    }
                }
                Some(Mutex::new(
                    OpenOptions::new().create(true).append(true).open(path)?,
                ))
            }
            None => None,
        };
        let mut checks = Vec::new();
        for obj in &cfg.objectives {
            let kinds = obj
                .p99_latency_us
                .map(CheckKind::P99Latency)
                .into_iter()
                .chain(obj.error_rate.map(CheckKind::ErrorRate));
            for kind in kinds {
                checks.push(CheckState {
                    signature: obj.signature.clone(),
                    kind,
                    fast_window_s: obj.fast_window_s,
                    slow_window_s: obj.slow_window_s,
                    burn_threshold: obj.burn_threshold,
                    samples: VecDeque::new(),
                    firing: false,
                });
            }
        }
        let engine = Arc::new(SloEngine {
            registry,
            poll_interval_ms: cfg.poll_interval_ms.max(1),
            status: Mutex::new(Vec::new()),
            stop: AtomicBool::new(false),
            gate: (Mutex::new(()), Condvar::new()),
            alarms,
            worker: Mutex::new(None),
        });
        let runner = Arc::clone(&engine);
        let handle = std::thread::Builder::new()
            .name("trp-slo".to_string())
            .spawn(move || runner.run(checks))?;
        *lock_recover(&engine.worker) = Some(handle);
        Ok(engine)
    }

    /// Current burn rates and alarm states, one entry per
    /// (objective, kind) pair, in config order.
    pub fn status(&self) -> Vec<SloStatusSnapshot> {
        lock_recover(&self.status).clone()
    }

    /// Stop the sampler and join it. Idempotent.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Release);
        self.gate.1.notify_all();
        let handle = lock_recover(&self.worker).take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }

    fn run(&self, mut checks: Vec<CheckState>) {
        let t0 = Instant::now();
        loop {
            if self.stop.load(Ordering::Acquire) {
                return;
            }
            let now_s = t0.elapsed().as_secs_f64();
            let sigs = self.registry.snapshot();
            let mut statuses = Vec::with_capacity(checks.len());
            for check in checks.iter_mut() {
                let (status, changed) = check.tick(now_s, &sigs);
                if changed {
                    self.append_alarm(&status);
                }
                statuses.push(status);
            }
            *lock_recover(&self.status) = statuses;
            let guard = lock_recover(&self.gate.0);
            // Condvar timeout is the poll pacing; notify_all from
            // shutdown cuts long intervals short.
            let _unused = self
                .gate
                .1
                .wait_timeout(guard, std::time::Duration::from_millis(self.poll_interval_ms));
        }
    }

    /// Append one alarm transition as JSONL, fsynced like a WAL record:
    /// an alarm line that only exists in the page cache is an alarm a
    /// crash un-rings.
    fn append_alarm(&self, status: &SloStatusSnapshot) {
        let Some(alarms) = &self.alarms else {
            return;
        };
        let unix_us = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0);
        let state = if status.firing { "firing" } else { "clear" };
        let line = format!(
            "{{\"unix_us\":{},\"signature\":\"{}\",\"objective\":\"{}\",\"target\":{},\
             \"fast_burn\":{},\"slow_burn\":{},\"state\":\"{}\"}}",
            unix_us,
            escape(&status.signature),
            status.objective,
            status.target,
            status.fast_burn,
            status.slow_burn,
            state,
        );
        let mut f = lock_recover(alarms);
        if let Err(e) = writeln!(f, "{line}").and_then(|()| f.sync_data()) {
            eprintln!("[slo] alarm append failed: {e}");
        }
    }
}

impl Drop for SloEngine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::MetricsRegistry;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "trp_slo_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn toml_subset_parses_objectives_and_top_level_keys() {
        let text = r#"
            # service objectives
            poll_interval_ms = 250
            alarms_path = "alarms/slo.jsonl"

            [[objective]]
            signature = "*"            # every signature
            p99_latency_us = 5000
            error_rate = 0.01

            [[objective]]
            signature = "dense/d[8,8]/k16"
            p99_latency_us = 2000
            fast_window_s = 60
            slow_window_s = 600
            burn_threshold = 6
        "#;
        let cfg = SloConfig::parse_toml(text).unwrap();
        assert_eq!(cfg.poll_interval_ms, 250);
        assert_eq!(cfg.alarms_path.as_deref(), Some(Path::new("alarms/slo.jsonl")));
        assert_eq!(cfg.objectives.len(), 2);
        let o0 = &cfg.objectives[0];
        assert_eq!(o0.signature, "*");
        assert_eq!(o0.p99_latency_us, Some(5000));
        assert_eq!(o0.error_rate, Some(0.01));
        assert_eq!(o0.fast_window_s, 300.0);
        assert_eq!(o0.slow_window_s, 3600.0);
        assert_eq!(o0.burn_threshold, 14.0);
        let o1 = &cfg.objectives[1];
        assert_eq!(o1.signature, "dense/d[8,8]/k16");
        assert_eq!(o1.fast_window_s, 60.0);
        assert_eq!(o1.slow_window_s, 600.0);
        assert_eq!(o1.burn_threshold, 6.0);
    }

    #[test]
    fn toml_rejects_bad_configs() {
        // An objective with no target is meaningless.
        let err = SloConfig::parse_toml("[[objective]]\nsignature = \"*\"\n").unwrap_err();
        assert!(err.contains("neither"), "{err}");
        // No objectives at all.
        let err = SloConfig::parse_toml("poll_interval_ms = 100\n").unwrap_err();
        assert!(err.contains("no [[objective]]"), "{err}");
        // Unknown keys fail loudly instead of being ignored.
        let err =
            SloConfig::parse_toml("[[objective]]\np99_latency_us = 10\ntypo_key = 3\n")
                .unwrap_err();
        assert!(err.contains("typo_key"), "{err}");
        // Out-of-range error rate.
        let err =
            SloConfig::parse_toml("[[objective]]\nerror_rate = 1.5\n").unwrap_err();
        assert!(err.contains("error_rate"), "{err}");
        // Inverted windows.
        let err = SloConfig::parse_toml(
            "[[objective]]\np99_latency_us = 10\nfast_window_s = 100\nslow_window_s = 10\n",
        )
        .unwrap_err();
        assert!(err.contains("fast_window_s"), "{err}");
    }

    #[test]
    fn burn_rate_is_windowed_delta_over_budget() {
        let mut check = CheckState {
            signature: "*".to_string(),
            kind: CheckKind::P99Latency(1000),
            fast_window_s: 10.0,
            slow_window_s: 100.0,
            burn_threshold: 14.0,
            samples: VecDeque::new(),
            firing: false,
        };
        // 100 requests, 2 bad at t=0; 200 requests, 52 bad at t=10:
        // over the fast window the delta is 50/100 = 0.5 bad fraction,
        // burn = 0.5 / 0.01 = 50.
        check.samples.push_back((0.0, 2, 100));
        check.samples.push_back((10.0, 52, 200));
        let burn = check.window_burn(10.0, 10.0);
        assert!((burn - 50.0).abs() < 1e-9, "burn={burn}");
        // Slow window reaches back to the oldest sample → same here.
        let slow = check.window_burn(10.0, 100.0);
        assert!((slow - 50.0).abs() < 1e-9, "slow={slow}");
        // No traffic in the window → zero burn (lets alarms clear).
        check.samples.push_back((20.0, 52, 200));
        let idle = check.window_burn(20.0, 10.0);
        assert_eq!(idle, 0.0);
    }

    #[test]
    fn alarm_fires_under_injected_latency_and_clears_when_traffic_stops() {
        let dir = temp_dir("fire");
        let alarms_path = dir.join("alarms.jsonl");
        // A 1µs p99 target puts every real observation (≥ 2µs) strictly
        // above the target bucket, so the burn rate saturates at
        // 1/0.01 = 100 ≫ 14 while traffic flows.
        let cfg = SloConfig {
            objectives: vec![Objective {
                signature: "*".to_string(),
                p99_latency_us: Some(1),
                fast_window_s: 0.05,
                slow_window_s: 0.1,
                ..Objective::default()
            }],
            poll_interval_ms: 10,
            alarms_path: Some(alarms_path.clone()),
        };
        let registry = Arc::new(MetricsRegistry::new());
        let engine = SloEngine::start(cfg, Arc::clone(&registry)).unwrap();
        let sig = registry.get("dense/d[4]/k8");

        // Inject slow traffic until the alarm fires.
        let deadline = Instant::now() + std::time::Duration::from_secs(10);
        let mut fired = false;
        while Instant::now() < deadline {
            sig.record_e2e(5_000, Some(42));
            if engine.status().iter().any(|s| s.firing) {
                fired = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert!(fired, "SLO alarm must fire under sustained over-target latency");

        // Stop traffic: burn falls to zero once the windows drain and
        // the alarm clears.
        let deadline = Instant::now() + std::time::Duration::from_secs(10);
        let mut cleared = false;
        while Instant::now() < deadline {
            if engine.status().iter().all(|s| !s.firing) {
                cleared = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(cleared, "SLO alarm must clear when traffic stops");
        engine.shutdown();

        // Both transitions landed in the alarm log, in order.
        let log = std::fs::read_to_string(&alarms_path).unwrap();
        let states: Vec<&str> = log
            .lines()
            .map(|l| {
                assert!(l.contains("\"signature\":\"*\""), "{l}");
                assert!(l.contains("\"objective\":\"p99_latency_us\""), "{l}");
                if l.contains("\"state\":\"firing\"") {
                    "firing"
                } else {
                    assert!(l.contains("\"state\":\"clear\""), "{l}");
                    "clear"
                }
            })
            .collect();
        assert!(!states.is_empty());
        assert_eq!(states[0], "firing", "first transition is the alarm firing");
        assert_eq!(*states.last().unwrap(), "clear", "last transition is the clear");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn status_reports_every_check_without_alarms_file() {
        let cfg = SloConfig {
            objectives: vec![Objective {
                signature: "*".to_string(),
                p99_latency_us: Some(1_000_000),
                error_rate: Some(0.5),
                fast_window_s: 0.05,
                slow_window_s: 0.1,
                ..Objective::default()
            }],
            poll_interval_ms: 5,
            alarms_path: None,
        };
        let registry = Arc::new(MetricsRegistry::new());
        let engine = SloEngine::start(cfg, Arc::clone(&registry)).unwrap();
        let deadline = Instant::now() + std::time::Duration::from_secs(5);
        while engine.status().len() < 2 && Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let status = engine.status();
        assert_eq!(status.len(), 2, "one check per objective kind");
        assert_eq!(status[0].objective, "p99_latency_us");
        assert_eq!(status[0].target, 1_000_000.0);
        assert_eq!(status[1].objective, "error_rate");
        assert_eq!(status[1].target, 0.5);
        assert!(!status[0].firing && !status[1].firing);
        engine.shutdown();
        // Shutdown is idempotent (Drop runs it again).
        engine.shutdown();
    }
}
