//! Observability: request-level tracing and the per-signature metrics
//! registry.
//!
//! Three layers, all off the numeric hot path:
//!
//! * [`trace`] — a lock-free bounded span ring. Producers (network
//!   threads, the dispatcher, worker jobs) record [`Span`]s with one CAS;
//!   a single drainer thread serializes them to size-capped, rotated
//!   JSONL files under `trp serve --trace-dir`. Tracing is
//!   zero-perturbation by construction: spans carry only ids, stage tags
//!   and timestamps — never numeric payload — so responses are
//!   bit-identical with tracing on or off (tier-1 gate in
//!   `tests/obs_props.rs`), and the disabled path is a single `Option`
//!   check.
//! * [`registry`] — per-signature counters and per-stage log-bucketed
//!   latency histograms, keyed like the projection-map registry (one
//!   entry per map signature). Always on; recording is a handful of
//!   relaxed atomics per flush.
//! * [`gemm_stats`] — flop + wall-time aggregation by GEMM shape bucket,
//!   hooked at the public `linalg::gemm` entries (never inside the
//!   microkernel) behind one relaxed atomic flag.
//!
//! The whole picture is exported as an [`ObsSnapshot`]: over the wire via
//! the `metrics` op, as JSON via `trp client --op metrics`, and as a
//! Prometheus-style text dump via `trp metrics [--watch]`.
//!
//! Two analysis layers sit on top of the recorders:
//!
//! * [`analyze`] — `trp trace analyze`: offline reconstruction of
//!   per-request waterfalls from the rotated JSONL stream, critical-path
//!   attribution per signature, flush fan-out stats, A/B diffs and a CI
//!   gate (≥ N% of requests reconstructed, zero ring drops).
//! * [`slo`] — declarative per-signature objectives (`trp serve --slo`)
//!   evaluated as multi-window burn rates over the metrics registry,
//!   exported in the snapshot and appended to `alarms.jsonl` on every
//!   firing/clear transition.

pub mod analyze;
pub mod gemm_stats;
pub mod registry;
pub mod slo;
pub mod trace;

pub use analyze::{analyze_dir, diff_reports, diff_to_json, render_diff, AnalyzeReport};
pub use gemm_stats::{
    gemm_profiling_enabled, gemm_record, gemm_stats_snapshot, reset_gemm_stats,
    set_gemm_profiling, GemmShapeStat,
};
pub use registry::{
    MetricsRegistry, ObsSnapshot, SigMetrics, SigSnapshot, SloStatusSnapshot, Stage,
    StageSnapshot, E2E_STAGE, STAGE_COUNT,
};
pub use slo::{Objective, SloConfig, SloEngine};
pub use trace::{
    Span, SpanRing, TraceConfig, TraceRecorder, TraceStats, OPTIONAL_STAGES, REQUIRED_STAGES,
};
