//! Random-hyperplane LSH backend with multi-probe search.
//!
//! Each of `tables` hash tables assigns an item a `bits`-bit signature:
//! bit `i` is the sign of the item's dot product with a Gaussian
//! hyperplane drawn from the same seeded rng stack as the projection maps
//! (Charikar 2002 — collision probability `1 − θ/π` per bit). A query
//! probes its exact bucket in every table plus, per table, the `probes`
//! buckets obtained by flipping the lowest-margin bits first (multi-probe,
//! Lv et al. 2007), which recovers most of the recall of extra tables at a
//! fraction of the memory. Candidates are deduplicated and exactly
//! re-scored against the stored vectors (storage is a [`FlatIndex`], so
//! insert/delete semantics — overwrite, tombstones, slot recycling — are
//! inherited rather than reimplemented).

use super::flat::FlatIndex;
use super::{AnnIndex, BackendKind, IndexStats, Neighbor, TopK};
use crate::linalg::matmul_into;
use crate::projections::Workspace;
use crate::rng::Rng;
use std::collections::HashMap;

/// LSH shape knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LshConfig {
    /// Independent hash tables (more tables → higher recall, more memory).
    pub tables: usize,
    /// Signature bits per table (more bits → smaller buckets).
    pub bits: usize,
    /// Extra flipped-bit buckets probed per table (multi-probe depth).
    pub probes: usize,
}

impl Default for LshConfig {
    fn default() -> Self {
        Self { tables: 8, bits: 12, probes: 4 }
    }
}

impl LshConfig {
    /// Derive a shape from the expected corpus size and a target recall
    /// instead of static knobs (the ROADMAP auto-tuning item). When the
    /// index is sharded, pass the expected **per-shard** corpus size —
    /// each shard hashes only its own partition.
    ///
    /// Heuristics (deterministic, clamped to constructible ranges):
    ///
    /// * `bits ≈ log₂(corpus)` — O(1) expected occupancy per bucket, so
    ///   candidate re-scoring stays cheap as the corpus grows;
    /// * `probes = bits / 3` (clamped to 2..=8) — deeper signatures merit
    ///   deeper multi-probe, which buys recall far cheaper than tables;
    /// * `tables` from the Charikar collision model: a "design" near
    ///   pair at cosine 0.9 collides per bit with `p = 1 − θ/π ≈ 0.86`;
    ///   per table with `p^bits`, boosted by multi-probe (each probed
    ///   flip carries ≈ `(1−p)/p` of the exact bucket's mass); tables is
    ///   the count driving the miss probability below `1 − target`.
    pub fn auto(corpus_hint: usize, target_recall: f64) -> LshConfig {
        let n = corpus_hint.max(2) as f64;
        let bits = (n.log2().ceil() as usize).clamp(4, 24);
        let probes = (bits / 3).clamp(2, 8);
        let p_bit: f64 = 1.0 - (0.9f64).acos() / std::f64::consts::PI;
        let p_table = p_bit.powi(bits as i32);
        let p_eff = (p_table * (1.0 + probes as f64 * (1.0 - p_bit) / p_bit)).min(0.95);
        let target = target_recall.clamp(0.05, 0.999);
        let tables = ((1.0 - target).ln() / (1.0 - p_eff).ln()).ceil() as usize;
        LshConfig { tables: tables.clamp(1, 64), bits, probes }
    }
}

/// Random-hyperplane LSH index over `R^k` embeddings.
pub struct LshIndex {
    /// Vector storage + exact re-scoring substrate.
    flat: FlatIndex,
    cfg: LshConfig,
    /// Hyperplane seed (persisted in snapshots so buckets re-derive).
    seed: u64,
    /// Hyperplanes pre-transposed to `dim × (tables · bits)`, so hashing
    /// a batch of `B` embeddings is one `B × dim · dim × (T·b)` GEMM.
    planes_t: Vec<f64>,
    /// Per table: signature → item ids.
    buckets: Vec<HashMap<u64, Vec<u64>>>,
    queries: u64,
}

impl LshIndex {
    /// New empty index; hyperplanes are drawn deterministically from
    /// `seed`.
    pub fn new(dim: usize, cfg: LshConfig, seed: u64) -> Self {
        assert!(dim > 0, "embedding dimension must be positive");
        assert!(cfg.tables >= 1, "need at least one hash table");
        assert!(
            (1..=63).contains(&cfg.bits),
            "signature bits must be in 1..=63 (codes are u64)"
        );
        let mut rng = Rng::seed_from(seed);
        // Drawn plane-major (the historical stream order), stored
        // transposed for the hashing GEMM.
        let tb = cfg.tables * cfg.bits;
        let planes = rng.gaussian_vec(tb * dim, 1.0);
        let mut planes_t = vec![0.0; dim * tb];
        for j in 0..tb {
            for p in 0..dim {
                planes_t[p * tb + j] = planes[j * dim + p];
            }
        }
        Self {
            flat: FlatIndex::new(dim),
            cfg,
            seed,
            planes_t,
            buckets: (0..cfg.tables).map(|_| HashMap::new()).collect(),
            queries: 0,
        }
    }

    /// The configured shape.
    pub fn config(&self) -> LshConfig {
        self.cfg
    }

    /// Hyperplane dot products of a batch of embeddings (row-major
    /// `[b, dim]`), written to `dots` as `[b, tables · bits]` — one GEMM
    /// against the transposed plane matrix, whatever the batch width.
    /// The GEMM accumulates the reduction dimension in the same ascending
    /// order for every `b`, so a code computed at insert time (`b = 1`)
    /// is bit-identical to the same vector hashed inside a query batch.
    fn dots_batch_into(&self, embeddings: &[f64], b: usize, dots: &mut Vec<f64>) {
        let d = self.flat.dim();
        let tb = self.cfg.tables * self.cfg.bits;
        debug_assert_eq!(embeddings.len(), b * d);
        dots.clear();
        dots.resize(b * tb, 0.0);
        matmul_into(embeddings, &self.planes_t, dots, b, d, tb);
    }

    /// Hyperplane dot products of one embedding, `tables · bits` values.
    fn dots_into(&self, embedding: &[f64], dots: &mut Vec<f64>) {
        self.dots_batch_into(embedding, 1, dots);
    }

    /// Signature of one table from its slice of dot products.
    fn code_of(dots_t: &[f64]) -> u64 {
        let mut code = 0u64;
        for (i, &v) in dots_t.iter().enumerate() {
            if v >= 0.0 {
                code |= 1u64 << i;
            }
        }
        code
    }

    /// Append the ids bucketed under `(table, code)` to `cands`.
    fn collect_bucket(&self, table: usize, code: u64, cands: &mut Vec<u64>) {
        if let Some(ids) = self.buckets[table].get(&code) {
            cands.extend_from_slice(ids);
        }
    }

    /// Remove `id` from its bucket in every table (codes recomputed from
    /// the stored vector, which must still be live in `flat`).
    fn unbucket(&mut self, id: u64, dots: &mut Vec<f64>) {
        let slot = self.flat.slot_of(id).expect("unbucket of a live id");
        // Copy the row out: recomputing codes borrows `self` immutably
        // while bucket surgery needs it mutably.
        let row: Vec<f64> = self.flat.row(slot).to_vec();
        self.dots_into(&row, dots);
        for t in 0..self.cfg.tables {
            let code = Self::code_of(&dots[t * self.cfg.bits..(t + 1) * self.cfg.bits]);
            if let Some(ids) = self.buckets[t].get_mut(&code) {
                ids.retain(|&x| x != id);
                if ids.is_empty() {
                    self.buckets[t].remove(&code);
                }
            }
        }
    }
}

impl AnnIndex for LshIndex {
    fn backend(&self) -> &'static str {
        "lsh"
    }

    fn dim(&self) -> usize {
        self.flat.dim()
    }

    fn len(&self) -> usize {
        self.flat.len()
    }

    fn insert(&mut self, id: u64, embedding: &[f64]) {
        assert_eq!(embedding.len(), self.flat.dim(), "embedding dimension mismatch");
        let mut dots = Vec::new();
        // Overwrite: drop the old bucket entries before the vector changes.
        if self.flat.slot_of(id).is_some() {
            self.unbucket(id, &mut dots);
        }
        self.dots_into(embedding, &mut dots);
        for t in 0..self.cfg.tables {
            let code = Self::code_of(&dots[t * self.cfg.bits..(t + 1) * self.cfg.bits]);
            self.buckets[t].entry(code).or_default().push(id);
        }
        self.flat.insert(id, embedding);
    }

    fn remove(&mut self, id: u64) -> bool {
        if self.flat.slot_of(id).is_none() {
            return false;
        }
        let mut dots = Vec::new();
        self.unbucket(id, &mut dots);
        self.flat.remove(id)
    }

    fn query_batch(
        &mut self,
        qs: &[f64],
        topks: &[usize],
        ws: &mut Workspace,
    ) -> Vec<Vec<Neighbor>> {
        let d = self.flat.dim();
        let b = topks.len();
        assert_eq!(qs.len(), b * d, "query batch layout must be [B, k]");
        self.queries += b as u64;
        // Hyperplane margins of the whole flush's queries in one GEMM
        // against the plane matrix, staged in workspace scratch.
        let tb = self.cfg.tables * self.cfg.bits;
        let mut dots = std::mem::take(&mut ws.tmp);
        self.dots_batch_into(qs, b, &mut dots);
        let mut out = Vec::with_capacity(b);
        let mut cands: Vec<u64> = Vec::new();
        let mut order: Vec<usize> = Vec::new();
        for (j, (q, &topk)) in qs.chunks_exact(d).zip(topks).enumerate() {
            let dots_q = &dots[j * tb..(j + 1) * tb];
            cands.clear();
            for t in 0..self.cfg.tables {
                let dots_t = &dots_q[t * self.cfg.bits..(t + 1) * self.cfg.bits];
                let code = Self::code_of(dots_t);
                self.collect_bucket(t, code, &mut cands);
                // Multi-probe: flip the bits whose hyperplane margin is
                // smallest — the buckets the query most nearly fell into.
                // `total_cmp` keeps the comparator a total order under
                // NaN margins (a NaN-margin bit sorts last and the probe
                // sequence stays deterministic).
                order.clear();
                order.extend(0..self.cfg.bits);
                order.sort_by(|&x, &y| {
                    dots_t[x]
                        .abs()
                        .total_cmp(&dots_t[y].abs())
                        .then(x.cmp(&y))
                });
                for &bit in order.iter().take(self.cfg.probes) {
                    self.collect_bucket(t, code ^ (1u64 << bit), &mut cands);
                }
            }
            // Deterministic candidate order: sort + dedup (ids collide
            // across tables and probes).
            cands.sort_unstable();
            cands.dedup();
            let qn2: f64 = q.iter().map(|v| v * v).sum();
            let mut sel = TopK::new(topk);
            for &id in &cands {
                if let Some(slot) = self.flat.slot_of(id) {
                    let row = self.flat.row(slot);
                    let dot: f64 = row.iter().zip(q).map(|(a, b)| a * b).sum();
                    let d2 = (self.flat.norm2(slot) + qn2 - 2.0 * dot).max(0.0);
                    sel.offer(id, d2.sqrt());
                }
            }
            out.push(sel.into_sorted());
        }
        ws.tmp = dots;
        out
    }

    fn stats(&self) -> IndexStats {
        let mut stats = self.flat.stats();
        stats.backend = self.backend().to_string();
        stats.queries = self.queries;
        stats.tables = self.cfg.tables;
        stats.bits = self.cfg.bits;
        stats.probes = self.cfg.probes;
        stats.buckets = self.buckets.iter().map(|t| t.len()).sum();
        stats.max_bucket = self
            .buckets
            .iter()
            .flat_map(|t| t.values().map(|ids| ids.len()))
            .max()
            .unwrap_or(0);
        stats
    }

    fn for_each_live(&self, visit: &mut dyn FnMut(u64, &[f64])) {
        // Buckets re-derive from the seeded planes on re-insert, so only
        // the flat substrate's live vectors need to travel.
        self.flat.for_each_live(visit);
    }

    fn persist_spec(&self) -> (BackendKind, LshConfig, u64) {
        (BackendKind::Lsh, self.cfg, self.seed)
    }

    fn restore_counters(&mut self, inserts: u64, deletes: u64, queries: u64) {
        // The flat substrate's query counter tracks internal re-scoring
        // only and is shadowed by `self.queries` in `stats`, so it resets.
        self.flat.restore_counters(inserts, deletes, 0);
        self.queries = queries;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> LshConfig {
        LshConfig { tables: 6, bits: 6, probes: 3 }
    }

    #[test]
    fn finds_near_duplicates() {
        // Planted structure: one stored vector is a near-duplicate of the
        // query, the rest are far; LSH must surface the duplicate.
        let mut rng = Rng::seed_from(3);
        let dim = 16;
        let mut idx = LshIndex::new(dim, small_cfg(), 99);
        let base = rng.gaussian_vec(dim, 1.0);
        let near: Vec<f64> = base.iter().map(|v| v + 0.01).collect();
        idx.insert(0, &near);
        for i in 1..50u64 {
            idx.insert(i, &rng.gaussian_vec(dim, 1.0));
        }
        let mut ws = Workspace::new();
        let res = idx.query(&base, 1, &mut ws);
        assert_eq!(res.len(), 1);
        assert_eq!(res[0].id, 0, "near-duplicate must be retrieved");
    }

    #[test]
    fn insert_delete_roundtrip_cleans_buckets() {
        let mut rng = Rng::seed_from(4);
        let dim = 8;
        let mut idx = LshIndex::new(dim, small_cfg(), 7);
        let xs: Vec<Vec<f64>> = (0..20).map(|_| rng.gaussian_vec(dim, 1.0)).collect();
        for (i, x) in xs.iter().enumerate() {
            idx.insert(i as u64, x);
        }
        assert_eq!(idx.len(), 20);
        let populated = idx.stats().buckets;
        assert!(populated > 0);
        for i in 0..20u64 {
            assert!(idx.remove(i));
        }
        assert_eq!(idx.len(), 0);
        let s = idx.stats();
        assert_eq!(s.buckets, 0, "deletes must clean every bucket");
        assert_eq!(s.max_bucket, 0);
        assert!(!idx.remove(3), "delete of an absent id reports false");
    }

    #[test]
    fn overwrite_rebuckets() {
        let mut rng = Rng::seed_from(5);
        let dim = 8;
        let mut idx = LshIndex::new(dim, small_cfg(), 11);
        let a = rng.gaussian_vec(dim, 1.0);
        let b: Vec<f64> = a.iter().map(|v| -v).collect();
        idx.insert(1, &a);
        idx.insert(1, &b); // overwrite with the antipode
        assert_eq!(idx.len(), 1);
        let mut ws = Workspace::new();
        // Querying near the new value must find it …
        let res = idx.query(&b, 1, &mut ws);
        assert_eq!(res.len(), 1);
        assert!(res[0].dist < 1e-9);
        // … and each table holds exactly one entry for the id.
        let s = idx.stats();
        assert_eq!(s.max_bucket, 1);
        assert_eq!(s.buckets, idx.config().tables);
    }

    #[test]
    fn same_seed_reproduces_hashes() {
        let mut rng = Rng::seed_from(6);
        let dim = 8;
        let xs: Vec<Vec<f64>> = (0..30).map(|_| rng.gaussian_vec(dim, 1.0)).collect();
        let q = rng.gaussian_vec(dim, 1.0);
        let run = |seed: u64| -> Vec<Neighbor> {
            let mut idx = LshIndex::new(dim, small_cfg(), seed);
            for (i, x) in xs.iter().enumerate() {
                idx.insert(i as u64, x);
            }
            let mut ws = Workspace::new();
            idx.query(&q, 5, &mut ws)
        };
        assert_eq!(run(42), run(42), "same seed → identical results");
    }

    #[test]
    fn nan_margin_query_terminates_with_deterministic_probes() {
        // A query with a NaN component poisons every hyperplane margin;
        // the probe order must stay a fixed total order (total_cmp)
        // instead of scrambling on a non-total comparator.
        let mut rng = Rng::seed_from(8);
        let dim = 8;
        let mut idx = LshIndex::new(dim, small_cfg(), 13);
        for i in 0..30u64 {
            idx.insert(i, &rng.gaussian_vec(dim, 1.0));
        }
        let mut q = rng.gaussian_vec(dim, 1.0);
        q[3] = f64::NAN;
        let mut ws = Workspace::new();
        let a = idx.query(&q, 5, &mut ws);
        let b = idx.query(&q, 5, &mut ws);
        assert_eq!(a, b, "NaN margins must not scramble probe order");
    }

    #[test]
    fn batched_query_hashing_matches_single_query() {
        // The flush-wide hashing GEMM must reproduce the per-query path
        // bit-for-bit (same kernel, same reduction order per row).
        let mut rng = Rng::seed_from(9);
        let dim = 12;
        let mut idx = LshIndex::new(dim, small_cfg(), 21);
        for i in 0..60u64 {
            idx.insert(i, &rng.gaussian_vec(dim, 1.0));
        }
        let qs: Vec<Vec<f64>> = (0..7).map(|_| rng.gaussian_vec(dim, 1.0)).collect();
        let flat_qs: Vec<f64> = qs.iter().flatten().copied().collect();
        let topks = vec![5; qs.len()];
        let mut ws = Workspace::new();
        let batched = idx.query_batch(&flat_qs, &topks, &mut ws);
        for (q, batch_res) in qs.iter().zip(&batched) {
            let single = idx.query(q, 5, &mut ws);
            assert_eq!(&single, batch_res, "batched hashing must be bit-identical");
        }
    }

    #[test]
    #[should_panic(expected = "signature bits")]
    fn rejects_oversized_signatures() {
        let _ = LshIndex::new(4, LshConfig { tables: 1, bits: 64, probes: 0 }, 0);
    }

    #[test]
    fn auto_shapes_are_constructible_across_the_input_range() {
        for corpus in [0usize, 1, 10, 100, 10_000, 1_000_000, 1 << 30] {
            for recall in [0.0, 0.5, 0.9, 0.99, 1.0] {
                let cfg = LshConfig::auto(corpus, recall);
                assert!(cfg.tables >= 1, "{corpus}/{recall}: {cfg:?}");
                assert!((1..=63).contains(&cfg.bits), "{corpus}/{recall}: {cfg:?}");
                assert!(cfg.probes <= cfg.bits, "{corpus}/{recall}: {cfg:?}");
                // Must actually construct (the snapshot decoder rejects
                // shapes `LshIndex::new` would panic on).
                let _ = LshIndex::new(4, cfg, 1);
            }
        }
    }

    #[test]
    fn auto_scales_bits_with_corpus_and_tables_with_recall() {
        let small = LshConfig::auto(100, 0.9);
        let large = LshConfig::auto(1_000_000, 0.9);
        assert!(
            large.bits > small.bits,
            "bigger corpus → longer signatures ({small:?} vs {large:?})"
        );
        let lax = LshConfig::auto(10_000, 0.5);
        let tight = LshConfig::auto(10_000, 0.99);
        assert!(
            tight.tables > lax.tables,
            "higher target recall → more tables ({lax:?} vs {tight:?})"
        );
        assert_eq!(
            LshConfig::auto(10_000, 0.9),
            LshConfig::auto(10_000, 0.9),
            "auto-tuning is deterministic"
        );
    }

    #[test]
    fn stats_report_the_effective_shape() {
        let cfg = LshConfig::auto(5_000, 0.9);
        let idx = LshIndex::new(8, cfg, 3);
        let s = idx.stats();
        assert_eq!((s.tables, s.bits, s.probes), (cfg.tables, cfg.bits, cfg.probes));
        assert_eq!(s.shards, 1);
    }
}
