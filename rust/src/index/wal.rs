//! Per-signature, per-shard-lane segmented write-ahead log.
//!
//! Snapshots alone lose every mutation since the last cut; this module
//! closes that gap. Each shard lane of a signature appends its
//! insert/delete ops to its own segment chain
//! (`sig_<hash>.shard<j>.<seg>.wal`) *inside the lane's sequencer turn*,
//! so replay order equals arrival order by construction — no cross-lane
//! interleaving exists to reconstruct, because ops for one id always land
//! in one lane (`shard_of`). Durability is group-committed: the
//! coordinator batches one `sync_data` per touched lane per flush (or per
//! N appended ops, see [`WalFsync`]), never one per op.
//!
//! ## On-disk format (little-endian throughout)
//!
//! Segment header — written once at segment creation, fsynced before any
//! record, and self-describing so a WAL-only recovery (crash before the
//! first checkpoint) can rebuild an empty index for the right signature:
//!
//! ```text
//! magic     b"TRPWAL0\0"    8 bytes
//! version   u32             currently 1
//! shard     u32             lane index this file belongs to
//! start_seq u64             seq of the first record in this segment
//! key_len   u32, key bytes  opaque signature encoding (MapKey::encode)
//! ```
//!
//! Record frame — length-framed and FNV-1a-checksummed:
//!
//! ```text
//! len  u32                  body length in bytes
//! body seq u64 | op u8 | id u64 | dim u32 | dim × f64
//! sum  u64                  FNV-1a over the body bytes
//! ```
//!
//! ## Torn-tail contract
//!
//! Appends are single `write_all` calls, so a crash leaves at most a
//! *prefix* of the final frame on disk. Readers therefore:
//!
//! * tolerate an incomplete frame at the end of the **final** segment
//!   (scan-to-last-valid: replay recovers exactly the valid prefix);
//! * reject — loudly, never silently skipping — a *complete* frame whose
//!   checksum mismatches, anywhere: that is real corruption, not a torn
//!   write;
//! * reject torn records or torn headers in a **non-final** segment
//!   (rotation fsyncs a segment before opening its successor, so a torn
//!   non-final segment cannot be produced by a crash);
//! * enforce seq contiguity within and across segments (segment `N+1`
//!   must start at the seq after segment `N`'s last record).
//!
//! Checkpoints are snapshot cuts: the manifest records each lane's
//! covered watermark, and [`WalWriter::truncate_covered`] deletes fully
//! covered segments only after the manifest is durably renamed.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};

use super::persist::{fnv1a, Cursor};

/// Segment file magic.
const WAL_MAGIC: &[u8; 8] = b"TRPWAL0\0";
/// Current segment format version.
const WAL_VERSION: u32 = 1;
/// Fixed header length before the variable-length key bytes.
const HEADER_FIXED: usize = 8 + 4 + 4 + 8 + 4;
/// Frame overhead: length prefix + checksum suffix.
const FRAME_OVERHEAD: usize = 4 + 8;
/// Body length of a record with a `dim`-element payload.
const BODY_FIXED: usize = 8 + 1 + 8 + 4;

/// WAL op tag: insert (payload = embedding).
pub const WAL_OP_INSERT: u8 = 1;
/// WAL op tag: delete (payload empty).
pub const WAL_OP_DELETE: u8 = 2;

/// Default segment rotation cap (bytes).
pub const DEFAULT_SEGMENT_CAP: u64 = 8 * 1024 * 1024;

/// When the coordinator fsyncs appended WAL records.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WalFsync {
    /// One `sync_data` per touched lane per flush, before replies are
    /// sent — an acked mutation is durable.
    Flush,
    /// `sync_data` once a lane accumulates N unsynced appends — cheaper,
    /// but up to N−1 acked ops per lane can be lost to a crash.
    EveryN(u64),
}

impl WalFsync {
    /// Parse the `--wal-fsync` CLI value: `flush` or `every-<n>`.
    pub fn parse(s: &str) -> Result<WalFsync, String> {
        if s == "flush" {
            return Ok(WalFsync::Flush);
        }
        if let Some(n) = s.strip_prefix("every-") {
            let n: u64 = n
                .parse()
                .map_err(|_| format!("bad --wal-fsync '{s}' (expected 'flush' or 'every-<n>')"))?;
            if n == 0 {
                return Err("--wal-fsync every-0 is meaningless; use 'flush'".into());
            }
            return Ok(WalFsync::EveryN(n));
        }
        Err(format!("bad --wal-fsync '{s}' (expected 'flush' or 'every-<n>')"))
    }

    /// Canonical name (inverse of [`WalFsync::parse`]).
    pub fn name(&self) -> String {
        match self {
            WalFsync::Flush => "flush".to_string(),
            WalFsync::EveryN(n) => format!("every-{n}"),
        }
    }
}

/// WAL configuration carried by the coordinator.
#[derive(Debug, Clone)]
pub struct WalConfig {
    /// Directory holding the segment files.
    pub dir: PathBuf,
    /// Segment rotation threshold in bytes.
    pub segment_cap: u64,
    /// Group-commit fsync policy.
    pub fsync: WalFsync,
}

/// One decoded WAL record.
#[derive(Debug, Clone, PartialEq)]
pub struct WalRecord {
    /// Per-lane sequence number (starts at 1; contiguous).
    pub seq: u64,
    /// [`WAL_OP_INSERT`] or [`WAL_OP_DELETE`].
    pub op: u8,
    /// Item id the op targets.
    pub id: u64,
    /// Embedding for inserts; empty for deletes.
    pub payload: Vec<f64>,
}

/// A fully read lane: every valid record across the segment chain.
#[derive(Debug, Clone)]
pub struct LaneStream {
    /// Lane index from the segment headers.
    pub shard: u32,
    /// Opaque signature encoding from the segment headers.
    pub key_bytes: Vec<u8>,
    /// Records in seq order (contiguous).
    pub records: Vec<WalRecord>,
    /// Readable segments in the chain.
    pub segments: usize,
    /// Bytes of torn (tolerated) tail discarded from the final segment.
    pub torn_bytes: u64,
    /// `start_seq` of the first segment (1 for a never-truncated lane).
    pub first_seq: u64,
}

/// Decoded segment header.
#[derive(Debug, Clone)]
struct SegmentHeader {
    shard: u32,
    start_seq: u64,
    key_bytes: Vec<u8>,
}

/// One scanned segment: header, valid records, and tail accounting.
struct SegmentScan {
    header: SegmentHeader,
    records: Vec<WalRecord>,
    /// Byte length of header + valid frames (the truncate-to point).
    valid_len: u64,
    /// Bytes past `valid_len` (a torn final frame; 0 when clean).
    torn_bytes: u64,
}

/// Outcome of scanning one segment file.
enum SegmentScanOutcome {
    /// The header itself is incomplete — a crash inside segment creation.
    /// Tolerable only for the newest segment of a lane.
    TornHeader,
    /// Header parsed; records scanned to the last valid frame.
    Scanned(SegmentScan),
}

fn read_u32_at(bytes: &[u8], p: usize) -> u32 {
    let mut b = [0u8; 4];
    b.copy_from_slice(&bytes[p..p + 4]);
    u32::from_le_bytes(b)
}

fn read_u64_at(bytes: &[u8], p: usize) -> u64 {
    let mut b = [0u8; 8];
    b.copy_from_slice(&bytes[p..p + 8]);
    u64::from_le_bytes(b)
}

/// Segment file name for `(stem, shard, seg)`.
pub fn wal_file_name(stem: &str, shard: u32, seg: u64) -> String {
    format!("{stem}.shard{shard}.{seg:08}.wal")
}

/// Parse a WAL file name back into `(stem, shard, seg)`; `None` when the
/// name is not a WAL segment.
pub fn parse_wal_name(name: &str) -> Option<(String, u32, u64)> {
    let rest = name.strip_suffix(".wal")?;
    let (rest, seg_s) = rest.rsplit_once('.')?;
    let (stem, shard_s) = rest.rsplit_once('.')?;
    let shard: u32 = shard_s.strip_prefix("shard")?.parse().ok()?;
    let seg: u64 = seg_s.parse().ok()?;
    if seg == 0 || stem.is_empty() {
        return None;
    }
    Some((stem.to_string(), shard, seg))
}

/// Discover every WAL lane under `dir`: stem → shard → seg-sorted file
/// list. A missing directory is an empty result, not an error.
#[allow(clippy::type_complexity)]
pub fn scan_dir(dir: &Path) -> Result<BTreeMap<String, BTreeMap<u32, Vec<(u64, PathBuf)>>>, String> {
    let mut out: BTreeMap<String, BTreeMap<u32, Vec<(u64, PathBuf)>>> = BTreeMap::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
        Err(e) => return Err(format!("read wal dir {}: {e}", dir.display())),
    };
    for entry in entries {
        let entry = entry.map_err(|e| format!("read wal dir {}: {e}", dir.display()))?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some((stem, shard, seg)) = parse_wal_name(name) else { continue };
        out.entry(stem).or_default().entry(shard).or_default().push((seg, entry.path()));
    }
    for lanes in out.values_mut() {
        for files in lanes.values_mut() {
            files.sort_by_key(|(seg, _)| *seg);
        }
    }
    Ok(out)
}

fn encode_header(shard: u32, start_seq: u64, key_bytes: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_FIXED + key_bytes.len());
    out.extend_from_slice(WAL_MAGIC);
    out.extend_from_slice(&WAL_VERSION.to_le_bytes());
    out.extend_from_slice(&shard.to_le_bytes());
    out.extend_from_slice(&start_seq.to_le_bytes());
    out.extend_from_slice(&(key_bytes.len() as u32).to_le_bytes());
    out.extend_from_slice(key_bytes);
    out
}

/// Encode one length-framed, checksummed record.
fn encode_frame(seq: u64, op: u8, id: u64, payload: &[f64]) -> Vec<u8> {
    let body_len = BODY_FIXED + payload.len() * 8;
    let mut out = Vec::with_capacity(4 + body_len + 8);
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
    out.extend_from_slice(&seq.to_le_bytes());
    out.push(op);
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    for v in payload {
        out.extend_from_slice(&v.to_le_bytes());
    }
    let sum = fnv1a(&out[4..]);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

fn decode_body(body: &[u8]) -> Result<WalRecord, String> {
    let mut cur = Cursor::new(body);
    let seq = cur.u64()?;
    let op = cur.u8()?;
    if op != WAL_OP_INSERT && op != WAL_OP_DELETE {
        return Err(format!("unknown wal op tag {op}"));
    }
    let id = cur.u64()?;
    let dim = cur.u32()? as usize;
    let raw = cur.take(dim.checked_mul(8).ok_or("wal payload length overflow")?)?;
    let mut payload = Vec::with_capacity(dim);
    for chunk in raw.chunks_exact(8) {
        let mut b = [0u8; 8];
        b.copy_from_slice(chunk);
        payload.push(f64::from_le_bytes(b));
    }
    if cur.pos() != body.len() {
        return Err("wal record body has trailing bytes".into());
    }
    Ok(WalRecord { seq, op, id, payload })
}

/// Scan one segment file: parse the header, then frames up to the last
/// valid one. Returns [`SegmentScanOutcome::TornHeader`] when the header
/// is an incomplete prefix (crash inside creation); errors loudly on bad
/// magic/version, checksum mismatch, malformed bodies, or seq gaps.
fn scan_segment(path: &Path) -> Result<SegmentScanOutcome, String> {
    let bytes = std::fs::read(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    if bytes.len() < 8 {
        return Ok(SegmentScanOutcome::TornHeader);
    }
    if &bytes[..8] != WAL_MAGIC {
        return Err(format!("{}: not a TRP wal segment (bad magic)", path.display()));
    }
    let mut cur = Cursor::new(&bytes);
    let _ = cur.take(8); // magic, verified above
    let Ok(version) = cur.u32() else { return Ok(SegmentScanOutcome::TornHeader) };
    if version != WAL_VERSION {
        return Err(format!(
            "{}: unsupported wal version {version} (expected {WAL_VERSION})",
            path.display()
        ));
    }
    let (Ok(shard), Ok(start_seq), Ok(key_len)) = (cur.u32(), cur.u64(), cur.u32()) else {
        return Ok(SegmentScanOutcome::TornHeader);
    };
    let Ok(key_bytes) = cur.take(key_len as usize) else {
        return Ok(SegmentScanOutcome::TornHeader);
    };
    let header = SegmentHeader { shard, start_seq, key_bytes: key_bytes.to_vec() };
    let mut p = cur.pos();
    let total = bytes.len();
    let mut records = Vec::new();
    let mut expected = start_seq;
    while p < total {
        let remaining = total - p;
        if remaining < 4 {
            break; // torn length prefix
        }
        let len = read_u32_at(&bytes, p) as usize;
        let Some(need) = len.checked_add(FRAME_OVERHEAD) else { break };
        if remaining < need {
            break; // torn frame (only a prefix was written)
        }
        let body = &bytes[p + 4..p + 4 + len];
        let stored = read_u64_at(&bytes, p + 4 + len);
        if fnv1a(body) != stored {
            return Err(format!(
                "{}: record checksum mismatch at byte {p} (corruption, not a torn tail)",
                path.display()
            ));
        }
        let rec = decode_body(body).map_err(|e| format!("{}: {e} at byte {p}", path.display()))?;
        if rec.seq != expected {
            return Err(format!(
                "{}: wal sequence gap at byte {p} (expected seq {expected}, found {})",
                path.display(),
                rec.seq
            ));
        }
        expected += 1;
        records.push(rec);
        p += need;
    }
    Ok(SegmentScanOutcome::Scanned(SegmentScan {
        header,
        records,
        valid_len: p as u64,
        torn_bytes: (total - p) as u64,
    }))
}

/// Read one lane's full record stream from its seg-sorted segment files.
///
/// Returns `Ok(None)` when the lane has no readable segment (only a
/// torn-header file — a crash during the very first segment creation).
/// Torn tails are tolerated on the final segment only; everything else
/// (mid-segment corruption, cross-segment seq gaps, header mismatches)
/// errors loudly.
pub fn read_lane(files: &[(u64, PathBuf)]) -> Result<Option<LaneStream>, String> {
    let mut records = Vec::new();
    let mut head: Option<(u32, Vec<u8>, u64)> = None;
    let mut torn_bytes = 0u64;
    let mut segments = 0usize;
    let mut prev_last: Option<u64> = None;
    for (i, (_seg, path)) in files.iter().enumerate() {
        let is_final = i + 1 == files.len();
        let scan = match scan_segment(path)? {
            SegmentScanOutcome::TornHeader => {
                if is_final {
                    break;
                }
                return Err(format!(
                    "{}: torn header on a non-final wal segment",
                    path.display()
                ));
            }
            SegmentScanOutcome::Scanned(s) => s,
        };
        if !is_final && scan.torn_bytes > 0 {
            return Err(format!(
                "{}: torn record inside a non-final wal segment",
                path.display()
            ));
        }
        if !is_final && scan.records.is_empty() {
            return Err(format!("{}: empty non-final wal segment", path.display()));
        }
        match &head {
            None => {
                head = Some((
                    scan.header.shard,
                    scan.header.key_bytes.clone(),
                    scan.header.start_seq,
                ));
            }
            Some((shard0, key0, _)) => {
                if scan.header.shard != *shard0 || scan.header.key_bytes != *key0 {
                    return Err(format!(
                        "{}: segment header disagrees with the lane's first segment",
                        path.display()
                    ));
                }
            }
        }
        if let Some(prev) = prev_last {
            if scan.header.start_seq != prev + 1 {
                return Err(format!(
                    "{}: wal segment starts at seq {} but the previous segment ended at {prev}",
                    path.display(),
                    scan.header.start_seq
                ));
            }
        }
        prev_last = Some(scan.records.last().map_or(scan.header.start_seq - 1, |r| r.seq));
        torn_bytes += scan.torn_bytes;
        segments += 1;
        records.extend(scan.records);
    }
    let Some((shard, key_bytes, first_seq)) = head else {
        return Ok(None);
    };
    Ok(Some(LaneStream { shard, key_bytes, records, segments, torn_bytes, first_seq }))
}

/// A closed (rotated-away) segment still on disk, awaiting checkpoint
/// truncation.
#[derive(Debug)]
struct ClosedSeg {
    path: PathBuf,
    last_seq: u64,
}

/// Append-side handle for one shard lane's segment chain.
///
/// One writer exists per `(signature, shard)` lane, driven inside that
/// lane's sequencer turn, so appends are externally serialized; the
/// writer itself does no locking. `sync` is the group-commit point the
/// coordinator batches per flush.
#[derive(Debug)]
pub struct WalWriter {
    dir: PathBuf,
    stem: String,
    shard: u32,
    key_bytes: Vec<u8>,
    segment_cap: u64,
    /// Last appended seq (0 before the first append of a fresh lane).
    seq: u64,
    seg: u64,
    file: File,
    seg_bytes: u64,
    seg_records: u64,
    unsynced: u64,
    closed: Vec<ClosedSeg>,
}

fn sync_parent_dir(dir: &Path) -> Result<(), String> {
    let d = File::open(dir).map_err(|e| format!("open wal dir {}: {e}", dir.display()))?;
    d.sync_all().map_err(|e| format!("sync wal dir {}: {e}", dir.display()))
}

impl WalWriter {
    /// Open (or create) the lane `(stem, shard)` under `dir`.
    ///
    /// Existing segments are validated like [`read_lane`]: a torn tail on
    /// the final segment is truncated away (`set_len` to the last valid
    /// frame) and appending continues from the last durable seq; a
    /// torn-header final segment (crash inside rotation) is deleted. A
    /// fresh lane starts at segment 1 with `start_seq = fresh_start_seq`
    /// (1 for a brand-new signature; recovery passes its replay watermark
    /// + 1 so new appends stay above the checkpoint marks).
    pub fn open(
        dir: &Path,
        stem: &str,
        shard: u32,
        key_bytes: Vec<u8>,
        segment_cap: u64,
        fresh_start_seq: u64,
    ) -> Result<WalWriter, String> {
        std::fs::create_dir_all(dir)
            .map_err(|e| format!("create wal dir {}: {e}", dir.display()))?;
        let mut files: Vec<(u64, PathBuf)> = scan_dir(dir)?
            .remove(stem)
            .and_then(|mut lanes| lanes.remove(&shard))
            .unwrap_or_default();
        // A crash inside rotation can leave the newest segment with a
        // torn header; drop it and continue on the previous segment.
        if let Some((_, path)) = files.last() {
            if matches!(scan_segment(path)?, SegmentScanOutcome::TornHeader) {
                std::fs::remove_file(path)
                    .map_err(|e| format!("remove {}: {e}", path.display()))?;
                files.pop();
            }
        }
        let mut w = WalWriter {
            dir: dir.to_path_buf(),
            stem: stem.to_string(),
            shard,
            key_bytes,
            segment_cap: segment_cap.max(1),
            seq: 0,
            seg: 0,
            // Placeholder; replaced below before any use. /dev/null-like
            // behavior is unnecessary because open_fresh/open_existing
            // always overwrite it — but File has no cheap dummy, so open
            // the directory read-only as the initial value.
            file: File::open(dir).map_err(|e| format!("open wal dir {}: {e}", dir.display()))?,
            seg_bytes: 0,
            seg_records: 0,
            unsynced: 0,
            closed: Vec::new(),
        };
        if files.is_empty() {
            w.open_fresh(1, fresh_start_seq.max(1))?;
            w.seq = fresh_start_seq.max(1) - 1;
            return Ok(w);
        }
        let n = files.len();
        let mut prev_last: Option<u64> = None;
        for (i, (seg_no, path)) in files.iter().enumerate() {
            let scan = match scan_segment(path)? {
                SegmentScanOutcome::TornHeader => {
                    return Err(format!(
                        "{}: torn header on a non-final wal segment",
                        path.display()
                    ))
                }
                SegmentScanOutcome::Scanned(s) => s,
            };
            if scan.header.shard != shard {
                return Err(format!(
                    "{}: header names shard {} but the file name says {shard}",
                    path.display(),
                    scan.header.shard
                ));
            }
            if scan.header.key_bytes != w.key_bytes {
                return Err(format!(
                    "{}: wal lane belongs to a different signature",
                    path.display()
                ));
            }
            if let Some(prev) = prev_last {
                if scan.header.start_seq != prev + 1 {
                    return Err(format!(
                        "{}: wal segment starts at seq {} but the previous segment ended at {prev}",
                        path.display(),
                        scan.header.start_seq
                    ));
                }
            }
            let last_seq = scan.records.last().map_or(scan.header.start_seq - 1, |r| r.seq);
            prev_last = Some(last_seq);
            if i + 1 < n {
                if scan.torn_bytes > 0 {
                    return Err(format!(
                        "{}: torn record inside a non-final wal segment",
                        path.display()
                    ));
                }
                if scan.records.is_empty() {
                    return Err(format!("{}: empty non-final wal segment", path.display()));
                }
                w.closed.push(ClosedSeg { path: path.clone(), last_seq });
            } else {
                if scan.torn_bytes > 0 {
                    // Truncate the torn tail so appends continue from the
                    // last valid frame instead of burying it.
                    let f = OpenOptions::new()
                        .read(true)
                        .write(true)
                        .open(path)
                        .map_err(|e| format!("open {}: {e}", path.display()))?;
                    f.set_len(scan.valid_len)
                        .map_err(|e| format!("truncate {}: {e}", path.display()))?;
                    f.sync_all().map_err(|e| format!("sync {}: {e}", path.display()))?;
                }
                w.file = OpenOptions::new()
                    .append(true)
                    .open(path)
                    .map_err(|e| format!("open {}: {e}", path.display()))?;
                w.seg = *seg_no;
                w.seg_bytes = scan.valid_len;
                w.seg_records = scan.records.len() as u64;
                w.seq = last_seq;
            }
        }
        Ok(w)
    }

    fn seg_path(&self, seg: u64) -> PathBuf {
        self.dir.join(wal_file_name(&self.stem, self.shard, seg))
    }

    /// Create segment `seg` starting at `start_seq`: write + fsync the
    /// header, then fsync the directory so the file name is durable.
    fn open_fresh(&mut self, seg: u64, start_seq: u64) -> Result<(), String> {
        let path = self.seg_path(seg);
        let mut f = OpenOptions::new()
            .write(true)
            .create_new(true)
            .open(&path)
            .map_err(|e| format!("create {}: {e}", path.display()))?;
        let header = encode_header(self.shard, start_seq, &self.key_bytes);
        f.write_all(&header).map_err(|e| format!("write {}: {e}", path.display()))?;
        f.sync_all().map_err(|e| format!("sync {}: {e}", path.display()))?;
        sync_parent_dir(&self.dir)?;
        self.file = f;
        self.seg = seg;
        self.seg_bytes = header.len() as u64;
        self.seg_records = 0;
        Ok(())
    }

    /// Rotate to a fresh segment: fsync the current one (its records are
    /// now durable), remember it for checkpoint truncation, and open the
    /// successor starting at `seq + 1`.
    fn rotate(&mut self) -> Result<(), String> {
        self.file
            .sync_data()
            .map_err(|e| format!("sync {}: {e}", self.seg_path(self.seg).display()))?;
        self.unsynced = 0;
        self.closed.push(ClosedSeg { path: self.seg_path(self.seg), last_seq: self.seq });
        self.open_fresh(self.seg + 1, self.seq + 1)
    }

    /// Append one op. Rotates first when the current segment is at the
    /// size cap. Returns the record's seq. Durability requires a
    /// subsequent [`WalWriter::sync`] (group-committed by the caller).
    pub fn append(&mut self, op: u8, id: u64, payload: &[f64]) -> Result<u64, String> {
        if self.seg_bytes >= self.segment_cap && self.seg_records > 0 {
            self.rotate()?;
        }
        let seq = self.seq + 1;
        let frame = encode_frame(seq, op, id, payload);
        self.file
            .write_all(&frame)
            .map_err(|e| format!("wal append {}: {e}", self.seg_path(self.seg).display()))?;
        self.seq = seq;
        self.seg_bytes += frame.len() as u64;
        self.seg_records += 1;
        self.unsynced += 1;
        Ok(seq)
    }

    /// Group-commit: `sync_data` the current segment. Closed segments
    /// were fsynced at rotation, so this covers every unsynced append.
    pub fn sync(&mut self) -> Result<(), String> {
        self.file
            .sync_data()
            .map_err(|e| format!("wal sync {}: {e}", self.seg_path(self.seg).display()))?;
        self.unsynced = 0;
        Ok(())
    }

    /// Last appended seq (0 when nothing was ever appended).
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Appends not yet covered by a [`WalWriter::sync`].
    pub fn unsynced(&self) -> u64 {
        self.unsynced
    }

    /// Delete every segment of this lane and start a fresh chain at
    /// `seq + 1` — the runtime `restore` wire op rewinds index state to a
    /// snapshot, so the logged tail must not be replayed over it. Seq
    /// numbering continues (never regresses) so records appended after
    /// the reset stay above any older checkpoint watermark.
    pub fn reset(&mut self) -> Result<(), String> {
        for c in std::mem::take(&mut self.closed) {
            std::fs::remove_file(&c.path)
                .map_err(|e| format!("remove {}: {e}", c.path.display()))?;
        }
        let current = self.seg_path(self.seg);
        std::fs::remove_file(&current)
            .map_err(|e| format!("remove {}: {e}", current.display()))?;
        self.unsynced = 0;
        self.open_fresh(1, self.seq + 1)
    }

    /// Checkpoint truncation: delete segments fully covered by the
    /// durable watermark `mark` (every record seq ≤ mark is captured in a
    /// durably renamed manifest). When the *active* segment is fully
    /// covered and non-empty, rotate past it first so the lane always
    /// keeps a live segment. Call only after the manifest rename is
    /// durable. Returns the number of deleted segments.
    pub fn truncate_covered(&mut self, mark: u64) -> Result<usize, String> {
        let mut deleted = 0usize;
        for c in std::mem::take(&mut self.closed) {
            if c.last_seq <= mark {
                std::fs::remove_file(&c.path)
                    .map_err(|e| format!("remove {}: {e}", c.path.display()))?;
                deleted += 1;
            } else {
                self.closed.push(c);
            }
        }
        if self.seq <= mark && self.seg_records > 0 {
            let old = self.seg_path(self.seg);
            self.open_fresh(self.seg + 1, self.seq + 1)?;
            std::fs::remove_file(&old).map_err(|e| format!("remove {}: {e}", old.display()))?;
            self.unsynced = 0;
            deleted += 1;
        }
        if deleted > 0 {
            sync_parent_dir(&self.dir)?;
        }
        Ok(deleted)
    }
}

/// Per-lane summary for `trp wal verify`.
#[derive(Debug, Clone)]
pub struct LaneReport {
    /// Lane index.
    pub shard: u32,
    /// Readable segments.
    pub segments: usize,
    /// Valid records across the chain.
    pub records: u64,
    /// Seq of the first record position (the first segment's start_seq).
    pub first_seq: u64,
    /// Last valid seq (first_seq − 1 when the chain holds no records).
    pub last_seq: u64,
    /// Torn tail bytes discarded by scan-to-last-valid.
    pub torn_bytes: u64,
    /// Total on-disk bytes of the lane's files.
    pub bytes: u64,
}

/// Per-signature summary for `trp wal verify`.
#[derive(Debug, Clone)]
pub struct StemReport {
    /// File stem (`sig_<hash>`).
    pub stem: String,
    /// Opaque signature encoding from the segment headers (empty when no
    /// lane was readable).
    pub key_bytes: Vec<u8>,
    /// Lane summaries in shard order.
    pub lanes: Vec<LaneReport>,
    /// First corruption hit, if any (lanes after it are still reported).
    pub error: Option<String>,
}

/// Verify every WAL chain under `dir`: scan-to-last-valid per lane,
/// reporting torn tails (tolerated) separately from corruption (loud,
/// recorded in [`StemReport::error`]).
pub fn verify_dir(dir: &Path) -> Result<Vec<StemReport>, String> {
    let mut out = Vec::new();
    for (stem, lanes) in scan_dir(dir)? {
        let mut report = StemReport {
            stem: stem.clone(),
            key_bytes: Vec::new(),
            lanes: Vec::new(),
            error: None,
        };
        for (shard, files) in &lanes {
            let bytes: u64 = files
                .iter()
                .map(|(_, p)| std::fs::metadata(p).map(|m| m.len()).unwrap_or(0))
                .sum();
            match read_lane(files) {
                Ok(Some(stream)) => {
                    if stream.shard != *shard {
                        report.error.get_or_insert(format!(
                            "{stem}.shard{shard}: header names shard {}",
                            stream.shard
                        ));
                    }
                    if report.key_bytes.is_empty() {
                        report.key_bytes = stream.key_bytes.clone();
                    } else if report.key_bytes != stream.key_bytes {
                        report.error.get_or_insert(format!(
                            "{stem}.shard{shard}: lanes disagree on the signature encoding"
                        ));
                    }
                    report.lanes.push(LaneReport {
                        shard: *shard,
                        segments: stream.segments,
                        records: stream.records.len() as u64,
                        first_seq: stream.first_seq,
                        last_seq: stream
                            .records
                            .last()
                            .map_or(stream.first_seq.saturating_sub(1), |r| r.seq),
                        torn_bytes: stream.torn_bytes,
                        bytes,
                    });
                }
                Ok(None) => {
                    report.lanes.push(LaneReport {
                        shard: *shard,
                        segments: 0,
                        records: 0,
                        first_seq: 0,
                        last_seq: 0,
                        torn_bytes: bytes,
                        bytes,
                    });
                }
                Err(e) => {
                    report.error.get_or_insert(e);
                    report.lanes.push(LaneReport {
                        shard: *shard,
                        segments: 0,
                        records: 0,
                        first_seq: 0,
                        last_seq: 0,
                        torn_bytes: 0,
                        bytes,
                    });
                }
            }
        }
        out.push(report);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("trp_wal_unit_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn key() -> Vec<u8> {
        vec![9, 8, 7, 6]
    }

    fn lane_files(dir: &Path, stem: &str, shard: u32) -> Vec<(u64, PathBuf)> {
        scan_dir(dir).unwrap().remove(stem).and_then(|mut l| l.remove(&shard)).unwrap_or_default()
    }

    #[test]
    fn fsync_policy_parses_and_rejects() {
        assert_eq!(WalFsync::parse("flush").unwrap(), WalFsync::Flush);
        assert_eq!(WalFsync::parse("every-64").unwrap(), WalFsync::EveryN(64));
        assert!(WalFsync::parse("every-0").is_err());
        assert!(WalFsync::parse("always").is_err());
        assert!(WalFsync::parse("every-x").is_err());
        assert_eq!(WalFsync::EveryN(8).name(), "every-8");
        assert_eq!(WalFsync::parse(&WalFsync::Flush.name()).unwrap(), WalFsync::Flush);
    }

    #[test]
    fn file_name_roundtrips() {
        let name = wal_file_name("sig_00ff", 3, 12);
        assert_eq!(name, "sig_00ff.shard3.00000012.wal");
        assert_eq!(parse_wal_name(&name), Some(("sig_00ff".to_string(), 3, 12)));
        assert_eq!(parse_wal_name("sig_00ff.snap"), None);
        assert_eq!(parse_wal_name("sig_00ff.shard3.00000000.wal"), None);
        assert_eq!(parse_wal_name("x.shardx.00000001.wal"), None);
    }

    #[test]
    fn append_read_roundtrip_preserves_order_and_bits() {
        let dir = tmp_dir("roundtrip");
        let mut w = WalWriter::open(&dir, "sig_a", 0, key(), DEFAULT_SEGMENT_CAP, 1).unwrap();
        assert_eq!(w.append(WAL_OP_INSERT, 7, &[1.5, -2.25, 3.125]).unwrap(), 1);
        assert_eq!(w.append(WAL_OP_DELETE, 7, &[]).unwrap(), 2);
        assert_eq!(w.append(WAL_OP_INSERT, 9, &[f64::MIN_POSITIVE, -0.0]).unwrap(), 3);
        w.sync().unwrap();
        assert_eq!(w.seq(), 3);
        let stream = read_lane(&lane_files(&dir, "sig_a", 0)).unwrap().unwrap();
        assert_eq!(stream.shard, 0);
        assert_eq!(stream.key_bytes, key());
        assert_eq!(stream.records.len(), 3);
        assert_eq!(stream.records[0].payload, vec![1.5, -2.25, 3.125]);
        assert_eq!(stream.records[1].op, WAL_OP_DELETE);
        assert!(stream.records[1].payload.is_empty());
        assert_eq!(stream.records[2].payload[1].to_bits(), (-0.0f64).to_bits());
        assert_eq!(stream.torn_bytes, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rotation_splits_segments_and_replay_spans_them() {
        let dir = tmp_dir("rotate");
        // Cap small enough that every record rotates after the first.
        let mut w = WalWriter::open(&dir, "sig_r", 1, key(), 64, 1).unwrap();
        for i in 0..10u64 {
            w.append(WAL_OP_INSERT, i, &[i as f64; 4]).unwrap();
        }
        w.sync().unwrap();
        let files = lane_files(&dir, "sig_r", 1);
        assert!(files.len() > 1, "size cap must rotate, got {} segment(s)", files.len());
        let stream = read_lane(&files).unwrap().unwrap();
        assert_eq!(stream.records.len(), 10);
        assert_eq!(stream.segments, files.len());
        for (i, r) in stream.records.iter().enumerate() {
            assert_eq!(r.seq, i as u64 + 1);
            assert_eq!(r.id, i as u64);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_at_every_byte_offset_recovers_exact_prefix() {
        // Satellite contract: truncate a segment at EVERY byte offset of
        // the final record and assert replay recovers exactly the
        // records before it.
        let dir = tmp_dir("torn");
        let mut w = WalWriter::open(&dir, "sig_t", 0, key(), DEFAULT_SEGMENT_CAP, 1).unwrap();
        for i in 0..4u64 {
            w.append(WAL_OP_INSERT, i, &[i as f64, 0.5]).unwrap();
        }
        w.sync().unwrap();
        drop(w);
        let files = lane_files(&dir, "sig_t", 0);
        assert_eq!(files.len(), 1);
        let path = files[0].1.clone();
        let full = std::fs::read(&path).unwrap();
        let frame_len = (4 + BODY_FIXED + 2 * 8 + 8) as u64;
        let final_start = full.len() as u64 - frame_len;
        for cut in final_start..full.len() as u64 {
            std::fs::write(&path, &full[..cut as usize]).unwrap();
            let stream = read_lane(&files).unwrap().unwrap();
            assert_eq!(
                stream.records.len(),
                3,
                "cut at byte {cut}: exactly the prefix before the torn record"
            );
            assert_eq!(stream.records.last().unwrap().seq, 3);
            assert_eq!(stream.torn_bytes, cut - final_start);
        }
        // Untruncated file still replays all 4.
        std::fs::write(&path, &full).unwrap();
        assert_eq!(read_lane(&files).unwrap().unwrap().records.len(), 4);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checksum_bad_record_with_valid_followers_is_rejected_loudly() {
        // Satellite contract: a complete frame with a bad checksum is
        // corruption, not a torn tail — replay must refuse, not skip.
        let dir = tmp_dir("badsum");
        let mut w = WalWriter::open(&dir, "sig_c", 0, key(), DEFAULT_SEGMENT_CAP, 1).unwrap();
        for i in 0..3u64 {
            w.append(WAL_OP_INSERT, i, &[1.0, 2.0]).unwrap();
        }
        w.sync().unwrap();
        drop(w);
        let files = lane_files(&dir, "sig_c", 0);
        let path = files[0].1.clone();
        let mut bytes = std::fs::read(&path).unwrap();
        let frame_len = 4 + BODY_FIXED + 2 * 8 + 8;
        // Flip one payload byte of the SECOND record (valid record after
        // it): checksum must catch it and the error must be loud.
        let second_body = bytes.len() - 2 * frame_len + 4;
        bytes[second_body + BODY_FIXED + 3] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = read_lane(&files).unwrap_err();
        assert!(err.contains("checksum mismatch"), "loud rejection, got: {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopen_after_torn_tail_truncates_and_continues_seq() {
        let dir = tmp_dir("reopen");
        let mut w = WalWriter::open(&dir, "sig_o", 2, key(), DEFAULT_SEGMENT_CAP, 1).unwrap();
        for i in 0..5u64 {
            w.append(WAL_OP_INSERT, i, &[i as f64]).unwrap();
        }
        w.sync().unwrap();
        drop(w);
        let files = lane_files(&dir, "sig_o", 2);
        let path = files[0].1.clone();
        let full = std::fs::read(&path).unwrap();
        // Tear the final record in half.
        std::fs::write(&path, &full[..full.len() - 7]).unwrap();
        let mut w = WalWriter::open(&dir, "sig_o", 2, key(), DEFAULT_SEGMENT_CAP, 1).unwrap();
        assert_eq!(w.seq(), 4, "torn record 5 truncated away");
        assert_eq!(w.append(WAL_OP_DELETE, 9, &[]).unwrap(), 5, "seq continues after the cut");
        w.sync().unwrap();
        let stream = read_lane(&lane_files(&dir, "sig_o", 2)).unwrap().unwrap();
        assert_eq!(stream.records.len(), 5);
        assert_eq!(stream.records[4].op, WAL_OP_DELETE);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reset_clears_the_lane_and_keeps_seq_monotonic() {
        let dir = tmp_dir("reset");
        let mut w = WalWriter::open(&dir, "sig_x", 0, key(), 64, 1).unwrap();
        for i in 0..6u64 {
            w.append(WAL_OP_INSERT, i, &[2.0; 3]).unwrap();
        }
        w.sync().unwrap();
        assert!(lane_files(&dir, "sig_x", 0).len() > 1);
        w.reset().unwrap();
        let files = lane_files(&dir, "sig_x", 0);
        assert_eq!(files.len(), 1, "reset leaves exactly one fresh segment");
        assert_eq!(w.append(WAL_OP_INSERT, 50, &[1.0]).unwrap(), 7, "seq never regresses");
        w.sync().unwrap();
        let stream = read_lane(&lane_files(&dir, "sig_x", 0)).unwrap().unwrap();
        assert_eq!(stream.records.len(), 1);
        assert_eq!(stream.first_seq, 7);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncate_covered_deletes_only_covered_segments() {
        let dir = tmp_dir("truncate");
        let mut w = WalWriter::open(&dir, "sig_k", 0, key(), 64, 1).unwrap();
        for i in 0..8u64 {
            w.append(WAL_OP_INSERT, i, &[3.0; 3]).unwrap();
        }
        w.sync().unwrap();
        let before = lane_files(&dir, "sig_k", 0).len();
        assert!(before >= 3, "need several segments, got {before}");
        // Watermark in the middle: early segments go, the tail stays.
        let deleted = w.truncate_covered(4).unwrap();
        assert!(deleted >= 1);
        let stream = read_lane(&lane_files(&dir, "sig_k", 0)).unwrap().unwrap();
        assert_eq!(stream.records.last().unwrap().seq, 8, "uncovered tail survives");
        assert!(stream.records[0].seq > 1, "covered head was truncated");
        assert!(stream.records[0].seq <= 5, "no uncovered record may be dropped");
        // Full coverage: everything goes, lane stays appendable.
        let _ = w.truncate_covered(8).unwrap();
        let stream = read_lane(&lane_files(&dir, "sig_k", 0)).unwrap().unwrap();
        assert!(stream.records.is_empty());
        assert_eq!(w.append(WAL_OP_INSERT, 99, &[1.0]).unwrap(), 9);
        w.sync().unwrap();
        let stream = read_lane(&lane_files(&dir, "sig_k", 0)).unwrap().unwrap();
        assert_eq!(stream.records.len(), 1);
        assert_eq!(stream.records[0].seq, 9);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_header_final_segment_is_dropped_not_fatal() {
        let dir = tmp_dir("tornhead");
        let mut w = WalWriter::open(&dir, "sig_h", 0, key(), 64, 1).unwrap();
        for i in 0..4u64 {
            w.append(WAL_OP_INSERT, i, &[4.0; 3]).unwrap();
        }
        w.sync().unwrap();
        drop(w);
        let files = lane_files(&dir, "sig_h", 0);
        let last_seg = files.last().unwrap().0;
        // Simulate a crash inside rotation: successor exists but only a
        // header prefix was written.
        let torn = dir.join(wal_file_name("sig_h", 0, last_seg + 1));
        std::fs::write(&torn, &WAL_MAGIC[..5]).unwrap();
        let all = lane_files(&dir, "sig_h", 0);
        let stream = read_lane(&all).unwrap().unwrap();
        assert_eq!(stream.records.len(), 4, "torn-header segment contributes nothing");
        let mut w = WalWriter::open(&dir, "sig_h", 0, key(), 64, 1).unwrap();
        assert_eq!(w.seq(), 4);
        assert!(!torn.exists(), "reopen deletes the torn-header segment");
        assert_eq!(w.append(WAL_OP_INSERT, 9, &[1.0]).unwrap(), 5);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cross_segment_seq_gap_is_rejected() {
        let dir = tmp_dir("gap");
        let mut w = WalWriter::open(&dir, "sig_g", 0, key(), 64, 1).unwrap();
        for i in 0..6u64 {
            w.append(WAL_OP_INSERT, i, &[5.0; 3]).unwrap();
        }
        w.sync().unwrap();
        drop(w);
        let files = lane_files(&dir, "sig_g", 0);
        assert!(files.len() >= 3);
        // Delete a MIDDLE segment: replay must refuse, not bridge the gap.
        std::fs::remove_file(&files[1].1).unwrap();
        let err = read_lane(&lane_files(&dir, "sig_g", 0)).unwrap_err();
        assert!(err.contains("previous segment ended"), "got: {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bad_magic_is_loud_everywhere() {
        let dir = tmp_dir("magic");
        let mut w = WalWriter::open(&dir, "sig_m", 0, key(), DEFAULT_SEGMENT_CAP, 1).unwrap();
        w.append(WAL_OP_INSERT, 1, &[1.0]).unwrap();
        w.sync().unwrap();
        drop(w);
        let files = lane_files(&dir, "sig_m", 0);
        let mut bytes = std::fs::read(&files[0].1).unwrap();
        bytes[0] ^= 0xFF;
        std::fs::write(&files[0].1, &bytes).unwrap();
        assert!(read_lane(&files).unwrap_err().contains("bad magic"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_or_torn_only_lane_reads_as_none() {
        let dir = tmp_dir("none");
        assert!(read_lane(&[]).unwrap().is_none());
        let torn = dir.join(wal_file_name("sig_n", 0, 1));
        std::fs::write(&torn, b"TRP").unwrap();
        let files = lane_files(&dir, "sig_n", 0);
        assert!(read_lane(&files).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn verify_dir_reports_lanes_and_corruption() {
        let dir = tmp_dir("verify");
        for shard in 0..2u32 {
            let mut w = WalWriter::open(&dir, "sig_v", shard, key(), 64, 1).unwrap();
            for i in 0..5u64 {
                w.append(WAL_OP_INSERT, i, &[6.0; 3]).unwrap();
            }
            w.sync().unwrap();
        }
        let reports = verify_dir(&dir).unwrap();
        assert_eq!(reports.len(), 1);
        assert_eq!(reports[0].stem, "sig_v");
        assert_eq!(reports[0].lanes.len(), 2);
        assert!(reports[0].error.is_none());
        assert_eq!(reports[0].key_bytes, key());
        for lane in &reports[0].lanes {
            assert_eq!(lane.records, 5);
            assert_eq!(lane.last_seq, 5);
            assert_eq!(lane.torn_bytes, 0);
            assert!(lane.bytes > 0);
        }
        // Corrupt one lane: verify still reports, with a loud error.
        let files = lane_files(&dir, "sig_v", 1);
        let mut bytes = std::fs::read(&files[0].1).unwrap();
        let n = bytes.len();
        bytes[n - 10] ^= 0xFF;
        std::fs::write(&files[0].1, &bytes).unwrap();
        let reports = verify_dir(&dir).unwrap();
        assert!(reports[0].error.is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scan_dir_of_missing_directory_is_empty() {
        let dir = std::env::temp_dir().join("trp_wal_unit_never_created");
        assert!(scan_dir(&dir).unwrap().is_empty());
    }
}
