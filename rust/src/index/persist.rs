//! Versioned, checksummed binary snapshots of one signature's ANN index.
//!
//! What is stored versus re-derived mirrors the projection maps'
//! durability model: a snapshot holds only what cannot be re-derived —
//! the live `id → vector` pairs plus the backend identity (kind, LSH
//! shape, hyperplane seed). LSH buckets are deliberately NOT serialized:
//! they re-derive from the seeded hyperplanes when the items are
//! re-inserted on load, exactly as the projection maps re-derive from
//! `(master_seed, map key)` on restart (`coordinator::ProjectionRegistry`).
//!
//! The signature itself travels as an opaque byte string encoded by the
//! caller (`coordinator::state::MapKey::encode`), so this module stays
//! below the coordinator in the layering.
//!
//! On-disk layout (all integers little-endian):
//!
//! ```text
//! magic  b"TRPSNAP\0"                       8 bytes
//! version u32                               currently 2
//! key_len u32, key bytes                    opaque signature encoding
//! backend u8                                0 = flat, 1 = lsh
//! tables u64, bits u64, probes u64          LSH shape (zeros for flat)
//! seed u64                                  LSH hyperplane seed
//! dim u64                                   embedding dimension k
//! inserts u64, deletes u64, queries u64     lifetime stats counters (v2+)
//! count u64                                 live item count
//! count × (id u64, dim × f64)               items in capture order
//! checksum u64                              FNV-1a over all prior bytes
//! ```
//!
//! Version 1 files (no counter block) still decode — their counters read
//! as `(live count, 0, 0)`, exactly the totals a v1-era restore rebuild
//! produced.
//!
//! Files are written atomically (temp file + rename), so a crash mid-
//! snapshot leaves the previous snapshot intact rather than a torn file.

use super::{build_index, AnnIndex, BackendKind, LshConfig};
use std::path::Path;

/// File magic: identifies a TRP index snapshot.
const MAGIC: &[u8; 8] = b"TRPSNAP\0";
/// Current format version (2 added the stats-counter block).
const VERSION: u32 = 2;
/// Oldest version this build still decodes.
const MIN_VERSION: u32 = 1;

/// Where a snapshot was written and what it covered (returned inside
/// `snapshot` responses and by the registry API).
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotReport {
    /// Snapshot file path.
    pub path: String,
    /// Live items captured.
    pub items: u64,
    /// Encoded size in bytes.
    pub bytes: u64,
}

/// An in-memory snapshot of one signature's index: everything needed to
/// rebuild it bit-identically (buckets re-derive; see module docs).
pub struct IndexSnapshot {
    /// Opaque signature encoding (the coordinator's `MapKey::encode`).
    pub key_bytes: Vec<u8>,
    /// Backend to rebuild.
    pub backend: BackendKind,
    /// LSH shape (ignored by the flat backend).
    pub lsh: LshConfig,
    /// LSH hyperplane seed (ignored by the flat backend).
    pub seed: u64,
    /// Embedding dimension.
    pub dim: usize,
    /// Lifetime insert counter at capture time.
    pub inserts: u64,
    /// Lifetime effective-delete counter at capture time.
    pub deletes: u64,
    /// Lifetime query counter at capture time.
    pub queries: u64,
    /// Live `id → vector` pairs in capture order.
    pub items: Vec<(u64, Vec<f64>)>,
}

impl IndexSnapshot {
    /// Capture the live contents of `index` under the given signature
    /// encoding. The caller must hold whatever ordering guarantee makes
    /// this a consistent cut (the coordinator captures inside the
    /// signature's FIFO sequencer turn).
    pub fn capture(key_bytes: Vec<u8>, index: &dyn AnnIndex) -> Self {
        let (backend, lsh, seed) = index.persist_spec();
        let stats = index.stats();
        let mut items = Vec::with_capacity(index.len());
        index.for_each_live(&mut |id, v| items.push((id, v.to_vec())));
        Self {
            key_bytes,
            backend,
            lsh,
            seed,
            dim: index.dim(),
            inserts: stats.inserts,
            deletes: stats.deletes,
            queries: stats.queries,
            items,
        }
    }

    /// Rebuild the index: construct the stored backend empty, re-insert
    /// every item in capture order, then restore the captured stats
    /// counters (re-insertion's own increments are an artifact of the
    /// rebuild, not served traffic). Queries against the result are
    /// bit-identical to the captured index (distances are per-slot
    /// arithmetic and the top-k order is total, so slot renumbering from
    /// tombstone compaction cannot change any result).
    pub fn build(&self) -> Box<dyn AnnIndex> {
        let mut index = build_index(self.backend, self.dim, &self.lsh, self.seed);
        for (id, v) in &self.items {
            index.insert(*id, v);
        }
        index.restore_counters(self.inserts, self.deletes, self.queries);
        index
    }

    /// Serialize to the versioned, checksummed binary format.
    pub fn encode(&self) -> Vec<u8> {
        let cap = 64 + self.key_bytes.len() + self.items.len() * (8 + self.dim * 8);
        let mut out = Vec::with_capacity(cap);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.key_bytes.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.key_bytes);
        out.push(match self.backend {
            BackendKind::Flat => 0,
            BackendKind::Lsh => 1,
        });
        out.extend_from_slice(&(self.lsh.tables as u64).to_le_bytes());
        out.extend_from_slice(&(self.lsh.bits as u64).to_le_bytes());
        out.extend_from_slice(&(self.lsh.probes as u64).to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&(self.dim as u64).to_le_bytes());
        out.extend_from_slice(&self.inserts.to_le_bytes());
        out.extend_from_slice(&self.deletes.to_le_bytes());
        out.extend_from_slice(&self.queries.to_le_bytes());
        out.extend_from_slice(&(self.items.len() as u64).to_le_bytes());
        for (id, v) in &self.items {
            out.extend_from_slice(&id.to_le_bytes());
            debug_assert_eq!(v.len(), self.dim);
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        let sum = fnv1a(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Parse and validate (magic, version, checksum, exact length).
    pub fn decode(bytes: &[u8]) -> Result<Self, String> {
        if bytes.len() < MAGIC.len() + 4 + 8 {
            return Err("snapshot truncated".into());
        }
        let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(sum_bytes.try_into().unwrap());
        if fnv1a(body) != stored {
            return Err("snapshot checksum mismatch (corrupt or torn file)".into());
        }
        let mut cur = Cursor::new(body);
        if cur.take(MAGIC.len())? != MAGIC {
            return Err("not a TRP index snapshot (bad magic)".into());
        }
        let version = cur.u32()?;
        if !(MIN_VERSION..=VERSION).contains(&version) {
            return Err(format!(
                "unsupported snapshot version {version} (expected {MIN_VERSION}..={VERSION})"
            ));
        }
        let key_len = cur.u32()? as usize;
        let key_bytes = cur.take(key_len)?.to_vec();
        let backend = match cur.u8()? {
            0 => BackendKind::Flat,
            1 => BackendKind::Lsh,
            other => return Err(format!("unknown backend tag {other}")),
        };
        let lsh = LshConfig {
            tables: cur.u64()? as usize,
            bits: cur.u64()? as usize,
            probes: cur.u64()? as usize,
        };
        let seed = cur.u64()?;
        let dim = cur.u64()? as usize;
        if dim == 0 {
            return Err("snapshot dim must be positive".into());
        }
        // Reject shapes [`build`] could not construct: `LshIndex::new`
        // asserts these, and a panic during restore would either abort
        // startup or wedge a sequencer lane instead of returning an error.
        if backend == BackendKind::Lsh && (lsh.tables < 1 || !(1..=63).contains(&lsh.bits)) {
            return Err(format!(
                "snapshot LSH shape invalid (tables {}, bits {})",
                lsh.tables, lsh.bits
            ));
        }
        // v1 files predate the counter block; resolved after `count` is
        // known (a v1-era rebuild counted one insert per live item).
        let counters = if version >= 2 {
            Some((cur.u64()?, cur.u64()?, cur.u64()?))
        } else {
            None
        };
        let count = cur.u64()? as usize;
        let mut items = Vec::with_capacity(count.min(1 << 20));
        for _ in 0..count {
            let id = cur.u64()?;
            let mut v = Vec::with_capacity(dim);
            for _ in 0..dim {
                v.push(f64::from_le_bytes(cur.take(8)?.try_into().unwrap()));
            }
            items.push((id, v));
        }
        if cur.pos != body.len() {
            return Err("snapshot has trailing bytes".into());
        }
        // v1 restores left the counters at the rebuild's own re-insert
        // totals (`restore_counters` didn't exist); reproduce that rather
        // than inventing an impossible inserts=0-with-items state.
        let (inserts, deletes, queries) = counters.unwrap_or((items.len() as u64, 0, 0));
        Ok(Self { key_bytes, backend, lsh, seed, dim, inserts, deletes, queries, items })
    }

    /// Write atomically and durably: encode to `<path>.tmp`, fsync it,
    /// rename over `path`, then fsync the parent directory so the rename
    /// itself survives a crash. Returns the encoded size in bytes.
    pub fn write_atomic(&self, path: &Path) -> Result<u64, String> {
        let bytes = self.encode();
        write_bytes_atomic(path, &bytes)?;
        Ok(bytes.len() as u64)
    }

    /// Read and validate a snapshot file.
    pub fn read(path: &Path) -> Result<Self, String> {
        let bytes = std::fs::read(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        Self::decode(&bytes)
    }
}

/// Atomic + durable byte-level file write shared by snapshot files and
/// shard manifests: write `<path>.tmp`, fsync, rename over `path`, fsync
/// the parent directory. Every fsync failure propagates — a durability
/// claim that swallows the directory sync is a silent lie after a crash
/// (the rename itself may not have reached disk).
pub(crate) fn write_bytes_atomic(path: &Path, bytes: &[u8]) -> Result<(), String> {
    use std::io::Write as _;
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    {
        let mut f = std::fs::File::create(&tmp)
            .map_err(|e| format!("create {}: {e}", tmp.display()))?;
        f.write_all(bytes)
            .map_err(|e| format!("write {}: {e}", tmp.display()))?;
        f.sync_all().map_err(|e| format!("sync {}: {e}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path).map_err(|e| format!("rename to {}: {e}", path.display()))?;
    // A bare relative file name has `parent() == Some("")`; "." is what
    // that actually means to the filesystem.
    if let Some(dir) = path.parent() {
        let dir = if dir.as_os_str().is_empty() { Path::new(".") } else { dir };
        let d = std::fs::File::open(dir)
            .map_err(|e| format!("open dir {}: {e}", dir.display()))?;
        d.sync_all().map_err(|e| format!("sync dir {}: {e}", dir.display()))?;
    }
    Ok(())
}

/// One shard file's manifest entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestShard {
    /// Shard file name, relative to the manifest's directory.
    pub file: String,
    /// Live items in the shard file.
    pub items: u64,
    /// FNV-1a over the shard file's complete bytes — detects a torn or
    /// swapped shard file even though each shard file also self-checks.
    pub checksum: u64,
}

/// The root of a sharded snapshot: names every shard file of one capture
/// sequence with its item count and whole-file checksum. Written last
/// (after every shard file's atomic rename), so a crash mid-snapshot
/// leaves orphan shard files but never a manifest pointing at missing or
/// half-written data; restore only trusts sequences whose manifest reads
/// back clean.
///
/// On-disk layout (little-endian, FNV-1a checksummed like snapshots):
///
/// ```text
/// magic  b"TRPMANI\0"                      8 bytes
/// version u32                              1 or 2
/// key_len u32, key bytes                   opaque signature encoding
/// shard_count u64
/// shard_count × (file_len u32, file bytes, items u64, checksum u64)
/// mark_count u64, mark_count × u64         WAL watermarks (v2 only)
/// checksum u64                             FNV-1a over all prior bytes
/// ```
///
/// Version 2 adds the per-lane WAL covered watermarks: this capture
/// includes every logged op with `seq ≤ wal_marks[lane]`, so replay
/// starts above them and fully covered segments may be truncated once
/// the manifest rename is durable. A WAL-less coordinator writes v1 —
/// byte-identical to pre-WAL builds — and v1 files decode with empty
/// marks (nothing covered: replay everything).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardManifest {
    /// Opaque signature encoding (the coordinator's `MapKey::encode`).
    pub key_bytes: Vec<u8>,
    /// Per-shard entries in shard order.
    pub shards: Vec<ManifestShard>,
    /// Per-lane WAL covered watermarks (empty when the WAL is off).
    pub wal_marks: Vec<u64>,
}

/// Manifest file magic.
const MANIFEST_MAGIC: &[u8; 8] = b"TRPMANI\0";
/// Manifest format version without WAL watermarks.
const MANIFEST_VERSION: u32 = 1;
/// Manifest format version carrying WAL watermarks.
const MANIFEST_VERSION_WAL: u32 = 2;

impl ShardManifest {
    /// Total live items across all shard files.
    pub fn total_items(&self) -> u64 {
        self.shards.iter().map(|s| s.items).sum()
    }

    /// Serialize to the versioned, checksummed binary format.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            32 + self.key_bytes.len()
                + self.shards.iter().map(|s| 20 + s.file.len()).sum::<usize>(),
        );
        out.extend_from_slice(MANIFEST_MAGIC);
        // v1 stays byte-identical when the WAL is off, so WAL-less
        // deployments produce files older builds still read.
        let version =
            if self.wal_marks.is_empty() { MANIFEST_VERSION } else { MANIFEST_VERSION_WAL };
        out.extend_from_slice(&version.to_le_bytes());
        out.extend_from_slice(&(self.key_bytes.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.key_bytes);
        out.extend_from_slice(&(self.shards.len() as u64).to_le_bytes());
        for s in &self.shards {
            out.extend_from_slice(&(s.file.len() as u32).to_le_bytes());
            out.extend_from_slice(s.file.as_bytes());
            out.extend_from_slice(&s.items.to_le_bytes());
            out.extend_from_slice(&s.checksum.to_le_bytes());
        }
        if version >= MANIFEST_VERSION_WAL {
            out.extend_from_slice(&(self.wal_marks.len() as u64).to_le_bytes());
            for m in &self.wal_marks {
                out.extend_from_slice(&m.to_le_bytes());
            }
        }
        let sum = fnv1a(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Parse and validate (magic, version, checksum, exact length).
    pub fn decode(bytes: &[u8]) -> Result<Self, String> {
        if bytes.len() < MANIFEST_MAGIC.len() + 4 + 8 {
            return Err("manifest truncated".into());
        }
        let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(sum_bytes.try_into().unwrap());
        if fnv1a(body) != stored {
            return Err("manifest checksum mismatch (corrupt or torn file)".into());
        }
        let mut cur = Cursor::new(body);
        if cur.take(MANIFEST_MAGIC.len())? != MANIFEST_MAGIC {
            return Err("not a TRP shard manifest (bad magic)".into());
        }
        let version = cur.u32()?;
        if !(MANIFEST_VERSION..=MANIFEST_VERSION_WAL).contains(&version) {
            return Err(format!(
                "unsupported manifest version {version} \
                 (expected {MANIFEST_VERSION}..={MANIFEST_VERSION_WAL})"
            ));
        }
        let key_len = cur.u32()? as usize;
        let key_bytes = cur.take(key_len)?.to_vec();
        let count = cur.u64()? as usize;
        if count == 0 {
            return Err("manifest names zero shard files".into());
        }
        let mut shards = Vec::with_capacity(count.min(1 << 16));
        for _ in 0..count {
            let file_len = cur.u32()? as usize;
            let file = String::from_utf8(cur.take(file_len)?.to_vec())
                .map_err(|_| "manifest shard file name is not UTF-8".to_string())?;
            let items = cur.u64()?;
            let checksum = cur.u64()?;
            shards.push(ManifestShard { file, items, checksum });
        }
        let mut wal_marks = Vec::new();
        if version >= MANIFEST_VERSION_WAL {
            let mark_count = cur.u64()? as usize;
            wal_marks.reserve(mark_count.min(1 << 16));
            for _ in 0..mark_count {
                wal_marks.push(cur.u64()?);
            }
        }
        if cur.pos != body.len() {
            return Err("manifest has trailing bytes".into());
        }
        Ok(Self { key_bytes, shards, wal_marks })
    }

    /// Write atomically (see [`write_bytes_atomic`]). Returns encoded
    /// size in bytes.
    pub fn write_atomic(&self, path: &Path) -> Result<u64, String> {
        let bytes = self.encode();
        write_bytes_atomic(path, &bytes)?;
        Ok(bytes.len() as u64)
    }

    /// Read and validate a manifest file.
    pub fn read(path: &Path) -> Result<Self, String> {
        let bytes = std::fs::read(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        Self::decode(&bytes)
    }
}

/// FNV-1a over a byte string (the same family the registry's key seeding
/// uses; collisions are irrelevant here — this only detects corruption).
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h
}

/// Bounds-checked little-endian reader, shared by the snapshot decoder
/// and the coordinator's `MapKey` codec (one implementation of the
/// truncation/overflow handling, not two that can drift).
pub(crate) struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    /// Reader over `bytes`, starting at offset 0.
    pub(crate) fn new(bytes: &'a [u8]) -> Self {
        Self { bytes, pos: 0 }
    }

    /// Consume and return the next `n` bytes.
    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self.pos.checked_add(n).ok_or("length overflow")?;
        if end > self.bytes.len() {
            return Err("unexpected end of input".into());
        }
        let s = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    /// Consume one byte.
    pub(crate) fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    /// Consume a little-endian u32.
    pub(crate) fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Consume a little-endian u64.
    pub(crate) fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Current read offset (for exact-length / trailing-byte checks by
    /// decoders outside this module).
    pub(crate) fn pos(&self) -> usize {
        self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::{FlatIndex, LshIndex};
    use crate::projections::Workspace;
    use crate::rng::Rng;

    fn sample_flat() -> FlatIndex {
        let mut rng = Rng::seed_from(1);
        let mut idx = FlatIndex::new(6);
        for i in 0..17u64 {
            idx.insert(i, &rng.gaussian_vec(6, 1.0));
        }
        idx.remove(4);
        idx.remove(9);
        idx
    }

    #[test]
    fn roundtrip_is_lossless() {
        let idx = sample_flat();
        let snap = IndexSnapshot::capture(vec![1, 2, 3], &idx);
        assert_eq!(snap.items.len(), 15, "tombstones are not captured");
        let back = IndexSnapshot::decode(&snap.encode()).unwrap();
        assert_eq!(back.key_bytes, vec![1, 2, 3]);
        assert_eq!(back.backend, BackendKind::Flat);
        assert_eq!(back.dim, 6);
        assert_eq!(back.items, snap.items, "vectors must round-trip bit-exactly");
    }

    #[test]
    fn rebuilt_index_answers_bit_identically() {
        let mut rng = Rng::seed_from(2);
        let dim = 8;
        let cfg = LshConfig { tables: 4, bits: 6, probes: 2 };
        let mut idx = LshIndex::new(dim, cfg, 77);
        for i in 0..40u64 {
            idx.insert(i, &rng.gaussian_vec(dim, 1.0));
        }
        idx.remove(7);
        let snap = IndexSnapshot::capture(Vec::new(), &idx);
        assert_eq!(snap.backend, BackendKind::Lsh);
        assert_eq!(snap.seed, 77, "hyperplane seed travels in the header");
        let mut rebuilt = snap.build();
        let mut ws = Workspace::new();
        for _ in 0..6 {
            let q = rng.gaussian_vec(dim, 1.0);
            assert_eq!(
                idx.query(&q, 5, &mut ws),
                rebuilt.query(&q, 5, &mut ws),
                "restored index must answer bit-identically"
            );
        }
    }

    #[test]
    fn checksum_corruption_is_rejected() {
        let snap = IndexSnapshot::capture(vec![9], &sample_flat());
        let mut bytes = snap.encode();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let err = IndexSnapshot::decode(&bytes).unwrap_err();
        assert!(err.contains("checksum"), "got: {err}");
    }

    #[test]
    fn truncation_is_rejected() {
        let snap = IndexSnapshot::capture(Vec::new(), &sample_flat());
        let bytes = snap.encode();
        for cut in [0, 7, bytes.len() / 2, bytes.len() - 1] {
            assert!(IndexSnapshot::decode(&bytes[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn bad_magic_and_version_are_rejected() {
        let snap = IndexSnapshot::capture(Vec::new(), &sample_flat());
        // Bad magic (re-checksummed so the magic check is what fires).
        let mut bytes = snap.encode();
        bytes[0] = b'X';
        let n = bytes.len();
        let sum = fnv1a(&bytes[..n - 8]).to_le_bytes();
        bytes[n - 8..].copy_from_slice(&sum);
        assert!(IndexSnapshot::decode(&bytes).unwrap_err().contains("magic"));
        // Future version (re-checksummed likewise).
        let mut bytes = snap.encode();
        bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
        let sum = fnv1a(&bytes[..n - 8]).to_le_bytes();
        bytes[n - 8..].copy_from_slice(&sum);
        assert!(IndexSnapshot::decode(&bytes).unwrap_err().contains("version"));
    }

    #[test]
    fn stats_counters_survive_capture_and_rebuild() {
        let mut rng = Rng::seed_from(9);
        let mut idx = FlatIndex::new(4);
        for i in 0..10u64 {
            idx.insert(i, &rng.gaussian_vec(4, 1.0));
        }
        idx.remove(3);
        let mut ws = Workspace::new();
        idx.query(&[0.0; 4], 2, &mut ws);
        idx.query(&[1.0, 0.0, 0.0, 0.0], 2, &mut ws);
        let snap = IndexSnapshot::capture(Vec::new(), &idx);
        assert_eq!((snap.inserts, snap.deletes, snap.queries), (10, 1, 2));
        let back = IndexSnapshot::decode(&snap.encode()).unwrap();
        assert_eq!((back.inserts, back.deletes, back.queries), (10, 1, 2));
        // Rebuild: counters equal the captured totals, not the rebuild's
        // own 9 re-inserts.
        let rebuilt = back.build();
        let s = rebuilt.stats();
        assert_eq!(s.inserts, 10, "restore must not reset the insert counter");
        assert_eq!(s.deletes, 1);
        assert_eq!(s.queries, 2);
        assert_eq!(s.len, 9);
    }

    #[test]
    fn lsh_counters_survive_rebuild() {
        let mut rng = Rng::seed_from(10);
        let cfg = LshConfig { tables: 3, bits: 5, probes: 2 };
        let mut idx = LshIndex::new(5, cfg, 11);
        for i in 0..7u64 {
            idx.insert(i, &rng.gaussian_vec(5, 1.0));
        }
        let mut ws = Workspace::new();
        idx.query(&rng.gaussian_vec(5, 1.0), 3, &mut ws);
        let rebuilt = IndexSnapshot::capture(Vec::new(), &idx).build();
        let s = rebuilt.stats();
        assert_eq!((s.inserts, s.deletes, s.queries), (7, 0, 1));
    }

    #[test]
    fn version_1_files_decode_with_rebuild_era_counters() {
        // Splice the 24-byte counter block out of a v2 file and patch the
        // version down — the layout that v1 writers produced.
        let snap = IndexSnapshot::capture(vec![1, 2, 3], &sample_flat());
        let v2 = snap.encode();
        let ctr_off = 8 + 4 + 4 + snap.key_bytes.len() + 1 + 24 + 8 + 8;
        let mut v1: Vec<u8> = Vec::new();
        v1.extend_from_slice(&v2[..ctr_off]);
        v1.extend_from_slice(&v2[ctr_off + 24..v2.len() - 8]);
        v1[8..12].copy_from_slice(&1u32.to_le_bytes());
        let sum = fnv1a(&v1).to_le_bytes();
        v1.extend_from_slice(&sum);
        let back = IndexSnapshot::decode(&v1).unwrap();
        // A v1-era restore counted one insert per re-inserted live item;
        // decoding must reproduce that, not an inserts=0-with-items state.
        let live = snap.items.len() as u64;
        assert_eq!((back.inserts, back.deletes, back.queries), (live, 0, 0));
        assert_eq!(back.build().stats().inserts, live);
        assert_eq!(back.items, snap.items, "items are unaffected by the version");
    }

    #[test]
    fn manifest_roundtrips_and_rejects_corruption() {
        let m = ShardManifest {
            key_bytes: vec![1, 2, 3],
            shards: vec![
                ManifestShard { file: "sig_ab.00000001.shard0.snap".into(), items: 7, checksum: 9 },
                ManifestShard { file: "sig_ab.00000001.shard1.snap".into(), items: 5, checksum: 4 },
            ],
            wal_marks: Vec::new(),
        };
        assert_eq!(m.total_items(), 12);
        let bytes = m.encode();
        let back = ShardManifest::decode(&bytes).unwrap();
        assert_eq!(back, m);
        // Flipped byte → checksum failure.
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x10;
        assert!(ShardManifest::decode(&bad).unwrap_err().contains("checksum"));
        // Truncations are rejected.
        for cut in [0, 9, bytes.len() / 2, bytes.len() - 1] {
            assert!(ShardManifest::decode(&bytes[..cut]).is_err(), "cut at {cut}");
        }
        // Zero shard files is not a valid capture.
        let empty =
            ShardManifest { key_bytes: Vec::new(), shards: Vec::new(), wal_marks: Vec::new() };
        assert!(ShardManifest::decode(&empty.encode()).unwrap_err().contains("zero"));
    }

    #[test]
    fn manifest_wal_marks_roundtrip_and_v1_stays_byte_stable() {
        let base = ShardManifest {
            key_bytes: vec![7],
            shards: vec![ManifestShard { file: "f0".into(), items: 3, checksum: 1 }],
            wal_marks: Vec::new(),
        };
        // Empty marks encode as v1: the version field says 1 and decoding
        // yields empty marks back.
        let v1 = base.encode();
        assert_eq!(u32::from_le_bytes(v1[8..12].try_into().unwrap()), 1);
        assert_eq!(ShardManifest::decode(&v1).unwrap(), base);
        // Non-empty marks encode as v2 and round-trip.
        let with_marks = ShardManifest { wal_marks: vec![12, 0, 99], ..base.clone() };
        let v2 = with_marks.encode();
        assert_eq!(u32::from_le_bytes(v2[8..12].try_into().unwrap()), 2);
        assert_eq!(ShardManifest::decode(&v2).unwrap(), with_marks);
        // The two encodings agree on everything but the version field and
        // the appended mark block (+ checksum): WAL-off output carries no
        // trace of the WAL feature.
        assert_eq!(&v1[..8], &v2[..8]);
        assert_eq!(&v1[12..v1.len() - 8], &v2[12..v1.len() - 8]);
        assert_eq!(v2.len(), v1.len() + 8 + 3 * 8);
    }

    #[test]
    fn manifest_write_is_atomic_and_readable() {
        let dir = std::env::temp_dir().join(format!("trp_manifest_unit_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sig_x.00000001.manifest");
        let m = ShardManifest {
            key_bytes: vec![9],
            shards: vec![ManifestShard { file: "f0".into(), items: 1, checksum: 2 }],
        };
        let bytes = m.write_atomic(&path).unwrap();
        assert_eq!(bytes, std::fs::metadata(&path).unwrap().len());
        assert_eq!(ShardManifest::read(&path).unwrap(), m);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn write_is_atomic_and_readable() {
        let dir = std::env::temp_dir().join(format!("trp_persist_unit_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sig_test.snap");
        let snap = IndexSnapshot::capture(vec![5, 5], &sample_flat());
        let bytes = snap.write_atomic(&path).unwrap();
        assert_eq!(bytes, std::fs::metadata(&path).unwrap().len());
        assert!(!path.with_extension("snap.tmp").exists());
        let back = IndexSnapshot::read(&path).unwrap();
        assert_eq!(back.items, snap.items);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
