//! Similarity-search index subsystem over projected embeddings.
//!
//! The paper's `f_TT(R)` / `f_CP(R)` maps approximately preserve Euclidean
//! distances (Johnson-Lindenstrauss), so nearest neighbours in the
//! `k`-dimensional projected space approximate nearest neighbours in the
//! (possibly astronomically large) original tensor space. This module is
//! the workload that consumes that guarantee: an in-memory ANN index keyed
//! by embedding vectors, with two backends behind one [`AnnIndex`] trait:
//!
//! * [`FlatIndex`] — exact scan over the projected vectors. Query batches
//!   are scored with one blocked GEMM (`linalg::matmul_into`) against the
//!   whole store, then reduced by an exact partial top-k select. Serves as
//!   both the production backend for modest corpora and the ground truth
//!   the LSH backend is measured against.
//! * [`LshIndex`] — random-hyperplane locality-sensitive hashing (Charikar
//!   2002) with multi-probe search (Lv et al. 2007): candidate buckets are
//!   probed in ascending hyperplane-margin order, and candidates are
//!   exactly re-scored against the stored vectors.
//!
//! The coordinator exposes the subsystem as wire ops (`insert`, `query`,
//! `delete`, `stats`) routed per map signature, so every stored embedding
//! for one index comes from the *same* deterministic projection map (see
//! `coordinator::state::IndexRegistry`). Distances returned by queries are
//! Euclidean distances **in the projected space** — within `1 ± ε` of the
//! original-space distances by the paper's Theorems 1-2.

mod flat;
mod lsh;
pub(crate) mod persist;
mod sharded;
pub mod wal;

pub use flat::FlatIndex;
pub use lsh::{LshConfig, LshIndex};
pub use persist::{IndexSnapshot, SnapshotReport};
pub use sharded::{
    combine_stats, merge_neighbors, restore_shard_counters, shard_of, ShardedIndex,
};
pub use wal::{WalConfig, WalFsync, WalWriter};

use crate::projections::Workspace;

/// One query result: a stored item and its distance to the query.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    /// Caller-assigned item id (the request id of the insert).
    pub id: u64,
    /// Euclidean distance in the projected space.
    pub dist: f64,
}

/// Point-in-time statistics of one index (the `stats` wire op payload).
#[derive(Debug, Clone, PartialEq)]
pub struct IndexStats {
    /// Backend name (`"flat"` or `"lsh"`).
    pub backend: String,
    /// Live (inserted and not deleted) item count.
    pub len: usize,
    /// Embedding dimension `k`.
    pub dim: usize,
    /// Total inserts processed.
    pub inserts: u64,
    /// Total deletes that removed an item.
    pub deletes: u64,
    /// Total queries answered.
    pub queries: u64,
    /// Occupied hash buckets across all tables (0 for flat).
    pub buckets: usize,
    /// Largest bucket population (0 for flat).
    pub max_bucket: usize,
    /// Shards aggregated into this snapshot (1 for a plain backend;
    /// [`combine_stats`] sums it).
    pub shards: usize,
    /// LSH hash tables in effect (0 for flat) — reported so auto-tuned
    /// shapes ([`LshConfig::auto`]) are observable through `stats`.
    pub tables: usize,
    /// LSH signature bits per table (0 for flat).
    pub bits: usize,
    /// LSH multi-probe depth (0 for flat).
    pub probes: usize,
}

/// Which ANN backend an index uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Exact GEMM scan ([`FlatIndex`]).
    Flat,
    /// Random-hyperplane LSH ([`LshIndex`]).
    Lsh,
}

impl BackendKind {
    /// Parse from the CLI / config name.
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "flat" => Some(BackendKind::Flat),
            "lsh" => Some(BackendKind::Lsh),
            _ => None,
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Flat => "flat",
            BackendKind::Lsh => "lsh",
        }
    }
}

/// An approximate-nearest-neighbour index over `R^k` embeddings.
///
/// Implementations are driven behind a mutex by the coordinator's worker
/// pool, so methods take `&mut self` and no internal locking exists.
pub trait AnnIndex: Send {
    /// Backend name (matches [`BackendKind::name`]).
    fn backend(&self) -> &'static str;

    /// Embedding dimension `k` every stored vector must have.
    fn dim(&self) -> usize;

    /// Live item count.
    fn len(&self) -> usize;

    /// True when no live items are stored.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Insert (or overwrite) item `id` with the given embedding.
    fn insert(&mut self, id: u64, embedding: &[f64]);

    /// Remove item `id`; returns whether it was present.
    fn remove(&mut self, id: u64) -> bool;

    /// Answer a batch of queries laid out row-major as `[topks.len(), k]`.
    /// `topks[j]` is the neighbour count requested by query `j`. Results
    /// are sorted by ascending distance (ties broken by ascending id) and
    /// may be shorter than `topks[j]` when fewer live items exist (or, for
    /// LSH, fewer candidates were probed).
    fn query_batch(
        &mut self,
        qs: &[f64],
        topks: &[usize],
        ws: &mut Workspace,
    ) -> Vec<Vec<Neighbor>>;

    /// Single-query convenience wrapper around [`AnnIndex::query_batch`].
    fn query(&mut self, q: &[f64], topk: usize, ws: &mut Workspace) -> Vec<Neighbor> {
        self.query_batch(q, &[topk], ws).pop().unwrap_or_default()
    }

    /// Statistics snapshot.
    fn stats(&self) -> IndexStats;

    /// Visit every live item (id, stored embedding) in a deterministic
    /// order. Drives snapshot capture ([`IndexSnapshot::capture`]).
    fn for_each_live(&self, visit: &mut dyn FnMut(u64, &[f64]));

    /// Backend identity + config needed to rebuild this index empty:
    /// `(kind, lsh shape, hyperplane seed)`. Stored in snapshot headers
    /// so a restore re-derives the LSH buckets instead of serializing
    /// them (the flat backend reports an all-zero LSH shape and seed).
    fn persist_spec(&self) -> (BackendKind, LshConfig, u64);

    /// Overwrite the lifetime stats counters. Snapshot restore calls this
    /// after re-inserting the captured items, so the rebuild's own insert
    /// increments are replaced by the captured totals instead of counters
    /// silently resetting to the corpus size. Default: no-op (an index
    /// without counters has nothing to restore).
    fn restore_counters(&mut self, inserts: u64, deletes: u64, queries: u64) {
        let _ = (inserts, deletes, queries);
    }
}

/// Construct a boxed index of the requested backend.
///
/// `seed` only matters for the LSH backend (it draws the hash hyperplanes
/// from the same deterministic rng stack as the projection maps, so a
/// restarted coordinator reproduces identical bucket assignments).
pub fn build_index(
    kind: BackendKind,
    dim: usize,
    lsh: &LshConfig,
    seed: u64,
) -> Box<dyn AnnIndex> {
    match kind {
        BackendKind::Flat => Box::new(FlatIndex::new(dim)),
        BackendKind::Lsh => Box::new(LshIndex::new(dim, *lsh, seed)),
    }
}

/// The `(dist, id)` total order shared by the per-shard top-k selects
/// ([`TopK`]) and the scatter-gather merge ([`merge_neighbors`]).
/// `total_cmp` (not `<`/`==`) keeps the order total under NaN distances,
/// so a poisoned query still selects deterministically — and having
/// exactly one definition is what keeps sharded gathers bit-identical to
/// unsharded selects on tied distances.
pub(crate) fn neighbor_order(a: &Neighbor, b: &Neighbor) -> std::cmp::Ordering {
    a.dist.total_cmp(&b.dist).then(a.id.cmp(&b.id))
}

/// Bounded partial top-k select over `(dist, id)` candidates: keeps the
/// `cap` smallest under [`neighbor_order`], sorted ascending. O(cap)
/// memory and O(log cap + cap) per accepted offer — the "partial select"
/// half of the flat backend's scan.
#[derive(Debug)]
pub(crate) struct TopK {
    cap: usize,
    entries: Vec<Neighbor>,
}

impl TopK {
    /// New selector keeping at most `cap` entries.
    pub(crate) fn new(cap: usize) -> Self {
        Self { cap, entries: Vec::with_capacity(cap.min(1024)) }
    }

    /// True when `a` strictly precedes `b` under [`neighbor_order`].
    fn precedes(a_dist: f64, a_id: u64, b: &Neighbor) -> bool {
        neighbor_order(&Neighbor { id: a_id, dist: a_dist }, b) == std::cmp::Ordering::Less
    }

    /// Offer one candidate.
    pub(crate) fn offer(&mut self, id: u64, dist: f64) {
        if self.cap == 0 {
            return;
        }
        if self.entries.len() == self.cap {
            let worst = self.entries.last().expect("cap > 0");
            if !Self::precedes(dist, id, worst) {
                return;
            }
            self.entries.pop();
        }
        let pos = self
            .entries
            .partition_point(|e| !Self::precedes(dist, id, e));
        self.entries.insert(pos, Neighbor { id, dist });
    }

    /// The selected entries, ascending by (dist, id).
    pub(crate) fn into_sorted(self) -> Vec<Neighbor> {
        self.entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_keeps_smallest_sorted() {
        let mut sel = TopK::new(3);
        for (id, dist) in [(1u64, 5.0), (2, 1.0), (3, 3.0), (4, 0.5), (5, 4.0)] {
            sel.offer(id, dist);
        }
        let out = sel.into_sorted();
        let ids: Vec<u64> = out.iter().map(|n| n.id).collect();
        assert_eq!(ids, vec![4, 2, 3]);
        assert!(out.windows(2).all(|w| w[0].dist <= w[1].dist));
    }

    #[test]
    fn topk_ties_break_by_id() {
        let mut sel = TopK::new(2);
        sel.offer(9, 1.0);
        sel.offer(3, 1.0);
        sel.offer(7, 1.0);
        let ids: Vec<u64> = sel.into_sorted().iter().map(|n| n.id).collect();
        assert_eq!(ids, vec![3, 7]);
    }

    #[test]
    fn topk_orders_nan_distances_deterministically() {
        // total_cmp places NaN after every finite distance, so a NaN
        // candidate never displaces a real neighbour and repeated runs
        // agree exactly.
        let run = || {
            let mut sel = TopK::new(3);
            sel.offer(1, f64::NAN);
            sel.offer(2, 1.0);
            sel.offer(3, 0.5);
            sel.offer(4, f64::NAN);
            sel.into_sorted().iter().map(|n| n.id).collect::<Vec<u64>>()
        };
        assert_eq!(run(), vec![3, 2, 1]);
        assert_eq!(run(), run());
    }

    #[test]
    fn topk_cap_zero_is_empty() {
        let mut sel = TopK::new(0);
        sel.offer(1, 1.0);
        assert!(sel.into_sorted().is_empty());
    }

    #[test]
    fn topk_underfull_returns_all() {
        let mut sel = TopK::new(10);
        sel.offer(2, 2.0);
        sel.offer(1, 1.0);
        let ids: Vec<u64> = sel.into_sorted().iter().map(|n| n.id).collect();
        assert_eq!(ids, vec![1, 2]);
    }

    #[test]
    fn backend_kind_parse_roundtrip() {
        for k in [BackendKind::Flat, BackendKind::Lsh] {
            assert_eq!(BackendKind::parse(k.name()), Some(k));
        }
        assert_eq!(BackendKind::parse("annoy"), None);
    }

    #[test]
    fn build_index_dispatches_backend() {
        let lsh = LshConfig::default();
        assert_eq!(build_index(BackendKind::Flat, 4, &lsh, 1).backend(), "flat");
        assert_eq!(build_index(BackendKind::Lsh, 4, &lsh, 1).backend(), "lsh");
    }
}
