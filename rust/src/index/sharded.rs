//! Sharded index execution: hash-partitioning one signature's corpus
//! across `S` independent backend shards with scatter-gather queries.
//!
//! The partitioning rule is a stable id hash ([`shard_of`]): an item's
//! shard depends only on its id and the shard count, never on insertion
//! order, so conflicting ops on the same id always land on the same shard
//! and a re-partition (snapshot restore into a different `S`) is a pure
//! function of the stored pairs.
//!
//! **Bit-identity contract.** Sharded queries are bit-identical to the
//! unsharded index for any shard count:
//!
//! * every shard is built with the *same* hyperplane seed, so an LSH item
//!   hashes to the same bucket codes in whichever shard it lives — the
//!   union of per-shard candidate sets equals the unsharded candidate set
//!   exactly (per-shard seeds would make recall depend on the shard
//!   count, which the tier-1 bit-identity gate forbids);
//! * per-item scores are shard-count invariant: `linalg::matmul_into`
//!   accumulates the reduction dimension in ascending order independently
//!   per output element, so an item's dot product does not depend on how
//!   many other rows share its GEMM;
//! * the gather is a k-way merge of per-shard top-k lists under the same
//!   `(dist, id)` total order (`total_cmp`) the per-shard selects use, so
//!   merging per-shard top-k equals the global top-k of the union.
//!
//! [`ShardedIndex`] is the in-process composition (experiments, property
//! tests, benches). The coordinator does not use it directly — it drives
//! one sequencer lane per shard (`coordinator::state::IndexSlot`) so
//! shards advance in parallel across pool workers — but both paths share
//! [`shard_of`], [`merge_neighbors`] and [`combine_stats`], which is what
//! keeps them bit-identical to each other.

use super::{build_index, neighbor_order, AnnIndex, BackendKind, IndexStats, LshConfig, Neighbor};
use crate::projections::Workspace;

/// Stable shard of an item id: a SplitMix64 finalizer over the id,
/// reduced modulo the shard count. The finalizer decorrelates shard
/// assignment from dense sequential ids (raw `id % S` would stripe a
/// counter workload perfectly but correlate with any id scheme that
/// strides), and the mapping is a pure function of `(id, shards)` so
/// restores can re-partition into any shard count.
pub fn shard_of(id: u64, shards: usize) -> usize {
    debug_assert!(shards > 0, "shard count must be positive");
    if shards <= 1 {
        return 0;
    }
    let mut z = id.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z % shards as u64) as usize
}

/// Merge two neighbour lists (each sorted ascending by the shared
/// `(dist, id)` total order, [`super`]'s `neighbor_order`) into the `cap`
/// smallest of their union, preserving that order — the same comparator
/// the per-shard [`super::TopK`] selects use, so the gather can never
/// disagree with the selects on ties or NaN distances. Merging is
/// associative under truncation — any element of the global top-`cap` is
/// within the top-`cap` of every union it appears in — so folding shards
/// pairwise in any order yields the global top-`cap`.
pub fn merge_neighbors(a: Vec<Neighbor>, b: Vec<Neighbor>, cap: usize) -> Vec<Neighbor> {
    if b.is_empty() {
        let mut a = a;
        a.truncate(cap);
        return a;
    }
    if a.is_empty() {
        let mut b = b;
        b.truncate(cap);
        return b;
    }
    let mut out = Vec::with_capacity(cap.min(a.len() + b.len()));
    let (mut i, mut j) = (0usize, 0usize);
    while out.len() < cap && (i < a.len() || j < b.len()) {
        let take_a = match (a.get(i), b.get(j)) {
            (Some(x), Some(y)) => neighbor_order(x, y) != std::cmp::Ordering::Greater,
            (Some(_), None) => true,
            _ => false,
        };
        if take_a {
            out.push(a[i]);
            i += 1;
        } else {
            out.push(b[j]);
            j += 1;
        }
    }
    out
}

/// Fold one shard's statistics into a signature-level aggregate.
///
/// Additive fields (`len`, `inserts`, `deletes`, `buckets`, `shards`)
/// sum; `max_bucket` takes the maximum. `queries` also takes the maximum:
/// every query scatters to every shard, so each shard's query counter
/// already equals the signature total and summing would multiply it by
/// the shard count. Backend identity and LSH shape are asserted equal in
/// debug builds (shards of one signature share them by construction).
pub fn combine_stats(acc: Option<IndexStats>, s: IndexStats) -> IndexStats {
    match acc {
        None => s,
        Some(mut acc) => {
            debug_assert_eq!(acc.backend, s.backend);
            debug_assert_eq!(acc.dim, s.dim);
            acc.len += s.len;
            acc.inserts += s.inserts;
            acc.deletes += s.deletes;
            acc.queries = acc.queries.max(s.queries);
            acc.buckets += s.buckets;
            acc.max_bucket = acc.max_bucket.max(s.max_bucket);
            acc.shards += s.shards;
            acc
        }
    }
}

/// Apply restored lifetime counters to a set of shards under the
/// aggregation rules [`combine_stats`] inverts: mutation totals cannot be
/// re-attributed per shard after a re-partition, so shard 0 carries them
/// (the sum-aggregate reproduces the totals), while the query total is
/// set on every shard (the max-aggregate reproduces it). Shared by
/// [`ShardedIndex::restore_counters`] and the coordinator's snapshot
/// restore path — one rule, not two that can drift.
pub fn restore_shard_counters(
    shards: &mut [Box<dyn AnnIndex>],
    inserts: u64,
    deletes: u64,
    queries: u64,
) {
    for (s, shard) in shards.iter_mut().enumerate() {
        if s == 0 {
            shard.restore_counters(inserts, deletes, queries);
        } else {
            shard.restore_counters(0, 0, queries);
        }
    }
}

/// An id-hash-partitioned composition of `S` backend shards behind the
/// one [`AnnIndex`] trait: inserts and deletes route to their id's shard,
/// queries scatter to every shard and gather via [`merge_neighbors`].
///
/// See the module docs for the bit-identity contract with the unsharded
/// backends.
pub struct ShardedIndex {
    dim: usize,
    shards: Vec<Box<dyn AnnIndex>>,
}

impl ShardedIndex {
    /// Build `shards` backend shards (clamped to ≥ 1), every one seeded
    /// with the *same* `seed` so LSH bucket codes are shard-invariant
    /// (module docs).
    pub fn new(
        kind: BackendKind,
        dim: usize,
        lsh: &LshConfig,
        seed: u64,
        shards: usize,
    ) -> Self {
        let shards = shards.max(1);
        Self {
            dim,
            shards: (0..shards).map(|_| build_index(kind, dim, lsh, seed)).collect(),
        }
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Live item counts per shard (the skew observable).
    pub fn shard_lens(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.len()).collect()
    }
}

impl AnnIndex for ShardedIndex {
    fn backend(&self) -> &'static str {
        self.shards[0].backend()
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    fn insert(&mut self, id: u64, embedding: &[f64]) {
        let s = shard_of(id, self.shards.len());
        self.shards[s].insert(id, embedding);
    }

    fn remove(&mut self, id: u64) -> bool {
        let s = shard_of(id, self.shards.len());
        self.shards[s].remove(id)
    }

    fn query_batch(
        &mut self,
        qs: &[f64],
        topks: &[usize],
        ws: &mut Workspace,
    ) -> Vec<Vec<Neighbor>> {
        let mut merged: Vec<Vec<Neighbor>> = vec![Vec::new(); topks.len()];
        for shard in &mut self.shards {
            let res = shard.query_batch(qs, topks, ws);
            for ((m, r), &cap) in merged.iter_mut().zip(res).zip(topks) {
                *m = merge_neighbors(std::mem::take(m), r, cap);
            }
        }
        merged
    }

    fn stats(&self) -> IndexStats {
        self.shards
            .iter()
            .fold(None, |acc, s| Some(combine_stats(acc, s.stats())))
            .expect("at least one shard")
    }

    fn for_each_live(&self, visit: &mut dyn FnMut(u64, &[f64])) {
        for shard in &self.shards {
            shard.for_each_live(visit);
        }
    }

    fn persist_spec(&self) -> (BackendKind, LshConfig, u64) {
        // The shards share backend identity and seed; captured pairs
        // re-partition into whatever shard count the restoring side is
        // configured with (answers are shard-count invariant).
        self.shards[0].persist_spec()
    }

    fn restore_counters(&mut self, inserts: u64, deletes: u64, queries: u64) {
        restore_shard_counters(&mut self.shards, inserts, deletes, queries);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn lsh_cfg() -> LshConfig {
        LshConfig { tables: 4, bits: 6, probes: 2 }
    }

    #[test]
    fn shard_of_is_stable_and_in_range() {
        for id in 0..500u64 {
            assert_eq!(shard_of(id, 1), 0);
            for s in [2usize, 3, 4, 7] {
                let a = shard_of(id, s);
                assert!(a < s);
                assert_eq!(a, shard_of(id, s), "stable per (id, shards)");
            }
        }
    }

    #[test]
    fn shard_of_spreads_sequential_ids() {
        let s = 4;
        let mut counts = vec![0usize; s];
        for id in 0..4000u64 {
            counts[shard_of(id, s)] += 1;
        }
        for &c in &counts {
            // Uniform would be 1000; allow wide slack — this only guards
            // against degenerate striping (everything on one shard).
            assert!((600..=1400).contains(&c), "skewed partition: {counts:?}");
        }
    }

    #[test]
    fn merge_keeps_global_topk_in_order() {
        let a = vec![
            Neighbor { id: 1, dist: 0.1 },
            Neighbor { id: 5, dist: 0.5 },
            Neighbor { id: 7, dist: 0.9 },
        ];
        let b = vec![
            Neighbor { id: 2, dist: 0.2 },
            Neighbor { id: 3, dist: 0.5 },
        ];
        let m = merge_neighbors(a.clone(), b.clone(), 4);
        let ids: Vec<u64> = m.iter().map(|n| n.id).collect();
        // Tie at 0.5 breaks by ascending id: 3 before 5.
        assert_eq!(ids, vec![1, 2, 3, 5]);
        // Merging in either order agrees.
        assert_eq!(merge_neighbors(b, a, 4), m);
    }

    #[test]
    fn merge_handles_empty_and_caps() {
        let a = vec![Neighbor { id: 1, dist: 0.5 }];
        assert_eq!(merge_neighbors(a.clone(), Vec::new(), 3), a);
        assert_eq!(merge_neighbors(Vec::new(), a.clone(), 3), a);
        assert!(merge_neighbors(a.clone(), a, 0).is_empty());
    }

    #[test]
    fn merge_orders_nan_last_deterministically() {
        let a = vec![Neighbor { id: 1, dist: f64::NAN }];
        let b = vec![Neighbor { id: 2, dist: 0.5 }];
        let m = merge_neighbors(a, b, 2);
        let ids: Vec<u64> = m.iter().map(|n| n.id).collect();
        assert_eq!(ids, vec![2, 1], "NaN sorts after every finite distance");
    }

    #[test]
    fn sharded_queries_bit_identical_to_unsharded_both_backends() {
        // The tier-1 contract at the data-structure level: identical
        // mutation history, identical queries, S ∈ {1, 2, 4} vs the plain
        // backend — results must match bitwise.
        let mut rng = Rng::seed_from(42);
        let dim = 12;
        let n = 80;
        let items: Vec<(u64, Vec<f64>)> =
            (0..n).map(|i| (i as u64, rng.gaussian_vec(dim, 1.0))).collect();
        let queries: Vec<Vec<f64>> = (0..9).map(|_| rng.gaussian_vec(dim, 1.0)).collect();
        for kind in [BackendKind::Flat, BackendKind::Lsh] {
            let mut base = build_index(kind, dim, &lsh_cfg(), 77);
            for (id, v) in &items {
                base.insert(*id, v);
            }
            // Interleave deletes + overwrites so tombstones and
            // re-bucketing are exercised too.
            base.remove(3);
            base.remove(40);
            base.insert(7, &items[8].1);
            let mut ws = Workspace::new();
            let flat_qs: Vec<f64> = queries.iter().flatten().copied().collect();
            let topks = vec![6; queries.len()];
            let want = base.query_batch(&flat_qs, &topks, &mut ws);
            for s in [1usize, 2, 4] {
                let mut idx = ShardedIndex::new(kind, dim, &lsh_cfg(), 77, s);
                for (id, v) in &items {
                    idx.insert(*id, v);
                }
                idx.remove(3);
                idx.remove(40);
                idx.insert(7, &items[8].1);
                assert_eq!(idx.len(), base.len());
                let got = idx.query_batch(&flat_qs, &topks, &mut ws);
                assert_eq!(
                    got, want,
                    "{} S={s}: sharded answers must be bit-identical",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn stats_aggregate_across_shards() {
        let mut rng = Rng::seed_from(3);
        let dim = 6;
        let mut idx = ShardedIndex::new(BackendKind::Lsh, dim, &lsh_cfg(), 5, 4);
        for i in 0..30u64 {
            idx.insert(i, &rng.gaussian_vec(dim, 1.0));
        }
        idx.remove(2);
        let mut ws = Workspace::new();
        idx.query(&rng.gaussian_vec(dim, 1.0), 3, &mut ws);
        idx.query(&rng.gaussian_vec(dim, 1.0), 3, &mut ws);
        let s = idx.stats();
        assert_eq!(s.backend, "lsh");
        assert_eq!(s.len, 29, "len sums across shards");
        assert_eq!(s.inserts, 30);
        assert_eq!(s.deletes, 1);
        assert_eq!(s.queries, 2, "queries are signature-level, not ×S");
        assert_eq!(s.shards, 4);
        assert_eq!((s.tables, s.bits, s.probes), (4, 6, 2));
        assert_eq!(idx.shard_lens().iter().sum::<usize>(), 29);
    }

    #[test]
    fn restore_counters_respect_aggregation_rules() {
        let mut idx = ShardedIndex::new(BackendKind::Flat, 4, &lsh_cfg(), 1, 3);
        for i in 0..6u64 {
            idx.insert(i, &[0.0; 4]);
        }
        idx.restore_counters(10, 2, 5);
        let s = idx.stats();
        assert_eq!((s.inserts, s.deletes, s.queries), (10, 2, 5));
    }

    #[test]
    fn for_each_live_covers_every_shard() {
        let mut idx = ShardedIndex::new(BackendKind::Flat, 3, &lsh_cfg(), 1, 4);
        for i in 0..20u64 {
            idx.insert(i, &[i as f64; 3]);
        }
        let mut seen = Vec::new();
        idx.for_each_live(&mut |id, v| {
            assert_eq!(v, &[id as f64; 3]);
            seen.push(id);
        });
        seen.sort_unstable();
        assert_eq!(seen, (0..20).collect::<Vec<u64>>());
    }
}
