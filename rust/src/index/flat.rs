//! Exact-scan backend: blocked-GEMM scoring + partial top-k select.
//!
//! Storage is a dense row-major slot array (`slots × k`) with per-slot
//! squared norms, an id → slot map, and a tombstone free-list so deletes
//! are O(1) and slots are recycled. A query batch of `B` vectors is scored
//! against the *entire* store with one `linalg::matmul_into` call
//! (`S = X · Qᵀ`, `slots × B`), then per query the squared distances
//! `‖x‖² + ‖q‖² − 2·S` are reduced by [`super::TopK`]. Tombstoned slots
//! are scored (keeping the GEMM operands contiguous) and skipped in the
//! select — the arithmetic waste is bounded by the free-list population.
//!
//! Determinism contract: for a fixed insert/delete history the scan order
//! is fixed, and the GEMM accumulates the reduction dimension in ascending
//! order regardless of the batch width, so a query returns bit-identical
//! neighbours whether it is scored alone or inside a batch (this is what
//! makes coordinator-served queries identical to direct in-process ones).

use super::{AnnIndex, BackendKind, IndexStats, LshConfig, Neighbor, TopK};
use crate::linalg::matmul_into;
use crate::projections::Workspace;
use std::collections::HashMap;

/// Exact nearest-neighbour index over `R^k` embeddings.
pub struct FlatIndex {
    dim: usize,
    /// Slot storage, row-major `slots × dim` (tombstones included).
    rows: Vec<f64>,
    /// Per-slot squared norm `‖x‖²`.
    norms2: Vec<f64>,
    /// Per-slot item id (stale for tombstoned slots).
    ids: Vec<u64>,
    /// Per-slot liveness.
    live: Vec<bool>,
    /// Live id → slot.
    by_id: HashMap<u64, usize>,
    /// Recyclable tombstoned slots.
    free: Vec<usize>,
    inserts: u64,
    deletes: u64,
    queries: u64,
}

impl FlatIndex {
    /// New empty index over `dim`-dimensional embeddings.
    pub fn new(dim: usize) -> Self {
        assert!(dim > 0, "embedding dimension must be positive");
        Self {
            dim,
            rows: Vec::new(),
            norms2: Vec::new(),
            ids: Vec::new(),
            live: Vec::new(),
            by_id: HashMap::new(),
            free: Vec::new(),
            inserts: 0,
            deletes: 0,
            queries: 0,
        }
    }

    /// Total slots (live + tombstoned).
    pub fn slots(&self) -> usize {
        self.ids.len()
    }

    /// Slot of a live id.
    pub(crate) fn slot_of(&self, id: u64) -> Option<usize> {
        self.by_id.get(&id).copied()
    }

    /// Stored embedding of a slot.
    pub(crate) fn row(&self, slot: usize) -> &[f64] {
        &self.rows[slot * self.dim..(slot + 1) * self.dim]
    }

    /// Stored squared norm of a slot.
    pub(crate) fn norm2(&self, slot: usize) -> f64 {
        self.norms2[slot]
    }
}

impl AnnIndex for FlatIndex {
    fn backend(&self) -> &'static str {
        "flat"
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.by_id.len()
    }

    fn insert(&mut self, id: u64, embedding: &[f64]) {
        assert_eq!(embedding.len(), self.dim, "embedding dimension mismatch");
        let slot = match self.by_id.get(&id) {
            // Re-insert of a live id overwrites in place.
            Some(&slot) => slot,
            None => {
                let slot = match self.free.pop() {
                    Some(slot) => slot,
                    None => {
                        self.rows.resize(self.rows.len() + self.dim, 0.0);
                        self.norms2.push(0.0);
                        self.ids.push(0);
                        self.live.push(false);
                        self.ids.len() - 1
                    }
                };
                self.by_id.insert(id, slot);
                slot
            }
        };
        self.rows[slot * self.dim..(slot + 1) * self.dim].copy_from_slice(embedding);
        self.norms2[slot] = embedding.iter().map(|v| v * v).sum();
        self.ids[slot] = id;
        self.live[slot] = true;
        self.inserts += 1;
    }

    fn remove(&mut self, id: u64) -> bool {
        match self.by_id.remove(&id) {
            Some(slot) => {
                self.live[slot] = false;
                self.free.push(slot);
                self.deletes += 1;
                true
            }
            None => false,
        }
    }

    fn query_batch(
        &mut self,
        qs: &[f64],
        topks: &[usize],
        ws: &mut Workspace,
    ) -> Vec<Vec<Neighbor>> {
        let d = self.dim;
        let b = topks.len();
        assert_eq!(qs.len(), b * d, "query batch layout must be [B, k]");
        self.queries += b as u64;
        let n = self.slots();
        // Stage Qᵀ (d × b) in workspace scratch so the scoring GEMM streams
        // both operands contiguously.
        ws.chain_b.clear();
        ws.chain_b.resize(d * b, 0.0);
        for (j, q) in qs.chunks_exact(d).enumerate() {
            for (p, &v) in q.iter().enumerate() {
                ws.chain_b[p * b + j] = v;
            }
        }
        // S = X · Qᵀ in one packed GEMM over the whole store. Large scans
        // split row panels across workers inside the kernel
        // (`linalg::gemm` parallel path) — rank-stable partitioning keeps
        // the per-element chains, and hence the neighbour sets, identical
        // at every worker count.
        ws.chain_a.clear();
        ws.chain_a.resize(n * b, 0.0);
        matmul_into(&self.rows, &ws.chain_b, &mut ws.chain_a, n, d, b);
        let mut out = Vec::with_capacity(b);
        for (j, (q, &topk)) in qs.chunks_exact(d).zip(topks).enumerate() {
            let qn2: f64 = q.iter().map(|v| v * v).sum();
            let mut sel = TopK::new(topk);
            for slot in 0..n {
                if !self.live[slot] {
                    continue;
                }
                // Clamp: cancellation can drive tiny true distances below 0.
                let d2 = (self.norms2[slot] + qn2 - 2.0 * ws.chain_a[slot * b + j]).max(0.0);
                sel.offer(self.ids[slot], d2.sqrt());
            }
            out.push(sel.into_sorted());
        }
        out
    }

    fn stats(&self) -> IndexStats {
        IndexStats {
            backend: self.backend().to_string(),
            len: self.len(),
            dim: self.dim,
            inserts: self.inserts,
            deletes: self.deletes,
            queries: self.queries,
            buckets: 0,
            max_bucket: 0,
            shards: 1,
            tables: 0,
            bits: 0,
            probes: 0,
        }
    }

    fn for_each_live(&self, visit: &mut dyn FnMut(u64, &[f64])) {
        for slot in 0..self.slots() {
            if self.live[slot] {
                visit(self.ids[slot], self.row(slot));
            }
        }
    }

    fn persist_spec(&self) -> (BackendKind, LshConfig, u64) {
        // Zeros per the snapshot format spec: the flat backend has no
        // hash shape and no seed (`persist::IndexSnapshot` layout docs).
        (BackendKind::Flat, LshConfig { tables: 0, bits: 0, probes: 0 }, 0)
    }

    fn restore_counters(&mut self, inserts: u64, deletes: u64, queries: u64) {
        self.inserts = inserts;
        self.deletes = deletes;
        self.queries = queries;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Brute-force reference select used to validate the GEMM path.
    fn naive_topk(data: &[(u64, Vec<f64>)], q: &[f64], topk: usize) -> Vec<Neighbor> {
        let mut sel = TopK::new(topk);
        for (id, x) in data {
            let d2: f64 = x.iter().zip(q).map(|(a, b)| (a - b) * (a - b)).sum();
            sel.offer(*id, d2.sqrt());
        }
        sel.into_sorted()
    }

    #[test]
    fn matches_naive_scan() {
        let mut rng = Rng::seed_from(1);
        let dim = 13;
        let data: Vec<(u64, Vec<f64>)> = (0..57)
            .map(|i| (i as u64, rng.gaussian_vec(dim, 1.0)))
            .collect();
        let mut idx = FlatIndex::new(dim);
        for (id, x) in &data {
            idx.insert(*id, x);
        }
        let mut ws = Workspace::new();
        for _ in 0..8 {
            let q = rng.gaussian_vec(dim, 1.0);
            let got = idx.query(&q, 5, &mut ws);
            let want = naive_topk(&data, &q, 5);
            let got_ids: Vec<u64> = got.iter().map(|n| n.id).collect();
            let want_ids: Vec<u64> = want.iter().map(|n| n.id).collect();
            assert_eq!(got_ids, want_ids);
            for (g, w) in got.iter().zip(&want) {
                assert!((g.dist - w.dist).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn batched_query_matches_single_query_bitwise() {
        let mut rng = Rng::seed_from(2);
        let dim = 16;
        let mut idx = FlatIndex::new(dim);
        for i in 0..40u64 {
            idx.insert(i, &rng.gaussian_vec(dim, 1.0));
        }
        let qs: Vec<Vec<f64>> = (0..7).map(|_| rng.gaussian_vec(dim, 1.0)).collect();
        let flat_qs: Vec<f64> = qs.iter().flatten().copied().collect();
        let topks = vec![4; qs.len()];
        let mut ws = Workspace::new();
        let batched = idx.query_batch(&flat_qs, &topks, &mut ws);
        for (q, batch_res) in qs.iter().zip(&batched) {
            let single = idx.query(q, 4, &mut ws);
            assert_eq!(&single, batch_res, "batched scoring must be bit-identical");
        }
    }

    #[test]
    fn delete_removes_and_reinsert_overwrites() {
        let mut ws = Workspace::new();
        let mut idx = FlatIndex::new(2);
        idx.insert(1, &[0.0, 0.0]);
        idx.insert(2, &[10.0, 0.0]);
        assert_eq!(idx.len(), 2);
        assert!(idx.remove(1));
        assert!(!idx.remove(1), "double delete is a no-op");
        assert_eq!(idx.len(), 1);
        let res = idx.query(&[0.1, 0.0], 5, &mut ws);
        assert_eq!(res.len(), 1);
        assert_eq!(res[0].id, 2);
        // Slot recycling: a new insert reuses the tombstoned slot.
        idx.insert(3, &[0.2, 0.0]);
        assert_eq!(idx.slots(), 2);
        // Overwrite of a live id updates the vector in place.
        idx.insert(3, &[5.0, 0.0]);
        assert_eq!(idx.slots(), 2);
        let res = idx.query(&[5.0, 0.0], 1, &mut ws);
        assert_eq!(res[0].id, 3);
        assert!(res[0].dist < 1e-12);
    }

    #[test]
    fn empty_index_returns_no_neighbors() {
        let mut ws = Workspace::new();
        let mut idx = FlatIndex::new(3);
        assert!(idx.is_empty());
        assert!(idx.query(&[1.0, 2.0, 3.0], 4, &mut ws).is_empty());
    }

    #[test]
    fn stats_track_operations() {
        let mut ws = Workspace::new();
        let mut idx = FlatIndex::new(2);
        idx.insert(1, &[1.0, 0.0]);
        idx.insert(2, &[0.0, 1.0]);
        idx.remove(1);
        idx.query(&[0.0, 1.0], 1, &mut ws);
        let s = idx.stats();
        assert_eq!(s.backend, "flat");
        assert_eq!(s.len, 1);
        assert_eq!(s.dim, 2);
        assert_eq!(s.inserts, 2);
        assert_eq!(s.deletes, 1);
        assert_eq!(s.queries, 1);
    }
}
