//! `trp` — the tensorized-random-projections CLI.
//!
//! ```text
//! trp serve       [--requests N] [--rate R] [--case medium] [--no-pjrt]
//!                 [--listen ADDR [--listen-secs N]]
//!                 [--snapshot-dir DIR] [--snapshot-every N]
//!                 [--restore DIR] [--index-shards S]
//!                 [--index-backend flat|lsh] [--lsh T,B,P | --lsh-auto N [--lsh-recall R]]
//!                 [--trace-dir DIR [--trace-file-cap BYTES] [--trace-keep N]
//!                  [--trace-ring-cap SPANS]]
//!                 [--slo FILE [--slo-alarms PATH]]
//!                 [--wal-dir DIR [--wal-segment-cap BYTES] [--wal-fsync flush|every-N]]
//! trp wal         verify|dump [--dir DIR] [--json]
//! trp metrics     --connect ADDR [--watch [--interval SECS]] [--reset]
//! trp metrics     --check-trace FILE          # CI: validate span JSONL coverage
//! trp trace       analyze [--dir DIR] [--json] [--gate [--min-frac F]]
//! trp trace       analyze --diff DIR_A DIR_B [--json]
//! trp slo         --connect ADDR [--watch [--interval SECS]] | --file FILE
//! trp snapshot    --connect ADDR --case medium --format tt [--restore]
//! trp project     --case medium --format tt [--k 64] [--map tt:5]
//! trp experiment  fig1|fig2|fig3|fig4|ablation|batch|ann [--quick] [--trials T]
//!                 [--shards 1,2,4]           # ann: QPS-vs-shard-count axis
//! trp bounds      --eps 0.5 --n 12 --r 10 --m 100 [--delta 0.05]
//! trp artifacts   [--artifacts DIR]          # list + verify compiled set
//! trp lint        [--json] [--baseline FILE] [--write-baseline] [--root DIR]
//! ```

use tensorized_rp::config::AppConfig;
use tensorized_rp::coordinator::{Coordinator, CoordinatorConfig, ProjectRequest};
use tensorized_rp::data::inputs::{unit_input, Regime};
use tensorized_rp::data::workload::{poisson_trace, FormatMix};
use tensorized_rp::experiments::{ablations, ann, batch, fig1, fig2, fig3, fig4, MapSpec};
use tensorized_rp::rng::Rng;
use tensorized_rp::runtime::PjrtEngine;
use tensorized_rp::tensor::{AnyTensor, Format};
use tensorized_rp::theory;
use tensorized_rp::util::cli::Args;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<(), String> {
    let cfg = AppConfig::from_args(args)?;
    match args.pos(0) {
        Some("serve") => cmd_serve(args, &cfg),
        Some("client") => cmd_client(args, &cfg),
        Some("metrics") => cmd_metrics(args),
        Some("snapshot") => cmd_snapshot(args),
        Some("project") => cmd_project(args, &cfg),
        Some("experiment") => cmd_experiment(args, &cfg),
        Some("bounds") => cmd_bounds(args),
        Some("sketch") => cmd_sketch(args, &cfg),
        Some("artifacts") => cmd_artifacts(&cfg),
        Some("lint") => cmd_lint(args),
        Some("wal") => cmd_wal(args),
        Some("trace") => cmd_trace(args),
        Some("slo") => cmd_slo(args),
        _ => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "trp — Tensorized Random Projections (Rakhshan & Rabusseau, AISTATS 2020)\n\
         \n\
         subcommands:\n\
           serve       run the compression service on a synthetic trace\n\
                       (--index-shards S partitions each signature's ANN\n\
                       index across S parallel lanes; --index-backend\n\
                       flat|lsh, --lsh T,B,P or --lsh-auto N --lsh-recall R;\n\
                       --trace-dir DIR records request spans as rotated JSONL;\n\
                       --wal-dir DIR logs every mutation ahead of apply so a\n\
                       SIGKILL loses nothing past the last group-commit fsync;\n\
                       --listen-secs N stops after N seconds with a clean\n\
                       drain so CI gets a sealed trace stream)\n\
           project     project one random input and print the distortion\n\
           experiment  regenerate a paper figure: fig1|fig2|fig3|fig4|ablation|batch|ann\n\
           bounds      evaluate the Theorem 2 size bounds\n\
           sketch      sketched SVD demo with a tensorized test matrix (§7)\n\
           client      send requests to a listening `trp serve --listen` instance\n\
                       (--op project|insert|query|stats|metrics)\n\
           metrics     Prometheus-style dump of a live server's observability\n\
                       snapshot (--watch to refresh; --reset clears the\n\
                       high-water gauges; --check-trace FILE validates a\n\
                       span JSONL file for CI)\n\
           snapshot    ask a listening server to snapshot (or, with\n\
                       --restore, reload) a signature's index\n\
           trace       offline span analysis over a `--trace-dir`:\n\
                       `analyze` stitches rotated JSONL generations,\n\
                       reconstructs per-request waterfalls, attributes the\n\
                       critical path per signature and reports flush\n\
                       fan-out (--json for the CI artifact; --gate\n\
                       [--min-frac F] exits nonzero unless ≥ F of requests\n\
                       reconstruct with zero ring drops; --diff A B\n\
                       compares two trace dirs stage by stage)\n\
           slo         burn-rate status of a live server's objectives\n\
                       (--connect ADDR [--watch]; --file FILE validates an\n\
                       objectives TOML offline without a server)\n\
           wal         offline write-ahead-log inspection: `verify` checks\n\
                       every segment chain (headers, checksums, seq\n\
                       continuity; exits nonzero on corruption replay would\n\
                       refuse), `dump` prints the decodable records\n\
           artifacts   list and verify the compiled artifact set\n\
           lint        determinism & concurrency static analysis over this\n\
                       crate's own sources (--json for the CI artifact;\n\
                       --baseline FILE, --write-baseline to grandfather;\n\
                       exits nonzero on any unwaived finding)\n\
         \n\
         common options: --seed S --trials T --threads W --quick --artifacts DIR --out DIR"
    )
}

fn cmd_serve(args: &Args, cfg: &AppConfig) -> Result<(), String> {
    let n: usize = args.get_parsed_or("requests", 200usize)?;
    let rate: f64 = args.get_parsed_or("rate", 2_000.0f64)?;
    let case = Regime::parse(&args.get_or("case", "medium")).ok_or("bad --case")?;
    let use_pjrt = !args.flag("no-pjrt");

    let engine = if use_pjrt {
        match PjrtEngine::cpu() {
            Ok(mut e) => match e.load_dir(&cfg.artifacts_dir) {
                Ok(na) => {
                    println!("[serve] PJRT {} with {na} artifacts", e.platform());
                    Some(e)
                }
                Err(err) => {
                    println!("[serve] artifacts unavailable ({err}); native only");
                    None
                }
            },
            Err(err) => {
                println!("[serve] PJRT unavailable ({err}); native only");
                None
            }
        }
    } else {
        None
    };

    let snapshot_dir = args.get("snapshot-dir").map(std::path::PathBuf::from);
    let snapshot_every: u64 = args.get_parsed_or("snapshot-every", 0u64)?;
    if snapshot_every > 0 && snapshot_dir.is_none() {
        return Err("--snapshot-every requires --snapshot-dir".into());
    }
    // Rotation depth: keep the last N snapshot sequences per signature.
    let snapshot_keep: usize = args.get_parsed_or("snapshot-keep", 2usize)?;
    if snapshot_keep == 0 {
        return Err("--snapshot-keep must be ≥ 1".into());
    }
    // Sharding: partition each signature's index across N sequencer
    // lanes so a single hot signature saturates the worker pool.
    let index_shards: usize = args.get_parsed_or("index-shards", 1usize)?;
    if index_shards == 0 {
        return Err("--index-shards must be ≥ 1".into());
    }
    let index_backend = {
        let name = args.get_or("index-backend", "flat");
        tensorized_rp::index::BackendKind::parse(&name)
            .ok_or_else(|| format!("bad --index-backend {name} (flat|lsh)"))?
    };
    // LSH shape: static `--lsh T,B,P`, or derived from the expected
    // corpus size + target recall (`--lsh-auto N [--lsh-recall R]`; the
    // hint is divided across shards — each shard hashes only its own
    // partition). `stats` responses report the effective shape.
    let lsh = if let Some(hint) = args.get("lsh-auto") {
        let corpus: usize = hint.parse().map_err(|_| format!("bad --lsh-auto {hint}"))?;
        let recall: f64 = args.get_parsed_or("lsh-recall", 0.9f64)?;
        let per_shard = (corpus / index_shards).max(1);
        let auto = tensorized_rp::index::LshConfig::auto(per_shard, recall);
        println!(
            "[serve] lsh auto({per_shard}/shard, recall {recall}): tables={} bits={} probes={}",
            auto.tables, auto.bits, auto.probes
        );
        auto
    } else if let Some(shape) = args.get("lsh") {
        let parts: Vec<usize> = shape
            .split(',')
            .map(|v| v.parse().map_err(|_| format!("bad --lsh {shape} (want T,B,P)")))
            .collect::<Result<_, String>>()?;
        if parts.len() != 3 {
            return Err(format!("bad --lsh {shape} (want T,B,P)"));
        }
        tensorized_rp::index::LshConfig { tables: parts[0], bits: parts[1], probes: parts[2] }
    } else {
        tensorized_rp::index::LshConfig::default()
    };
    // Tracing: --trace-dir DIR drains request-level spans to rotated
    // JSONL files under DIR (see obs::trace). Off by default — and the
    // response stream is bit-identical either way.
    let trace = match args.get("trace-dir") {
        Some(dir) => {
            let mut tc = tensorized_rp::obs::TraceConfig::new(dir);
            tc.max_file_bytes = args.get_parsed_or("trace-file-cap", tc.max_file_bytes)?;
            tc.keep_files = args.get_parsed_or("trace-keep", tc.keep_files)?;
            if tc.keep_files == 0 {
                return Err("--trace-keep must be ≥ 1".into());
            }
            // Ring sizing: under sustained overload the ring sheds spans
            // (counted, surfaced by `trp metrics --check-trace`); raising
            // the cap trades memory for loss-free capture.
            tc.ring_capacity = args.get_parsed_or("trace-ring-cap", tc.ring_capacity)?;
            if tc.ring_capacity == 0 {
                return Err("--trace-ring-cap must be ≥ 1".into());
            }
            println!(
                "[serve] tracing to {}/trace.jsonl (cap {} bytes × {} files, ring {} spans)",
                tc.dir.display(),
                tc.max_file_bytes,
                tc.keep_files,
                tc.ring_capacity.next_power_of_two()
            );
            Some(tc)
        }
        None => None,
    };
    // SLO objectives: --slo FILE loads a declarative TOML of per-signature
    // burn-rate objectives (see obs::slo). Alarm transitions append to
    // --slo-alarms PATH, defaulting to alarms.jsonl under the trace dir.
    let slo = match args.get("slo") {
        Some(path) => {
            let mut sc = tensorized_rp::obs::SloConfig::load(std::path::Path::new(path))?;
            if let Some(p) = args.get("slo-alarms") {
                sc.alarms_path = Some(std::path::PathBuf::from(p));
            } else if sc.alarms_path.is_none() {
                sc.alarms_path = trace.as_ref().map(|tc| tc.dir.join("alarms.jsonl"));
            }
            println!(
                "[serve] slo: {} objectives from {path} (poll {} ms, alarms {})",
                sc.objectives.len(),
                sc.poll_interval_ms,
                sc.alarms_path
                    .as_ref()
                    .map(|p| p.display().to_string())
                    .unwrap_or_else(|| "off".into())
            );
            Some(sc)
        }
        None => {
            if args.get("slo-alarms").is_some() {
                return Err("--slo-alarms requires --slo FILE".into());
            }
            None
        }
    };
    // Durability: --wal-dir DIR turns on the per-signature, per-shard-lane
    // write-ahead log (index::wal). Requires --snapshot-dir because WAL
    // checkpoints are snapshot cuts — recovery replays the segment tail on
    // top of the newest restorable snapshot, and runs inside
    // `Coordinator::start` before any traffic is accepted.
    let wal_dir = args.get("wal-dir").map(std::path::PathBuf::from);
    if wal_dir.is_some() && snapshot_dir.is_none() {
        return Err("--wal-dir requires --snapshot-dir (WAL checkpoints are snapshot cuts)".into());
    }
    let wal_segment_cap: u64 =
        args.get_parsed_or("wal-segment-cap", tensorized_rp::index::wal::DEFAULT_SEGMENT_CAP)?;
    if wal_segment_cap == 0 {
        return Err("--wal-segment-cap must be ≥ 1".into());
    }
    let wal_fsync = tensorized_rp::index::WalFsync::parse(&args.get_or("wal-fsync", "flush"))
        .map_err(|e| format!("bad --wal-fsync: {e}"))?;
    if let Some(dir) = &wal_dir {
        println!(
            "[serve] wal at {} (segment cap {wal_segment_cap} bytes, fsync {})",
            dir.display(),
            wal_fsync.name()
        );
    }
    let coord = Coordinator::start(
        CoordinatorConfig {
            master_seed: cfg.seed,
            snapshot_dir,
            snapshot_every_ops: snapshot_every,
            snapshot_keep,
            index_shards,
            index_backend,
            lsh,
            trace,
            slo,
            wal_dir,
            wal_segment_cap,
            wal_fsync,
            ..Default::default()
        },
        engine,
    );

    // --restore DIR: crash recovery — reload every index snapshot before
    // any traffic is accepted.
    if let Some(dir) = args.get("restore").map(std::path::PathBuf::from) {
        let (sigs, items) = coord
            .restore_from(&dir)
            .map_err(|e| format!("restore from {}: {e}", dir.display()))?;
        println!(
            "[serve] restored {items} items across {sigs} signatures from {}",
            dir.display()
        );
    }

    // --listen ADDR: expose the service over TCP instead of replaying a
    // synthetic trace (newline-delimited JSON; see coordinator::wire).
    // --listen-secs N bounds the lifetime: after N seconds the server
    // stops accepting, drains, and shuts the coordinator down cleanly —
    // sealing the trace stream — so CI can gate on a complete JSONL
    // stream instead of SIGTERM-truncated files. 0 (default) = forever.
    if let Some(addr) = args.get("listen") {
        let listen_secs: u64 = args.get_parsed_or("listen-secs", 0u64)?;
        let coord = std::sync::Arc::new(coord);
        let server = tensorized_rp::coordinator::NetServer::start(
            std::sync::Arc::clone(&coord),
            addr,
        )
        .map_err(|e| e.to_string())?;
        println!("[serve] listening on {} — Ctrl-C to stop", server.addr());
        let started = std::time::Instant::now();
        let mut up = 0u64;
        loop {
            std::thread::sleep(std::time::Duration::from_secs(1));
            up += 1;
            if up % 5 == 0 {
                let m = coord.metrics();
                println!(
                    "[serve] served={} completed={} pjrt_batches={} mean={:.0}µs",
                    server.served(),
                    m.completed,
                    m.pjrt_batches,
                    m.mean_latency_us
                );
            }
            if listen_secs > 0 && started.elapsed().as_secs() >= listen_secs {
                break;
            }
        }
        println!("[serve] --listen-secs {listen_secs} elapsed; draining");
        server.shutdown();
        match std::sync::Arc::try_unwrap(coord) {
            Ok(c) => c.shutdown(),
            Err(_) => eprintln!("[serve] coordinator still referenced; skipping drain"),
        }
        return Ok(());
    }

    let trace = poisson_trace(n, rate, case, FormatMix::default(), cfg.seed);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = trace
        .payloads
        .into_iter()
        .enumerate()
        .map(|(i, p)| coord.submit(ProjectRequest::new(i as u64, p)))
        .collect();
    let mut ok = 0usize;
    for rx in rxs {
        if rx.recv().map_err(|e| e.to_string())?.is_ok() {
            ok += 1;
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    println!(
        "[serve] {ok}/{n} ok in {elapsed:.3}s → {:.0} req/s | native={} pjrt={} batches={} \
         padded={} | mean={:.0}µs p50={}µs p99={}µs",
        ok as f64 / elapsed,
        m.native_requests,
        m.pjrt_requests,
        m.pjrt_batches,
        m.padded_slots,
        m.mean_latency_us,
        m.p50_latency_us,
        m.p99_latency_us,
    );
    coord.shutdown();
    Ok(())
}

fn cmd_client(args: &Args, cfg: &AppConfig) -> Result<(), String> {
    let addr = args.get("connect").unwrap_or("127.0.0.1:7070");
    let case = Regime::parse(&args.get_or("case", "medium")).ok_or("bad --case")?;
    let format = args.get_or("format", "tt");
    let op = args.get_or("op", "project");
    let n: usize = args.get_parsed_or("requests", 4usize)?;
    // A metrics snapshot is global: one request tells the whole story.
    let n = if op == "metrics" { 1 } else { n };
    let topk: usize = args.get_parsed_or("k", 5usize)?;
    let mut client =
        tensorized_rp::coordinator::NetClient::connect(addr).map_err(|e| e.to_string())?;
    let mut rng = Rng::seed_from(cfg.seed);
    for i in 0..n {
        let req = match op.as_str() {
            "project" | "insert" | "query" => {
                let x = unit_input(&case.dims(), case.input_rank(), &format, &mut rng);
                match op.as_str() {
                    "project" => ProjectRequest::new(i as u64, x),
                    "insert" => ProjectRequest::insert(i as u64, x),
                    _ => ProjectRequest::query(i as u64, x, topk),
                }
            }
            "stats" => {
                let f = Format::parse(&format).ok_or("bad --format")?;
                ProjectRequest::index_stats(i as u64, f, case.dims())
            }
            "metrics" => ProjectRequest::metrics(i as u64, args.flag("reset")),
            other => {
                return Err(format!("unknown --op {other} (project|insert|query|stats|metrics)"))
            }
        };
        let resp = client.roundtrip(&req).map_err(|e| e.to_string())?;
        let id = resp
            .id
            .map(|v| v.to_string())
            .unwrap_or_else(|| "null".into());
        if let Some(e) = resp.error {
            println!("id={id} error: {e}");
            continue;
        }
        if let Some(m) = resp.metrics {
            println!(
                "id={id} metrics: submitted={} completed={} failed={} signatures={} \
                 gemm_buckets={} trace_recorded={}",
                m.global.submitted,
                m.global.completed,
                m.global.failed,
                m.signatures.len(),
                m.gemm.len(),
                m.trace.recorded
            );
            for s in &m.signatures {
                let stages = s
                    .stages
                    .iter()
                    .map(|st| format!("{}:p50={}µs/p99={}µs", st.stage, st.p50_us, st.p99_us))
                    .collect::<Vec<_>>()
                    .join(" ");
                println!(
                    "  sig {} req={} proj={} ins={} qry={} del={} err={} flushes={} | {stages}",
                    s.signature,
                    s.requests,
                    s.projects,
                    s.inserts,
                    s.queries,
                    s.deletes,
                    s.errors,
                    s.flushes
                );
            }
        } else if let Some(ns) = resp.neighbors {
            let nearest = ns
                .first()
                .map(|nb| format!("{}@{:.4}", nb.id, nb.dist))
                .unwrap_or_else(|| "-".into());
            println!("id={id} neighbors={} nearest={nearest}", ns.len());
        } else if let Some(s) = resp.index {
            println!(
                "id={id} index backend={} len={} inserts={} deletes={} queries={}",
                s.backend, s.len, s.inserts, s.deletes, s.queries
            );
        } else if let Some(y) = resp.embedding {
            let n2: f64 = y.iter().map(|v| v * v).sum();
            println!(
                "id={id} k={} ‖y‖²={n2:.4} via {}",
                y.len(),
                resp.path.unwrap_or_default()
            );
        } else {
            println!("id={id} empty response");
        }
    }
    Ok(())
}

/// Render a live server's observability snapshot as a Prometheus-style
/// text dump (`trp metrics --connect ADDR [--watch] [--reset]`), or
/// validate a span JSONL file (`trp metrics --check-trace FILE` — the CI
/// trace smoke job's assertion).
fn cmd_metrics(args: &Args) -> Result<(), String> {
    if let Some(path) = args.get("check-trace") {
        return check_trace(std::path::Path::new(path));
    }
    let addr = args.get("connect").unwrap_or("127.0.0.1:7070");
    let reset = args.flag("reset");
    let watch = args.flag("watch");
    let interval: u64 = args.get_parsed_or("interval", 2u64)?;
    let mut client =
        tensorized_rp::coordinator::NetClient::connect(addr).map_err(|e| e.to_string())?;
    let mut id = 0u64;
    loop {
        let resp = client
            .roundtrip(&ProjectRequest::metrics(id, reset))
            .map_err(|e| e.to_string())?;
        if let Some(e) = resp.error {
            return Err(e);
        }
        let snap = resp.metrics.ok_or("server answered without a metrics snapshot")?;
        print!("{}", snap.to_prometheus());
        if !watch {
            return Ok(());
        }
        println!("# ---");
        id += 1;
        std::thread::sleep(std::time::Duration::from_secs(interval.max(1)));
    }
}

/// Every line must parse as a span record with a known stage tag and
/// integer timing fields (meta records — anchors, signature interning,
/// the stats seal — are validated and skipped), and every required
/// pipeline stage must appear at least once. A stats seal reporting ring
/// drops > 0 fails the check loudly: the stream is incomplete and the fix
/// is `--trace-ring-cap`. `Err` (exit 1) otherwise, so CI can gate on it.
fn check_trace(path: &std::path::Path) -> Result<(), String> {
    use tensorized_rp::obs::{OPTIONAL_STAGES, REQUIRED_STAGES};
    use tensorized_rp::util::json::Json;
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    let mut seen: std::collections::BTreeMap<&str, u64> = std::collections::BTreeMap::new();
    let mut lines = 0u64;
    let mut metas = 0u64;
    let mut dropped: Option<u64> = None;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(line)
            .map_err(|e| format!("{}:{}: bad JSON: {e}", path.display(), i + 1))?;
        if let Some(kind) = v.get("meta").and_then(Json::as_str) {
            if kind == "stats" {
                dropped = Some(
                    v.get("dropped").and_then(Json::as_usize).ok_or_else(|| {
                        format!("{}:{}: stats meta without a dropped count", path.display(), i + 1)
                    })? as u64,
                );
            }
            metas += 1;
            continue;
        }
        let stage = v
            .get("stage")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("{}:{}: span without a stage tag", path.display(), i + 1))?;
        let known = REQUIRED_STAGES.iter().chain(OPTIONAL_STAGES.iter());
        let stage = known
            .copied()
            .find(|s| *s == stage)
            .ok_or_else(|| format!("{}:{}: unknown stage {stage:?}", path.display(), i + 1))?;
        for key in ["start_us", "dur_us"] {
            if v.get(key).and_then(Json::as_usize).is_none() {
                return Err(format!(
                    "{}:{}: span missing integer {key}",
                    path.display(),
                    i + 1
                ));
            }
        }
        *seen.entry(stage).or_insert(0) += 1;
        lines += 1;
    }
    if lines == 0 {
        return Err(format!("{}: no spans recorded", path.display()));
    }
    let missing: Vec<&str> =
        REQUIRED_STAGES.iter().copied().filter(|s| !seen.contains_key(s)).collect();
    if !missing.is_empty() {
        return Err(format!(
            "{}: {lines} spans but missing required stages: {}",
            path.display(),
            missing.join(", ")
        ));
    }
    if let Some(d) = dropped {
        if d > 0 {
            return Err(format!(
                "{}: span ring dropped {d} spans — the stream is incomplete; \
                 raise `trp serve --trace-ring-cap`",
                path.display()
            ));
        }
    }
    let summary =
        seen.iter().map(|(s, n)| format!("{s}={n}")).collect::<Vec<_>>().join(" ");
    println!(
        "[check-trace] {}: {lines} spans ok ({metas} meta records, dropped={}) — {summary}",
        path.display(),
        dropped.map(|d| d.to_string()).unwrap_or_else(|| "unsealed".into())
    );
    Ok(())
}

/// Offline trace analysis: `trp trace analyze [--dir DIR] [--json]
/// [--gate [--min-frac F]]` stitches the rotated JSONL generations under
/// DIR, reconstructs per-request waterfalls and prints critical-path
/// attribution per signature plus flush fan-out; `--gate` turns the
/// report into a CI assertion (≥ F of requests reconstructed, full stage
/// coverage, zero ring drops, sealed stream). `--diff DIR_A DIR_B`
/// compares two runs stage by stage and flags p99 regressions.
fn cmd_trace(args: &Args) -> Result<(), String> {
    use tensorized_rp::obs::{analyze_dir, diff_reports, diff_to_json, render_diff};
    let action = args.pos(1).ok_or("trace needs an action: analyze")?;
    if action != "analyze" {
        return Err(format!("unknown trace action {action} (analyze)"));
    }
    if let Some(a) = args.get("diff") {
        let b = args
            .pos(2)
            .ok_or("--diff needs two directories: --diff DIR_A DIR_B")?;
        let ra = analyze_dir(std::path::Path::new(a))?;
        let rb = analyze_dir(std::path::Path::new(b))?;
        let rows = diff_reports(&ra, &rb);
        if args.flag("json") {
            println!("{}", diff_to_json(&rows).to_string_pretty());
        } else {
            print!("{}", render_diff(&rows));
        }
        return Ok(());
    }
    let dir = std::path::PathBuf::from(args.get_or("dir", "trace"));
    let report = analyze_dir(&dir)?;
    if args.flag("json") {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        print!("{}", report.render());
    }
    if args.flag("gate") {
        let min_frac: f64 = args.get_parsed_or("min-frac", 0.99f64)?;
        report
            .gate(min_frac)
            .map_err(|errs| format!("trace analyze gate failed:\n  {}", errs.join("\n  ")))?;
        println!(
            "[trace-analyze] gate ok: {}/{} requests reconstructed (≥ {:.0}% required), \
             zero ring drops",
            report.reconstructed,
            report.requests,
            min_frac * 100.0
        );
    }
    Ok(())
}

/// Burn-rate status of a live server's SLO objectives: `trp slo
/// --connect ADDR [--watch [--interval SECS]]` renders the
/// [`SloStatusSnapshot`](tensorized_rp::obs::SloStatusSnapshot) rows the
/// server exports in its metrics snapshot. `--file FILE` instead
/// validates an objectives TOML offline and prints what it declares.
fn cmd_slo(args: &Args) -> Result<(), String> {
    if let Some(path) = args.get("file") {
        let cfg = tensorized_rp::obs::SloConfig::load(std::path::Path::new(path))?;
        println!(
            "[slo] {path}: {} objectives (poll {} ms, alarms {})",
            cfg.objectives.len(),
            cfg.poll_interval_ms,
            cfg.alarms_path
                .as_ref()
                .map(|p| p.display().to_string())
                .unwrap_or_else(|| "unset".into())
        );
        for o in &cfg.objectives {
            let mut targets = Vec::new();
            if let Some(t) = o.p99_latency_us {
                targets.push(format!("p99_latency_us≤{t}"));
            }
            if let Some(r) = o.error_rate {
                targets.push(format!("error_rate≤{r}"));
            }
            println!(
                "  sig {}: {} | windows {}s/{}s, burn threshold {}",
                o.signature,
                targets.join(" "),
                o.fast_window_s,
                o.slow_window_s,
                o.burn_threshold
            );
        }
        return Ok(());
    }
    let addr = args.get("connect").unwrap_or("127.0.0.1:7070");
    let watch = args.flag("watch");
    let interval: u64 = args.get_parsed_or("interval", 2u64)?;
    let mut client =
        tensorized_rp::coordinator::NetClient::connect(addr).map_err(|e| e.to_string())?;
    let mut id = 0u64;
    loop {
        let resp = client
            .roundtrip(&ProjectRequest::metrics(id, false))
            .map_err(|e| e.to_string())?;
        if let Some(e) = resp.error {
            return Err(e);
        }
        let snap = resp.metrics.ok_or("server answered without a metrics snapshot")?;
        if snap.slo.is_empty() {
            println!("[slo] no objectives loaded — start the server with --slo FILE");
        }
        for s in &snap.slo {
            println!(
                "sig {} {} target={} fast_burn={:.2} slow_burn={:.2} {}",
                s.signature,
                s.objective,
                s.target,
                s.fast_burn,
                s.slow_burn,
                if s.firing { "FIRING" } else { "ok" }
            );
        }
        if !watch {
            return Ok(());
        }
        println!("# ---");
        id += 1;
        std::thread::sleep(std::time::Duration::from_secs(interval.max(1)));
    }
}

/// Ask a listening server to persist (or reload) one signature's index:
/// `trp snapshot --connect ADDR --case medium --format tt [--restore]`.
/// The server writes to its own `--snapshot-dir`; this just triggers the
/// op through the wire protocol so the cut is sequenced with live
/// traffic.
fn cmd_snapshot(args: &Args) -> Result<(), String> {
    let addr = args.get("connect").unwrap_or("127.0.0.1:7070");
    let case = Regime::parse(&args.get_or("case", "medium")).ok_or("bad --case")?;
    let format = Format::parse(&args.get_or("format", "tt")).ok_or("bad --format")?;
    let mut client =
        tensorized_rp::coordinator::NetClient::connect(addr).map_err(|e| e.to_string())?;
    let req = if args.flag("restore") {
        ProjectRequest::restore(1, format, case.dims())
    } else {
        ProjectRequest::snapshot(1, format, case.dims())
    };
    let resp = client.roundtrip(&req).map_err(|e| e.to_string())?;
    if let Some(e) = resp.error {
        return Err(e);
    }
    if let Some(rep) = resp.snapshot {
        println!("[snapshot] {} items ({} bytes) → {}", rep.items, rep.bytes, rep.path);
    }
    if let Some(items) = resp.restored {
        println!("[restore] {items} items reloaded");
    }
    Ok(())
}

fn cmd_project(args: &Args, cfg: &AppConfig) -> Result<(), String> {
    let case = Regime::parse(&args.get_or("case", "medium")).ok_or("bad --case")?;
    let format = args.get_or("format", "tt");
    let k: usize = args.get_parsed_or("k", 64usize)?;
    let map = parse_map_spec(&args.get_or("map", "tt:5"))?;
    let mut rng = Rng::seed_from(cfg.seed);
    let x = unit_input(&case.dims(), case.input_rank(), &format, &mut rng);
    let f = map.build(&case.dims(), k, &mut rng);
    let t = tensorized_rp::util::Timer::start();
    let y = f.project(&x);
    let secs = t.elapsed_secs();
    let d = tensorized_rp::projections::distortion_ratio(&y, x.fro_norm());
    println!(
        "map={} k={k} input={format}/{} | distortion={d:.4} | {:.3} ms | params={}",
        f.name(),
        case.name(),
        secs * 1e3,
        f.num_params()
    );
    Ok(())
}

fn parse_map_spec(s: &str) -> Result<MapSpec, String> {
    match s {
        "gaussian" => return Ok(MapSpec::Gaussian),
        "very_sparse" | "sparse" => return Ok(MapSpec::VerySparse),
        _ => {}
    }
    if let Some((kind, r)) = s.split_once(':') {
        let r: usize = r.parse().map_err(|_| format!("bad rank in --map {s}"))?;
        return match kind {
            "tt" => Ok(MapSpec::Tt(r)),
            "cp" => Ok(MapSpec::Cp(r)),
            _ => Err(format!("unknown map kind {kind}")),
        };
    }
    Err(format!("cannot parse --map {s} (want tt:R, cp:R, gaussian, very_sparse)"))
}

fn cmd_experiment(args: &Args, cfg: &AppConfig) -> Result<(), String> {
    let which = args.pos(1).ok_or("experiment needs a figure name")?;
    match which {
        "fig1" => {
            let case = Regime::parse(&args.get_or("case", "medium")).ok_or("bad --case")?;
            let mut c = if cfg.quick {
                fig1::Fig1Config::quick(case)
            } else {
                fig1::Fig1Config::paper(case)
            };
            c.seed = cfg.seed;
            if let Some(t) = cfg.trials {
                c.trials = t;
            }
            c.threads = cfg.threads();
            let rows = fig1::run(&c);
            let csv = fig1::to_csv(case, &rows);
            print!("{}", csv.to_markdown());
            let path = cfg.results_dir.join(format!("fig1_{}.csv", case.name()));
            csv.write_to(&path).map_err(|e| e.to_string())?;
            println!("[written {}]", path.display());
        }
        "fig2" => {
            let c = if cfg.quick { fig2::Fig2Config::quick() } else { fig2::Fig2Config::paper() };
            let rows = fig2::run(&c);
            let csv = fig2::to_csv(&rows);
            print!("{}", csv.to_markdown());
            let path = cfg.results_dir.join("fig2_time.csv");
            csv.write_to(&path).map_err(|e| e.to_string())?;
            println!("[written {}]", path.display());
        }
        "fig3" => {
            let mut c =
                if cfg.quick { fig3::Fig3Config::quick() } else { fig3::Fig3Config::paper() };
            c.seed = cfg.seed;
            if let Some(t) = cfg.trials {
                c.trials = t;
            }
            c.threads = cfg.threads();
            let rows = fig3::run(&c);
            let csv = fig3::to_csv(&rows);
            print!("{}", csv.to_markdown());
            let path = cfg.results_dir.join("fig3_pairwise.csv");
            csv.write_to(&path).map_err(|e| e.to_string())?;
            println!("[written {}]", path.display());
        }
        "fig4" => {
            let c = if cfg.quick { fig4::Fig4Config::quick() } else { fig4::Fig4Config::paper() };
            let rows = fig4::run(&c);
            let csv = fig4::to_csv(&rows);
            print!("{}", csv.to_markdown());
            let path = cfg.results_dir.join("fig4_scaling.csv");
            csv.write_to(&path).map_err(|e| e.to_string())?;
            println!("[written {}]", path.display());
        }
        "batch" => {
            let mut c = if cfg.quick {
                batch::BatchSweepConfig::quick()
            } else {
                batch::BatchSweepConfig::paper()
            };
            c.seed = cfg.seed;
            let rows = batch::run(&c);
            let csv = batch::to_csv(&rows);
            print!("{}", csv.to_markdown());
            let path = cfg.results_dir.join("batch_sweep.csv");
            csv.write_to(&path).map_err(|e| e.to_string())?;
            println!("[written {}]", path.display());
            // Machine-readable trajectory tracked across PRs (same schema
            // as `cargo bench --bench batch_sweep`): TT-input and CP-input
            // series next to the dense ones, plus the kernel GFLOP/s rows
            // (packed vs frozen PR 5 kernel) on the sweep's shape mix.
            let krows = batch::kernel_bench(&c);
            // Tracing tripwire: same coordinator point with tracing off
            // vs on — responses must be bit-identical, overhead small.
            let trow = batch::trace_overhead(&c);
            // Durability tripwire: same insert point with the WAL off vs
            // on — responses must be bit-identical and WAL-on must
            // retain ≥ 80% of WAL-off insert throughput.
            let wrow = batch::wal_overhead(&c);
            let bench_path = args.get_or("bench-out", "BENCH_batch_sweep.json");
            std::fs::write(
                &bench_path,
                batch::to_json(&c, &rows, &krows, Some(&trow), Some(&wrow)).to_string_pretty(),
            )
            .map_err(|e| e.to_string())?;
            println!("[written {bench_path}]");
            batch::print_verdict(&rows);
            batch::print_kernel_verdict(&krows);
            batch::print_trace_verdict(&trow);
            batch::print_wal_verdict(&wrow);
        }
        "ann" => {
            let mut c = if cfg.quick {
                ann::AnnSweepConfig::quick()
            } else {
                ann::AnnSweepConfig::paper()
            };
            c.seed = cfg.seed;
            // Shard-count axis: BENCH_ann_sweep.json then carries a
            // QPS-vs-shard-count series per (map, m) cell.
            if let Some(list) = args.get("shards") {
                c.shards = list
                    .split(',')
                    .map(|v| v.parse().map_err(|_| format!("bad --shards entry {v}")))
                    .collect::<Result<Vec<usize>, String>>()?;
                if c.shards.is_empty() || c.shards.contains(&0) {
                    return Err("--shards needs a comma list of counts ≥ 1".into());
                }
            }
            let rows = ann::run(&c);
            let csv = ann::to_csv(&rows);
            print!("{}", csv.to_markdown());
            let path = cfg.results_dir.join("ann_sweep.csv");
            csv.write_to(&path).map_err(|e| e.to_string())?;
            println!("[written {}]", path.display());
            // Machine-readable trajectory tracked across PRs alongside
            // BENCH_batch_sweep.json.
            let bench_path = args.get_or("bench-out", "BENCH_ann_sweep.json");
            std::fs::write(&bench_path, ann::to_json(&c, &rows).to_string_pretty())
                .map_err(|e| e.to_string())?;
            println!("[written {bench_path}]");
            ann::print_verdict(&rows);
        }
        "ablation" => {
            let mut c = if cfg.quick {
                ablations::AblationConfig::quick()
            } else {
                ablations::AblationConfig::default_sweep()
            };
            if let Some(t) = cfg.trials {
                c.trials = t;
            }
            c.threads = cfg.threads();
            let rows = ablations::run_variance_sweep(&c);
            let csv = ablations::to_csv(&rows);
            print!("{}", csv.to_markdown());
            let path = cfg.results_dir.join("ablation_variance.csv");
            csv.write_to(&path).map_err(|e| e.to_string())?;
            println!("[written {}]", path.display());
        }
        other => return Err(format!("unknown experiment {other}")),
    }
    Ok(())
}

fn cmd_bounds(args: &Args) -> Result<(), String> {
    let eps: f64 = args.get_parsed_or("eps", 0.5f64)?;
    let n: usize = args.get_parsed_or("n", 12usize)?;
    let r: usize = args.get_parsed_or("r", 10usize)?;
    let m: usize = args.get_parsed_or("m", 100usize)?;
    let delta: f64 = args.get_parsed_or("delta", 0.05f64)?;
    let tt = theory::tt_k_lower_bound(eps, n, r, m, delta);
    let cp = theory::cp_k_lower_bound(eps, n, r, m, delta);
    let (best, k) = theory::suggest_k(eps, n, r, m, delta);
    println!("Theorem 2 size bounds (ε={eps}, N={n}, R={r}, m={m}, δ={delta}):");
    println!("  k_TT ≳ {tt:.3e}");
    println!("  k_CP ≳ {cp:.3e}   (ratio CP/TT = {:.3e})", cp / tt);
    println!("  suggestion: {best} with k ≈ {k:.3e}");
    println!(
        "  variance bounds at k=100: TT {:.3e}, CP {:.3e}",
        theory::tt_variance_bound(n, r, 100),
        theory::cp_variance_bound(n, r, 100)
    );
    Ok(())
}

fn cmd_sketch(args: &Args, cfg: &AppConfig) -> Result<(), String> {
    // Demo of the §7 future-work extension: sketched low-rank SVD with a
    // tensorized (Definition-1) test matrix on a synthetic decaying-
    // spectrum matrix whose columns factorize over `--col-dims`.
    use tensorized_rp::linalg::{qr, Matrix};
    use tensorized_rp::sketch::{sketched_svd, SketchConfig};
    let rows: usize = args.get_parsed_or("rows", 64usize)?;
    let rank: usize = args.get_parsed_or("rank", 8usize)?;
    let tt_rank: usize = args.get_parsed_or("tt-rank", 3usize)?;
    let col_dims: Vec<usize> = args
        .get_or("col-dims", "4,4,4,4")
        .split(',')
        .map(|s| s.parse().map_err(|_| format!("bad --col-dims entry {s}")))
        .collect::<Result<_, String>>()?;
    let cols: usize = col_dims.iter().product();
    let mut rng = Rng::seed_from(cfg.seed);
    // Synthetic matrix with geometric spectrum 0.7^i.
    let (u, _) = qr(&Matrix::from_vec(rows, rows, rng.gaussian_vec(rows * rows, 1.0)));
    let (v, _) = qr(&Matrix::from_vec(cols, cols.min(rows), {
        let n = cols * cols.min(rows);
        rng.gaussian_vec(n, 1.0)
    }));
    let mut a = Matrix::zeros(rows, cols);
    for r in 0..rows.min(cols) {
        let sv = 0.7f64.powi(r as i32);
        for i in 0..rows {
            for j in 0..cols {
                a[(i, j)] += sv * u[(i, r)] * v[(j, r)];
            }
        }
    }
    let t = tensorized_rp::util::Timer::start();
    let out = sketched_svd(
        &a,
        &col_dims,
        SketchConfig { rank, oversample: 8, tt_rank, seed: cfg.seed },
    );
    let secs = t.elapsed_secs();
    let err = tensorized_rp::linalg::rel_err(a.data(), out.svd.reconstruct().data());
    println!(
        "sketched SVD: {rows}×{cols} → rank {rank} in {:.1} ms | rel err {err:.4} | \
         tensorized Ω stores {} params (dense Ω would store {})",
        secs * 1e3,
        out.omega_params,
        cols * (rank + 8)
    );
    Ok(())
}

/// Run the determinism & concurrency lint over this crate's own source
/// tree (`trp lint [--json] [--baseline FILE] [--write-baseline]
/// [--root DIR]`). Exit status is the gate: nonzero iff any finding is
/// neither waived at the site nor absorbed by the baseline.
fn cmd_lint(args: &Args) -> Result<(), String> {
    use tensorized_rp::analysis::{self, baseline::Baseline};
    let root = match args.get("root") {
        Some(dir) => std::path::PathBuf::from(dir),
        // Default to the crate we were built from; fall back to the
        // current directory when it looks like a crate root (CI runs
        // from a fresh checkout where the embedded path still holds).
        None => {
            let here = std::path::Path::new("src");
            if here.is_dir() && std::path::Path::new("Cargo.toml").is_file() {
                std::path::PathBuf::from(".")
            } else {
                std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            }
        }
    };
    let bpath = args
        .get("baseline")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| root.join("lint_baseline.txt"));
    if args.flag("write-baseline") {
        let rows = analysis::baseline_rows(&root)?;
        let n = rows.len();
        std::fs::write(&bpath, Baseline::render(&rows))
            .map_err(|e| format!("write {}: {e}", bpath.display()))?;
        println!("[lint] grandfathered {n} findings into {}", bpath.display());
        return Ok(());
    }
    let baseline = Baseline::load(&bpath)?;
    let report = analysis::lint_root(&root, baseline)?;
    if args.flag("json") {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        print!("{}", report.to_text());
    }
    if report.violations.is_empty() {
        Ok(())
    } else {
        Err(format!("{} unwaived lint violations", report.violations.len()))
    }
}

/// Offline inspection of a write-ahead-log directory. `trp wal verify
/// [--dir D] [--json]` scans every segment chain (headers, checksums,
/// sequence continuity) and exits nonzero on any corruption that recovery
/// replay would refuse — torn final records are tolerated and reported as
/// `torn_bytes`. `trp wal dump [--dir D]` prints every decodable record.
fn cmd_wal(args: &Args) -> Result<(), String> {
    use tensorized_rp::index::wal;
    use tensorized_rp::util::json::{obj, Json};
    let action = args.pos(1).ok_or("wal needs an action: verify|dump")?;
    let dir = std::path::PathBuf::from(args.get_or("dir", "wal"));
    match action {
        "verify" => {
            let reports = wal::verify_dir(&dir)?;
            let bad: Vec<&str> = reports
                .iter()
                .filter(|r| r.error.is_some())
                .map(|r| r.stem.as_str())
                .collect();
            if args.flag("json") {
                let stems: Vec<Json> = reports
                    .iter()
                    .map(|r| {
                        let lanes: Vec<Json> = r
                            .lanes
                            .iter()
                            .map(|l| {
                                obj(vec![
                                    ("shard", Json::Num(f64::from(l.shard))),
                                    ("segments", Json::Num(l.segments as f64)),
                                    ("records", Json::Num(l.records as f64)),
                                    ("first_seq", Json::Num(l.first_seq as f64)),
                                    ("last_seq", Json::Num(l.last_seq as f64)),
                                    ("torn_bytes", Json::Num(l.torn_bytes as f64)),
                                    ("bytes", Json::Num(l.bytes as f64)),
                                ])
                            })
                            .collect();
                        let mut fields = vec![
                            ("stem", Json::Str(r.stem.clone())),
                            ("ok", Json::Num(f64::from(u8::from(r.error.is_none())))),
                            ("lanes", Json::Arr(lanes)),
                        ];
                        if let Some(e) = &r.error {
                            fields.push(("error", Json::Str(e.clone())));
                        }
                        obj(fields)
                    })
                    .collect();
                let report = obj(vec![
                    ("dir", Json::Str(dir.display().to_string())),
                    ("stems", Json::Arr(stems)),
                    ("corrupt", Json::Num(bad.len() as f64)),
                ]);
                println!("{}", report.to_string_pretty());
            } else {
                for r in &reports {
                    println!("[wal] {}: {}", r.stem, r.error.as_deref().unwrap_or("ok"));
                    for l in &r.lanes {
                        println!(
                            "  shard{} segs={} records={} seq={}..={} torn_bytes={} bytes={}",
                            l.shard,
                            l.segments,
                            l.records,
                            l.first_seq,
                            l.last_seq,
                            l.torn_bytes,
                            l.bytes
                        );
                    }
                }
                if reports.is_empty() {
                    println!("[wal] {}: no segments", dir.display());
                }
            }
            if bad.is_empty() {
                Ok(())
            } else {
                Err(format!("wal verify: corruption in {}", bad.join(", ")))
            }
        }
        "dump" => {
            for (stem, lanes) in wal::scan_dir(&dir)? {
                for (shard, files) in &lanes {
                    match wal::read_lane(files) {
                        Ok(Some(stream)) => {
                            for rec in &stream.records {
                                let op = if rec.op == wal::WAL_OP_DELETE {
                                    "delete"
                                } else {
                                    "insert"
                                };
                                println!(
                                    "{stem} shard={shard} seq={} {op} id={} dim={}",
                                    rec.seq,
                                    rec.id,
                                    rec.payload.len()
                                );
                            }
                        }
                        Ok(None) => println!("# {stem}.shard{shard}: torn header only"),
                        Err(e) => println!("# {stem}.shard{shard}: {e}"),
                    }
                }
            }
            Ok(())
        }
        other => Err(format!("unknown wal action {other} (verify|dump)")),
    }
}

fn cmd_artifacts(cfg: &AppConfig) -> Result<(), String> {
    let mut engine = PjrtEngine::cpu().map_err(|e| e.to_string())?;
    let n = engine.load_dir(&cfg.artifacts_dir).map_err(|e| e.to_string())?;
    println!("[artifacts] compiled {n} artifacts on {}", engine.platform());
    for name in engine.artifact_names() {
        let spec = engine.spec(&name).unwrap();
        println!(
            "  {name}: kind={:?} k={} B={} pallas={} params={}",
            spec.kind,
            spec.k,
            spec.batch,
            spec.use_pallas,
            spec.params.len()
        );
    }
    // Smoke-execute one TT artifact through the coordinator and report the
    // squared norm (≈ 1 for unit inputs).
    let names = engine.artifact_names();
    if let Some(name) = names.iter().find(|n| {
        engine.spec(n).map(|s| s.kind == tensorized_rp::runtime::ArtifactKind::Tt) == Some(true)
    }) {
        let spec = engine.spec(name).unwrap().clone();
        let (n_modes, d, _r, rt) = spec.tt_meta().map_err(|e| e.to_string())?;
        let mut rng = Rng::seed_from(7);
        let x = tensorized_rp::tensor::TtTensor::random_unit(&vec![d; n_modes], rt, &mut rng);
        let coord = Coordinator::start(
            CoordinatorConfig { master_seed: cfg.seed, ..Default::default() },
            Some(engine),
        );
        let resp = coord.project_blocking(ProjectRequest::new(0, AnyTensor::Tt(x)))?;
        println!(
            "  smoke: {name} → ‖y‖² = {:.4} via {}",
            tensorized_rp::projections::squared_norm(&resp.embedding),
            resp.path
        );
        coord.shutdown();
    }
    Ok(())
}
