//! Iterative radix-2 complex FFT + circular convolution.
//!
//! Substrate for the Tensor Sketch baseline (Pham & Pagh 2013, the
//! paper's related work): sketching a Kronecker/CP structure reduces to
//! circular convolutions of count-sketches, computed here via FFT. No FFT
//! crate offline, so this is a from-scratch iterative Cooley-Tukey with a
//! wrap-around trick so *any* convolution length is supported with
//! power-of-two transforms.

use std::f64::consts::PI;

/// In-place iterative radix-2 FFT over interleaved complex buffers.
/// `inverse = true` computes the unscaled inverse (caller divides by n).
fn fft_pow2(re: &mut [f64], im: &mut [f64], inverse: bool) {
    let n = re.len();
    debug_assert!(n.is_power_of_two());
    debug_assert_eq!(im.len(), n);
    // Bit-reversal permutation.
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    // Butterflies.
    let mut len = 2;
    while len <= n {
        let ang = 2.0 * PI / len as f64 * if inverse { 1.0 } else { -1.0 };
        let (wr, wi) = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let mut cur_r = 1.0f64;
            let mut cur_i = 0.0f64;
            for k in 0..len / 2 {
                let (ar, ai) = (re[i + k], im[i + k]);
                let (br, bi) = (re[i + k + len / 2], im[i + k + len / 2]);
                let tr = br * cur_r - bi * cur_i;
                let ti = br * cur_i + bi * cur_r;
                re[i + k] = ar + tr;
                im[i + k] = ai + ti;
                re[i + k + len / 2] = ar - tr;
                im[i + k + len / 2] = ai - ti;
                let nr = cur_r * wr - cur_i * wi;
                cur_i = cur_r * wi + cur_i * wr;
                cur_r = nr;
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Complex spectrum of a real signal, zero-padded to `n_fft` (power of 2).
pub fn rfft(signal: &[f64], n_fft: usize) -> (Vec<f64>, Vec<f64>) {
    assert!(n_fft.is_power_of_two());
    assert!(n_fft >= signal.len());
    let mut re = vec![0.0; n_fft];
    let mut im = vec![0.0; n_fft];
    re[..signal.len()].copy_from_slice(signal);
    fft_pow2(&mut re, &mut im, false);
    (re, im)
}

/// Pointwise complex multiply: `a *= b`.
pub fn spectrum_mul(ar: &mut [f64], ai: &mut [f64], br: &[f64], bi: &[f64]) {
    for k in 0..ar.len() {
        let r = ar[k] * br[k] - ai[k] * bi[k];
        let i = ar[k] * bi[k] + ai[k] * br[k];
        ar[k] = r;
        ai[k] = i;
    }
}

/// Inverse FFT returning the real part (scaled).
pub fn irfft(re: &mut [f64], im: &mut [f64]) -> Vec<f64> {
    let n = re.len();
    fft_pow2(re, im, true);
    re.iter().map(|&x| x / n as f64).collect()
}

/// Circular convolution of length `n` (any `n`): linear convolution via a
/// power-of-two FFT of size ≥ `2n−1`, then wrap-around mod `n`.
pub fn circular_convolve(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n == 0 {
        return Vec::new();
    }
    if n == 1 {
        return vec![a[0] * b[0]];
    }
    // Small sizes: direct O(n²) beats FFT overhead.
    if n <= 32 {
        let mut out = vec![0.0; n];
        for i in 0..n {
            let av = a[i];
            if av == 0.0 {
                continue;
            }
            for j in 0..n {
                out[(i + j) % n] += av * b[j];
            }
        }
        return out;
    }
    let n_fft = (2 * n - 1).next_power_of_two();
    let (mut ar, mut ai) = rfft(a, n_fft);
    let (br, bi) = rfft(b, n_fft);
    spectrum_mul(&mut ar, &mut ai, &br, &bi);
    let lin = irfft(&mut ar, &mut ai);
    let mut out = vec![0.0; n];
    for (i, &v) in lin.iter().take(2 * n - 1).enumerate() {
        out[i % n] += v;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn convolve_naive(a: &[f64], b: &[f64]) -> Vec<f64> {
        let n = a.len();
        let mut out = vec![0.0; n];
        for i in 0..n {
            for j in 0..n {
                out[(i + j) % n] += a[i] * b[j];
            }
        }
        out
    }

    #[test]
    fn fft_roundtrip() {
        let mut rng = Rng::seed_from(1);
        let x = rng.gaussian_vec(64, 1.0);
        let (mut re, mut im) = rfft(&x, 64);
        let back = irfft(&mut re, &mut im);
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn parseval_holds() {
        let mut rng = Rng::seed_from(2);
        let x = rng.gaussian_vec(128, 1.0);
        let (re, im) = rfft(&x, 128);
        let time: f64 = x.iter().map(|v| v * v).sum();
        let freq: f64 = re.iter().zip(&im).map(|(r, i)| r * r + i * i).sum::<f64>() / 128.0;
        assert!((time - freq).abs() < 1e-8 * time);
    }

    #[test]
    fn circular_convolution_matches_naive_all_sizes() {
        let mut rng = Rng::seed_from(3);
        for n in [1usize, 2, 3, 7, 16, 33, 50, 100, 127] {
            let a = rng.gaussian_vec(n, 1.0);
            let b = rng.gaussian_vec(n, 1.0);
            let fast = circular_convolve(&a, &b);
            let slow = convolve_naive(&a, &b);
            for (x, y) in fast.iter().zip(&slow) {
                assert!((x - y).abs() < 1e-9, "n={n}");
            }
        }
    }

    #[test]
    fn convolution_with_delta_is_shift() {
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let mut delta = vec![0.0; 5];
        delta[1] = 1.0; // shift by one
        let out = circular_convolve(&a, &delta);
        let rounded: Vec<f64> = out.iter().map(|x| x.round()).collect();
        assert_eq!(rounded, vec![5.0, 1.0, 2.0, 3.0, 4.0]);
    }
}
