//! Dense linear algebra substrate.
//!
//! No BLAS/LAPACK binding is available offline, so the kernels the tensor
//! layer needs are implemented here from scratch:
//!
//! * [`Matrix`] — a minimal row-major matrix type,
//! * [`matmul`] / [`Matrix::matmul`] — packed, register-tiled GEMM with
//!   an AVX2 microkernel behind runtime feature detection (see
//!   [`gemm`] for the kernel architecture and determinism contract),
//! * [`qr`] — Householder QR (thin), used by TT orthogonalization,
//! * [`svd`] — one-sided Jacobi SVD, used by TT-SVD and TT-rounding.
//!
//! All routines are deterministic and carry unit tests against algebraic
//! identities (reconstruction, orthogonality, known decompositions).

pub mod fft;
pub mod gemm;
mod matrix;
mod qr;
mod svd;

pub use gemm::{
    gemm_threads, matmul, matmul_acc, matmul_acc_with_threads, matmul_gather_scatter_acc,
    matmul_into, matvec, set_gemm_threads,
};
pub use matrix::Matrix;
pub use qr::qr;
pub use svd::{svd, Svd};

/// Frobenius-norm relative error `‖a − b‖ / max(‖a‖, 1e-300)`.
pub fn rel_err(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in a.iter().zip(b) {
        num += (x - y) * (x - y);
        den += x * x;
    }
    (num / den.max(1e-300)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_err_zero_for_identical() {
        let a = [1.0, 2.0, 3.0];
        assert_eq!(rel_err(&a, &a), 0.0);
    }

    #[test]
    fn rel_err_scales() {
        let a = [1.0, 0.0];
        let b = [1.1, 0.0];
        assert!((rel_err(&a, &b) - 0.1).abs() < 1e-12);
    }
}
