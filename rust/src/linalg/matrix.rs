//! Minimal row-major dense matrix.

use std::fmt;

/// Row-major `rows × cols` matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Self { rows, cols, data }
    }

    /// Build from a slice of rows.
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Underlying row-major buffer.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable row-major buffer.
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the underlying buffer.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Matrix product `self · other` (cache-blocked).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "inner dimension mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        super::gemm::matmul_into(
            self.data(),
            other.data(),
            out.data_mut(),
            self.rows,
            self.cols,
            other.cols,
        );
        out
    }

    /// Matrix product `selfᵀ · other` without materializing the
    /// transpose: the GEMM packs A through a column-stride gather
    /// ([`super::gemm::matmul_gather_scatter_acc`]), so the result is
    /// bit-identical to `self.transpose().matmul(other)` while skipping
    /// the `rows × cols` copy. Used by the sketching and theory layers
    /// for their Gram/projection products.
    pub fn t_matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "inner dimension mismatch");
        let mut out = Matrix::zeros(self.cols, other.cols);
        let n = other.cols;
        super::gemm::matmul_gather_scatter_acc(
            |i, p| self.data[p * self.cols + i],
            other.data(),
            out.data_mut(),
            self.cols,
            self.rows,
            n,
            |i| i * n,
        );
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Scale in place.
    pub fn scale(&mut self, s: f64) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// Extract the leading `r` columns.
    pub fn leading_cols(&self, r: usize) -> Matrix {
        assert!(r <= self.cols);
        let mut m = Matrix::zeros(self.rows, r);
        for i in 0..self.rows {
            m.row_mut(i).copy_from_slice(&self.row(i)[..r]);
        }
        m
    }

    /// Extract the leading `r` rows.
    pub fn leading_rows(&self, r: usize) -> Matrix {
        assert!(r <= self.rows);
        Matrix::from_vec(r, self.cols, self.data[..r * self.cols].to_vec())
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            writeln!(f, "  {:?}", &self.row(i)[..self.cols.min(8)])?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_transpose() {
        let m = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(m[(0, 2)], 3.0);
        assert_eq!(m[(1, 0)], 4.0);
        let t = m.transpose();
        assert_eq!(t.rows(), 3);
        assert_eq!(t[(2, 0)], 3.0);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn identity_matmul() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let i = Matrix::identity(2);
        assert_eq!(m.matmul(&i), m);
        assert_eq!(i.matmul(&m), m);
    }

    #[test]
    fn known_product() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Matrix::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn t_matmul_matches_materialized_transpose() {
        let mut rng = crate::rng::Rng::seed_from(31);
        let (r, c, n) = (23, 9, 14);
        let a = Matrix::from_vec(r, c, rng.gaussian_vec(r * c, 1.0));
        let b = Matrix::from_vec(r, n, rng.gaussian_vec(r * n, 1.0));
        let fused = a.t_matmul(&b);
        let materialized = a.transpose().matmul(&b);
        assert_eq!(fused.rows(), c);
        assert_eq!(fused.cols(), n);
        // Bit-identical, not just close: same kernel, same chains.
        for (x, y) in fused.data().iter().zip(materialized.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn leading_blocks() {
        let m = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(m.leading_cols(2).data(), &[1.0, 2.0, 4.0, 5.0]);
        assert_eq!(m.leading_rows(1).data(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn fro_norm() {
        let m = Matrix::from_rows(&[&[3.0, 4.0]]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-12);
    }
}
