//! Cache-blocked GEMM on row-major buffers.
//!
//! This is the single hottest primitive in the repository: every TT/CP
//! contraction in `projections::` reduces to small-to-medium GEMMs. The
//! implementation uses:
//!
//! * loop order `i-k-j` (row-major friendly: the inner loop streams both
//!   `b` and `c` contiguously and autovectorizes to FMA),
//! * `K_BLK × J_BLK` cache blocking to keep the `b` panel in L1/L2,
//! * a fused accumulate variant ([`matmul_acc`]) used by the batched
//!   projection paths to avoid zeroing temporaries.

/// Tile size along the reduction (k) dimension.
const K_BLK: usize = 64;
/// Tile size along the output-column (j) dimension.
const J_BLK: usize = 256;

/// `c = a · b` where `a` is `m×k`, `b` is `k×n`, `c` is `m×n` (row-major).
pub fn matmul_into(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "a size");
    assert_eq!(b.len(), k * n, "b size");
    assert_eq!(c.len(), m * n, "c size");
    c.fill(0.0);
    matmul_acc(a, b, c, m, k, n);
}

/// `c += a · b` (same layout as [`matmul_into`]).
pub fn matmul_acc(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    // Small-n fast path: blocking overhead dominates below a tile.
    if n <= 8 || k <= 8 {
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for (p, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[p * n..(p + 1) * n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
        return;
    }
    let mut kb = 0;
    while kb < k {
        let kend = (kb + K_BLK).min(k);
        let mut jb = 0;
        while jb < n {
            let jend = (jb + J_BLK).min(n);
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n + jb..i * n + jend];
                for p in kb..kend {
                    let av = arow[p];
                    let brow = &b[p * n + jb..p * n + jend];
                    // Autovectorizes: contiguous fused multiply-add.
                    for (cj, bj) in crow.iter_mut().zip(brow) {
                        *cj += av * bj;
                    }
                }
            }
            jb = jend;
        }
        kb = kend;
    }
}

/// Allocating wrapper around [`matmul_into`].
pub fn matmul(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0.0; m * n];
    matmul_into(a, b, &mut c, m, k, n);
    c
}

/// Matrix-vector product `y = a · x` for row-major `a` (`m×k`).
pub fn matvec(a: &[f64], x: &[f64], m: usize, k: usize) -> Vec<f64> {
    assert_eq!(a.len(), m * k);
    assert_eq!(x.len(), k);
    let mut y = vec![0.0; m];
    for i in 0..m {
        let row = &a[i * k..(i + 1) * k];
        let mut acc = 0.0;
        for (av, xv) in row.iter().zip(x) {
            acc += av * xv;
        }
        y[i] = acc;
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Naive reference used to validate the blocked kernel.
    fn matmul_naive(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    acc += a[i * k + p] * b[p * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn matches_naive_on_random_shapes() {
        let mut rng = Rng::seed_from(12);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 5, 2),
            (7, 8, 9),
            (16, 64, 16),
            (33, 129, 257), // crosses both block boundaries
            (2, 300, 5),    // small-n fast path with large k
        ] {
            let a = rng.gaussian_vec(m * k, 1.0);
            let b = rng.gaussian_vec(k * n, 1.0);
            let c = matmul(&a, &b, m, k, n);
            let r = matmul_naive(&a, &b, m, k, n);
            assert!(super::super::rel_err(&c, &r) < 1e-12, "shape {m}x{k}x{n}");
        }
    }

    #[test]
    fn acc_accumulates() {
        let a = [1.0, 0.0, 0.0, 1.0];
        let b = [2.0, 0.0, 0.0, 2.0];
        let mut c = vec![1.0; 4];
        matmul_acc(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, vec![3.0, 1.0, 1.0, 3.0]);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::seed_from(8);
        let (m, k) = (17, 43);
        let a = rng.gaussian_vec(m * k, 1.0);
        let x = rng.gaussian_vec(k, 1.0);
        let y = matvec(&a, &x, m, k);
        let y2 = matmul(&a, &x, m, k, 1);
        assert!(super::super::rel_err(&y, &y2) < 1e-13);
    }

    #[test]
    fn empty_dims_are_noops() {
        let c = matmul(&[], &[], 0, 0, 0);
        assert!(c.is_empty());
        let c = matmul(&[], &[], 0, 3, 0);
        assert!(c.is_empty());
    }
}
